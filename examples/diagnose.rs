//! Diagnosis scenario: a 16-GPU BERT job is mysteriously slow. dPRO's
//! profiler + replayer identify the culprit from the critical path of the
//! execution graph — without access to the cluster internals.
//!
//! Two injected faults: a straggler GPU (thermal throttling) and a slow
//! NIC (mis-negotiated link rate) — the classic cases from paper §1.

use dpro::baselines::deployed_default;
use dpro::config::{JobSpec, Transport};
use dpro::profiler;
use dpro::testbed::{run as testbed_run, Straggler, TestbedOpts};
use dpro::util::fmt_us;
use std::collections::HashMap;

fn diagnose(name: &str, spec: &JobSpec, opts: &TestbedOpts) {
    let tb = testbed_run(spec, opts);
    let est = profiler::estimate(spec, &tb.trace, true);
    let path = est.result.critical_path();

    // attribute critical-path time per worker and per op kind
    let mut per_proc: HashMap<u16, f64> = HashMap::new();
    let mut per_kind: HashMap<&'static str, f64> = HashMap::new();
    for &n in &path {
        let node = est.graph.dfg.node(n);
        let d = est.result.end[n as usize] - est.result.start[n as usize];
        *per_proc.entry(node.owner).or_default() += d;
        *per_kind.entry(dpro::trace::kind_str(node.kind)).or_default() += d;
    }
    let mut procs: Vec<_> = per_proc.into_iter().collect();
    procs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut kinds: Vec<_> = per_kind.into_iter().collect();
    kinds.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

    println!("--- {name} ---");
    println!("iteration: {}   critical path: {} ops", fmt_us(tb.avg_iter()), path.len());
    print!("critical-path time by kind: ");
    for (k, t) in kinds.iter().take(4) {
        print!("{k}={} ", fmt_us(*t));
    }
    println!();
    println!(
        "worker dominating the critical path: w{} ({})",
        procs[0].0,
        fmt_us(procs[0].1)
    );
    println!();
}

fn main() {
    let base = deployed_default(&JobSpec::standard("bert_base", "horovod", Transport::Rdma));

    diagnose("healthy cluster", &base, &TestbedOpts { iterations: 5, ..Default::default() });

    diagnose(
        "straggler GPU (w11 throttled 1.8x)",
        &base,
        &TestbedOpts {
            iterations: 5,
            stragglers: vec![Straggler::SlowGpu { worker: 11, factor: 1.8 }],
            ..Default::default()
        },
    );

    diagnose(
        "slow NIC (machine 1 at 3x slower)",
        &base,
        &TestbedOpts {
            iterations: 5,
            stragglers: vec![Straggler::SlowLink { machine: 1, factor: 3.0 }],
            ..Default::default()
        },
    );

    println!("A straggler GPU shows up as one worker owning the computation segment of the");
    println!("critical path; a slow NIC shifts the path into SEND/RECV ops of that machine.");
}
