//! Quickstart: the full dPRO pipeline on one job —
//! profile (testbed) → align → replay → diagnose → optimize → validate.
//!
//! ```sh
//! cargo run --release --example quickstart [model] [scheme] [transport]
//! ```

use dpro::baselines;
use dpro::config::{JobSpec, Transport};
use dpro::optimizer::{optimize, SearchOpts};
use dpro::profiler;
use dpro::testbed::{run as testbed_run, TestbedOpts};
use dpro::util::stats::rel_err_pct;
use dpro::util::{fmt_bytes, fmt_us};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("resnet50");
    let scheme = args.get(1).map(String::as_str).unwrap_or("horovod");
    let transport = match args.get(2).map(String::as_str) {
        Some("tcp") => Transport::Tcp,
        _ => Transport::Rdma,
    };

    // A 16-GPU job with the communication library's *deployed defaults*
    // (Horovod 64 MB fusion buckets / BytePS 4 MB partitions).
    let spec = baselines::deployed_default(&JobSpec::standard(model, scheme, transport));
    println!(
        "== dPRO quickstart: {} × {} GPUs, {}, {} ==\n",
        spec.model.name,
        spec.cluster.n_workers,
        spec.scheme.name(),
        transport.name()
    );

    // 1. Profile: run the job on the ground-truth testbed and collect the
    //    fine-grained global trace (what the paper's profiler collects).
    let tb = testbed_run(&spec, &TestbedOpts { iterations: 10, ..Default::default() });
    println!("[profile] ground-truth iteration: {}", fmt_us(tb.avg_iter()));
    println!("[profile] {} trace events over 10 iterations", tb.trace.events.len());

    // 2. Align + replay: reconstruct the global DFG from the trace and
    //    simulate it (paper §4.2–4.3).
    let est = profiler::estimate(&spec, &tb.trace, true);
    let err = rel_err_pct(est.iteration_us(), tb.avg_iter());
    println!("\n[replay] estimated iteration: {} (error {:.2}%)", fmt_us(est.iteration_us()), err);
    println!("[replay] FW {} / BW {}", fmt_us(est.fw_us()), fmt_us(est.bw_us()));
    println!("[replay] est. peak memory: {}  (truth {})",
             fmt_bytes(est.peak_memory(&spec)), fmt_bytes(tb.peak_memory));

    // 3. Diagnose: show the tail of the critical path.
    let path = est.result.critical_path();
    println!("\n[diagnose] critical path has {} ops; tail:", path.len());
    let tail: Vec<_> = path.iter().rev().take(5).collect();
    for &n in tail.iter().rev() {
        let node = est.graph.dfg.node(*n);
        println!("  {:50} {:>10}", node.name, fmt_us(node.duration));
    }

    // 4. Optimize: Alg. 1 with all accelerations.
    let out = optimize(&spec, &SearchOpts { budget_wall_s: 30.0, ..Default::default() });
    println!(
        "\n[optimize] replayed {} → {} ({:.2}x) via {} passes in {:.1}s",
        fmt_us(out.baseline_iteration_us),
        fmt_us(out.est_iteration_us),
        out.speedup(),
        out.actions_applied,
        out.wall_s
    );

    // 5. Validate on the ground truth (the measurement the paper reports).
    let tb_opt = testbed_run(&out.spec, &TestbedOpts { iterations: 10, ..Default::default() });
    println!(
        "[validate] testbed: {} → {} ({:.2}x real speed-up)",
        fmt_us(tb.avg_iter()),
        fmt_us(tb_opt.avg_iter()),
        tb.avg_iter() / tb_opt.avg_iter()
    );
}
