//! Optimize a whole model zoo across communication schemes (a miniature of
//! paper Fig. 9): for each (model, scheme), search combined op-fusion +
//! tensor-fusion/partition strategies and validate the found strategies on
//! the ground-truth testbed against the deployed defaults and XLA.

use dpro::baselines;
use dpro::config::{JobSpec, Transport};
use dpro::optimizer::{optimize, SearchOpts};
use dpro::testbed::{run as testbed_run, TestbedOpts};

fn throughput(spec: &JobSpec) -> f64 {
    let r = testbed_run(spec, &TestbedOpts { iterations: 5, ..Default::default() });
    let imgs = (spec.cluster.n_workers * spec.model.batch_size) as f64;
    imgs / (r.avg_iter() / 1e6)
}

fn main() {
    println!("{:<14} {:<8} {:>12} {:>12} {:>12} {:>9}", "model", "scheme", "default/s",
             "xla/s", "dPRO/s", "speedup");
    for model in ["resnet50", "vgg16", "inception_v3", "bert_base"] {
        for scheme in ["horovod", "byteps"] {
            let spec = JobSpec::standard(model, scheme, Transport::Rdma);
            let deployed = baselines::deployed_default(&spec);
            let t_default = throughput(&deployed);

            let mut xla = deployed.clone();
            xla.fusion = baselines::xla_auto_cluster(&xla.model);
            let t_xla = throughput(&xla);

            let out = optimize(&deployed, &SearchOpts { budget_wall_s: 25.0, ..Default::default() });
            let t_dpro = throughput(&out.spec);

            println!(
                "{:<14} {:<8} {:>12.1} {:>12.1} {:>12.1} {:>8.2}x",
                model,
                scheme,
                t_default,
                t_xla,
                t_dpro,
                t_dpro / t_default.max(t_xla).max(1e-9)
            );
        }
    }
    println!("\n(samples/s on the ground-truth testbed, 16 GPUs, RDMA; dPRO column is the");
    println!(" combined OPFS+TSFS strategy found by Alg. 1 with all accelerations)");
}
