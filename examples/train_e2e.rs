//! End-to-end driver: proves the three layers compose on a real workload.
//!
//! 1. **Live training**: the Rust coordinator loads the AOT-compiled
//!    JAX+Pallas GPT (`gpt_mini`, ~14M params) via PJRT and trains it
//!    data-parallel for a few hundred steps on a synthetic corpus,
//!    logging the loss curve (written to `artifacts/loss_curve.json`).
//!    Computation is real (PJRT wall time); gradient AllReduce latency is
//!    simulated by the testbed network model (1 CPU, no NICs).
//! 2. **Capacity check**: a few steps of the ~110M-param `m100` config.
//! 3. **dPRO on the live job**: the coordinator's gTrace is replayed to
//!    predict step time, and the matching simulated 16-GPU job is
//!    optimized — the full paper pipeline on the system we just ran.
//!
//! Usage: cargo run --release --example train_e2e [--steps N] [--workers K]
//!        (requires `make artifacts` first)

use dpro::config::{JobSpec, Transport};
use dpro::coordinator::{train, TrainCfg};
use dpro::optimizer::{optimize, SearchOpts};
use dpro::util::json::Json;
use dpro::util::{fmt_us, Args};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize("steps", 200);
    let workers = args.usize("workers", 4);

    // ---- 1. live training of gpt_mini ----
    println!("== live data-parallel training: gpt_mini via PJRT ==");
    let cfg = TrainCfg {
        steps,
        n_workers: workers,
        log_every: 20,
        ..Default::default()
    };
    let report = train(&cfg)?;
    println!(
        "\nloss {:.4} -> {:.4} over {} steps | {:.0} tokens/s | {} params",
        report.losses.first().unwrap(),
        report.final_loss(),
        report.losses.len(),
        report.tokens_per_s(),
        report.n_params,
    );

    // loss curve to JSON for EXPERIMENTS.md
    let curve = Json::Arr(report.losses.iter().map(|&l| Json::Num(l as f64)).collect());
    let mut o = Json::obj();
    o.set("config", Json::Str("mini".into()));
    o.set("workers", Json::Num(workers as f64));
    o.set("losses", curve);
    o.set("tokens_per_s", Json::Num(report.tokens_per_s()));
    std::fs::write("artifacts/loss_curve.json", o.to_string_pretty())?;
    println!("wrote artifacts/loss_curve.json");

    // ---- 2. capacity check on the 110M-param config ----
    if std::path::Path::new("artifacts/gpt_m100.train.hlo.txt").exists() && !args.flag("skip-m100")
    {
        println!("\n== capacity check: gpt_m100 (~110M params), 3 steps ==");
        let big = TrainCfg {
            config: "m100".into(),
            steps: 3,
            n_workers: 1,
            log_every: 1,
            ..Default::default()
        };
        let r = train(&big)?;
        println!("m100 final loss {:.4} ({} params)", r.final_loss(), r.n_params);
    }

    // ---- 3. dPRO on the live job's trace ----
    println!("\n== dPRO replay of the live coordinator trace ==");
    // average measured step phases from the trace
    let db = report.trace.profile_db();
    let grad = db.get("w0.BW.grad_step").unwrap_or(0.0);
    let comm = db.get("allreduce.grads").unwrap_or(0.0);
    let apply = db.get("w0.UPD.apply_step").unwrap_or(0.0);
    println!(
        "measured phases: grad {} | allreduce(sim) {} | apply {}",
        fmt_us(grad),
        fmt_us(comm),
        fmt_us(apply)
    );
    println!("predicted step (serial phases, 1 device): {}", fmt_us(grad + comm + apply));

    // ---- and the paper pipeline on the matching simulated 16-GPU job ----
    println!("\n== optimizing the matching simulated 16-GPU gpt job ==");
    let spec = JobSpec::standard("gpt_mini", "horovod", Transport::Rdma);
    let out = optimize(&spec, &SearchOpts { budget_wall_s: 20.0, ..Default::default() });
    println!(
        "replayed {} -> {} ({:.2}x via {} passes)",
        fmt_us(out.baseline_iteration_us),
        fmt_us(out.est_iteration_us),
        out.speedup(),
        out.actions_applied
    );
    Ok(())
}
