//! Paper Fig. 10: large-scale behaviour up to 128 GPUs — (a) replay
//! accuracy of dPRO vs Daydream as the cluster grows, (b) throughput of
//! dPRO's combined strategies vs XLA default fusion (paper: up to 3.48x),
//! (c) replay scaling across **all registered comm schemes** in one table.

use dpro::baselines::{self, daydream};
use dpro::config::{ClusterSpec, JobSpec, NetworkSpec, ALL_SCHEMES};
use dpro::optimizer::{optimize, SearchOpts};
use dpro::profiler;
use dpro::testbed::{run, TestbedOpts};
use dpro::util::print_table;
use dpro::util::stats::rel_err_pct;

fn scheme_spec_for(model: &str, scheme: &str, gpus: usize) -> JobSpec {
    let model = dpro::models::by_name(model, 32).unwrap();
    let mut cluster = ClusterSpec::new(gpus, 8, NetworkSpec::rdma_100g());
    cluster.clock.drift_std_us = 600.0 * (gpus as f64 / 8.0).sqrt();
    // JobSpec::new seeds per-tensor/singleton plans; deployed_default then
    // swaps in the scheme's real-world defaults (fusion buckets / 4 MB
    // partitions)
    baselines::deployed_default(&JobSpec::with_scheme_name(model, cluster, scheme))
}

fn spec_for(model: &str, gpus: usize) -> JobSpec {
    scheme_spec_for(model, "horovod", gpus)
}

fn main() {
    let budget = std::env::var("DPRO_BENCH_BUDGET_S").ok().and_then(|s| s.parse().ok()).unwrap_or(25.0);
    println!("\n=== Fig. 10(a): replay accuracy at scale (Horovod RDMA) ===\n");
    let mut rows = Vec::new();
    for model in ["resnet50", "bert_base"] {
        for gpus in [16usize, 32, 64, 128] {
            let spec = spec_for(model, gpus);
            let iters = if gpus >= 64 { 4 } else { 8 };
            let tb = run(&spec, &TestbedOpts { iterations: iters, ..Default::default() });
            let est = profiler::estimate(&spec, &tb.trace, true);
            let db = profiler::corrected_profile(&tb.trace, &dpro::alignment::Alignment::identity());
            let dd = daydream::estimate(&spec, Some(&db));
            rows.push(vec![
                model.to_string(),
                format!("{gpus}"),
                format!("{:.1}", tb.avg_iter() / 1e3),
                format!("{:.2}%", rel_err_pct(est.iteration_us(), tb.avg_iter())),
                format!("{:.2}%", rel_err_pct(dd.iteration_us, tb.avg_iter())),
            ]);
        }
    }
    print_table(&["model", "GPUs", "truth (ms)", "dPRO err", "Daydream err"], &rows);

    println!("\n=== Fig. 10(b): dPRO combined strategies vs XLA at scale ===\n");
    let mut rows = Vec::new();
    for model in ["resnet50", "bert_base"] {
        for gpus in [16usize, 64, 128] {
            let spec = spec_for(model, gpus);
            let mut xla = spec.clone();
            xla.fusion = baselines::xla_auto_cluster(&xla.model);
            let t_xla = run(&xla, &TestbedOpts { iterations: 3, ..Default::default() }).avg_iter();
            let out = optimize(&spec, &SearchOpts { budget_wall_s: budget, max_rounds: 10, ..Default::default() });
            let t_dpro = run(&out.spec, &TestbedOpts { iterations: 3, ..Default::default() }).avg_iter();
            let thr = |t: f64| (gpus * spec.model.batch_size) as f64 / (t / 1e6);
            rows.push(vec![
                model.to_string(),
                format!("{gpus}"),
                format!("{:.0}", thr(t_xla)),
                format!("{:.0}", thr(t_dpro)),
                format!("{:.2}x", t_xla / t_dpro),
            ]);
        }
    }
    print_table(&["model", "GPUs", "XLA (samples/s)", "dPRO (samples/s)", "speedup"], &rows);
    println!("\npaper: dPRO's combined strategies scale best, up to 3.48x over XLA at 128 GPUs");

    println!("\n=== Fig. 10(c): replay scaling across comm schemes (resnet50, RDMA) ===\n");
    let mut rows = Vec::new();
    for scheme in ALL_SCHEMES {
        for gpus in [16usize, 32] {
            let spec = scheme_spec_for("resnet50", scheme, gpus);
            let tb = run(&spec, &TestbedOpts { iterations: 5, ..Default::default() });
            let est = profiler::estimate(&spec, &tb.trace, true);
            let props = dpro::graph::plan_props(&spec);
            rows.push(vec![
                spec.scheme.name().to_string(),
                format!("{gpus}"),
                format!("{}", props.stages_per_group),
                format!("{:.1}", tb.avg_iter() / 1e3),
                format!("{:.1}", est.iteration_us() / 1e3),
                format!("{:.2}%", rel_err_pct(est.iteration_us(), tb.avg_iter())),
            ]);
        }
    }
    print_table(
        &["scheme", "GPUs", "stages/group", "truth (ms)", "replay (ms)", "err"],
        &rows,
    );
    println!("\nall schemes flow through the same comm-plan IR: replay accuracy is scheme-independent");
}
