//! Paper Fig. 10: large-scale behaviour up to 128 GPUs — (a) replay
//! accuracy of dPRO vs Daydream as the cluster grows, (b) throughput of
//! dPRO's combined strategies vs XLA default fusion (paper: up to 3.48x),
//! (c) replay scaling across **all registered comm schemes** in one table,
//! (d) fleet-scale replay at 1k–4k workers: tiered (symmetry-class)
//! simulation vs exact event replay, in rounds/sec. Section (d) is
//! emitted to `BENCH_fig10_scalability.json` for the CI perf trajectory.

use std::time::Instant;

use dpro::baselines::{self, daydream};
use dpro::config::{ClusterSpec, JobSpec, NetworkSpec, ALL_SCHEMES};
use dpro::graph::{build_global_nameless, AnalyticCost};
use dpro::optimizer::{optimize, SearchOpts};
use dpro::profiler;
use dpro::replay::tiered::TieredReplayer;
use dpro::replay::Replayer;
use dpro::testbed::{run, TestbedOpts};
use dpro::util::json::Json;
use dpro::util::print_table;
use dpro::util::stats::rel_err_pct;

fn scheme_spec_for(model: &str, scheme: &str, gpus: usize) -> JobSpec {
    let model = dpro::models::by_name(model, 32).unwrap();
    let mut cluster = ClusterSpec::new(gpus, 8, NetworkSpec::rdma_100g());
    cluster.clock.drift_std_us = 600.0 * (gpus as f64 / 8.0).sqrt();
    // JobSpec::new seeds per-tensor/singleton plans; deployed_default then
    // swaps in the scheme's real-world defaults (fusion buckets / 4 MB
    // partitions)
    baselines::deployed_default(&JobSpec::with_scheme_name(model, cluster, scheme))
}

fn spec_for(model: &str, gpus: usize) -> JobSpec {
    scheme_spec_for(model, "horovod", gpus)
}

/// Replay rounds until `slice_s` elapses (at least one, at most 12);
/// returns (rounds/sec, last iteration estimate in us).
fn rounds_per_sec(mut one_round: impl FnMut() -> f64, slice_s: f64) -> (f64, f64) {
    let t0 = Instant::now();
    let mut iter_us = one_round();
    let mut rounds = 1usize;
    loop {
        let el = t0.elapsed().as_secs_f64();
        if el >= slice_s || rounds >= 12 {
            return (rounds as f64 / el.max(1e-9), iter_us);
        }
        iter_us = one_round();
        rounds += 1;
    }
}

/// Estimated resident simulator state per worker: the SoA per-node arrays
/// (durations, ready times, schedule, device/class ids ≈ 64 B/node) plus
/// the adjacency lists (each edge appears in one preds and one succs slot,
/// 4 B each). The point of the metric is that it stays flat per worker as
/// the fleet grows — a 4096-worker job must not cost more per worker than
/// a 16-worker one.
fn state_bytes_per_worker(nodes: usize, edges: usize, workers: usize) -> f64 {
    (nodes as f64 * 64.0 + edges as f64 * 8.0) / workers as f64
}

fn main() {
    let budget = std::env::var("DPRO_BENCH_BUDGET_S").ok().and_then(|s| s.parse().ok()).unwrap_or(25.0);
    println!("\n=== Fig. 10(a): replay accuracy at scale (Horovod RDMA) ===\n");
    let mut rows = Vec::new();
    for model in ["resnet50", "bert_base"] {
        for gpus in [16usize, 32, 64, 128] {
            let spec = spec_for(model, gpus);
            let iters = if gpus >= 64 { 4 } else { 8 };
            let tb = run(&spec, &TestbedOpts { iterations: iters, ..Default::default() });
            let est = profiler::estimate(&spec, &tb.trace, true);
            let db = profiler::corrected_profile(&tb.trace, &dpro::alignment::Alignment::identity());
            let dd = daydream::estimate(&spec, Some(&db));
            rows.push(vec![
                model.to_string(),
                format!("{gpus}"),
                format!("{:.1}", tb.avg_iter() / 1e3),
                format!("{:.2}%", rel_err_pct(est.iteration_us(), tb.avg_iter())),
                format!("{:.2}%", rel_err_pct(dd.iteration_us, tb.avg_iter())),
            ]);
        }
    }
    print_table(&["model", "GPUs", "truth (ms)", "dPRO err", "Daydream err"], &rows);

    println!("\n=== Fig. 10(b): dPRO combined strategies vs XLA at scale ===\n");
    let mut rows = Vec::new();
    for model in ["resnet50", "bert_base"] {
        for gpus in [16usize, 64, 128] {
            let spec = spec_for(model, gpus);
            let mut xla = spec.clone();
            xla.fusion = baselines::xla_auto_cluster(&xla.model);
            let t_xla = run(&xla, &TestbedOpts { iterations: 3, ..Default::default() }).avg_iter();
            let out = optimize(&spec, &SearchOpts { budget_wall_s: budget, max_rounds: 10, ..Default::default() });
            let t_dpro = run(&out.spec, &TestbedOpts { iterations: 3, ..Default::default() }).avg_iter();
            let thr = |t: f64| (gpus * spec.model.batch_size) as f64 / (t / 1e6);
            rows.push(vec![
                model.to_string(),
                format!("{gpus}"),
                format!("{:.0}", thr(t_xla)),
                format!("{:.0}", thr(t_dpro)),
                format!("{:.2}x", t_xla / t_dpro),
            ]);
        }
    }
    print_table(&["model", "GPUs", "XLA (samples/s)", "dPRO (samples/s)", "speedup"], &rows);
    println!("\npaper: dPRO's combined strategies scale best, up to 3.48x over XLA at 128 GPUs");

    println!("\n=== Fig. 10(c): replay scaling across comm schemes (resnet50, RDMA) ===\n");
    let mut rows = Vec::new();
    for scheme in ALL_SCHEMES {
        for gpus in [16usize, 32] {
            let spec = scheme_spec_for("resnet50", scheme, gpus);
            let tb = run(&spec, &TestbedOpts { iterations: 5, ..Default::default() });
            let est = profiler::estimate(&spec, &tb.trace, true);
            let props = dpro::graph::plan_props(&spec);
            rows.push(vec![
                spec.scheme.name().to_string(),
                format!("{gpus}"),
                format!("{}", props.stages_per_group),
                format!("{:.1}", tb.avg_iter() / 1e3),
                format!("{:.1}", est.iteration_us() / 1e3),
                format!("{:.2}%", rel_err_pct(est.iteration_us(), tb.avg_iter())),
            ]);
        }
    }
    print_table(
        &["scheme", "GPUs", "stages/group", "truth (ms)", "replay (ms)", "err"],
        &rows,
    );
    println!("\nall schemes flow through the same comm-plan IR: replay accuracy is scheme-independent");

    // ---- (d) fleet scale: tiered symmetry-class replay vs exact ----
    // No testbed run at this scale — the graph is built analytically and
    // replayed in both engines. horovod declares machine-rotation
    // symmetry, so tiered simulates one machine and derives the other
    // 127+ by translation; byteps (PS) declares none and demotes to
    // exact, which is the honest fallback row.
    println!("\n=== Fig. 10(d): fleet-scale replay — tiered vs exact (resnet50, RDMA) ===\n");
    let fleet: &[(&str, usize)] = if budget >= 60.0 {
        &[("horovod", 1024), ("horovod", 2048), ("horovod", 4096), ("byteps", 2048)]
    } else if budget >= 20.0 {
        &[("horovod", 1024), ("byteps", 2048)]
    } else {
        &[("horovod", 1024)]
    };
    // per-measurement time slice: enough rounds to be stable, bounded so
    // the exact-mode replay of a multi-million-node graph can't eat the
    // whole budget
    let slice = (budget / (6.0 * fleet.len() as f64)).clamp(0.5, 4.0);
    let mut rows = Vec::new();
    let mut jfleet = Vec::new();
    for &(scheme, workers) in fleet {
        let spec = scheme_spec_for("resnet50", scheme, workers);
        let t0 = Instant::now();
        let g = build_global_nameless(&spec, &AnalyticCost::new(&spec));
        let t_build = t0.elapsed().as_secs_f64();
        let nodes = g.dfg.len();
        let edges: usize = g.dfg.ids().map(|i| g.dfg.preds(i).len()).sum();

        let mut exact = Replayer::new(&g);
        exact.replay(&g); // warm: first replay pays allocation
        let (exact_rps, iter_us) = rounds_per_sec(|| exact.replay(&g).iteration_time, slice);

        let mut tiered = TieredReplayer::new(&g, &spec);
        tiered.replay(&g); // warm: pays symmetry verification + allocation
        let (tiered_rps, tiered_iter) =
            rounds_per_sec(|| tiered.replay(&g).iteration_time, slice);
        let rep = tiered.report().clone();
        assert_eq!(
            tiered_iter.to_bits(),
            iter_us.to_bits(),
            "tiered and exact disagree on {scheme}@{workers}"
        );

        let bpw = state_bytes_per_worker(nodes, edges, workers);
        rows.push(vec![
            scheme.to_string(),
            format!("{workers}"),
            format!("{}", spec.cluster.n_machines()),
            format!("{}", nodes),
            rep.mode_used.clone(),
            format!("{:.2}", exact_rps),
            format!("{:.2}", tiered_rps),
            format!("{:.1}x", tiered_rps / exact_rps),
            format!("{:.0}", bpw / 1024.0),
        ]);
        let mut j = Json::obj();
        j.set("scheme", Json::Str(scheme.to_string()));
        j.set("workers", Json::Num(workers as f64));
        j.set("machines", Json::Num(spec.cluster.n_machines() as f64));
        j.set("nodes", Json::Num(nodes as f64));
        j.set("edges", Json::Num(edges as f64));
        j.set("build_s", Json::Num(t_build));
        j.set("mode_used", Json::Str(rep.mode_used.clone()));
        j.set("simulated_nodes", Json::Num(rep.simulated_nodes as f64));
        j.set("derived_nodes", Json::Num(rep.derived_nodes as f64));
        j.set("exact_rounds_per_sec", Json::Num(exact_rps));
        j.set("tiered_rounds_per_sec", Json::Num(tiered_rps));
        j.set("tiered_speedup", Json::Num(tiered_rps / exact_rps));
        j.set("bytes_per_worker", Json::Num(bpw));
        j.set("iteration_ms", Json::Num(iter_us / 1e3));
        jfleet.push(j);
    }
    print_table(
        &[
            "scheme", "workers", "machines", "nodes", "mode", "exact r/s", "tiered r/s",
            "speedup", "KB/worker",
        ],
        &rows,
    );
    println!("\ntiered replay simulates one machine per symmetry class and derives the rest by");
    println!("timeline translation; asymmetric schemes demote to exact replay (same result).");

    let mut report = Json::obj();
    report.set("bench", Json::Str("fig10_scalability".to_string()));
    report.set("provenance", Json::Str("measured".to_string()));
    report.set("fleet", Json::Arr(jfleet));
    match std::fs::write("BENCH_fig10_scalability.json", report.to_string_pretty()) {
        Ok(()) => println!("\nwrote BENCH_fig10_scalability.json"),
        Err(e) => eprintln!("\ncould not write BENCH_fig10_scalability.json: {e}"),
    }
}
