//! Paper Fig. 10: large-scale behaviour up to 128 GPUs — (a) replay
//! accuracy of dPRO vs Daydream as the cluster grows, (b) throughput of
//! dPRO's combined strategies vs XLA default fusion (paper: up to 3.48x),
//! (c) replay scaling across **all registered comm schemes** in one table,
//! (d) fleet-scale replay at 1k–4k workers: tiered (symmetry-class)
//! simulation vs exact event replay, run as a campaign sweep. Section
//! (d)'s per-cell wall times, modes and campaign spec hash are emitted
//! to `BENCH_fig10_scalability.json` for the CI artifact trail.

use dpro::baselines::{self, daydream};
use dpro::campaign::{self, CampaignSpec, CellState, Filter, LaunchMode, RunOpts, Source};
use dpro::config::{ClusterSpec, JobSpec, NetworkSpec, ALL_SCHEMES};
use dpro::optimizer::{optimize, SearchOpts};
use dpro::profiler;
use dpro::replay::tiered::ReplayMode;
use dpro::testbed::{run, TestbedOpts};
use dpro::util::json::Json;
use dpro::util::print_table;
use dpro::util::stats::rel_err_pct;

fn scheme_spec_for(model: &str, scheme: &str, gpus: usize) -> JobSpec {
    let model = dpro::models::by_name(model, 32).unwrap();
    let mut cluster = ClusterSpec::new(gpus, 8, NetworkSpec::rdma_100g());
    cluster.clock.drift_std_us = 600.0 * (gpus as f64 / 8.0).sqrt();
    // JobSpec::new seeds per-tensor/singleton plans; deployed_default then
    // swaps in the scheme's real-world defaults (fusion buckets / 4 MB
    // partitions)
    baselines::deployed_default(&JobSpec::with_scheme_name(model, cluster, scheme))
}

fn spec_for(model: &str, gpus: usize) -> JobSpec {
    scheme_spec_for(model, "horovod", gpus)
}

fn main() {
    let budget = std::env::var("DPRO_BENCH_BUDGET_S").ok().and_then(|s| s.parse().ok()).unwrap_or(25.0);
    println!("\n=== Fig. 10(a): replay accuracy at scale (Horovod RDMA) ===\n");
    let mut rows = Vec::new();
    for model in ["resnet50", "bert_base"] {
        for gpus in [16usize, 32, 64, 128] {
            let spec = spec_for(model, gpus);
            let iters = if gpus >= 64 { 4 } else { 8 };
            let tb = run(&spec, &TestbedOpts { iterations: iters, ..Default::default() });
            let est = profiler::estimate(&spec, &tb.trace, true);
            let db = profiler::corrected_profile(&tb.trace, &dpro::alignment::Alignment::identity());
            let dd = daydream::estimate(&spec, Some(&db));
            rows.push(vec![
                model.to_string(),
                format!("{gpus}"),
                format!("{:.1}", tb.avg_iter() / 1e3),
                format!("{:.2}%", rel_err_pct(est.iteration_us(), tb.avg_iter())),
                format!("{:.2}%", rel_err_pct(dd.iteration_us, tb.avg_iter())),
            ]);
        }
    }
    print_table(&["model", "GPUs", "truth (ms)", "dPRO err", "Daydream err"], &rows);

    println!("\n=== Fig. 10(b): dPRO combined strategies vs XLA at scale ===\n");
    let mut rows = Vec::new();
    for model in ["resnet50", "bert_base"] {
        for gpus in [16usize, 64, 128] {
            let spec = spec_for(model, gpus);
            let mut xla = spec.clone();
            xla.fusion = baselines::xla_auto_cluster(&xla.model);
            let t_xla = run(&xla, &TestbedOpts { iterations: 3, ..Default::default() }).avg_iter();
            let out = optimize(&spec, &SearchOpts { budget_wall_s: budget, max_rounds: 10, ..Default::default() });
            let t_dpro = run(&out.spec, &TestbedOpts { iterations: 3, ..Default::default() }).avg_iter();
            let thr = |t: f64| (gpus * spec.model.batch_size) as f64 / (t / 1e6);
            rows.push(vec![
                model.to_string(),
                format!("{gpus}"),
                format!("{:.0}", thr(t_xla)),
                format!("{:.0}", thr(t_dpro)),
                format!("{:.2}x", t_xla / t_dpro),
            ]);
        }
    }
    print_table(&["model", "GPUs", "XLA (samples/s)", "dPRO (samples/s)", "speedup"], &rows);
    println!("\npaper: dPRO's combined strategies scale best, up to 3.48x over XLA at 128 GPUs");

    println!("\n=== Fig. 10(c): replay scaling across comm schemes (resnet50, RDMA) ===\n");
    let mut rows = Vec::new();
    for scheme in ALL_SCHEMES {
        for gpus in [16usize, 32] {
            let spec = scheme_spec_for("resnet50", scheme, gpus);
            let tb = run(&spec, &TestbedOpts { iterations: 5, ..Default::default() });
            let est = profiler::estimate(&spec, &tb.trace, true);
            let props = dpro::graph::plan_props(&spec);
            rows.push(vec![
                spec.scheme.name().to_string(),
                format!("{gpus}"),
                format!("{}", props.stages_per_group),
                format!("{:.1}", tb.avg_iter() / 1e3),
                format!("{:.1}", est.iteration_us() / 1e3),
                format!("{:.2}%", rel_err_pct(est.iteration_us(), tb.avg_iter())),
            ]);
        }
    }
    print_table(
        &["scheme", "GPUs", "stages/group", "truth (ms)", "replay (ms)", "err"],
        &rows,
    );
    println!("\nall schemes flow through the same comm-plan IR: replay accuracy is scheme-independent");

    // ---- (d) fleet scale: tiered symmetry-class replay vs exact ----
    // Expressed as a campaign: the fleet is a declarative sweep over
    // scheme × workers × replay-mode, executed by the campaign engine
    // (journal + matrix, the same path `dpro campaign run` takes), and
    // both the table and the tiered==exact equivalence assertion are
    // read off the matrix rows. horovod declares machine-rotation
    // symmetry, so tiered simulates one machine and derives the rest by
    // translation; byteps (PS) declares none and demotes to exact,
    // which is the honest fallback row. No testbed run at this scale —
    // source=analytic builds the graph, exactly as the old inline loop.
    println!("\n=== Fig. 10(d): fleet-scale replay — tiered vs exact (resnet50, RDMA) ===\n");
    let fleet: &[(&str, usize)] = if budget >= 60.0 {
        &[("horovod", 1024), ("horovod", 2048), ("horovod", 4096), ("byteps", 2048)]
    } else if budget >= 20.0 {
        &[("horovod", 1024), ("byteps", 2048)]
    } else {
        &[("horovod", 1024)]
    };
    let mut cspec = CampaignSpec::default();
    cspec.name = "fig10-fleet".into();
    cspec.models = vec!["resnet50".into()];
    cspec.schemes = {
        let mut s: Vec<String> = fleet.iter().map(|&(s, _)| s.to_string()).collect();
        s.dedup();
        s
    };
    cspec.workers = {
        let mut w: Vec<usize> = fleet.iter().map(|&(_, w)| w).collect();
        w.sort_unstable();
        w.dedup();
        w
    };
    cspec.modes = vec![ReplayMode::Exact, ReplayMode::Tiered];
    cspec.source = Source::Analytic;
    // the fleet is a sparse subset of the scheme × workers product:
    // exactly what include filters are for
    cspec.include = fleet
        .iter()
        .map(|&(scheme, workers)| Filter {
            clauses: vec![
                ("scheme".into(), scheme.to_string()),
                ("workers".into(), workers.to_string()),
            ],
        })
        .collect();

    let out_dir = std::env::temp_dir().join(format!("dpro_fig10_fleet_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out_dir);
    // jobs=1: each fleet cell holds a multi-million-node graph; serial
    // execution bounds peak memory exactly like the old inline loop
    let opts = RunOpts { out_dir, jobs: 1, quiet: true, ..RunOpts::default() };
    let out = match campaign::run(&cspec, LaunchMode::Fresh, &opts) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("fig10(d): campaign failed: {}", e.message());
            std::process::exit(e.exit_code());
        }
    };
    assert_eq!(out.failed, 0, "fleet cells must not fail");
    let state = campaign::run::load_state(&cspec, &opts.out_dir)
        .expect("the campaign just wrote this journal");
    let cell_result = |scheme: &str, workers: usize, mode: ReplayMode| -> (Json, f64) {
        let cell = cspec
            .expand()
            .into_iter()
            .find(|c| c.scheme == scheme && c.workers == workers && c.mode == mode)
            .unwrap_or_else(|| panic!("{scheme}@{workers} missing from expansion"));
        match state.cells.get(&cell.id()) {
            Some(CellState::Done { result, wall_ms, .. }) => (result.clone(), *wall_ms),
            other => panic!("{scheme}@{workers}/{} not done: {other:?}", mode.name()),
        }
    };

    let mut rows = Vec::new();
    let mut jfleet = Vec::new();
    for &(scheme, workers) in fleet {
        let (exact, exact_ms) = cell_result(scheme, workers, ReplayMode::Exact);
        let (tiered, tiered_ms) = cell_result(scheme, workers, ReplayMode::Tiered);
        // the PR-7 contract, now asserted on matrix rows: tiered replay
        // is an exact-equivalent engine, whatever tier it picked
        assert_eq!(
            exact.f64("iteration_us"),
            tiered.f64("iteration_us"),
            "tiered and exact disagree on {scheme}@{workers}"
        );
        let mode_used = tiered.str("mode_used").to_string();
        rows.push(vec![
            scheme.to_string(),
            format!("{workers}"),
            format!("{}", exact.f64("ops")),
            mode_used.clone(),
            format!("{:.1}", exact.f64("iteration_us") / 1e3),
            format!("{:.2}", exact_ms / 1e3),
            format!("{:.2}", tiered_ms / 1e3),
            format!("{:.1}x", exact_ms / tiered_ms.max(1e-9)),
        ]);
        let mut j = Json::obj();
        j.set("scheme", Json::Str(scheme.to_string()));
        j.set("workers", Json::Num(workers as f64));
        j.set("nodes", Json::Num(exact.f64("ops")));
        j.set("mode_used", Json::Str(mode_used));
        j.set("iteration_ms", Json::Num(exact.f64("iteration_us") / 1e3));
        // per-cell wall covers build+replay end-to-end (each campaign
        // cell builds its own graph; replay-only rounds/sec is tracked
        // by perf_hotpath and gated there)
        j.set("exact_cell_s", Json::Num(exact_ms / 1e3));
        j.set("tiered_cell_s", Json::Num(tiered_ms / 1e3));
        j.set("tiered_speedup", Json::Num(exact_ms / tiered_ms.max(1e-9)));
        jfleet.push(j);
    }
    print_table(
        &[
            "scheme", "workers", "nodes", "mode", "iter (ms)", "exact cell (s)",
            "tiered cell (s)", "speedup",
        ],
        &rows,
    );
    println!("\ntiered replay simulates one machine per symmetry class and derives the rest by");
    println!("timeline translation; asymmetric schemes demote to exact replay (same result).");
    if let (Some(csv), Some(json)) = (&out.csv, &out.json) {
        println!("campaign matrix: {} + {}", csv.display(), json.display());
    }

    let mut report = Json::obj();
    report.set("bench", Json::Str("fig10_scalability".to_string()));
    report.set("provenance", Json::Str("measured".to_string()));
    report.set("campaign_spec_hash", Json::Str(cspec.hash()));
    report.set("fleet", Json::Arr(jfleet));
    match std::fs::write("BENCH_fig10_scalability.json", report.to_string_pretty()) {
        Ok(()) => println!("\nwrote BENCH_fig10_scalability.json"),
        Err(e) => eprintln!("\ncould not write BENCH_fig10_scalability.json: {e}"),
    }
}
