//! Paper Table 2: simulation-error deep dive for TensorFlow Horovod RDMA —
//! iteration / FW / BW breakdown for ResNet50 and BERT Base. Both
//! simulators get computation right; the iteration gap is all in
//! communication modeling.

use dpro::baselines::{self, daydream};
use dpro::config::{JobSpec, Transport};
use dpro::profiler;
use dpro::testbed::{run, TestbedOpts};
use dpro::util::print_table;

fn main() {
    println!("\n=== Table 2: deep dive (Horovod RDMA, 16 GPUs, batch 32) ===\n");
    let mut rows = Vec::new();
    for model in ["resnet50", "bert_base"] {
        let spec = baselines::deployed_default(&JobSpec::standard(model, "horovod", Transport::Rdma));
        let tb = run(&spec, &TestbedOpts { iterations: 10, ..Default::default() });
        let est = profiler::estimate(&spec, &tb.trace, true);
        let db = profiler::corrected_profile(&tb.trace, &dpro::alignment::Alignment::identity());
        let dd = daydream::estimate(&spec, Some(&db));
        let ms = |x: f64| format!("{:.2}", x / 1e3);
        rows.push(vec![model.into(), "Ground truth".into(), ms(tb.avg_iter()), ms(tb.fw_time), ms(tb.bw_time)]);
        rows.push(vec!["".into(), "dPRO".into(), ms(est.iteration_us()), ms(est.fw_us()), ms(est.bw_us())]);
        rows.push(vec!["".into(), "Daydream".into(), ms(dd.iteration_us), ms(dd.fw_us), ms(dd.bw_us)]);
    }
    print_table(&["model", "experiment", "iteration (ms)", "FW (ms)", "BW (ms)"], &rows);
    println!("\npaper: FW/BW predicted accurately by both; Daydream misses the iteration");
    println!("time because coarse comm ops ignore queuing/protocol/GPU-kernel effects.");
}
