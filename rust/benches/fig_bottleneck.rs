//! §Bottleneck-identification table: the diagnosis sweep over models ×
//! ALL_SCHEMES, expressed as a **campaign** — the sweep is a declarative
//! [`CampaignSpec`] expanded, journaled and executed by the campaign
//! engine (the same path `dpro campaign run` takes), and the table plus
//! `BENCH_fig_bottleneck.json` are read back off the results matrix.
//! The per-battery zero-rebuild guarantee this bench used to assert
//! inline is pinned by the diagnosis tests and the CI diagnose-smoke
//! step. Budgeted via `DPRO_BENCH_BUDGET_S` like `perf_hotpath`; a
//! truncated run reports how many combinations were skipped.

use std::time::Instant;

use dpro::campaign::{self, CampaignSpec, CellState, LaunchMode, RunOpts, Source};
use dpro::config::ALL_SCHEMES;
use dpro::util::json::Json;
use dpro::util::print_table;

fn main() {
    let budget_s: f64 = std::env::var("DPRO_BENCH_BUDGET_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120.0);
    let t0 = Instant::now();

    let mut spec = CampaignSpec::default();
    spec.name = "fig-bottleneck".into();
    spec.models = ["resnet50", "vgg16", "inception_v3", "bert_base", "gpt_mini"]
        .iter()
        .map(|m| m.to_string())
        .collect();
    spec.schemes = ALL_SCHEMES.iter().map(|s| s.to_string()).collect();
    spec.workers = vec![16];
    spec.source = Source::Analytic;
    spec.diagnose = true;

    let out_dir = std::env::temp_dir().join(format!("dpro_fig_bottleneck_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out_dir);
    let opts = RunOpts {
        out_dir,
        jobs: 4,
        budget_s: Some(budget_s),
        quiet: true,
        ..RunOpts::default()
    };
    let out = match campaign::run(&spec, LaunchMode::Fresh, &opts) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("fig_bottleneck: campaign failed: {}", e.message());
            std::process::exit(e.exit_code());
        }
    };
    let state = campaign::run::load_state(&spec, &opts.out_dir)
        .expect("the campaign just wrote this journal");

    let total = spec.product();
    let skipped = out.pending;
    if skipped > 0 {
        println!(
            "\n[budget] {budget_s}s exhausted after {} of {total} jobs; \
             {skipped} combinations skipped (raise DPRO_BENCH_BUDGET_S for the full table)",
            out.done + out.failed
        );
    }

    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    // spec order (model-major), not the matrix's sorted-id order
    for cell in spec.expand() {
        let Some(CellState::Done { result, result_hash, wall_ms }) = state.cells.get(&cell.id())
        else {
            continue;
        };
        let iteration_us = result.f64("iteration_us");
        let pct = |x: f64| if iteration_us > 0.0 { x / iteration_us * 100.0 } else { 0.0 };
        let comp = result.f64("path_comp_us");
        let comm = result.f64("path_comm_us");
        let top = match result.get("top_bottleneck") {
            Some(Json::Str(s)) => s.clone(),
            _ => "-".into(),
        };
        let po = result.get("perfect_overlap_speedup").and_then(Json::as_f64).unwrap_or(1.0);
        rows.push(vec![
            format!("{}/{}", cell.model, cell.scheme),
            format!("{:.1}", iteration_us / 1e3),
            format!("{:.0}%", pct(comp)),
            format!("{:.0}%", pct(comm)),
            top.clone(),
            format!("{po:.2}x"),
        ]);
        let mut j = Json::obj();
        j.set("job", Json::Str(format!("{}/{}", cell.model, cell.scheme)));
        j.set("iteration_us", Json::Num(iteration_us));
        j.set("path_comp_us", Json::Num(comp));
        j.set("path_comm_us", Json::Num(comm));
        j.set("top_bottleneck", Json::Str(top));
        j.set("perfect_overlap_speedup", Json::Num(po));
        j.set("wall_ms", Json::Num(*wall_ms));
        j.set("result_hash", Json::Str(result_hash.clone()));
        jrows.push(j);
    }

    println!("\n=== bottleneck identification (diagnosis engine, via campaign) ===\n");
    print_table(
        &["job", "iter (ms)", "path comp", "path comm", "top bottleneck", "overlap bound"],
        &rows,
    );
    if let (Some(csv), Some(json)) = (&out.csv, &out.json) {
        println!("\ncampaign matrix: {} + {}", csv.display(), json.display());
    }

    let mut report = Json::obj();
    report.set("jobs", Json::Arr(jrows));
    report.set("skipped", Json::Num(skipped as f64));
    report.set("failed", Json::Num(out.failed as f64));
    report.set("budget_s", Json::Num(budget_s));
    report.set("wall_s", Json::Num(t0.elapsed().as_secs_f64()));
    report.set("campaign_spec_hash", Json::Str(spec.hash()));
    match std::fs::write("BENCH_fig_bottleneck.json", report.to_string_pretty()) {
        Ok(()) => println!("\nwrote BENCH_fig_bottleneck.json"),
        Err(e) => eprintln!("\ncould not write BENCH_fig_bottleneck.json: {e}"),
    }
    if out.failed > 0 {
        eprintln!("fig_bottleneck: {} cells failed (see matrix for reasons)", out.failed);
        std::process::exit(1);
    }
}
