//! §Bottleneck-identification table: run the diagnosis engine over
//! models × ALL_SCHEMES and tabulate where each job's iteration goes —
//! critical-path compute/communication split, the top-ranked bottleneck,
//! and the replayed perfect-overlap headroom — all answered with zero
//! global-DFG builds per query battery. Emits `BENCH_fig_bottleneck.json`
//! (uploaded by CI, budgeted via `DPRO_BENCH_BUDGET_S` like
//! `perf_hotpath`).

use std::time::Instant;

use dpro::config::{JobSpec, Transport, ALL_SCHEMES};
use dpro::diagnosis::Diagnoser;
use dpro::util::json::Json;
use dpro::util::print_table;

fn main() {
    let budget_s: f64 = std::env::var("DPRO_BENCH_BUDGET_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120.0);
    let t0 = Instant::now();

    let models = ["resnet50", "vgg16", "inception_v3", "bert_base", "gpt_mini"];
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    let mut skipped = 0usize;
    let total = models.len() * ALL_SCHEMES.len();

    'sweep: for model in models {
        for scheme in ALL_SCHEMES {
            if t0.elapsed().as_secs_f64() > budget_s {
                skipped = total - rows.len();
                println!(
                    "\n[budget] {budget_s}s exhausted after {} of {total} jobs; \
                     {skipped} combinations skipped (raise DPRO_BENCH_BUDGET_S for the full table)",
                    rows.len()
                );
                break 'sweep;
            }
            let spec = JobSpec::standard(model, scheme, Transport::Rdma);
            let mut d = Diagnoser::new(spec);
            let queries = d.auto_queries();
            let rep = d.report(&queries, 3);
            assert_eq!(rep.builds_during_queries, 0, "{model}/{scheme} rebuilt");

            let iter_ms = rep.iteration_us / 1e3;
            let pct = |x: f64| if rep.iteration_us > 0.0 { x / rep.iteration_us * 100.0 } else { 0.0 };
            let top = rep
                .bottlenecks
                .first()
                .map(|b| format!("{}:{}", b.kind.name(), b.subject))
                .unwrap_or_else(|| "-".into());
            let po = rep
                .whatif
                .iter()
                .find(|a| a.query == "perfect-overlap")
                .map(|a| a.speedup)
                .unwrap_or(1.0);
            rows.push(vec![
                format!("{model}/{scheme}"),
                format!("{iter_ms:.1}"),
                format!("{:.0}%", pct(rep.blame.path.comp_us)),
                format!("{:.0}%", pct(rep.blame.path.comm_us)),
                top.clone(),
                format!("{po:.2}x"),
                format!("{}", rep.whatif.len()),
                format!("{}", rep.builds_during_queries),
            ]);
            let mut j = Json::obj();
            j.set("job", Json::Str(format!("{model}/{scheme}")));
            j.set("iteration_us", Json::Num(rep.iteration_us));
            j.set("path_comp_us", Json::Num(rep.blame.path.comp_us));
            j.set("path_comm_us", Json::Num(rep.blame.path.comm_us));
            j.set("top_bottleneck", Json::Str(top));
            j.set("perfect_overlap_speedup", Json::Num(po));
            j.set("queries", Json::Num(rep.whatif.len() as f64));
            j.set(
                "builds_during_queries",
                Json::Num(rep.builds_during_queries as f64),
            );
            jrows.push(j);
        }
    }

    println!("\n=== bottleneck identification (diagnosis engine) ===\n");
    print_table(
        &[
            "job",
            "iter (ms)",
            "path comp",
            "path comm",
            "top bottleneck",
            "overlap bound",
            "queries",
            "builds",
        ],
        &rows,
    );

    let mut report = Json::obj();
    report.set("jobs", Json::Arr(jrows));
    report.set("skipped", Json::Num(skipped as f64));
    report.set("budget_s", Json::Num(budget_s));
    report.set("wall_s", Json::Num(t0.elapsed().as_secs_f64()));
    match std::fs::write("BENCH_fig_bottleneck.json", report.to_string_pretty()) {
        Ok(()) => println!("\nwrote BENCH_fig_bottleneck.json"),
        Err(e) => eprintln!("\ncould not write BENCH_fig_bottleneck.json: {e}"),
    }
}
