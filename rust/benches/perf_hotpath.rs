//! §Perf microbenchmarks of the L3 hot paths: global-DFG construction,
//! replay throughput (ops/s), partial replay, alignment solve, search
//! rounds (from-scratch rebuild vs incremental splice + cone replay), the
//! self-telemetry overhead guard (disabled `obs::span()` must cost ≤2% of
//! a search round; the enabled delta is recorded, not gated), and
//! one full search. Emits `BENCH_perf_hotpath.json` so the perf
//! trajectory is tracked across PRs; used for the before/after log in
//! EXPERIMENTS.md §Perf.

use std::time::Instant;

use dpro::baselines::deployed_default;
use dpro::config::{ClusterSpec, CommPlan, FusionPlan, JobSpec, NetworkSpec, Transport};
use dpro::graph::{build_global, build_global_nameless, AnalyticCost, MutableGraph};
use dpro::optimizer::{optimize, passes, SearchOpts};
use dpro::replay::incremental::IncrementalReplayer;
use dpro::replay::Replayer;
use dpro::testbed::{run, TestbedOpts};
use dpro::util::json::Json;
use dpro::util::print_table;

fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// The scripted "search round" mix both paths replay: tensor fusions of
/// the head groups interleaved with re-partitioning, the same edit kinds
/// Alg. 1 emits.
#[derive(Clone, Copy)]
enum Round {
    Fuse(usize, usize),
    Partition(usize, usize),
}

fn round_script(n_rounds: usize) -> Vec<Round> {
    (0..n_rounds)
        .map(|i| if i % 3 == 2 { Round::Partition(0, (i % 4) + 1) } else { Round::Fuse(0, 1) })
        .collect()
}

/// From-scratch baseline: every round mutates the spec, rebuilds the
/// global DFG, allocates a fresh replayer, and replays.
fn rounds_from_scratch(spec: &JobSpec, script: &[Round]) -> f64 {
    let mut s = spec.clone();
    let t0 = Instant::now();
    for r in script {
        match *r {
            Round::Fuse(a, b) => {
                let _ = passes::fuse_tensor_groups(&mut s, a, b);
            }
            Round::Partition(g, k) => {
                let _ = passes::set_partitions(&mut s, g, k);
            }
        }
        let g = build_global_nameless(&s, &AnalyticCost::new(&s));
        let mut rp = Replayer::new(&g);
        rp.replay(&g);
    }
    t0.elapsed().as_secs_f64()
}

/// Incremental path: one long-lived mutable graph + engine; rounds splice
/// in place and replay only the affected cone.
fn rounds_incremental(spec: &JobSpec, script: &[Round]) -> (f64, usize) {
    let mut mg = MutableGraph::new(spec.clone());
    let mut eng = IncrementalReplayer::new();
    let log = mg.commit();
    eng.replay_incremental(&mg, &log);
    let mut cone_total = 0usize;
    let t0 = Instant::now();
    for r in script {
        match *r {
            Round::Fuse(a, b) => {
                let _ = mg.fuse_tensor_groups(a, b);
            }
            Round::Partition(g, k) => {
                let _ = mg.set_partitions(g, k);
            }
        }
        let log = mg.commit();
        eng.replay_incremental(&mg, &log);
        cone_total += eng.last_recomputed();
    }
    (t0.elapsed().as_secs_f64(), cone_total / script.len().max(1))
}

fn main() {
    let mut report = Json::obj();
    let mut rows = Vec::new();
    let mut graph_rows = Vec::new();
    for (model, gpus) in [("resnet50", 16usize), ("bert_base", 16), ("resnet50", 128)] {
        let mut spec = JobSpec::standard(model, "horovod", Transport::Rdma);
        spec.cluster = ClusterSpec::new(gpus, 8, NetworkSpec::rdma_100g());
        spec.plan = CommPlan::per_tensor(&spec.model);
        spec.fusion = FusionPlan::singletons(&spec.model);
        let (g, t_build) = time(|| build_global(&spec, &AnalyticCost::new(&spec)));
        let (_, t_nameless) =
            time(|| dpro::graph::build_global_nameless(&spec, &AnalyticCost::new(&spec)));
        let mut rp = Replayer::new(&g);
        // warm
        rp.replay(&g);
        let reps = if gpus > 64 { 3 } else { 20 };
        let (_, t_replay) = time(|| {
            for _ in 0..reps {
                rp.replay(&g);
            }
        });
        let per_replay = t_replay / reps as f64;
        rows.push(vec![
            format!("{model}@{gpus}"),
            format!("{}", g.dfg.len()),
            format!("{:.1}", t_build * 1e3),
            format!("{:.1}", t_nameless * 1e3),
            format!("{:.2}", per_replay * 1e3),
            format!("{:.2}M", g.dfg.len() as f64 / per_replay / 1e6),
        ]);
        let mut jrow = Json::obj();
        jrow.set("graph", Json::Str(format!("{model}@{gpus}")));
        jrow.set("nodes", Json::Num(g.dfg.len() as f64));
        jrow.set("build_ms", Json::Num(t_build * 1e3));
        jrow.set("build_nameless_ms", Json::Num(t_nameless * 1e3));
        jrow.set("replay_ms", Json::Num(per_replay * 1e3));
        jrow.set("replays_per_s", Json::Num(1.0 / per_replay));
        graph_rows.push(jrow);
    }
    println!("\n=== replayer hot path ===\n");
    print_table(
        &["graph", "nodes", "build (ms)", "build nameless (ms)", "replay (ms)", "ops/s"],
        &rows,
    );
    report.set("replayer", Json::Arr(graph_rows));

    // ---- search rounds: from-scratch rebuild vs incremental splice ----
    // every registered scheme rides the same mutable-graph splice path
    println!("\n=== search rounds: full rebuild vs incremental ===\n");
    let n_rounds = 30usize;
    let script = round_script(n_rounds);
    let mut round_rows = Vec::new();
    let mut jrounds = Vec::new();
    for (model, scheme) in [
        ("resnet50", "horovod"),
        ("vgg16", "byteps"),
        ("vgg16", "ring"),
        ("vgg16", "ps-tree"),
    ] {
        let spec = JobSpec::standard(model, scheme, Transport::Rdma);
        let t_full = rounds_from_scratch(&spec, &script);
        let (t_inc, avg_cone) = rounds_incremental(&spec, &script);
        let full_rps = n_rounds as f64 / t_full;
        let inc_rps = n_rounds as f64 / t_inc;
        round_rows.push(vec![
            format!("{model}/{scheme}"),
            format!("{:.1}", full_rps),
            format!("{:.1}", inc_rps),
            format!("{:.1}x", inc_rps / full_rps),
            format!("{avg_cone}"),
        ]);
        let mut j = Json::obj();
        j.set("job", Json::Str(format!("{model}/{scheme}")));
        j.set("rounds", Json::Num(n_rounds as f64));
        j.set("full_rounds_per_s", Json::Num(full_rps));
        j.set("incremental_rounds_per_s", Json::Num(inc_rps));
        j.set("speedup", Json::Num(inc_rps / full_rps));
        j.set("avg_cone_nodes", Json::Num(avg_cone as f64));
        jrounds.push(j);
    }
    print_table(
        &["job", "full rounds/s", "incremental rounds/s", "speedup", "avg cone (nodes)"],
        &round_rows,
    );
    report.set("search_rounds", Json::Arr(jrounds));

    // ---- self-telemetry overhead guard (docs/OBSERVABILITY.md) ----
    // The obs layer must be free when disabled: a span() call is one
    // relaxed atomic load and an inert guard. Measure that cost
    // directly, bound it against a search round, then record the
    // enabled-path throughput delta for the trajectory log.
    println!("\n=== self-telemetry overhead ===\n");
    assert!(!dpro::obs::enabled(), "span collection must start disabled");
    let spins = 10_000_000u64;
    let (_, t_noop) = time(|| {
        for _ in 0..spins {
            let _g = dpro::obs::span("bench.obs.noop", dpro::obs::SpanKind::Work);
        }
    });
    let ns_disabled = t_noop / spins as f64 * 1e9;
    let ospec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
    let (t_off, _) = rounds_incremental(&ospec, &script);
    dpro::obs::set_enabled(true);
    let (t_on, _) = rounds_incremental(&ospec, &script);
    dpro::obs::set_enabled(false);
    let spans_collected = dpro::obs::take_spans().len();
    let rps_off = n_rounds as f64 / t_off;
    let rps_on = n_rounds as f64 / t_on;
    // instrumentation is per-round/per-phase, never per-op; 100 span()
    // calls per round is a generous ceiling for the analytic bound
    let spans_per_round = 100.0;
    let round_us_off = t_off / n_rounds as f64 * 1e6;
    let disabled_overhead_pct = spans_per_round * ns_disabled / 1e3 / round_us_off * 100.0;
    let enabled_delta_pct = (rps_off - rps_on) / rps_off * 100.0;
    println!(
        "disabled span(): {ns_disabled:.1} ns ({disabled_overhead_pct:.4}% of a search round \
         at {spans_per_round:.0} spans/round)"
    );
    println!(
        "search rounds/s: {rps_off:.1} disabled -> {rps_on:.1} enabled \
         ({enabled_delta_pct:+.1}% delta, {spans_collected} spans collected)"
    );
    assert!(
        disabled_overhead_pct <= 2.0,
        "disabled span overhead {disabled_overhead_pct:.3}% of a search round exceeds the 2% guard"
    );
    let mut jobs = Json::obj();
    jobs.set("disabled_span_ns", Json::Num(ns_disabled));
    jobs.set("spans_per_round_assumed", Json::Num(spans_per_round));
    jobs.set("disabled_overhead_pct", Json::Num(disabled_overhead_pct));
    jobs.set("rounds_per_s_disabled", Json::Num(rps_off));
    jobs.set("rounds_per_s_enabled", Json::Num(rps_on));
    jobs.set("enabled_delta_pct", Json::Num(enabled_delta_pct));
    jobs.set("spans_collected", Json::Num(spans_collected as f64));
    report.set("obs_overhead", jobs);

    // alignment solve
    let spec = deployed_default(&JobSpec::standard("resnet50", "horovod", Transport::Tcp));
    let tb = run(&spec, &TestbedOpts { iterations: 10, ..Default::default() });
    let (a, t_align) = time(|| dpro::alignment::align(&tb.trace, 1.0, 1.0));
    println!(
        "\nalignment: {} offsets from {} events in {:.2}s ({} iters)",
        a.theta.len(),
        tb.trace.events.len(),
        t_align,
        a.iterations
    );
    let mut jalign = Json::obj();
    jalign.set("events", Json::Num(tb.trace.events.len() as f64));
    jalign.set("solve_s", Json::Num(t_align));
    report.set("alignment", jalign);

    // end-to-end search (budget overridable so CI smoke runs stay short)
    let budget_s = std::env::var("DPRO_BENCH_BUDGET_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60.0);
    let (out, t_search) =
        time(|| optimize(&spec, &SearchOpts { budget_wall_s: budget_s, ..Default::default() }));
    println!(
        "search: {:.2}s wall, {} replays, {} actions, {} builds in rounds, speedup {:.2}x",
        t_search, out.replays, out.actions_applied, out.builds_during_search, out.speedup()
    );
    let mut jsearch = Json::obj();
    jsearch.set("wall_s", Json::Num(t_search));
    jsearch.set("replays", Json::Num(out.replays as f64));
    jsearch.set("actions", Json::Num(out.actions_applied as f64));
    jsearch.set("builds_during_search", Json::Num(out.builds_during_search as f64));
    jsearch.set("speedup", Json::Num(out.speedup()));
    report.set("search", jsearch);

    match std::fs::write("BENCH_perf_hotpath.json", report.to_string_pretty()) {
        Ok(()) => println!("\nwrote BENCH_perf_hotpath.json"),
        Err(e) => eprintln!("\ncould not write BENCH_perf_hotpath.json: {e}"),
    }
}
