//! §Perf microbenchmarks of the L3 hot paths: global-DFG construction,
//! replay throughput (ops/s), partial replay, alignment solve, and one
//! full search. Used for the before/after log in EXPERIMENTS.md §Perf.

use std::time::Instant;

use dpro::baselines::deployed_default;
use dpro::config::{ClusterSpec, CommPlan, FusionPlan, JobSpec, NetworkSpec, Transport};
use dpro::graph::{build_global, AnalyticCost};
use dpro::optimizer::{optimize, SearchOpts};
use dpro::replay::Replayer;
use dpro::testbed::{run, TestbedOpts};
use dpro::util::print_table;

fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

fn main() {
    let mut rows = Vec::new();
    for (model, gpus) in [("resnet50", 16usize), ("bert_base", 16), ("resnet50", 128)] {
        let mut spec = JobSpec::standard(model, "horovod", Transport::Rdma);
        spec.cluster = ClusterSpec::new(gpus, 8, NetworkSpec::rdma_100g());
        spec.plan = CommPlan::per_tensor(&spec.model);
        spec.fusion = FusionPlan::singletons(&spec.model);
        let (g, t_build) = time(|| build_global(&spec, &AnalyticCost::new(&spec)));
        let (_, t_nameless) = time(|| dpro::graph::build_global_nameless(&spec, &AnalyticCost::new(&spec)));
        let mut rp = Replayer::new(&g);
        // warm
        rp.replay(&g);
        let reps = if gpus > 64 { 3 } else { 20 };
        let (_, t_replay) = time(|| {
            for _ in 0..reps {
                rp.replay(&g);
            }
        });
        let per_replay = t_replay / reps as f64;
        rows.push(vec![
            format!("{model}@{gpus}"),
            format!("{}", g.dfg.len()),
            format!("{:.1}", t_build * 1e3),
            format!("{:.1}", t_nameless * 1e3),
            format!("{:.2}", per_replay * 1e3),
            format!("{:.2}M", g.dfg.len() as f64 / per_replay / 1e6),
        ]);
    }
    println!("\n=== replayer hot path ===\n");
    print_table(&["graph", "nodes", "build (ms)", "build nameless (ms)", "replay (ms)", "ops/s"], &rows);

    // alignment solve
    let spec = deployed_default(&JobSpec::standard("resnet50", "horovod", Transport::Tcp));
    let tb = run(&spec, &TestbedOpts { iterations: 10, ..Default::default() });
    let (a, t_align) = time(|| dpro::alignment::align(&tb.trace, 1.0, 1.0));
    println!("\nalignment: {} offsets from {} events in {:.2}s ({} iters)",
             a.theta.len(), tb.trace.events.len(), t_align, a.iterations);

    // end-to-end search
    let (out, t_search) = time(|| optimize(&spec, &SearchOpts { budget_wall_s: 60.0, ..Default::default() }));
    println!("search: {:.2}s wall, {} replays, {} actions, speedup {:.2}x",
             t_search, out.replays, out.actions_applied, out.speedup());
}
