//! Paper Fig. 1 (motivation): training ResNet50 on a 100 Gbps fabric under
//! four deployed configurations. Ground truth (testbed) varies by protocol
//! and architecture; Daydream's size/bandwidth estimate stays flat.

use dpro::baselines::{self, daydream};
use dpro::config::{JobSpec, Transport};
use dpro::profiler::corrected_profile;
use dpro::testbed::{run, TestbedOpts};
use dpro::util::print_table;

fn main() {
    println!("\n=== Fig. 1: ResNet50, 16 GPUs, 100 Gbps, batch 32/GPU ===\n");
    let mut rows = Vec::new();
    for (scheme, tp) in [
        ("horovod", Transport::Rdma),
        ("horovod", Transport::Tcp),
        ("byteps", Transport::Rdma),
        ("byteps", Transport::Tcp),
    ] {
        let spec = baselines::deployed_default(&JobSpec::standard("resnet50", scheme, tp));
        let tb = run(&spec, &TestbedOpts { iterations: 10, ..Default::default() });
        let db = corrected_profile(&tb.trace, &dpro::alignment::Alignment::identity());
        let dd = daydream::estimate(&spec, Some(&db));
        rows.push(vec![
            format!("{}+{}", spec.scheme.name(), tp.name()),
            format!("{:.1}", tb.avg_iter() / 1e3),
            format!("{:.1}", dd.iteration_us / 1e3),
            format!("{:+.1}%", 100.0 * (dd.iteration_us - tb.avg_iter()) / tb.avg_iter()),
        ]);
    }
    print_table(
        &["config", "ground truth (ms)", "Daydream (ms)", "Daydream bias"],
        &rows,
    );
    println!("\npaper: real time varies strongly across the four configs while");
    println!("Daydream's prediction stays ~constant (it only sees nominal bandwidth).");
}
