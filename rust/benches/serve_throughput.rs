//! Load generator for the `dprod` serve daemon. Two modes:
//!
//! - `--smoke [--trace-dir DIR]` — the CI gate: start an in-process
//!   daemon, register a job (the fixture dump when `--trace-dir` is
//!   given, an analytic job otherwise), assert the response schemas, and
//!   assert the second registration and query hit the session cache.
//!   Exits nonzero on any failed expectation.
//! - default — a closed-loop throughput sweep: N client threads × a
//!   mixed replay/diagnose/what-if workload over two resident sessions,
//!   for each N in 1/2/4/8. `DPRO_BENCH_BUDGET_S` bounds total wall time.
//!
//! Both modes write `BENCH_serve_throughput.json` (qps × clients ×
//! cache-hit rate).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use dpro::serve::http::Client;
use dpro::serve::{start, ServeOpts};
use dpro::util::json::Json;
use dpro::util::{print_table, Args};

fn fail(msg: &str) -> ! {
    eprintln!("serve_throughput: FAIL: {msg}");
    std::process::exit(1);
}

fn expect(cond: bool, msg: &str) {
    if !cond {
        fail(msg);
    }
}

// thin fail-fast wrappers over the shared [`Client`] JSON helpers (the
// same ones the campaign executor's serve path uses)
fn get_ok(c: &mut Client, path: &str) -> Json {
    c.get_json(path).unwrap_or_else(|e| fail(&e))
}

fn post_ok(c: &mut Client, path: &str, body: &str) -> Json {
    c.post_json(path, body).unwrap_or_else(|e| fail(&e))
}

const ANALYTIC_JOB: &str =
    r#"{"job":{"model":"gpt_mini","scheme":"horovod","transport":"rdma","workers":4}}"#;
const ANALYTIC_JOB_2: &str =
    r#"{"job":{"model":"vgg16","scheme":"horovod","transport":"rdma","workers":4}}"#;

fn main() {
    let args = Args::from_env();
    let opts = ServeOpts {
        addr: "127.0.0.1:0".into(),
        threads: args.usize("threads", 8),
        batch_window_ms: 2,
        ..ServeOpts::default()
    };
    let handle = match start(&opts) {
        Ok(h) => h,
        Err(e) => fail(&format!("daemon start: {e}")),
    };
    let addr = handle.addr().to_string();

    if args.flag("smoke") {
        smoke(&addr, args.get("trace-dir"));
    } else {
        sweep(&addr, opts.threads);
    }
    handle.stop();
}

/// The CI smoke: schemas are stable and the second registration + query
/// hit the cache instead of rebuilding.
fn smoke(addr: &str, trace_dir: Option<&str>) {
    let mut c = Client::new(addr);

    let health = get_ok(&mut c, "/healthz");
    expect(health.str("status") == "ok", "healthz status");

    let reg_body = match trace_dir {
        Some(dir) => {
            let mut b = Json::obj();
            b.set("trace_dir", Json::Str(dir.to_string()));
            b.to_string()
        }
        None => ANALYTIC_JOB.to_string(),
    };
    let reg = post_ok(&mut c, "/jobs", &reg_body);
    let id = reg.str("job").to_string();
    expect(
        reg.get("cached").and_then(Json::as_bool) == Some(false),
        "first registration must build",
    );
    expect(reg.f64("iteration_us") > 0.0, "registration iteration_us");

    let replay = get_ok(&mut c, &format!("/jobs/{id}/replay"));
    for key in [
        "job", "snapshot", "model", "scheme", "transport", "workers", "ops", "alive_ops",
        "iteration_us", "fw_us", "bw_us", "est_peak_mem_bytes", "report",
    ] {
        expect(replay.get(key).is_some(), &format!("replay schema key {key}"));
    }
    let diag = get_ok(&mut c, &format!("/jobs/{id}/diagnose"));
    for key in ["job", "snapshot", "blame", "bottlenecks", "whatif", "builds_during_queries"] {
        expect(diag.get(key).is_some(), &format!("diagnose schema key {key}"));
    }

    let wpath = format!("/jobs/{id}/whatif");
    let w1 = post_ok(&mut c, &wpath, r#"{"query":"nic-bw=2"}"#);
    expect(
        w1.get("answers").and_then(Json::as_arr).map(<[Json]>::len) == Some(1),
        "whatif answers",
    );

    // second registration: byte/path-identical job must hit the cache
    let reg2 = post_ok(&mut c, "/jobs", &reg_body);
    expect(
        reg2.get("cached").and_then(Json::as_bool) == Some(true),
        "second registration must be a cache hit",
    );
    // identical what-if against the same snapshot: byte-identical payload
    let w2 = post_ok(&mut c, &wpath, r#"{"query":"nic-bw=2"}"#);
    expect(w1.to_string() == w2.to_string(), "repeated whatif must be bit-for-bit stable");

    let stats = get_ok(&mut c, "/statsz");
    let cache = stats.get("cache").unwrap_or_else(|| fail("statsz cache section"));
    expect(cache.f64("hits") >= 1.0, "statsz must show a cache hit");
    expect(cache.f64("hit_rate") > 0.0, "statsz hit rate");

    let mut report = Json::obj();
    report.set("mode", Json::Str("smoke".into()));
    report.set("job", Json::Str(id));
    report.set("cache_hit_on_second_query", Json::Bool(true));
    report.set("cache_hit_rate", Json::Num(cache.f64("hit_rate")));
    report.set("requests", Json::Num(stats.f64("requests")));
    write_report(&report);
    println!("serve smoke OK: schemas stable, second registration hit the cache");
}

/// Closed-loop mixed workload against two resident analytic sessions.
fn sweep(addr: &str, threads: usize) {
    let budget_s: f64 = std::env::var("DPRO_BENCH_BUDGET_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20.0);
    let mut c = Client::new(addr);
    let id1 = post_ok(&mut c, "/jobs", ANALYTIC_JOB).str("job").to_string();
    let id2 = post_ok(&mut c, "/jobs", ANALYTIC_JOB_2).str("job").to_string();

    let client_counts = [1usize, 2, 4, 8];
    let per_sweep = Duration::from_secs_f64((budget_s / client_counts.len() as f64).max(2.0));
    let mut rows = Vec::new();
    let mut jrows = Vec::new();
    for &clients in &client_counts {
        let done = Arc::new(AtomicU64::new(0));
        let deadline = Instant::now() + per_sweep;
        let workers: Vec<_> = (0..clients)
            .map(|w| {
                let addr = addr.to_string();
                let id = if w % 2 == 0 { id1.clone() } else { id2.clone() };
                let done = Arc::clone(&done);
                std::thread::spawn(move || {
                    let mut c = Client::new(&addr);
                    let mut i = 0usize;
                    while Instant::now() < deadline {
                        let ok = match i % 4 {
                            0 => c.call("GET", &format!("/jobs/{id}/replay"), None).is_ok(),
                            1 => c.call("GET", &format!("/jobs/{id}/diagnose"), None).is_ok(),
                            2 => c
                                .call(
                                    "POST",
                                    &format!("/jobs/{id}/whatif"),
                                    Some(r#"{"query":"nic-bw=2"}"#),
                                )
                                .is_ok(),
                            _ => c
                                .call(
                                    "POST",
                                    &format!("/jobs/{id}/whatif"),
                                    Some(r#"{"queries":["perfect-overlap","nic-bw=4"]}"#),
                                )
                                .is_ok(),
                        };
                        if ok {
                            done.fetch_add(1, Ordering::Relaxed);
                        }
                        i += 1;
                    }
                })
            })
            .collect();
        let t0 = Instant::now();
        for w in workers {
            let _ = w.join();
        }
        let elapsed = t0.elapsed().as_secs_f64() + 1e-9;
        let total = done.load(Ordering::Relaxed);
        let qps = total as f64 / elapsed;
        rows.push(vec![
            format!("{clients}"),
            format!("{total}"),
            format!("{elapsed:.1}"),
            format!("{qps:.0}"),
        ]);
        let mut j = Json::obj();
        j.set("clients", Json::Num(clients as f64));
        j.set("requests", Json::Num(total as f64));
        j.set("wall_s", Json::Num(elapsed));
        j.set("qps", Json::Num(qps));
        jrows.push(j);
    }

    let stats = get_ok(&mut c, "/statsz");
    let cache = stats.get("cache").unwrap_or_else(|| fail("statsz cache section"));
    let batch = stats.get("batch").unwrap_or_else(|| fail("statsz batch section"));

    println!("\n=== serve throughput ({threads} server threads, 2 sessions) ===\n");
    print_table(&["clients", "requests", "wall (s)", "qps"], &rows);
    println!(
        "\ncache hit rate {:.3}, what-if batches {}, coalesced {}",
        cache.f64("hit_rate"),
        batch.f64("batches"),
        batch.f64("coalesced"),
    );

    let mut report = Json::obj();
    report.set("mode", Json::Str("sweep".into()));
    report.set("server_threads", Json::Num(threads as f64));
    report.set("rows", Json::Arr(jrows));
    report.set("cache_hit_rate", Json::Num(cache.f64("hit_rate")));
    report.set("whatif_batches", Json::Num(batch.f64("batches")));
    report.set("whatif_coalesced", Json::Num(batch.f64("coalesced")));
    write_report(&report);
}

fn write_report(report: &Json) {
    match std::fs::write("BENCH_serve_throughput.json", report.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_serve_throughput.json"),
        Err(e) => eprintln!("could not write BENCH_serve_throughput.json: {e}"),
    }
}
