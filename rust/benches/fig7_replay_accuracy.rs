//! Paper Fig. 7: replay accuracy of dPRO vs Daydream across models ×
//! communication schemes × transports (16 GPUs, deployed defaults).
//! Paper claim: dPRO < 5% in most cases; Daydream up to 70.2%.

use dpro::baselines::{self, daydream};
use dpro::config::{JobSpec, Transport};
use dpro::profiler;
use dpro::testbed::{run, TestbedOpts};
use dpro::util::print_table;
use dpro::util::stats::rel_err_pct;

fn main() {
    println!("\n=== Fig. 7: replay error vs ground truth (16 GPUs) ===\n");
    let mut rows = Vec::new();
    let mut dpro_errs = Vec::new();
    let mut dd_errs = Vec::new();
    for model in ["resnet50", "vgg16", "inception_v3", "bert_base"] {
        for (scheme, tp) in [
            ("horovod", Transport::Rdma),
            ("horovod", Transport::Tcp),
            ("byteps", Transport::Rdma),
            ("byteps", Transport::Tcp),
        ] {
            let spec = baselines::deployed_default(&JobSpec::standard(model, scheme, tp));
            let tb = run(&spec, &TestbedOpts { iterations: 10, ..Default::default() });
            let est = profiler::estimate(&spec, &tb.trace, true);
            let db = profiler::corrected_profile(&tb.trace, &dpro::alignment::Alignment::identity());
            let dd = daydream::estimate(&spec, Some(&db));
            let e_dpro = rel_err_pct(est.iteration_us(), tb.avg_iter());
            let e_dd = rel_err_pct(dd.iteration_us, tb.avg_iter());
            dpro_errs.push(e_dpro);
            dd_errs.push(e_dd);
            rows.push(vec![
                model.to_string(),
                format!("{}+{}", spec.scheme.name(), tp.name()),
                format!("{:.1}", tb.avg_iter() / 1e3),
                format!("{:.2}%", e_dpro),
                format!("{:.2}%", e_dd),
            ]);
        }
    }
    print_table(&["model", "config", "truth (ms)", "dPRO err", "Daydream err"], &rows);
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let max = |v: &[f64]| v.iter().cloned().fold(0.0f64, f64::max);
    println!("\ndPRO:     mean {:.2}%  max {:.2}%   (paper: <5% average)", mean(&dpro_errs), max(&dpro_errs));
    println!("Daydream: mean {:.2}%  max {:.2}%   (paper: up to 70.2%)", mean(&dd_errs), max(&dd_errs));
}
