//! Paper Fig. 8: effect of trace time alignment vs cluster size. Workers
//! of the 8-GPU job share one machine (no drift — only the RECV launch
//! error); larger clusters add NTP-grade clock drift.
//!
//! A second table sweeps *degraded* traces through the full on-disk
//! pipeline (`trace::degrade`/`fault` → `trace::io::dump_dir` →
//! `load_dir` → replay): injected clock drift, dropped events, straggler
//! iterations, worker crashes, machine losses, NIC flaps, and compound
//! failures — reporting the ingestion diagnostics and the replay error
//! with raw vs aligned profiles.

use dpro::baselines::deployed_default;
use dpro::config::{ClusterSpec, CommPlan, FusionPlan, JobSpec, NetworkSpec, Transport};
use dpro::profiler;
use dpro::testbed::{run, TestbedOpts};
use dpro::trace::degrade;
use dpro::trace::io::{dump_dir_with_job, load_dir, JobMeta};
use dpro::trace::validate::DiagKind;
use dpro::trace::GTrace;
use dpro::util::print_table;
use dpro::util::stats::rel_err_pct;

fn main() {
    println!("\n=== Fig. 8: replay error w/ and w/o time alignment ===\n");
    let mut rows = Vec::new();
    for model in ["resnet50", "bert_base"] {
        for gpus in [8usize, 16, 32, 64] {
            let mut spec = JobSpec::standard(model, "horovod", Transport::Rdma);
            spec.cluster = ClusterSpec::new(gpus, 8, NetworkSpec::rdma_100g());
            // NTP-grade drift grows with cluster sprawl
            spec.cluster.clock.drift_std_us = 800.0 * (gpus as f64 / 8.0);
            spec.plan = CommPlan::per_tensor(&spec.model);
            spec.fusion = FusionPlan::singletons(&spec.model);
            let spec = deployed_default(&spec);
            let tb = run(&spec, &TestbedOpts { iterations: 8, ..Default::default() });
            let w = profiler::estimate(&spec, &tb.trace, true);
            let wo = profiler::estimate(&spec, &tb.trace, false);
            rows.push(vec![
                model.to_string(),
                format!("{gpus}"),
                format!("{:.2}%", rel_err_pct(wo.iteration_us(), tb.avg_iter())),
                format!("{:.2}%", rel_err_pct(w.iteration_us(), tb.avg_iter())),
            ]);
        }
    }
    print_table(&["model", "GPUs", "err w/o alignment", "err w/ alignment"], &rows);
    println!("\npaper: w/o alignment up to 36.7% error, growing with cluster size;");
    println!("alignment brings it under 5% everywhere (8-GPU error is pure RECV launch error).");

    degraded_trace_table();
}

/// An iteration-pinned fault (docs/FAULTS.md grammar) as a degradation
/// knob for the scenario table.
fn fault_knob(spec: &'static str) -> Box<dyn Fn(&mut GTrace)> {
    Box::new(move |t: &mut GTrace| {
        for f in dpro::fault::parse_faults(spec).unwrap() {
            f.apply(t);
        }
    })
}

/// Degraded-trace robustness sweep: every scenario round-trips through
/// the on-disk pipeline, so the diagnostics column is what `dpro replay
/// --trace-dir` would report on the same dump.
fn degraded_trace_table() {
    println!("\n=== Degraded external traces: diagnostics + replay error ===\n");
    const DRIFT_US: f64 = 20_000.0;

    let mut spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
    spec.cluster.clock.drift_std_us = 0.0; // drift is injected explicitly
    let tb = run(&spec, &TestbedOpts { iterations: 6, ..Default::default() });
    let truth = tb.avg_iter();

    type Knob = Box<dyn Fn(&mut GTrace)>;
    let scenarios: Vec<(&str, Knob)> = vec![
        ("clean", Box::new(|_t: &mut GTrace| {})),
        (
            "drift m1 +20ms",
            Box::new(|t: &mut GTrace| {
                degrade::inject_drift(t, 1, DRIFT_US);
            }),
        ),
        (
            "drop 2% events",
            Box::new(|t: &mut GTrace| {
                degrade::drop_events(t, 0.02, 23);
            }),
        ),
        (
            "straggler iter x3",
            Box::new(|t: &mut GTrace| {
                degrade::straggle_iteration(t, 2, 3.0);
            }),
        ),
        (
            "drift + drop",
            Box::new(|t: &mut GTrace| {
                degrade::inject_drift(t, 1, DRIFT_US);
                degrade::drop_events(t, 0.02, 23);
            }),
        ),
        // fault scenarios (docs/FAULTS.md): what `--inject` applies —
        // ingestion must stay a diagnosis, never a failure
        ("worker crash w1@3", fault_knob("worker-crash:1@3")),
        ("machine loss m1@3", fault_knob("machine-loss:1@3")),
        ("NIC flap m1 x5@2..4", fault_knob("nic-flap:1:5@2..4")),
        (
            "crash + drift",
            Box::new(|t: &mut GTrace| {
                degrade::inject_drift(t, 1, DRIFT_US);
                for f in dpro::fault::parse_faults("worker-crash:1@3").unwrap() {
                    f.apply(t);
                }
            }),
        ),
    ];

    let dir = std::env::temp_dir().join(format!("dpro_fig8_degraded_{}", std::process::id()));
    let mut rows = Vec::new();
    for (label, knob) in &scenarios {
        let mut trace = tb.trace.clone();
        knob(&mut trace);
        let _ = std::fs::remove_dir_all(&dir);
        dump_dir_with_job(&trace, &dir, Some(&JobMeta::of(&spec))).expect("dump");
        let loaded = load_dir(&dir).expect("load");
        let raw = profiler::estimate(&spec, &loaded.trace, false);
        let aligned = profiler::estimate(&spec, &loaded.trace, true);
        let diags = format!(
            "{} unmatched, {} overlap, {} lost",
            loaded.report.count(DiagKind::UnmatchedTxid),
            loaded.report.count(DiagKind::OverlapOnProc),
            loaded.report.count(DiagKind::WorkerLost),
        );
        rows.push(vec![
            label.to_string(),
            format!("{}", loaded.trace.events.len()),
            diags,
            format!("{:.2}%", rel_err_pct(raw.iteration_us(), truth)),
            format!("{:.2}%", rel_err_pct(aligned.iteration_us(), truth)),
        ]);
    }
    let _ = std::fs::remove_dir_all(&dir);
    print_table(
        &["scenario", "events", "ingest diagnostics", "err raw profile", "err aligned"],
        &rows,
    );
    println!("\nevery scenario is a dump→load round trip: the reader diagnoses damage");
    println!("(TraceReport) instead of failing, and §4.2 alignment absorbs injected drift.");
}
