//! Paper Fig. 8: effect of trace time alignment vs cluster size. Workers
//! of the 8-GPU job share one machine (no drift — only the RECV launch
//! error); larger clusters add NTP-grade clock drift.

use dpro::baselines::deployed_default;
use dpro::config::{ClusterSpec, CommPlan, FusionPlan, JobSpec, NetworkSpec, Transport};
use dpro::profiler;
use dpro::testbed::{run, TestbedOpts};
use dpro::util::print_table;
use dpro::util::stats::rel_err_pct;

fn main() {
    println!("\n=== Fig. 8: replay error w/ and w/o time alignment ===\n");
    let mut rows = Vec::new();
    for model in ["resnet50", "bert_base"] {
        for gpus in [8usize, 16, 32, 64] {
            let mut spec = JobSpec::standard(model, "horovod", Transport::Rdma);
            spec.cluster = ClusterSpec::new(gpus, 8, NetworkSpec::rdma_100g());
            // NTP-grade drift grows with cluster sprawl
            spec.cluster.clock.drift_std_us = 800.0 * (gpus as f64 / 8.0);
            spec.plan = CommPlan::per_tensor(&spec.model);
            spec.fusion = FusionPlan::singletons(&spec.model);
            let spec = deployed_default(&spec);
            let tb = run(&spec, &TestbedOpts { iterations: 8, ..Default::default() });
            let w = profiler::estimate(&spec, &tb.trace, true);
            let wo = profiler::estimate(&spec, &tb.trace, false);
            rows.push(vec![
                model.to_string(),
                format!("{gpus}"),
                format!("{:.2}%", rel_err_pct(wo.iteration_us(), tb.avg_iter())),
                format!("{:.2}%", rel_err_pct(w.iteration_us(), tb.avg_iter())),
            ]);
        }
    }
    print_table(&["model", "GPUs", "err w/o alignment", "err w/ alignment"], &rows);
    println!("\npaper: w/o alignment up to 36.7% error, growing with cluster size;");
    println!("alignment brings it under 5% everywhere (8-GPU error is pure RECV launch error).");
}
