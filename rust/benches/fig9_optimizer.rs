//! Paper Fig. 9: training throughput of dPRO's searched strategies vs the
//! baselines — XLA auto-clustering, Horovod default + autotune, BytePS
//! default. All strategies are *validated on the ground-truth testbed*
//! (the paper measures real training throughput).
//!
//! Paper claims: dPRO_OPFS up to +51.8% vs XLA; dPRO_TSFS up to +19.1% vs
//! default Horovod/BytePS; combined dPRO_OPFS_TSFS best in most cases.

use dpro::baselines;
use dpro::config::{CommPlan, JobSpec, Transport};
use dpro::optimizer::{optimize, SearchOpts};
use dpro::testbed::{run, TestbedOpts};
use dpro::util::print_table;

fn samples_per_s(spec: &JobSpec) -> f64 {
    let r = run(spec, &TestbedOpts { iterations: 5, ..Default::default() });
    (spec.cluster.n_workers * spec.model.batch_size) as f64 / (r.avg_iter() / 1e6)
}

fn main() {
    println!("\n=== Fig. 9: throughput of op-fusion / tensor-fusion strategies (16 GPUs, RDMA) ===\n");
    let budget = std::env::var("DPRO_BENCH_BUDGET_S").ok().and_then(|s| s.parse().ok()).unwrap_or(20.0);
    let mut rows = Vec::new();
    for model in ["resnet50", "vgg16", "inception_v3", "bert_base"] {
        for scheme in ["horovod", "byteps"] {
            let base = JobSpec::standard(model, scheme, Transport::Rdma);
            let deployed = baselines::deployed_default(&base);
            let t_default = samples_per_s(&deployed);

            // XLA default fusion on top of the deployed comm plan
            let mut xla = deployed.clone();
            xla.fusion = baselines::xla_auto_cluster(&xla.model);
            let t_xla = samples_per_s(&xla);

            // Horovod autotune (tensor-fusion tuning baseline)
            let t_autotune = if scheme == "horovod" {
                let mut tuned = base.clone();
                tuned.plan = baselines::horovod_autotune_plan(&base, |plan| {
                    let mut s = base.clone();
                    s.plan = plan.clone();
                    let g = dpro::graph::build_global(&s, &dpro::graph::AnalyticCost::new(&s));
                    dpro::replay::replay_once(&g).iteration_time
                });
                Some(samples_per_s(&tuned))
            } else {
                None
            };

            // dPRO strategies (search on replayer, validate on testbed)
            let opfs = optimize(&deployed, &SearchOpts { budget_wall_s: budget, ..SearchOpts::opfs_only() });
            let t_opfs = samples_per_s(&opfs.spec);
            let tsfs_start = {
                // tensor fusion searches from per-tensor granularity
                let mut s = base.clone();
                s.plan = CommPlan::per_tensor(&s.model);
                s
            };
            let tsfs = optimize(&tsfs_start, &SearchOpts { budget_wall_s: budget, ..SearchOpts::tsfs_only() });
            let t_tsfs = samples_per_s(&tsfs.spec);
            let both = optimize(&deployed, &SearchOpts { budget_wall_s: budget, ..Default::default() });
            let t_both = samples_per_s(&both.spec);

            rows.push(vec![
                model.to_string(),
                scheme.to_string(),
                format!("{t_default:.0}"),
                t_autotune.map(|t| format!("{t:.0}")).unwrap_or_else(|| "-".into()),
                format!("{t_xla:.0}"),
                format!("{t_opfs:.0}"),
                format!("{t_tsfs:.0}"),
                format!("{t_both:.0}"),
                format!("{:+.1}% / {:+.1}%",
                        100.0 * (t_both / t_xla - 1.0),
                        100.0 * (t_both / t_default - 1.0)),
            ]);
        }
    }
    print_table(
        &["model", "scheme", "default", "autotune", "XLA", "dPRO_OPFS", "dPRO_TSFS", "dPRO_BOTH", "BOTH vs XLA/default"],
        &rows,
    );
    println!("\n(samples/s on the ground-truth testbed; search budget {budget:.0}s per strategy)");
}
