//! Paper Table 5: strategy-search time as the accelerations stack up —
//! strawman → +Coarsened View → +Partial Replay → +Symmetry.
//!
//! The strawman estimates t_sync by replaying the *entire* global DFG per
//! query (paper: >24 h for BERT on their machine), so every configuration
//! here runs under a wall-clock cap; capped entries are lower bounds.

use dpro::baselines::deployed_default;
use dpro::config::{JobSpec, Transport};
use dpro::optimizer::{optimize, SearchOpts};
use dpro::util::print_table;

fn main() {
    let cap = std::env::var("DPRO_BENCH_BUDGET_S").ok().and_then(|s| s.parse().ok()).unwrap_or(30.0);
    println!("\n=== Table 5: search time (s) on BytePS, 16 GPUs (cap {cap:.0}s per cell) ===\n");
    let configs: [(&str, fn() -> SearchOpts); 4] = [
        ("strawman", || SearchOpts::strawman()),
        ("+CoarsenedView", || SearchOpts { use_coarsened_view: true, ..SearchOpts::strawman() }),
        ("+PartialReplay", || SearchOpts {
            use_coarsened_view: true,
            use_partial_replay: true,
            ..SearchOpts::strawman()
        }),
        ("+Symmetry", || SearchOpts::default()),
    ];
    let mut rows = Vec::new();
    for model in ["resnet50", "vgg16", "inception_v3", "bert_base"] {
        let spec = deployed_default(&JobSpec::standard(model, "byteps", Transport::Rdma));
        let mut row = vec![model.to_string()];
        let mut speedup_cell = String::new();
        let mut first: Option<f64> = None;
        for (name, mk) in &configs {
            let mut opts = mk();
            opts.budget_wall_s = cap;
            opts.max_rounds = 10;
            let out = optimize(&spec, &opts);
            let capped = out.wall_s >= cap * 0.98;
            row.push(format!("{}{:.2}", if capped { ">" } else { "" }, out.wall_s));
            if first.is_none() {
                first = Some(out.wall_s);
            }
            if *name == "+Symmetry" {
                speedup_cell = format!("{:.0}x", first.unwrap() / out.wall_s.max(1e-6));
            }
        }
        row.push(speedup_cell);
        rows.push(row);
    }
    print_table(
        &["model", "strawman", "+CoarsenedView", "+PartialReplay", "+Symmetry", "total speedup"],
        &rows,
    );
    println!("\npaper (hours): ResNet50 14.6 → 5.35 → 0.91 → 0.29; BERT >24 → 22 → 3.25 → 0.49");
    println!("(\">\" marks cells cut off by the wall-clock cap — true strawman time is higher)");
}
