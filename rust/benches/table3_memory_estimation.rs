//! Paper Table 3: peak-memory estimation accuracy of the replayer vs the
//! device's real peak (ground-truth testbed), batch 32/GPU.

use dpro::baselines::deployed_default;
use dpro::config::{JobSpec, Transport};
use dpro::profiler;
use dpro::testbed::{run, TestbedOpts};
use dpro::util::print_table;
use dpro::util::stats::rel_err_pct;

fn main() {
    println!("\n=== Table 3: peak memory, real vs estimated (batch 32/GPU) ===\n");
    let mut rows = Vec::new();
    for model in ["bert_base", "resnet50", "inception_v3", "vgg16"] {
        let spec = deployed_default(&JobSpec::standard(model, "horovod", Transport::Rdma));
        let tb = run(&spec, &TestbedOpts { iterations: 3, ..Default::default() });
        let est = profiler::estimate(&spec, &tb.trace, true);
        let est_mem = est.peak_memory(&spec);
        rows.push(vec![
            model.to_string(),
            format!("{:.2}", tb.peak_memory / 1e9),
            format!("{:.2}", est_mem / 1e9),
            format!("{:.2}%", rel_err_pct(est_mem, tb.peak_memory)),
        ]);
    }
    print_table(&["model", "real (GB)", "est. (GB)", "relative error"], &rows);
    println!("\npaper: relative errors 1.4% – 5.3% (absolute GB differ from the paper's");
    println!("TF allocator; the claim under test is estimation error, see DESIGN.md)");
}
