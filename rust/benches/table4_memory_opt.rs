//! Paper Table 4: BERT Base at batch 64 on 16 GB GPUs OOMs without memory
//! optimization; the optimizer evaluates re-computation vs gradient
//! accumulation. Real (testbed) vs estimated (replayer) time & memory.

use dpro::config::{CommPlan, FusionPlan, JobSpec, Transport};
use dpro::models::cost::GpuModel;
use dpro::optimizer::memopt::{self, MemOpt};
use dpro::util::print_table;

fn main() {
    println!("\n=== Table 4: BERT Base, batch 64/GPU, 16 GB V100s, 16 GPUs ===\n");
    let mut spec = JobSpec::standard("bert_base", "horovod", Transport::Rdma);
    spec.model = dpro::models::bert::bert_base(64, 128);
    spec.plan = CommPlan::per_tensor(&spec.model);
    spec.fusion = FusionPlan::singletons(&spec.model);
    spec.cluster.gpu = GpuModel::v100_16gb();

    let budget = spec.cluster.gpu.mem_capacity;
    let mut rows = Vec::new();
    for opt in [MemOpt::None, MemOpt::Recomputation, MemOpt::GradAccum] {
        let est = memopt::evaluate(&spec, opt);
        let real = memopt::ground_truth(&spec, opt);
        let oom = if real.mem_bytes > budget { " (OOM!)" } else { "" };
        rows.push(vec![
            opt.name().to_string(),
            format!("{:.2}", real.time_us / 1e3),
            format!("{:.2}", est.time_us / 1e3),
            format!("{:.2}{oom}", real.mem_bytes / 1e9),
            format!("{:.2}", est.mem_bytes / 1e9),
        ]);
    }
    print_table(
        &["optimization", "time real (ms)", "time est (ms)", "mem real (GB)", "mem est (GB)"],
        &rows,
    );
    let (chosen, _) = memopt::choose(&spec, budget);
    println!("\noptimizer's choice under the 16 GB budget: {}", chosen.name());
    println!("paper: re-computation wins (696 ms vs 714 ms; 7.4 GB vs 10.0 GB)");
}
