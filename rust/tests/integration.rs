//! Integration tests over the public API: testbed → profiler → alignment →
//! replay → optimizer, plus the PJRT runtime path (requires artifacts).

use dpro::baselines;
use dpro::config::{ClusterSpec, CommPlan, CommScheme, JobSpec, NetworkSpec, PsSpec, Transport};
use dpro::optimizer::{optimize, SearchOpts};
use dpro::profiler;
use dpro::testbed::{run as testbed_run, TestbedOpts};
use dpro::util::stats::rel_err_pct;

fn accuracy_for(model: &str, scheme: &str, transport: Transport) -> (f64, f64) {
    let spec = baselines::deployed_default(&JobSpec::standard(model, scheme, transport));
    let tb = testbed_run(&spec, &TestbedOpts { iterations: 10, ..Default::default() });
    let est = profiler::estimate(&spec, &tb.trace, true);
    let dd = baselines::daydream::estimate(
        &spec,
        Some(&profiler::corrected_profile(
            &tb.trace,
            &dpro::alignment::Alignment::identity(),
        )),
    );
    (
        rel_err_pct(est.iteration_us(), tb.avg_iter()),
        rel_err_pct(dd.iteration_us, tb.avg_iter()),
    )
}

#[test]
fn headline_replay_accuracy_beats_daydream() {
    // the paper's central claim (Fig. 7): dPRO < 5%, Daydream up to 70%
    let mut dpro_worst: f64 = 0.0;
    let mut daydream_worst: f64 = 0.0;
    for (scheme, transport) in [
        ("horovod", Transport::Rdma),
        ("byteps", Transport::Tcp),
    ] {
        let (d, dd) = accuracy_for("resnet50", scheme, transport);
        dpro_worst = dpro_worst.max(d);
        daydream_worst = daydream_worst.max(dd);
    }
    assert!(dpro_worst < 6.0, "dPRO worst-case err {dpro_worst:.2}%");
    assert!(
        daydream_worst > dpro_worst * 3.0,
        "Daydream ({daydream_worst:.1}%) should err far more than dPRO ({dpro_worst:.1}%)"
    );
}

#[test]
fn alignment_never_hurts_and_fixes_drifted_traces() {
    let mut spec =
        baselines::deployed_default(&JobSpec::standard("resnet50", "horovod", Transport::Tcp));
    spec.cluster.clock.drift_std_us = 2500.0;
    let tb = testbed_run(&spec, &TestbedOpts { iterations: 8, ..Default::default() });
    let with = profiler::estimate(&spec, &tb.trace, true);
    let without = profiler::estimate(&spec, &tb.trace, false);
    let e_with = rel_err_pct(with.iteration_us(), tb.avg_iter());
    let e_without = rel_err_pct(without.iteration_us(), tb.avg_iter());
    assert!(e_with <= e_without + 0.5, "with={e_with:.2}% without={e_without:.2}%");
    assert!(e_with < 6.0, "aligned error {e_with:.2}%");
}

#[test]
fn optimizer_beats_deployed_defaults_on_ground_truth() {
    for scheme in ["horovod", "byteps"] {
        let spec =
            baselines::deployed_default(&JobSpec::standard("resnet50", scheme, Transport::Rdma));
        let out = optimize(&spec, &SearchOpts { budget_wall_s: 25.0, max_rounds: 12, ..Default::default() });
        let base = testbed_run(&spec, &TestbedOpts { iterations: 5, ..Default::default() }).avg_iter();
        let opt =
            testbed_run(&out.spec, &TestbedOpts { iterations: 5, ..Default::default() }).avg_iter();
        assert!(
            opt < base * 1.01,
            "{scheme}: optimized {opt} vs base {base} on the testbed"
        );
    }
}

#[test]
fn scale_out_replay_accuracy_64_gpus() {
    // mini Fig. 10: accuracy holds as the cluster grows
    let mut spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
    spec.cluster = ClusterSpec::new(64, 8, NetworkSpec::rdma_100g());
    spec.plan = CommPlan::per_tensor(&spec.model);
    let spec = baselines::deployed_default(&spec);
    let tb = testbed_run(&spec, &TestbedOpts { iterations: 4, ..Default::default() });
    let est = profiler::estimate(&spec, &tb.trace, true);
    let err = rel_err_pct(est.iteration_us(), tb.avg_iter());
    assert!(err < 6.0, "64-GPU replay err {err:.2}%");
}

#[test]
fn new_schemes_replay_accurately() {
    // the comm-plan IR makes the whole pipeline scheme-blind: the two new
    // schemes must flow through testbed → trace → alignment → replay with
    // accuracy in the same ballpark as the original pair
    for scheme in ["ring", "ps-tree"] {
        let spec = baselines::deployed_default(&JobSpec::standard(
            "resnet50",
            scheme,
            Transport::Rdma,
        ));
        let tb = testbed_run(&spec, &TestbedOpts { iterations: 6, ..Default::default() });
        let est = profiler::estimate(&spec, &tb.trace, true);
        let err = rel_err_pct(est.iteration_us(), tb.avg_iter());
        assert!(err < 10.0, "{scheme}: replay err {err:.2}%");
    }
}

#[test]
fn ps_server_count_follows_machines() {
    let spec = JobSpec::standard("vgg16", "byteps", Transport::Rdma);
    match &spec.scheme {
        CommScheme::Ps(ps) => assert_eq!(ps.n_servers, PsSpec::for_cluster(&spec.cluster).n_servers),
        _ => panic!("expected PS"),
    }
}

#[test]
fn trace_roundtrip_through_disk() {
    let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
    let tb = testbed_run(&spec, &TestbedOpts { iterations: 2, ..Default::default() });
    let path = std::env::temp_dir().join("dpro_test_trace.json");
    tb.trace.save(path.to_str().unwrap()).unwrap();
    let back = dpro::trace::GTrace::load(path.to_str().unwrap()).unwrap();
    assert_eq!(back.events.len(), tb.trace.events.len());
    let est_a = profiler::estimate(&spec, &tb.trace, true);
    let est_b = profiler::estimate(&spec, &back, true);
    assert!((est_a.iteration_us() - est_b.iteration_us()).abs() < 1.0);
    let _ = std::fs::remove_file(path);
}

// ---- PJRT runtime path (requires the `pjrt` feature + `make artifacts`) ----

#[cfg(feature = "pjrt")]
mod pjrt_path {
    fn artifacts_available() -> bool {
        std::path::Path::new("artifacts/gpt_tiny.train.hlo.txt").exists()
    }

    #[test]
    fn pjrt_live_training_loss_finite_and_moving() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let cfg = dpro::coordinator::TrainCfg {
            config: "tiny".into(),
            steps: 6,
            n_workers: 2,
            log_every: 0,
            ..Default::default()
        };
        let report = dpro::coordinator::train(&cfg).expect("training");
        assert_eq!(report.losses.len(), 6);
        assert!(report.losses.iter().all(|l| l.is_finite()));
        // parameters actually change: loss at init ≈ ln(vocab)=5.55, and the
        // sequence must not be constant
        let first = report.losses[0];
        assert!((4.0..7.0).contains(&first), "init loss {first}");
        assert!(report.losses.iter().any(|&l| (l - first).abs() > 1e-4));
        // the trace contains per-worker comp events + comm + update
        assert!(report.trace.events.len() >= 6 * (2 + 2));
    }

    #[test]
    fn pjrt_deterministic_init() {
        if !artifacts_available() {
            return;
        }
        let rt = dpro::runtime::Runtime::cpu().unwrap();
        let art = dpro::runtime::GptArtifacts::load(&rt, "artifacts", "tiny").unwrap();
        let a = art.init.run(&[xla::Literal::scalar(7i32)]).unwrap();
        let b = art.init.run(&[xla::Literal::scalar(7i32)]).unwrap();
        let va = a[0].to_vec::<f32>().unwrap();
        let vb = b[0].to_vec::<f32>().unwrap();
        assert_eq!(va, vb);
        let c = art.init.run(&[xla::Literal::scalar(8i32)]).unwrap();
        assert_ne!(va, c[0].to_vec::<f32>().unwrap());
    }
}
