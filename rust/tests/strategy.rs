//! Strategy-API guarantees:
//!
//! 1. **Transactional rollback is exact**: for random decision sequences
//!    with interleaved rollbacks, the long-lived [`MutableGraph`] +
//!    incremental engine stay bit-identical to a from-scratch build +
//!    replay of the accepted-only spec, across every registered comm
//!    scheme. (A rollback is a pure inverse-journal replay — no rebuild,
//!    no spec re-clone — so any divergence here is a journal bug.)
//! 2. **Registry and memory strategies are first-class search
//!    participants**: mixed precision and a memory pass win/lose inside
//!    the round loop via incremental replay, with
//!    `builds_during_search == 0` preserved.

use std::collections::HashMap;

use dpro::config::{CommPlan, FusionPlan, JobSpec, Transport, ALL_SCHEMES};
use dpro::graph::MutableGraph;
use dpro::optimizer::memopt::{self, MemOpt};
use dpro::optimizer::registry::{GraphPass, MixedPrecisionPass};
use dpro::optimizer::strategy::Decision;
use dpro::optimizer::{optimize, SearchOpts};
use dpro::replay::incremental::IncrementalReplayer;
use dpro::util::rng::Pcg;

fn full_replay(spec: &JobSpec) -> (MutableGraph, IncrementalReplayer) {
    let mut mg = MutableGraph::new(spec.clone());
    let mut eng = IncrementalReplayer::new();
    let log = mg.commit();
    eng.replay_incremental(&mg, &log);
    (mg, eng)
}

/// Live-node schedule keyed by canonical rank — the node identity shared
/// between an incrementally-edited graph and a fresh build of its spec.
fn schedule_by_canon(mg: &MutableGraph, eng: &IncrementalReplayer) -> HashMap<u64, (f64, f64)> {
    let r = eng.result();
    let mut m = HashMap::new();
    for i in mg.dfg().ids() {
        let iu = i as usize;
        if mg.alive()[iu] {
            let prev = m.insert(mg.canon_ranks()[iu], (r.start[iu], r.end[iu]));
            assert!(prev.is_none(), "duplicate canonical rank");
        }
    }
    m
}

/// One random primitive edit (the search's own mix, plus whole-job
/// template swaps); returns the number of passes applied.
fn random_edit(rng: &mut Pcg, mg: &mut MutableGraph) -> usize {
    match rng.below(5) {
        0 => {
            let n = mg.spec().fusion.groups.len();
            let (a, b) = (rng.below(n), rng.below(n));
            (a != b && mg.fuse_comp_groups(a, b).is_ok()) as usize
        }
        1 => {
            let n = mg.n_groups();
            if n < 2 {
                return 0;
            }
            let (a, b) = (rng.below(n), rng.below(n));
            (a != b && mg.fuse_tensor_groups(a, b).is_ok()) as usize
        }
        2 | 3 => {
            let n = mg.n_groups();
            let g = rng.below(n);
            let k = 1 + rng.below(8);
            let before = mg.spec().plan.groups[g].partitions;
            (mg.set_partitions(g, k).is_ok() && before != k.max(1)) as usize
        }
        _ => {
            // whole-job template swap (mixed precision — repeated
            // applications keep shrinking tensors, which is fine here: the
            // equivalence obligation is against whatever spec results)
            match MixedPrecisionPass.apply(mg.spec()) {
                Some(cand) => mg.swap_model(cand.model).is_ok() as usize,
                None => 0,
            }
        }
    }
}

/// The incremental state must equal a from-scratch build of the current
/// (accepted-only) spec, bit-for-bit.
fn assert_matches_fresh(
    mg: &MutableGraph,
    eng: &IncrementalReplayer,
    label: &str,
) {
    let inc = eng.result().iteration_time;
    let (mg2, eng2) = full_replay(mg.spec());
    let fresh = eng2.result().iteration_time;
    assert_eq!(inc, fresh, "{label}: iteration_time diverged");
    let a = schedule_by_canon(mg, eng);
    let b = schedule_by_canon(&mg2, &eng2);
    assert_eq!(a.len(), b.len(), "{label}: live node counts differ");
    for (c, &(s1, e1)) in &a {
        let &(s2, e2) =
            b.get(c).unwrap_or_else(|| panic!("{label}: rank {c:#x} missing in fresh build"));
        assert!(
            (s1 - s2).abs() <= 1e-6 && (e1 - e2).abs() <= 1e-6,
            "{label}: node times diverged ({s1},{e1}) vs ({s2},{e2})"
        );
    }
}

#[test]
fn rollback_restores_accepted_only_state_across_schemes() {
    let mut rng = Pcg::seeded(20260730);
    let models_for = |scheme: &str| -> Vec<(&'static str, usize)> {
        match scheme {
            // the flat worker ring lowers to much larger graphs: fewer
            // (still multi-edit) steps keep the from-scratch oracle cheap
            "ring" => vec![("vgg16", 3)],
            _ => vec![("vgg16", 5), ("resnet50", 4)],
        }
    };
    for scheme in ALL_SCHEMES {
        for (model, n_steps) in models_for(scheme) {
            let spec = JobSpec::standard(model, scheme, Transport::Rdma);
            let (mut mg, mut eng) = full_replay(&spec);
            for step in 0..n_steps {
                let label = format!("{model}/{scheme} step {step}");
                let txn = mg.begin();
                let want = 1 + rng.below(3);
                let mut applied = 0usize;
                for _ in 0..24 {
                    applied += random_edit(&mut rng, &mut mg);
                    if applied >= want {
                        break;
                    }
                }
                // replay the candidate state (as the search does), then
                // randomly keep or reject it
                let log = mg.commit();
                eng.replay_incremental(&mg, &log);
                let keep = applied > 0 && rng.below(2) == 0;
                if keep {
                    mg.commit_txn(txn);
                } else {
                    mg.rollback(txn);
                    let log = mg.commit();
                    eng.replay_incremental(&mg, &log);
                }
                assert_eq!(mg.validate(), Ok(()), "{label}");
                assert!(!mg.in_txn(), "{label}");
                assert_matches_fresh(&mg, &eng, &label);
            }
        }
    }
}

#[test]
fn rollback_of_multi_edit_transaction_is_exact() {
    // one transaction mixing every decision kind, rejected as a whole:
    // the post-rollback state must equal the never-applied state exactly
    let spec = JobSpec::standard("resnet50", "byteps", Transport::Tcp);
    let (mut mg, mut eng) = full_replay(&spec);
    let before = eng.result().iteration_time;
    let n0 = mg.dfg().len();

    let txn = mg.begin();
    assert!(mg.in_txn());
    mg.fuse_tensor_groups(0, 1).unwrap();
    mg.fuse_comp_groups(2, 3).unwrap();
    mg.set_partitions(0, 4).unwrap();
    let cand = MixedPrecisionPass.apply(mg.spec()).unwrap();
    mg.swap_model(cand.model).unwrap();
    let log = mg.commit();
    let mid = eng.replay_incremental(&mg, &log).iteration_time;
    assert_ne!(mid, before, "the transaction must have had an effect");

    mg.rollback(txn);
    let log = mg.commit();
    let after = eng.replay_incremental(&mg, &log).iteration_time;
    assert_eq!(after, before, "rollback must be bit-exact");
    assert_eq!(mg.validate(), Ok(()));
    assert_matches_fresh(&mg, &eng, "multi-edit rollback");
    // appended-then-killed splice nodes stay as tombstones (ids are never
    // reused) but the arena must not have exploded from one rejection
    assert!(mg.dfg().len() < n0 * 3, "arena grew {n0} -> {}", mg.dfg().len());
}

/// The memory-constrained job of the paper's Table 4 (BERT-Base at batch
/// 64 on a 16 GB V100).
fn bert64() -> JobSpec {
    let mut s = JobSpec::standard("bert_base", "horovod", Transport::Rdma);
    s.model = dpro::models::bert::bert_base(64, 128);
    s.plan = CommPlan::per_tensor(&s.model);
    s.fusion = FusionPlan::singletons(&s.model);
    s.cluster.gpu = dpro::models::cost::GpuModel::v100_16gb();
    s
}

#[test]
fn registry_and_memory_strategies_win_inside_the_round_loop() {
    let spec = bert64();
    // a budget below the unoptimized peak forces a memory pass; mixed
    // precision alone cannot close the gap (it halves gradients, not
    // activations)
    let budget = memopt::evaluate(&spec, MemOpt::None).mem_bytes * 0.8;
    let opts = SearchOpts {
        max_rounds: 6,
        budget_wall_s: 90.0,
        memory_budget_bytes: Some(budget),
        strategies: Some("op-fuse,tensor-fuse,mixed-precision,recompute".into()),
        ..Default::default()
    };
    let out = optimize(&spec, &opts);

    // zero rebuilds even with registry + memory strategies in the loop
    assert_eq!(
        out.builds_during_search, 0,
        "registry/memory participation rebuilt the world {} times",
        out.builds_during_search
    );
    // the memory pass won a round-loop decision and restored feasibility
    assert_eq!(out.mem_opt, MemOpt::Recomputation);
    assert!(
        out.accepted.contains(&Decision::Memory(MemOpt::Recomputation)),
        "accepted: {:?}",
        out.accepted
    );
    assert!(
        out.est_mem_bytes <= budget,
        "est mem {:.2} GB over budget {:.2} GB",
        out.est_mem_bytes / 1e9,
        budget / 1e9
    );
    // mixed precision won too (compute-bound BERT)
    assert!(
        out.accepted.iter().any(|d| matches!(d, Decision::WholeJob(n) if n == "mixed_precision")),
        "accepted: {:?}",
        out.accepted
    );
    assert!(out.candidates_tried >= out.accepted.len());
    assert_eq!(out.spec.plan.validate(&out.spec.model), Ok(()));
    assert_eq!(out.spec.fusion.validate(&out.spec.model), Ok(()));
}

#[test]
fn memory_strategy_stays_quiet_under_a_generous_budget() {
    let spec = bert64();
    let budget = memopt::evaluate(&spec, MemOpt::None).mem_bytes * 2.0;
    let opts = SearchOpts {
        max_rounds: 4,
        budget_wall_s: 60.0,
        memory_budget_bytes: Some(budget),
        ..Default::default()
    };
    let out = optimize(&spec, &opts);
    assert_eq!(out.mem_opt, MemOpt::None);
    assert!(
        !out.accepted.iter().any(|d| matches!(d, Decision::Memory(_))),
        "accepted: {:?}",
        out.accepted
    );
    assert!(out.est_mem_bytes > 0.0, "budgeted searches report peak memory");
    assert!(out.est_mem_bytes <= budget);
}
