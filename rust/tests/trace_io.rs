//! End-to-end tests of the on-disk trace pipeline (ISSUE 4 tentpole):
//!
//! 1. `dump → load → corrected_profile → replay` reproduces the in-memory
//!    replay **bit-for-bit** across every scheme in `ALL_SCHEMES`;
//! 2. alignment on a drift-injected dump recovers the injected
//!    per-machine clock offsets within 1%, and the identity-alignment
//!    ablation is measurably worse;
//! 3. degraded traces (dropped events, straggler iterations) produce
//!    typed diagnostics, never panics, and still replay;
//! 4. the committed golden fixture keeps loading with a stable report and
//!    stable CLI JSON schemas.

use std::collections::HashMap;
use std::path::PathBuf;

use dpro::alignment::{align, Alignment};
use dpro::cli::{align_json, replay_json};
use dpro::config::{JobSpec, Transport, ALL_SCHEMES};
use dpro::graph::{build_global, AnalyticCost};
use dpro::profiler::{corrected_profile, estimate};
use dpro::replay::replay_once;
use dpro::testbed::{run, TestbedOpts};
use dpro::trace::degrade;
use dpro::trace::io::{dump_dir_with_job, load_dir, JobMeta, LoadedTrace};
use dpro::trace::validate::DiagKind;
use dpro::trace::GTrace;
use dpro::util::stats::rel_err_pct;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dpro_trace_io_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn dump_and_load(trace: &GTrace, spec: &JobSpec, tag: &str) -> LoadedTrace {
    let dir = tmp_dir(tag);
    dump_dir_with_job(trace, &dir, Some(&JobMeta::of(spec))).expect("dump");
    let loaded = load_dir(&dir).expect("load");
    std::fs::remove_dir_all(&dir).expect("cleanup");
    loaded
}

/// The acceptance property: an externally-persisted trace flows through
/// skeleton join + alignment + replay to the *identical* estimate the
/// in-memory trace produced — for every communication scheme.
#[test]
fn dump_load_replay_bit_for_bit_across_all_schemes() {
    for scheme in ALL_SCHEMES {
        let spec = JobSpec::standard("vgg16", scheme, Transport::Rdma);
        let tb = run(&spec, &TestbedOpts { iterations: 3, ..Default::default() });
        let mem = estimate(&spec, &tb.trace, true);

        let loaded = dump_and_load(&tb.trace, &spec, &format!("rt_{scheme}"));
        assert!(loaded.report.no_errors(), "{scheme}: {}", loaded.report);
        assert_eq!(loaded.trace.events, tb.trace.events, "{scheme}: events changed");
        assert_eq!(loaded.trace.n_workers, tb.trace.n_workers);
        assert_eq!(loaded.trace.n_procs, tb.trace.n_procs);
        assert_eq!(loaded.trace.iterations, tb.trace.iterations);
        assert_eq!(loaded.job, Some(JobMeta::of(&spec)), "{scheme}: job meta");

        let disk = estimate(&spec, &loaded.trace, true);
        assert_eq!(
            disk.iteration_us().to_bits(),
            mem.iteration_us().to_bits(),
            "{scheme}: iteration time not bit-for-bit ({} vs {})",
            disk.iteration_us(),
            mem.iteration_us()
        );
        assert_eq!(disk.fw_us().to_bits(), mem.fw_us().to_bits(), "{scheme}: fw");
        assert_eq!(disk.bw_us().to_bits(), mem.bw_us().to_bits(), "{scheme}: bw");
        assert_eq!(disk.profiled_ops, mem.profiled_ops, "{scheme}: coverage");
    }
}

/// Inject a known per-machine clock offset into a clean-clock trace; the
/// §4.2 solver must recover it within 1%, and replay with the recovered
/// offsets must beat the identity-alignment ablation.
#[test]
fn alignment_recovers_injected_drift_within_1pct() {
    const DRIFT_US: f64 = 50_000.0;
    let mut spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
    spec.cluster.clock.drift_std_us = 0.0; // clean clocks, then inject
    let tb = run(&spec, &TestbedOpts { iterations: 6, ..Default::default() });

    let mut degraded = tb.trace.clone();
    let shifted = degrade::inject_drift(&mut degraded, 1, DRIFT_US);
    assert!(shifted > 0);

    let loaded = dump_and_load(&degraded, &spec, "drift");
    assert!(loaded.report.no_errors(), "{}", loaded.report);

    let a = align(&loaded.trace, 1.0, 1.0);
    let mut per_machine: HashMap<u16, Vec<f64>> = HashMap::new();
    for (&proc, &theta) in &a.theta {
        if (proc as usize) < spec.cluster.n_workers {
            per_machine
                .entry(spec.cluster.machine_of(proc as usize) as u16)
                .or_default()
                .push(theta);
        }
    }
    // machine 1 drifted +50 ms ⇒ θ ≈ −50 ms; machine 0 is the reference
    let m1 = dpro::util::stats::mean(&per_machine[&1]);
    let m0 = dpro::util::stats::mean(&per_machine[&0]);
    let recovered = m1 - m0;
    assert!(
        (recovered + DRIFT_US).abs() < 0.01 * DRIFT_US,
        "recovered {recovered:.1} us for injected {DRIFT_US} us (m0={m0:.1}, m1={m1:.1})"
    );

    // replay quality: solved alignment beats the identity ablation
    let truth = tb.avg_iter();
    let aligned = estimate(&spec, &loaded.trace, true);
    let err_aligned = rel_err_pct(aligned.iteration_us(), truth);

    let db = corrected_profile(&loaded.trace, &Alignment::identity());
    let mut g = build_global(&spec, &AnalyticCost::new(&spec));
    db.apply(&mut g);
    let err_identity = rel_err_pct(replay_once(&g).iteration_time, truth);

    assert!(
        err_aligned < err_identity,
        "aligned {err_aligned:.2}% should beat identity {err_identity:.2}%"
    );
    assert!(
        err_identity - err_aligned > 1.0,
        "ablation should be measurably worse: identity {err_identity:.2}% vs aligned {err_aligned:.2}%"
    );
    assert!(err_aligned < 10.0, "aligned err {err_aligned:.2}%");
}

/// Dropped events break SEND↔RECV pairs: the pipeline must diagnose and
/// keep going, and the estimate must still be finite and positive.
#[test]
fn dropped_events_are_diagnosed_not_fatal() {
    let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
    let tb = run(&spec, &TestbedOpts { iterations: 2, ..Default::default() });
    let mut degraded = tb.trace.clone();
    let dropped = degrade::drop_events(&mut degraded, 0.03, 11);
    assert!(dropped > 0);

    let loaded = dump_and_load(&degraded, &spec, "drop");
    assert!(
        loaded.report.count(DiagKind::UnmatchedTxid) > 0,
        "broken transactions should be flagged: {}",
        loaded.report
    );
    let est = estimate(&spec, &loaded.trace, true);
    assert!(est.iteration_us().is_finite() && est.iteration_us() > 0.0);
}

/// A straggler iteration leaves physically impossible overlaps in the
/// recorded timeline: flagged as warnings, replay still proceeds.
#[test]
fn straggler_iteration_flagged_and_survivable() {
    let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
    let tb = run(&spec, &TestbedOpts { iterations: 3, ..Default::default() });
    let mut degraded = tb.trace.clone();
    let stretched = degrade::straggle_iteration(&mut degraded, 1, 4.0);
    assert!(stretched > 0);

    let loaded = dump_and_load(&degraded, &spec, "straggle");
    assert!(
        loaded.report.count(DiagKind::OverlapOnProc) > 0,
        "stretched iteration should overlap: {}",
        loaded.report
    );
    assert!(loaded.report.no_errors(), "warnings only: {}", loaded.report);
    let est = estimate(&spec, &loaded.trace, true);
    assert!(est.iteration_us().is_finite() && est.iteration_us() > 0.0);
    // the straggler inflates averaged durations, so the estimate rises
    let clean = estimate(&spec, &tb.trace, true);
    assert!(est.iteration_us() > clean.iteration_us());
}

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/two_worker")
}

/// The committed golden fixture: a hand-written two-worker dump with one
/// tolerated Chrome metadata event and one orphan transaction. Pins the
/// ingestion behavior and the report schema against regressions.
#[test]
fn golden_fixture_loads_with_stable_report() {
    let loaded = load_dir(&fixture_dir()).expect("fixture should load");
    assert_eq!(loaded.trace.n_workers, 2);
    assert_eq!(loaded.trace.n_procs, 2);
    assert_eq!(loaded.trace.iterations, 2);
    // 9 complete events survive; the ph:"M" process_name row is skipped
    assert_eq!(loaded.trace.events.len(), 9);
    assert_eq!(loaded.report.count(DiagKind::NonCompleteEvent), 1);
    assert_eq!(loaded.report.count(DiagKind::UnmatchedTxid), 1);
    assert!(loaded.report.no_errors(), "{}", loaded.report);

    let job = loaded.job.expect("fixture carries a job descriptor");
    assert_eq!(job.model, "resnet50");
    assert_eq!(job.scheme, "ring");
    assert_eq!(job.n_workers, 2);

    // events are seq-ordered: the first is worker 0's forward op
    assert_eq!(loaded.trace.events[0].name, "w0.FW.toy_stem");
    // SEND↔RECV pairing on (txid, iter) held for txid 1 in both iterations
    let recv = loaded.trace.events.iter().find(|e| e.name == "w1.RECV.g0").unwrap();
    assert_eq!(recv.txid, Some(1));
    assert_eq!(recv.machine, 1);
}

/// Alignment on the fixture sees machine 1's clock running ~2 ms ahead
/// and pushes its offset the other way; the CLI JSON schemas stay stable.
#[test]
fn golden_fixture_aligns_and_json_schemas_stable() {
    let loaded = load_dir(&fixture_dir()).expect("fixture should load");
    let a = align(&loaded.trace, 1.0, 1.0);
    let theta1 = a.offset(1);
    assert!(
        theta1 < -1500.0 && theta1 > -2500.0,
        "fixture drift is +2000 us; solved theta1 = {theta1}"
    );

    let aj = align_json(&a, &loaded.report);
    for key in ["procs", "objective", "iterations", "report"] {
        assert!(aj.get(key).is_some(), "align json missing {key}");
    }
    let procs = aj.get("procs").unwrap().as_arr().unwrap();
    assert_eq!(procs.len(), 2);
    for row in procs {
        assert!(row.get("proc").is_some() && row.get("theta_us").is_some());
    }

    // replay from the fixture job descriptor (op names intentionally do
    // not join the resnet50 skeleton — coverage 0, analytic durations;
    // `toy_stem` exists in no model template)
    let spec = JobSpec::standard(&loaded.job.as_ref().unwrap().model, "ring", Transport::Rdma);
    let est = estimate(&spec, &loaded.trace, true);
    assert_eq!(est.profiled_ops, 0, "fixture names must not join the skeleton");
    let rj = replay_json(&spec, &est, true, &loaded.report);
    for key in [
        "ops",
        "profiled_ops",
        "aligned",
        "iteration_us",
        "fw_us",
        "bw_us",
        "est_peak_mem_bytes",
        "report",
    ] {
        assert!(rj.get(key).is_some(), "replay json missing {key}");
    }
    let report = rj.get("report").unwrap();
    for key in ["files", "events_loaded", "events_skipped", "max_severity", "counts", "details"] {
        assert!(report.get(key).is_some(), "report json missing {key}");
    }
}
