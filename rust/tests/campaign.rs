//! Campaign engine contract tests (ISSUE 9).
//!
//! The headline property: a campaign killed mid-sweep at a seeded
//! random cell and then resumed produces a results matrix **bit-for-bit
//! identical** to an uninterrupted run, with **zero** re-executed
//! `done` cells (counted via the journal, not trusted from the
//! executor). Around it: seeded Display↔parse round-trip fuzz for the
//! sweep-spec grammar, truncation/bit-flip robustness (malformed specs
//! are clean errors, never panics), algebraic expansion counts, journal
//! torn-tail tolerance, and the CLI exit-code battery for
//! `dpro campaign`.

use dpro::campaign::queue::Journal;
use dpro::campaign::run::load_state;
use dpro::campaign::spec::NONE;
use dpro::campaign::{run, CampaignError, CampaignSpec, Filter, LaunchMode, RunOpts, Source};
use dpro::cli;
use dpro::replay::tiered::ReplayMode;
use dpro::util::rng::Pcg;
use dpro::util::Args;
use std::path::PathBuf;

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dpro_campaign_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Deterministic executor options: serial pool (a reproducible crash
/// point), pinned wall time and git describe (the two nondeterministic
/// provenance inputs).
fn det_opts(dir: &std::path::Path) -> RunOpts {
    RunOpts {
        out_dir: dir.to_path_buf(),
        jobs: 1,
        git: Some("testbuild".into()),
        fixed_wall_ms: Some(1.0),
        quiet: true,
        ..RunOpts::default()
    }
}

/// 8 analytic cells: 2 models × 2 worker counts × 2 replay modes.
const RESUME_SPEC: &str = "name = resume-prop\n\
     models = resnet50, vgg16\n\
     schemes = horovod\n\
     workers = 2, 4\n\
     source = analytic\n\
     replay-mode = exact, tiered\n";

// ---------------------------------------------------------------------
// The resumability property (satellite 2 / acceptance criterion)
// ---------------------------------------------------------------------

#[test]
fn kill_and_resume_reproduces_uninterrupted_matrix_bit_for_bit() {
    let spec = CampaignSpec::parse(RESUME_SPEC).unwrap();
    let n = spec.expand().len();
    assert_eq!(n, 8);
    // seeded random kill point, guaranteed to leave both completed and
    // unfinished cells behind
    let k = Pcg::seeded(0xD15E_A5E0).below(n - 1) + 1;

    // interrupted run: dies between cell k's `running` line and its
    // result, exactly like a SIGKILL
    let dir_a = tmp("resume_a");
    let mut kill_opts = det_opts(&dir_a);
    kill_opts.kill_after_done = Some(k);
    let out_a = run(&spec, LaunchMode::Fresh, &kill_opts).unwrap();
    assert!(out_a.killed, "crash simulation must fire");
    assert_eq!(out_a.done, k);
    assert_eq!(out_a.executed, k, "the in-flight cell must not count as executed");
    assert!(!dir_a.join("matrix.csv").exists(), "a killed run writes no matrix");
    assert!(!dir_a.join("matrix.json").exists());

    // resume: finishes the sweep off the journal
    let out_r = run(&spec, LaunchMode::Resume, &det_opts(&dir_a)).unwrap();
    assert!(!out_r.killed);
    assert_eq!(out_r.done, n);
    assert_eq!(out_r.failed, 0);
    assert_eq!(out_r.reused, k, "every done cell must be reused, not re-run");
    assert_eq!(out_r.executed, n - k, "resume executes exactly the unfinished cells");

    // uninterrupted reference run
    let dir_b = tmp("resume_b");
    let out_b = run(&spec, LaunchMode::Fresh, &det_opts(&dir_b)).unwrap();
    assert_eq!(out_b.done, n);

    // bit-for-bit identical matrices
    for file in ["matrix.csv", "matrix.json"] {
        let a = std::fs::read(dir_a.join(file)).unwrap();
        let b = std::fs::read(dir_b.join(file)).unwrap();
        assert!(!a.is_empty());
        assert_eq!(a, b, "{file} must be byte-identical across kill+resume vs uninterrupted");
    }

    // zero re-executed done cells, counted from the journal itself
    let state = load_state(&spec, &dir_a).unwrap();
    assert_eq!(state.reruns_after_done, 0, "resume must never re-run a done cell");
    // attempts: k done once + the killed cell's dangling attempt + the
    // resume's n-k executions = n + 1 running lines in total
    let total_attempts: usize = state.attempts.values().sum();
    assert_eq!(total_attempts, n + 1);
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

// ---------------------------------------------------------------------
// Sweep-spec grammar: seeded round-trip + malformed-input fuzz
// ---------------------------------------------------------------------

fn subset<'a>(rng: &mut Pcg, pool: &[&'a str]) -> Vec<&'a str> {
    let count = rng.below(pool.len()) + 1;
    let mut picked: Vec<&str> = Vec::new();
    let mut order: Vec<usize> = (0..pool.len()).collect();
    rng.shuffle(&mut order);
    for &i in order.iter().take(count) {
        picked.push(pool[i]);
    }
    picked
}

fn random_spec(rng: &mut Pcg, tag: usize) -> CampaignSpec {
    // pools are already in canonical form, so Display emits them verbatim
    let models = subset(rng, &["resnet50", "vgg16", "gpt_mini"]);
    let schemes = subset(rng, &["horovod", "ring", "byteps", "ps-tree"]);
    let workers_pool = [2usize, 4, 8, 16];
    let mut workers: Vec<usize> = Vec::new();
    for _ in 0..rng.below(3) + 1 {
        let w = workers_pool[rng.below(workers_pool.len())];
        if !workers.contains(&w) {
            workers.push(w);
        }
    }
    let strategies = subset(rng, &[NONE, "op-fuse", "op-fuse+tensor-fuse"]);
    let inject = subset(
        rng,
        &[NONE, "worker-crash:1@1", "nic-degrade:0:2@1+straggler:1:1.5@2"],
    );
    let modes = match rng.below(3) {
        0 => vec![ReplayMode::Exact],
        1 => vec![ReplayMode::Tiered],
        _ => vec![ReplayMode::Exact, ReplayMode::Tiered],
    };
    let mut spec = CampaignSpec {
        name: format!("fuzz{tag}"),
        models: models.iter().map(|s| s.to_string()).collect(),
        schemes: schemes.iter().map(|s| s.to_string()).collect(),
        workers,
        strategies: strategies.iter().map(|s| s.to_string()).collect(),
        inject: inject.iter().map(|s| s.to_string()).collect(),
        modes,
        source: Source::Testbed, // inject scenarios require testbed
        diagnose: rng.below(2) == 1,
        iters: rng.below(5) + 1,
        seed: rng.next_u64() % 1000,
        rounds: rng.below(3) + 1,
        ..CampaignSpec::default()
    };
    // a filter over values the axes actually hold stays valid on re-parse
    if rng.below(2) == 1 {
        spec.exclude.push(Filter {
            clauses: vec![
                ("model".into(), spec.models[rng.below(spec.models.len())].clone()),
                ("workers".into(), spec.workers[rng.below(spec.workers.len())].to_string()),
            ],
        });
    }
    if rng.below(4) == 0 {
        spec.include.push(Filter {
            clauses: vec![("scheme".into(), spec.schemes[rng.below(spec.schemes.len())].clone())],
        });
    }
    spec
}

#[test]
fn display_parse_round_trip_on_seeded_random_specs() {
    let mut rng = Pcg::seeded(0x5EED_CA3F);
    for trial in 0..100 {
        let spec = random_spec(&mut rng, trial);
        let text = spec.to_string();
        let re = CampaignSpec::parse(&text)
            .unwrap_or_else(|e| panic!("trial {trial}: canonical form rejected: {e}\n{text}"));
        assert_eq!(re, spec, "trial {trial}: parse(display) must be the identity\n{text}");
        assert_eq!(re.to_string(), text, "trial {trial}: display must be a fixed point");
        assert_eq!(re.hash(), spec.hash());
    }
}

#[test]
fn truncated_and_bit_flipped_specs_never_panic() {
    let base = std::fs::read_to_string(fixture_path()).unwrap();
    assert!(base.is_ascii(), "fixture must stay ASCII so byte slicing is safe");
    let mut rng = Pcg::seeded(0xBADC_0DE5);
    // every truncation point: clean Ok or Err, never a panic
    for cut in 0..base.len() {
        let _ = CampaignSpec::parse(&base[..cut]);
    }
    // seeded random byte flips
    for _ in 0..300 {
        let mut bytes = base.clone().into_bytes();
        let pos = rng.below(bytes.len());
        bytes[pos] = (rng.below(0x5F) + 0x20) as u8; // printable ASCII
        if let Ok(text) = String::from_utf8(bytes) {
            let _ = CampaignSpec::parse(&text);
        }
    }
    // garbage that is not even key=value shaped
    for garbage in ["= = =", "models", "\u{0}\u{1}\u{2}", "include = &&&", "workers = -3"] {
        assert!(CampaignSpec::parse(garbage).is_err(), "{garbage:?} must be rejected");
    }
}

#[test]
fn expansion_count_matches_algebraic_product_minus_exclusions() {
    let mut rng = Pcg::seeded(0xA1_6EB3A);
    for trial in 0..50 {
        let mut spec = random_spec(&mut rng, trial);
        spec.include.clear(); // isolate the exclusion arithmetic
        let product = spec.product();
        assert_eq!(
            product,
            spec.models.len()
                * spec.schemes.len()
                * spec.workers.len()
                * spec.strategies.len()
                * spec.inject.len()
                * spec.modes.len()
        );
        // a conjunction filter over distinct axes removes exactly the
        // sub-product where each filtered axis is pinned to one value
        let expected = match spec.exclude.first() {
            None => product,
            Some(f) => {
                let mut removed = product;
                for (key, _) in &f.clauses {
                    removed /= match key.as_str() {
                        "model" => spec.models.len(),
                        "workers" => spec.workers.len(),
                        other => panic!("unexpected filter key {other}"),
                    };
                }
                product - removed
            }
        };
        assert_eq!(spec.expand().len(), expected, "trial {trial}");
    }
}

// ---------------------------------------------------------------------
// Journal robustness (integration-level; unit tests live in queue.rs)
// ---------------------------------------------------------------------

#[test]
fn journal_with_torn_tail_resumes_cleanly() {
    use std::io::Write;
    let spec = CampaignSpec::parse(RESUME_SPEC).unwrap();
    let dir = tmp("torn");
    let mut kill_opts = det_opts(&dir);
    kill_opts.kill_after_done = Some(2);
    let out = run(&spec, LaunchMode::Fresh, &kill_opts).unwrap();
    assert!(out.killed);
    // a crash can also tear the final appended line: simulate it
    let jpath = dir.join("journal.jsonl");
    let mut f = std::fs::OpenOptions::new().append(true).open(&jpath).unwrap();
    f.write_all(b"{\"cell\":\"half-writ").unwrap();
    drop(f);
    let out_r = run(&spec, LaunchMode::Resume, &det_opts(&dir)).unwrap();
    assert_eq!(out_r.done, spec.expand().len());
    assert_eq!(load_state(&spec, &dir).unwrap().reruns_after_done, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_under_an_edited_spec_is_refused() {
    let spec = CampaignSpec::parse(RESUME_SPEC).unwrap();
    let dir = tmp("edited");
    let mut kill_opts = det_opts(&dir);
    kill_opts.kill_after_done = Some(1);
    run(&spec, LaunchMode::Fresh, &kill_opts).unwrap();
    let mut edited = spec.clone();
    edited.workers.push(8); // different matrix, different hash
    match run(&edited, LaunchMode::Resume, &det_opts(&dir)) {
        Err(CampaignError::Data(m)) => assert!(m.contains("different spec"), "{m}"),
        other => panic!("expected Data error, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn journal_header_spec_hash_round_trips() {
    let spec = CampaignSpec::parse(RESUME_SPEC).unwrap();
    let dir = tmp("header");
    std::fs::create_dir_all(&dir).unwrap();
    let j = Journal::create(&dir, &spec.name, &spec.hash()).unwrap();
    drop(j);
    let state = Journal::load(&dir, Some(&spec.hash())).unwrap();
    assert_eq!(state.campaign, "resume-prop");
    assert_eq!(state.spec_hash, spec.hash());
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// The committed example spec + CLI exit-code battery (satellite 4)
// ---------------------------------------------------------------------

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/campaign/smoke.spec")
}

#[test]
fn committed_smoke_spec_parses_and_expands() {
    let spec = CampaignSpec::load(&fixture_path()).unwrap();
    assert_eq!(spec.name, "smoke");
    assert_eq!(spec.product(), 8, "the CI smoke is a 2×2×2 sweep");
    assert_eq!(spec.expand().len(), 8);
    assert_eq!(spec.source, Source::Analytic);
    assert!(spec.diagnose);
    // canonical round-trip holds for the committed file too
    let re = CampaignSpec::parse(&spec.to_string()).unwrap();
    assert_eq!(re, spec);
}

fn campaign_args(action: &str, pairs: &[(&str, &str)], flags: &[&str]) -> Args {
    let mut a = Args::default();
    a.positional.push("campaign".into());
    if !action.is_empty() {
        a.positional.push(action.into());
    }
    for (k, v) in pairs {
        a.options.insert(k.to_string(), v.to_string());
    }
    for f in flags {
        a.flags.push(f.to_string());
    }
    a
}

#[test]
fn cli_exit_code_contract() {
    let fixture = fixture_path();
    let fixture = fixture.to_str().unwrap();

    // argument class → 2
    let bad_spec_dir = tmp("cli_badspec");
    std::fs::create_dir_all(&bad_spec_dir).unwrap();
    let bad_spec = bad_spec_dir.join("bad.spec");
    std::fs::write(&bad_spec, "models = warp9\n").unwrap();
    for (label, args) in [
        ("malformed spec", campaign_args("run", &[("spec", bad_spec.to_str().unwrap())], &[])),
        ("missing --spec", campaign_args("run", &[], &[])),
        ("missing action", campaign_args("", &[("spec", fixture)], &[])),
        ("unknown action", campaign_args("rerun", &[("spec", fixture)], &[])),
        ("bad --jobs", campaign_args("run", &[("spec", fixture), ("jobs", "0")], &[])),
        ("unparsable --jobs", campaign_args("run", &[("spec", fixture), ("jobs", "many")], &[])),
        (
            "bad --endpoint syntax",
            campaign_args("run", &[("spec", fixture), ("endpoint", "not an addr")], &[]),
        ),
        (
            "bad --budget-s",
            campaign_args("run", &[("spec", fixture), ("budget-s", "-5")], &[]),
        ),
        ("unreadable spec path", campaign_args("run", &[("spec", "/nonexistent-dpro.spec")], &[])),
    ] {
        assert_eq!(cli::run(args), 2, "{label} must exit 2");
    }

    // data class → 3
    let empty = tmp("cli_empty");
    std::fs::create_dir_all(&empty).unwrap();
    for (label, args) in [
        (
            "resume without a journal",
            campaign_args(
                "resume",
                &[("spec", fixture), ("out", empty.to_str().unwrap())],
                &["quiet"],
            ),
        ),
        (
            "status without a journal",
            campaign_args("status", &[("spec", fixture), ("out", empty.to_str().unwrap())], &[]),
        ),
        (
            "unreachable endpoint",
            campaign_args(
                "run",
                &[
                    ("spec", fixture),
                    ("out", tmp("cli_endpoint").to_str().unwrap()),
                    ("endpoint", "127.0.0.1:1"),
                ],
                &["quiet"],
            ),
        ),
    ] {
        assert_eq!(cli::run(args), 3, "{label} must exit 3");
    }

    let _ = std::fs::remove_dir_all(&bad_spec_dir);
    let _ = std::fs::remove_dir_all(&empty);
}

#[test]
fn cli_run_then_status_is_clean() {
    // a tiny end-to-end pass through the real CLI surface: run a 2-cell
    // sweep, then status — both exit 0 and the matrix lands on disk
    let dir = tmp("cli_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let spec_file = dir.join("tiny.spec");
    std::fs::write(
        &spec_file,
        "name = tiny\nmodels = resnet50\nschemes = horovod\nworkers = 2, 4\nsource = analytic\n",
    )
    .unwrap();
    let out_dir = dir.join("out");
    let spec_str = spec_file.to_str().unwrap();
    let out_str = out_dir.to_str().unwrap();
    assert_eq!(
        cli::run(campaign_args(
            "run",
            &[("spec", spec_str), ("out", out_str), ("jobs", "2")],
            &["quiet"],
        )),
        0
    );
    assert!(out_dir.join("matrix.csv").exists());
    assert!(out_dir.join("matrix.json").exists());
    assert!(out_dir.join("spec.txt").exists());
    assert_eq!(
        cli::run(campaign_args("status", &[("spec", spec_str), ("out", out_str)], &["json"])),
        0
    );
    // a second `run` on the same journal is the argument class
    assert_eq!(
        cli::run(campaign_args(
            "run",
            &[("spec", spec_str), ("out", out_str)],
            &["quiet"],
        )),
        2
    );
    let _ = std::fs::remove_dir_all(&dir);
}
