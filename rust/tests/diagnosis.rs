//! Diagnosis-engine guarantees:
//!
//! 1. **Exact blame decomposition**: critical-path and per-device blame
//!    categories sum to the replayed iteration time *bit-for-bit*, across
//!    every registered comm scheme × model (and on trace-driven
//!    diagnoses).
//! 2. **Perfect-overlap is an upper bound**: zeroing all communication
//!    bounds any compute-preserving comm-plan optimization — no
//!    `optimize()` run restricted to plan rewrites may beat it.
//! 3. **Queries leave no trace**: after any what-if sequence the graph +
//!    engine equal a from-scratch build bit-exactly (the strategy.rs
//!    rollback-equivalence sweep, re-aimed at the query path), with zero
//!    `build_global*` calls (transaction counter).
//! 4. **Blame ranking pays off**: ordering candidates by critical-path
//!    blame reaches the unranked search's best cost in strictly fewer
//!    candidates on at least one model/scheme pair.
//! 5. **Degraded traces degrade, never panic**: a trace with dropped
//!    events yields a diagnosis carrying `TraceReport` warnings and a
//!    still-exact blame decomposition.

use std::collections::HashMap;

use dpro::config::{JobSpec, Transport, ALL_SCHEMES};
use dpro::diagnosis::{Diagnoser, WhatIfQuery};
use dpro::graph::{build_count, MutableGraph};
use dpro::optimizer::{optimize, SearchOpts, SearchOutcome};
use dpro::replay::incremental::IncrementalReplayer;
use dpro::trace::degrade;
use dpro::trace::validate::{validate, DiagKind, TraceReport};
use dpro::util::rng::Pcg;

// ---------------------------------------------------------------------------
// 1. exact blame decomposition
// ---------------------------------------------------------------------------

fn assert_blame_exact(d: &Diagnoser, label: &str) {
    let b = d.blame();
    assert!(b.iteration_us > 0.0, "{label}: empty replay");
    // the contract, in the documented evaluation order, bitwise
    assert_eq!(
        (b.path.comp_us + b.path.comm_us) + b.path.blocked_us,
        b.iteration_us,
        "{label}: path blame does not sum exactly"
    );
    for row in &b.devices {
        assert_eq!(
            (row.comp_us + row.comm_us) + row.blocked_us,
            b.iteration_us,
            "{label}: device {} does not sum exactly",
            row.device
        );
    }
    assert_eq!(b.check(), Ok(()), "{label}");
    // the replayed critical path has no gaps: blocked is float noise only
    assert!(
        b.path.blocked_us.abs() < 1.0,
        "{label}: path blocked {} us",
        b.path.blocked_us
    );
}

#[test]
fn blame_sums_bit_for_bit_across_schemes_and_models() {
    for scheme in ALL_SCHEMES {
        for model in ["vgg16", "resnet50"] {
            let spec = JobSpec::standard(model, scheme, Transport::Rdma);
            let d = Diagnoser::new(spec);
            assert_blame_exact(&d, &format!("{model}/{scheme}"));
        }
    }
}

#[test]
fn blame_sums_bit_for_bit_on_trace_driven_diagnosis() {
    let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
    let tb = dpro::testbed::run(
        &spec,
        &dpro::testbed::TestbedOpts { iterations: 3, ..Default::default() },
    );
    let mut report = TraceReport::default();
    validate(&tb.trace, &mut report);
    let d = Diagnoser::from_trace(spec, &tb.trace, report);
    assert_blame_exact(&d, "trace-driven resnet50/horovod");
}

// ---------------------------------------------------------------------------
// 2. perfect-overlap upper bound
// ---------------------------------------------------------------------------

#[test]
fn perfect_overlap_bounds_plan_rewriting_search() {
    // Restricted to plan rewrites that preserve every computation
    // duration (partition only; no coarsening, no op fusion), the
    // optimizer can never beat the zero-communication replay: schedule
    // times are monotone in durations, and with all comm at zero every
    // plan collapses to the same pure-compute schedule.
    for (model, scheme) in [("vgg16", "byteps"), ("resnet50", "ps-tree")] {
        let spec = JobSpec::standard(model, scheme, Transport::Tcp);
        let mut d = Diagnoser::new(spec.clone());
        let po = d.what_if(&WhatIfQuery::PerfectOverlap);
        assert!(po.edited_ops > 0);
        assert!(po.iteration_us < po.baseline_us, "{model}/{scheme}");

        let opts = SearchOpts {
            use_coarsened_view: false,
            strategies: Some("partition".into()),
            max_rounds: 5,
            budget_wall_s: 60.0,
            ..Default::default()
        };
        let out = optimize(&spec, &opts);
        assert!(
            po.iteration_us <= out.est_iteration_us,
            "{model}/{scheme}: perfect overlap {} must bound the search's {}",
            po.iteration_us,
            out.est_iteration_us
        );
    }
}

// ---------------------------------------------------------------------------
// 3. queries leave no trace (the strategy.rs rollback sweep, on queries)
// ---------------------------------------------------------------------------

/// Live-node schedule keyed by canonical rank — the node identity shared
/// between an incrementally-edited graph and a fresh build of its spec.
fn schedule_by_canon(mg: &MutableGraph, eng: &IncrementalReplayer) -> HashMap<u64, (f64, f64)> {
    let r = eng.result();
    let mut m = HashMap::new();
    for i in mg.dfg().ids() {
        let iu = i as usize;
        if mg.alive()[iu] {
            let prev = m.insert(mg.canon_ranks()[iu], (r.start[iu], r.end[iu]));
            assert!(prev.is_none(), "duplicate canonical rank");
        }
    }
    m
}

fn random_query(rng: &mut Pcg, d: &Diagnoser) -> WhatIfQuery {
    let n_workers = d.mg().n_workers().max(1);
    let n_groups = d.mg().n_groups().max(1);
    let n_fusion = d.spec().fusion.groups.len().max(1);
    match rng.below(6) {
        0 => WhatIfQuery::PerfectOverlap,
        1 => WhatIfQuery::ScaleNic(0.5 + rng.f64() * 3.5),
        2 => WhatIfQuery::ScaleNvlink(0.5 + rng.f64() * 3.5),
        3 => WhatIfQuery::EqualizeWorker(rng.below(n_workers) as u16),
        4 => WhatIfQuery::ZeroGroup(rng.below(n_groups)),
        _ => WhatIfQuery::ShrinkOp(rng.below(n_fusion) as u32, 0.25 + rng.f64()),
    }
}

#[test]
fn graph_restored_bit_exactly_after_any_query_sequence() {
    let mut rng = Pcg::seeded(20260731);
    for (model, scheme) in [("resnet50", "horovod"), ("vgg16", "ps-tree")] {
        let spec = JobSpec::standard(model, scheme, Transport::Rdma);
        let mut d = Diagnoser::new(spec.clone());
        let base = d.baseline_us();
        let before = schedule_by_canon(d.mg(), d.engine());
        let builds0 = build_count();
        for step in 0..12 {
            let q = random_query(&mut rng, &d);
            let a = d.what_if(&q);
            assert!(
                a.iteration_us.is_finite() && a.iteration_us >= 0.0,
                "{model}/{scheme} step {step}: bad answer for {q}"
            );
            // restored bit-exactly after every single query
            assert_eq!(
                d.engine().result().iteration_time,
                base,
                "{model}/{scheme} step {step}: engine not restored after {q}"
            );
        }
        assert_eq!(build_count(), builds0, "{model}/{scheme}: queries built graphs");
        assert_eq!(d.queries_run(), 12);
        // the cached schedule equals the pre-query one, node for node
        let after = schedule_by_canon(d.mg(), d.engine());
        assert_eq!(before, after, "{model}/{scheme}: schedule diverged");
        // ... and equals a from-scratch build of the (unchanged) spec
        let mut mg2 = MutableGraph::new(spec);
        let mut eng2 = IncrementalReplayer::new();
        let log = mg2.commit();
        eng2.replay_incremental(&mg2, &log);
        let fresh = schedule_by_canon(&mg2, &eng2);
        assert_eq!(after, fresh, "{model}/{scheme}: diverged from fresh build");
        assert_eq!(d.mg().validate(), Ok(()));
    }
}

#[test]
fn diagnose_answers_four_query_kinds_with_zero_builds() {
    // the acceptance contract: >= 4 what-if query kinds answered with
    // builds_during_search == 0, via the transaction counter
    let spec = JobSpec::standard("resnet50", "byteps", Transport::Rdma);
    let mut d = Diagnoser::new(spec);
    let queries = [
        WhatIfQuery::PerfectOverlap,
        WhatIfQuery::ScaleNic(2.0),
        WhatIfQuery::EqualizeWorker(0),
        WhatIfQuery::ZeroGroup(0),
        WhatIfQuery::ShrinkOp(0, 0.5),
    ];
    let builds0 = build_count();
    let answers: Vec<_> = queries.iter().map(|q| d.what_if(q)).collect();
    assert_eq!(build_count() - builds0, 0, "what-if queries built graphs");
    assert_eq!(d.builds_during_queries(), 0);
    assert_eq!(answers.len(), 5);
    for a in &answers {
        assert!(a.iteration_us > 0.0);
    }
    // and the bundled report agrees
    let auto = d.auto_queries();
    let rep = d.report(&auto, 5);
    assert_eq!(rep.builds_during_queries, 0);
}

// ---------------------------------------------------------------------------
// 4. blame-ranked search spends fewer candidates
// ---------------------------------------------------------------------------

/// Candidates tried when the search first reached `target` or better.
fn candidates_to(out: &SearchOutcome, target: f64) -> Option<usize> {
    out.accept_trace
        .iter()
        .find(|&&(_, t)| t <= target)
        .map(|&(n, _)| n)
}

#[test]
fn blame_ranking_reaches_target_in_fewer_candidates() {
    let pairs = [
        ("resnet50", "horovod"),
        ("vgg16", "byteps"),
        ("vgg16", "horovod"),
        ("bert_base", "horovod"),
    ];
    let mut strictly_fewer = false;
    for (model, scheme) in pairs {
        let spec = JobSpec::standard(model, scheme, Transport::Rdma);
        let run = |ranked: bool| {
            let opts = SearchOpts {
                use_blame_ranking: ranked,
                max_rounds: 6,
                budget_wall_s: 60.0,
                ..Default::default()
            };
            optimize(&spec, &opts)
        };
        let unranked = run(false);
        let ranked = run(true);
        // the ranked search must still land at (or beyond) the same cost
        let target = unranked.est_iteration_us;
        let (Some(r), Some(u)) = (candidates_to(&ranked, target), candidates_to(&unranked, target))
        else {
            continue;
        };
        if r < u {
            strictly_fewer = true;
        }
    }
    assert!(
        strictly_fewer,
        "blame ranking never strictly reduced candidates-to-target on any pair"
    );
}

// ---------------------------------------------------------------------------
// 5. degraded traces degrade, never panic
// ---------------------------------------------------------------------------

#[test]
fn whatif_on_degraded_trace_warns_instead_of_panicking() {
    let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
    let tb = dpro::testbed::run(
        &spec,
        &dpro::testbed::TestbedOpts { iterations: 2, ..Default::default() },
    );
    let mut trace = tb.trace.clone();
    let dropped = degrade::drop_events(&mut trace, 0.5, 1234);
    assert!(dropped > 0);
    let mut report = TraceReport::default();
    validate(&trace, &mut report);

    let mut d = Diagnoser::from_trace(spec, &trace, report);
    let auto = d.auto_queries();
    let rep = d.report(&auto, 5);
    // the damage is reported, in TraceReport form...
    assert!(!rep.trace.is_clean(), "dropped events must be flagged");
    assert!(
        rep.trace.count(DiagKind::MissingProfile) > 0
            || rep.trace.count(DiagKind::UnmatchedTxid) > 0,
        "expected missing_profile/unmatched_txid diagnostics: {}",
        rep.trace
    );
    // ...and the diagnosis itself stays sound: exact sums, finite answers
    assert_blame_exact(&d, "degraded vgg16/horovod");
    for a in &rep.whatif {
        assert!(a.iteration_us.is_finite() && a.iteration_us >= 0.0, "{}", a.query);
    }
    assert_eq!(rep.builds_during_queries, 0);
}
