//! Self-telemetry tests: span nesting and cross-thread parenting through
//! the worker pools, histogram bucket edges and quantiles, deterministic
//! Prometheus rendering, the `--self-trace` gTrace dump round-tripping
//! through `trace::io::load_dir` with zero diagnostics, the CLI flag
//! contract (malformed `--self-trace` exits 2), and the serve daemon's
//! `/statsz` ↔ `/metricsz` consistency (two renderings of one registry,
//! legacy JSON schema pinned).
//!
//! Span collection (`obs::set_enabled`) and the span sink are
//! process-global, so every test that enables collection or drains
//! [`dpro::obs::take_spans`] serializes on [`OBS_LOCK`] and filters by
//! its own unique span-name prefix.

use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard};

use dpro::cli;
use dpro::config::{JobSpec, Transport};
use dpro::graph::{build_global_nameless, AnalyticCost, OpKind};
use dpro::obs::export::{dump_self_trace, gtrace_from_spans, op_kind_for};
use dpro::obs::metrics::LATENCY_BOUNDS_US;
use dpro::obs::{
    set_enabled, span, take_spans, Histogram, MetricsRegistry, SpanKind, SpanRec,
};
use dpro::replay::Replayer;
use dpro::serve::http::Client;
use dpro::serve::{start, ServeOpts};
use dpro::trace::io::load_dir;
use dpro::util::json::{parse, Json};
use dpro::util::pool::{parallel_for, FixedPool};
use dpro::util::Args;

/// Serializes every test that flips the process-global enable flag or
/// drains the global span sink.
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn obs_lock() -> MutexGuard<'static, ()> {
    // a failed sibling test must not cascade into poisoned-lock panics
    OBS_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dpro_obs_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/two_worker")
}

fn by_name<'a>(spans: &'a [SpanRec], name: &str) -> Vec<&'a SpanRec> {
    spans.iter().filter(|s| s.name.resolve() == name).collect()
}

// ---------------------------------------------------------------- spans

#[test]
fn disabled_spans_are_inert() {
    let _l = obs_lock();
    set_enabled(false);
    let _ = take_spans();
    {
        let g = span("obs.test.inert", SpanKind::Work);
        assert_eq!(g.id(), 0, "a disabled span guard must be the inert zero guard");
    }
    assert!(
        by_name(&take_spans(), "obs.test.inert").is_empty(),
        "disabled spans must not reach the sink"
    );
}

#[test]
fn nesting_parents_on_one_thread() {
    let _l = obs_lock();
    let _ = take_spans();
    set_enabled(true);
    let (outer_id, inner_id) = {
        let outer = span("obs.test.nest.outer", SpanKind::Work);
        let inner = span("obs.test.nest.inner", SpanKind::Wait);
        let _leaf = span("obs.test.nest.leaf", SpanKind::Read);
        (outer.id(), inner.id())
    };
    set_enabled(false);
    let spans = take_spans();
    let outer = by_name(&spans, "obs.test.nest.outer");
    let inner = by_name(&spans, "obs.test.nest.inner");
    let leaf = by_name(&spans, "obs.test.nest.leaf");
    assert_eq!((outer.len(), inner.len(), leaf.len()), (1, 1, 1));
    assert_eq!(outer[0].id, outer_id);
    assert_eq!(outer[0].parent, 0, "outer is a root span");
    assert_eq!(inner[0].parent, outer_id, "inner nests under outer");
    assert_eq!(leaf[0].parent, inner_id, "leaf nests under inner");
    assert_eq!(inner[0].kind, SpanKind::Wait);
    assert!(outer[0].dur_us >= inner[0].dur_us, "parent spans contain their children");
}

#[test]
fn workers_parent_under_the_submitting_span() {
    let _l = obs_lock();
    let _ = take_spans();
    set_enabled(true);

    // scoped pool: parallel_for captures the caller's context
    let outer_id = {
        let outer = span("obs.test.pool.outer", SpanKind::Work);
        parallel_for(4, |_| {
            let _s = span("obs.test.pool.task", SpanKind::Work);
        });
        outer.id()
    };

    // persistent pool: execute captures the submitter's context
    let submit_id = {
        let submit = span("obs.test.pool.submit", SpanKind::Work);
        let pool = FixedPool::new(2);
        for _ in 0..3 {
            pool.execute(|| {
                let _s = span("obs.test.pool.job", SpanKind::Work);
            });
        }
        drop(pool); // joins the workers, flushing their span buffers
        submit.id()
    };

    set_enabled(false);
    let spans = take_spans();
    let tasks = by_name(&spans, "obs.test.pool.task");
    assert_eq!(tasks.len(), 4);
    for t in tasks {
        assert_eq!(t.parent, outer_id, "parallel_for task must parent under the caller");
    }
    let jobs = by_name(&spans, "obs.test.pool.job");
    assert_eq!(jobs.len(), 3);
    for j in jobs {
        assert_eq!(j.parent, submit_id, "pool job must parent under the submitter");
    }
}

// -------------------------------------------------------------- metrics

#[test]
fn histogram_bucket_edges_are_inclusive() {
    let h = Histogram::new();
    // exactly on a bound lands in that bucket (inclusive upper edge)
    h.observe_us(1.0);
    h.observe_us(2.5);
    h.observe_us(2.6); // first value past the 2.5 edge
    h.observe_us(-4.0); // clamped to 0 → first bucket
    h.observe_us(f64::NAN); // clamped to 0 → first bucket
    h.observe_us(1e12); // beyond the ladder → +Inf bucket
    let s = h.snapshot();
    assert_eq!(s.count, 6);
    assert_eq!(s.buckets[0], 3, "1.0 and the two clamped values share bucket le=1");
    assert_eq!(s.buckets[1], 1, "2.5 sits inside le=2.5, not le=5");
    assert_eq!(s.buckets[2], 1, "2.6 spills into le=5");
    assert_eq!(*s.buckets.last().unwrap(), 1, "1e12 lands in +Inf");
    assert_eq!(s.sum_us, 1 + 3 + 3 + 1_000_000_000_000);

    // quantiles: 100 observations spread across one bucket interpolate
    let q = Histogram::new();
    for _ in 0..100 {
        q.observe_us(7.0); // bucket (5, 10]
    }
    let qs = q.snapshot();
    assert_eq!(qs.p50(), 7.5, "mid-bucket rank interpolates linearly");
    assert!(qs.p99() > qs.p50());
    assert!(qs.p99() <= 10.0, "p99 stays inside the bucket");
    assert_eq!(LATENCY_BOUNDS_US[0], 1.0);
    assert_eq!(*LATENCY_BOUNDS_US.last().unwrap(), 10_000_000.0);
}

#[test]
fn prometheus_render_is_deterministic_and_typed() {
    let reg = MetricsRegistry::new();
    reg.counter("dpro_test_total").add(3);
    reg.counter_with("dpro_test_routed_total", &[("route", "/jobs"), ("status", "200")]).inc();
    reg.counter_with("dpro_test_routed_total", &[("route", "/healthz"), ("status", "200")]).inc();
    reg.gauge("dpro_test_depth").set(7);
    let h = reg.histogram("dpro_test_latency_us");
    h.observe_us(3.0);
    h.observe_us(40.0);
    let a = reg.render_prometheus();
    let b = reg.render_prometheus();
    assert_eq!(a, b, "rendering the same registry twice must be byte-identical");
    assert!(a.contains("# TYPE dpro_test_total counter"));
    assert!(a.contains("dpro_test_total 3"));
    assert!(a.contains("# TYPE dpro_test_depth gauge"));
    assert!(a.contains("dpro_test_depth 7"));
    assert!(a.contains("# TYPE dpro_test_latency_us histogram"));
    assert!(a.contains("dpro_test_latency_us_bucket{le=\"+Inf\"} 2"));
    assert!(a.contains("dpro_test_latency_us_sum 43"));
    assert!(a.contains("dpro_test_latency_us_count 2"));
    // labeled series render sorted, one per label set
    let routed = a.lines().filter(|l| l.starts_with("dpro_test_routed_total{")).count();
    assert_eq!(routed, 2);
    let healthz = a.find("route=\"/healthz\"").unwrap();
    let jobs = a.find("route=\"/jobs\"").unwrap();
    assert!(healthz < jobs, "label sets render in sorted order");
}

// -------------------------------------------------------------- exports

#[test]
fn span_kinds_export_to_unchecked_op_kinds() {
    assert_eq!(op_kind_for(SpanKind::Work), OpKind::Aggregate);
    assert_eq!(op_kind_for(SpanKind::Wait), OpKind::Negotiate);
    assert_eq!(op_kind_for(SpanKind::Read), OpKind::In);
    assert_eq!(op_kind_for(SpanKind::Write), OpKind::Out);
    assert_eq!(op_kind_for(SpanKind::Net), OpKind::Send);
    // an empty sink still dumps a loadable one-event trace
    let g = gtrace_from_spans(&[]);
    assert_eq!(g.events.len(), 1);
    assert_eq!(g.events[0].name, "obs.idle");
}

/// The acceptance property: enable collection, run a real replay, dump
/// the span forest with [`dump_self_trace`], and re-ingest the directory
/// through the ordinary trace loader with **zero diagnostics of any
/// severity** — dpro's own trace is a first-class gTrace.
#[test]
fn self_trace_dump_round_trips_load_dir() {
    let _l = obs_lock();
    let _ = take_spans();
    set_enabled(true);
    {
        let _root = span("obs.test.roundtrip", SpanKind::Work);
        let spec = JobSpec::standard("gpt_mini", "horovod", Transport::Rdma);
        let g = build_global_nameless(&spec, &AnalyticCost::new(&spec));
        let mut rp = Replayer::new(&g);
        rp.replay(&g);
    }
    set_enabled(false);

    let dir = tmp_dir("roundtrip");
    let summary = dump_self_trace(&dir).unwrap();
    assert!(summary.events >= 2, "expected at least the root and replay spans");
    assert!(dir.join("metrics.prom").exists(), "the Prometheus sidecar must be written");

    let loaded = load_dir(&dir).unwrap();
    assert!(
        loaded.report.diagnostics.is_empty(),
        "self-trace must re-ingest clean, got: {:?}",
        loaded.report.diagnostics
    );
    assert!(loaded.report.no_errors());
    assert_eq!(loaded.report.events_skipped, 0);
    let names: Vec<&str> = loaded.trace.events.iter().map(|e| e.name.as_str()).collect();
    assert!(names.contains(&"replay.exact"), "replay span missing from dump: {names:?}");
    assert!(names.contains(&"obs.test.roundtrip"));
    // the sink was drained by the dump
    assert!(take_spans().is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}

/// `dpro replay --trace-dir <fixture> --self-trace <dir>` end-to-end
/// through the CLI entry point: exit 0, and the dump re-ingests clean
/// with the CLI root span present.
#[test]
fn cli_replay_self_trace_dumps_cleanly() {
    let _l = obs_lock();
    let _ = take_spans();
    let dir = tmp_dir("cli");
    let mut a = Args::default();
    a.positional.push("replay".into());
    a.options.insert("trace-dir".into(), fixture_dir().display().to_string());
    a.options.insert("self-trace".into(), dir.display().to_string());
    a.flags.push("json".into());
    let code = cli::run(a);
    set_enabled(false); // cli::run enables collection and leaves it on
    assert_eq!(code, 0);

    let loaded = load_dir(&dir).unwrap();
    assert!(
        loaded.report.diagnostics.is_empty(),
        "CLI self-trace must re-ingest clean, got: {:?}",
        loaded.report.diagnostics
    );
    let names: Vec<&str> = loaded.trace.events.iter().map(|e| e.name.as_str()).collect();
    assert!(names.contains(&"cli.replay"), "root CLI span missing: {names:?}");
    assert!(names.contains(&"replay.exact"), "replay span missing: {names:?}");
    let _ = take_spans();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Malformed `--self-trace` is a usage error: exit 2 before any work,
/// without enabling collection.
#[test]
fn malformed_self_trace_exits_2() {
    // bare flag, no directory argument
    let mut a = Args::default();
    a.positional.push("replay".into());
    a.flags.push("self-trace".into());
    assert_eq!(cli::run(a), 2);

    // argument exists but is a file, not a directory
    let file = std::env::temp_dir().join(format!("dpro_obs_notdir_{}", std::process::id()));
    std::fs::write(&file, "x").unwrap();
    let mut a = Args::default();
    a.positional.push("replay".into());
    a.options.insert("self-trace".into(), file.display().to_string());
    assert_eq!(cli::run(a), 2);
    let _ = std::fs::remove_file(&file);
}

// ---------------------------------------------------------------- serve

fn prom_value(text: &str, series: &str) -> f64 {
    text.lines()
        .find_map(|l| {
            let mut it = l.split_whitespace();
            (it.next() == Some(series)).then(|| it.next().unwrap().parse().unwrap())
        })
        .unwrap_or_else(|| panic!("series {series} missing from:\n{text}"))
}

/// `/statsz` and `/metricsz` are two renderings of one registry: the
/// session-cache counters agree exactly, and the request-latency
/// histogram is present with counted traffic.
#[test]
fn statsz_and_metricsz_agree_on_one_registry() {
    let opts = ServeOpts {
        addr: "127.0.0.1:0".into(),
        threads: 2,
        batch_window_ms: 0,
        ..ServeOpts::default()
    };
    let handle = start(&opts).unwrap();
    let mut c = Client::new(&handle.addr().to_string());

    let job_body =
        r#"{"job":{"model":"gpt_mini","scheme":"horovod","transport":"rdma","workers":2}}"#;
    let (s, _) = c.call("POST", "/jobs", Some(job_body)).unwrap();
    assert_eq!(s, 200);
    let (s, _) = c.call("POST", "/jobs", Some(job_body)).unwrap(); // cache hit
    assert_eq!(s, 200);

    let stats = c.get_json("/statsz").unwrap();
    let cache = stats.get("cache").unwrap();
    assert_eq!(cache.f64("hits"), 1.0);
    assert_eq!(cache.f64("misses"), 1.0);

    let (s, prom) = c.call("GET", "/metricsz", None).unwrap();
    assert_eq!(s, 200);
    assert_eq!(prom_value(&prom, "dpro_cache_hits_total"), cache.f64("hits"));
    assert_eq!(prom_value(&prom, "dpro_cache_misses_total"), cache.f64("misses"));
    assert_eq!(prom_value(&prom, "dpro_cache_evictions_total"), cache.f64("evictions"));
    assert_eq!(prom_value(&prom, "dpro_cache_bytes"), cache.f64("bytes"));
    assert_eq!(prom_value(&prom, "dpro_sessions"), cache.f64("sessions"));
    assert_eq!(prom_value(&prom, "dpro_threads"), stats.f64("threads"));
    // the /metricsz request itself is the one request after /statsz
    assert_eq!(prom_value(&prom, "dpro_requests_total"), stats.f64("requests") + 1.0);

    // request-latency histogram, labeled by route pattern
    assert!(prom.contains("# TYPE dpro_request_latency_us histogram"), "{prom}");
    assert!(prom.contains("dpro_request_latency_us_bucket{route=\"/jobs\",le=\"+Inf\"} 2"));
    assert!(prom.contains("dpro_request_latency_us_count{route=\"/jobs\"} 2"));
    assert!(prom.contains("dpro_request_latency_us_count{route=\"/statsz\"} 1"));
    // per-route/status response counters and queue-wait histogram exist
    assert!(prom.contains("dpro_responses_total{route=\"/jobs\",status=\"200\"} 2"));
    assert!(prom.contains("dpro_conn_queue_wait_us_count"));

    handle.stop();
}

/// The legacy `/statsz` JSON schema, pinned: consolidating the daemon's
/// counters into the registry must not change the response shape.
#[test]
fn statsz_legacy_schema_is_stable() {
    fn flatten(j: &Json, prefix: &str, out: &mut Vec<String>) {
        match j {
            Json::Obj(m) => {
                for (k, v) in m {
                    let p = if prefix.is_empty() {
                        k.clone()
                    } else {
                        format!("{prefix}.{k}")
                    };
                    flatten(v, &p, out);
                }
            }
            Json::Arr(a) => match a.first() {
                None => out.push(format!("{prefix}[]")),
                Some(first) => flatten(first, &format!("{prefix}[]"), out),
            },
            _ => out.push(prefix.to_string()),
        }
    }

    let opts = ServeOpts {
        addr: "127.0.0.1:0".into(),
        threads: 2,
        batch_window_ms: 0,
        ..ServeOpts::default()
    };
    let handle = start(&opts).unwrap();
    let mut c = Client::new(&handle.addr().to_string());
    let job_body =
        r#"{"job":{"model":"gpt_mini","scheme":"horovod","transport":"rdma","workers":2}}"#;
    let (s, _) = c.call("POST", "/jobs", Some(job_body)).unwrap();
    assert_eq!(s, 200);

    let (s, body) = c.call("GET", "/statsz", None).unwrap();
    assert_eq!(s, 200);
    let mut keys = Vec::new();
    flatten(&parse(&body).unwrap(), "", &mut keys);
    assert_eq!(
        keys,
        vec![
            "batch.batches",
            "batch.coalesced",
            "cache.bytes",
            "cache.cap_bytes",
            "cache.evictions",
            "cache.hit_rate",
            "cache.hits",
            "cache.misses",
            "cache.sessions",
            "queue_depth",
            "requests",
            "sessions[].bytes",
            "sessions[].job",
            "sessions[].whatif_served",
            "threads",
            "uptime_s",
            "version",
        ],
        "the legacy /statsz schema changed"
    );
    handle.stop();
}
