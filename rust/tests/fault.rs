//! Fault-injection + elastic-recovery guarantees (docs/FAULTS.md):
//!
//! 1. **Elastic replan is exact**: `MutableGraph::rescale_workers(n-1)`
//!    on a live incremental graph matches a from-scratch build + replay
//!    of the (n-1)-worker spec bit-for-bit, for every registered scheme.
//! 2. **`continue-on:<k>` is transactional**: the what-if runs as
//!    begin → apply → replay → rollback with zero `build_global*` calls,
//!    and the graph + engine are restored bit-exactly afterward.
//! 3. **Any single-fault trace diagnoses, never panics**: every scheme ×
//!    every fault kind ingests into a full diagnosis with the fault
//!    surfaced as a warning, not an error.
//! 4. **Fuzzed partial dumps never panic ingestion** (seeded): truncating
//!    or byte-flipping any single dump file yields, at worst, a typed
//!    error from `load_dir`.

use std::collections::HashMap;
use std::path::PathBuf;

use dpro::config::{JobSpec, Transport, ALL_SCHEMES};
use dpro::diagnosis::{Diagnoser, WhatIfQuery};
use dpro::fault::Fault;
use dpro::graph::MutableGraph;
use dpro::replay::incremental::IncrementalReplayer;
use dpro::testbed::{run as tb_run, TestbedOpts};
use dpro::trace::io::{dump_dir_with_job, load_dir, JobMeta};
use dpro::trace::validate::DiagKind;
use dpro::trace::GTrace;
use dpro::util::rng::Pcg;

fn full_replay(spec: &JobSpec) -> (MutableGraph, IncrementalReplayer) {
    let mut mg = MutableGraph::new(spec.clone());
    let mut eng = IncrementalReplayer::new();
    let log = mg.commit();
    eng.replay_incremental(&mg, &log);
    (mg, eng)
}

/// Live-node schedule keyed by canonical rank — the node identity shared
/// between an incrementally-edited graph and a fresh build of its spec.
fn schedule_by_canon(mg: &MutableGraph, eng: &IncrementalReplayer) -> HashMap<u64, (f64, f64)> {
    let r = eng.result();
    let mut m = HashMap::new();
    for i in mg.dfg().ids() {
        let iu = i as usize;
        if mg.alive()[iu] {
            let prev = m.insert(mg.canon_ranks()[iu], (r.start[iu], r.end[iu]));
            assert!(prev.is_none(), "duplicate canonical rank");
        }
    }
    m
}

/// The incremental state must equal a from-scratch build of the current
/// spec, bit-for-bit on iteration time and per-node times by rank.
fn assert_matches_fresh(mg: &MutableGraph, eng: &IncrementalReplayer, label: &str) {
    let inc = eng.result().iteration_time;
    let (mg2, eng2) = full_replay(mg.spec());
    let fresh = eng2.result().iteration_time;
    assert_eq!(inc, fresh, "{label}: iteration_time diverged");
    let a = schedule_by_canon(mg, eng);
    let b = schedule_by_canon(&mg2, &eng2);
    assert_eq!(a.len(), b.len(), "{label}: live node counts differ");
    for (c, &(s1, e1)) in &a {
        let &(s2, e2) =
            b.get(c).unwrap_or_else(|| panic!("{label}: rank {c:#x} missing in fresh build"));
        assert!(
            (s1 - s2).abs() <= 1e-6 && (e1 - e2).abs() <= 1e-6,
            "{label}: node times diverged ({s1},{e1}) vs ({s2},{e2})"
        );
    }
}

#[test]
fn elastic_replan_matches_fresh_smaller_build() {
    for scheme in ALL_SCHEMES {
        let spec = JobSpec::standard("vgg16", scheme, Transport::Rdma);
        let n = spec.cluster.n_workers;
        let (mut mg, mut eng) = full_replay(&spec);

        // n → n-1: the acceptance bar
        let gone = mg.rescale_workers(n - 1).unwrap();
        assert!(gone > 0, "{scheme}: rescale removed no nodes");
        let log = mg.commit();
        eng.replay_incremental(&mg, &log);
        assert_eq!(mg.n_workers(), n - 1);
        assert_eq!(mg.spec().cluster.n_workers, n - 1);
        assert_matches_fresh(&mg, &eng, &format!("{scheme} n->n-1"));

        // and further down, across a machine boundary (8 gpus/machine)
        mg.rescale_workers(n - 9).unwrap();
        let log = mg.commit();
        eng.replay_incremental(&mg, &log);
        assert_matches_fresh(&mg, &eng, &format!("{scheme} n->n-9"));
    }
}

#[test]
fn continue_on_is_transactional_across_schemes() {
    for scheme in ALL_SCHEMES {
        let spec = JobSpec::standard("vgg16", scheme, Transport::Rdma);
        let n = spec.cluster.n_workers;
        let mut d = Diagnoser::new(spec);
        let base = d.baseline_us();
        let before = schedule_by_canon(d.mg(), d.engine());

        let ans = d.what_if(&WhatIfQuery::ContinueOn(n - 2));
        assert!(ans.edited_ops > 0, "{scheme}: continue-on edited nothing");
        assert!(
            ans.iteration_us.is_finite() && ans.iteration_us > 0.0,
            "{scheme}: bad answer {}",
            ans.iteration_us
        );

        // transactional: zero builds, fleet + schedule restored bit-exactly
        assert_eq!(d.builds_during_queries(), 0, "{scheme}: query rebuilt the graph");
        assert_eq!(d.mg().n_workers(), n, "{scheme}: fleet not restored");
        assert_eq!(d.baseline_us(), base, "{scheme}: baseline drifted");
        let after = schedule_by_canon(d.mg(), d.engine());
        assert_eq!(before, after, "{scheme}: schedule not restored bit-exactly");

        // k >= n is the no-op baseline answer, still transactional
        let noop = d.what_if(&WhatIfQuery::ContinueOn(n + 3));
        assert_eq!(noop.edited_ops, 0);
        assert_eq!(noop.iteration_us, base);
        assert_eq!(d.builds_during_queries(), 0);
    }
}

/// Every scheme × every fault kind: inject into a measured trace,
/// diagnose, and the session must end with a finite answer, zero builds,
/// and (for worker-killing faults) `worker_lost` evidence in the report.
#[test]
fn single_fault_scenarios_diagnose_without_panic() {
    let fault_specs = [
        "worker-crash:1@2",
        "machine-loss:1@2",
        "nic-degrade:1:4@1",
        "nic-flap:1:6@1..3",
        "straggler:2:3@1",
    ];
    for scheme in ALL_SCHEMES {
        let spec = JobSpec::standard("resnet50", scheme, Transport::Rdma);
        let tb = tb_run(&spec, &TestbedOpts { iterations: 3, ..Default::default() });
        for fs in fault_specs {
            let label = format!("{scheme} + {fs}");
            let fault = Fault::parse(fs).unwrap();
            let mut trace = tb.trace.clone();
            let mut report = dpro::trace::validate::TraceReport::default();
            report.events_loaded = trace.events.len();
            fault.apply_with_report(&mut trace, &mut report);

            let mut d = Diagnoser::from_trace(spec.clone(), &trace, report);
            let qs = d.auto_queries();
            let rep = d.report(&qs, 5);
            assert!(
                rep.iteration_us.is_finite() && rep.iteration_us > 0.0,
                "{label}: bad iteration {}",
                rep.iteration_us
            );
            assert_eq!(rep.builds_during_queries, 0, "{label}: queries rebuilt");
            assert!(rep.trace.no_errors(), "{label}: fault escalated to error: {}", rep.trace);
            if fs.starts_with("worker-crash") || fs.starts_with("machine-loss") {
                assert!(
                    rep.trace.count(DiagKind::WorkerLost) >= 1,
                    "{label}: lost worker not surfaced: {}",
                    rep.trace
                );
                // the battery must have priced the elastic replan
                assert!(
                    rep.whatif.iter().any(|a| a.query.starts_with("continue-on:")),
                    "{label}: no continue-on what-if in {:?}",
                    rep.whatif.iter().map(|a| a.query.clone()).collect::<Vec<_>>()
                );
            }
        }
    }
}

/// A crashed-worker dump round-trips through the on-disk pipeline and
/// still diagnoses: the partial per-process file set is a warning
/// (`worker_lost`), never an ingestion error.
#[test]
fn crashed_worker_dump_roundtrips_to_diagnosis() {
    let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
    let tb = tb_run(&spec, &TestbedOpts { iterations: 3, ..Default::default() });
    let mut trace = tb.trace.clone();
    Fault::WorkerCrash { worker: 1, at_iter: 0 }.apply(&mut trace);

    let dir = tmp_dir("crash_roundtrip");
    dump_dir_with_job(&trace, &dir, Some(&JobMeta::of(&spec))).unwrap();
    // a worker dead from iteration 0 writes no dump file at all, but the
    // metadata still declares the full fleet — the loader must keep
    // n_workers and leave detection to the diagnosis, not error out
    let loaded = load_dir(&dir).unwrap();
    assert!(loaded.report.no_errors(), "{}", loaded.report);
    assert_eq!(loaded.trace.n_workers, spec.cluster.n_workers, "fleet size lost");

    let mut d = Diagnoser::from_trace(spec, &loaded.trace, loaded.report);
    let qs = d.auto_queries();
    let rep = d.report(&qs, 8);
    assert!(rep.trace.count(DiagKind::WorkerLost) >= 1, "{}", rep.trace);
    assert!(
        rep.bottlenecks.iter().any(|b| b.kind.name() == "worker-lost"),
        "no worker-lost bottleneck in {:?}",
        rep.bottlenecks.iter().map(|b| b.kind.name()).collect::<Vec<_>>()
    );
    assert_eq!(rep.builds_during_queries, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Satellite: seeded fuzz over single-file corruption. Truncating or
/// byte-flipping any one dump file must never panic `load_dir` — worst
/// case is a typed error string.
#[test]
fn fuzzed_single_file_corruption_never_panics_ingestion() {
    let mut spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
    spec.cluster.n_workers = 4;
    spec.cluster.gpus_per_machine = 2;
    let tb = tb_run(&spec, &TestbedOpts { iterations: 2, ..Default::default() });
    let dir = tmp_dir("fuzz_corrupt");
    dump_dir_with_job(&tb.trace, &dir, Some(&JobMeta::of(&spec))).unwrap();
    let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    files.sort();
    assert!(files.len() >= 5, "expected metadata + 4 proc files, got {files:?}");

    let mut rng = Pcg::seeded(0x5EED_FA17);
    for round in 0..40 {
        let path = &files[rng.below(files.len())];
        let pristine = std::fs::read(path).unwrap();
        let corrupted = if rng.below(2) == 0 {
            // truncate at a random offset (half-written dump)
            pristine[..rng.below(pristine.len().max(1))].to_vec()
        } else {
            // flip one random byte (bit rot / torn write)
            let mut b = pristine.clone();
            if !b.is_empty() {
                let at = rng.below(b.len());
                b[at] ^= 1 << rng.below(8) as u8;
            }
            b
        };
        std::fs::write(path, &corrupted).unwrap();
        // the contract under fuzz: Ok-with-report or a typed error —
        // a panic aborts this test
        match load_dir(&dir) {
            Ok(loaded) => {
                let _: &GTrace = &loaded.trace;
                let _ = loaded.report.to_json().to_string();
            }
            Err(e) => assert!(!e.is_empty(), "round {round}: empty error"),
        }
        std::fs::write(path, &pristine).unwrap();
    }
    // pristine bytes restored → the dump must load cleanly again
    let loaded = load_dir(&dir).unwrap();
    assert_eq!(loaded.trace.events.len(), tb.trace.events.len());
    std::fs::remove_dir_all(&dir).unwrap();
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dpro_fault_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}
