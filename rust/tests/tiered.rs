//! Symmetry-class (tiered) replay guarantees:
//!
//! 1. **Bit-for-bit**: on unbroken symmetric plans, tiered replay equals
//!    exact replay on every node's start/end and on the iteration time,
//!    across ALL registered schemes × models × worker counts. Schemes
//!    without machine-rotation symmetry (the PS family) fall back to the
//!    exact engine and are trivially equal — the sweep asserts which of
//!    the two happened via the tier report.
//! 2. **Broken symmetry demotes, never corrupts**: stragglers, single-
//!    node what-if edits, diagnosis evidence and uneven machine layouts
//!    all demote to exact replay, and the result still equals a
//!    from-scratch exact engine fed the same edits.
//! 3. The profiler's `--replay-mode tiered` path returns the same
//!    estimate as the exact path on a measured trace.

use dpro::config::{ClusterSpec, JobSpec, NetworkSpec, Transport, ALL_SCHEMES};
use dpro::graph::{build_global, plan_symmetry, AnalyticCost, DeviceKey, GlobalDfg, PlanSymmetry};
use dpro::replay::tiered::{ReplayMode, TieredReplayer};
use dpro::replay::{replay_once, Replayer, ReplayResult};

fn spec_for(model: &str, scheme: &str, workers: usize, gpm: usize) -> JobSpec {
    let m = dpro::models::by_name(model, 32).unwrap();
    let cluster = ClusterSpec::new(workers, gpm, NetworkSpec::rdma_100g());
    JobSpec::with_scheme_name(m, cluster, scheme)
}

/// start/end/iteration_time must match to the last bit. (`last` and
/// `crit_pred` are tie-break metadata: equal-time nodes may legitimately
/// be attributed differently, so they are not compared.)
fn assert_bitwise_eq(g: &GlobalDfg, exact: &ReplayResult, tiered: &ReplayResult, label: &str) {
    assert_eq!(
        exact.iteration_time.to_bits(),
        tiered.iteration_time.to_bits(),
        "{label}: iteration_time {} vs {}",
        exact.iteration_time,
        tiered.iteration_time
    );
    for i in g.dfg.ids() {
        let iu = i as usize;
        assert_eq!(
            exact.start[iu].to_bits(),
            tiered.start[iu].to_bits(),
            "{label}: start of node {i} ({}) {} vs {}",
            g.dfg.node(i).name,
            exact.start[iu],
            tiered.start[iu]
        );
        assert_eq!(
            exact.end[iu].to_bits(),
            tiered.end[iu].to_bits(),
            "{label}: end of node {i} ({}) {} vs {}",
            g.dfg.node(i).name,
            exact.end[iu],
            tiered.end[iu]
        );
    }
}

#[test]
fn tiered_matches_exact_bitwise_across_schemes_and_sizes() {
    for scheme in ALL_SCHEMES {
        for (workers, gpm) in [(8usize, 8usize), (16, 8), (32, 8)] {
            let label = format!("{scheme} {workers}w/{gpm}gpm");
            let spec = spec_for("resnet50", scheme, workers, gpm);
            let g = build_global(&spec, &AnalyticCost::new(&spec));
            let exact = replay_once(&g);
            let mut rp = TieredReplayer::new(&g, &spec);
            let tiered = rp.replay(&g).clone();
            assert_bitwise_eq(&g, &exact, &tiered, &label);

            let rep = rp.report();
            let n_machines = spec.cluster.n_machines();
            let symmetric =
                plan_symmetry(&spec.scheme) == PlanSymmetry::MachineRotation && n_machines > 1;
            if symmetric {
                assert_eq!(rep.mode_used, "tiered", "{label}: {:?}", rep.demoted);
                assert_eq!(rep.n_symmetric, n_machines, "{label}");
                assert!(rep.derived_nodes > 0, "{label}: nothing derived");
                assert_eq!(
                    rep.simulated_nodes + rep.derived_nodes,
                    g.dfg.len(),
                    "{label}: node accounting"
                );
            } else {
                assert_eq!(rep.mode_used, "exact", "{label}: expected fallback");
                assert!(!rep.demoted.is_empty(), "{label}: fallback must give a reason");
            }
        }
    }
}

#[test]
fn tiered_matches_exact_across_models() {
    for model in ["resnet50", "vgg16", "bert_base", "gpt_mini"] {
        for scheme in ["horovod", "ring"] {
            let label = format!("{model} {scheme}");
            let spec = spec_for(model, scheme, 16, 8);
            let g = build_global(&spec, &AnalyticCost::new(&spec));
            let exact = replay_once(&g);
            let mut rp = TieredReplayer::new(&g, &spec);
            let tiered = rp.replay(&g).clone();
            assert_bitwise_eq(&g, &exact, &tiered, &label);
            assert_eq!(rp.report().mode_used, "tiered", "{label}: {:?}", rp.report().demoted);
        }
    }
}

/// A straggling machine (every GPU op on machine 1 slowed 1.5×) breaks
/// the shift symmetry: the engine must demote itself and still return
/// exactly what a from-scratch exact engine returns under the same edits.
#[test]
fn straggler_machine_demotes_and_matches_exact() {
    let spec = spec_for("resnet50", "horovod", 16, 8);
    let g = build_global(&spec, &AnalyticCost::new(&spec));
    let mut rp = TieredReplayer::new(&g, &spec);
    let mut reference = Replayer::new(&g);
    for i in g.dfg.ids() {
        if let DeviceKey::Gpu(w) = g.dfg.node(i).device {
            if w >= 8 {
                let d = rp.duration(i) * 1.5;
                rp.set_duration(i, d);
                reference.set_duration(i, d);
            }
        }
    }
    let tiered = rp.replay(&g).clone();
    let rep = rp.report().clone();
    assert_eq!(rep.mode_used, "exact", "straggler must demote");
    assert!(
        rep.demoted.iter().any(|r| r.contains("shift-equivalent")),
        "reason missing: {:?}",
        rep.demoted
    );
    assert!(rep.n_symmetric < spec.cluster.n_machines());
    let exact = reference.replay(&g).clone();
    assert_bitwise_eq(&g, &exact, &tiered, "straggler");
}

/// A single asymmetric what-if edit (one op on machine 1 doubled) is
/// caught by the duration-sensitive signatures — and editing it back
/// restores tiered mode.
#[test]
fn single_node_whatif_edit_demotes_then_recovers() {
    let spec = spec_for("vgg16", "ring", 16, 8);
    let g = build_global(&spec, &AnalyticCost::new(&spec));
    let mut rp = TieredReplayer::new(&g, &spec);
    assert!(rp.replay(&g).iteration_time.is_finite());
    assert_eq!(rp.report().mode_used, "tiered", "{:?}", rp.report().demoted);

    let victim = g
        .dfg
        .ids()
        .find(|&i| matches!(g.dfg.node(i).device, DeviceKey::Gpu(12)) && g.dfg.node(i).duration > 0.0)
        .expect("machine-1 GPU op");
    let orig = rp.duration(victim);
    let mut reference = Replayer::new(&g);
    rp.set_duration(victim, orig * 2.0);
    reference.set_duration(victim, orig * 2.0);
    let tiered = rp.replay(&g).clone();
    assert_eq!(rp.report().mode_used, "exact", "what-if edit must demote");
    let exact = reference.replay(&g).clone();
    assert_bitwise_eq(&g, &exact, &tiered, "whatif");

    // undo the edit: the symmetry verification re-runs and re-enables
    // derivation, matching the pristine exact replay again
    rp.set_duration(victim, orig);
    let restored = rp.replay(&g).clone();
    assert_eq!(rp.report().mode_used, "tiered", "{:?}", rp.report().demoted);
    assert_bitwise_eq(&g, &replay_once(&g), &restored, "restored");
}

/// Diagnosis evidence demotes even a perfectly symmetric plan (the
/// evidence says the *real* fleet deviates — derivation would hide it),
/// and clearing the evidence restores tiered mode.
#[test]
fn evidence_demotes_symmetric_plan() {
    let spec = spec_for("resnet50", "horovod", 16, 8);
    let g = build_global(&spec, &AnalyticCost::new(&spec));
    let exact = replay_once(&g);
    let mut rp = TieredReplayer::new(&g, &spec);
    rp.demote_machines([1u16]);
    let demoted = rp.replay(&g).clone();
    assert_eq!(rp.report().mode_used, "exact");
    assert!(
        rp.report().demoted.iter().any(|r| r.contains("evidence")),
        "{:?}",
        rp.report().demoted
    );
    assert_bitwise_eq(&g, &exact, &demoted, "evidence");
    rp.clear_demotions();
    let back = rp.replay(&g).clone();
    assert_eq!(rp.report().mode_used, "tiered", "{:?}", rp.report().demoted);
    assert_bitwise_eq(&g, &exact, &back, "evidence cleared");
}

/// TraceFacts → broken machines: the thresholds of the bottleneck ranker
/// applied to stretch/drift/comm/lost-worker evidence, with lost workers
/// mapped onto machines.
#[test]
fn trace_evidence_names_broken_machines() {
    let facts = dpro::diagnosis::TraceFacts {
        machine_stretch: vec![(0, 1.0), (1, 1.3)],
        machine_drift_us: vec![(0, 12.0), (2, 900.0)],
        machine_comm_stretch: vec![(0, 1.0), (4, 3.5)],
        lost_workers: vec![(25, 0)],
        ..Default::default()
    };
    assert_eq!(facts.broken_machines(8), vec![1, 2, 3, 4]);
    let clean = dpro::diagnosis::TraceFacts::default();
    assert!(clean.broken_machines(8).is_empty());
}

/// One machine: nothing to derive — honest fallback with a reason.
#[test]
fn single_machine_is_trivially_exact() {
    let spec = spec_for("resnet50", "horovod", 8, 8);
    let g = build_global(&spec, &AnalyticCost::new(&spec));
    let mut rp = TieredReplayer::new(&g, &spec);
    let tiered = rp.replay(&g).clone();
    assert_eq!(rp.report().mode_used, "exact");
    assert!(
        rp.report().demoted.iter().any(|r| r.contains("single machine")),
        "{:?}",
        rp.report().demoted
    );
    assert_bitwise_eq(&g, &replay_once(&g), &tiered, "single machine");
}

/// An uneven layout (12 workers on 8-GPU machines → 8 + 4) can never be
/// shift-symmetric: demote + exact equality.
#[test]
fn uneven_machine_layout_demotes() {
    let spec = spec_for("resnet50", "ring", 12, 8);
    let g = build_global(&spec, &AnalyticCost::new(&spec));
    let mut rp = TieredReplayer::new(&g, &spec);
    let tiered = rp.replay(&g).clone();
    assert_eq!(rp.report().mode_used, "exact", "uneven layout must demote");
    assert_bitwise_eq(&g, &replay_once(&g), &tiered, "uneven");
}

/// The CLI/profiler path: a tiered estimate from a measured trace equals
/// the exact estimate bit-for-bit (measured per-worker noise breaks the
/// symmetry, so this exercises the evidence + verification fallback
/// end-to-end through `estimate_with_mode`).
#[test]
fn profiler_tiered_estimate_equals_exact() {
    let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
    let tb = dpro::testbed::run(
        &spec,
        &dpro::testbed::TestbedOpts { iterations: 3, ..Default::default() },
    );
    let exact = dpro::profiler::estimate(&spec, &tb.trace, true);
    let tiered = dpro::profiler::estimate_with_mode(&spec, &tb.trace, true, ReplayMode::Tiered);
    assert_eq!(
        exact.iteration_us().to_bits(),
        tiered.iteration_us().to_bits(),
        "{} vs {}",
        exact.iteration_us(),
        tiered.iteration_us()
    );
    let rep = tiered.tier.expect("tiered path must report");
    assert!(
        rep.mode_used == "tiered" || !rep.demoted.is_empty(),
        "demotion without a reason: {rep:?}"
    );
}

/// Report JSON carries the schema the CLI promises.
#[test]
fn tier_report_json_schema() {
    let spec = spec_for("resnet50", "horovod", 16, 8);
    let g = build_global(&spec, &AnalyticCost::new(&spec));
    let mut rp = TieredReplayer::new(&g, &spec);
    rp.replay(&g);
    let j = rp.report().to_json();
    for key in ["mode_used", "n_machines", "n_symmetric", "simulated_nodes", "derived_nodes", "demoted"] {
        assert!(j.get(key).is_some(), "missing key {key}");
    }
}
