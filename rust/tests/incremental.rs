//! Equivalence guarantee of the incremental replay subsystem: a graph
//! edited in place by [`MutableGraph`] and replayed incrementally must
//! produce exactly the schedule a from-scratch `build_global` + full
//! replay of the mutated spec produces — bit-for-bit on `iteration_time`,
//! within 1e-6 on every node's start/end (in practice: exactly equal).
//!
//! Swept across models × **all registered comm schemes** × random decision
//! sequences, mirroring the search's own edit mix (op fusion, tensor
//! fusion, partition). The sweep is the proof obligation every new
//! `CommPlanner` must meet: chain splices go through the same lowering as
//! fresh builds, so the equivalence is scheme-independent by construction
//! — this test keeps it that way.

use std::collections::HashMap;

use dpro::config::{JobSpec, Transport, ALL_SCHEMES};
use dpro::graph::MutableGraph;
use dpro::replay::incremental::IncrementalReplayer;
use dpro::util::rng::Pcg;

fn full_replay(spec: &JobSpec) -> (MutableGraph, IncrementalReplayer) {
    let mut mg = MutableGraph::new(spec.clone());
    let mut eng = IncrementalReplayer::new();
    let log = mg.commit();
    eng.replay_incremental(&mg, &log);
    (mg, eng)
}

/// Live-node schedule keyed by canonical rank — the node identity shared
/// between an incrementally-edited graph and a fresh build of its spec.
fn schedule_by_canon(mg: &MutableGraph, eng: &IncrementalReplayer) -> HashMap<u64, (f64, f64)> {
    let r = eng.result();
    let mut m = HashMap::new();
    for i in mg.dfg().ids() {
        let iu = i as usize;
        if mg.alive()[iu] {
            let prev = m.insert(mg.canon_ranks()[iu], (r.start[iu], r.end[iu]));
            assert!(prev.is_none(), "duplicate canonical rank");
        }
    }
    m
}

/// One random in-place edit; returns whether anything was applied.
fn random_decision(rng: &mut Pcg, mg: &mut MutableGraph) -> bool {
    match rng.below(3) {
        0 => {
            let n = mg.spec().fusion.groups.len();
            let (a, b) = (rng.below(n), rng.below(n));
            a != b && mg.fuse_comp_groups(a, b).is_ok()
        }
        1 => {
            let n = mg.n_groups();
            if n < 2 {
                return false;
            }
            let (a, b) = (rng.below(n), rng.below(n));
            a != b && mg.fuse_tensor_groups(a, b).is_ok()
        }
        _ => {
            let n = mg.n_groups();
            let g = rng.below(n);
            let k = 1 + rng.below(8);
            let before = mg.spec().plan.groups[g].partitions;
            mg.set_partitions(g, k).is_ok() && before != k.max(1)
        }
    }
}

#[test]
fn incremental_replay_matches_from_scratch_across_models_and_schemes() {
    let mut rng = Pcg::seeded(4242);
    // the case list is DERIVED from ALL_SCHEMES so a newly registered
    // planner is swept the moment it exists; the ring scheme's flat worker
    // ring lowers to much larger graphs, so its from-scratch ground truth
    // gets fewer (still multi-edit) steps on smaller models
    let models_for = |scheme: &str| -> Vec<(&'static str, i32)> {
        match scheme {
            "ring" => vec![("vgg16", 3), ("resnet50", 2)],
            _ => vec![("resnet50", 6), ("vgg16", 6), ("bert_base", 6)],
        }
    };
    let cases: Vec<(&str, &str, i32)> = ALL_SCHEMES
        .iter()
        .flat_map(|&scheme| {
            models_for(scheme).into_iter().map(move |(m, s)| (m, scheme, s))
        })
        .collect();
    for (model, scheme, n_steps) in cases {
        let spec = JobSpec::standard(model, scheme, Transport::Rdma);
        let (mut mg, mut eng) = full_replay(&spec);
        for step in 0..n_steps {
            // a burst of random decisions, like one search round
            let want = 1 + rng.below(3);
            let mut applied = 0;
            for _ in 0..24 {
                if random_decision(&mut rng, &mut mg) {
                    applied += 1;
                    if applied >= want {
                        break;
                    }
                }
            }
            assert_eq!(mg.validate(), Ok(()), "{model}/{scheme} step {step}");

            let log = mg.commit();
            let inc = eng.replay_incremental(&mg, &log).iteration_time;

            // ground truth: rebuild the world from the mutated spec
            let (mg2, eng2) = full_replay(mg.spec());
            let fresh = eng2.result().iteration_time;
            assert_eq!(
                inc, fresh,
                "{model}/{scheme} step {step}: iteration_time diverged"
            );

            let a = schedule_by_canon(&mg, &eng);
            let b = schedule_by_canon(&mg2, &eng2);
            assert_eq!(
                a.len(),
                b.len(),
                "{model}/{scheme} step {step}: live node counts differ"
            );
            for (c, &(s1, e1)) in &a {
                let &(s2, e2) = b
                    .get(c)
                    .unwrap_or_else(|| panic!("{model}/{scheme}: rank {c:#x} missing"));
                assert!(
                    (s1 - s2).abs() <= 1e-6 && (e1 - e2).abs() <= 1e-6,
                    "{model}/{scheme} step {step}: node times diverged \
                     ({s1},{e1}) vs ({s2},{e2})"
                );
            }
        }
    }
}

#[test]
fn static_order_engine_tracks_event_driven_replayer() {
    // The incremental engine serializes each device in canonical static
    // order; the validated event-driven `Replayer` uses FIFO ready
    // queues. Both are work-conserving schedules of the same graph: they
    // may diverge where contention reorders readiness, but a large gap
    // would mean the static order mis-models the execution graph. Pin the
    // divergence and the work-conservation lower bound.
    use dpro::graph::{build_global, AnalyticCost, DeviceKey};
    for (model, scheme) in [("resnet50", "horovod"), ("vgg16", "byteps")] {
        let spec = JobSpec::standard(model, scheme, Transport::Rdma);
        let (mg, eng) = full_replay(&spec);
        let t_static = eng.result().iteration_time;
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        let t_fifo = dpro::replay::replay_once(&g).iteration_time;
        let rel = (t_static - t_fifo).abs() / t_fifo;
        assert!(
            rel < 0.10,
            "{model}/{scheme}: static {t_static} vs event-driven {t_fifo} ({:.1}% apart)",
            rel * 100.0
        );
        // work conservation: never beat the busiest device
        let mut busy: HashMap<DeviceKey, f64> = HashMap::new();
        for i in mg.dfg().ids() {
            let n = mg.dfg().node(i);
            if mg.alive()[i as usize] && n.device != DeviceKey::Null {
                *busy.entry(n.device).or_default() += n.duration;
            }
        }
        let lower = busy.values().cloned().fold(0.0, f64::max);
        assert!(t_static >= lower - 1e-6, "{model}/{scheme}: {t_static} < busy bound {lower}");
    }
}

#[test]
fn incremental_replay_is_deterministic() {
    // two independent incremental sessions applying the same decisions
    // agree bit-for-bit
    let spec = JobSpec::standard("resnet50", "byteps", Transport::Tcp);
    let run = || {
        let (mut mg, mut eng) = full_replay(&spec);
        mg.fuse_tensor_groups(1, 4).unwrap();
        mg.set_partitions(0, 6).unwrap();
        mg.fuse_comp_groups(10, 11).unwrap();
        let log = mg.commit();
        eng.replay_incremental(&mg, &log).iteration_time
    };
    assert_eq!(run(), run());
}

#[test]
fn tombstones_never_grow_unboundedly_within_a_search() {
    // a realistic search applies tens of decisions; the arena must stay
    // within a small constant of the live size
    let spec = JobSpec::standard("vgg16", "byteps", Transport::Rdma);
    let (mut mg, mut eng) = full_replay(&spec);
    let n0 = mg.dfg().len();
    for i in 0..12 {
        let _ = mg.set_partitions(0, (i % 4) + 1);
        let _ = mg.fuse_tensor_groups(0, 1);
        let log = mg.commit();
        eng.replay_incremental(&mg, &log);
    }
    assert!(
        mg.dfg().len() < n0 * 4,
        "arena grew from {} to {}",
        n0,
        mg.dfg().len()
    );
    assert_eq!(mg.validate(), Ok(()));
}
