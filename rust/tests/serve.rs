//! Service-layer tests: the snapshot-isolation property (N concurrent
//! readers stay bit-for-bit equal to a quiesced run while a writer
//! applies and rolls back strategies), what-if coalescing, the LRU
//! byte-budget cache, the HTTP endpoint surface end-to-end (analytic,
//! uploaded, and `--trace-dir` registered jobs), and the `dpro serve`
//! exit-code contract.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use dpro::cli;
use dpro::config::{JobSpec, Transport};
use dpro::diagnosis::parse_whatif;
use dpro::optimizer::registry::{GraphPass, Registry};
use dpro::optimizer::strategy::RegistryStrategy;
use dpro::optimizer::{SearchOpts, Strategy};
use dpro::serve::http::Client;
use dpro::serve::{start, ServeError, ServeOpts, Session, SessionCache};
use dpro::util::json::{parse, Json};
use dpro::util::Args;

fn gpt_session(id: &str, window_ms: u64) -> Session {
    let spec = JobSpec::standard("gpt_mini", "horovod", Transport::Rdma);
    Session::build(id, spec, None, 5, window_ms)
}

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/two_worker")
}

/// A whole-job rewrite that is strictly worse: double every op's FLOPs
/// and every gradient's bytes. The search must evaluate it, reject it,
/// and roll it back — the adversarial writer of the isolation property.
struct Pessimizer;

impl GraphPass for Pessimizer {
    fn name(&self) -> &str {
        "pessimizer"
    }

    fn apply(&self, spec: &JobSpec) -> Option<JobSpec> {
        let mut s = spec.clone();
        for op in &mut s.model.ops {
            op.flops *= 2.0;
        }
        for t in &mut s.model.tensors {
            t.bytes *= 2.0;
        }
        Some(s)
    }
}

fn pessimist_strategies() -> Vec<Box<dyn Strategy>> {
    let mut reg = Registry::empty();
    reg.register(Box::new(Pessimizer));
    vec![Box::new(RegistryStrategy::new(reg))]
}

/// The tentpole property: while a writer repeatedly applies and rolls
/// back a strictly-pessimizing strategy, every concurrent reader result —
/// replay snapshot, diagnose snapshot, what-if payload — is bit-for-bit
/// identical to a quiesced single-threaded session, the search never
/// rebuilds (`builds_during_search == 0`), and no snapshot is published.
#[test]
fn readers_stay_bit_for_bit_quiesced_under_a_rejected_writer() {
    let reference = gpt_session("jprop", 0);
    let qs = parse_whatif("nic-bw=2,perfect-overlap").unwrap();
    let ref_snap = reference.snapshot();
    let (ref_whatif, _) = reference.whatif(&qs);
    let ref_whatif = ref_whatif.unwrap();

    let sess = Arc::new(gpt_session("jprop", 0));
    let stop = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for _ in 0..4 {
        let sess = Arc::clone(&sess);
        let stop = Arc::clone(&stop);
        let (ref_replay, ref_diag, ref_w, qs) = (
            ref_snap.replay.clone(),
            ref_snap.diagnose.clone(),
            ref_whatif.clone(),
            qs.clone(),
        );
        readers.push(std::thread::spawn(move || {
            let mut checks = 0usize;
            while checks < 8 || !stop.load(Ordering::Relaxed) {
                let snap = sess.snapshot();
                assert_eq!(snap.version, 0, "a rejected search must never publish");
                assert_eq!(snap.replay, ref_replay, "reader saw a perturbed replay");
                assert_eq!(snap.diagnose, ref_diag, "reader saw a perturbed diagnosis");
                let (w, _) = sess.whatif(&qs);
                assert_eq!(w.unwrap(), ref_w, "reader saw a perturbed what-if");
                checks += 1;
                if checks >= 64 {
                    break;
                }
            }
            checks
        }));
    }

    let opts = SearchOpts {
        use_coarsened_view: false,
        max_rounds: 1,
        budget_wall_s: 30.0,
        ..SearchOpts::default()
    };
    for _ in 0..3 {
        let out = parse(&sess.optimize_with(&opts, pessimist_strategies())).unwrap();
        assert_eq!(out.get("committed").and_then(Json::as_bool), Some(false));
        assert!(out.get("accepted").and_then(Json::as_arr).unwrap().is_empty());
        assert_eq!(out.f64("builds_during_search"), 0.0, "search rebuilt the graph");
        assert_eq!(out.f64("snapshot"), 0.0);
        // rollback restored the exact baseline estimate
        assert_eq!(out.f64("est_iteration_us"), out.f64("baseline_iteration_us"));
    }
    stop.store(true, Ordering::Relaxed);
    for r in readers {
        assert!(r.join().unwrap() >= 8);
    }
    // quiesced again: still snapshot 0, still the reference bytes
    let end = sess.snapshot();
    assert_eq!(end.version, 0);
    assert_eq!(end.replay, ref_snap.replay);
    assert_eq!(end.diagnose, ref_snap.diagnose);
}

/// A writer that *does* commit swaps the published snapshot atomically:
/// every reader observation is internally consistent (payload version tag
/// matches the snapshot version) and versions only ever map to one byte
/// sequence — old XOR new, never a torn mix.
#[test]
fn committing_writer_swaps_snapshots_atomically() {
    let sess = Arc::new(gpt_session("jcommit", 0));
    let v0 = sess.snapshot();
    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..3)
        .map(|_| {
            let sess = Arc::clone(&sess);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut seen: std::collections::BTreeMap<u64, (String, String)> =
                    std::collections::BTreeMap::new();
                loop {
                    let snap = sess.snapshot();
                    let r = parse(&snap.replay).unwrap();
                    assert_eq!(r.f64("snapshot"), snap.version as f64, "torn replay payload");
                    let d = parse(&snap.diagnose).unwrap();
                    assert_eq!(d.f64("snapshot"), snap.version as f64, "torn diagnose payload");
                    let cur = (snap.replay.clone(), snap.diagnose.clone());
                    if let Some(prev) = seen.insert(snap.version, cur.clone()) {
                        assert_eq!(prev, cur, "one version, two payloads");
                    }
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                }
                seen
            })
        })
        .collect();

    let opts = SearchOpts {
        use_coarsened_view: false,
        max_rounds: 4,
        budget_wall_s: 60.0,
        ..SearchOpts::default()
    };
    let out = parse(&sess.optimize(&opts)).unwrap();
    stop.store(true, Ordering::Relaxed);
    let maps: Vec<_> = readers.into_iter().map(|h| h.join().unwrap()).collect();

    let committed = out.get("committed").and_then(Json::as_bool).unwrap();
    let end = sess.snapshot();
    if committed {
        assert_eq!(end.version, 1, "one commit, one version bump");
        assert!(
            end.iteration_us <= v0.iteration_us,
            "a committed search must not slow the job"
        );
    } else {
        assert_eq!(end.version, 0);
    }
    for seen in maps {
        for (v, (r, _)) in &seen {
            assert!(*v <= end.version, "reader saw a version never published");
            if *v == 0 {
                assert_eq!(r, &v0.replay);
            }
            if *v == end.version {
                assert_eq!(r, &end.replay);
            }
        }
    }
}

/// Identical what-if batteries inside the window coalesce to fewer
/// evaluations, and every caller gets the byte-identical payload.
#[test]
fn identical_whatif_batteries_coalesce() {
    let sess = Arc::new(gpt_session("jbatch", 40));
    let qs = parse_whatif("nic-bw=2").unwrap();
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let sess = Arc::clone(&sess);
            let qs = qs.clone();
            std::thread::spawn(move || sess.whatif(&qs))
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let first = results[0].0.clone().unwrap();
    for (payload, _) in &results {
        assert_eq!(payload.as_deref(), Ok(first.as_str()));
    }
    let flagged = results.iter().filter(|(_, c)| *c).count() as u64;
    let (batches, coalesced) = sess.batch_stats();
    assert_eq!(coalesced, flagged);
    assert_eq!(batches + coalesced, 8, "every call was a leader or a waiter");
    assert!(coalesced >= 1, "a 40 ms window should coalesce something");
    assert_eq!(sess.whatif_served(), 8);
}

/// The byte budget evicts least-recently-used sessions — but never the
/// entry being inserted, and a failed build leaves the key retryable.
#[test]
fn byte_budget_evicts_lru_sessions() {
    let cache = SessionCache::new(1); // smaller than any session
    let (_a, hit) = cache.get_or_build("a", || Ok(gpt_session("a", 0))).unwrap();
    assert!(!hit);
    // the freshly inserted session survives its own over-budget insert
    assert!(cache.lookup("a").is_some());
    let (_b, hit) = cache.get_or_build("b", || Ok(gpt_session("b", 0))).unwrap();
    assert!(!hit);
    assert!(cache.lookup("b").is_some(), "fresh insert must survive");
    assert!(cache.lookup("a").is_none(), "LRU session must be evicted");
    let stats = cache.stats();
    assert!(stats.evictions >= 1);
    assert_eq!(stats.sessions, 1);
    assert!(stats.hit_rate() > 0.0);

    let err = cache
        .get_or_build("c", || Err(ServeError::UnusableTrace("bad dump".into())))
        .unwrap_err();
    assert_eq!(err.http_status(), 422);
    let (_c, hit) = cache.get_or_build("c", || Ok(gpt_session("c", 0))).unwrap();
    assert!(!hit, "failed build must clear the placeholder, not poison the key");
}

/// The full endpoint surface against an analytic job, including the
/// HTTP ↔ exit-code status mapping (400 argument class, 404/405).
#[test]
fn http_end_to_end_analytic_job() {
    let opts = ServeOpts { addr: "127.0.0.1:0".into(), threads: 4, batch_window_ms: 0, ..ServeOpts::default() };
    let handle = start(&opts).unwrap();
    let mut c = Client::new(&handle.addr().to_string());

    let (s, b) = c.call("GET", "/healthz", None).unwrap();
    assert_eq!(s, 200);
    assert_eq!(parse(&b).unwrap().str("status"), "ok");

    let job_body =
        r#"{"job":{"model":"gpt_mini","scheme":"horovod","transport":"rdma","workers":4}}"#;
    let (s, b) = c.call("POST", "/jobs", Some(job_body)).unwrap();
    assert_eq!(s, 200, "{b}");
    let reg = parse(&b).unwrap();
    let id = reg.str("job").to_string();
    assert!(id.starts_with('j'));
    assert_eq!(reg.get("cached").and_then(Json::as_bool), Some(false));
    assert!(reg.f64("iteration_us") > 0.0);

    // same descriptor again: the graph build is skipped
    let (s, b) = c.call("POST", "/jobs", Some(job_body)).unwrap();
    assert_eq!(s, 200);
    assert_eq!(parse(&b).unwrap().get("cached").and_then(Json::as_bool), Some(true));

    let (s, b) = c.call("GET", &format!("/jobs/{id}/replay"), None).unwrap();
    assert_eq!(s, 200, "{b}");
    let r = parse(&b).unwrap();
    for key in ["job", "snapshot", "model", "scheme", "transport", "workers", "ops",
        "alive_ops", "iteration_us", "fw_us", "bw_us", "est_peak_mem_bytes", "report"]
    {
        assert!(r.get(key).is_some(), "replay payload missing {key}");
    }
    assert_eq!(r.f64("workers"), 4.0);

    let (s, b) = c.call("GET", &format!("/jobs/{id}/diagnose"), None).unwrap();
    assert_eq!(s, 200, "{b}");
    let d = parse(&b).unwrap();
    for key in ["job", "snapshot", "blame", "bottlenecks", "whatif", "builds_during_queries"] {
        assert!(d.get(key).is_some(), "diagnose payload missing {key}");
    }

    let (s, b) = c
        .call("POST", &format!("/jobs/{id}/whatif"), Some(r#"{"query":"nic-bw=2"}"#))
        .unwrap();
    assert_eq!(s, 200, "{b}");
    let w = parse(&b).unwrap();
    assert_eq!(w.str("job"), id);
    assert_eq!(w.get("answers").and_then(Json::as_arr).unwrap().len(), 1);

    let (s, b) = c
        .call(
            "POST",
            &format!("/jobs/{id}/whatif"),
            Some(r#"{"queries":["nic-bw=2","perfect-overlap"]}"#),
        )
        .unwrap();
    assert_eq!(s, 200, "{b}");
    assert_eq!(parse(&b).unwrap().get("answers").and_then(Json::as_arr).unwrap().len(), 2);

    let (s, b) = c
        .call("POST", &format!("/jobs/{id}/optimize"), Some(r#"{"max_rounds":1,"budget_s":5}"#))
        .unwrap();
    assert_eq!(s, 200, "{b}");
    let o = parse(&b).unwrap();
    assert!(o.get("committed").and_then(Json::as_bool).is_some());
    assert!(o.get("snapshot").is_some());
    assert!(o.get("accepted").is_some());

    // 400: the exit-2 argument class, same messages as the CLI
    for (path, body) in [
        ("/jobs".to_string(), "not json"),
        ("/jobs".to_string(), "{}"),
        ("/jobs".to_string(), r#"{"job":{"model":"nope"}}"#),
        ("/jobs".to_string(), r#"{"job":{"workers":0}}"#),
        (format!("/jobs/{id}/whatif"), r#"{"query":"bogus-form"}"#),
        (format!("/jobs/{id}/whatif"), r#"{}"#),
        (format!("/jobs/{id}/optimize"), r#"{"max_rounds":0}"#),
        (format!("/jobs/{id}/optimize"), r#"{"unknown_field":1}"#),
        (format!("/jobs/{id}/optimize"), r#"{"strategies":"warp-drive"}"#),
    ] {
        let (s, b) = c.call("POST", &path, Some(body)).unwrap();
        assert_eq!(s, 400, "POST {path} {body} -> {b}");
        assert!(parse(&b).unwrap().get("error").is_some());
    }
    let (s, b) = c.call("POST", "/jobs", Some(r#"{"job":{"model":"nope"}}"#)).unwrap();
    assert_eq!(s, 400);
    assert!(parse(&b).unwrap().str("error").contains("model"), "{b}");

    // 404 / 405
    let (s, _) = c.call("GET", "/jobs/jdeadbeef/replay", None).unwrap();
    assert_eq!(s, 404);
    let (s, _) = c.call("GET", "/nope", None).unwrap();
    assert_eq!(s, 404);
    let (s, _) = c.call("DELETE", "/healthz", None).unwrap();
    assert_eq!(s, 405);
    let (s, _) = c.call("GET", "/jobs", None).unwrap();
    assert_eq!(s, 405);

    let (s, b) = c.call("GET", "/statsz", None).unwrap();
    assert_eq!(s, 200);
    let stats = parse(&b).unwrap();
    let cache = stats.get("cache").unwrap();
    assert!(cache.f64("hits") >= 1.0, "{b}");
    assert!(cache.f64("hit_rate") > 0.0);
    assert_eq!(cache.f64("sessions"), 1.0);
    assert_eq!(stats.f64("threads"), 4.0);
    assert!(stats.f64("requests") >= 10.0);
    assert!(stats.get("queue_depth").is_some());
    assert!(stats.get("batch").is_some());
    assert_eq!(stats.get("sessions").and_then(Json::as_arr).unwrap().len(), 1);

    handle.stop();
}

/// Upload ingestion (`{"files": ...}`) and `{"trace_dir": ...}`
/// registration of the on-disk fixture, with content-hash identity
/// (re-upload of the same dump is a cache hit) and the 422 class.
#[test]
fn http_upload_and_trace_dir_registration() {
    let opts = ServeOpts { addr: "127.0.0.1:0".into(), threads: 2, ..ServeOpts::default() };
    let handle = start(&opts).unwrap();
    let mut c = Client::new(&handle.addr().to_string());

    let mut files = Json::obj();
    for entry in std::fs::read_dir(fixture_dir()).unwrap() {
        let e = entry.unwrap();
        let name = e.file_name().into_string().unwrap();
        files.set(&name, Json::Str(std::fs::read_to_string(e.path()).unwrap()));
    }
    let mut body = Json::obj();
    body.set("files", files);
    let body = body.to_string();

    let (s, b) = c.call("POST", "/jobs", Some(&body)).unwrap();
    assert_eq!(s, 200, "{b}");
    let reg = parse(&b).unwrap();
    let id = reg.str("job").to_string();
    assert_eq!(reg.get("cached").and_then(Json::as_bool), Some(false));

    // byte-identical upload: content-hash identity makes it a hit
    let (s, b) = c.call("POST", "/jobs", Some(&body)).unwrap();
    assert_eq!(s, 200);
    assert_eq!(parse(&b).unwrap().get("cached").and_then(Json::as_bool), Some(true));

    let (s, b) = c.call("GET", &format!("/jobs/{id}/replay"), None).unwrap();
    assert_eq!(s, 200, "{b}");
    let r = parse(&b).unwrap();
    assert_eq!(r.f64("workers"), 2.0, "fixture is a two-worker dump");
    assert!(r.get("report").is_some());

    // the same dump registered by directory (separate identity: path-based)
    let mut reg_body = Json::obj();
    reg_body.set("trace_dir", Json::Str(fixture_dir().display().to_string()));
    let reg_body = reg_body.to_string();
    let (s, b) = c.call("POST", "/jobs", Some(&reg_body)).unwrap();
    assert_eq!(s, 200, "{b}");
    let id2 = parse(&b).unwrap().str("job").to_string();
    assert!(id2.starts_with('d'));
    let (s, _) = c.call("POST", "/jobs", Some(&reg_body)).unwrap();
    assert_eq!(s, 200);
    let (s, b) = c.call("GET", &format!("/jobs/{id2}/diagnose"), None).unwrap();
    assert_eq!(s, 200, "{b}");

    // 422: the exit-3 unusable-trace class
    for bad in [
        r#"{"files":{"a.json":"this is not json"}}"#.to_string(),
        r#"{"files":{"readme.txt":"no trace files here"}}"#.to_string(),
        r#"{"trace_dir":"/nonexistent-dpro-dump"}"#.to_string(),
    ] {
        let (s, b) = c.call("POST", "/jobs", Some(&bad)).unwrap();
        assert_eq!(s, 422, "{bad} -> {b}");
    }

    handle.stop();
}

/// `--trace-dir` preload registers the session before the socket opens;
/// an unusable preload fails startup with the exit-3 class.
#[test]
fn preload_registers_fixture_before_bind() {
    let opts = ServeOpts {
        addr: "127.0.0.1:0".into(),
        threads: 2,
        preload: vec![fixture_dir().display().to_string()],
        ..ServeOpts::default()
    };
    let handle = start(&opts).unwrap();
    let mut c = Client::new(&handle.addr().to_string());
    let (s, b) = c.call("GET", "/statsz", None).unwrap();
    assert_eq!(s, 200);
    assert_eq!(parse(&b).unwrap().get("cache").unwrap().f64("sessions"), 1.0);
    // registering the preloaded dir over HTTP is a pure cache hit
    let mut reg_body = Json::obj();
    reg_body.set("trace_dir", Json::Str(fixture_dir().display().to_string()));
    let (s, b) = c.call("POST", "/jobs", Some(&reg_body.to_string())).unwrap();
    assert_eq!(s, 200, "{b}");
    assert_eq!(parse(&b).unwrap().get("cached").and_then(Json::as_bool), Some(true));
    handle.stop();

    let err = start(&ServeOpts {
        addr: "127.0.0.1:0".into(),
        preload: vec!["/nonexistent-dpro-dump".into()],
        ..ServeOpts::default()
    })
    .unwrap_err();
    assert_eq!(err.http_status(), 422, "unusable preload is the exit-3 class");
}

fn serve_args(pairs: &[(&str, &str)]) -> Args {
    let mut a = Args::default();
    a.positional.push("serve".into());
    for (k, v) in pairs {
        a.options.insert(k.to_string(), v.to_string());
    }
    a
}

/// The CLI exit-code contract extended to `serve`: malformed flags exit
/// 2, an unusable preload exits 3 — both decided before a socket opens.
#[test]
fn serve_cli_exit_codes_follow_the_contract() {
    for bad in [
        &[("addr", "not-an-addr")][..],
        &[("cache-bytes", "0")],
        &[("cache-bytes", "12Q")],
        &[("threads", "0")],
        &[("threads", "many")],
        &[("top", "-3")],
        &[("batch-window-ms", "soon")],
        &[("slow-query-us", "0")],
        &[("slow-query-us", "fast")],
    ] {
        assert_eq!(cli::run(serve_args(bad)), 2, "{bad:?} should exit 2");
    }
    assert_eq!(
        cli::run(serve_args(&[("addr", "127.0.0.1:0"), ("trace-dir", "/nonexistent-dpro")])),
        3,
        "unusable preload should exit 3"
    );
}
