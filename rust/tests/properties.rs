//! Property-based tests over the core invariants, driven by the crate's
//! own seeded PCG (the image has no proptest): randomized pass sequences,
//! cluster shapes and trace perturbations must never break the
//! replayer/optimizer contracts.

use dpro::config::{ClusterSpec, CommPlan, CommScheme, FusionPlan, JobSpec, NetworkSpec, Transport};
use dpro::graph::{build_global, AnalyticCost};
use dpro::optimizer::passes;
use dpro::replay::replay_once;
use dpro::util::rng::Pcg;

fn random_job(rng: &mut Pcg) -> JobSpec {
    let models = ["resnet50", "vgg16", "inception_v3", "bert_base", "gpt_mini"];
    let model = models[rng.below(models.len())];
    let scheme = if rng.f64() < 0.5 { "horovod" } else { "byteps" };
    let transport = if rng.f64() < 0.5 { Transport::Rdma } else { Transport::Tcp };
    let mut spec = JobSpec::standard(model, scheme, transport);
    let workers = [4usize, 8, 16, 24][rng.below(4)];
    spec.cluster = ClusterSpec::new(
        workers,
        [2usize, 4, 8][rng.below(3)],
        if transport == Transport::Tcp { NetworkSpec::tcp_100g() } else { NetworkSpec::rdma_100g() },
    );
    if let CommScheme::Ps(ps) = &mut spec.scheme {
        ps.n_servers = spec.cluster.n_machines().max(1);
    }
    spec
}

/// Apply a random sequence of passes, checking validity is preserved.
fn random_passes(rng: &mut Pcg, spec: &mut JobSpec, n: usize) -> usize {
    let mut applied = 0;
    for _ in 0..n {
        match rng.below(3) {
            0 => {
                let a = rng.below(spec.fusion.groups.len());
                let b = rng.below(spec.fusion.groups.len());
                if a != b && passes::fuse_comp_groups(spec, a, b).is_ok() {
                    applied += 1;
                }
            }
            1 => {
                let a = rng.below(spec.plan.groups.len());
                let b = rng.below(spec.plan.groups.len());
                if a != b && passes::fuse_tensor_groups(spec, a, b).is_ok() {
                    applied += 1;
                }
            }
            _ => {
                let g = rng.below(spec.plan.groups.len());
                let k = 1 + rng.below(16);
                if passes::set_partitions(spec, g, k).is_ok() {
                    applied += 1;
                }
            }
        }
    }
    applied
}

#[test]
fn random_pass_sequences_preserve_invariants() {
    let mut rng = Pcg::seeded(2024);
    for case in 0..12 {
        let mut spec = random_job(&mut rng);
        let applied = random_passes(&mut rng, &mut spec, 60);
        assert!(applied > 0, "case {case}: nothing applied");
        // plans stay valid partitions of tensors / ops
        assert_eq!(spec.plan.validate(&spec.model), Ok(()), "case {case}");
        assert_eq!(spec.fusion.validate(&spec.model), Ok(()), "case {case}");
        // the rewritten job still builds an acyclic global DFG that replays
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        assert!(g.dfg.is_dag(), "case {case}: cycle after passes");
        let r = replay_once(&g);
        assert!(r.iteration_time.is_finite() && r.iteration_time > 0.0, "case {case}");
    }
}

#[test]
fn replay_is_deterministic_across_clones() {
    let mut rng = Pcg::seeded(7);
    for _ in 0..6 {
        let spec = random_job(&mut rng);
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        let a = replay_once(&g).iteration_time;
        let b = replay_once(&g).iteration_time;
        assert_eq!(a, b);
    }
}

#[test]
fn fusion_monotonically_reduces_group_counts() {
    let mut rng = Pcg::seeded(99);
    let mut spec = random_job(&mut rng);
    let mut last_plan = spec.plan.groups.len();
    let mut last_fusion = spec.fusion.groups.len();
    for _ in 0..40 {
        random_passes(&mut rng, &mut spec, 1);
        assert!(spec.plan.groups.len() <= last_plan);
        assert!(spec.fusion.groups.len() <= last_fusion);
        last_plan = spec.plan.groups.len();
        last_fusion = spec.fusion.groups.len();
    }
}

#[test]
fn replay_never_beats_critical_work_lower_bound() {
    // iteration time >= max over devices of its total busy time
    let mut rng = Pcg::seeded(31);
    for _ in 0..6 {
        let spec = random_job(&mut rng);
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        let r = replay_once(&g);
        let mut busy: std::collections::HashMap<dpro::graph::DeviceKey, f64> = Default::default();
        for i in g.dfg.ids() {
            let n = g.dfg.node(i);
            if n.device != dpro::graph::DeviceKey::Null {
                *busy.entry(n.device).or_default() += n.duration;
            }
        }
        let lower = busy.values().cloned().fold(0.0, f64::max);
        assert!(
            r.iteration_time >= lower - 1e-6,
            "iteration {} < device lower bound {}",
            r.iteration_time,
            lower
        );
    }
}

#[test]
fn testbed_trace_always_joinable() {
    // every non-virtual node of the skeleton appears in the trace, for
    // random jobs — the contract that makes replay-from-trace possible
    let mut rng = Pcg::seeded(55);
    for _ in 0..4 {
        let spec = random_job(&mut rng);
        let tb = dpro::testbed::run(
            &spec,
            &dpro::testbed::TestbedOpts { iterations: 2, ..Default::default() },
        );
        let db = tb.trace.profile_db();
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        for i in g.dfg.ids() {
            let n = g.dfg.node(i);
            if !n.kind.is_virtual() {
                assert!(db.get_id(n.name).is_some(), "missing {}", n.name);
            }
        }
    }
}

#[test]
fn json_fuzz_roundtrip() {
    // random JSON trees survive write→parse→write
    use dpro::util::json::{parse, Json};
    let mut rng = Pcg::seeded(123);
    fn gen(rng: &mut Pcg, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.f64() * 2e6).round() / 2.0 - 5e5),
            3 => Json::Str(format!("s{}\n\"{}", rng.below(1000), rng.below(10))),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for k in 0..rng.below(5) {
                    o.set(&format!("k{k}"), gen(rng, depth - 1));
                }
                o
            }
        }
    }
    for _ in 0..200 {
        let v = gen(&mut rng, 3);
        let text = v.to_string();
        let back = parse(&text).unwrap_or_else(|e| panic!("parse {text}: {e}"));
        assert_eq!(back, v, "text: {text}");
        assert_eq!(parse(&back.to_string_pretty()).unwrap(), v);
    }
}

#[test]
fn alignment_identity_on_driftless_traces() {
    // a single-machine job has one clock: θ must stay ~0 for every proc
    let mut spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
    spec.cluster = ClusterSpec::new(8, 8, NetworkSpec::rdma_100g());
    spec.plan = CommPlan::per_tensor(&spec.model);
    spec.fusion = FusionPlan::singletons(&spec.model);
    let tb = dpro::testbed::run(
        &spec,
        &dpro::testbed::TestbedOpts { iterations: 4, ..Default::default() },
    );
    let a = dpro::alignment::align(&tb.trace, 1.0, 1.0);
    for (&proc, &theta) in &a.theta {
        assert!(theta.abs() < 500.0, "proc {proc} drifted to {theta}");
    }
}
