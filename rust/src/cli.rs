//! Command-line interface (paper §6 "APIs and Commands").
//!
//! ```text
//! dpro profile  --model resnet50 --scheme horovod --transport rdma --dump-dir trace/
//! dpro replay   --trace-dir trace/ --json
//! dpro align    --trace-dir trace/ --json
//! dpro diagnose --trace-dir trace/ --json
//! dpro optimize --model resnet50 --scheme ps-tree --transport rdma \
//!               --strategies op-fuse,tensor-fuse,mixed-precision,recompute
//! dpro train    --config mini --workers 4 --steps 50
//! dpro report   --model bert_base --scheme ring
//! ```
//!
//! `diagnose` answers *why* an iteration is slow before `optimize` makes
//! it faster: critical-path blame (compute / communication /
//! blocked-on-sync, summing exactly to the iteration time), ranked
//! bottlenecks, and replayed what-if counterfactuals (`--whatif`, see
//! [`crate::diagnosis::whatif::WHATIF_FORMS`]) — with or without a
//! measured trace. The `--json` schema is documented in
//! `docs/DIAGNOSIS.md`.
//!
//! `profile --dump-dir` writes a per-process Chrome-trace directory (see
//! `docs/TRACE_FORMAT.md`) that `replay`/`align` ingest back with
//! `--trace-dir` — including externally produced or hand-edited dumps
//! (the what-if workflow). A dump's `metadata.json` carries the job
//! descriptor, so `dpro replay --trace-dir` needs no `--model/--scheme`
//! flags; explicit flags still win when given. The legacy single-file
//! `-o trace.json` / `--trace trace.json` forms remain supported.
//!
//! `--scheme` accepts any registered communication scheme (`horovod`,
//! `ring`, `byteps`, `ps-tree` + aliases) — see the `parse` constructor on
//! [`crate::config::CommScheme`]; adding a scheme automatically extends
//! every command. `--strategies` accepts any registered optimization
//! strategy ([`crate::optimizer::strategy::parse_strategies`]) — adding a
//! strategy likewise extends `optimize`.
//!
//! `replay --replay-mode tiered` selects the symmetry-class engine
//! ([`crate::replay::tiered`]): one representative machine is simulated
//! per verified shift-equivalence class and the rest derived by timeline
//! translation — bit-identical to exact replay, and automatically
//! demoted to it (with the reasons reported) when stragglers, faults,
//! per-machine profile noise or asymmetric what-if edits break the
//! symmetry. The default `exact` simulates every node.
//!
//! `replay` and `diagnose` accept `--inject <fault-spec>[,<fault-spec>]`
//! (see [`crate::fault::FAULT_FORMS`] and `docs/FAULTS.md`): each fault is
//! applied to the loaded trace *before* estimation, so "what does a crash
//! at iteration 3 look like?" is answered by replay, not by crashing a
//! fleet. A trace showing lost workers surfaces `worker_lost` diagnostics
//! and a `continue-on:<survivors>` what-if (the elastic replan).
//!
//! Invalid argument values (an unparsable `--workers`, an unknown
//! `--transport`/`--model`/`--scheme`/strategy name, a malformed
//! `--inject` spec) are rejected with a message listing the valid values
//! and exit code 2 — never silently replaced by a default. `replay`,
//! `optimize` and `report` accept `--json` for machine-readable output on
//! stdout.
//!
//! Exit-code contract for the trace-consuming commands
//! (`replay`/`align`/`diagnose`, asserted by the CI fixture smoke): **0**
//! for a clean run *and* for a degraded-but-usable trace (the warnings
//! live in the `report` payload), **2** for argument errors, **3** for an
//! unusable trace (unreadable directory, zero usable events) — distinct
//! so scripts can tell "you typoed" from "the dump is bad".
//!
//! `serve` starts `dprod`, the diagnosis-as-a-service daemon
//! ([`crate::serve`], `docs/SERVE.md`): built graphs stay resident in an
//! LRU session cache and are queried over HTTP. The exit-code contract
//! extends to it twice over — at startup (a malformed `--addr`,
//! `--cache-bytes`, `--threads`, `--batch-window-ms` or `--top` exits 2;
//! an unusable `--trace-dir` preload exits 3) and per request, where HTTP
//! statuses mirror the same classes: **400** = the exit-2 argument class,
//! **422** = the exit-3 unusable-trace class, plus 404/405/413/500 for the
//! transport-level cases.
//!
//! Every command accepts the global `--self-trace DIR` flag
//! ([`crate::obs`], `docs/OBSERVABILITY.md`): the run's own span tree is
//! dumped into `DIR` in the same gTrace format the pipeline ingests, so
//! the profiler profiles itself with its own tooling. A bare
//! `--self-trace` or one naming an existing non-directory exits 2, as
//! does a non-positive `serve --slow-query-us`.

use crate::alignment::Alignment;
use crate::baselines;
use crate::config::{ClusterSpec, CommScheme, JobSpec, Transport, ALL_SCHEMES};
use crate::optimizer::{optimize, strategy, SearchOpts};
use crate::profiler;
use crate::replay::tiered::ReplayMode;
use crate::testbed::{run as tb_run, TestbedOpts};
use crate::trace::io::{dump_dir_with_job, load_dir, JobMeta};
use crate::trace::validate::TraceReport;
use crate::trace::GTrace;
use crate::util::json::Json;
use crate::util::{fmt_bytes, fmt_us, Args};
use std::path::Path;

/// Dispatch a parsed command line; returns the process exit code.
///
/// The global `--self-trace DIR` flag works on every command: it turns
/// on span collection ([`crate::obs`]) for the run, and when the
/// command returns, dumps the collected span tree into `DIR` as a
/// standard gTrace directory (`docs/OBSERVABILITY.md`) that `load_dir`
/// re-ingests cleanly and Perfetto opens. A bare `--self-trace`, or one
/// naming an existing non-directory, is an argument error (exit 2).
/// `serve` blocks until killed, so its telemetry is served live on
/// `GET /metricsz` instead of dumped.
pub fn run(args: Args) -> i32 {
    let self_trace: Option<String> = if args.flag("self-trace") {
        eprintln!("--self-trace requires a directory argument (e.g. --self-trace obs_out)");
        return 2;
    } else {
        match args.get("self-trace") {
            Some(d) => {
                let p = Path::new(d);
                if p.exists() && !p.is_dir() {
                    eprintln!("invalid --self-trace {d:?}: exists and is not a directory");
                    return 2;
                }
                crate::obs::set_enabled(true);
                Some(d.to_string())
            }
            None => None,
        }
    };
    let code = {
        let cmd = args.positional.first().cloned().unwrap_or_else(|| "help".into());
        // root of the span tree; dropped before the dump below so every
        // span is closed when the trace is written
        let _root = crate::obs::span(&format!("cli.{cmd}"), crate::obs::SpanKind::Work);
        match args.positional.first().map(String::as_str) {
            Some("profile") => cmd_profile(&args),
            Some("replay") => cmd_replay(&args),
            Some("align") => cmd_align(&args),
            Some("diagnose") => cmd_diagnose(&args),
            Some("optimize") => cmd_optimize(&args),
            Some("train") => cmd_train(&args),
            Some("report") => cmd_report(&args),
            Some("serve") => cmd_serve(&args),
            Some("campaign") => cmd_campaign(&args),
            Some(other) => {
                eprintln!("unknown command {other:?}");
                usage();
                2
            }
            None => {
                usage();
                0
            }
        }
    };
    if let Some(dir) = self_trace {
        match crate::obs::export::dump_self_trace(Path::new(&dir)) {
            Ok(s) => eprintln!(
                "self-trace: {} spans in {} files under {dir} (gTrace; Perfetto-loadable)",
                s.events, s.files
            ),
            // telemetry failure must not mask the command's own outcome
            Err(e) => eprintln!("self-trace: dump to {dir} failed: {e}"),
        }
    }
    code
}

fn usage() {
    println!(
        "dpro {} — profiling & optimization for distributed DNN training\n\n\
         commands:\n  \
         profile  --model M --scheme S --transport T [-o trace.json] [--dump-dir DIR] [--iters 10]\n  \
         replay   --trace-dir DIR | --trace trace.json [--model M --scheme S --transport T]\n           \
         [--no-align] [--inject FAULTS] [--replay-mode exact|tiered] [--json]\n  \
         align    --trace-dir DIR | --trace trace.json [--json]\n  \
         diagnose [--model M --scheme S --transport T] [--trace-dir DIR]\n           \
         [--whatif auto|perfect-overlap,nic-bw=2,nvlink-bw=2,equalize=W,zero-group=G,shrink-op=OP:F,continue-on:K]\n           \
         [--inject FAULTS] [--top 5] [--json]\n  \
         optimize --model M --scheme S --transport T [--budget-s 60] [--strawman]\n           \
         [--strategies {}] [--memory-budget-gb G] [--json]\n  \
         train    [--config mini] [--workers 4] [--steps 50] [--artifacts artifacts]\n           \
         [--dump-dir DIR]\n  \
         report   --model M [--scheme S] [--transport T] [--json]\n  \
         serve    [--addr 127.0.0.1:7077] [--cache-bytes 1G] [--threads 8]\n           \
         [--batch-window-ms 2] [--top 5] [--trace-dir DIR[,DIR]] [--slow-query-us N]\n  \
         campaign run|resume|status --spec FILE [--out campaign_out] [--jobs 4]\n           \
         [--endpoint HOST:PORT] [--budget-s S] [--retry-failed] [--quiet] [--json]\n\n\
         global: --self-trace DIR dumps the run's own span tree as a gTrace\n\
         directory (docs/OBSERVABILITY.md); serve exposes GET /metricsz instead.\n\
         models: resnet50 vgg16 inception_v3 bert_base gpt_mini\n\
         schemes: {}   transports: rdma tcp\n\
         faults (--inject, docs/FAULTS.md): {}\n\n\
         trace directories follow docs/TRACE_FORMAT.md; `replay --trace-dir`\n\
         reads the job from the dump's metadata.json (explicit flags win).\n\
         exit codes for replay/align/diagnose/campaign: 0 ok (even with\n\
         warnings), 2 bad arguments, 3 unusable trace/journal/endpoint.\n\
         campaign sweeps are declarative spec files (docs/CAMPAIGN.md) run\n\
         on a resumable crash-safe journal; `campaign resume` never\n\
         re-executes a done cell",
        crate::version(),
        strategy::STRATEGY_NAMES.join(","),
        ALL_SCHEMES.join(" "),
        crate::fault::FAULT_FORMS,
    );
}

/// Build the job spec from CLI args, rejecting invalid values instead of
/// silently substituting defaults.
fn job_from_args(args: &Args) -> Result<JobSpec, String> {
    job_from_args_with(args, None)
}

/// Like [`job_from_args`], but with a trace dump's job descriptor as the
/// default layer: explicit CLI flags win, then `metadata.json`, then the
/// built-in defaults. Validation is identical either way — a bad value
/// from metadata is rejected with the same message as a bad flag.
pub(crate) fn job_from_args_with(args: &Args, meta: Option<&JobMeta>) -> Result<JobSpec, String> {
    let model = args
        .get("model")
        .map(str::to_string)
        .or_else(|| meta.map(|m| m.model.clone()))
        .unwrap_or_else(|| "resnet50".into());
    let scheme = args
        .get("scheme")
        .map(str::to_string)
        .or_else(|| meta.map(|m| m.scheme.clone()))
        .unwrap_or_else(|| "horovod".into());
    let transport_name = args
        .get("transport")
        .map(str::to_string)
        .or_else(|| meta.map(|m| m.transport.clone()))
        .unwrap_or_else(|| "rdma".into());
    let transport = match transport_name.as_str() {
        "tcp" => Transport::Tcp,
        "rdma" => Transport::Rdma,
        other => {
            return Err(format!(
                "invalid --transport {other:?}; valid values: rdma, tcp"
            ))
        }
    };
    let workers = match args.get("workers") {
        // metadata gets the same validation as the flag (hand-edited
        // dumps are untrusted; a 0 would divide comm chunks by zero)
        None => match meta.map(|m| m.n_workers) {
            Some(0) => {
                return Err(
                    "invalid n_workers 0 in trace metadata; expected a positive integer".into(),
                )
            }
            w => w,
        },
        Some(w) => match w.parse::<usize>() {
            Ok(n) if n >= 1 => Some(n),
            _ => {
                return Err(format!(
                    "invalid --workers {w:?}; expected a positive integer"
                ))
            }
        },
    };
    if crate::models::by_name(&model, 1).is_none() {
        return Err(format!(
            "unknown --model {model:?}; valid values: resnet50, vgg16, \
             inception_v3, bert_base, gpt_mini"
        ));
    }
    if CommScheme::parse(&scheme, &ClusterSpec::default_16(transport)).is_none() {
        return Err(format!(
            "unknown --scheme {scheme:?}; valid values: {}",
            ALL_SCHEMES.join(", ")
        ));
    }
    let mut spec = JobSpec::standard(&model, &scheme, transport);
    if let Some(m) = meta {
        // cluster layout from the dump (same machine ⇒ same clock matters
        // for alignment); no CLI flag exists for gpus_per_machine
        spec.cluster.gpus_per_machine = m.gpus_per_machine.max(1);
    }
    if let Some(w) = workers {
        spec.cluster.n_workers = w;
    }
    // server-family schemes size their fleet from the machine count:
    // re-parse against the *resolved* cluster shape, not the default one
    spec.scheme = CommScheme::parse(&scheme, &spec.cluster)
        .expect("scheme validated above");
    // plan family: explicit flags win, then the dump's recorded plan
    // (skeleton op names depend on it — a mismatch would silently break
    // the trace join), then the deployed default
    let deployed = if args.flag("per-tensor") {
        false
    } else if args.flag("deployed") {
        true
    } else {
        meta.map_or(true, |m| m.plan != crate::trace::io::PLAN_PER_TENSOR)
    };
    if deployed {
        spec = baselines::deployed_default(&spec);
    }
    Ok(spec)
}

/// Unwrap a job spec or print the error and exit with code 2.
macro_rules! job_or_exit {
    ($args:expr) => {
        match job_from_args($args) {
            Ok(spec) => spec,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    };
}

fn cmd_profile(args: &Args) -> i32 {
    let spec = job_or_exit!(args);
    let iters = args.usize("iters", 10);
    println!(
        "profiling {} × {} workers ({}, {}) for {iters} iterations on the testbed...",
        spec.model.name,
        spec.cluster.n_workers,
        spec.scheme.name(),
        spec.cluster.network.transport.name()
    );
    let r = tb_run(&spec, &TestbedOpts { iterations: iters, ..Default::default() });
    println!("ground-truth iteration: {}", fmt_us(r.avg_iter()));
    println!("peak memory (worker 0): {}", fmt_bytes(r.peak_memory));
    if let Some(dir) = args.get("dump-dir") {
        match dump_dir_with_job(&r.trace, Path::new(dir), Some(&JobMeta::of(&spec))) {
            Ok(s) => println!(
                "dumped {} events to {} per-process files in {dir}/ \
                 (Perfetto-loadable; replay with `dpro replay --trace-dir {dir}`)",
                s.events, s.files
            ),
            Err(e) => {
                eprintln!("error dumping to {dir}: {e}");
                return 1;
            }
        }
        // the single-file form is implied only when explicitly requested
        // alongside a directory dump
        if args.get("o").is_none() {
            return 0;
        }
    }
    let out = args.get_or("o", "trace.json");
    match r.trace.save(&out) {
        Ok(()) => {
            println!("wrote {} events to {out}", r.trace.events.len());
            0
        }
        Err(e) => {
            eprintln!("error writing {out}: {e}");
            1
        }
    }
}

/// Load the trace named by `--trace-dir` (directory form) or `--trace`
/// (legacy single file). Returns the trace, the ingestion report (empty
/// for the single-file form) and the dump's job descriptor, if any.
fn trace_from_args(args: &Args) -> Result<(GTrace, TraceReport, Option<JobMeta>), String> {
    if let Some(dir) = args.get("trace-dir") {
        let loaded = load_dir(Path::new(dir))?;
        if loaded.trace.events.is_empty() {
            return Err(format!("no usable events in {dir}: {}", loaded.report));
        }
        Ok((loaded.trace, loaded.report, loaded.job))
    } else {
        let path = args.get_or("trace", "trace.json");
        let trace = GTrace::load(&path).map_err(|e| format!("error loading {path}: {e}"))?;
        // the strict single-file loader collects no diagnostics, but the
        // report's load counters must still tell the truth
        let mut report = TraceReport::default();
        report.files = 1;
        report.events_loaded = trace.events.len();
        Ok((trace, report, None))
    }
}

/// Parse `--inject` into a fault list (empty when the flag is absent).
/// Validation happens before any trace is read: a malformed spec is an
/// argument error (exit 2), not a trace error.
fn faults_from_args(args: &Args) -> Result<Vec<crate::fault::Fault>, String> {
    match args.get("inject") {
        None => Ok(Vec::new()),
        Some(list) => crate::fault::parse_faults(list),
    }
}

/// Machine-readable replay outcome: schema-stable keys asserted by the
/// golden-fixture CI step (`ops`, `profiled_ops`, `aligned`,
/// `iteration_us`, `fw_us`, `bw_us`, `est_peak_mem_bytes`, `report`).
pub fn replay_json(
    spec: &JobSpec,
    est: &profiler::Estimate,
    aligned: bool,
    report: &TraceReport,
) -> Json {
    let mut j = Json::obj();
    j.set("ops", Json::Num(est.graph.dfg.len() as f64));
    j.set("profiled_ops", Json::Num(est.profiled_ops as f64));
    j.set("aligned", Json::Bool(aligned));
    j.set("iteration_us", Json::Num(est.iteration_us()));
    j.set("fw_us", Json::Num(est.fw_us()));
    j.set("bw_us", Json::Num(est.bw_us()));
    j.set("est_peak_mem_bytes", Json::Num(est.peak_memory(spec)));
    j.set("report", report.to_json());
    // engine provenance: which engine ran (tiered demotes itself to
    // exact when symmetry is broken — the tier object says why)
    match &est.tier {
        Some(t) => {
            j.set("replay_mode", Json::Str(t.mode_used.clone()));
            j.set("tier", t.to_json());
        }
        None => {
            j.set("replay_mode", Json::Str("exact".into()));
        }
    }
    j
}

fn cmd_replay(args: &Args) -> i32 {
    // cheap argument validation first: a bad --inject spec or
    // --replay-mode must exit 2 before a multi-GB trace ingestion starts
    let faults = match faults_from_args(args) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mode = match args.get("replay-mode") {
        None => ReplayMode::Exact,
        Some(m) => match ReplayMode::parse(m) {
            Some(m) => m,
            None => {
                eprintln!("invalid --replay-mode {m:?}; valid values: exact, tiered");
                return 2;
            }
        },
    };
    let (mut trace, mut report, job) = match trace_from_args(args) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return 3;
        }
    };
    let spec = match job_from_args_with(args, job.as_ref()) {
        Ok(spec) => spec,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if !faults.is_empty() {
        let edited = crate::fault::apply_all(&faults, &mut trace, &mut report);
        if !args.flag("json") {
            println!("injected {} fault(s), {edited} events affected", faults.len());
        }
    }
    let aligned = !args.flag("no-align");
    let est = profiler::estimate_with_mode(&spec, &trace, aligned, mode);
    if args.flag("json") {
        println!("{}", replay_json(&spec, &est, aligned, &report).to_string());
        return 0;
    }
    if !report.is_clean() {
        println!("trace: {report}");
    }
    println!(
        "replayed {} ops, {} with profiled durations (alignment: {})",
        est.graph.dfg.len(),
        est.profiled_ops,
        if aligned { "on" } else { "off" }
    );
    if let Some(t) = &est.tier {
        if t.mode_used == "tiered" {
            println!(
                "  tiered replay: {} machines, all symmetric; {} nodes simulated, \
                 {} derived by translation",
                t.n_machines, t.simulated_nodes, t.derived_nodes
            );
        } else {
            println!(
                "  tiered replay demoted to exact: {}",
                t.demoted.join("; ")
            );
        }
    }
    println!("estimated iteration: {}", fmt_us(est.iteration_us()));
    println!("  forward:  {}", fmt_us(est.fw_us()));
    println!("  backward: {}", fmt_us(est.bw_us()));
    println!("  est. peak memory: {}", fmt_bytes(est.peak_memory(&spec)));
    0
}

/// Machine-readable alignment outcome: schema-stable keys asserted by the
/// golden-fixture CI step (`procs` as `{proc, theta_us}` rows sorted by
/// process id, `objective`, `iterations`, `report`).
pub fn align_json(a: &Alignment, report: &TraceReport) -> Json {
    let mut procs: Vec<_> = a.theta.iter().collect();
    procs.sort_by_key(|(p, _)| **p);
    let rows: Vec<Json> = procs
        .into_iter()
        .map(|(proc, theta)| {
            let mut o = Json::obj();
            o.set("proc", Json::Num(*proc as f64));
            o.set("theta_us", Json::Num(*theta));
            o
        })
        .collect();
    let mut j = Json::obj();
    j.set("procs", Json::Arr(rows));
    j.set("objective", Json::Num(a.objective));
    j.set("iterations", Json::Num(a.iterations as f64));
    j.set("report", report.to_json());
    j
}

fn cmd_align(args: &Args) -> i32 {
    let (trace, report, _job) = match trace_from_args(args) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return 3;
        }
    };
    let a = crate::alignment::align(&trace, 1.0, 1.0);
    if args.flag("json") {
        println!("{}", align_json(&a, &report).to_string());
        return 0;
    }
    if !report.is_clean() {
        println!("trace: {report}");
    }
    println!("solved {} clock offsets in {} iterations (objective {:.3})",
             a.theta.len(), a.iterations, a.objective);
    let mut procs: Vec<_> = a.theta.iter().collect();
    procs.sort_by_key(|(p, _)| **p);
    for (proc, theta) in procs {
        println!("  proc {proc:4}: θ = {theta:+.1} us");
    }
    0
}

fn cmd_diagnose(args: &Args) -> i32 {
    use crate::diagnosis::{parse_whatif, Diagnoser};

    // validate cheap arguments before any heavy work (a multi-GB trace
    // ingestion must not precede a typo's exit 2): same contract as
    // replay/optimize — the message lists the valid values
    let whatif_arg = args.get_or("whatif", "auto");
    let explicit = match whatif_arg.as_str() {
        "auto" | "all" => None,
        list => match parse_whatif(list) {
            Ok(qs) => Some(qs),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };
    let top = match args.get("top") {
        None => 5usize,
        Some(t) => match t.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                eprintln!("invalid --top {t:?}; expected a positive integer");
                return 2;
            }
        },
    };

    let faults = match faults_from_args(args) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };

    // a trace is optional for diagnose: without one, the analytic cost
    // model supplies durations (the pre-deployment what-if workflow)
    let traced = args.get("trace-dir").is_some() || args.get("trace").is_some();
    if !faults.is_empty() && !traced {
        eprintln!(
            "--inject needs a measured trace to degrade; add --trace-dir DIR \
             (or --trace FILE)"
        );
        return 2;
    }
    let (trace, mut report, job) = if traced {
        match trace_from_args(args) {
            Ok((t, r, j)) => (Some(t), r, j),
            Err(e) => {
                eprintln!("{e}");
                return 3;
            }
        }
    } else {
        (None, TraceReport::default(), None)
    };
    let trace = trace.map(|mut t| {
        crate::fault::apply_all(&faults, &mut t, &mut report);
        t
    });
    let spec = match job_from_args_with(args, job.as_ref()) {
        Ok(spec) => spec,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };

    let mut d = match &trace {
        Some(t) => Diagnoser::from_trace(spec, t, report),
        None => Diagnoser::new(spec),
    };
    let queries = explicit.unwrap_or_else(|| d.auto_queries());
    let rep = d.report(&queries, top);
    if args.flag("json") {
        println!("{}", rep.to_json().to_string());
        return 0;
    }

    println!(
        "=== diagnosis: {} / {} / {} / {} workers ===",
        rep.model, rep.scheme, rep.transport, rep.workers
    );
    if !rep.trace.is_clean() {
        println!("trace: {}", rep.trace);
    }
    println!("replayed iteration: {}", fmt_us(rep.iteration_us));
    let p = &rep.blame.path;
    let pct = |x: f64| if rep.iteration_us > 0.0 { x / rep.iteration_us * 100.0 } else { 0.0 };
    println!(
        "critical path ({} ops): compute {} ({:.1}%), communication {} ({:.1}%), blocked {}",
        p.ops,
        fmt_us(p.comp_us),
        pct(p.comp_us),
        fmt_us(p.comm_us),
        pct(p.comm_us),
        fmt_us(p.blocked_us),
    );
    println!("bottlenecks (by estimated headroom):");
    for (i, b) in rep.bottlenecks.iter().enumerate() {
        println!(
            "  {}. [{}] {} — blame {}, headroom {}\n     {}",
            i + 1,
            b.kind.name(),
            b.subject,
            fmt_us(b.blame_us),
            fmt_us(b.headroom_us),
            b.detail
        );
    }
    println!("what-if (replayed counterfactuals):");
    for a in &rep.whatif {
        println!(
            "  {:<28} -> {}  ({:.2}x, {} ops edited)",
            a.query,
            fmt_us(a.iteration_us),
            a.speedup,
            a.edited_ops
        );
    }
    println!("(global-DFG builds during queries: {})", rep.builds_during_queries);
    0
}

fn cmd_optimize(args: &Args) -> i32 {
    let spec = job_or_exit!(args);
    let mut opts = if args.flag("strawman") { SearchOpts::strawman() } else { SearchOpts::default() };
    opts.budget_wall_s = args.f64("budget-s", 60.0);
    if let Some(b) = args.get("memory-budget-gb") {
        match b.parse::<f64>() {
            Ok(g) if g > 0.0 => opts.memory_budget_bytes = Some(g * 1e9),
            _ => {
                eprintln!("invalid --memory-budget-gb {b:?}; expected a positive number");
                return 2;
            }
        }
    }
    if let Some(list) = args.get("strategies") {
        // validate up front so a typo exits 2 with the valid names listed
        if let Err(e) = strategy::parse_strategies(list) {
            eprintln!("{e}");
            return 2;
        }
        opts.strategies = Some(list.to_string());
    }
    let json = args.flag("json");
    if !json {
        println!(
            "optimizing {} × {} workers ({}, {})...",
            spec.model.name,
            spec.cluster.n_workers,
            spec.scheme.name(),
            spec.cluster.network.transport.name()
        );
    }
    let out = optimize(&spec, &opts);
    // validate on the testbed
    let base = tb_run(&spec, &TestbedOpts { iterations: 5, ..Default::default() });
    let opt = tb_run(&out.spec, &TestbedOpts { iterations: 5, ..Default::default() });
    if json {
        let mut j = out.to_json();
        j.set("model", Json::Str(spec.model.name.clone()));
        j.set("scheme", Json::Str(spec.scheme.name().to_string()));
        j.set("workers", Json::Num(spec.cluster.n_workers as f64));
        j.set("testbed_base_us", Json::Num(base.avg_iter()));
        j.set("testbed_opt_us", Json::Num(opt.avg_iter()));
        j.set("testbed_speedup", Json::Num(base.avg_iter() / opt.avg_iter()));
        println!("{}", j.to_string());
        return 0;
    }
    println!("baseline iteration (replayed): {}", fmt_us(out.baseline_iteration_us));
    println!("optimized iteration (replayed): {}", fmt_us(out.est_iteration_us));
    println!(
        "speed-up: {:.2}x  ({} passes applied, {}/{} candidates accepted, {} replays, {:.1}s search)",
        out.speedup(),
        out.actions_applied,
        out.accepted.len(),
        out.candidates_tried,
        out.replays,
        out.wall_s
    );
    println!("memory pass: {}", out.mem_opt.name());
    println!(
        "testbed validation: {} -> {} ({:.2}x real speed-up)",
        fmt_us(base.avg_iter()),
        fmt_us(opt.avg_iter()),
        base.avg_iter() / opt.avg_iter()
    );
    0
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> i32 {
    eprintln!(
        "`dpro train` drives the live PJRT path, which is feature-gated: \
         rebuild with `--features pjrt` in an environment that provides \
         the xla/anyhow/log crates (see rust/README.md)."
    );
    2
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> i32 {
    let artifacts: std::path::PathBuf = args.get_or("artifacts", "artifacts").into();
    // live runs always dump their gTrace (profile-then-replay toolchain);
    // --dump-dir overrides the default <artifacts>/trace location
    let dump = args
        .get("dump-dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| artifacts.join("trace"));
    let cfg = crate::coordinator::TrainCfg {
        artifacts_dir: artifacts,
        config: args.get_or("config", "mini"),
        n_workers: args.usize("workers", 4),
        steps: args.usize("steps", 50),
        seed: args.u64("seed", 17),
        log_every: args.usize("log-every", 10),
        trace_dump_dir: Some(dump),
        ..Default::default()
    };
    match crate::coordinator::train(&cfg) {
        Ok(report) => {
            println!(
                "final loss {:.4} after {} steps; throughput {:.0} tokens/s ({} params)",
                report.final_loss(),
                report.losses.len(),
                report.tokens_per_s(),
                report.n_params
            );
            0
        }
        Err(e) => {
            eprintln!("training failed: {e:#}");
            1
        }
    }
}

fn cmd_report(args: &Args) -> i32 {
    let spec = job_or_exit!(args);
    let tb = tb_run(&spec, &TestbedOpts { iterations: 10, ..Default::default() });
    let est = profiler::estimate(&spec, &tb.trace, true);
    let dd = baselines::daydream::estimate(
        &spec,
        Some(&profiler::corrected_profile(&tb.trace, &crate::alignment::Alignment::identity())),
    );
    let truth = tb.avg_iter();
    if args.flag("json") {
        let mut j = Json::obj();
        j.set("model", Json::Str(spec.model.name.clone()));
        j.set("scheme", Json::Str(spec.scheme.name().to_string()));
        j.set("transport", Json::Str(spec.cluster.network.transport.name().to_string()));
        j.set("workers", Json::Num(spec.cluster.n_workers as f64));
        j.set("ground_truth_us", Json::Num(truth));
        j.set("dpro_us", Json::Num(est.iteration_us()));
        j.set(
            "dpro_err_pct",
            Json::Num(crate::util::stats::rel_err_pct(est.iteration_us(), truth)),
        );
        j.set("daydream_us", Json::Num(dd.iteration_us));
        j.set(
            "daydream_err_pct",
            Json::Num(crate::util::stats::rel_err_pct(dd.iteration_us, truth)),
        );
        println!("{}", j.to_string());
        return 0;
    }
    println!("=== {} / {} / {} / {} workers ===",
             spec.model.name, spec.scheme.name(),
             spec.cluster.network.transport.name(), spec.cluster.n_workers);
    println!("ground truth : {}", fmt_us(truth));
    println!("dPRO replay  : {}  (err {:.2}%)", fmt_us(est.iteration_us()),
             crate::util::stats::rel_err_pct(est.iteration_us(), truth));
    println!("Daydream     : {}  (err {:.2}%)", fmt_us(dd.iteration_us),
             crate::util::stats::rel_err_pct(dd.iteration_us, truth));
    0
}

/// `dpro serve`: start the `dprod` daemon and block. Argument errors exit
/// 2, an unusable `--trace-dir` preload exits 3 — the standard contract,
/// applied at startup; per-request errors map to HTTP statuses instead
/// (see the module docs and `docs/SERVE.md`).
fn cmd_serve(args: &Args) -> i32 {
    use crate::serve::{parse_bytes, ServeError, ServeOpts};
    use std::net::ToSocketAddrs;

    let mut opts = ServeOpts::default();
    if let Some(addr) = args.get("addr") {
        if addr.to_socket_addrs().map(|mut a| a.next()).ok().flatten().is_none() {
            eprintln!("invalid --addr {addr:?}: expected host:port (e.g. 127.0.0.1:7077)");
            return 2;
        }
        opts.addr = addr.to_string();
    }
    if let Some(cb) = args.get("cache-bytes") {
        match parse_bytes(cb) {
            Ok(n) => opts.cache_bytes = n,
            Err(e) => {
                eprintln!("invalid --cache-bytes {cb:?}: {e}");
                return 2;
            }
        }
    }
    // positive-integer flags: absence keeps the default, a malformed or
    // zero value is an argument error — never silently replaced
    for (key, slot) in [
        ("threads", &mut opts.threads as &mut usize),
        ("top", &mut opts.top),
    ] {
        if let Some(v) = args.get(key) {
            match v.parse::<usize>() {
                Ok(n) if n >= 1 => *slot = n,
                _ => {
                    eprintln!("invalid --{key} {v:?}: expected a positive integer");
                    return 2;
                }
            }
        }
    }
    if let Some(v) = args.get("batch-window-ms") {
        match v.parse::<u64>() {
            Ok(ms) => opts.batch_window_ms = ms,
            Err(_) => {
                eprintln!("invalid --batch-window-ms {v:?}: expected a non-negative integer");
                return 2;
            }
        }
    }
    // absence keeps the default (threshold disabled); an explicit zero
    // or junk value is an argument error, same as --threads
    if let Some(v) = args.get("slow-query-us") {
        match v.parse::<u64>() {
            Ok(us) if us >= 1 => opts.slow_query_us = us,
            _ => {
                eprintln!("invalid --slow-query-us {v:?}: expected a positive integer (µs)");
                return 2;
            }
        }
    }
    if let Some(dirs) = args.get("trace-dir") {
        opts.preload = dirs.split(',').map(str::to_string).collect();
    }

    match crate::serve::start(&opts) {
        Ok(handle) => {
            println!(
                "dprod {} listening on {} ({} threads, {} cache, {} ms batch window, {} preloaded)",
                crate::version(),
                handle.addr(),
                opts.threads,
                fmt_bytes(opts.cache_bytes as f64),
                opts.batch_window_ms,
                opts.preload.len(),
            );
            handle.wait();
            0
        }
        Err(e) => {
            eprintln!("serve: {}", e.message());
            match e {
                ServeError::UnusableTrace(_) => 3,
                _ => 2,
            }
        }
    }
}

fn cmd_campaign(args: &Args) -> i32 {
    use crate::campaign::{run as campaign, CampaignSpec, LaunchMode, RunOpts};
    use std::net::ToSocketAddrs;
    use std::path::PathBuf;

    let action = match args.positional.get(1).map(String::as_str) {
        Some(a @ ("run" | "resume" | "status")) => a,
        Some(other) => {
            eprintln!("unknown campaign action {other:?}; valid actions: run, resume, status");
            return 2;
        }
        None => {
            eprintln!(
                "usage: dpro campaign run|resume|status --spec FILE [--out DIR] [--jobs N] \
                 [--endpoint HOST:PORT] [--budget-s S] [--retry-failed] [--quiet] [--json]"
            );
            return 2;
        }
    };
    let Some(spec_path) = args.get("spec") else {
        eprintln!("campaign: --spec FILE is required (grammar: docs/CAMPAIGN.md)");
        return 2;
    };
    // the spec is an argument: unreadable or malformed is the exit-2
    // class, same as a bad --inject string
    let spec = match CampaignSpec::load(Path::new(spec_path)) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("campaign: {e}");
            return 2;
        }
    };

    let mut opts = RunOpts {
        out_dir: PathBuf::from(args.get_or("out", "campaign_out")),
        retry_failed: args.flag("retry-failed"),
        quiet: args.flag("quiet"),
        ..RunOpts::default()
    };
    if let Some(v) = args.get("jobs") {
        match v.parse::<usize>() {
            Ok(n) if n >= 1 => opts.jobs = n,
            _ => {
                eprintln!("invalid --jobs {v:?}: expected a positive integer");
                return 2;
            }
        }
    }
    if let Some(addr) = args.get("endpoint") {
        // syntax (exit 2) is checked here; reachability (exit 3) by run()
        if addr.to_socket_addrs().map(|mut a| a.next()).ok().flatten().is_none() {
            eprintln!("invalid --endpoint {addr:?}: expected host:port (e.g. 127.0.0.1:7077)");
            return 2;
        }
        opts.endpoint = Some(addr.to_string());
    }
    if let Some(v) = args.get("budget-s") {
        match v.parse::<f64>() {
            Ok(s) if s > 0.0 && s.is_finite() => opts.budget_s = Some(s),
            _ => {
                eprintln!("invalid --budget-s {v:?}: expected a positive number of seconds");
                return 2;
            }
        }
    }

    if action == "status" {
        let state = match campaign::load_state(&spec, &opts.out_dir) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("campaign: {}", e.message());
                return e.exit_code();
            }
        };
        let cells = spec.expand();
        let done = state.count("done");
        let failed = state.count("failed");
        let running = state.count("running");
        let pending = cells.len().saturating_sub(done + failed + running);
        if args.flag("json") {
            let mut j = Json::obj();
            j.set("campaign", Json::Str(state.campaign.clone()));
            j.set("spec_hash", Json::Str(state.spec_hash.clone()));
            j.set("total", Json::Num(cells.len() as f64));
            j.set("done", Json::Num(done as f64));
            j.set("failed", Json::Num(failed as f64));
            j.set("running", Json::Num(running as f64));
            j.set("pending", Json::Num(pending as f64));
            let rows: Vec<Json> = cells
                .iter()
                .map(|c| {
                    let id = c.id();
                    let status = match state.cells.get(&id) {
                        Some(crate::campaign::CellState::Done { .. }) => "done",
                        Some(crate::campaign::CellState::Failed { .. }) => "failed",
                        Some(crate::campaign::CellState::Running) => "running",
                        None => "pending",
                    };
                    let mut row = Json::obj();
                    row.set("cell", Json::Str(id));
                    row.set("status", Json::Str(status.to_string()));
                    row
                })
                .collect();
            j.set("cells", Json::Arr(rows));
            println!("{}", j.to_string_pretty());
        } else {
            println!(
                "campaign {} (spec {}): {} cells — {done} done, {failed} failed, \
                 {running} running, {pending} pending",
                state.campaign,
                state.spec_hash,
                cells.len(),
            );
        }
        return 0;
    }

    let mode = if action == "run" { LaunchMode::Fresh } else { LaunchMode::Resume };
    match campaign::run(&spec, mode, &opts) {
        Ok(out) => {
            println!(
                "campaign {}: {} cells — {} done ({} executed now, {} reused), {} failed, \
                 {} pending",
                spec.name, out.total, out.done, out.executed, out.reused, out.failed, out.pending,
            );
            if let (Some(csv), Some(json)) = (&out.csv, &out.json) {
                println!("matrix: {} + {}", csv.display(), json.display());
            }
            // failed cells: the sweep completed but not cleanly — exit 1,
            // distinct from the argument (2) and data (3) classes
            i32::from(out.failed > 0)
        }
        Err(e) => {
            eprintln!("campaign: {}", e.message());
            e.exit_code()
        }
    }
}
