//! Command-line interface (paper §6 "APIs and Commands").
//!
//! ```text
//! dpro profile  --model resnet50 --scheme horovod --transport rdma -o trace.json
//! dpro replay   --model resnet50 --scheme horovod --transport rdma --trace trace.json
//! dpro align    --trace trace.json
//! dpro optimize --model resnet50 --scheme ps-tree --transport rdma \
//!               --strategies op-fuse,tensor-fuse,mixed-precision,recompute
//! dpro train    --config mini --workers 4 --steps 50
//! dpro report   --model bert_base --scheme ring
//! ```
//!
//! `--scheme` accepts any registered communication scheme (`horovod`,
//! `ring`, `byteps`, `ps-tree` + aliases) — see the `parse` constructor on
//! [`crate::config::CommScheme`]; adding a scheme automatically extends
//! every command. `--strategies` accepts any registered optimization
//! strategy ([`crate::optimizer::strategy::parse_strategies`]) — adding a
//! strategy likewise extends `optimize`.
//!
//! Invalid argument values (an unparsable `--workers`, an unknown
//! `--transport`/`--model`/`--scheme`/strategy name) are rejected with a
//! message listing the valid values and exit code 2 — never silently
//! replaced by a default. `replay`, `optimize` and `report` accept
//! `--json` for machine-readable output on stdout.

use crate::baselines;
use crate::config::{ClusterSpec, CommScheme, JobSpec, Transport, ALL_SCHEMES};
use crate::optimizer::{optimize, strategy, SearchOpts};
use crate::profiler;
use crate::testbed::{run as tb_run, TestbedOpts};
use crate::trace::GTrace;
use crate::util::json::Json;
use crate::util::{fmt_bytes, fmt_us, Args};

pub fn run(args: Args) -> i32 {
    match args.positional.first().map(String::as_str) {
        Some("profile") => cmd_profile(&args),
        Some("replay") => cmd_replay(&args),
        Some("align") => cmd_align(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("train") => cmd_train(&args),
        Some("report") => cmd_report(&args),
        Some(other) => {
            eprintln!("unknown command {other:?}");
            usage();
            2
        }
        None => {
            usage();
            0
        }
    }
}

fn usage() {
    println!(
        "dpro {} — profiling & optimization for distributed DNN training\n\n\
         commands:\n  \
         profile  --model M --scheme S --transport T [-o trace.json] [--iters 10]\n  \
         replay   --model M --scheme S --transport T --trace trace.json [--no-align] [--json]\n  \
         align    --trace trace.json\n  \
         optimize --model M --scheme S --transport T [--budget-s 60] [--strawman]\n           \
         [--strategies {}] [--memory-budget-gb G] [--json]\n  \
         train    [--config mini] [--workers 4] [--steps 50] [--artifacts artifacts]\n  \
         report   --model M [--scheme S] [--transport T] [--json]\n\n\
         models: resnet50 vgg16 inception_v3 bert_base gpt_mini\n\
         schemes: {}   transports: rdma tcp",
        crate::version(),
        strategy::STRATEGY_NAMES.join(","),
        ALL_SCHEMES.join(" "),
    );
}

/// Build the job spec from CLI args, rejecting invalid values instead of
/// silently substituting defaults.
fn job_from_args(args: &Args) -> Result<JobSpec, String> {
    let model = args.get_or("model", "resnet50");
    let scheme = args.get_or("scheme", "horovod");
    let transport = match args.get_or("transport", "rdma").as_str() {
        "tcp" => Transport::Tcp,
        "rdma" => Transport::Rdma,
        other => {
            return Err(format!(
                "invalid --transport {other:?}; valid values: rdma, tcp"
            ))
        }
    };
    let workers = match args.get("workers") {
        None => None,
        Some(w) => match w.parse::<usize>() {
            Ok(n) if n >= 1 => Some(n),
            _ => {
                return Err(format!(
                    "invalid --workers {w:?}; expected a positive integer"
                ))
            }
        },
    };
    if crate::models::by_name(&model, 1).is_none() {
        return Err(format!(
            "unknown --model {model:?}; valid values: resnet50, vgg16, \
             inception_v3, bert_base, gpt_mini"
        ));
    }
    if CommScheme::parse(&scheme, &ClusterSpec::default_16(transport)).is_none() {
        return Err(format!(
            "unknown --scheme {scheme:?}; valid values: {}",
            ALL_SCHEMES.join(", ")
        ));
    }
    let mut spec = JobSpec::standard(&model, &scheme, transport);
    if let Some(w) = workers {
        spec.cluster.n_workers = w;
    }
    if args.flag("deployed") || !args.flag("per-tensor") {
        spec = baselines::deployed_default(&spec);
    }
    Ok(spec)
}

/// Unwrap a job spec or print the error and exit with code 2.
macro_rules! job_or_exit {
    ($args:expr) => {
        match job_from_args($args) {
            Ok(spec) => spec,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    };
}

fn cmd_profile(args: &Args) -> i32 {
    let spec = job_or_exit!(args);
    let iters = args.usize("iters", 10);
    let out = args.get_or("o", "trace.json");
    println!(
        "profiling {} × {} workers ({}, {}) for {iters} iterations on the testbed...",
        spec.model.name,
        spec.cluster.n_workers,
        spec.scheme.name(),
        spec.cluster.network.transport.name()
    );
    let r = tb_run(&spec, &TestbedOpts { iterations: iters, ..Default::default() });
    println!("ground-truth iteration: {}", fmt_us(r.avg_iter()));
    println!("peak memory (worker 0): {}", fmt_bytes(r.peak_memory));
    match r.trace.save(&out) {
        Ok(()) => {
            println!("wrote {} events to {out}", r.trace.events.len());
            0
        }
        Err(e) => {
            eprintln!("error writing {out}: {e}");
            1
        }
    }
}

fn cmd_replay(args: &Args) -> i32 {
    let spec = job_or_exit!(args);
    let path = args.get_or("trace", "trace.json");
    let trace = match GTrace::load(&path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error loading {path}: {e}");
            return 1;
        }
    };
    let aligned = !args.flag("no-align");
    let est = profiler::estimate(&spec, &trace, aligned);
    if args.flag("json") {
        let mut j = Json::obj();
        j.set("ops", Json::Num(est.graph.dfg.len() as f64));
        j.set("aligned", Json::Bool(aligned));
        j.set("iteration_us", Json::Num(est.iteration_us()));
        j.set("fw_us", Json::Num(est.fw_us()));
        j.set("bw_us", Json::Num(est.bw_us()));
        j.set("est_peak_mem_bytes", Json::Num(est.peak_memory(&spec)));
        println!("{}", j.to_string());
        return 0;
    }
    println!(
        "replayed {} ops (alignment: {})",
        est.graph.dfg.len(),
        if aligned { "on" } else { "off" }
    );
    println!("estimated iteration: {}", fmt_us(est.iteration_us()));
    println!("  forward:  {}", fmt_us(est.fw_us()));
    println!("  backward: {}", fmt_us(est.bw_us()));
    println!("  est. peak memory: {}", fmt_bytes(est.peak_memory(&spec)));
    0
}

fn cmd_align(args: &Args) -> i32 {
    let path = args.get_or("trace", "trace.json");
    let trace = match GTrace::load(&path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error loading {path}: {e}");
            return 1;
        }
    };
    let a = crate::alignment::align(&trace, 1.0, 1.0);
    println!("solved {} clock offsets in {} iterations (objective {:.3})",
             a.theta.len(), a.iterations, a.objective);
    let mut procs: Vec<_> = a.theta.iter().collect();
    procs.sort_by_key(|(p, _)| **p);
    for (proc, theta) in procs {
        println!("  proc {proc:4}: θ = {theta:+.1} us");
    }
    0
}

fn cmd_optimize(args: &Args) -> i32 {
    let spec = job_or_exit!(args);
    let mut opts = if args.flag("strawman") { SearchOpts::strawman() } else { SearchOpts::default() };
    opts.budget_wall_s = args.f64("budget-s", 60.0);
    if let Some(b) = args.get("memory-budget-gb") {
        match b.parse::<f64>() {
            Ok(g) if g > 0.0 => opts.memory_budget_bytes = Some(g * 1e9),
            _ => {
                eprintln!("invalid --memory-budget-gb {b:?}; expected a positive number");
                return 2;
            }
        }
    }
    if let Some(list) = args.get("strategies") {
        // validate up front so a typo exits 2 with the valid names listed
        if let Err(e) = strategy::parse_strategies(list) {
            eprintln!("{e}");
            return 2;
        }
        opts.strategies = Some(list.to_string());
    }
    let json = args.flag("json");
    if !json {
        println!(
            "optimizing {} × {} workers ({}, {})...",
            spec.model.name,
            spec.cluster.n_workers,
            spec.scheme.name(),
            spec.cluster.network.transport.name()
        );
    }
    let out = optimize(&spec, &opts);
    // validate on the testbed
    let base = tb_run(&spec, &TestbedOpts { iterations: 5, ..Default::default() });
    let opt = tb_run(&out.spec, &TestbedOpts { iterations: 5, ..Default::default() });
    if json {
        let mut j = out.to_json();
        j.set("model", Json::Str(spec.model.name.clone()));
        j.set("scheme", Json::Str(spec.scheme.name().to_string()));
        j.set("workers", Json::Num(spec.cluster.n_workers as f64));
        j.set("testbed_base_us", Json::Num(base.avg_iter()));
        j.set("testbed_opt_us", Json::Num(opt.avg_iter()));
        j.set("testbed_speedup", Json::Num(base.avg_iter() / opt.avg_iter()));
        println!("{}", j.to_string());
        return 0;
    }
    println!("baseline iteration (replayed): {}", fmt_us(out.baseline_iteration_us));
    println!("optimized iteration (replayed): {}", fmt_us(out.est_iteration_us));
    println!(
        "speed-up: {:.2}x  ({} passes applied, {}/{} candidates accepted, {} replays, {:.1}s search)",
        out.speedup(),
        out.actions_applied,
        out.accepted.len(),
        out.candidates_tried,
        out.replays,
        out.wall_s
    );
    println!("memory pass: {}", out.mem_opt.name());
    println!(
        "testbed validation: {} -> {} ({:.2}x real speed-up)",
        fmt_us(base.avg_iter()),
        fmt_us(opt.avg_iter()),
        base.avg_iter() / opt.avg_iter()
    );
    0
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> i32 {
    eprintln!(
        "`dpro train` drives the live PJRT path, which is feature-gated: \
         rebuild with `--features pjrt` in an environment that provides \
         the xla/anyhow/log crates (see rust/README.md)."
    );
    2
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> i32 {
    let cfg = crate::coordinator::TrainCfg {
        artifacts_dir: args.get_or("artifacts", "artifacts").into(),
        config: args.get_or("config", "mini"),
        n_workers: args.usize("workers", 4),
        steps: args.usize("steps", 50),
        seed: args.u64("seed", 17),
        log_every: args.usize("log-every", 10),
        ..Default::default()
    };
    match crate::coordinator::train(&cfg) {
        Ok(report) => {
            println!(
                "final loss {:.4} after {} steps; throughput {:.0} tokens/s ({} params)",
                report.final_loss(),
                report.losses.len(),
                report.tokens_per_s(),
                report.n_params
            );
            0
        }
        Err(e) => {
            eprintln!("training failed: {e:#}");
            1
        }
    }
}

fn cmd_report(args: &Args) -> i32 {
    let spec = job_or_exit!(args);
    let tb = tb_run(&spec, &TestbedOpts { iterations: 10, ..Default::default() });
    let est = profiler::estimate(&spec, &tb.trace, true);
    let dd = baselines::daydream::estimate(
        &spec,
        Some(&profiler::corrected_profile(&tb.trace, &crate::alignment::Alignment::identity())),
    );
    let truth = tb.avg_iter();
    if args.flag("json") {
        let mut j = Json::obj();
        j.set("model", Json::Str(spec.model.name.clone()));
        j.set("scheme", Json::Str(spec.scheme.name().to_string()));
        j.set("transport", Json::Str(spec.cluster.network.transport.name().to_string()));
        j.set("workers", Json::Num(spec.cluster.n_workers as f64));
        j.set("ground_truth_us", Json::Num(truth));
        j.set("dpro_us", Json::Num(est.iteration_us()));
        j.set(
            "dpro_err_pct",
            Json::Num(crate::util::stats::rel_err_pct(est.iteration_us(), truth)),
        );
        j.set("daydream_us", Json::Num(dd.iteration_us));
        j.set(
            "daydream_err_pct",
            Json::Num(crate::util::stats::rel_err_pct(dd.iteration_us, truth)),
        );
        println!("{}", j.to_string());
        return 0;
    }
    println!("=== {} / {} / {} / {} workers ===",
             spec.model.name, spec.scheme.name(),
             spec.cluster.network.transport.name(), spec.cluster.n_workers);
    println!("ground truth : {}", fmt_us(truth));
    println!("dPRO replay  : {}  (err {:.2}%)", fmt_us(est.iteration_us()),
             crate::util::stats::rel_err_pct(est.iteration_us(), truth));
    println!("Daydream     : {}  (err {:.2}%)", fmt_us(dd.iteration_us),
             crate::util::stats::rel_err_pct(dd.iteration_us, truth));
    0
}
