//! Command-line interface (paper §6 "APIs and Commands").
//!
//! ```text
//! dpro profile  --model resnet50 --scheme horovod --transport rdma -o trace.json
//! dpro replay   --model resnet50 --scheme horovod --transport rdma --trace trace.json
//! dpro align    --trace trace.json
//! dpro optimize --model resnet50 --scheme ps-tree --transport rdma
//! dpro train    --config mini --workers 4 --steps 50
//! dpro report   --model bert_base --scheme ring
//! ```
//!
//! `--scheme` accepts any registered communication scheme (`horovod`,
//! `ring`, `byteps`, `ps-tree` + aliases) — see the `parse` constructor on
//! [`crate::config::CommScheme`]; adding a scheme automatically extends
//! every command.

use crate::baselines;
use crate::config::{JobSpec, Transport};
use crate::optimizer::{optimize, SearchOpts};
use crate::profiler;
use crate::testbed::{run as tb_run, TestbedOpts};
use crate::trace::GTrace;
use crate::util::{fmt_bytes, fmt_us, Args};

pub fn run(args: Args) -> i32 {
    match args.positional.first().map(String::as_str) {
        Some("profile") => cmd_profile(&args),
        Some("replay") => cmd_replay(&args),
        Some("align") => cmd_align(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("train") => cmd_train(&args),
        Some("report") => cmd_report(&args),
        Some(other) => {
            eprintln!("unknown command {other:?}");
            usage();
            2
        }
        None => {
            usage();
            0
        }
    }
}

fn usage() {
    println!(
        "dpro {} — profiling & optimization for distributed DNN training\n\n\
         commands:\n  \
         profile  --model M --scheme S --transport T [-o trace.json] [--iters 10]\n  \
         replay   --model M --scheme S --transport T --trace trace.json [--no-align]\n  \
         align    --trace trace.json\n  \
         optimize --model M --scheme S --transport T [--budget-s 60] [--strawman]\n  \
         train    [--config mini] [--workers 4] [--steps 50] [--artifacts artifacts]\n  \
         report   --model M [--scheme S] [--transport T]\n\n\
         models: resnet50 vgg16 inception_v3 bert_base gpt_mini\n\
         schemes: horovod ring byteps ps-tree   transports: rdma tcp",
        crate::version()
    );
}

fn job_from_args(args: &Args) -> JobSpec {
    let model = args.get_or("model", "resnet50");
    let scheme = args.get_or("scheme", "horovod");
    let transport = match args.get_or("transport", "rdma").as_str() {
        "tcp" => Transport::Tcp,
        _ => Transport::Rdma,
    };
    let mut spec = JobSpec::standard(&model, &scheme, transport);
    if let Some(w) = args.get("workers") {
        let w: usize = w.parse().unwrap_or(16);
        spec.cluster.n_workers = w;
    }
    if args.flag("deployed") || !args.flag("per-tensor") {
        spec = baselines::deployed_default(&spec);
    }
    spec
}

fn cmd_profile(args: &Args) -> i32 {
    let spec = job_from_args(args);
    let iters = args.usize("iters", 10);
    let out = args.get_or("o", "trace.json");
    println!(
        "profiling {} × {} workers ({}, {}) for {iters} iterations on the testbed...",
        spec.model.name,
        spec.cluster.n_workers,
        spec.scheme.name(),
        spec.cluster.network.transport.name()
    );
    let r = tb_run(&spec, &TestbedOpts { iterations: iters, ..Default::default() });
    println!("ground-truth iteration: {}", fmt_us(r.avg_iter()));
    println!("peak memory (worker 0): {}", fmt_bytes(r.peak_memory));
    match r.trace.save(&out) {
        Ok(()) => {
            println!("wrote {} events to {out}", r.trace.events.len());
            0
        }
        Err(e) => {
            eprintln!("error writing {out}: {e}");
            1
        }
    }
}

fn cmd_replay(args: &Args) -> i32 {
    let spec = job_from_args(args);
    let path = args.get_or("trace", "trace.json");
    let trace = match GTrace::load(&path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error loading {path}: {e}");
            return 1;
        }
    };
    let aligned = !args.flag("no-align");
    let est = profiler::estimate(&spec, &trace, aligned);
    println!(
        "replayed {} ops (alignment: {})",
        est.graph.dfg.len(),
        if aligned { "on" } else { "off" }
    );
    println!("estimated iteration: {}", fmt_us(est.iteration_us()));
    println!("  forward:  {}", fmt_us(est.fw_us()));
    println!("  backward: {}", fmt_us(est.bw_us()));
    println!("  est. peak memory: {}", fmt_bytes(est.peak_memory(&spec)));
    0
}

fn cmd_align(args: &Args) -> i32 {
    let path = args.get_or("trace", "trace.json");
    let trace = match GTrace::load(&path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error loading {path}: {e}");
            return 1;
        }
    };
    let a = crate::alignment::align(&trace, 1.0, 1.0);
    println!("solved {} clock offsets in {} iterations (objective {:.3})",
             a.theta.len(), a.iterations, a.objective);
    let mut procs: Vec<_> = a.theta.iter().collect();
    procs.sort_by_key(|(p, _)| **p);
    for (proc, theta) in procs {
        println!("  proc {proc:4}: θ = {theta:+.1} us");
    }
    0
}

fn cmd_optimize(args: &Args) -> i32 {
    let spec = job_from_args(args);
    let mut opts = if args.flag("strawman") { SearchOpts::strawman() } else { SearchOpts::default() };
    opts.budget_wall_s = args.f64("budget-s", 60.0);
    if let Some(b) = args.get("memory-budget-gb") {
        opts.memory_budget_bytes = b.parse::<f64>().ok().map(|g| g * 1e9);
    }
    println!(
        "optimizing {} × {} workers ({}, {})...",
        spec.model.name,
        spec.cluster.n_workers,
        spec.scheme.name(),
        spec.cluster.network.transport.name()
    );
    let out = optimize(&spec, &opts);
    println!("baseline iteration (replayed): {}", fmt_us(out.baseline_iteration_us));
    println!("optimized iteration (replayed): {}", fmt_us(out.est_iteration_us));
    println!("speed-up: {:.2}x  ({} passes applied, {} replays, {:.1}s search)",
             out.speedup(), out.actions_applied, out.replays, out.wall_s);
    println!("memory pass: {}", out.mem_opt.name());
    // validate on the testbed
    let base = tb_run(&spec, &TestbedOpts { iterations: 5, ..Default::default() });
    let opt = tb_run(&out.spec, &TestbedOpts { iterations: 5, ..Default::default() });
    println!(
        "testbed validation: {} -> {} ({:.2}x real speed-up)",
        fmt_us(base.avg_iter()),
        fmt_us(opt.avg_iter()),
        base.avg_iter() / opt.avg_iter()
    );
    0
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> i32 {
    eprintln!(
        "`dpro train` drives the live PJRT path, which is feature-gated: \
         rebuild with `--features pjrt` in an environment that provides \
         the xla/anyhow/log crates (see rust/README.md)."
    );
    2
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> i32 {
    let cfg = crate::coordinator::TrainCfg {
        artifacts_dir: args.get_or("artifacts", "artifacts").into(),
        config: args.get_or("config", "mini"),
        n_workers: args.usize("workers", 4),
        steps: args.usize("steps", 50),
        seed: args.u64("seed", 17),
        log_every: args.usize("log-every", 10),
        ..Default::default()
    };
    match crate::coordinator::train(&cfg) {
        Ok(report) => {
            println!(
                "final loss {:.4} after {} steps; throughput {:.0} tokens/s ({} params)",
                report.final_loss(),
                report.losses.len(),
                report.tokens_per_s(),
                report.n_params
            );
            0
        }
        Err(e) => {
            eprintln!("training failed: {e:#}");
            1
        }
    }
}

fn cmd_report(args: &Args) -> i32 {
    let spec = job_from_args(args);
    let tb = tb_run(&spec, &TestbedOpts { iterations: 10, ..Default::default() });
    let est = profiler::estimate(&spec, &tb.trace, true);
    let dd = baselines::daydream::estimate(
        &spec,
        Some(&profiler::corrected_profile(&tb.trace, &crate::alignment::Alignment::identity())),
    );
    let truth = tb.avg_iter();
    println!("=== {} / {} / {} / {} workers ===",
             spec.model.name, spec.scheme.name(),
             spec.cluster.network.transport.name(), spec.cluster.n_workers);
    println!("ground truth : {}", fmt_us(truth));
    println!("dPRO replay  : {}  (err {:.2}%)", fmt_us(est.iteration_us()),
             crate::util::stats::rel_err_pct(est.iteration_us(), truth));
    println!("Daydream     : {}  (err {:.2}%)", fmt_us(dd.iteration_us),
             crate::util::stats::rel_err_pct(dd.iteration_us, truth));
    0
}
