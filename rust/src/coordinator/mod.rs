//! The live data-parallel training coordinator: the Rust "leader" that
//! drives the AOT-compiled JAX/Pallas train step through PJRT across
//! simulated data-parallel workers, synchronizing gradients through the
//! testbed's network model, and profiling itself with dPRO's trace format.
//!
//! Computation times are **real** (PJRT execution wall time); network
//! times are simulated (this box has one CPU and no NICs — see DESIGN.md
//! §Substitutions). dPRO's profiler/replayer consume the resulting gTrace
//! exactly as they would a hardware trace.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::config::NetworkSpec;
use crate::graph::dfg::OpKind;
use crate::runtime::{scalar_f32, tokens_literal, GptArtifacts, Runtime};
use crate::trace::{GTrace, TraceEvent};
use crate::util::rng::Pcg;
use crate::util::Us;

/// Configuration of one live training run.
#[derive(Clone, Debug)]
pub struct TrainCfg {
    /// Directory holding the AOT artifacts (`make artifacts`).
    pub artifacts_dir: PathBuf,
    /// Model config name (`mini`, `m100`).
    pub config: String,
    /// Simulated data-parallel worker count.
    pub n_workers: usize,
    /// Training steps to run.
    pub steps: usize,
    /// Data/seeding root.
    pub seed: u64,
    /// Log every N steps (0 disables progress logs).
    pub log_every: usize,
    /// Simulated inter-worker fabric for gradient synchronization.
    pub network: NetworkSpec,
    /// Where to dump the run's gTrace as a per-process Chrome-trace
    /// directory (`docs/TRACE_FORMAT.md`) for Perfetto inspection and
    /// `dpro replay --trace-dir`. `None` skips the dump.
    pub trace_dump_dir: Option<PathBuf>,
}

/// Machine layout of the simulated data-parallel cluster: workers are
/// packed 8 per machine. The trace's `machine` ids and the dumped
/// JobMeta must agree on this, so it has exactly one definition.
pub const GPUS_PER_MACHINE: usize = 8;

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            artifacts_dir: PathBuf::from("artifacts"),
            config: "mini".into(),
            n_workers: 4,
            steps: 50,
            seed: 17,
            log_every: 10,
            network: NetworkSpec::rdma_100g(),
            trace_dump_dir: None,
        }
    }
}

/// What a live training run produced and measured.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// Per-step mean loss across workers.
    pub losses: Vec<f32>,
    /// wall seconds per step (compute, real)
    pub grad_wall_s: Vec<f64>,
    /// Wall seconds per step of the leader's update (real).
    pub apply_wall_s: Vec<f64>,
    /// simulated AllReduce time per step (us)
    pub sim_comm_us: Vec<Us>,
    /// Tokens consumed per step across all workers.
    pub tokens_per_step: usize,
    /// The run's gTrace (real compute times, simulated comm).
    pub trace: GTrace,
    /// Model parameter count (elements).
    pub n_params: usize,
}

impl TrainReport {
    /// Loss of the last step (NaN before any step ran).
    pub fn final_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }

    /// Effective training throughput (tokens/s) counting real compute and
    /// simulated communication.
    pub fn tokens_per_s(&self) -> f64 {
        let total: f64 = self
            .grad_wall_s
            .iter()
            .zip(&self.apply_wall_s)
            .zip(&self.sim_comm_us)
            .map(|((g, a), c)| g + a + c / 1e6)
            .sum();
        self.tokens_per_step as f64 * self.losses.len() as f64 / total
    }
}

/// Synthetic corpus batch (same transition rule as model.synthetic_batch:
/// next = cur + 13·s + 1 mod vocab, s ∈ {0,1,2}).
pub fn synthetic_batch(
    rng: &mut Pcg,
    batch: usize,
    seq: usize,
    vocab: usize,
) -> (Vec<i32>, Vec<i32>) {
    let mut x = vec![0i32; batch * seq];
    let mut y = vec![0i32; batch * seq];
    for b in 0..batch {
        let mut tok = rng.below(vocab) as i64;
        for t in 1..seq {
            let s = rng.below(3) as i64;
            let next = (tok + 13 * s + 1) % vocab as i64;
            x[b * seq + t] = next as i32;
            if t >= 1 {
                y[b * seq + t - 1] = if t == 1 { 0 } else { next as i32 };
            }
            // y is x shifted left: y[t] = x[t+1]
            tok = next;
        }
        // fix up y to be exactly x shifted left
        for t in 0..seq - 1 {
            y[b * seq + t] = x[b * seq + t + 1];
        }
        y[b * seq + seq - 1] = 0;
    }
    (x, y)
}

/// Simulated ring-allreduce time for `bytes` across `n` workers (the same
/// model as `NetworkSpec` + the analytic cost in graph::build).
pub fn allreduce_time_us(net: &NetworkSpec, bytes: f64, n: usize) -> Us {
    if n <= 1 {
        return 0.0;
    }
    let volume = 2.0 * (n as f64 - 1.0) / n as f64 * bytes;
    let steps = 2 * (n - 1);
    net.wire_time_us(volume) + steps as f64 * (net.per_msg_overhead_us() + net.base_latency_us())
}

/// Run live data-parallel training. Workers share one PJRT CPU device
/// (time-sliced); gradients are averaged by the leader in Rust.
pub fn train(cfg: &TrainCfg) -> Result<TrainReport> {
    let rt = Runtime::cpu()?;
    let art = GptArtifacts::load(&rt, cfg.artifacts_dir.clone(), &cfg.config)?;
    let meta = &art.meta;
    let n = meta.n_params();
    let grad_bytes = meta.total_elems() as f64 * 4.0;
    let mut rng = Pcg::seeded(cfg.seed);

    // init params + opt state on the leader
    let mut state: Vec<xla::Literal> = art.init.run(&[xla::Literal::scalar(cfg.seed as i32)])?;
    assert_eq!(state.len(), n + meta.n_state_leaves, "init arity");

    let mut report = TrainReport {
        tokens_per_step: cfg.n_workers * meta.batch_size * meta.seq_len,
        n_params: meta.total_elems(),
        ..Default::default()
    };
    let mut clock: Us = 0.0; // simulated global clock for the trace
    let t_run = Instant::now();

    for step in 0..cfg.steps {
        // ---- per-worker gradient computation (real PJRT execution) ----
        let mut grad_sum: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut loss_sum = 0.0f32;
        let mut grad_wall = 0.0f64;
        let mut max_worker_us: Us = 0.0;
        for w in 0..cfg.n_workers {
            let (x, y) = synthetic_batch(&mut rng, meta.batch_size, meta.seq_len, meta.vocab);
            let xl = tokens_literal(&x, meta.batch_size, meta.seq_len)?;
            let yl = tokens_literal(&y, meta.batch_size, meta.seq_len)?;
            let mut args: Vec<&xla::Literal> = state[..n].iter().collect();
            args.push(&xl);
            args.push(&yl);
            let t0 = Instant::now();
            let out = art.grad.run(&args)?;
            let dur = t0.elapsed().as_secs_f64();
            grad_wall += dur;
            max_worker_us = max_worker_us.max(dur * 1e6);
            loss_sum += scalar_f32(&out[0])?;
            for (i, g) in out[1..].iter().enumerate() {
                let v = g.to_vec::<f32>()?;
                if w == 0 {
                    grad_sum.push(v);
                } else {
                    for (a, b) in grad_sum[i].iter_mut().zip(v) {
                        *a += b;
                    }
                }
            }
            report.trace.events.push(TraceEvent {
                name: format!("w{w}.BW.grad_step"),
                kind: OpKind::Backward,
                ts: clock,
                dur: dur * 1e6,
                proc: w as u16,
                machine: (w / GPUS_PER_MACHINE) as u16,
                iter: step as u32,
                txid: None,
            });
        }

        // ---- simulated gradient AllReduce ----
        let comm_us = allreduce_time_us(&cfg.network, grad_bytes, cfg.n_workers);
        report.trace.events.push(TraceEvent {
            name: "allreduce.grads".into(),
            kind: OpKind::Recv,
            ts: clock + max_worker_us,
            dur: comm_us,
            proc: 0,
            machine: 0,
            iter: step as u32,
            txid: Some(step as u64 + 1),
        });

        // ---- leader update (real PJRT execution) ----
        let inv = 1.0 / cfg.n_workers as f32;
        let avg: Vec<xla::Literal> = grad_sum
            .iter()
            .zip(&meta.params)
            .map(|(g, pm)| {
                let scaled: Vec<f32> = g.iter().map(|x| x * inv).collect();
                let dims: Vec<i64> = pm.shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(&scaled);
                if dims.is_empty() {
                    lit
                } else {
                    lit.reshape(&dims).unwrap()
                }
            })
            .collect();
        let t0 = Instant::now();
        let mut args: Vec<&xla::Literal> = state.iter().collect();
        let avg_refs: Vec<&xla::Literal> = avg.iter().collect();
        args.extend(avg_refs);
        let new_state = art.apply.run(&args)?;
        let apply_dur = t0.elapsed().as_secs_f64();
        report.trace.events.push(TraceEvent {
            name: "w0.UPD.apply_step".into(),
            kind: OpKind::Update,
            ts: clock + max_worker_us + comm_us,
            dur: apply_dur * 1e6,
            proc: 0,
            machine: 0,
            iter: step as u32,
            txid: None,
        });
        state = new_state;

        let loss = loss_sum / cfg.n_workers as f32;
        report.losses.push(loss);
        report.grad_wall_s.push(grad_wall);
        report.apply_wall_s.push(apply_dur);
        report.sim_comm_us.push(comm_us);
        clock += max_worker_us + comm_us + apply_dur * 1e6;

        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            log::info!(
                "step {step:4}  loss {loss:.4}  grad {:.2}s  comm(sim) {:.1}ms  apply {:.2}s",
                grad_wall,
                comm_us / 1e3,
                apply_dur
            );
            println!(
                "step {step:4}  loss {loss:.4}  grad {grad_wall:.2}s  comm(sim) {:.1}ms  apply {apply_dur:.2}s",
                comm_us / 1e3
            );
        }
    }
    report.trace.n_workers = cfg.n_workers;
    report.trace.n_procs = cfg.n_workers;
    report.trace.iterations = cfg.steps;
    // dump the measured trace for Perfetto / `dpro replay --trace-dir`
    // (profile-then-replay toolchain, paper Fig. 3); compute times in the
    // dump are real PJRT wall times, network times simulated
    if let Some(dir) = &cfg.trace_dump_dir {
        // carry the job context so `dpro replay --trace-dir` reconstructs
        // this run's shape instead of defaulting to resnet50×16. The
        // coordinator's gradient sync is a flat ring over workers, and its
        // trace is step-granular (grad/allreduce/apply), so the gpt_mini
        // skeleton is the honest closest template.
        let job = crate::trace::io::JobMeta {
            model: "gpt_mini".into(),
            scheme: "ring".into(),
            transport: cfg.network.transport.name().to_lowercase(),
            n_workers: cfg.n_workers,
            gpus_per_machine: GPUS_PER_MACHINE,
            plan: crate::trace::io::PLAN_DEPLOYED.to_string(),
        };
        match crate::trace::io::dump_dir_with_job(&report.trace, dir, Some(&job)) {
            Ok(s) => log::info!(
                "dumped {} trace events to {} files in {}",
                s.events,
                s.files,
                dir.display()
            ),
            Err(e) => log::warn!("trace dump to {} failed: {e}", dir.display()),
        }
    }
    log::info!("trained {} steps in {:.1}s", cfg.steps, t_run.elapsed().as_secs_f64());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_batch_shifted() {
        let mut rng = Pcg::seeded(1);
        let (x, y) = synthetic_batch(&mut rng, 2, 16, 256);
        assert_eq!(x.len(), 32);
        for b in 0..2 {
            for t in 0..15 {
                assert_eq!(y[b * 16 + t], x[b * 16 + t + 1]);
            }
        }
        assert!(x.iter().all(|&t| t >= 0 && t < 256));
    }

    #[test]
    fn allreduce_time_scales() {
        let net = NetworkSpec::rdma_100g();
        let t4 = allreduce_time_us(&net, 64.0e6, 4);
        let t16 = allreduce_time_us(&net, 64.0e6, 16);
        assert!(t16 > t4);
        assert_eq!(allreduce_time_us(&net, 64.0e6, 1), 0.0);
        // 64 MB at ~94 Gbps ring ≈ 8-12 ms
        assert!((4_000.0..20_000.0).contains(&t16), "t16={t16}");
    }

    // PJRT-dependent tests live in rust/tests/integration.rs (they need
    // built artifacts).
}
