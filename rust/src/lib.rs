//! # dPRO — profiling, replay and optimization for distributed DNN training
//!
//! Reproduction of *dPRO: A Generic Profiling and Optimization System for
//! Expediting Distributed DNN Training* (Hu et al., MLSys 2022) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **Profiler** ([`testbed`] emits fine-grained traces; [`trace`] builds
//!   the global timeline; [`alignment`] corrects clock drift, §4.2).
//! - **Replayer** ([`replay`]): per-device-queue simulation of the global
//!   DFG, critical path, partial replay, peak-memory estimation (§4.3).
//! - **Diagnosis** ([`diagnosis`]): critical-path blame attribution,
//!   bottleneck ranking, and transactional what-if queries over the
//!   incremental engine — *why* an iteration is slow, before optimizing
//!   (§bottleneck identification).
//! - **Optimizer** ([`optimizer`]): one Strategy API
//!   ([`optimizer::strategy`]) through which the critical-path search of
//!   Alg. 1, the graph-pass registry, and the memory passes all run as
//!   transactional decisions on the incremental engine, with Coarsened
//!   View / partial replay / symmetry accelerations (§5), validated
//!   against [`baselines`].
//! - **Service** ([`serve`]): `dprod`, a std-only HTTP daemon keeping
//!   built graphs resident in a byte-accounted LRU session cache and
//!   serving concurrent replay / diagnose / what-if queries with
//!   snapshot isolation (single-writer `optimize`, coalesced what-ifs).
//! - **Campaigns** ([`campaign`]): declarative scenario sweeps (models ×
//!   schemes × workers × strategies × faults × replay modes) on a
//!   persistent resumable work queue, emitting one provenance-stamped
//!   CSV/JSON results matrix — `dpro campaign`, the engine behind the
//!   paper-figure benches.
//! - **Self-telemetry** ([`obs`]): spans + metrics over dpro's own
//!   replay/search/serve/campaign loops; `--self-trace` dumps a run's
//!   execution in the crate's own gTrace format, `GET /metricsz`
//!   exposes the serve registry as Prometheus text.
//!
//! The live end-to-end path ([`runtime`] + [`coordinator`]) executes a JAX
//! (+Pallas) transformer AOT-compiled to HLO through PJRT, with Python
//! never on the hot path.
//!
//! See `DESIGN.md` for the module-to-paper map and the hardware
//! substitutions, and `docs/TRACE_FORMAT.md` for the on-disk trace schema.

// The CI docs job runs `cargo doc` with RUSTDOCFLAGS="-D warnings", so an
// undocumented public item fails the build, not just the style bar.
#![warn(missing_docs)]

pub mod alignment;
pub mod baselines;
pub mod campaign;
pub mod cli;
/// Live data-parallel training coordinator. Requires the `pjrt` feature
/// (and an environment providing the `xla`/`anyhow`/`log` crates); the
/// default offline build compiles everything else.
#[cfg(feature = "pjrt")]
pub mod coordinator;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod config;
pub mod diagnosis;
pub mod fault;
pub mod testbed;
pub mod trace;
pub mod graph;
pub mod models;
pub mod obs;
pub mod optimizer;
pub mod profiler;
pub mod replay;
pub mod serve;
pub mod util;

/// Crate version (from `Cargo.toml`), shown by the CLI.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
