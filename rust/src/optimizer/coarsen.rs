//! Coarsened View (paper §5.3, Fig. 6): shrink the strategy space before
//! search by applying the fusions Theorem 3 shows are never harmful:
//!
//! 1. a computation op that produces **no** tensor is grouped with the
//!    tensor-producing op it feeds (view its null tensor as fused);
//! 2. tensors produced by the **same** computation op (e.g. BatchNorm's
//!    γ and β) are fused into one synchronization group.

use crate::config::JobSpec;
use crate::graph::dfg::OpKind;
use crate::optimizer::passes;

/// Statistics of a coarsening application.
#[derive(Debug, Default, Clone, Copy)]
pub struct CoarsenStats {
    /// Rule-1 fusion-group merges applied (non-producing op → successor).
    pub op_fusions: usize,
    /// Rule-2 comm-group merges applied (same-producer tensors).
    pub tensor_fusions: usize,
}

/// Apply the Coarsened View to `spec` in place.
pub fn coarsen(spec: &mut JobSpec) -> CoarsenStats {
    let mut stats = CoarsenStats::default();

    // --- rule 2: fuse tensors produced by the same op ---
    // (do this first: comm-group indices shift as we merge)
    let produced_together: Vec<Vec<u32>> = spec
        .model
        .ops
        .iter()
        .filter(|o| o.produces.len() >= 2)
        .map(|o| o.produces.clone())
        .collect();
    for tensors in produced_together {
        // merge the comm group of tensors[1..] into tensors[0]'s group
        for &t in &tensors[1..] {
            let Some(a) = passes::comm_group_of_tensor(spec, tensors[0]) else { continue };
            let Some(b) = passes::comm_group_of_tensor(spec, t) else { continue };
            if a != b && passes::fuse_tensor_groups(spec, a, b).is_ok() {
                stats.tensor_fusions += 1;
            }
        }
    }

    // --- rule 1: group non-producing comp ops with their unique
    // tensor-producing successor (backward ops; mirrored on forward) ---
    // successor lists over template ops of the same kind
    let n = spec.model.ops.len();
    let mut succs: Vec<Vec<u32>> = vec![Vec::new(); n];
    for (i, op) in spec.model.ops.iter().enumerate() {
        for &d in &op.deps {
            if spec.model.ops[d as usize].kind == op.kind {
                succs[d as usize].push(i as u32);
            }
        }
    }
    // walk backward ops in reverse template order (BW topological order)
    let bw_ids: Vec<u32> = spec.model.bw_ids();
    for &b in &bw_ids {
        let op = &spec.model.ops[b as usize];
        if op.kind != OpKind::Backward || !op.produces.is_empty() {
            continue;
        }
        // unique same-kind successor
        if succs[b as usize].len() != 1 {
            continue;
        }
        let succ = succs[b as usize][0];
        let ga = spec.fusion.group_of[b as usize] as usize;
        let gb = spec.fusion.group_of[succ as usize] as usize;
        if ga == gb {
            continue;
        }
        if passes::fuse_comp_groups(spec, ga, gb).is_ok() {
            stats.op_fusions += 1;
            // mirror the fusion on the forward side (keeps FW/BW kernels
            // consistent, as XLA clusters both directions)
            let (ma, mb) = (
                spec.model.ops[b as usize].mirror,
                spec.model.ops[succ as usize].mirror,
            );
            if let (Some(ma), Some(mb)) = (ma, mb) {
                let fa = spec.fusion.group_of[ma as usize] as usize;
                let fb = spec.fusion.group_of[mb as usize] as usize;
                if fa != fb {
                    let _ = passes::fuse_comp_groups(spec, fa, fb);
                }
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobSpec, Transport};

    #[test]
    fn resnet_coarsening_shrinks_search_space() {
        let mut s = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let groups_before = s.plan.groups.len();
        let fusion_before = s.fusion.groups.len();
        let stats = coarsen(&mut s);
        // BN produces γ+β → 53 tensor fusions; ReLU/pool/add BW ops fold in
        assert!(stats.tensor_fusions >= 50, "{stats:?}");
        assert!(stats.op_fusions >= 50, "{stats:?}");
        assert!(s.plan.groups.len() < groups_before);
        assert!(s.fusion.groups.len() < fusion_before);
        assert_eq!(s.plan.validate(&s.model), Ok(()));
        assert_eq!(s.fusion.validate(&s.model), Ok(()));
    }

    #[test]
    fn coarsened_graph_still_replays() {
        let mut s = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let g0 = crate::graph::build_global(&s, &crate::graph::AnalyticCost::new(&s));
        let t0 = crate::replay::replay_once(&g0).iteration_time;
        coarsen(&mut s);
        let g1 = crate::graph::build_global(&s, &crate::graph::AnalyticCost::new(&s));
        assert!(g1.dfg.is_dag());
        let t1 = crate::replay::replay_once(&g1).iteration_time;
        // coarsening fuses launch overheads away and merges tiny
        // collectives: should not slow the job down materially
        assert!(t1 < t0 * 1.05, "t0={t0} t1={t1}");
    }

    #[test]
    fn bert_coarsening_fuses_ln_tensors() {
        let mut s = JobSpec::standard("bert_base", "horovod", Transport::Rdma);
        let before = s.plan.groups.len();
        let stats = coarsen(&mut s);
        assert!(stats.tensor_fusions > 20);
        assert!(s.plan.groups.len() < before);
    }
}
