//! The **Strategy API**: one public surface through which *every*
//! optimization strategy — the paper's critical-path fusion/partition walk
//! (Alg. 1), Graph-Pass-Registry rewrites (§8), and the memory passes
//! (§5.2) — plugs into the same transactional, incrementally-replayed
//! search.
//!
//! The pieces:
//!
//! - [`Decision`] — the public decision IR. Graph-level rewrites
//!   (`OpFuse`, `TensorFuse`, `Partition`) apply as in-place edits on the
//!   long-lived [`MutableGraph`]; whole-job rewrites (`WholeJob` for
//!   registry passes, `Memory` for the memory passes) apply as template
//!   swaps on the same graph. No decision kind ever rebuilds the global
//!   DFG.
//! - [`Strategy`] — the trait a strategy implements: propose
//!   [`Decision`]s from a [`SearchCtx`] snapshot, apply one inside an open
//!   transaction, and optionally adjust the replayed cost
//!   ([`Strategy::evaluate`], the cost-hint hook gradient accumulation
//!   uses for its second micro-batch).
//! - The accept/reject loop in [`crate::optimizer::search::optimize_with`]
//!   is strategy-agnostic: per candidate it opens a transaction
//!   ([`MutableGraph::begin`]), applies, replays incrementally, and keeps
//!   ([`MutableGraph::commit_txn`]) or rolls back
//!   ([`MutableGraph::rollback`]) — a rejected candidate costs one cone
//!   repair, never a `build_global*` call or a spec re-clone.
//!
//! Three built-ins ship: [`CriticalPathStrategy`] (Theorems 1–3 on the
//! critical path), [`RegistryStrategy`] (every registered
//! [`crate::optimizer::registry::GraphPass`], mixed precision by default),
//! and [`MemoryStrategy`] (re-computation / gradient accumulation, active
//! while the replayed peak memory exceeds the budget).
//!
//! # Writing a strategy (~60 LoC gets you a full search participant)
//!
//! ```
//! use dpro::config::{JobSpec, Transport};
//! use dpro::graph::MutableGraph;
//! use dpro::optimizer::strategy::{
//!     apply_graph_decision, ApplyCtx, Decision, SearchCtx, Strategy,
//! };
//! use dpro::optimizer::{optimize_with, SearchOpts};
//!
//! /// Toy strategy: always propose fusing the first two comm groups.
//! struct FuseFirstPair;
//!
//! impl Strategy for FuseFirstPair {
//!     fn name(&self) -> &str {
//!         "fuse-first-pair"
//!     }
//!
//!     fn candidates(&mut self, ctx: &mut SearchCtx) -> Vec<Decision> {
//!         let plan = &ctx.mg.spec().plan;
//!         if plan.groups.len() < 2 {
//!             return Vec::new();
//!         }
//!         vec![Decision::TensorFuse(plan.groups[0].tensors[0], plan.groups[1].tensors[0])]
//!     }
//!
//!     fn apply(&mut self, mg: &mut MutableGraph, d: &Decision, ctx: &ApplyCtx) -> usize {
//!         apply_graph_decision(mg, d, ctx.sym, true, true)
//!     }
//! }
//!
//! let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
//! let opts = SearchOpts {
//!     max_rounds: 2,
//!     budget_wall_s: 30.0,
//!     use_coarsened_view: false, // keep the baseline spec bit-comparable
//!     ..Default::default()
//! };
//! let strategies: Vec<Box<dyn Strategy>> = vec![Box::new(FuseFirstPair)];
//! let out = optimize_with(&spec, &opts, strategies);
//! // rejected candidates roll back, so the estimate never regresses
//! assert!(out.est_iteration_us <= out.baseline_iteration_us * 1.0 + 1e-9);
//! assert_eq!(out.builds_during_search, 0);
//! ```

use std::collections::{HashMap, HashSet};

use crate::config::JobSpec;
use crate::graph::dfg::{NodeId, OpKind, TensorId};
use crate::graph::{build_global_nameless, AnalyticCost, MutableGraph};
use crate::optimizer::memopt::{self, MemOpt, MICRO_BATCH_INEFFICIENCY};
use crate::optimizer::passes;
use crate::optimizer::registry::Registry;
use crate::optimizer::search::SearchOpts;
use crate::optimizer::symmetry::SymmetryIndex;
use crate::replay::partial::TsyncEstimator;
use crate::replay::{replay_once, ReplayResult};
use crate::util::Us;

/// One candidate rewrite, in *stable* identifiers (template op ids /
/// tensor ids) so a decision survives the plan-index shifts earlier
/// decisions of the same round cause.
#[derive(Clone, Debug, PartialEq)]
pub enum Decision {
    /// Fuse the fusion groups containing these two template ops + the comm
    /// groups of their produced tensors (Theorems 1+3).
    OpFuse(u32, u32),
    /// Fuse the comm groups containing these two tensors + their producer
    /// fusion groups (Theorems 2+3).
    TensorFuse(TensorId, TensorId),
    /// Set the partition count of the comm group containing the tensor.
    Partition(TensorId, usize),
    /// Apply the registered graph pass of this name as a whole-job
    /// template rewrite (see [`crate::optimizer::registry`]).
    WholeJob(String),
    /// Apply a memory-optimization pass as a whole-job template rewrite.
    Memory(MemOpt),
}

impl std::fmt::Display for Decision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Decision::OpFuse(a, b) => write!(f, "op-fuse({a},{b})"),
            Decision::TensorFuse(a, b) => write!(f, "tensor-fuse({a},{b})"),
            Decision::Partition(t, k) => write!(f, "partition({t},{k})"),
            Decision::WholeJob(name) => write!(f, "pass:{name}"),
            Decision::Memory(m) => write!(f, "memory:{}", m.name()),
        }
    }
}

/// Replay-judged cost of one candidate (or of the current accepted state).
/// `mem_bytes` is only computed when a memory budget is set (the peak walk
/// is the expensive part); `comp_us` is always available.
#[derive(Clone, Copy, Debug, Default)]
pub struct CandidateEval {
    /// Replayed iteration time (us).
    pub time_us: Us,
    /// Estimated peak memory (bytes; 0.0 when no budget is set).
    pub mem_bytes: f64,
    /// Forward+backward busy time of worker 0 (the gradient-accumulation
    /// cost hint needs it).
    pub comp_us: Us,
}

/// The search's uniform acceptance objective: with no budget, strictly
/// smaller iteration time wins; with a budget, feasibility (peak memory
/// within budget) dominates, time breaks ties among feasible states, and
/// among infeasible states any memory reduction is progress. This single
/// rule is what lets memory passes win *inside* the round loop even though
/// they cost time.
pub fn better(new: &CandidateEval, cur: &CandidateEval, budget: Option<f64>) -> bool {
    let Some(b) = budget else { return new.time_us < cur.time_us };
    match (new.mem_bytes <= b, cur.mem_bytes <= b) {
        (true, false) => true,
        (false, true) => false,
        (true, true) => new.time_us < cur.time_us,
        (false, false) => new.mem_bytes < cur.mem_bytes,
    }
}

/// Evaluate the current graph state from its (incremental) replay result.
pub fn eval_state(
    mg: &MutableGraph,
    result: &ReplayResult,
    budget: Option<f64>,
) -> CandidateEval {
    let time_us = result.iteration_time;
    let mem_bytes = if budget.is_some() {
        crate::replay::estimate_peak_memory_mut(mg, &result.end)
    } else {
        0.0
    };
    let dfg = mg.dfg();
    let alive = mg.alive();
    let comp_us = dfg
        .ids()
        .filter(|&i| alive[i as usize])
        .map(|i| dfg.node(i))
        .filter(|n| {
            n.owner == 0
                && n.proc == 0
                && matches!(n.kind, OpKind::Forward | OpKind::Backward)
        })
        .map(|n| n.duration)
        .sum();
    CandidateEval { time_us, mem_bytes, comp_us }
}

/// Context a strategy proposes candidates from: the current graph state,
/// its last replay, the critical path, and the shared `t_sync` oracle.
pub struct SearchCtx<'a> {
    /// The shared long-lived graph (read-only while proposing).
    pub mg: &'a MutableGraph,
    /// Per-node end times of the last replay.
    pub end: &'a [f64],
    /// Critical path of the last replay, source → sink.
    pub path: &'a [NodeId],
    /// Per-group critical-path blame of the last replay
    /// ([`crate::diagnosis::critical::group_blame`]): strategies sort
    /// their candidates by it (when
    /// [`SearchOpts::use_blame_ranking`] is on) so high-blame targets are
    /// tried first.
    pub blame: &'a crate::diagnosis::critical::GroupBlame,
    /// Shared `t_sync(s, k)` oracle (§5.1).
    pub tsync: &'a mut Tsync,
    /// The search configuration in force.
    pub opts: &'a SearchOpts,
    /// Whether tensor partitioning is worthwhile under the current scheme
    /// (derived from plan properties, never from the scheme enum).
    pub partition_enabled: bool,
    /// Memory budget, if the job is memory-constrained.
    pub budget_bytes: Option<f64>,
    /// Evaluation of the current accepted state.
    pub cur: CandidateEval,
    /// Round number, 0-based.
    pub round: usize,
}

/// Context for applying a decision (symmetry propagation).
pub struct ApplyCtx<'a> {
    /// Symmetry index for propagating a decision across symmetric blocks
    /// (§5.4), when enabled.
    pub sym: Option<&'a SymmetryIndex>,
}

/// A pluggable optimization strategy. The search calls [`Self::candidates`]
/// once per round, then for each candidate opens a transaction on the
/// shared [`MutableGraph`], calls [`Self::apply`], replays incrementally,
/// scores the result through [`Self::evaluate`], and keeps or rolls back.
/// [`Self::decided`] reports the verdict so the strategy can stop
/// re-proposing settled candidates.
pub trait Strategy {
    /// Stable strategy name (`--strategies` key, logs).
    fn name(&self) -> &str;

    /// Propose candidate decisions for this round, in stable ids.
    fn candidates(&mut self, ctx: &mut SearchCtx) -> Vec<Decision>;

    /// Apply one of this strategy's decisions as in-place edits on `mg`
    /// (a transaction is already open). Returns the number of primitive
    /// passes applied — 0 means "not applicable here", and the empty
    /// transaction is rolled back without a replay.
    fn apply(&mut self, mg: &mut MutableGraph, d: &Decision, ctx: &ApplyCtx) -> usize;

    /// Cost hint: adjust the raw replayed evaluation of a candidate this
    /// strategy proposed (e.g. gradient accumulation's second micro-batch
    /// runs outside the replayed graph).
    fn evaluate(&self, _d: &Decision, raw: CandidateEval, _mg: &MutableGraph) -> CandidateEval {
        raw
    }

    /// Verdict callback: `accepted == false` means the decision was rolled
    /// back.
    fn decided(&mut self, _d: &Decision, _accepted: bool) {}
}

// ---------------------------------------------------------------------------
// Shared application of graph-level decisions
// ---------------------------------------------------------------------------

/// Apply a graph-level decision (`OpFuse` / `TensorFuse` / `Partition`)
/// plus its Theorem-3 companions and symmetry analogs as in-place edits.
/// Returns the number of primitive passes applied; `WholeJob` / `Memory`
/// decisions return 0 (they are applied by their owning strategies).
pub fn apply_graph_decision(
    mg: &mut MutableGraph,
    d: &Decision,
    sym: Option<&SymmetryIndex>,
    op_fusion: bool,
    tensor_fusion: bool,
) -> usize {
    let mut n = 0usize;
    match *d {
        Decision::OpFuse(op_a, op_b) => {
            n += fuse_ops_and_tensors(mg, op_a, op_b, tensor_fusion);
            if let Some(sym) = sym {
                for (x, y) in sym.analog_pairs(op_a, op_b) {
                    n += fuse_ops_and_tensors(mg, x, y, tensor_fusion);
                }
            }
        }
        Decision::TensorFuse(ta, tb) => {
            n += fuse_tensors_and_ops(mg, ta, tb, op_fusion);
            if let Some(sym) = sym {
                let pa = mg.spec().model.producer_of(ta);
                let pb = mg.spec().model.producer_of(tb);
                if let (Some(pa), Some(pb)) = (pa, pb) {
                    for (x, y) in sym.analog_pairs(pa, pb) {
                        // fuse the first produced tensors of the analogs
                        let tx = mg.spec().model.ops[x as usize].produces.first().copied();
                        let ty = mg.spec().model.ops[y as usize].produces.first().copied();
                        if let (Some(tx), Some(ty)) = (tx, ty) {
                            n += fuse_tensors_and_ops(mg, tx, ty, op_fusion);
                        }
                    }
                }
            }
        }
        Decision::Partition(t, k) => {
            if let Some(cg) = passes::comm_group_of_tensor(mg.spec(), t) {
                if mg.spec().plan.groups[cg].partitions != k && mg.set_partitions(cg, k).is_ok()
                {
                    n += 1;
                }
            }
        }
        Decision::WholeJob(_) | Decision::Memory(_) => {}
    }
    n
}

/// Theorem 1 + 3: fuse two fusion groups and the comm groups they feed.
fn fuse_ops_and_tensors(mg: &mut MutableGraph, op_a: u32, op_b: u32, tensor_fusion: bool) -> usize {
    let n_ops = mg.spec().model.ops.len();
    if op_a as usize >= n_ops || op_b as usize >= n_ops {
        return 0;
    }
    let fa = mg.spec().fusion.group_of[op_a as usize] as usize;
    let fb = mg.spec().fusion.group_of[op_b as usize] as usize;
    if fa == fb {
        return 0;
    }
    let mut n = 0;
    let cgs_a = passes::comm_groups_of_fusion_group(mg.spec(), fa);
    let cgs_b = passes::comm_groups_of_fusion_group(mg.spec(), fb);
    if mg.fuse_comp_groups(fa, fb).is_ok() {
        n += 1;
        // companion tensor fusion (Theorem 3)
        if tensor_fusion {
            if let (Some(&ca), Some(&cb)) = (cgs_a.first(), cgs_b.first()) {
                // indices may have shifted only for fusion groups, not comm
                if ca != cb && mg.fuse_tensor_groups(ca, cb).is_ok() {
                    n += 1;
                }
            }
        }
    }
    n
}

/// Theorem 2 + 3: fuse two comm groups and their producer fusion groups.
fn fuse_tensors_and_ops(
    mg: &mut MutableGraph,
    ta: TensorId,
    tb: TensorId,
    op_fusion: bool,
) -> usize {
    let Some(ca) = passes::comm_group_of_tensor(mg.spec(), ta) else { return 0 };
    let Some(cb) = passes::comm_group_of_tensor(mg.spec(), tb) else { return 0 };
    if ca == cb {
        return 0;
    }
    let pa = passes::producer_fusion_group(mg.spec(), ca);
    let pb = passes::producer_fusion_group(mg.spec(), cb);
    let mut n = 0;
    if mg.fuse_tensor_groups(ca, cb).is_ok() {
        n += 1;
        if op_fusion {
            if let (Some(pa), Some(pb)) = (pa, pb) {
                if pa != pb && mg.fuse_comp_groups(pa, pb).is_ok() {
                    n += 1;
                }
            }
        }
    }
    n
}

// ---------------------------------------------------------------------------
// t_sync oracle (shared by every strategy through SearchCtx)
// ---------------------------------------------------------------------------

/// `t_sync(s, k)` oracle: partial replay (fast, never builds) or full
/// replay of the entire current job (the strawman's approach, memoized on
/// `(bytes_bucket, k)` so repeated probes within a round do not repeat
/// builds — the cache is cleared each round because a strawman probe
/// measures the *current* mutating job, not an idle network).
pub struct Tsync {
    partial: Option<TsyncEstimator>,
    strawman_cache: HashMap<(u64, usize), Us>,
    full_replays: usize,
}

impl Tsync {
    /// Build the oracle. `partial == true` pre-builds one probe engine per
    /// partition count in `1..=max_k` (plus counts the deployed plan
    /// already uses); `false` selects the strawman full-replay path.
    pub fn new(spec: &JobSpec, partial: bool, max_k: usize) -> Tsync {
        let partial = partial.then(|| {
            // pre-instantiate every partition count a round can query: the
            // grid range plus whatever the deployed plan already uses —
            // after this, t_sync never constructs a graph
            let mut ks: Vec<usize> = (1..=max_k.max(1)).collect();
            ks.extend(spec.plan.groups.iter().map(|g| g.partitions.max(1)));
            TsyncEstimator::with_prebuilt(spec, ks)
        });
        Tsync { partial, strawman_cache: HashMap::new(), full_replays: 0 }
    }

    /// Invalidate measurements that depend on the evolving job (the
    /// partial-replay estimator probes an idle network and stays valid).
    pub fn new_round(&mut self) {
        self.strawman_cache.clear();
    }

    /// Full-job replays the strawman path performed (0 with partial replay).
    pub fn full_replays(&self) -> usize {
        self.full_replays
    }

    /// Synchronization time of a `bytes`-sized group split `k` ways under
    /// the current scheme (§5.1's `t_sync(s, k)` query).
    pub fn t_sync(&mut self, spec: &JobSpec, bytes: f64, k: usize) -> Us {
        if let Some(p) = &mut self.partial {
            return p.t_sync(bytes, k);
        }
        let key = ((bytes / 1024.0).round() as u64, k.max(1));
        if let Some(&v) = self.strawman_cache.get(&key) {
            return v;
        }
        // strawman: rebuild and replay the entire current job with group 0
        // rescaled to the probe size
        if spec.plan.groups.is_empty() {
            return 0.0;
        }
        let mut s = spec.clone();
        s.plan.groups[0].partitions = k.max(1);
        let scale_t = s.plan.groups[0].tensors[0] as usize;
        let group_rest: f64 = s.plan.groups[0]
            .tensors
            .iter()
            .skip(1)
            .map(|&t| s.model.tensors[t as usize].bytes)
            .sum();
        s.model.tensors[scale_t].bytes = (bytes - group_rest).max(1.0);
        let g = build_global_nameless(&s, &AnalyticCost::new(&s));
        let r = replay_once(&g);
        self.full_replays += 1;
        let mut t_in = f64::INFINITY;
        let mut t_out: f64 = 0.0;
        for &n in &g.group_nodes[0] {
            let node = g.dfg.node(n);
            match node.kind {
                OpKind::In => t_in = t_in.min(r.end[n as usize]),
                OpKind::Out => t_out = t_out.max(r.end[n as usize]),
                _ => {}
            }
        }
        let t = (t_out - t_in).max(0.0);
        self.strawman_cache.insert(key, t);
        t
    }

    /// Best partition count for a `bytes`-sized group and its `t_sync`
    /// (grid scan over `1..=max_k`).
    pub fn opt_part_num(&mut self, spec: &JobSpec, bytes: f64, max_k: usize) -> (usize, Us) {
        let mut best = (1usize, f64::INFINITY);
        for k in 1..=max_k.max(1) {
            let t = self.t_sync(spec, bytes, k);
            if t < best.1 {
                best = (k, t);
            }
        }
        best
    }
}

// ---------------------------------------------------------------------------
// Built-in strategy 1: the critical-path walker (paper Alg. 1 lines 5–25)
// ---------------------------------------------------------------------------

/// The paper's core search strategy: walk the critical path of the last
/// replay and propose the fusions/partitions Theorems 1–3 justify.
pub struct CriticalPathStrategy {
    /// Propose op-fusion decisions.
    pub op_fusion: bool,
    /// Propose tensor-fusion decisions.
    pub tensor_fusion: bool,
    /// Propose partition decisions (still auto-gated per scheme through
    /// [`SearchCtx::partition_enabled`]).
    pub partition: bool,
}

impl CriticalPathStrategy {
    /// Configure from the search options' enable flags.
    pub fn from_opts(opts: &SearchOpts) -> CriticalPathStrategy {
        CriticalPathStrategy {
            op_fusion: opts.enable_op_fusion,
            tensor_fusion: opts.enable_tensor_fusion,
            // still auto-gated per scheme through SearchCtx::partition_enabled
            partition: true,
        }
    }
}

impl Strategy for CriticalPathStrategy {
    fn name(&self) -> &str {
        "critical-path"
    }

    fn candidates(&mut self, ctx: &mut SearchCtx) -> Vec<Decision> {
        let mg = ctx.mg;
        let spec = mg.spec();
        let dfg = mg.dfg();
        let end = ctx.end;
        let path = ctx.path;
        let gpu = &spec.cluster.gpu;
        let partition_enabled = self.partition && ctx.partition_enabled;
        let mut out = Vec::new();

        // group-level end times for q^e (max end over the group's comm chain)
        let group_end = |cg: usize| -> f64 {
            mg.group_nodes_iter(cg).map(|n| end[n as usize]).fold(0.0, f64::max)
        };

        for w in path.windows(2) {
            let (a, b) = (dfg.node(w[0]), dfg.node(w[1]));

            // ---- computation-bound segment: consecutive comp ops ----
            if self.op_fusion
                && a.kind == b.kind
                && (a.kind == OpKind::Backward || a.kind == OpKind::Forward)
                && a.owner == b.owner
            {
                let (Some(fa), Some(fb)) = (a.template_id, b.template_id) else { continue };
                if fa == fb {
                    continue;
                }
                let da = spec.fusion.duration(&spec.model, gpu, fa as usize);
                let db = spec.fusion.duration(&spec.model, gpu, fb as usize);
                let fused = gpu.fused_time(&[da, db]);
                // q_{n-1}: sync of the tensors produced by the earlier group
                let cgs = passes::comm_groups_of_fusion_group(spec, fa as usize);
                let q_d = cgs
                    .iter()
                    .map(|&cg| {
                        let bytes = spec.plan.group_bytes(&spec.model, cg);
                        ctx.tsync.t_sync(spec, bytes, spec.plan.groups[cg].partitions)
                    })
                    .fold(0.0, f64::max);
                // Theorem 1
                if q_d <= da + db - fused {
                    let op_a = spec.fusion.groups[fa as usize][0];
                    let op_b = spec.fusion.groups[fb as usize][0];
                    out.push(Decision::OpFuse(op_a, op_b));
                }
                continue;
            }

            // ---- communication-bound segment: consecutive comm ops ----
            if (self.tensor_fusion || partition_enabled)
                && a.kind.is_comm()
                && b.kind.is_comm()
            {
                let (Some(ta), Some(tb)) = (a.tensor, b.tensor) else { continue };
                let (ca, cb) = (ta.tensor_id as usize, tb.tensor_id as usize);
                if ca == cb || ca >= spec.plan.groups.len() || cb >= spec.plan.groups.len() {
                    continue;
                }
                let sb = spec.plan.group_bytes(&spec.model, cb);
                let max_k = if partition_enabled { ctx.opts.max_partitions } else { 1 };
                let mut fused = false;
                if self.tensor_fusion {
                    let sa = spec.plan.group_bytes(&spec.model, ca);
                    let (k_f, t_f) = ctx.tsync.opt_part_num(spec, sa + sb, max_k);
                    let (_k_b, t_b) = ctx.tsync.opt_part_num(spec, sb, max_k);
                    let q_prev_end = group_end(ca);
                    // p_n^e: end of the producer comp group of cb on this
                    // worker
                    let p_end = passes::producer_fusion_group(spec, cb)
                        .and_then(|fg| mg.comp_node(b.owner, fg as u32))
                        .map(|n| end[n as usize])
                        .unwrap_or(0.0);
                    // Theorem 2
                    if q_prev_end > p_end + t_f - t_b {
                        let t_first = spec.plan.groups[ca].tensors[0];
                        let t_second = spec.plan.groups[cb].tensors[0];
                        out.push(Decision::TensorFuse(t_first, t_second));
                        if partition_enabled && k_f > 1 {
                            out.push(Decision::Partition(t_first, k_f));
                        }
                        fused = true;
                    }
                }
                if !fused && partition_enabled {
                    let (k_n, _) = ctx.tsync.opt_part_num(spec, sb, max_k);
                    if k_n != spec.plan.groups[cb].partitions {
                        out.push(Decision::Partition(spec.plan.groups[cb].tensors[0], k_n));
                    }
                }
            }
        }

        // ---- blame ranking: try high-blame targets first ----
        // The accept/reject loop updates its acceptance bar after every
        // win, so evaluation order changes how many candidates are spent
        // to reach a given cost; sorting by the diagnosis engine's
        // per-group path blame front-loads the big wins (stable sort —
        // ties keep path-walk order, the pre-diagnosis behavior).
        if ctx.opts.use_blame_ranking {
            // decorate–sort–undecorate: comm_group_of_tensor is a linear
            // plan scan, so the key is computed once per candidate, not
            // O(n log n) times inside the comparator
            let blame_of = |d: &Decision| -> f64 {
                match *d {
                    Decision::OpFuse(a, _) => spec
                        .fusion
                        .group_of
                        .get(a as usize)
                        .and_then(|&fg| ctx.blame.comp_us.get(fg as usize))
                        .copied()
                        .unwrap_or(0.0),
                    Decision::TensorFuse(t, _) | Decision::Partition(t, _) => {
                        passes::comm_group_of_tensor(spec, t)
                            .and_then(|cg| ctx.blame.comm_us.get(cg))
                            .copied()
                            .unwrap_or(0.0)
                    }
                    _ => 0.0,
                }
            };
            let mut keyed: Vec<(f64, Decision)> =
                out.into_iter().map(|d| (blame_of(&d), d)).collect();
            keyed.sort_by(|x, y| y.0.total_cmp(&x.0));
            return keyed.into_iter().map(|(_, d)| d).collect();
        }
        out
    }

    fn apply(&mut self, mg: &mut MutableGraph, d: &Decision, ctx: &ApplyCtx) -> usize {
        apply_graph_decision(mg, d, ctx.sym, self.op_fusion, self.tensor_fusion)
    }
}

// ---------------------------------------------------------------------------
// Built-in strategy 2: the Graph-Pass Registry (paper §8)
// ---------------------------------------------------------------------------

/// Proposes every registered [`crate::optimizer::registry::GraphPass`] as a
/// [`Decision::WholeJob`] candidate, once — the replay-judged accept/reject
/// verdict settles it (a pass that loses is rolled back and not re-tried).
pub struct RegistryStrategy {
    registry: Registry,
    resolved: HashSet<String>,
}

impl RegistryStrategy {
    /// Wrap an explicit registry (custom passes included).
    pub fn new(registry: Registry) -> RegistryStrategy {
        RegistryStrategy { registry, resolved: HashSet::new() }
    }

    /// The built-in pass set (mixed precision).
    pub fn default_passes() -> RegistryStrategy {
        RegistryStrategy::new(Registry::default())
    }
}

impl Strategy for RegistryStrategy {
    fn name(&self) -> &str {
        "registry"
    }

    fn candidates(&mut self, _ctx: &mut SearchCtx) -> Vec<Decision> {
        self.registry
            .names()
            .into_iter()
            .filter(|n| !self.resolved.contains(*n))
            .map(|n| Decision::WholeJob(n.to_string()))
            .collect()
    }

    fn apply(&mut self, mg: &mut MutableGraph, d: &Decision, _ctx: &ApplyCtx) -> usize {
        let Decision::WholeJob(name) = d else { return 0 };
        let Some(pass) = self.registry.get(name) else { return 0 };
        let Some(cand) = pass.apply(mg.spec()) else { return 0 };
        // in-loop passes are template-level: the rewritten model is swapped
        // onto the live graph; plan/fusion rewrites are not representable
        // as in-place edits and are ignored (see registry module docs)
        match mg.swap_model(cand.model) {
            Ok(()) => 1,
            Err(_) => 0,
        }
    }

    fn decided(&mut self, d: &Decision, _accepted: bool) {
        if let Decision::WholeJob(name) = d {
            self.resolved.insert(name.clone());
        }
    }
}

// ---------------------------------------------------------------------------
// Built-in strategy 3: memory passes (paper §5.2 step 1 / Table 4)
// ---------------------------------------------------------------------------

/// Proposes re-computation / gradient accumulation while the replayed peak
/// memory exceeds the budget (or once each when no budget is set — an
/// explicitly requested pass is then judged, loses on time, and the
/// rejection is recorded rather than silently skipped). Under the uniform
/// objective ([`better`]) a memory pass is accepted despite costing time,
/// because feasibility dominates — exactly the paper's OOM handling, but
/// judged inside the round loop by incremental replay instead of up-front
/// full builds.
pub struct MemoryStrategy {
    allowed: Vec<MemOpt>,
    tried: Vec<MemOpt>,
    applied: bool,
}

impl MemoryStrategy {
    /// Restrict to an explicit set of memory passes.
    pub fn new(allowed: Vec<MemOpt>) -> MemoryStrategy {
        MemoryStrategy { allowed, tried: Vec::new(), applied: false }
    }

    /// Both built-in memory passes (re-computation, grad accumulation).
    pub fn all() -> MemoryStrategy {
        MemoryStrategy::new(vec![MemOpt::Recomputation, MemOpt::GradAccum])
    }
}

impl Strategy for MemoryStrategy {
    fn name(&self) -> &str {
        "memory"
    }

    fn candidates(&mut self, ctx: &mut SearchCtx) -> Vec<Decision> {
        if self.applied {
            return Vec::new();
        }
        // with a budget, stay quiet while the current plan already fits;
        // *without* one, still propose each pass once and let replay judge
        // (an explicitly requested memory strategy must not silently
        // vanish — it loses on time and the rejection is recorded)
        if let Some(budget) = ctx.budget_bytes {
            if ctx.cur.mem_bytes <= budget {
                return Vec::new();
            }
        }
        self.allowed
            .iter()
            .filter(|m| !self.tried.contains(*m))
            .map(|&m| Decision::Memory(m))
            .collect()
    }

    fn apply(&mut self, mg: &mut MutableGraph, d: &Decision, _ctx: &ApplyCtx) -> usize {
        let Decision::Memory(m) = d else { return 0 };
        let new_model = match m {
            MemOpt::None => return 0,
            MemOpt::Recomputation => memopt::recompute_model(&mg.spec().model),
            MemOpt::GradAccum => {
                let name = mg.spec().model.name.clone();
                let bs = mg.spec().model.batch_size;
                match memopt::grad_accum_model(&name, bs) {
                    Some(m) => m,
                    None => return 0,
                }
            }
        };
        match mg.swap_model(new_model) {
            Ok(()) => 1,
            Err(_) => 0,
        }
    }

    fn evaluate(&self, d: &Decision, raw: CandidateEval, mg: &MutableGraph) -> CandidateEval {
        match d {
            // the second micro-batch re-runs pure compute; half-batch
            // kernels run below peak efficiency, and the accumulated
            // gradient buffer persists across micro-batches (mirrors
            // `memopt::evaluate`)
            Decision::Memory(MemOpt::GradAccum) => CandidateEval {
                time_us: raw.time_us * MICRO_BATCH_INEFFICIENCY
                    + raw.comp_us * MICRO_BATCH_INEFFICIENCY,
                mem_bytes: raw.mem_bytes + mg.spec().model.param_bytes(),
                comp_us: raw.comp_us,
            },
            _ => raw,
        }
    }

    fn decided(&mut self, d: &Decision, accepted: bool) {
        if let Decision::Memory(m) = d {
            self.tried.push(*m);
            if accepted {
                self.applied = true;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Strategy-set construction (SearchOpts / CLI `--strategies`)
// ---------------------------------------------------------------------------

/// Names accepted by [`parse_strategies`] / the CLI `--strategies` flag.
pub const STRATEGY_NAMES: [&str; 8] = [
    "op-fuse",
    "tensor-fuse",
    "partition",
    "critical-path",
    "mixed-precision",
    "recompute",
    "grad-accum",
    "memory",
];

/// Parse a comma-separated strategy list into a strategy set. The three
/// graph-level names collapse into one [`CriticalPathStrategy`] (one
/// path walk serves them all); `critical-path` enables all three;
/// `mixed-precision` adds the default registry; `recompute` / `grad-accum`
/// (or `memory` for both) add the memory passes.
pub fn parse_strategies(list: &str) -> Result<Vec<Box<dyn Strategy>>, String> {
    let (mut opf, mut tsf, mut part, mut mixed) = (false, false, false, false);
    let mut mem: Vec<MemOpt> = Vec::new();
    for raw in list.split(',') {
        let name = raw.trim();
        if name.is_empty() {
            continue;
        }
        match name {
            "op-fuse" => opf = true,
            "tensor-fuse" => tsf = true,
            "partition" => part = true,
            "critical-path" => {
                opf = true;
                tsf = true;
                part = true;
            }
            "mixed-precision" => mixed = true,
            "recompute" | "recomputation" => mem.push(MemOpt::Recomputation),
            "grad-accum" | "gradient-accumulation" => mem.push(MemOpt::GradAccum),
            "memory" => {
                mem.push(MemOpt::Recomputation);
                mem.push(MemOpt::GradAccum);
            }
            other => {
                return Err(format!(
                    "unknown strategy {other:?}; valid strategies: {}",
                    STRATEGY_NAMES.join(", ")
                ))
            }
        }
    }
    let mut out: Vec<Box<dyn Strategy>> = Vec::new();
    if opf || tsf || part {
        out.push(Box::new(CriticalPathStrategy {
            op_fusion: opf,
            tensor_fusion: tsf,
            partition: part,
        }));
    }
    if mixed {
        out.push(Box::new(RegistryStrategy::default_passes()));
    }
    if !mem.is_empty() {
        let mut uniq: Vec<MemOpt> = Vec::new();
        for m in mem {
            if !uniq.contains(&m) {
                uniq.push(m);
            }
        }
        out.push(Box::new(MemoryStrategy::new(uniq)));
    }
    if out.is_empty() {
        return Err(format!(
            "no strategies selected; valid strategies: {}",
            STRATEGY_NAMES.join(", ")
        ));
    }
    Ok(out)
}

/// The memory pass among a search's accepted decisions, if any (the last
/// one wins — an earlier one can only have been superseded).
pub fn accepted_mem_opt(accepted: &[Decision]) -> MemOpt {
    accepted
        .iter()
        .rev()
        .find_map(|d| match d {
            Decision::Memory(m) => Some(*m),
            _ => None,
        })
        .unwrap_or(MemOpt::None)
}

/// The strategy set [`crate::optimizer::optimize`] runs: from
/// [`SearchOpts::strategies`] when set (panics on an invalid name — the CLI
/// pre-validates with [`parse_strategies`]), else the critical-path walker
/// per the enable flags plus the memory passes whenever a budget is set.
pub fn strategies_from_opts(opts: &SearchOpts) -> Vec<Box<dyn Strategy>> {
    if let Some(list) = &opts.strategies {
        return parse_strategies(list).unwrap_or_else(|e| panic!("{e}"));
    }
    let mut out: Vec<Box<dyn Strategy>> = Vec::new();
    if opts.enable_op_fusion || opts.enable_tensor_fusion {
        out.push(Box::new(CriticalPathStrategy::from_opts(opts)));
    }
    if opts.memory_budget_bytes.is_some() {
        out.push(Box::new(MemoryStrategy::all()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Transport;

    #[test]
    fn decision_display_is_stable() {
        assert_eq!(Decision::OpFuse(3, 4).to_string(), "op-fuse(3,4)");
        assert_eq!(Decision::TensorFuse(0, 9).to_string(), "tensor-fuse(0,9)");
        assert_eq!(Decision::Partition(2, 8).to_string(), "partition(2,8)");
        assert_eq!(
            Decision::WholeJob("mixed_precision".into()).to_string(),
            "pass:mixed_precision"
        );
        assert_eq!(
            Decision::Memory(MemOpt::Recomputation).to_string(),
            "memory:Re-computation"
        );
    }

    #[test]
    fn better_is_time_only_without_budget() {
        let fast = CandidateEval { time_us: 1.0, mem_bytes: 9e9, comp_us: 0.0 };
        let slow = CandidateEval { time_us: 2.0, mem_bytes: 1e9, comp_us: 0.0 };
        assert!(better(&fast, &slow, None));
        assert!(!better(&slow, &fast, None));
        // equal time is not an improvement
        assert!(!better(&fast, &fast, None));
    }

    #[test]
    fn better_feasibility_dominates_with_budget() {
        let b = Some(4e9);
        let fit_slow = CandidateEval { time_us: 5.0, mem_bytes: 3e9, comp_us: 0.0 };
        let oom_fast = CandidateEval { time_us: 1.0, mem_bytes: 6e9, comp_us: 0.0 };
        let oom_smaller = CandidateEval { time_us: 1.5, mem_bytes: 5e9, comp_us: 0.0 };
        assert!(better(&fit_slow, &oom_fast, b), "feasible beats infeasible");
        assert!(!better(&oom_fast, &fit_slow, b));
        assert!(better(&oom_smaller, &oom_fast, b), "less memory is progress");
    }

    #[test]
    fn parse_strategies_rejects_unknown_names() {
        assert!(parse_strategies("op-fuse,tensor-fuse,mixed-precision,recompute").is_ok());
        let err = parse_strategies("op-fuse,warp-drive").unwrap_err();
        assert!(err.contains("warp-drive") && err.contains("mixed-precision"), "{err}");
        assert!(parse_strategies("").is_err());
    }

    #[test]
    fn parse_strategies_collapses_walker_names() {
        let s = parse_strategies("critical-path").unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].name(), "critical-path");
        let s = parse_strategies("memory,mixed-precision").unwrap();
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn registry_strategy_applies_and_reverts_mixed_precision() {
        use crate::replay::incremental::IncrementalReplayer;
        let spec = crate::config::JobSpec::standard("bert_base", "horovod", Transport::Rdma);
        let mut mg = MutableGraph::new(spec);
        let mut eng = IncrementalReplayer::new();
        let log = mg.commit();
        let base = eng.replay_incremental(&mg, &log).iteration_time;

        let mut reg = RegistryStrategy::default_passes();
        let d = Decision::WholeJob("mixed_precision".into());
        let txn = mg.begin();
        let n = reg.apply(&mut mg, &d, &ApplyCtx { sym: None });
        assert_eq!(n, 1);
        let log = mg.commit();
        let fp16 = eng.replay_incremental(&mg, &log).iteration_time;
        assert!(fp16 < base * 0.85, "base={base} fp16={fp16}");

        // reject it: rollback must restore the exact baseline schedule
        mg.rollback(txn);
        let log = mg.commit();
        let restored = eng.replay_incremental(&mg, &log).iteration_time;
        assert_eq!(restored, base, "rollback must be exact");
        assert_eq!(mg.validate(), Ok(()));
    }
}
