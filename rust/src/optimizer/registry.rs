//! Graph-Pass Registry (paper Fig. 3 + §8): the extension point through
//! which developers register custom whole-job rewrites; mixed-precision
//! training is the built-in example the paper mentions.
//!
//! Registered passes participate in the search's round loop through
//! [`crate::optimizer::strategy::RegistryStrategy`], which proposes each
//! pass as a [`crate::optimizer::strategy::Decision::WholeJob`] candidate:
//! the rewrite is applied as an in-place template swap on the long-lived
//! [`crate::graph::MutableGraph`], judged by incremental replay, and kept
//! or rolled back — no global-DFG construction either way. For that
//! in-loop path a pass must be **template-level**: it may rewrite
//! `spec.model` (op costs, precisions, tensor bytes) but must keep the op
//! and tensor counts, and its plan/fusion/cluster changes are ignored.
//! [`Registry::best_improvement`] remains as the standalone
//! build-and-replay evaluator for passes that do rewrite plans.

use crate::config::JobSpec;
use crate::graph::{build_global, AnalyticCost};
use crate::models::cost::Precision;
use crate::replay::replay_once;
use crate::util::Us;

/// A whole-job rewrite whose benefit is judged by replay.
pub trait GraphPass {
    /// Unique registry name (the `--strategies` / lookup key).
    fn name(&self) -> &str;
    /// Rewrite the spec (returning a candidate); `None` = not applicable.
    fn apply(&self, spec: &JobSpec) -> Option<JobSpec>;
}

/// Built-in custom pass: flip compute-bound GEMM/conv ops to fp16
/// (Micikevicius et al. 2018). Gradients shrink to half size as well.
pub struct MixedPrecisionPass;

impl GraphPass for MixedPrecisionPass {
    fn name(&self) -> &str {
        "mixed_precision"
    }

    fn apply(&self, spec: &JobSpec) -> Option<JobSpec> {
        let mut s = spec.clone();
        let mut flipped = 0;
        for op in &mut s.model.ops {
            // only compute-bound ops benefit from tensor cores
            if op.flops > 0.0 {
                op.precision = Precision::Fp16;
                flipped += 1;
            }
        }
        // fp16 gradients: half the synchronization volume
        for t in &mut s.model.tensors {
            t.bytes *= 0.5;
        }
        (flipped > 0).then_some(s)
    }
}

/// The registry: evaluate every pass by replay, keep improvements.
pub struct Registry {
    passes: Vec<Box<dyn GraphPass>>,
}

impl Default for Registry {
    fn default() -> Self {
        Registry { passes: vec![Box::new(MixedPrecisionPass)] }
    }
}

impl Registry {
    /// A registry with no passes (add via [`Registry::register`]).
    pub fn empty() -> Registry {
        Registry { passes: Vec::new() }
    }

    /// Register a custom pass (the §8 extension point).
    pub fn register(&mut self, pass: Box<dyn GraphPass>) {
        self.passes.push(pass);
    }

    /// Names of all registered passes, in registration order.
    pub fn names(&self) -> Vec<&str> {
        self.passes.iter().map(|p| p.name()).collect()
    }

    /// Pass registered under `name`, if any. The strategy layer
    /// ([`crate::optimizer::strategy::RegistryStrategy`]) resolves
    /// [`crate::optimizer::strategy::Decision::WholeJob`] decisions through
    /// this lookup when applying them inside the search's round loop.
    pub fn get(&self, name: &str) -> Option<&dyn GraphPass> {
        self.passes.iter().find(|p| p.name() == name).map(|b| b.as_ref())
    }

    /// Try every registered pass; return the best (name, spec, est) that
    /// beats `baseline_us`, if any.
    pub fn best_improvement(
        &self,
        spec: &JobSpec,
        baseline_us: Us,
    ) -> Option<(String, JobSpec, Us)> {
        let mut best: Option<(String, JobSpec, Us)> = None;
        for p in &self.passes {
            if let Some(cand) = p.apply(spec) {
                let g = build_global(&cand, &AnalyticCost::new(&cand));
                let est = replay_once(&g).iteration_time;
                if est < baseline_us && best.as_ref().map(|(_, _, b)| est < *b).unwrap_or(true) {
                    best = Some((p.name().to_string(), cand, est));
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Transport;

    #[test]
    fn mixed_precision_speeds_up_compute_bound_model() {
        let spec = JobSpec::standard("bert_base", "horovod", Transport::Rdma);
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        let base = replay_once(&g).iteration_time;
        let reg = Registry::default();
        let (name, cand, est) = reg.best_improvement(&spec, base).expect("should improve");
        assert_eq!(name, "mixed_precision");
        assert!(est < base * 0.8, "base={base} est={est}");
        // gradient volume halved
        assert!(cand.model.param_bytes() < spec.model.param_bytes() * 0.6);
    }

    #[test]
    fn custom_pass_registration() {
        struct Noop;
        impl GraphPass for Noop {
            fn name(&self) -> &str {
                "noop"
            }
            fn apply(&self, _: &JobSpec) -> Option<JobSpec> {
                None
            }
        }
        let mut reg = Registry::empty();
        reg.register(Box::new(Noop));
        assert_eq!(reg.names(), vec!["noop"]);
        let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
        assert!(reg.best_improvement(&spec, 1.0).is_none());
    }
}
