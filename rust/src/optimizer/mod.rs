//! The dPRO optimizer (paper §5 + §8): one **Strategy API** through which
//! every optimization strategy — the critical-path search of Alg. 1, the
//! Graph-Pass Registry's whole-job rewrites, and the memory passes — plugs
//! into the same transactional, incrementally-replayed accept/reject loop.
//!
//! The architecture mirrors the comm-plan IR one layer up: just as every
//! communication scheme lowers to one [`crate::graph::comm_plan`] IR,
//! every optimization strategy proposes one [`strategy::Decision`] IR,
//! applied through [`crate::graph::MutableGraph`] transactions and judged
//! by [`crate::replay::incremental`] — so a new strategy gets the
//! incremental engine, rollback, and the joint search for free:
//!
//! ```text
//!   Strategy::candidates(&SearchCtx)      ← per-strategy logic
//!                  │
//!             Vec<Decision>   (the decision IR: OpFuse / TensorFuse /
//!                  │           Partition / WholeJob / Memory)
//!   MutableGraph::begin → Strategy::apply → commit → incremental replay
//!                  │
//!     better(candidate, current)?  → commit_txn  (keep)
//!                                  → rollback    (inverse-edit journal:
//!                                    no rebuild, no spec re-clone)
//! ```
//!
//! - [`strategy`] — the Strategy API: decision IR, [`strategy::Strategy`]
//!   trait, the three built-ins (critical path / registry / memory), and
//!   strategy-set parsing (`--strategies`)
//! - [`search`] — the strategy-agnostic round loop of Alg. 1 with the
//!   three Table 5 accelerations
//! - [`passes`] — op fusion / tensor fusion / tensor partition plan
//!   rewrites (the plan-level source of truth)
//! - [`theorems`] — the fusion-profitability predicates of Theorems 1–3
//! - [`coarsen`] — Coarsened View construction (§5.3)
//! - [`symmetry`] — block-analogy propagation (§5.3)
//! - [`memopt`] — re-computation / gradient-accumulation passes (Table 4),
//!   searched in-loop through [`strategy::MemoryStrategy`]
//! - [`registry`] — the Graph-Pass Registry (§8), searched in-loop through
//!   [`strategy::RegistryStrategy`]

pub mod coarsen;
pub mod memopt;
pub mod passes;
pub mod registry;
pub mod search;
pub mod strategy;
pub mod symmetry;
pub mod theorems;

pub use search::{optimize, optimize_with, SearchOpts, SearchOutcome};
pub use strategy::{Decision, Strategy};
