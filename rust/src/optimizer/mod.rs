//! The dPRO optimizer (paper §5): a Graph-Pass Registry plus the
//! critical-path search of Alg. 1.
//!
//! - [`passes`] — op fusion / tensor fusion / tensor partition rewrites
//! - [`theorems`] — the fusion-profitability predicates of Theorems 1–3
//! - [`coarsen`] — Coarsened View construction (§5.3)
//! - [`symmetry`] — block-analogy propagation (§5.3)
//! - [`memopt`] — re-computation / gradient-accumulation passes (Table 4)
//! - [`search`] — Alg. 1 with the three search accelerations
//! - [`registry`] — the extension point for custom strategies (§8), with
//!   mixed-precision as the built-in example

pub mod coarsen;
pub mod memopt;
pub mod passes;
pub mod registry;
pub mod search;
pub mod symmetry;
pub mod theorems;

pub use search::{optimize, SearchOpts, SearchOutcome};
