//! The profitability predicates of paper §5.1 as standalone functions —
//! exactly the inequalities of Theorems 1 and 2 (Theorem 3 is structural:
//! fusing companions never hurts, and is applied by the search directly).

use crate::util::Us;

/// **Theorem 1 (Op Fusion).** Fusing computation ops `p_{n-1}` and `p_n`
/// improves `T_n` iff the previous tensor's synchronization hides inside
/// the fused kernel's saving:
/// `q_{n-1}^d ≤ p_{n-1}^d + p_n^d − opfs_time(p_{n-1}, p_n)`.
pub fn op_fusion_profitable(q_prev_sync: Us, p_prev: Us, p_cur: Us, fused: Us) -> bool {
    q_prev_sync <= p_prev + p_cur - fused
}

/// **Theorem 2 (Tensor Fusion/Partition).** Fusing tensors `q_{n-1}` and
/// `q_n` improves `T_n` iff
/// `q_{n-1}^e > p_n^e + t_sync(s_{n-1}+s_n, k*) − t_sync(s_n, k*[s_n])`.
pub fn tensor_fusion_profitable(
    q_prev_end: Us,
    p_cur_end: Us,
    t_sync_fused_opt: Us,
    t_sync_cur_opt: Us,
) -> bool {
    q_prev_end > p_cur_end + t_sync_fused_opt - t_sync_cur_opt
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem1_boundary() {
        // saving = 10+10-15 = 5; sync of 4 hides, sync of 6 does not
        assert!(op_fusion_profitable(4.0, 10.0, 10.0, 15.0));
        assert!(op_fusion_profitable(5.0, 10.0, 10.0, 15.0));
        assert!(!op_fusion_profitable(6.0, 10.0, 10.0, 15.0));
    }

    #[test]
    fn theorem1_fusion_never_profitable_when_kernel_grows() {
        // a "fused" kernel slower than its parts can never win
        assert!(!op_fusion_profitable(1.0, 10.0, 10.0, 25.0));
    }

    #[test]
    fn theorem2_boundary() {
        // prev sync ends at 100; cur producer ends at 60; fusing costs
        // 50 vs 20 ⇒ threshold 60 + 30 = 90 < 100 ⇒ fuse
        assert!(tensor_fusion_profitable(100.0, 60.0, 50.0, 20.0));
        // if prev sync already ended early (80 < 90), don't fuse
        assert!(!tensor_fusion_profitable(80.0, 60.0, 50.0, 20.0));
    }
}
