//! Symmetry acceleration (paper §5.3): state-of-the-art DNNs repeat
//! identical blocks (BERT's 12 transformer blocks, ResNet's stages), so a
//! fusion decision found on the critical path inside one block can be
//! propagated to every analogous position without re-searching.
//!
//! Analogy is structural: op names are `<block>_<role>` (e.g.
//! `blk03_ff1`, `s2b1_conv1`); two ops are analogous if they share the
//! role and differ only in block.

use std::collections::HashMap;

use crate::models::ModelGraph;

/// Split a template op name into (kind prefix, block, role).
/// `BW.blk03_ff1` → ("BW", "blk03", "ff1"). Returns None for unblocked
/// names (no '_' separator).
fn split_name(name: &str) -> Option<(&str, &str, &str)> {
    let (kind, rest) = name.split_once('.')?;
    let (block, role) = rest.split_once('_')?;
    Some((kind, block, role))
}

/// Index of (kind, block, role) → op id, plus the set of blocks.
pub struct SymmetryIndex {
    by_key: HashMap<(String, String, String), u32>,
    /// op id → (kind, block, role)
    parts: Vec<Option<(String, String, String)>>,
    blocks: Vec<String>,
}

impl SymmetryIndex {
    /// Index a model template's op names into (kind, block, role) parts.
    pub fn new(model: &ModelGraph) -> SymmetryIndex {
        let mut by_key = HashMap::new();
        let mut parts = Vec::with_capacity(model.ops.len());
        let mut blocks: Vec<String> = Vec::new();
        for (i, op) in model.ops.iter().enumerate() {
            match split_name(&op.name) {
                Some((k, b, r)) => {
                    let key = (k.to_string(), b.to_string(), r.to_string());
                    by_key.insert(key.clone(), i as u32);
                    if !blocks.contains(&key.1) {
                        blocks.push(key.1.clone());
                    }
                    parts.push(Some(key));
                }
                None => parts.push(None),
            }
        }
        SymmetryIndex { by_key, parts, blocks }
    }

    /// Number of distinct symmetric blocks the model decomposes into.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// All analogous op pairs of (a, b) in *other* blocks. Only meaningful
    /// when a and b live in the same block.
    pub fn analog_pairs(&self, a: u32, b: u32) -> Vec<(u32, u32)> {
        let (Some(pa), Some(pb)) = (&self.parts[a as usize], &self.parts[b as usize]) else {
            return Vec::new();
        };
        if pa.1 != pb.1 {
            return Vec::new(); // different blocks: no analogy to exploit
        }
        let mut out = Vec::new();
        for blk in &self.blocks {
            if *blk == pa.1 {
                continue;
            }
            let ka = (pa.0.clone(), blk.clone(), pa.2.clone());
            let kb = (pb.0.clone(), blk.clone(), pb.2.clone());
            if let (Some(&x), Some(&y)) = (self.by_key.get(&ka), self.by_key.get(&kb)) {
                out.push((x, y));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn bert_blocks_are_analogous() {
        let m = models::by_name("bert_base", 8).unwrap();
        let idx = SymmetryIndex::new(&m);
        assert!(idx.n_blocks() >= 12);
        // find FW.blk00_ff1 and FW.blk00_gelu
        let a = m.ops.iter().position(|o| o.name == "FW.blk00_ff1").unwrap() as u32;
        let b = m.ops.iter().position(|o| o.name == "FW.blk00_gelu").unwrap() as u32;
        let pairs = idx.analog_pairs(a, b);
        assert_eq!(pairs.len(), 11, "one pair per other block");
        for (x, y) in pairs {
            assert!(m.ops[x as usize].name.ends_with("_ff1"));
            assert!(m.ops[y as usize].name.ends_with("_gelu"));
            assert_ne!(x, a);
            assert_ne!(y, b);
        }
    }

    #[test]
    fn cross_block_pairs_have_no_analogs() {
        let m = models::by_name("bert_base", 8).unwrap();
        let idx = SymmetryIndex::new(&m);
        let a = m.ops.iter().position(|o| o.name == "FW.blk00_ff1").unwrap() as u32;
        let b = m.ops.iter().position(|o| o.name == "FW.blk01_ff1").unwrap() as u32;
        assert!(idx.analog_pairs(a, b).is_empty());
    }

    #[test]
    fn resnet_stage_blocks_indexed() {
        let m = models::by_name("resnet50", 8).unwrap();
        let idx = SymmetryIndex::new(&m);
        // s1b1..s4b3 = 16 blocks (+ stem etc.)
        assert!(idx.n_blocks() >= 16);
    }
}
