//! Graph passes (paper §5.2): rewrites of a [`JobSpec`]'s fusion plan
//! (op fusion), comm plan (tensor fusion / tensor partition), and template
//! (memory passes live in [`super::memopt`]). Passes never mutate the model
//! template itself — op fusion is a partition over template ops, tensor
//! fusion a partition over tensors — so every rewrite is cheap and
//! reversible by cloning the spec.
//!
//! These functions are the *plan-level* source of truth (validity rules,
//! index bookkeeping). The search's hot path applies them through
//! [`crate::graph::mutable::MutableGraph`], which mirrors each pass as an
//! in-place edit of the already-built global DFG so no round ever
//! reconstructs the graph from the spec.

use crate::config::JobSpec;
use crate::graph::dfg::TensorId;

/// Error type for invalid pass applications.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PassError {
    /// Merging the groups would sandwich a third group between them,
    /// creating a dependency cycle.
    WouldCreateCycle,
    /// The groups hold ops of different kinds (e.g. forward and backward).
    KindMismatch,
    /// Both indices name the same group.
    SameGroup,
    /// A group index is out of range.
    OutOfRange,
}

impl std::fmt::Display for PassError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

/// Does a path exist from fusion group `from` to fusion group `to` that
/// passes through at least one intermediate group? (A direct edge is fine
/// to contract; an indirect path would make the merged group cyclic.)
fn indirect_path(spec: &JobSpec, from: usize, to: usize) -> bool {
    let fusion = &spec.fusion;
    let model = &spec.model;
    // group-level successor lists
    let n = fusion.groups.len();
    let mut succs: Vec<Vec<u32>> = vec![Vec::new(); n];
    for (gi, members) in fusion.groups.iter().enumerate() {
        for &m in members {
            for &d in &model.ops[m as usize].deps {
                let dg = fusion.group_of[d as usize] as usize;
                if dg != gi && !succs[dg].contains(&(gi as u32)) {
                    succs[dg].push(gi as u32);
                }
            }
        }
    }
    // BFS from `from`'s successors except the direct edge to `to`
    let mut seen = vec![false; n];
    let mut queue: Vec<u32> = succs[from].iter().copied().filter(|&s| s as usize != to).collect();
    while let Some(g) = queue.pop() {
        let gi = g as usize;
        if seen[gi] {
            continue;
        }
        seen[gi] = true;
        if gi == to {
            return true;
        }
        for &s in &succs[gi] {
            if !seen[s as usize] {
                queue.push(s);
            }
        }
    }
    false
}

/// **Op fusion pass**: merge fusion groups `a` and `b` into one kernel.
/// Valid only for same-kind groups with no indirect dependency path
/// between them.
pub fn fuse_comp_groups(spec: &mut JobSpec, a: usize, b: usize) -> Result<usize, PassError> {
    let n = spec.fusion.groups.len();
    if a >= n || b >= n {
        return Err(PassError::OutOfRange);
    }
    if a == b {
        return Err(PassError::SameGroup);
    }
    let ka = spec.model.ops[spec.fusion.groups[a][0] as usize].kind;
    let kb = spec.model.ops[spec.fusion.groups[b][0] as usize].kind;
    if ka != kb {
        return Err(PassError::KindMismatch);
    }
    if indirect_path(spec, a, b) || indirect_path(spec, b, a) {
        return Err(PassError::WouldCreateCycle);
    }
    let (keep, drop) = if a < b { (a, b) } else { (b, a) };
    let dropped = spec.fusion.groups.remove(drop);
    spec.fusion.groups[keep].extend(dropped);
    spec.fusion.groups[keep].sort_unstable();
    spec.fusion.rebuild_index(spec.model.ops.len());
    Ok(keep)
}

/// **Tensor fusion pass**: merge comm groups `a` and `b` into one
/// synchronization unit (partitions reset to the max of the two).
pub fn fuse_tensor_groups(spec: &mut JobSpec, a: usize, b: usize) -> Result<usize, PassError> {
    let n = spec.plan.groups.len();
    if a >= n || b >= n {
        return Err(PassError::OutOfRange);
    }
    if a == b {
        return Err(PassError::SameGroup);
    }
    let (keep, drop) = if a < b { (a, b) } else { (b, a) };
    let dropped = spec.plan.groups.remove(drop);
    let kept = &mut spec.plan.groups[keep];
    kept.partitions = kept.partitions.max(dropped.partitions);
    kept.tensors.extend(dropped.tensors);
    kept.tensors.sort_unstable();
    Ok(keep)
}

/// **Tensor partition pass**: slice comm group `g` into `k` pieces.
pub fn set_partitions(spec: &mut JobSpec, g: usize, k: usize) -> Result<(), PassError> {
    if g >= spec.plan.groups.len() {
        return Err(PassError::OutOfRange);
    }
    spec.plan.groups[g].partitions = k.max(1);
    Ok(())
}

/// Comm group that synchronizes tensor `t`.
pub fn comm_group_of_tensor(spec: &JobSpec, t: TensorId) -> Option<usize> {
    spec.plan.groups.iter().position(|g| g.tensors.contains(&t))
}

/// Comm groups fed by fusion group `fg` (tensors produced by its members).
pub fn comm_groups_of_fusion_group(spec: &JobSpec, fg: usize) -> Vec<usize> {
    let mut out = Vec::new();
    for &m in &spec.fusion.groups[fg] {
        for &t in &spec.model.ops[m as usize].produces {
            if let Some(cg) = comm_group_of_tensor(spec, t) {
                if !out.contains(&cg) {
                    out.push(cg);
                }
            }
        }
    }
    out
}

/// Fusion group that produces the tensors of comm group `cg` (the op the
/// paper calls `p_n` for a communication op `q_n`). Returns the *latest*
/// producer group if the comm group spans several.
pub fn producer_fusion_group(spec: &JobSpec, cg: usize) -> Option<usize> {
    spec.plan.groups[cg]
        .tensors
        .iter()
        .filter_map(|&t| spec.model.producer_of(t))
        .map(|op| spec.fusion.group_of[op as usize] as usize)
        .max()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobSpec, Transport};

    fn spec() -> JobSpec {
        JobSpec::standard("vgg16", "horovod", Transport::Rdma)
    }

    #[test]
    fn fuse_adjacent_comp_ops() {
        let mut s = spec();
        // conv1_1 (op 0) and its relu (op 1) are directly dependent
        let keep = fuse_comp_groups(&mut s, 0, 1).unwrap();
        assert_eq!(s.fusion.groups[keep], vec![0, 1]);
        assert_eq!(s.fusion.validate(&s.model), Ok(()));
        // the fused kernel is faster than the sum of its parts
        let gpu = &s.cluster.gpu;
        let fused = s.fusion.duration(&s.model, gpu, keep);
        let sum = s.model.ops[0].duration(gpu) + s.model.ops[1].duration(gpu);
        assert!(fused < sum);
    }

    #[test]
    fn fusion_rejects_kind_mismatch() {
        let mut s = spec();
        let n_fw = s.model.fw_ids().len();
        // fusing a forward op with a backward op is invalid
        let err = fuse_comp_groups(&mut s, 0, n_fw).unwrap_err();
        assert_eq!(err, PassError::KindMismatch);
    }

    #[test]
    fn fusion_rejects_indirect_path() {
        let mut s = spec();
        // op 0 -> op 1 -> op 2: fusing 0 and 2 would sandwich op 1
        let err = fuse_comp_groups(&mut s, 0, 2).unwrap_err();
        assert_eq!(err, PassError::WouldCreateCycle);
    }

    #[test]
    fn chained_fusion_is_allowed() {
        let mut s = spec();
        let g = fuse_comp_groups(&mut s, 0, 1).unwrap();
        // now group {0,1} is directly before op 2's group — fusable
        let g2_group = s.fusion.group_of[2] as usize;
        let kept = fuse_comp_groups(&mut s, g, g2_group).unwrap();
        assert_eq!(s.fusion.groups[kept], vec![0, 1, 2]);
        assert_eq!(s.fusion.validate(&s.model), Ok(()));
    }

    #[test]
    fn tensor_fusion_merges_groups() {
        let mut s = spec();
        let n0 = s.plan.groups.len();
        let keep = fuse_tensor_groups(&mut s, 0, 1).unwrap();
        assert_eq!(s.plan.groups.len(), n0 - 1);
        assert_eq!(s.plan.groups[keep].tensors, vec![0, 1]);
        assert_eq!(s.plan.validate(&s.model), Ok(()));
    }

    #[test]
    fn partition_pass() {
        let mut s = spec();
        set_partitions(&mut s, 0, 8).unwrap();
        assert_eq!(s.plan.groups[0].partitions, 8);
        set_partitions(&mut s, 0, 0).unwrap();
        assert_eq!(s.plan.groups[0].partitions, 1);
        assert!(set_partitions(&mut s, 10_000, 2).is_err());
    }

    #[test]
    fn producer_lookups_consistent() {
        let s = spec();
        // tensor 0 (conv1_1.weight) produced by BW.conv1_1, the last op
        let cg = comm_group_of_tensor(&s, 0).unwrap();
        let fg = producer_fusion_group(&s, cg).unwrap();
        let member = s.fusion.groups[fg][0] as usize;
        assert!(s.model.ops[member].produces.contains(&0));
        let cgs = comm_groups_of_fusion_group(&s, fg);
        assert!(cgs.contains(&cg));
    }
}
