//! Memory-optimization passes (paper §5.2 step 1 + Table 4): when the
//! estimated peak memory exceeds the budget, evaluate **re-computation**
//! (Chen et al. 2016) and **gradient accumulation**, pick whichever fits
//! the budget with the smaller iteration time.

use crate::config::JobSpec;
use crate::graph::{build_global, AnalyticCost};
use crate::models::ModelGraph;
use crate::replay::{estimate_peak_memory, replay_once};
use crate::util::Us;

/// Per-sample efficiency loss of half-size micro-batches (V100 GEMMs lose
/// 15–25% at half batch; our roofline is otherwise linear in batch).
pub const MICRO_BATCH_INEFFICIENCY: f64 = 1.18;

/// The memory-optimization strategies of Table 4.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemOpt {
    /// No memory pass applied.
    None,
    /// √L-checkpoint re-computation (drop activations, re-forward before
    /// the backward op).
    Recomputation,
    /// Gradient accumulation over two half-size micro-batches.
    GradAccum,
}

impl MemOpt {
    /// Display name used in reports (matches Table 4's row labels).
    pub fn name(self) -> &'static str {
        match self {
            MemOpt::None => "w/o optimization",
            MemOpt::Recomputation => "Re-computation",
            MemOpt::GradAccum => "Gradient Accumulation",
        }
    }
}

/// Estimated (time, memory) of a memory strategy, via the replayer.
#[derive(Clone, Copy, Debug)]
pub struct MemEval {
    /// Estimated iteration time (us).
    pub time_us: Us,
    /// Estimated peak memory per worker (bytes).
    pub mem_bytes: f64,
}

/// Apply √L-checkpoint re-computation to a model template: activations of
/// non-checkpoint forward ops are dropped after use (not held for the
/// backward pass) and re-computed before their mirrored backward op, which
/// inherits the forward op's cost on top of its own (Fig. 2b).
pub fn recompute_model(model: &ModelGraph) -> ModelGraph {
    let mut m = model.clone();
    let fw: Vec<u32> = m.fw_ids();
    let stride = (fw.len() as f64).sqrt().ceil() as usize;
    for (pos, &f) in fw.iter().enumerate() {
        let is_checkpoint = pos % stride == 0;
        if is_checkpoint {
            continue;
        }
        let (extra_flops, extra_bytes) = {
            let op = &m.ops[f as usize];
            (op.flops, op.bytes)
        };
        m.ops[f as usize].activation_bytes = 0.0;
        if let Some(b) = m.ops[f as usize].mirror {
            // re-forward inserted before the backward op; the segment
            // re-runs in one fused sweep with warm caches/cudnn algos, so
            // the amortized extra cost is well below a cold forward
            const REFW_COST: f64 = 0.25;
            m.ops[b as usize].flops += REFW_COST * extra_flops;
            m.ops[b as usize].bytes += REFW_COST * extra_bytes;
        }
    }
    m
}

/// Model for one micro-batch of gradient accumulation (half batch size).
pub fn grad_accum_model(model_name: &str, batch_size: usize) -> Option<ModelGraph> {
    crate::models::by_name(model_name, (batch_size / 2).max(1))
}

/// Spec with a memory optimization applied (re-computation rewrites the
/// template; gradient accumulation halves the per-micro-batch model).
pub fn apply(spec: &JobSpec, opt: MemOpt) -> JobSpec {
    let mut s = spec.clone();
    match opt {
        MemOpt::None => {}
        MemOpt::Recomputation => {
            s.model = recompute_model(&s.model);
        }
        MemOpt::GradAccum => {
            if let Some(m) = grad_accum_model(&s.model.name.clone(), s.model.batch_size) {
                s.model = m;
                s.plan = crate::config::CommPlan::per_tensor(&s.model);
                s.fusion = crate::config::FusionPlan::singletons(&s.model);
            }
        }
    }
    s
}

/// Replayer estimate of (iteration time, peak memory) under a strategy.
/// Gradient accumulation synchronizes once per *effective* batch: the
/// first micro-batch contributes only compute.
pub fn evaluate(spec: &JobSpec, opt: MemOpt) -> MemEval {
    let s = apply(spec, opt);
    let g = build_global(&s, &AnalyticCost::new(&s));
    let r = replay_once(&g);
    let mem = estimate_peak_memory(&s, &g, &r);
    match opt {
        MemOpt::GradAccum => {
            // the second micro-batch adds pure compute; half-batch kernels
            // run below peak efficiency on real GPUs (sub-linear scaling)
            let comp: Us = r.kind_time(&g, 0, crate::graph::OpKind::Forward)
                + r.kind_time(&g, 0, crate::graph::OpKind::Backward);
            MemEval {
                time_us: r.iteration_time * MICRO_BATCH_INEFFICIENCY
                    + comp * MICRO_BATCH_INEFFICIENCY,
                // accumulated gradient buffer persists across micro-batches
                mem_bytes: mem + s.model.param_bytes(),
            }
        }
        _ => MemEval { time_us: r.iteration_time, mem_bytes: mem },
    }
}

/// Ground-truth (testbed) measurement of the same strategy, for Table 4's
/// "Real" columns.
pub fn ground_truth(spec: &JobSpec, opt: MemOpt) -> MemEval {
    let s = apply(spec, opt);
    let tb = crate::testbed::run(&s, &crate::testbed::TestbedOpts { iterations: 5, ..Default::default() });
    match opt {
        MemOpt::GradAccum => MemEval {
            time_us: (tb.avg_iter() + tb.fw_time + tb.bw_time) * MICRO_BATCH_INEFFICIENCY,
            mem_bytes: tb.peak_memory + s.model.param_bytes() * crate::testbed::memory::FRAGMENTATION,
        },
        _ => MemEval { time_us: tb.avg_iter(), mem_bytes: tb.peak_memory },
    }
}

/// Paper's OOM handling (Alg. 1 line 1): pick the strategy with the
/// smallest estimated time whose memory fits the budget.
pub fn choose(spec: &JobSpec, budget_bytes: f64) -> (MemOpt, MemEval) {
    let none = evaluate(spec, MemOpt::None);
    if none.mem_bytes <= budget_bytes {
        return (MemOpt::None, none);
    }
    let candidates = [MemOpt::Recomputation, MemOpt::GradAccum];
    let mut best: Option<(MemOpt, MemEval)> = None;
    for opt in candidates {
        let e = evaluate(spec, opt);
        if e.mem_bytes <= budget_bytes
            && best.map(|(_, b)| e.time_us < b.time_us).unwrap_or(true)
        {
            best = Some((opt, e));
        }
    }
    best.unwrap_or((MemOpt::None, none))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobSpec, Transport};

    fn bert64() -> JobSpec {
        let mut s = JobSpec::standard("bert_base", "horovod", Transport::Rdma);
        s.model = crate::models::bert::bert_base(64, 128);
        s.plan = crate::config::CommPlan::per_tensor(&s.model);
        s.fusion = crate::config::FusionPlan::singletons(&s.model);
        s.cluster.gpu = crate::models::cost::GpuModel::v100_16gb();
        s
    }

    #[test]
    fn recomputation_cuts_memory_costs_time() {
        let spec = bert64();
        let none = evaluate(&spec, MemOpt::None);
        let rec = evaluate(&spec, MemOpt::Recomputation);
        assert!(rec.mem_bytes < none.mem_bytes * 0.75, "none={:.2}GB rec={:.2}GB",
                none.mem_bytes / 1e9, rec.mem_bytes / 1e9);
        assert!(rec.time_us > none.time_us, "recomputation must cost time");
    }

    #[test]
    fn grad_accum_cuts_memory_costs_time() {
        let spec = bert64();
        let none = evaluate(&spec, MemOpt::None);
        let ga = evaluate(&spec, MemOpt::GradAccum);
        assert!(ga.mem_bytes < none.mem_bytes, "none={:.2}GB ga={:.2}GB",
                none.mem_bytes / 1e9, ga.mem_bytes / 1e9);
        assert!(ga.time_us > none.time_us);
    }

    #[test]
    fn chooser_respects_budget() {
        let spec = bert64();
        let none = evaluate(&spec, MemOpt::None);
        // budget below the unoptimized peak forces a memory pass
        let budget = none.mem_bytes * 0.8;
        let (opt, eval) = choose(&spec, budget);
        assert_ne!(opt, MemOpt::None);
        assert!(eval.mem_bytes <= budget, "chosen {:?} exceeds budget", opt);
        // generous budget keeps the unoptimized plan
        let (opt2, _) = choose(&spec, none.mem_bytes * 2.0);
        assert_eq!(opt2, MemOpt::None);
    }

    #[test]
    fn estimates_track_ground_truth() {
        let spec = bert64();
        for opt in [MemOpt::None, MemOpt::Recomputation, MemOpt::GradAccum] {
            let est = evaluate(&spec, opt);
            let real = ground_truth(&spec, opt);
            let terr = crate::util::stats::rel_err_pct(est.time_us, real.time_us);
            let merr = crate::util::stats::rel_err_pct(est.mem_bytes, real.mem_bytes);
            assert!(terr < 12.0, "{:?} time err {terr:.1}%", opt);
            assert!(merr < 12.0, "{:?} mem err {merr:.1}%", opt);
        }
    }
}
