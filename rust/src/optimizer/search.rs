//! The diagnosis & optimization search (paper Alg. 1): iteratively replay,
//! let every registered [`Strategy`] propose decisions from the replayed
//! critical path, and keep each candidate only if an incremental replay
//! judges it an improvement — until the estimate converges or the budget
//! runs out.
//!
//! The loop is **strategy-agnostic**: all candidate generation goes through
//! the [`Strategy`] trait ([`crate::optimizer::strategy`]), and every
//! candidate — fusion, partition, registry pass, memory pass alike — is
//! applied inside a [`MutableGraph`] transaction, replayed incrementally,
//! and committed or rolled back. The loop holds **one long-lived**
//! [`MutableGraph`] + [`IncrementalReplayer`] across all rounds; after
//! setup, a search performs **zero** global-DFG constructions (tracked by
//! [`crate::graph::build_count`] and pinned by tests) — the Table 5
//! speedups come precisely from decoupling per-candidate simulation cost
//! from graph-construction cost.

use std::time::Instant;

use crate::config::JobSpec;
use crate::graph::{plan_props, MutableGraph};
use crate::optimizer::memopt::MemOpt;
use crate::optimizer::strategy::{
    self, ApplyCtx, CandidateEval, Decision, SearchCtx, Strategy, Tsync,
};
use crate::optimizer::{coarsen, symmetry::SymmetryIndex};
use crate::replay::incremental::IncrementalReplayer;
use crate::util::json::Json;
use crate::util::Us;

/// Search configuration; the three `use_*` flags are the paper's Table 5
/// ablation axes.
#[derive(Clone, Debug)]
pub struct SearchOpts {
    /// Shrink the strategy space up front with Theorem 3's always-safe
    /// fusions (§5.3).
    pub use_coarsened_view: bool,
    /// Answer `t_sync` queries with pre-built probe engines instead of
    /// full builds (§5.1).
    pub use_partial_replay: bool,
    /// Propagate accepted decisions across symmetric blocks (§5.4).
    pub use_symmetry: bool,
    /// Order every round's candidates by critical-path blame
    /// ([`crate::diagnosis::critical::group_blame`]) so strategies try
    /// high-blame targets first — measurably fewer candidates to reach
    /// the same cost (pinned by `rust/tests/diagnosis.rs`). Off preserves
    /// plain path-walk order.
    pub use_blame_ranking: bool,
    /// Let the critical-path walker propose op-fusion decisions.
    pub enable_op_fusion: bool,
    /// Let the critical-path walker propose tensor-fusion decisions.
    pub enable_tensor_fusion: bool,
    /// Tensor partition (paper: most valuable under PS). `None` = auto —
    /// on when the scheme's lowered plan routes through servers (its
    /// per-partition chains pipeline push against pull), off for
    /// collective schemes. Decided from plan properties
    /// ([`crate::graph::plan_props`]), never from the scheme enum.
    pub enable_partition: Option<bool>,
    /// Per-worker memory budget (bytes); activates the memory strategies
    /// and makes feasibility dominate the objective.
    pub memory_budget_bytes: Option<f64>,
    /// Explicit strategy set as a comma-separated name list (the CLI's
    /// `--strategies`; see [`strategy::parse_strategies`]). `None` = the
    /// critical-path walker per the enable flags above, plus the memory
    /// passes whenever a budget is set.
    pub strategies: Option<String>,
    /// Hard cap on search rounds.
    pub max_rounds: usize,
    /// Stop when the estimate improves < 0.5% over this many rounds.
    pub converge_rounds: usize,
    /// Wall-clock budget for the whole search (seconds).
    pub budget_wall_s: f64,
    /// Largest partition count the partition strategy may propose.
    pub max_partitions: usize,
}

impl Default for SearchOpts {
    fn default() -> Self {
        SearchOpts {
            use_coarsened_view: true,
            use_partial_replay: true,
            use_symmetry: true,
            use_blame_ranking: true,
            enable_op_fusion: true,
            enable_tensor_fusion: true,
            enable_partition: None,
            memory_budget_bytes: None,
            strategies: None,
            max_rounds: 40,
            converge_rounds: 5,
            budget_wall_s: 120.0,
            max_partitions: 16,
        }
    }
}

impl SearchOpts {
    /// The Table 5 "strawman": Alg. 1 with no acceleration technique
    /// (blame ranking included — it reorders candidates to reach the
    /// target cost sooner, so the baseline must not run it either).
    pub fn strawman() -> SearchOpts {
        SearchOpts {
            use_coarsened_view: false,
            use_partial_replay: false,
            use_symmetry: false,
            use_blame_ranking: false,
            ..Default::default()
        }
    }

    /// Only search op-fusion decisions (paper's dPRO_OPFS).
    pub fn opfs_only() -> SearchOpts {
        SearchOpts {
            enable_tensor_fusion: false,
            enable_partition: Some(false),
            ..Default::default()
        }
    }

    /// Only search tensor-fusion/partition decisions (paper's dPRO_TSFS).
    pub fn tsfs_only() -> SearchOpts {
        SearchOpts { enable_op_fusion: false, ..Default::default() }
    }
}

/// Outcome of a search run.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    /// The optimized job spec (the search's final plan state).
    pub spec: JobSpec,
    /// Replayed iteration time before any decision (us).
    pub baseline_iteration_us: Us,
    /// Replayed iteration time of the chosen plan (us).
    pub est_iteration_us: Us,
    /// Estimated peak memory of the chosen plan (0 unless a memory budget
    /// was set — the peak walk only runs for budgeted searches).
    pub est_mem_bytes: f64,
    /// Best estimate after each round (convergence trajectory).
    pub history: Vec<Us>,
    /// The memory pass the round loop accepted, if any (derived from
    /// [`Self::accepted`]).
    pub mem_opt: MemOpt,
    /// Every accepted decision, in acceptance order.
    pub accepted: Vec<Decision>,
    /// Candidates evaluated (accepted + rolled back).
    pub candidates_tried: usize,
    /// Per acceptance: `(candidates_tried at that moment, accepted
    /// state's time_us)` — the cost-vs-effort trajectory the blame-ranking
    /// tests compare (how many candidates until a target cost).
    pub accept_trace: Vec<(usize, Us)>,
    /// Incremental replays performed across all rounds.
    pub replays: usize,
    /// Full builds+replays the strawman `t_sync` oracle needed (0 with
    /// partial replay on).
    pub full_replays_for_tsync: usize,
    /// Total primitive plan edits applied (symmetry propagation included).
    pub actions_applied: usize,
    /// Global-DFG constructions performed by the round loop itself. Zero
    /// whenever partial replay is on (the strawman t_sync oracle is the
    /// only remaining builder, and it is what Table 5 ablated away).
    pub builds_during_search: usize,
    /// Wall-clock time of the search (seconds).
    pub wall_s: f64,
}

impl SearchOutcome {
    /// Baseline over optimized iteration time.
    pub fn speedup(&self) -> f64 {
        self.baseline_iteration_us / self.est_iteration_us
    }

    /// Machine-readable form (CLI `--json`, benches, CI).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("baseline_iteration_us", Json::Num(self.baseline_iteration_us));
        j.set("est_iteration_us", Json::Num(self.est_iteration_us));
        j.set("speedup", Json::Num(self.speedup()));
        j.set("est_mem_bytes", Json::Num(self.est_mem_bytes));
        j.set("mem_opt", Json::Str(self.mem_opt.name().to_string()));
        j.set(
            "history_us",
            Json::Arr(self.history.iter().map(|&h| Json::Num(h)).collect()),
        );
        j.set(
            "accepted",
            Json::Arr(self.accepted.iter().map(|d| Json::Str(d.to_string())).collect()),
        );
        j.set("candidates_tried", Json::Num(self.candidates_tried as f64));
        j.set(
            "accept_trace",
            Json::Arr(
                self.accept_trace
                    .iter()
                    .map(|&(tried, t)| {
                        let mut o = Json::obj();
                        o.set("tried", Json::Num(tried as f64));
                        o.set("time_us", Json::Num(t));
                        o
                    })
                    .collect(),
            ),
        );
        j.set("replays", Json::Num(self.replays as f64));
        j.set(
            "full_replays_for_tsync",
            Json::Num(self.full_replays_for_tsync as f64),
        );
        j.set("actions_applied", Json::Num(self.actions_applied as f64));
        j.set(
            "builds_during_search",
            Json::Num(self.builds_during_search as f64),
        );
        j.set("wall_s", Json::Num(self.wall_s));
        j
    }
}

/// Round-level convergence check: a feasibility change always counts as
/// progress; otherwise require a > 0.5% time improvement.
fn round_improves(new: &CandidateEval, best: &CandidateEval, budget: Option<f64>) -> bool {
    let slack = CandidateEval { time_us: best.time_us * 0.995, ..*best };
    strategy::better(new, &slack, budget)
}

/// Run Alg. 1 on a job spec with the default strategy set (see
/// [`strategy::strategies_from_opts`]).
pub fn optimize(spec0: &JobSpec, opts: &SearchOpts) -> SearchOutcome {
    optimize_with(spec0, opts, strategy::strategies_from_opts(opts))
}

/// Run Alg. 1 with an explicit strategy set. The loop body is the whole
/// public contract: per round, replay the current state once, collect
/// candidates from every strategy, then for each candidate open a
/// transaction, apply, replay incrementally, and keep or roll back under
/// the uniform objective [`strategy::better`]. No strategy-specific logic
/// lives here.
pub fn optimize_with(
    spec0: &JobSpec,
    opts: &SearchOpts,
    strategies: Vec<Box<dyn Strategy>>,
) -> SearchOutcome {
    let t0 = Instant::now();
    let mut replays = 0usize;

    // baseline estimate (deployed plan, before any dPRO strategy); the
    // graph is kept — if no setup pass changes the spec it becomes the
    // search's long-lived state instead of being rebuilt
    let mut base_mg = MutableGraph::new(spec0.clone());
    let mut base_eng = IncrementalReplayer::new();
    let baseline = {
        let log = base_mg.commit();
        replays += 1;
        base_eng.replay_incremental(&base_mg, &log).iteration_time
    };

    let mut spec = spec0.clone();
    let mut spec_dirty = false;

    // ---- Coarsened View (Alg. 1 line 2) ----
    if opts.use_coarsened_view {
        let stats = coarsen::coarsen(&mut spec);
        spec_dirty |= stats.op_fusions + stats.tensor_fusions > 0;
    }

    // ---- long-lived incremental replay state: built once (or adopted
    // from the baseline), then only edited in place for the rest of the
    // search ----
    let (mut mg, mut eng) = if spec_dirty {
        (MutableGraph::new(spec), IncrementalReplayer::new())
    } else {
        (base_mg, base_eng)
    };
    run_rounds(&mut mg, &mut eng, opts, strategies, t0, baseline, replays)
}

/// Run Alg. 1 on a **resident** graph + engine — the serve session's
/// writer path (`POST /jobs/:id/optimize`): accepted candidates commit
/// through the transaction journal into the caller's long-lived state,
/// rejected ones roll back bit-exactly, and the caller keeps the mutated
/// graph (unlike [`optimize_with`], which builds and discards its own).
///
/// The Coarsened-View setup pass is intentionally skipped — it rewrites
/// the *spec* and would force a rebuild, and a resident graph's whole
/// point is that it is never rebuilt ([`SearchOpts::use_coarsened_view`]
/// is ignored). The baseline reported in the outcome is the resident
/// state's replayed time at entry, so repeated calls compose: each call's
/// baseline is the previous call's result.
pub fn optimize_resident(
    mg: &mut MutableGraph,
    eng: &mut IncrementalReplayer,
    opts: &SearchOpts,
    strategies: Vec<Box<dyn Strategy>>,
) -> SearchOutcome {
    let t0 = Instant::now();
    let mut replays = 0usize;
    let baseline = {
        let log = mg.commit();
        replays += 1;
        eng.replay_incremental(mg, &log).iteration_time
    };
    run_rounds(mg, eng, opts, strategies, t0, baseline, replays)
}

/// The shared round loop of [`optimize_with`] / [`optimize_resident`]:
/// everything after setup. `builds_during_search` counts from here, i.e.
/// after the `t_sync` probe engines are built — the same accounting the
/// Table 5 tests pin.
fn run_rounds(
    mg: &mut MutableGraph,
    eng: &mut IncrementalReplayer,
    opts: &SearchOpts,
    mut strategies: Vec<Box<dyn Strategy>>,
    t0: Instant,
    baseline: Us,
    mut replays: usize,
) -> SearchOutcome {
    let spec = mg.spec().clone();
    let budget = opts.memory_budget_bytes;
    let partition_enabled = opts
        .enable_partition
        .unwrap_or_else(|| plan_props(&spec).uses_servers);
    let sym = opts.use_symmetry.then(|| SymmetryIndex::new(&spec.model));
    let mut tsync = Tsync::new(
        &spec,
        opts.use_partial_replay,
        if partition_enabled { opts.max_partitions } else { 1 },
    );
    let builds_before_rounds = crate::graph::build_count();

    let mut history: Vec<Us> = Vec::new();
    let mut best: Option<(CandidateEval, JobSpec)> = None;
    let mut stale = 0usize;
    let mut actions_applied = 0usize;
    let mut candidates_tried = 0usize;
    let mut accept_trace: Vec<(usize, Us)> = Vec::new();
    // accepted decisions with their proposing strategy: an accepted
    // decision's cost hint (Strategy::evaluate — e.g. gradient
    // accumulation's +18% and accumulated-gradient buffer) is a property
    // of the resulting *state*, so it must keep adjusting every later
    // evaluation, not just the one that judged it
    let mut accepted: Vec<(usize, Decision)> = Vec::new();
    // evaluation of the current (last accepted) state, for the post-loop
    // fold — acceptances between round starts are not yet in `best`
    let mut final_eval: Option<CandidateEval> = None;

    'rounds: for round in 0..opts.max_rounds {
        if t0.elapsed().as_secs_f64() > opts.budget_wall_s {
            break;
        }
        let _round_span = crate::obs::span("search.round", crate::obs::SpanKind::Work);
        tsync.new_round();

        // ---- one replay of the current accepted state ----
        let log = mg.commit();
        let cur0;
        let path;
        let mut cands: Vec<(usize, Decision)> = Vec::new();
        {
            let r = eng.replay_incremental(mg, &log);
            replays += 1;
            let mut e = strategy::eval_state(mg, r, budget);
            for (asi, ad) in &accepted {
                e = strategies[*asi].evaluate(ad, e, mg);
            }
            cur0 = e;
            history.push(cur0.time_us);
            let improved = match &best {
                None => true,
                Some((b, _)) => round_improves(&cur0, b, budget),
            };
            if improved {
                best = Some((cur0, mg.spec().clone()));
                stale = 0;
            } else {
                stale += 1;
                if stale >= opts.converge_rounds {
                    break;
                }
            }

            // ---- collect candidates from every strategy ----
            path = r.critical_path();
            // per-group critical-path blame: strategies order their
            // candidates by it so high-blame targets are tried first
            // (empty when ranking is off — nothing reads it then)
            let gblame = if opts.use_blame_ranking {
                crate::diagnosis::critical::group_blame(mg, r)
            } else {
                crate::diagnosis::critical::GroupBlame::default()
            };
            let mut ctx = SearchCtx {
                mg,
                end: &r.end,
                path: &path,
                blame: &gblame,
                tsync: &mut tsync,
                opts,
                partition_enabled,
                budget_bytes: budget,
                cur: cur0,
                round,
            };
            for (si, s) in strategies.iter_mut().enumerate() {
                for d in s.candidates(&mut ctx) {
                    cands.push((si, d));
                }
            }
        }
        if cands.is_empty() {
            break;
        }

        // ---- transactional accept/reject, judged by incremental replay ----
        let actx = ApplyCtx { sym: sym.as_ref() };
        let mut cur = cur0;
        let mut round_applied = 0usize;
        for (si, d) in cands {
            if t0.elapsed().as_secs_f64() > opts.budget_wall_s {
                break 'rounds;
            }
            candidates_tried += 1;
            let _cand_span = crate::obs::span("search.candidate", crate::obs::SpanKind::Work);
            let txn = mg.begin();
            let n = {
                let _apply = crate::obs::span("search.apply", crate::obs::SpanKind::Work);
                strategies[si].apply(mg, &d, &actx)
            };
            if n == 0 {
                // decision not applicable in the current state
                mg.rollback(txn);
                crate::obs::hot::search_rollbacks().inc();
                continue;
            }
            let log = mg.commit();
            let mut raw = {
                let res = eng.replay_incremental(mg, &log);
                replays += 1;
                strategy::eval_state(mg, res, budget)
            };
            // re-apply the cost hints of every previously accepted decision
            // (they describe the state, which still contains those rewrites)
            for (asi, ad) in &accepted {
                raw = strategies[*asi].evaluate(ad, raw, mg);
            }
            let cand = strategies[si].evaluate(&d, raw, mg);
            if strategy::better(&cand, &cur, budget) {
                {
                    let _commit =
                        crate::obs::span("search.commit", crate::obs::SpanKind::Work);
                    mg.commit_txn(txn);
                }
                crate::obs::hot::search_accepts().inc();
                cur = cand;
                final_eval = Some(cand);
                round_applied += n;
                accept_trace.push((candidates_tried, cand.time_us));
                strategies[si].decided(&d, true);
                accepted.push((si, d));
            } else {
                {
                    let _rb =
                        crate::obs::span("search.rollback", crate::obs::SpanKind::Work);
                    mg.rollback(txn);
                }
                crate::obs::hot::search_rejects().inc();
                crate::obs::hot::search_rollbacks().inc();
                strategies[si].decided(&d, false);
            }
        }
        actions_applied += round_applied;
        if round_applied == 0 {
            break;
        }
    }
    let builds_during_search = crate::graph::build_count() - builds_before_rounds;

    // fold the final accepted state into the best tracking (the loop may
    // exit before re-evaluating it at a round start)
    if let Some(fe) = final_eval {
        let fold = match &best {
            None => true,
            Some((b, _)) => strategy::better(&fe, b, budget),
        };
        if fold {
            best = Some((fe, mg.spec().clone()));
        }
    }

    // a zero-round run (budget/max_rounds exhausted up front) still owes
    // the caller an estimate of the unmodified plan
    let (best_eval, best_spec) = match best {
        Some((e, s)) => (e, s),
        None => {
            let log = mg.commit();
            replays += 1;
            let r = eng.replay_incremental(mg, &log);
            let e = strategy::eval_state(mg, r, budget);
            (e, mg.spec().clone())
        }
    };

    let accepted: Vec<Decision> = accepted.into_iter().map(|(_, d)| d).collect();
    SearchOutcome {
        spec: best_spec,
        baseline_iteration_us: baseline,
        est_iteration_us: best_eval.time_us,
        est_mem_bytes: best_eval.mem_bytes,
        history,
        mem_opt: strategy::accepted_mem_opt(&accepted),
        accepted,
        candidates_tried,
        accept_trace,
        replays,
        full_replays_for_tsync: tsync.full_replays(),
        actions_applied,
        builds_during_search,
        wall_s: t0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Transport;

    fn quick_opts() -> SearchOpts {
        SearchOpts { max_rounds: 8, budget_wall_s: 30.0, ..Default::default() }
    }

    #[test]
    fn search_improves_resnet_horovod() {
        let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let out = optimize(&spec, &quick_opts());
        assert!(
            out.est_iteration_us < out.baseline_iteration_us,
            "no improvement: base={} est={}",
            out.baseline_iteration_us,
            out.est_iteration_us
        );
        assert!(out.actions_applied > 0);
        assert!(!out.accepted.is_empty());
        assert!(out.candidates_tried >= out.accepted.len());
        assert_eq!(out.spec.plan.validate(&out.spec.model), Ok(()));
        assert_eq!(out.spec.fusion.validate(&out.spec.model), Ok(()));
    }

    #[test]
    fn search_performs_zero_builds_during_rounds() {
        // the tentpole guarantee: after the initial construction, the
        // round loop never rebuilds the global DFG from the spec — and
        // rejected candidates roll back without a rebuild either
        let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let out = optimize(&spec, &quick_opts());
        assert_eq!(
            out.builds_during_search, 0,
            "search rebuilt the world {} times",
            out.builds_during_search
        );
        assert!(out.replays >= 2);
        // the strawman, by contrast, rebuilds for its t_sync probes
        let spec_ps = JobSpec::standard("vgg16", "byteps", Transport::Tcp);
        let mut strawman = SearchOpts::tsfs_only();
        strawman.use_partial_replay = false;
        strawman.max_rounds = 2;
        let out_strawman = optimize(&spec_ps, &strawman);
        assert!(out_strawman.builds_during_search > 0);
    }

    #[test]
    fn optimized_spec_faster_on_testbed_too() {
        // the claim that matters: strategies found on the replayer must
        // speed up the *ground truth*
        let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let out = optimize(&spec, &quick_opts());
        let tb_base = crate::testbed::run(
            &spec,
            &crate::testbed::TestbedOpts { iterations: 4, ..Default::default() },
        )
        .avg_iter();
        let tb_opt = crate::testbed::run(
            &out.spec,
            &crate::testbed::TestbedOpts { iterations: 4, ..Default::default() },
        )
        .avg_iter();
        assert!(
            tb_opt < tb_base,
            "testbed: base={tb_base} opt={tb_opt}"
        );
    }

    #[test]
    fn partial_replay_avoids_full_replays() {
        // tensor-fusion-only search on a comm-bound PS job forces t_sync
        // queries; partial replay answers them without full replays.
        let spec = JobSpec::standard("vgg16", "byteps", Transport::Tcp);
        let mut fast = SearchOpts::tsfs_only();
        fast.max_rounds = 3;
        fast.budget_wall_s = 60.0;
        let with = optimize(&spec, &fast);
        let mut slow = fast.clone();
        slow.use_partial_replay = false;
        let without = optimize(&spec, &slow);
        assert_eq!(with.full_replays_for_tsync, 0);
        assert!(
            without.full_replays_for_tsync > 0,
            "strawman did {} full replays",
            without.full_replays_for_tsync
        );
        assert!(with.wall_s <= without.wall_s + 0.5, "with={} without={}", with.wall_s, without.wall_s);
    }

    #[test]
    fn search_is_scheme_blind() {
        // the search loop must run unmodified on the pluggable schemes:
        // zero rebuilds, valid plans, and no regression of the estimate
        for scheme in ["ring", "ps-tree"] {
            let spec = JobSpec::standard("vgg16", scheme, Transport::Rdma);
            let mut o = quick_opts();
            o.max_rounds = 3;
            let out = optimize(&spec, &o);
            assert_eq!(out.builds_during_search, 0, "{scheme}");
            // mechanics, not magnitude: the estimate must stay in the
            // baseline's ballpark (coarsening alone is allowed ~5% slack
            // elsewhere in the suite)
            assert!(
                out.est_iteration_us <= out.baseline_iteration_us * 1.05,
                "{scheme}: est {} vs base {}",
                out.est_iteration_us,
                out.baseline_iteration_us
            );
            assert_eq!(out.spec.plan.validate(&out.spec.model), Ok(()), "{scheme}");
            assert_eq!(out.spec.fusion.validate(&out.spec.model), Ok(()), "{scheme}");
        }
        // auto partition-enabling keys off plan properties, not the enum
        let ps_tree = JobSpec::standard("vgg16", "ps-tree", Transport::Rdma);
        let ring = JobSpec::standard("vgg16", "ring", Transport::Rdma);
        assert!(crate::graph::plan_props(&ps_tree).uses_servers);
        assert!(!crate::graph::plan_props(&ring).uses_servers);
    }

    #[test]
    fn opfs_only_never_touches_comm_plan() {
        let spec = JobSpec::standard("inception_v3", "horovod", Transport::Rdma);
        let n_groups = spec.plan.groups.len();
        let mut o = SearchOpts::opfs_only();
        o.max_rounds = 4;
        o.use_coarsened_view = false; // coarsening fuses tensors by design
        let out = optimize(&spec, &o);
        assert_eq!(out.spec.plan.groups.len(), n_groups);
    }

    #[test]
    fn rejected_candidates_leave_no_trace() {
        // a search driven only by a strategy whose candidates always lose
        // must end bit-identical to its baseline: every transaction rolled
        // back, zero builds, nothing accepted
        struct Pessimizer;
        impl crate::optimizer::registry::GraphPass for Pessimizer {
            fn name(&self) -> &str {
                "pessimize"
            }
            fn apply(&self, spec: &JobSpec) -> Option<JobSpec> {
                let mut s = spec.clone();
                for op in &mut s.model.ops {
                    op.flops *= 3.0;
                    op.bytes *= 3.0;
                }
                Some(s)
            }
        }
        let mut reg = crate::optimizer::registry::Registry::empty();
        reg.register(Box::new(Pessimizer));
        let strategies: Vec<Box<dyn Strategy>> =
            vec![Box::new(crate::optimizer::strategy::RegistryStrategy::new(reg))];
        let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
        let opts = SearchOpts {
            max_rounds: 3,
            use_coarsened_view: false,
            ..Default::default()
        };
        let out = optimize_with(&spec, &opts, strategies);
        assert!(out.accepted.is_empty());
        assert_eq!(out.candidates_tried, 1, "settled after one rejection");
        assert_eq!(out.builds_during_search, 0);
        assert_eq!(
            out.est_iteration_us, out.baseline_iteration_us,
            "rollback must restore the exact baseline estimate"
        );
    }

    #[test]
    fn to_json_roundtrips() {
        let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
        let mut o = quick_opts();
        o.max_rounds = 2;
        let out = optimize(&spec, &o);
        let j = out.to_json();
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.f64("builds_during_search"), 0.0);
        assert!(parsed.f64("speedup") > 0.0);
        assert!(parsed.get("accepted").unwrap().as_arr().is_some());
    }
}
