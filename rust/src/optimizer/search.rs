//! The diagnosis & optimization search (paper Alg. 1): iteratively replay,
//! extract the critical path of the execution graph, and apply op fusion /
//! tensor fusion / tensor partition guided by Theorems 1–3 until the
//! estimated iteration time converges or the budget runs out.
//!
//! The loop holds **one long-lived** [`MutableGraph`] +
//! [`IncrementalReplayer`] across all rounds: decisions apply as in-place
//! graph edits and each round's replay recomputes only the affected cone.
//! After setup, a search performs **zero** global-DFG constructions
//! (tracked by [`crate::graph::build_count`] and pinned by tests) — the
//! Table 5 speedups come precisely from decoupling per-candidate
//! simulation cost from graph-construction cost.

use std::collections::HashMap;
use std::time::Instant;

use crate::config::JobSpec;
use crate::graph::dfg::{NodeId, OpKind, TensorId};
use crate::graph::{build_global_nameless, plan_props, AnalyticCost, MutableGraph};
use crate::optimizer::memopt::{self, MemOpt};
use crate::optimizer::{coarsen, passes, symmetry::SymmetryIndex};
use crate::replay::incremental::IncrementalReplayer;
use crate::replay::partial::TsyncEstimator;
use crate::replay::replay_once;
use crate::util::Us;

/// Search configuration; the three `use_*` flags are the paper's Table 5
/// ablation axes.
#[derive(Clone, Debug)]
pub struct SearchOpts {
    pub use_coarsened_view: bool,
    pub use_partial_replay: bool,
    pub use_symmetry: bool,
    pub enable_op_fusion: bool,
    pub enable_tensor_fusion: bool,
    /// Tensor partition (paper: most valuable under PS). `None` = auto —
    /// on when the scheme's lowered plan routes through servers (its
    /// per-partition chains pipeline push against pull), off for
    /// collective schemes. Decided from plan properties
    /// ([`crate::graph::plan_props`]), never from the scheme enum.
    pub enable_partition: Option<bool>,
    pub memory_budget_bytes: Option<f64>,
    pub max_rounds: usize,
    /// Stop when the estimate improves < 0.5% over this many rounds.
    pub converge_rounds: usize,
    pub budget_wall_s: f64,
    pub max_partitions: usize,
}

impl Default for SearchOpts {
    fn default() -> Self {
        SearchOpts {
            use_coarsened_view: true,
            use_partial_replay: true,
            use_symmetry: true,
            enable_op_fusion: true,
            enable_tensor_fusion: true,
            enable_partition: None,
            memory_budget_bytes: None,
            max_rounds: 40,
            converge_rounds: 5,
            budget_wall_s: 120.0,
            max_partitions: 16,
        }
    }
}

impl SearchOpts {
    /// The Table 5 "strawman": Alg. 1 with no acceleration technique.
    pub fn strawman() -> SearchOpts {
        SearchOpts {
            use_coarsened_view: false,
            use_partial_replay: false,
            use_symmetry: false,
            ..Default::default()
        }
    }

    /// Only search op-fusion decisions (paper's dPRO_OPFS).
    pub fn opfs_only() -> SearchOpts {
        SearchOpts {
            enable_tensor_fusion: false,
            enable_partition: Some(false),
            ..Default::default()
        }
    }

    /// Only search tensor-fusion/partition decisions (paper's dPRO_TSFS).
    pub fn tsfs_only() -> SearchOpts {
        SearchOpts { enable_op_fusion: false, ..Default::default() }
    }
}

/// Outcome of a search run.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    pub spec: JobSpec,
    pub baseline_iteration_us: Us,
    pub est_iteration_us: Us,
    pub history: Vec<Us>,
    pub mem_opt: MemOpt,
    pub replays: usize,
    pub full_replays_for_tsync: usize,
    pub actions_applied: usize,
    /// Global-DFG constructions performed by the round loop itself. Zero
    /// whenever partial replay is on (the strawman t_sync oracle is the
    /// only remaining builder, and it is what Table 5 ablated away).
    pub builds_during_search: usize,
    pub wall_s: f64,
}

impl SearchOutcome {
    pub fn speedup(&self) -> f64 {
        self.baseline_iteration_us / self.est_iteration_us
    }
}

/// A decision recorded during a critical-path walk, in *stable* ids
/// (template ops / tensors) so it survives plan-index shifts.
#[derive(Clone, Debug)]
enum Decision {
    /// fuse the fusion groups containing these two template ops + the comm
    /// groups of their produced tensors (Theorems 1+3)
    OpFuse(u32, u32),
    /// fuse the comm groups containing these two tensors + their producer
    /// fusion groups (Theorems 2+3)
    TensorFuse(TensorId, TensorId),
    /// set partition count of the comm group containing the tensor
    Partition(TensorId, usize),
}

/// t_sync oracle: partial replay (fast, never builds) or full replay of
/// the entire current job (the strawman's approach, memoized on
/// `(bytes_bucket, k)` so repeated probes within a round do not repeat
/// builds — the cache is cleared each round because a strawman probe
/// measures the *current* mutating job, not an idle network).
struct Tsync {
    partial: Option<TsyncEstimator>,
    strawman_cache: HashMap<(u64, usize), Us>,
    full_replays: usize,
}

impl Tsync {
    fn new(spec: &JobSpec, partial: bool, max_k: usize) -> Tsync {
        let partial = partial.then(|| {
            // pre-instantiate every partition count a round can query: the
            // grid range plus whatever the deployed plan already uses —
            // after this, t_sync never constructs a graph
            let mut ks: Vec<usize> = (1..=max_k.max(1)).collect();
            ks.extend(spec.plan.groups.iter().map(|g| g.partitions.max(1)));
            TsyncEstimator::with_prebuilt(spec, ks)
        });
        Tsync { partial, strawman_cache: HashMap::new(), full_replays: 0 }
    }

    /// Invalidate measurements that depend on the evolving job (the
    /// partial-replay estimator probes an idle network and stays valid).
    fn new_round(&mut self) {
        self.strawman_cache.clear();
    }

    fn t_sync(&mut self, spec: &JobSpec, bytes: f64, k: usize) -> Us {
        if let Some(p) = &mut self.partial {
            return p.t_sync(bytes, k);
        }
        let key = ((bytes / 1024.0).round() as u64, k.max(1));
        if let Some(&v) = self.strawman_cache.get(&key) {
            return v;
        }
        // strawman: rebuild and replay the entire current job with group 0
        // rescaled to the probe size
        if spec.plan.groups.is_empty() {
            return 0.0;
        }
        let mut s = spec.clone();
        s.plan.groups[0].partitions = k.max(1);
        let scale_t = s.plan.groups[0].tensors[0] as usize;
        let group_rest: f64 = s.plan.groups[0]
            .tensors
            .iter()
            .skip(1)
            .map(|&t| s.model.tensors[t as usize].bytes)
            .sum();
        s.model.tensors[scale_t].bytes = (bytes - group_rest).max(1.0);
        let g = build_global_nameless(&s, &AnalyticCost::new(&s));
        let r = replay_once(&g);
        self.full_replays += 1;
        let mut t_in = f64::INFINITY;
        let mut t_out: f64 = 0.0;
        for &n in &g.group_nodes[0] {
            let node = g.dfg.node(n);
            match node.kind {
                OpKind::In => t_in = t_in.min(r.end[n as usize]),
                OpKind::Out => t_out = t_out.max(r.end[n as usize]),
                _ => {}
            }
        }
        let t = (t_out - t_in).max(0.0);
        self.strawman_cache.insert(key, t);
        t
    }

    fn opt_part_num(&mut self, spec: &JobSpec, bytes: f64, max_k: usize) -> (usize, Us) {
        let mut best = (1usize, f64::INFINITY);
        for k in 1..=max_k.max(1) {
            let t = self.t_sync(spec, bytes, k);
            if t < best.1 {
                best = (k, t);
            }
        }
        best
    }
}

/// Run Alg. 1 on a job spec.
pub fn optimize(spec0: &JobSpec, opts: &SearchOpts) -> SearchOutcome {
    let t0 = Instant::now();
    let mut replays = 0usize;

    // baseline estimate (deployed plan, before any dPRO strategy); the
    // graph is kept — if no setup pass changes the spec it becomes the
    // search's long-lived state instead of being rebuilt
    let mut base_mg = MutableGraph::new(spec0.clone());
    let mut base_eng = IncrementalReplayer::new();
    let baseline = {
        let log = base_mg.commit();
        replays += 1;
        base_eng.replay_incremental(&base_mg, &log).iteration_time
    };

    let mut spec = spec0.clone();
    let mut spec_dirty = false;

    // ---- memory passes (Alg. 1 line 1) ----
    let mut mem_opt = MemOpt::None;
    if let Some(budget) = opts.memory_budget_bytes {
        let (chosen, _) = memopt::choose(&spec, budget);
        mem_opt = chosen;
        if chosen != MemOpt::None {
            spec = memopt::apply(&spec, chosen);
            spec_dirty = true;
        }
    }

    // ---- Coarsened View (Alg. 1 line 2) ----
    if opts.use_coarsened_view {
        let stats = coarsen::coarsen(&mut spec);
        spec_dirty |= stats.op_fusions + stats.tensor_fusions > 0;
    }

    let partition_enabled = opts
        .enable_partition
        .unwrap_or_else(|| plan_props(&spec).uses_servers);
    let sym = opts.use_symmetry.then(|| SymmetryIndex::new(&spec.model));
    let mut tsync = Tsync::new(
        &spec,
        opts.use_partial_replay,
        if partition_enabled { opts.max_partitions } else { 1 },
    );

    // ---- long-lived incremental replay state: built once (or adopted
    // from the baseline), then only edited in place for the rest of the
    // search ----
    let (mut mg, mut eng) = if spec_dirty {
        (MutableGraph::new(spec), IncrementalReplayer::new())
    } else {
        (base_mg, base_eng)
    };
    let builds_before_rounds = crate::graph::build_count();

    let mut history: Vec<Us> = Vec::new();
    let mut best = f64::INFINITY;
    let mut best_spec = mg.spec().clone();
    let mut stale = 0usize;
    let mut actions_applied = 0usize;

    for _round in 0..opts.max_rounds {
        if t0.elapsed().as_secs_f64() > opts.budget_wall_s {
            break;
        }
        tsync.new_round();
        let log = mg.commit();
        let result = eng.replay_incremental(&mg, &log);
        replays += 1;
        let est = result.iteration_time;
        history.push(est);
        if est < best * 0.995 {
            best = est;
            best_spec = mg.spec().clone();
            stale = 0;
        } else {
            stale += 1;
            if stale >= opts.converge_rounds {
                break;
            }
        }

        // ---- walk the critical path and collect decisions ----
        let path = result.critical_path();
        let decisions =
            collect_decisions(&mg, &path, &result.end, &mut tsync, opts, partition_enabled);
        if decisions.is_empty() {
            break;
        }

        // ---- apply in place (with symmetry propagation) ----
        let mut applied = 0usize;
        for d in &decisions {
            applied += apply_decision(&mut mg, d, sym.as_ref(), opts);
        }
        actions_applied += applied;
        if applied == 0 {
            break;
        }
    }
    let builds_during_search = crate::graph::build_count() - builds_before_rounds;

    // a zero-round run (budget/max_rounds exhausted up front) still owes
    // the caller an estimate of the unmodified plan
    if !best.is_finite() {
        let log = mg.commit();
        replays += 1;
        best = eng.replay_incremental(&mg, &log).iteration_time;
        best_spec = mg.spec().clone();
    }

    SearchOutcome {
        spec: best_spec,
        baseline_iteration_us: baseline,
        est_iteration_us: best,
        history,
        mem_opt,
        replays,
        full_replays_for_tsync: tsync.full_replays,
        actions_applied,
        builds_during_search,
        wall_s: t0.elapsed().as_secs_f64(),
    }
}

/// Walk the path per Alg. 1 (lines 5–25) and collect fusion/partition
/// decisions in stable ids.
fn collect_decisions(
    mg: &MutableGraph,
    path: &[NodeId],
    end: &[f64],
    tsync: &mut Tsync,
    opts: &SearchOpts,
    partition_enabled: bool,
) -> Vec<Decision> {
    let spec = mg.spec();
    let dfg = mg.dfg();
    let gpu = &spec.cluster.gpu;
    let mut out = Vec::new();

    // group-level end times for q^e (max end over the group's comm chain)
    let group_end = |cg: usize| -> f64 {
        mg.group_nodes_iter(cg).map(|n| end[n as usize]).fold(0.0, f64::max)
    };

    // Alg. 1 walks the whole critical path each round; decisions are in
    // stable ids so applying a batch cannot invalidate later ones
    for w in path.windows(2) {
        let (a, b) = (dfg.node(w[0]), dfg.node(w[1]));

        // ---- computation-bound segment: consecutive comp ops ----
        if opts.enable_op_fusion
            && a.kind == b.kind
            && (a.kind == OpKind::Backward || a.kind == OpKind::Forward)
            && a.owner == b.owner
        {
            let (Some(fa), Some(fb)) = (a.template_id, b.template_id) else { continue };
            if fa == fb {
                continue;
            }
            let da = spec.fusion.duration(&spec.model, gpu, fa as usize);
            let db = spec.fusion.duration(&spec.model, gpu, fb as usize);
            let fused = gpu.fused_time(&[da, db]);
            // q_{n-1}: sync of the tensors produced by the earlier group
            let cgs = passes::comm_groups_of_fusion_group(spec, fa as usize);
            let q_d = cgs
                .iter()
                .map(|&cg| {
                    let bytes = spec.plan.group_bytes(&spec.model, cg);
                    tsync.t_sync(spec, bytes, spec.plan.groups[cg].partitions)
                })
                .fold(0.0, f64::max);
            // Theorem 1
            if q_d <= da + db - fused {
                let op_a = spec.fusion.groups[fa as usize][0];
                let op_b = spec.fusion.groups[fb as usize][0];
                out.push(Decision::OpFuse(op_a, op_b));
            }
            continue;
        }

        // ---- communication-bound segment: consecutive comm ops ----
        if opts.enable_tensor_fusion && a.kind.is_comm() && b.kind.is_comm() {
            let (Some(ta), Some(tb)) = (a.tensor, b.tensor) else { continue };
            let (ca, cb) = (ta.tensor_id as usize, tb.tensor_id as usize);
            if ca == cb || ca >= spec.plan.groups.len() || cb >= spec.plan.groups.len() {
                continue;
            }
            let sa = spec.plan.group_bytes(&spec.model, ca);
            let sb = spec.plan.group_bytes(&spec.model, cb);
            let max_k = if partition_enabled { opts.max_partitions } else { 1 };
            let (k_f, t_f) = tsync.opt_part_num(spec, sa + sb, max_k);
            let (_k_b, t_b) = tsync.opt_part_num(spec, sb, max_k);
            let q_prev_end = group_end(ca);
            // p_n^e: end of the producer comp group of cb on this worker
            let p_end = passes::producer_fusion_group(spec, cb)
                .and_then(|fg| mg.comp_node(b.owner, fg as u32))
                .map(|n| end[n as usize])
                .unwrap_or(0.0);
            // Theorem 2
            if q_prev_end > p_end + t_f - t_b {
                let t_first = spec.plan.groups[ca].tensors[0];
                let t_second = spec.plan.groups[cb].tensors[0];
                out.push(Decision::TensorFuse(t_first, t_second));
                if partition_enabled && k_f > 1 {
                    out.push(Decision::Partition(t_first, k_f));
                }
            } else if partition_enabled {
                let (k_n, _) = tsync.opt_part_num(spec, sb, max_k);
                if k_n != spec.plan.groups[cb].partitions {
                    out.push(Decision::Partition(spec.plan.groups[cb].tensors[0], k_n));
                }
            }
        }
    }
    out
}

/// Apply one decision (+ its Theorem-3 companions and symmetry analogs) as
/// in-place graph edits. Returns the number of primitive passes applied.
fn apply_decision(
    mg: &mut MutableGraph,
    d: &Decision,
    sym: Option<&SymmetryIndex>,
    opts: &SearchOpts,
) -> usize {
    let mut n = 0usize;
    match *d {
        Decision::OpFuse(op_a, op_b) => {
            n += fuse_ops_and_tensors(mg, op_a, op_b, opts);
            if let Some(sym) = sym {
                for (x, y) in sym.analog_pairs(op_a, op_b) {
                    n += fuse_ops_and_tensors(mg, x, y, opts);
                }
            }
        }
        Decision::TensorFuse(ta, tb) => {
            n += fuse_tensors_and_ops(mg, ta, tb, opts);
            if let Some(sym) = sym {
                let pa = mg.spec().model.producer_of(ta);
                let pb = mg.spec().model.producer_of(tb);
                if let (Some(pa), Some(pb)) = (pa, pb) {
                    for (x, y) in sym.analog_pairs(pa, pb) {
                        // fuse the first produced tensors of the analogs
                        let tx = mg.spec().model.ops[x as usize].produces.first().copied();
                        let ty = mg.spec().model.ops[y as usize].produces.first().copied();
                        if let (Some(tx), Some(ty)) = (tx, ty) {
                            n += fuse_tensors_and_ops(mg, tx, ty, opts);
                        }
                    }
                }
            }
        }
        Decision::Partition(t, k) => {
            if let Some(cg) = passes::comm_group_of_tensor(mg.spec(), t) {
                if mg.spec().plan.groups[cg].partitions != k && mg.set_partitions(cg, k).is_ok()
                {
                    n += 1;
                }
            }
        }
    }
    n
}

/// Theorem 1 + 3: fuse two fusion groups and the comm groups they feed.
fn fuse_ops_and_tensors(mg: &mut MutableGraph, op_a: u32, op_b: u32, opts: &SearchOpts) -> usize {
    let fa = mg.spec().fusion.group_of[op_a as usize] as usize;
    let fb = mg.spec().fusion.group_of[op_b as usize] as usize;
    if fa == fb {
        return 0;
    }
    let mut n = 0;
    let cgs_a = passes::comm_groups_of_fusion_group(mg.spec(), fa);
    let cgs_b = passes::comm_groups_of_fusion_group(mg.spec(), fb);
    if mg.fuse_comp_groups(fa, fb).is_ok() {
        n += 1;
        // companion tensor fusion (Theorem 3)
        if opts.enable_tensor_fusion {
            if let (Some(&ca), Some(&cb)) = (cgs_a.first(), cgs_b.first()) {
                // indices may have shifted only for fusion groups, not comm
                if ca != cb && mg.fuse_tensor_groups(ca, cb).is_ok() {
                    n += 1;
                }
            }
        }
    }
    n
}

/// Theorem 2 + 3: fuse two comm groups and their producer fusion groups.
fn fuse_tensors_and_ops(
    mg: &mut MutableGraph,
    ta: TensorId,
    tb: TensorId,
    opts: &SearchOpts,
) -> usize {
    let Some(ca) = passes::comm_group_of_tensor(mg.spec(), ta) else { return 0 };
    let Some(cb) = passes::comm_group_of_tensor(mg.spec(), tb) else { return 0 };
    if ca == cb {
        return 0;
    }
    let pa = passes::producer_fusion_group(mg.spec(), ca);
    let pb = passes::producer_fusion_group(mg.spec(), cb);
    let mut n = 0;
    if mg.fuse_tensor_groups(ca, cb).is_ok() {
        n += 1;
        if opts.enable_op_fusion {
            if let (Some(pa), Some(pb)) = (pa, pb) {
                if pa != pb && mg.fuse_comp_groups(pa, pb).is_ok() {
                    n += 1;
                }
            }
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Transport;

    fn quick_opts() -> SearchOpts {
        SearchOpts { max_rounds: 8, budget_wall_s: 30.0, ..Default::default() }
    }

    #[test]
    fn search_improves_resnet_horovod() {
        let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let out = optimize(&spec, &quick_opts());
        assert!(
            out.est_iteration_us < out.baseline_iteration_us,
            "no improvement: base={} est={}",
            out.baseline_iteration_us,
            out.est_iteration_us
        );
        assert!(out.actions_applied > 0);
        assert_eq!(out.spec.plan.validate(&out.spec.model), Ok(()));
        assert_eq!(out.spec.fusion.validate(&out.spec.model), Ok(()));
    }

    #[test]
    fn search_performs_zero_builds_during_rounds() {
        // the tentpole guarantee: after the initial construction, the
        // round loop never rebuilds the global DFG from the spec
        let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let out = optimize(&spec, &quick_opts());
        assert_eq!(
            out.builds_during_search, 0,
            "search rebuilt the world {} times",
            out.builds_during_search
        );
        assert!(out.replays >= 2);
        // the strawman, by contrast, rebuilds for its t_sync probes
        let spec_ps = JobSpec::standard("vgg16", "byteps", Transport::Tcp);
        let mut strawman = SearchOpts::tsfs_only();
        strawman.use_partial_replay = false;
        strawman.max_rounds = 2;
        let out_strawman = optimize(&spec_ps, &strawman);
        assert!(out_strawman.builds_during_search > 0);
    }

    #[test]
    fn optimized_spec_faster_on_testbed_too() {
        // the claim that matters: strategies found on the replayer must
        // speed up the *ground truth*
        let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let out = optimize(&spec, &quick_opts());
        let tb_base = crate::testbed::run(
            &spec,
            &crate::testbed::TestbedOpts { iterations: 4, ..Default::default() },
        )
        .avg_iter();
        let tb_opt = crate::testbed::run(
            &out.spec,
            &crate::testbed::TestbedOpts { iterations: 4, ..Default::default() },
        )
        .avg_iter();
        assert!(
            tb_opt < tb_base,
            "testbed: base={tb_base} opt={tb_opt}"
        );
    }

    #[test]
    fn partial_replay_avoids_full_replays() {
        // tensor-fusion-only search on a comm-bound PS job forces t_sync
        // queries; partial replay answers them without full replays.
        let spec = JobSpec::standard("vgg16", "byteps", Transport::Tcp);
        let mut fast = SearchOpts::tsfs_only();
        fast.max_rounds = 3;
        fast.budget_wall_s = 60.0;
        let with = optimize(&spec, &fast);
        let mut slow = fast.clone();
        slow.use_partial_replay = false;
        let without = optimize(&spec, &slow);
        assert_eq!(with.full_replays_for_tsync, 0);
        assert!(
            without.full_replays_for_tsync > 0,
            "strawman did {} full replays",
            without.full_replays_for_tsync
        );
        assert!(with.wall_s <= without.wall_s + 0.5, "with={} without={}", with.wall_s, without.wall_s);
    }

    #[test]
    fn search_is_scheme_blind() {
        // the search loop must run unmodified on the pluggable schemes:
        // zero rebuilds, valid plans, and no regression of the estimate
        for scheme in ["ring", "ps-tree"] {
            let spec = JobSpec::standard("vgg16", scheme, Transport::Rdma);
            let mut o = quick_opts();
            o.max_rounds = 3;
            let out = optimize(&spec, &o);
            assert_eq!(out.builds_during_search, 0, "{scheme}");
            // mechanics, not magnitude: the estimate must stay in the
            // baseline's ballpark (coarsening alone is allowed ~5% slack
            // elsewhere in the suite)
            assert!(
                out.est_iteration_us <= out.baseline_iteration_us * 1.05,
                "{scheme}: est {} vs base {}",
                out.est_iteration_us,
                out.baseline_iteration_us
            );
            assert_eq!(out.spec.plan.validate(&out.spec.model), Ok(()), "{scheme}");
            assert_eq!(out.spec.fusion.validate(&out.spec.model), Ok(()), "{scheme}");
        }
        // auto partition-enabling keys off plan properties, not the enum
        let ps_tree = JobSpec::standard("vgg16", "ps-tree", Transport::Rdma);
        let ring = JobSpec::standard("vgg16", "ring", Transport::Rdma);
        assert!(crate::graph::plan_props(&ps_tree).uses_servers);
        assert!(!crate::graph::plan_props(&ring).uses_servers);
    }

    #[test]
    fn opfs_only_never_touches_comm_plan() {
        let spec = JobSpec::standard("inception_v3", "horovod", Transport::Rdma);
        let n_groups = spec.plan.groups.len();
        let mut o = SearchOpts::opfs_only();
        o.max_rounds = 4;
        o.use_coarsened_view = false; // coarsening fuses tensors by design
        let out = optimize(&spec, &o);
        assert_eq!(out.spec.plan.groups.len(), n_groups);
    }
}
