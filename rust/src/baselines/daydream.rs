//! Daydream's simulator (Zhu et al., ATC'20) as the paper characterizes
//! it: the **local** DFG of one worker plus one coarse-grained
//! communication op per tensor whose duration is `tensor size / nominal
//! bandwidth` — no queuing, no negotiation, no protocol efficiency, no
//! per-message overhead (paper §2.2 + Fig. 1).

use crate::config::JobSpec;
use crate::graph::dfg::{DeviceKey, Dfg, Node, OpKind, TensorMeta};
use crate::trace::ProfileDb;
use crate::util::Us;

/// Daydream's iteration-time estimate for a job. Computation durations
/// come from the profile (Daydream profiles compute accurately); each
/// tensor gets one AllReduce/PushPull op at nominal bandwidth on a single
/// network device.
pub fn estimate(spec: &JobSpec, profile: Option<&ProfileDb>) -> DaydreamEstimate {
    let model = &spec.model;
    let gpu = &spec.cluster.gpu;
    let nominal_bw = spec.cluster.network.nic_gbps * 1e9 / 8.0; // bytes/s

    let mut dfg = Dfg::new();
    let mut comp_ids = Vec::with_capacity(model.ops.len());
    for (i, op) in model.ops.iter().enumerate() {
        let mut dur = op.duration(gpu);
        if let Some(db) = profile {
            if let Some(d) = db.get(&format!("w0.{}", op.name)) {
                dur = d;
            }
        }
        let id = dfg.add(Node {
            name: crate::util::intern::intern(&format!("w0.{}", op.name)),
            kind: op.kind,
            device: DeviceKey::Gpu(0),
            duration: dur,
            owner: 0,
            proc: 0,
            tensor: None,
            txid: None,
            template_id: Some(i as u32),
        });
        for &d in &op.deps {
            dfg.edge(comp_ids[d as usize], id);
        }
        comp_ids.push(id);
    }

    // One coarse comm op per tensor: size/bandwidth, with the standard
    // algorithm-bandwidth factor of the chosen scheme. The factor is the
    // wire bytes a gradient byte traverses on the plan's critical path —
    // 2(N−1)/N for the ring schemes, 2 (push+pull) for the PS schemes —
    // derived from the scheme's lowered plan, so Daydream stays exactly as
    // naive as the paper describes for any pluggable scheme. A plan with
    // no Send stages at all (single-machine hierarchical AllReduce) falls
    // back to the textbook ring factor: Daydream has no intra-node model
    // and would otherwise price communication at zero.
    let n = spec.cluster.n_workers as f64;
    let props_factor = crate::graph::plan_props(spec).critical_path_wire_factor;
    let factor = if props_factor > 0.0 { props_factor } else { 2.0 * (n - 1.0) / n };
    for (t, tensor) in model.tensors.iter().enumerate() {
        let dur: Us = tensor.bytes * factor / nominal_bw * 1e6;
        let comm = dfg.add(Node {
            name: crate::util::intern::intern(&format!("dd.comm.t{t}")),
            kind: OpKind::Recv,
            device: DeviceKey::LinkTx(0),
            duration: dur,
            owner: 0,
            proc: 0,
            tensor: Some(TensorMeta { tensor_id: t as u32, bytes: tensor.bytes }),
            txid: None,
            template_id: None,
        });
        if let Some(p) = model.producer_of(t as u32) {
            dfg.edge(comp_ids[p as usize], comm);
        }
        // update after sync
        let upd = dfg.add(Node {
            name: crate::util::intern::intern(&format!("dd.upd.t{t}")),
            kind: OpKind::Update,
            device: DeviceKey::Gpu(0),
            duration: gpu.launch_overhead_us + 4.0 * tensor.bytes / gpu.mem_bw * 1e6,
            owner: 0,
            proc: 0,
            tensor: None,
            txid: None,
            template_id: None,
        });
        dfg.edge(comm, upd);
    }

    // wrap in a GlobalDfg-shaped structure for the replayer
    let g = crate::graph::GlobalDfg {
        dfg,
        comp_node: Default::default(),
        group_nodes: Vec::new(),
        group_out: Default::default(),
        update_node: Default::default(),
        n_workers: 1,
    };
    let r = crate::replay::replay_once(&g);
    DaydreamEstimate {
        iteration_us: r.iteration_time,
        fw_us: r.kind_time(&g, 0, OpKind::Forward),
        bw_us: r.kind_time(&g, 0, OpKind::Backward),
    }
}

/// Daydream's replay estimate for one job.
#[derive(Clone, Copy, Debug)]
pub struct DaydreamEstimate {
    /// Estimated iteration time (us).
    pub iteration_us: Us,
    /// Worker 0's forward busy time (us).
    pub fw_us: Us,
    /// Worker 0's backward busy time (us).
    pub bw_us: Us,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Transport;
    use crate::testbed::{run, TestbedOpts};
    use crate::util::stats::rel_err_pct;

    #[test]
    fn daydream_underestimates_deployed_job() {
        // ground truth with deployed defaults; Daydream ignores queuing,
        // negotiation and protocol overheads → notable underestimate
        let spec = crate::baselines::deployed_default(&JobSpec::standard(
            "resnet50", "byteps", Transport::Tcp,
        ));
        let tb = run(&spec, &TestbedOpts { iterations: 5, ..Default::default() });
        let db = crate::profiler::corrected_profile(&tb.trace, &crate::alignment::Alignment::identity());
        let dd = estimate(&spec, Some(&db));
        assert!(
            dd.iteration_us < tb.avg_iter(),
            "daydream={} truth={}",
            dd.iteration_us,
            tb.avg_iter()
        );
        let err = rel_err_pct(dd.iteration_us, tb.avg_iter());
        assert!(err > 8.0, "daydream should err substantially, got {err:.1}%");
    }

    #[test]
    fn daydream_insensitive_to_transport() {
        // paper Fig. 1: Daydream's predictions stay ~flat across
        // RDMA/TCP because it only sees nominal bandwidth
        let tcp = JobSpec::standard("resnet50", "horovod", Transport::Tcp);
        let rdma = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let a = estimate(&tcp, None).iteration_us;
        let b = estimate(&rdma, None).iteration_us;
        assert!((a - b).abs() / b < 0.01, "tcp={a} rdma={b}");
    }

    #[test]
    fn daydream_compute_breakdown_is_accurate() {
        // Daydream *does* model computation well (paper Table 2)
        let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let tb = run(&spec, &TestbedOpts { iterations: 5, ..Default::default() });
        let db = crate::profiler::corrected_profile(&tb.trace, &crate::alignment::Alignment::identity());
        let dd = estimate(&spec, Some(&db));
        assert!(rel_err_pct(dd.fw_us, tb.fw_time) < 5.0);
        assert!(rel_err_pct(dd.bw_us, tb.bw_time) < 5.0);
    }
}
