//! Baselines the paper compares against (§7.1):
//!
//! - [`daydream`] — Daydream's simulator (local DFG + `size/bandwidth`
//!   coarse communication ops);
//! - [`xla_auto_cluster`] — XLA's default auto-clustering op fusion
//!   ("fuse as many ops as possible");
//! - [`horovod_default_plan`] / [`horovod_autotune_plan`] — Horovod's
//!   5 ms / 64 MB tensor-fusion buckets and its autotuner;
//! - [`byteps_default_plan`] — BytePS's fixed 4 MB tensor partitions.
//!
//! The plan builders also define the **deployed defaults** used as the
//! ground-truth configurations in Figs. 1/7 (real jobs run with default
//! Horovod/BytePS settings, not per-tensor sync).

pub mod daydream;

use crate::config::{CommPlan, FusionPlan, JobSpec, TensorGroup};
use crate::graph::dfg::{OpKind, TensorId};
use crate::models::cost::GpuModel;
use crate::models::ModelGraph;

/// Horovod's default tensor fusion: buckets closed at 64 MB or when the
/// next tensor becomes ready more than one 5 ms cycle later. Tensor
/// readiness is approximated by a serial backward schedule on the cost
/// model (what the Horovod cycle would observe).
pub fn horovod_default_plan(model: &ModelGraph, gpu: &GpuModel) -> CommPlan {
    horovod_plan(model, gpu, 5_000.0, 64.0e6)
}

/// Horovod Autotune: grid over (cycle, cap) picking the plan whose
/// replayed iteration time is best for the job. `eval` maps a candidate
/// plan to an iteration-time estimate.
pub fn horovod_autotune_plan(
    spec: &JobSpec,
    mut eval: impl FnMut(&CommPlan) -> f64,
) -> CommPlan {
    let gpu = &spec.cluster.gpu;
    let mut best: Option<(f64, CommPlan)> = None;
    for cycle in [1_000.0, 2_500.0, 5_000.0, 10_000.0] {
        for cap in [8.0e6, 32.0e6, 64.0e6, 128.0e6] {
            let plan = horovod_plan(&spec.model, gpu, cycle, cap);
            let t = eval(&plan);
            if best.as_ref().map(|(b, _)| t < *b).unwrap_or(true) {
                best = Some((t, plan));
            }
        }
    }
    best.unwrap().1
}

/// Shared bucketing logic: walk tensors in backward-production order,
/// close a bucket when the size cap is hit or when the producing op's
/// (serial) completion time crosses into the next fusion cycle.
pub fn horovod_plan(model: &ModelGraph, gpu: &GpuModel, cycle_us: f64, cap_bytes: f64) -> CommPlan {
    // tensor readiness = serial finish time of its producer in BW order
    let mut t = 0.0;
    let mut ready: Vec<(f64, TensorId)> = Vec::new();
    for op in &model.ops {
        if op.kind != OpKind::Backward {
            continue;
        }
        t += op.duration(gpu);
        for &tid in &op.produces {
            ready.push((t, tid));
        }
    }
    let mut groups: Vec<TensorGroup> = Vec::new();
    let mut cur: Vec<TensorId> = Vec::new();
    let mut cur_bytes = 0.0;
    let mut cur_cycle = 0u64;
    for (rt, tid) in ready {
        let bytes = model.tensors[tid as usize].bytes;
        let cyc = (rt / cycle_us) as u64;
        if !cur.is_empty() && (cur_bytes + bytes > cap_bytes || cyc != cur_cycle) {
            groups.push(TensorGroup { tensors: std::mem::take(&mut cur), partitions: 1 });
            cur_bytes = 0.0;
        }
        cur_cycle = cyc;
        cur.push(tid);
        cur_bytes += bytes;
    }
    if !cur.is_empty() {
        groups.push(TensorGroup { tensors: cur, partitions: 1 });
    }
    CommPlan { groups }
}

/// BytePS default: per-tensor groups partitioned into fixed 4 MB slices.
pub fn byteps_default_plan(model: &ModelGraph) -> CommPlan {
    CommPlan {
        groups: (0..model.tensors.len() as TensorId)
            .map(|tid| {
                let bytes = model.tensors[tid as usize].bytes;
                TensorGroup {
                    tensors: vec![tid],
                    partitions: ((bytes / 4.0e6).ceil() as usize).max(1),
                }
            })
            .collect(),
    }
}

/// XLA's default auto-clustering: fuse maximal same-kind chains with no
/// regard for communication overlap (the behaviour Fig. 2(a) criticizes —
/// it delays gradient availability).
pub fn xla_auto_cluster(model: &ModelGraph) -> FusionPlan {
    // fuse runs of same-kind ops along template order whenever the next op
    // directly depends on (any op in) the current cluster
    let mut plan = FusionPlan::singletons(model);
    let mut groups: Vec<Vec<u32>> = Vec::new();
    let mut cur: Vec<u32> = Vec::new();
    // XLA's auto-clustering greedily grows clusters with no regard for
    // gradient availability — "may fuse all back-propagation ops" (§2.3)
    const MAX_CLUSTER: usize = 4096;
    for i in 0..model.ops.len() as u32 {
        let op = &model.ops[i as usize];
        let extends = !cur.is_empty()
            && model.ops[cur[0] as usize].kind == op.kind
            && cur.len() < MAX_CLUSTER
            && op.deps.iter().any(|d| cur.contains(d));
        if extends {
            cur.push(i);
        } else {
            if !cur.is_empty() {
                groups.push(std::mem::take(&mut cur));
            }
            cur.push(i);
        }
    }
    if !cur.is_empty() {
        groups.push(cur);
    }
    plan.groups = groups;
    plan.rebuild_index(model.ops.len());
    plan
}

/// The *deployed-default* job: what a practitioner actually runs before
/// dPRO (Horovod's fusion buckets / BytePS's 4 MB partitions). Used as the
/// ground-truth configuration in Figs. 1 and 7 and the baseline in Fig. 9.
pub fn deployed_default(spec: &JobSpec) -> JobSpec {
    let mut s = spec.clone();
    // server-family schemes ship with BytePS's fixed 4 MB partitions,
    // collective-family schemes with Horovod's fusion buckets
    s.plan = if s.scheme.uses_servers() {
        byteps_default_plan(&s.model)
    } else {
        horovod_default_plan(&s.model, &s.cluster.gpu)
    };
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobSpec, Transport};
    use crate::models;

    #[test]
    fn horovod_buckets_respect_cap() {
        let m = models::by_name("vgg16", 32).unwrap();
        let gpu = GpuModel::default();
        let plan = horovod_default_plan(&m, &gpu);
        assert!(plan.validate(&m).is_ok());
        assert!(plan.groups.len() < m.tensors.len(), "some fusion must happen");
        for (gi, g) in plan.groups.iter().enumerate() {
            let bytes = plan.group_bytes(&m, gi);
            // a single oversized tensor may exceed the cap on its own
            if g.tensors.len() > 1 {
                assert!(bytes <= 64.0e6 * 1.01, "bucket {gi} = {bytes}");
            }
        }
    }

    #[test]
    fn byteps_partitions_4mb() {
        let m = models::by_name("vgg16", 32).unwrap();
        let plan = byteps_default_plan(&m);
        assert!(plan.validate(&m).is_ok());
        // fc1 (411 MB) → ≥ 100 slices
        let fc1 = plan
            .groups
            .iter()
            .max_by(|a, b| {
                let ba = m.tensors[a.tensors[0] as usize].bytes;
                let bb = m.tensors[b.tensors[0] as usize].bytes;
                ba.partial_cmp(&bb).unwrap()
            })
            .unwrap();
        assert!(fc1.partitions >= 100, "partitions={}", fc1.partitions);
        // small tensors stay whole
        assert!(plan.groups.iter().any(|g| g.partitions == 1));
    }

    #[test]
    fn xla_clusters_are_large_and_valid() {
        let m = models::by_name("resnet50", 32).unwrap();
        let plan = xla_auto_cluster(&m);
        assert!(plan.validate(&m).is_ok());
        assert!(plan.groups.len() < m.ops.len() / 3, "clusters={}", plan.groups.len());
        let max = plan.groups.iter().map(|g| g.len()).max().unwrap();
        assert!(max >= 10, "max cluster={max}");
    }

    #[test]
    fn xla_slows_distributed_training() {
        // the paper's Fig. 9 observation: fuse-everything delays gradients
        // and can lose to no-fusion in distributed mode
        let spec = JobSpec::standard("vgg16", "horovod", Transport::Tcp);
        let mut xla = spec.clone();
        xla.fusion = xla_auto_cluster(&xla.model);
        let t_plain = crate::testbed::run(
            &spec,
            &crate::testbed::TestbedOpts { iterations: 3, ..Default::default() },
        )
        .avg_iter();
        let t_xla = crate::testbed::run(
            &xla,
            &crate::testbed::TestbedOpts { iterations: 3, ..Default::default() },
        )
        .avg_iter();
        // XLA wins on pure compute but loses overlap; on a comm-heavy
        // TCP job it must not be dramatically better, and is typically worse
        assert!(t_xla > t_plain * 0.9, "xla={t_xla} plain={t_plain}");
    }

    #[test]
    fn autotune_at_least_matches_default() {
        let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let eval = |plan: &CommPlan| {
            let mut s = spec.clone();
            s.plan = plan.clone();
            let g = crate::graph::build_global(&s, &crate::graph::AnalyticCost::new(&s));
            crate::replay::replay_once(&g).iteration_time
        };
        let default_plan = horovod_default_plan(&spec.model, &spec.cluster.gpu);
        let mut e1 = eval;
        let auto = horovod_autotune_plan(&spec, &mut e1);
        let t_default = e1(&default_plan);
        let t_auto = e1(&auto);
        assert!(t_auto <= t_default * 1.001, "auto={t_auto} default={t_default}");
    }

    #[test]
    fn deployed_default_uses_scheme_plan() {
        let hvd = deployed_default(&JobSpec::standard("resnet50", "horovod", Transport::Rdma));
        assert!(hvd.plan.groups.len() < hvd.model.tensors.len());
        let bps = deployed_default(&JobSpec::standard("resnet50", "byteps", Transport::Rdma));
        assert_eq!(bps.plan.groups.len(), bps.model.tensors.len());
    }
}
