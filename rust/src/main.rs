//! `dpro` CLI — profile / align / replay / optimize / train, mirroring the
//! paper's `dpro profile|replay|optimize` commands (§6).

use dpro::cli;

fn main() {
    let code = cli::run(dpro::util::Args::from_env());
    std::process::exit(code);
}
