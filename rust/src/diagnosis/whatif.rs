//! Transactional what-if queries (the Daydream-style counterfactuals,
//! answered on dPRO's incremental engine): *what would the iteration time
//! be if* the NIC were 2× faster, the straggler GPU ran at the fleet
//! median, one comm chain were free, a kernel were halved?
//!
//! Every query is a pure duration rewrite executed as a
//! [`MutableGraph::begin`] → edit → commit → incremental replay →
//! [`MutableGraph::rollback`] transaction: **zero** `build_global*` calls
//! (pinned by the transaction-counter test in `rust/tests/diagnosis.rs`),
//! and the graph + engine are restored bit-exactly afterwards, so any
//! query sequence leaves no trace. Structural counterfactuals (different
//! fusion/partition plans) are the optimizer's job — the same transaction
//! machinery, one layer up.

use crate::graph::dfg::NodeId;
use crate::graph::MutableGraph;
use crate::replay::incremental::IncrementalReplayer;
use crate::util::json::Json;
use crate::util::Us;

/// One counterfactual. Factors are multiplicative and must be positive
/// ([`parse_whatif`] enforces it); bandwidth factors scale the *speed*, so
/// durations scale by their inverse.
#[derive(Clone, Debug, PartialEq)]
pub enum WhatIfQuery {
    /// Zero every fine-grained communication op — the perfect-overlap
    /// upper bound on any communication optimization.
    PerfectOverlap,
    /// Scale NIC bandwidth by this factor (ops on `LinkTx`/`LinkRx`
    /// devices run `1/factor` as long; the whole op duration is treated
    /// as bandwidth-bound, so per-message overheads scale too — an upper
    /// bound on the real gain).
    ScaleNic(f64),
    /// Scale NVLink bandwidth by this factor (ops on `NvLink` devices).
    ScaleNvlink(f64),
    /// Equalize one straggler worker: every computation op of this worker
    /// runs at the per-fusion-group median across workers.
    EqualizeWorker(u16),
    /// Zero one comm group's synchronization chain (its In/Out stay, its
    /// update op stays — only the fine-grained comm ops become free).
    ZeroGroup(usize),
    /// Scale one fusion group's kernel duration by this factor on every
    /// worker (e.g. `0.5` = a 2× faster kernel).
    ShrinkOp(u32, f64),
    /// Continue the job on `k` surviving workers: the elastic-recovery
    /// counterfactual ("is it worth continuing on 7 after a failure?").
    /// Unlike the duration rewrites above this is a *structural* query —
    /// it runs [`MutableGraph::rescale_workers`] inside the same
    /// begin → replay → rollback transaction, still with zero
    /// `build_global*` calls.
    ContinueOn(usize),
}

impl std::fmt::Display for WhatIfQuery {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WhatIfQuery::PerfectOverlap => write!(f, "perfect-overlap"),
            WhatIfQuery::ScaleNic(x) => write!(f, "nic-bw={x}"),
            WhatIfQuery::ScaleNvlink(x) => write!(f, "nvlink-bw={x}"),
            WhatIfQuery::EqualizeWorker(w) => write!(f, "equalize={w}"),
            WhatIfQuery::ZeroGroup(g) => write!(f, "zero-group={g}"),
            WhatIfQuery::ShrinkOp(op, x) => write!(f, "shrink-op={op}:{x}"),
            WhatIfQuery::ContinueOn(k) => write!(f, "continue-on:{k}"),
        }
    }
}

/// The query forms [`parse_whatif`] / the CLI `--whatif` flag accept.
pub const WHATIF_FORMS: &str = "perfect-overlap, nic-bw=<factor>, nvlink-bw=<factor>, \
     equalize=<worker>, zero-group=<group>, shrink-op=<fusion-group>:<factor>, \
     continue-on:<workers>";

/// Parse a comma-separated what-if list (the CLI `--whatif` value). The
/// [`std::fmt::Display`] form of every query parses back to itself.
pub fn parse_whatif(list: &str) -> Result<Vec<WhatIfQuery>, String> {
    let bad = |tok: &str| format!("invalid what-if query {tok:?}; valid forms: {WHATIF_FORMS}");
    let mut out = Vec::new();
    for raw in list.split(',') {
        let tok = raw.trim();
        if tok.is_empty() {
            continue;
        }
        let q = if tok == "perfect-overlap" {
            WhatIfQuery::PerfectOverlap
        } else if let Some(v) = tok.strip_prefix("nic-bw=") {
            WhatIfQuery::ScaleNic(parse_factor(v).ok_or_else(|| bad(tok))?)
        } else if let Some(v) = tok.strip_prefix("nvlink-bw=") {
            WhatIfQuery::ScaleNvlink(parse_factor(v).ok_or_else(|| bad(tok))?)
        } else if let Some(v) = tok.strip_prefix("equalize=") {
            WhatIfQuery::EqualizeWorker(v.parse::<u16>().map_err(|_| bad(tok))?)
        } else if let Some(v) = tok.strip_prefix("zero-group=") {
            WhatIfQuery::ZeroGroup(v.parse::<usize>().map_err(|_| bad(tok))?)
        } else if let Some(v) = tok.strip_prefix("shrink-op=") {
            let (op, fac) = v.split_once(':').ok_or_else(|| bad(tok))?;
            WhatIfQuery::ShrinkOp(
                op.parse::<u32>().map_err(|_| bad(tok))?,
                parse_factor(fac).ok_or_else(|| bad(tok))?,
            )
        } else if let Some(v) = tok.strip_prefix("continue-on:") {
            let k = v.parse::<usize>().map_err(|_| bad(tok))?;
            if k == 0 {
                return Err(bad(tok));
            }
            WhatIfQuery::ContinueOn(k)
        } else {
            return Err(bad(tok));
        };
        out.push(q);
    }
    if out.is_empty() {
        return Err(format!("empty what-if list; valid forms: {WHATIF_FORMS}"));
    }
    Ok(out)
}

fn parse_factor(s: &str) -> Option<f64> {
    s.parse::<f64>().ok().filter(|f| f.is_finite() && *f > 0.0)
}

/// A replayed counterfactual answer.
#[derive(Clone, Debug)]
pub struct WhatIfAnswer {
    /// The query, in its canonical (re-parseable) form.
    pub query: String,
    /// Replayed iteration time under the counterfactual (us).
    pub iteration_us: Us,
    /// The unmodified plan's replayed iteration time (us).
    pub baseline_us: Us,
    /// `baseline_us / iteration_us`.
    pub speedup: f64,
    /// Number of op durations the query actually changed (0 means the
    /// query had no grip — e.g. scaling a NIC no op uses).
    pub edited_ops: usize,
}

impl WhatIfAnswer {
    /// Schema-stable JSON row (`query`, `iteration_us`, `baseline_us`,
    /// `speedup`, `edited_ops`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("query", Json::Str(self.query.clone()));
        o.set("iteration_us", Json::Num(self.iteration_us));
        o.set("baseline_us", Json::Num(self.baseline_us));
        o.set("speedup", Json::Num(self.speedup));
        o.set("edited_ops", Json::Num(self.edited_ops as f64));
        o
    }
}

/// The duration edits a query implies, gathered against the *current*
/// graph state (immutable pass), so the mutable apply loop holds no
/// conflicting borrows.
fn gather_edits(mg: &MutableGraph, q: &WhatIfQuery) -> Vec<(NodeId, f64)> {
    use crate::graph::dfg::DeviceKey;
    let dfg = mg.dfg();
    let alive = mg.alive();
    let mut edits = Vec::new();
    match *q {
        WhatIfQuery::PerfectOverlap => {
            for i in dfg.ids() {
                let n = dfg.node(i);
                if alive[i as usize] && n.kind.is_comm() && n.duration != 0.0 {
                    edits.push((i, 0.0));
                }
            }
        }
        WhatIfQuery::ScaleNic(f) => {
            for i in dfg.ids() {
                let n = dfg.node(i);
                if alive[i as usize]
                    && matches!(n.device, DeviceKey::LinkTx(_) | DeviceKey::LinkRx(_))
                {
                    edits.push((i, n.duration / f));
                }
            }
        }
        WhatIfQuery::ScaleNvlink(f) => {
            for i in dfg.ids() {
                let n = dfg.node(i);
                if alive[i as usize] && matches!(n.device, DeviceKey::NvLink(_)) {
                    edits.push((i, n.duration / f));
                }
            }
        }
        WhatIfQuery::EqualizeWorker(w) => {
            let n_workers = mg.n_workers();
            if (w as usize) < n_workers {
                let n_groups = mg.spec().fusion.groups.len();
                for fg in 0..n_groups {
                    // median over the OTHER workers: including `w` itself
                    // would make equalizing the straggler of a 2-worker
                    // job a no-op (the upper median is its own duration)
                    let mut durs: Vec<f64> = (0..n_workers as u16)
                        .filter(|&wi| wi != w)
                        .filter_map(|wi| mg.comp_node(wi, fg as u32))
                        .map(|id| dfg.node(id).duration)
                        .collect();
                    if durs.is_empty() {
                        continue;
                    }
                    durs.sort_by(f64::total_cmp);
                    let median = durs[durs.len() / 2];
                    if let Some(id) = mg.comp_node(w, fg as u32) {
                        edits.push((id, median));
                    }
                }
            }
        }
        WhatIfQuery::ZeroGroup(gi) => {
            if gi < mg.n_groups() {
                for id in mg.group_nodes_iter(gi) {
                    if alive[id as usize] && dfg.node(id).kind.is_comm() {
                        edits.push((id, 0.0));
                    }
                }
            }
        }
        WhatIfQuery::ShrinkOp(fg, f) => {
            if (fg as usize) < mg.spec().fusion.groups.len() {
                for w in 0..mg.n_workers() as u16 {
                    if let Some(id) = mg.comp_node(w, fg) {
                        if alive[id as usize] {
                            edits.push((id, dfg.node(id).duration * f));
                        }
                    }
                }
            }
        }
        // structural query: no duration edits — run_query dispatches it
        // to the rescale primitive instead
        WhatIfQuery::ContinueOn(_) => {}
    }
    edits
}

/// Answer one query: apply its duration edits inside a transaction,
/// replay incrementally, then roll back and replay again so the engine's
/// cached schedule is restored bit-exactly. Never constructs a graph.
pub(crate) fn run_query(
    mg: &mut MutableGraph,
    eng: &mut IncrementalReplayer,
    baseline_us: Us,
    q: &WhatIfQuery,
) -> WhatIfAnswer {
    let edits = gather_edits(mg, q);
    let txn = mg.begin();
    let mut edited = 0usize;
    for (id, dur) in edits {
        edited += mg.override_duration(id, dur) as usize;
    }
    if let WhatIfQuery::ContinueOn(k) = *q {
        // the elastic-recovery counterfactual: shrink the fleet inside
        // the transaction (k >= current fleet is a no-op answer — the
        // job already runs on that many workers or fewer)
        if k < mg.n_workers() {
            edited += mg.rescale_workers(k).unwrap_or(0);
        }
    }
    let log = mg.commit();
    let iteration_us = eng.replay_incremental(mg, &log).iteration_time;
    mg.rollback(txn);
    let log = mg.commit();
    eng.replay_incremental(mg, &log);
    WhatIfAnswer {
        query: q.to_string(),
        iteration_us,
        baseline_us,
        speedup: if iteration_us > 0.0 { baseline_us / iteration_us } else { f64::INFINITY },
        edited_ops: edited,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobSpec, Transport};

    #[test]
    fn parse_roundtrips_and_rejects() {
        let qs = parse_whatif(
            "perfect-overlap, nic-bw=2, nvlink-bw=1.5, equalize=3, zero-group=0, \
             shrink-op=5:0.5, continue-on:7",
        )
        .unwrap();
        assert_eq!(qs.len(), 7);
        for q in &qs {
            assert_eq!(parse_whatif(&q.to_string()).unwrap(), vec![q.clone()]);
        }
        for bad in
            ["warp-drive", "nic-bw=0", "nic-bw=-2", "shrink-op=5", "equalize=x", "continue-on:0", ""]
        {
            let err = parse_whatif(bad).unwrap_err();
            assert!(err.contains("perfect-overlap"), "{bad}: {err}");
        }
    }

    #[test]
    fn queries_move_time_the_right_way_and_restore() {
        let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
        let mut mg = crate::graph::MutableGraph::new(spec);
        let mut eng = crate::replay::incremental::IncrementalReplayer::new();
        let log = mg.commit();
        let base = eng.replay_incremental(&mg, &log).iteration_time;

        let faster = run_query(&mut mg, &mut eng, base, &WhatIfQuery::ScaleNic(4.0));
        assert!(faster.edited_ops > 0);
        assert!(faster.iteration_us < base, "4x NIC must help a comm-bound job");
        let slower = run_query(&mut mg, &mut eng, base, &WhatIfQuery::ScaleNic(0.25));
        assert!(slower.iteration_us > base, "a 4x slower NIC must hurt");
        let po = run_query(&mut mg, &mut eng, base, &WhatIfQuery::PerfectOverlap);
        assert!(po.iteration_us <= faster.iteration_us, "perfect overlap dominates");
        assert!(po.speedup >= 1.0);

        // engine restored after every query: the baseline replays exactly
        let log = mg.commit();
        assert!(log.is_empty(mg.dfg().len()), "rollback left pending changes");
        assert_eq!(eng.replay_incremental(&mg, &log).iteration_time, base);
    }

    #[test]
    fn continue_on_rescales_and_restores() {
        let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
        let n = spec.cluster.n_workers;
        let mut mg = crate::graph::MutableGraph::new(spec);
        let mut eng = crate::replay::incremental::IncrementalReplayer::new();
        let log = mg.commit();
        let base = eng.replay_incremental(&mg, &log).iteration_time;

        let a = run_query(&mut mg, &mut eng, base, &WhatIfQuery::ContinueOn(n - 1));
        assert!(a.edited_ops > 0, "the departing worker owns nodes");
        assert!(a.iteration_us.is_finite() && a.iteration_us > 0.0);
        // the fleet is restored: same worker count, same baseline replay
        assert_eq!(mg.n_workers(), n);
        assert_eq!(mg.spec().cluster.n_workers, n);
        let log = mg.commit();
        assert_eq!(eng.replay_incremental(&mg, &log).iteration_time, base);

        // k >= n answers the baseline without touching the graph
        let noop = run_query(&mut mg, &mut eng, base, &WhatIfQuery::ContinueOn(n + 5));
        assert_eq!(noop.edited_ops, 0);
        assert_eq!(noop.iteration_us, base);
    }
}
