//! The diagnosis engine (paper §1: *"identify the root cause(s) of
//! inefficiency"* before optimizing): critical-path blame attribution,
//! bottleneck ranking, and transactional what-if queries — the subsystem
//! behind `dpro diagnose`.
//!
//! Three layers, each usable on its own:
//!
//! - [`critical`] — decompose the replayed schedule: the critical path and
//!   every device timeline split into compute / communication /
//!   blocked-on-sync, under a **bit-exact sum contract** (each row's
//!   categories sum to the replayed iteration time exactly), plus
//!   per-comm-group / per-fusion-group path blame ([`GroupBlame`] — also
//!   what the optimizer's [`crate::optimizer::strategy::SearchCtx`]
//!   exposes so strategies visit high-blame candidates first).
//! - [`rank`](mod@rank) — turn blame into an ordered list of actionable
//!   [`Bottleneck`]s (slowest rank, straggler machines via trace
//!   drift/stretch, dominating comm stage classes, hot groups), scored by
//!   estimated headroom.
//! - [`whatif`] — replayed counterfactuals (scale NIC/NVLink bandwidth,
//!   equalize a straggler, zero a comm chain, shrink a kernel), each a
//!   `begin → edit durations → incremental replay → rollback` transaction
//!   on the long-lived [`MutableGraph`]: zero `build_global*` calls, and
//!   the graph + engine restored bit-exactly after any query sequence.
//!
//! [`Diagnoser`] ties the layers together over one long-lived graph +
//! incremental engine, built either from a job spec (analytic durations)
//! or from a measured/dumped trace ([`Diagnoser::from_trace`] — tolerant:
//! a degraded trace yields a diagnosis with [`TraceReport`] warnings,
//! never a panic). [`DiagnosisReport::to_json`] is the schema-stable
//! surface `dpro diagnose --json` prints; see `docs/DIAGNOSIS.md`.

pub mod critical;
pub mod rank;
pub mod whatif;

pub use critical::{blame, group_blame, BlameReport, DeviceBlame, GroupBlame, PathBlame};
pub use rank::{rank, Bottleneck, BottleneckKind, TraceFacts};
pub use whatif::{parse_whatif, WhatIfAnswer, WhatIfQuery, WHATIF_FORMS};

use crate::config::JobSpec;
use crate::graph::{build_count, build_global, AnalyticCost, MutableGraph};
use crate::replay::incremental::IncrementalReplayer;
use crate::replay::ReplayResult;
use crate::trace::validate::{DiagKind, Severity, TraceReport};
use crate::trace::GTrace;
use crate::util::json::Json;
use crate::util::Us;

/// One diagnosis session: a long-lived [`MutableGraph`] + incremental
/// engine over one job, with the baseline schedule cached. All analytics
/// read the baseline; what-if queries borrow the graph transactionally
/// and restore it, so a `Diagnoser` can answer any number of queries
/// without ever rebuilding (tracked by [`Diagnoser::builds_during_queries`]).
pub struct Diagnoser {
    mg: MutableGraph,
    eng: IncrementalReplayer,
    baseline: ReplayResult,
    report: TraceReport,
    facts: Option<TraceFacts>,
    builds_at_ready: usize,
    queries_run: usize,
}

impl Diagnoser {
    /// Diagnose a job spec with analytic (cost-model) durations — the
    /// no-trace path, one graph construction total.
    pub fn new(spec: JobSpec) -> Diagnoser {
        Diagnoser::assemble(MutableGraph::new(spec), TraceReport::default(), None)
    }

    /// Diagnose a measured trace: solve clock alignment, build the job's
    /// *named* skeleton, join the corrected per-op profile onto it, and
    /// replay. `report` should be the ingestion report (from
    /// [`crate::trace::io::load_dir`], or a fresh default plus
    /// [`crate::trace::validate::validate`] for in-memory traces); ops
    /// the trace does not cover keep analytic durations and are flagged
    /// as a `missing_profile` warning — a degraded trace degrades the
    /// diagnosis, it never panics it.
    pub fn from_trace(spec: JobSpec, trace: &GTrace, mut report: TraceReport) -> Diagnoser {
        let alignment = crate::alignment::align(trace, 1.0, 1.0);
        let db = crate::profiler::corrected_profile(trace, &alignment);
        let mut g = build_global(&spec, &AnalyticCost::new(&spec));
        let profiled = db.apply(&mut g);
        let non_virtual = g.dfg.nodes.iter().filter(|n| !n.kind.is_virtual()).count();
        if profiled < non_virtual {
            report.push(
                Severity::Warning,
                DiagKind::MissingProfile,
                format!(
                    "{} of {} graph ops have no measured duration (dropped events or a \
                     partial dump); analytic estimates fill the gaps, so blame on those \
                     ops is model-derived",
                    non_virtual - profiled,
                    non_virtual
                ),
            );
        }
        // reuse the alignment solved for the corrected profile above —
        // the §4.2 solve is the expensive ingestion step
        let facts = TraceFacts::from_trace_aligned(trace, &alignment);
        // fault evidence becomes diagnostics, not errors: a trace with a
        // crashed worker or a sick NIC still yields a full diagnosis (the
        // ranking and the continue-on what-if pick the evidence up)
        for &(w, from_iter) in &facts.lost_workers {
            report.push(
                Severity::Warning,
                DiagKind::WorkerLost,
                format!("w{w}: no events from iteration {from_iter} on"),
            );
        }
        for &(m, stretch) in &facts.machine_comm_stretch {
            if stretch >= rank::LINK_DEGRADED_FACTOR {
                report.push(
                    Severity::Warning,
                    DiagKind::LinkDegraded,
                    format!(
                        "machine{m}: SEND/RECV durations {stretch:.1}x the fleet median"
                    ),
                );
            }
        }
        Diagnoser::assemble(MutableGraph::from_built(spec, g), report, Some(facts))
    }

    fn assemble(
        mut mg: MutableGraph,
        report: TraceReport,
        facts: Option<TraceFacts>,
    ) -> Diagnoser {
        let mut eng = IncrementalReplayer::new();
        let log = mg.commit();
        let baseline = eng.replay_incremental(&mg, &log).clone();
        Diagnoser {
            builds_at_ready: build_count(),
            mg,
            eng,
            baseline,
            report,
            facts,
            queries_run: 0,
        }
    }

    /// The diagnosed job's spec.
    pub fn spec(&self) -> &JobSpec {
        self.mg.spec()
    }

    /// The long-lived graph (restored bit-exactly between queries).
    pub fn mg(&self) -> &MutableGraph {
        &self.mg
    }

    /// The incremental engine (its cached schedule equals the baseline
    /// between queries).
    pub fn engine(&self) -> &IncrementalReplayer {
        &self.eng
    }

    /// The baseline replayed schedule all analytics decompose.
    pub fn baseline(&self) -> &ReplayResult {
        &self.baseline
    }

    /// Baseline replayed iteration time (us).
    pub fn baseline_us(&self) -> Us {
        self.baseline.iteration_time
    }

    /// Ingestion/diagnosis warnings accumulated so far.
    pub fn trace_report(&self) -> &TraceReport {
        &self.report
    }

    /// Global-DFG constructions since this diagnoser became ready — the
    /// what-if machinery keeps it at 0 (transaction-counter test).
    ///
    /// The underlying counter is thread-local, so when one diagnoser is
    /// driven from several threads (the serve session engine hands it
    /// from worker to worker under a mutex) the difference saturates at 0
    /// rather than underflowing; the zero-builds guarantee itself is
    /// enforced by the transaction machinery and pinned by the
    /// single-threaded tests.
    pub fn builds_during_queries(&self) -> usize {
        build_count().saturating_sub(self.builds_at_ready)
    }

    /// What-if queries answered so far.
    pub fn queries_run(&self) -> usize {
        self.queries_run
    }

    /// Blame decomposition of the baseline schedule (see
    /// [`critical::blame`]).
    pub fn blame(&self) -> BlameReport {
        critical::blame(&self.mg, &self.baseline)
    }

    /// Per-group critical-path blame of the baseline schedule.
    pub fn group_blame(&self) -> GroupBlame {
        critical::group_blame(&self.mg, &self.baseline)
    }

    /// Ranked bottlenecks of the baseline (trace facts included when this
    /// diagnoser was built from a trace).
    pub fn rank(&self) -> Vec<Bottleneck> {
        let b = self.blame();
        let gb = self.group_blame();
        rank::rank(&self.mg, &self.baseline, &b, &gb, self.facts.as_ref())
    }

    /// Answer one counterfactual (transactional — the graph and engine
    /// are restored before this returns).
    pub fn what_if(&mut self, q: &WhatIfQuery) -> WhatIfAnswer {
        self.queries_run += 1;
        whatif::run_query(&mut self.mg, &mut self.eng, self.baseline.iteration_time, q)
    }

    /// The standard query battery, seeded by the ranking: the
    /// perfect-overlap bound, 2× NIC and NVLink bandwidth, the slowest
    /// rank equalized, the hottest comm chain zeroed, and the hottest
    /// kernel halved — at least four distinct query kinds on any job.
    /// When the trace shows lost workers (and ≥ 2 survive), the battery
    /// also prices `continue-on:<survivors>` — the elastic replan.
    pub fn auto_queries(&self) -> Vec<WhatIfQuery> {
        let mut qs = vec![
            WhatIfQuery::PerfectOverlap,
            WhatIfQuery::ScaleNic(2.0),
            WhatIfQuery::ScaleNvlink(2.0),
        ];
        // slowest rank from replayed GPU busy time
        let dfg = self.mg.dfg();
        let alive = self.mg.alive();
        let mut busy = vec![0.0f64; self.mg.n_workers()];
        for i in dfg.ids() {
            if !alive[i as usize] {
                continue;
            }
            if let crate::graph::DeviceKey::Gpu(w) = dfg.node(i).device {
                if (w as usize) < busy.len() {
                    busy[w as usize] +=
                        self.baseline.end[i as usize] - self.baseline.start[i as usize];
                }
            }
        }
        if let Some((w, _)) =
            busy.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1))
        {
            qs.push(WhatIfQuery::EqualizeWorker(w as u16));
        }
        let gb = self.group_blame();
        if let Some(gi) = gb.hottest_comm_group() {
            qs.push(WhatIfQuery::ZeroGroup(gi));
        }
        if let Some(fg) = gb.hottest_fusion_group() {
            qs.push(WhatIfQuery::ShrinkOp(fg as u32, 0.5));
        }
        // trace shows lost workers → price finishing on the survivors
        // (elastic replan; only when ≥ 2 survive — a 1-worker "fleet" has
        // nothing to communicate and is better restarted)
        if let Some(f) = &self.facts {
            let lost = f.lost_workers.len();
            let survivors = self.mg.n_workers().saturating_sub(lost);
            if lost > 0 && survivors >= 2 {
                qs.push(WhatIfQuery::ContinueOn(survivors));
            }
        }
        qs
    }

    /// Run the transactional optimizer (Alg. 1) **on this diagnoser's
    /// resident graph**, with the default strategy set derived from
    /// `opts` — the serve session's writer path. Accepted candidates
    /// commit through the transaction journal and become the new
    /// baseline; rejected ones roll back bit-exactly, so a search that
    /// accepts nothing leaves every subsequent query answer unchanged.
    /// Coarsened-view setup is skipped (it would force a rebuild); see
    /// [`crate::optimizer::search::optimize_resident`].
    pub fn optimize(&mut self, opts: &crate::optimizer::SearchOpts) -> crate::optimizer::SearchOutcome {
        self.optimize_with(opts, crate::optimizer::strategy::strategies_from_opts(opts))
    }

    /// [`Self::optimize`] with an explicit strategy set.
    pub fn optimize_with(
        &mut self,
        opts: &crate::optimizer::SearchOpts,
        strategies: Vec<Box<dyn crate::optimizer::strategy::Strategy>>,
    ) -> crate::optimizer::SearchOutcome {
        let out = crate::optimizer::search::optimize_resident(
            &mut self.mg,
            &mut self.eng,
            opts,
            strategies,
        );
        // committed decisions changed the schedule: refresh the cached
        // baseline every analytic reads (a no-accept search replays to
        // the identical schedule — rollback equivalence)
        let log = self.mg.commit();
        self.baseline = self.eng.replay_incremental(&self.mg, &log).clone();
        // setup builds (t_sync probe engines) are excluded from the query
        // counter exactly like the initial construction; the round loop's
        // own builds are reported in `SearchOutcome::builds_during_search`
        self.builds_at_ready = build_count();
        out
    }

    /// Run the full diagnosis: blame, ranked bottlenecks (truncated to
    /// `top`), and the given what-if battery. One bundle, ready for
    /// [`DiagnosisReport::to_json`].
    pub fn report(&mut self, queries: &[WhatIfQuery], top: usize) -> DiagnosisReport {
        let blame = self.blame();
        let mut bottlenecks = self.rank();
        bottlenecks.truncate(top);
        let whatif: Vec<WhatIfAnswer> = queries.iter().map(|q| self.what_if(q)).collect();
        let spec = self.mg.spec();
        DiagnosisReport {
            model: spec.model.name.clone(),
            scheme: spec.scheme.cli_name().to_string(),
            transport: spec.cluster.network.transport.name().to_lowercase(),
            workers: spec.cluster.n_workers,
            iteration_us: blame.iteration_us,
            blame,
            bottlenecks,
            whatif,
            builds_during_queries: self.builds_during_queries(),
            trace: self.report.clone(),
        }
    }
}

/// The full diagnosis of one job — the stable payload behind
/// `dpro diagnose --json` (schema in `docs/DIAGNOSIS.md`).
#[derive(Clone, Debug)]
pub struct DiagnosisReport {
    /// Model template name.
    pub model: String,
    /// Canonical scheme name (a [`crate::config::ALL_SCHEMES`] entry).
    pub scheme: String,
    /// Transport name, lower-case.
    pub transport: String,
    /// Worker count.
    pub workers: usize,
    /// Baseline replayed iteration time (us).
    pub iteration_us: Us,
    /// Blame decomposition (path + devices, exact-sum contract).
    pub blame: BlameReport,
    /// Ranked bottlenecks (top-N by estimated headroom).
    pub bottlenecks: Vec<Bottleneck>,
    /// Replayed counterfactual answers.
    pub whatif: Vec<WhatIfAnswer>,
    /// Global-DFG constructions the queries performed (always 0).
    pub builds_during_queries: usize,
    /// Ingestion/diagnosis warnings (`TraceReport` schema; empty counters
    /// for the no-trace path).
    pub trace: TraceReport,
}

impl DiagnosisReport {
    /// Schema-stable JSON: `model`, `scheme`, `transport`, `workers`,
    /// `iteration_us`, `blame{...}`, `bottlenecks[...]`, `whatif[...]`,
    /// `builds_during_queries`, `report{...}` (the
    /// [`TraceReport::to_json`] schema). Keys are asserted by the CI
    /// smoke step; see `docs/DIAGNOSIS.md`.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("model", Json::Str(self.model.clone()));
        j.set("scheme", Json::Str(self.scheme.clone()));
        j.set("transport", Json::Str(self.transport.clone()));
        j.set("workers", Json::Num(self.workers as f64));
        j.set("iteration_us", Json::Num(self.iteration_us));
        j.set("blame", self.blame.to_json());
        j.set(
            "bottlenecks",
            Json::Arr(self.bottlenecks.iter().map(Bottleneck::to_json).collect()),
        );
        j.set(
            "whatif",
            Json::Arr(self.whatif.iter().map(WhatIfAnswer::to_json).collect()),
        );
        j.set(
            "builds_during_queries",
            Json::Num(self.builds_during_queries as f64),
        );
        j.set("report", self.trace.to_json());
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Transport;

    #[test]
    fn diagnoser_answers_auto_battery_without_builds() {
        let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
        let mut d = Diagnoser::new(spec);
        let qs = d.auto_queries();
        // at least 4 distinct query kinds
        let kinds: std::collections::HashSet<std::mem::Discriminant<WhatIfQuery>> =
            qs.iter().map(std::mem::discriminant).collect();
        assert!(kinds.len() >= 4, "only {} query kinds", kinds.len());
        let rep = d.report(&qs, 5);
        assert_eq!(rep.builds_during_queries, 0);
        assert_eq!(rep.whatif.len(), qs.len());
        assert!(rep.iteration_us > 0.0);
        assert!(!rep.bottlenecks.is_empty());
        // JSON surface parses back with the documented keys
        let parsed = crate::util::json::parse(&rep.to_json().to_string()).unwrap();
        for key in [
            "model",
            "scheme",
            "transport",
            "workers",
            "iteration_us",
            "blame",
            "bottlenecks",
            "whatif",
            "builds_during_queries",
            "report",
        ] {
            assert!(parsed.get(key).is_some(), "missing key {key}");
        }
        assert_eq!(parsed.f64("builds_during_queries"), 0.0);
    }
}
