//! Critical-path analytics over a replayed schedule (paper §1's "identify
//! the root cause(s) of inefficiency"): extract the execution graph's
//! critical path, decompose it — and every device's timeline — into
//! compute / communication / blocked-on-sync time, and attribute
//! critical-path time to the plan entities the optimizer can actually act
//! on (comm groups, fusion groups).
//!
//! ## The exact-sum contract
//!
//! Every decomposition in this module satisfies, **bit-for-bit**,
//!
//! ```text
//! (comp_us + comm_us) + blocked_us == iteration_us
//! ```
//!
//! evaluated left-to-right in `f64`. Busy categories are plain sums of
//! schedule spans; `blocked_us` is the *residual* — semantically the time
//! the resource (or the path) spent waiting on synchronization — computed
//! by [`exact_residual`], which nudges the naive `total − busy` difference
//! by at most a few ULPs until the identity holds exactly. On the critical
//! path the engine guarantees no gaps (every instant of `[0, T]` is inside
//! some path op's span), so the path's `blocked_us` is always within a few
//! ULPs of zero; per-device rows carry the real idle time. Tests sweep the
//! contract across `ALL_SCHEMES` × models (`rust/tests/diagnosis.rs`).

use crate::graph::dfg::{DeviceKey, NodeId};
use crate::graph::MutableGraph;
use crate::replay::ReplayResult;
use crate::util::json::Json;
use crate::util::Us;

/// Critical-path blame: where the iteration's end-to-end time was spent.
#[derive(Clone, Copy, Debug)]
pub struct PathBlame {
    /// Path time inside computation ops (FW/BW/UPD), us.
    pub comp_us: Us,
    /// Path time inside fine-grained communication ops
    /// (SEND/RECV/NEG/AGG), us.
    pub comm_us: Us,
    /// Residual so the exact-sum contract holds (see module docs); within
    /// a few ULPs of zero because the replayed critical path has no gaps.
    pub blocked_us: Us,
    /// Number of ops on the critical path.
    pub ops: usize,
}

/// One execution resource's timeline over `[0, iteration_us]`.
#[derive(Clone, Debug)]
pub struct DeviceBlame {
    /// Short resource label (`gpu3`, `tx1`, `rx1`, `ps0`, `nvlink1`,
    /// `coord`).
    pub device: String,
    /// Resource class (`gpu`, `nic-tx`, `nic-rx`, `ps-cpu`, `nvlink`,
    /// `coordinator`).
    pub class: &'static str,
    /// Busy time inside computation ops, us.
    pub comp_us: Us,
    /// Busy time inside communication ops, us.
    pub comm_us: Us,
    /// Idle / blocked-on-sync time (exact residual against the iteration
    /// time; can be a few ULPs negative from float rounding of the busy
    /// sums — the exact-sum contract is the invariant, not the sign).
    pub blocked_us: Us,
}

/// The full blame report of one replayed iteration.
#[derive(Clone, Debug)]
pub struct BlameReport {
    /// Replayed iteration time (us) every row decomposes.
    pub iteration_us: Us,
    /// Critical-path decomposition.
    pub path: PathBlame,
    /// Per-device timeline decompositions, sorted by (class, device).
    pub devices: Vec<DeviceBlame>,
}

impl BlameReport {
    /// Verify the exact-sum contract on the path and on every device row.
    /// Returns the first violated row's description, if any (the property
    /// tests call this; production code may `debug_assert!` it).
    pub fn check(&self) -> Result<(), String> {
        let t = self.iteration_us;
        let p = &self.path;
        if (p.comp_us + p.comm_us) + p.blocked_us != t {
            return Err(format!(
                "path blame {} + {} + {} != {t}",
                p.comp_us, p.comm_us, p.blocked_us
            ));
        }
        for d in &self.devices {
            if (d.comp_us + d.comm_us) + d.blocked_us != t {
                return Err(format!(
                    "device {} blame {} + {} + {} != {t}",
                    d.device, d.comp_us, d.comm_us, d.blocked_us
                ));
            }
        }
        Ok(())
    }

    /// Schema-stable JSON (`iteration_us`, `path{comp_us, comm_us,
    /// blocked_us, ops}`, `devices[{device, class, comp_us, comm_us,
    /// blocked_us}]`) — part of `dpro diagnose --json` (see
    /// `docs/DIAGNOSIS.md`).
    pub fn to_json(&self) -> Json {
        let mut p = Json::obj();
        p.set("comp_us", Json::Num(self.path.comp_us));
        p.set("comm_us", Json::Num(self.path.comm_us));
        p.set("blocked_us", Json::Num(self.path.blocked_us));
        p.set("ops", Json::Num(self.path.ops as f64));
        let devices: Vec<Json> = self
            .devices
            .iter()
            .map(|d| {
                let mut o = Json::obj();
                o.set("device", Json::Str(d.device.clone()));
                o.set("class", Json::Str(d.class.to_string()));
                o.set("comp_us", Json::Num(d.comp_us));
                o.set("comm_us", Json::Num(d.comm_us));
                o.set("blocked_us", Json::Num(d.blocked_us));
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("iteration_us", Json::Num(self.iteration_us));
        j.set("path", p);
        j.set("devices", Json::Arr(devices));
        j
    }
}

/// Resource class of a device key (report labels; `Null` never appears in
/// blame rows).
pub fn device_class(d: DeviceKey) -> &'static str {
    match d {
        DeviceKey::Gpu(_) => "gpu",
        DeviceKey::LinkTx(_) => "nic-tx",
        DeviceKey::LinkRx(_) => "nic-rx",
        DeviceKey::PsCpu(_) => "ps-cpu",
        DeviceKey::NvLink(_) => "nvlink",
        DeviceKey::Coordinator => "coordinator",
        DeviceKey::Null => "null",
    }
}

/// Short label of a device key (`gpu3`, `tx1`, ...).
pub fn device_label(d: DeviceKey) -> String {
    match d {
        DeviceKey::Gpu(w) => format!("gpu{w}"),
        DeviceKey::LinkTx(n) => format!("tx{n}"),
        DeviceKey::LinkRx(n) => format!("rx{n}"),
        DeviceKey::PsCpu(s) => format!("ps{s}"),
        DeviceKey::NvLink(m) => format!("nvlink{m}"),
        DeviceKey::Coordinator => "coord".to_string(),
        DeviceKey::Null => "null".to_string(),
    }
}

/// Find the `f64` residual `x` such that `busy + x == total` **exactly**
/// under one left-to-right `f64` addition. Starts from the naive
/// difference and steps by single ULPs; since `busy ≥ 0` implies
/// `ulp(x) ≤ ulp(busy + x)`, each step moves the rounded sum by at most
/// one representable value, so the walk cannot skip `total`. The initial
/// error is a few ULPs at most, so the loop terminates almost
/// immediately; non-finite inputs (impossible for replay schedules) fall
/// back to the naive difference.
pub fn exact_residual(total: f64, busy: f64) -> f64 {
    let mut x = total - busy;
    if !total.is_finite() || !busy.is_finite() || !x.is_finite() {
        return x;
    }
    for _ in 0..256 {
        let s = busy + x;
        if s == total {
            return x;
        }
        x = step_ulp(x, s < total);
    }
    // unreachable in practice (see the doc comment); keep the closest
    // candidate rather than aborting a diagnosis
    x
}

/// One ULP toward +∞ (`up`) or −∞ (`!up`), without the still-recent
/// `f64::next_up` API.
fn step_ulp(x: f64, up: bool) -> f64 {
    if x == 0.0 {
        let tiny = f64::from_bits(1); // smallest positive subnormal
        return if up { tiny } else { -tiny };
    }
    let bits = x.to_bits();
    // for positive x, +1 in bit space moves away from zero (toward +inf);
    // for negative x it moves toward -inf, i.e. also away from zero
    let away = (x > 0.0) == up;
    f64::from_bits(if away { bits + 1 } else { bits - 1 })
}

/// Decompose the replayed schedule: critical-path blame plus every
/// device's timeline. `r` must be the replay of `mg`'s current state (the
/// [`crate::diagnosis::Diagnoser`] guarantees this pairing).
pub fn blame(mg: &MutableGraph, r: &ReplayResult) -> BlameReport {
    let dfg = mg.dfg();
    let alive = mg.alive();
    let t = r.iteration_time;

    // ---- critical path ----
    let path = r.critical_path();
    let mut p_comp = 0.0f64;
    let mut p_comm = 0.0f64;
    for &n in &path {
        let i = n as usize;
        let seg = r.end[i] - r.start[i];
        let kind = dfg.node(n).kind;
        if kind.is_comp() {
            p_comp += seg;
        } else if kind.is_comm() {
            p_comm += seg;
        }
        // virtual In/Out ops have zero duration and contribute nothing
    }
    let p_blocked = exact_residual(t, p_comp + p_comm);

    // ---- per-device timelines ----
    let mut per_dev: std::collections::HashMap<DeviceKey, (f64, f64)> =
        std::collections::HashMap::new();
    for i in dfg.ids() {
        if !alive[i as usize] {
            continue;
        }
        let node = dfg.node(i);
        if node.device == DeviceKey::Null {
            continue;
        }
        let seg = r.end[i as usize] - r.start[i as usize];
        let ent = per_dev.entry(node.device).or_insert((0.0, 0.0));
        if node.kind.is_comp() {
            ent.0 += seg;
        } else {
            ent.1 += seg;
        }
    }
    let mut keys: Vec<DeviceKey> = per_dev.keys().copied().collect();
    keys.sort();
    let devices: Vec<DeviceBlame> = keys
        .into_iter()
        .map(|k| {
            let (comp, comm) = per_dev[&k];
            DeviceBlame {
                device: device_label(k),
                class: device_class(k),
                comp_us: comp,
                comm_us: comm,
                blocked_us: exact_residual(t, comp + comm),
            }
        })
        .collect();

    BlameReport {
        iteration_us: t,
        path: PathBlame {
            comp_us: p_comp,
            comm_us: p_comm,
            blocked_us: p_blocked,
            ops: path.len(),
        },
        devices,
    }
}

/// Critical-path time attributed to the plan entities the optimizer acts
/// on — the ranking [`crate::optimizer::strategy::SearchCtx`] exposes so
/// strategies visit high-blame candidates first.
#[derive(Clone, Debug, Default)]
pub struct GroupBlame {
    /// Path time of each comm group's synchronization ops (indexed by the
    /// *current* plan index), us.
    pub comm_us: Vec<Us>,
    /// Path time of each fusion group's computation ops (indexed by the
    /// current fusion-group index), us.
    pub comp_us: Vec<Us>,
}

impl GroupBlame {
    /// Comm-group index with the largest path blame, if any is nonzero.
    pub fn hottest_comm_group(&self) -> Option<usize> {
        argmax_positive(&self.comm_us)
    }

    /// Fusion-group index with the largest path blame, if any is nonzero.
    pub fn hottest_fusion_group(&self) -> Option<usize> {
        argmax_positive(&self.comp_us)
    }
}

fn argmax_positive(xs: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &x) in xs.iter().enumerate() {
        if x > 0.0 && best.map_or(true, |(_, b)| x > b) {
            best = Some((i, x));
        }
    }
    best.map(|(i, _)| i)
}

/// Attribute critical-path time per comm group and per fusion group.
/// Comp ops blame through their `template_id` (fusion-group index); comm
/// and virtual ops through their `TensorMeta::tensor_id`, which
/// [`MutableGraph`] keeps equal to the current comm-group index.
pub fn group_blame(mg: &MutableGraph, r: &ReplayResult) -> GroupBlame {
    let dfg = mg.dfg();
    let spec = mg.spec();
    let mut gb = GroupBlame {
        comm_us: vec![0.0; spec.plan.groups.len()],
        comp_us: vec![0.0; spec.fusion.groups.len()],
    };
    let mut cur = Some(r.last);
    while let Some(n) = cur {
        let i = n as usize;
        let seg = r.end[i] - r.start[i];
        let node = dfg.node(n as NodeId);
        if node.kind.is_comp() {
            if let Some(fg) = node.template_id {
                if let Some(slot) = gb.comp_us.get_mut(fg as usize) {
                    *slot += seg;
                }
            }
        } else if let Some(tm) = node.tensor {
            if let Some(slot) = gb.comm_us.get_mut(tm.tensor_id as usize) {
                *slot += seg;
            }
        }
        cur = r.crit_pred[i];
    }
    gb
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobSpec, Transport};
    use crate::replay::incremental::IncrementalReplayer;

    fn diag(model: &str, scheme: &str) -> (MutableGraph, IncrementalReplayer) {
        let spec = JobSpec::standard(model, scheme, Transport::Rdma);
        let mut mg = MutableGraph::new(spec);
        let mut eng = IncrementalReplayer::new();
        let log = mg.commit();
        eng.replay_incremental(&mg, &log);
        (mg, eng)
    }

    #[test]
    fn exact_residual_closes_the_sum() {
        for (total, busy) in [
            (1.0e6, 0.3e6),
            (123456.789, 123000.0001),
            (7.0, 0.0),
            (1.0, 1.0000000000000002),
            (0.1 + 0.2, 0.3),
        ] {
            let x = exact_residual(total, busy);
            assert_eq!(busy + x, total, "total={total} busy={busy} x={x}");
        }
    }

    #[test]
    fn blame_sums_bit_exactly() {
        let (mg, eng) = diag("vgg16", "horovod");
        let b = blame(&mg, eng.result());
        assert!(b.iteration_us > 0.0);
        assert_eq!(b.check(), Ok(()));
        // the replayed critical path has no gaps: blocked is ~0
        assert!(
            b.path.blocked_us.abs() < 1.0,
            "path blocked {} us",
            b.path.blocked_us
        );
        // blame found both busy categories
        assert!(b.path.comp_us > 0.0 && b.path.comm_us > 0.0);
        assert!(b.devices.iter().any(|d| d.class == "gpu"));
    }

    #[test]
    fn group_blame_covers_hot_groups() {
        let (mg, eng) = diag("resnet50", "byteps");
        let gb = group_blame(&mg, eng.result());
        assert_eq!(gb.comm_us.len(), mg.spec().plan.groups.len());
        assert_eq!(gb.comp_us.len(), mg.spec().fusion.groups.len());
        assert!(gb.hottest_fusion_group().is_some());
        // a comm-heavy PS job must put some comm groups on the path
        assert!(gb.comm_us.iter().sum::<f64>() > 0.0);
    }
}
