//! Bottleneck ranking: turn the blame decomposition into an ordered list
//! of *actionable* findings — the slowest rank, straggler machines (via
//! trace drift/stretch, the same axes `trace/degrade.rs` injects), the
//! comm stage class dominating the critical path (keyed off the lowered
//! comm-plan's stage metadata through [`crate::graph::plan_props`]), and
//! the hottest comm/fusion groups — each scored by **estimated headroom**:
//! an upper bound on the iteration-time reduction fixing it could buy.
//! The corresponding what-if query ([`crate::diagnosis::whatif`]) turns
//! any estimate into a replayed answer.

use std::collections::HashMap;

use crate::graph::dfg::{DeviceKey, OpKind};
use crate::graph::{plan_props, MutableGraph};
use crate::replay::ReplayResult;
use crate::trace::GTrace;
use crate::util::json::Json;
use crate::util::Us;

use super::critical::{device_class, BlameReport, GroupBlame};

/// The finding classes the ranker emits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BottleneckKind {
    /// The worker GPU with the most busy time — the rank the iteration
    /// waits for.
    SlowestRank,
    /// A machine whose GPUs are systematically slower than the fleet
    /// median (replayed busy time, or measured duration stretch when a
    /// trace is available).
    StragglerMachine,
    /// One iteration of the measured trace ran stretched (preemption, GC
    /// pause) — a profiling artifact inflating the averages.
    StragglerIteration,
    /// A machine's clock offset is large — a measurement artifact the
    /// alignment stage corrects, not a job slowdown.
    ClockDrift,
    /// A communication stage class (NIC, NVLink, PS CPU, coordinator)
    /// dominating the critical path.
    CommStage,
    /// A comm group whose synchronization sits on the critical path.
    HotCommGroup,
    /// A fusion group (kernel) dominating critical-path compute.
    HotOpGroup,
    /// A worker stopped emitting events before the trace ended (crashed
    /// process, lost machine, or a missing per-process dump file) — the
    /// fault [`crate::fault::Fault::WorkerCrash`] injects.
    WorkerLost,
    /// A machine's measured SEND/RECV durations are several times the
    /// fleet median — a degraded or flapping NIC
    /// ([`crate::fault::Fault::NicDegrade`] / `NicFlap`), not a slow GPU.
    LinkDegraded,
}

impl BottleneckKind {
    /// Stable kebab-case key used in JSON reports.
    pub fn name(self) -> &'static str {
        match self {
            BottleneckKind::SlowestRank => "slowest-rank",
            BottleneckKind::StragglerMachine => "straggler-machine",
            BottleneckKind::StragglerIteration => "straggler-iteration",
            BottleneckKind::ClockDrift => "clock-drift",
            BottleneckKind::CommStage => "comm-stage",
            BottleneckKind::HotCommGroup => "hot-comm-group",
            BottleneckKind::HotOpGroup => "hot-op-group",
            BottleneckKind::WorkerLost => "worker-lost",
            BottleneckKind::LinkDegraded => "link-degraded",
        }
    }
}

/// One ranked finding.
#[derive(Clone, Debug)]
pub struct Bottleneck {
    /// Finding class.
    pub kind: BottleneckKind,
    /// What is to blame (`w3`, `machine1`, `nic-tx`, `g17`, an op name).
    pub subject: String,
    /// Time attributed to the subject (critical-path share or busy-time
    /// excess), us.
    pub blame_us: Us,
    /// Estimated upper bound on the iteration-time reduction fixing the
    /// subject could buy, us (0 for pure measurement artifacts).
    pub headroom_us: Us,
    /// Human-readable context.
    pub detail: String,
}

impl Bottleneck {
    /// Schema-stable JSON row (`kind`, `subject`, `blame_us`,
    /// `headroom_us`, `detail`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("kind", Json::Str(self.kind.name().to_string()));
        o.set("subject", Json::Str(self.subject.clone()));
        o.set("blame_us", Json::Num(self.blame_us));
        o.set("headroom_us", Json::Num(self.headroom_us));
        o.set("detail", Json::Str(self.detail.clone()));
        o
    }
}

/// Straggler/drift evidence extracted from a measured trace — the
/// detection side of the axes [`crate::trace::degrade`] injects
/// (per-machine drift, straggler-iteration stretch).
#[derive(Clone, Debug, Default)]
pub struct TraceFacts {
    /// Per machine: mean solved clock offset θ (us), sorted by machine id.
    pub machine_drift_us: Vec<(u16, f64)>,
    /// Per machine: mean measured FW/BW duration relative to the fleet
    /// median machine (1.0 = typical), sorted by machine id.
    pub machine_stretch: Vec<(u16, f64)>,
    /// Per iteration: mean measured FW/BW duration relative to the median
    /// iteration (1.0 = typical), sorted by iteration.
    pub iter_stretch: Vec<(u32, f64)>,
    /// Workers that stop emitting events before the trace ends:
    /// `(worker, first missing iteration)`, sorted by worker. A worker
    /// with no events at all reports iteration 0 — the signature a
    /// missing per-process dump file leaves after
    /// [`crate::trace::io::load_dir`]'s partial-dump downgrade.
    pub lost_workers: Vec<(u16, u32)>,
    /// Per machine: mean measured SEND/RECV duration relative to the
    /// fleet median machine (1.0 = typical), sorted by machine id —
    /// drift-immune, like `machine_stretch`, but over the comm ops a
    /// degraded NIC stretches.
    pub machine_comm_stretch: Vec<(u16, f64)>,
}

impl TraceFacts {
    /// Extract drift and stretch facts from a measured trace. Runs the
    /// §4.2 alignment solve for the per-machine offsets; stretch uses
    /// duration ratios, which are drift-immune. Empty or degenerate
    /// traces yield empty facts (never a panic).
    pub fn from_trace(trace: &GTrace) -> TraceFacts {
        if trace.events.is_empty() {
            return TraceFacts::default();
        }
        TraceFacts::from_trace_aligned(trace, &crate::alignment::align(trace, 1.0, 1.0))
    }

    /// Like [`TraceFacts::from_trace`], but reusing an already-solved
    /// alignment — callers that ran the §4.2 solve for the corrected
    /// profile (e.g. [`crate::diagnosis::Diagnoser::from_trace`]) must
    /// not pay for it twice.
    pub fn from_trace_aligned(
        trace: &GTrace,
        a: &crate::alignment::Alignment,
    ) -> TraceFacts {
        if trace.events.is_empty() {
            return TraceFacts::default();
        }
        // proc → machine (same machine ⇒ same clock)
        let mut machine_of: HashMap<u16, u16> = HashMap::new();
        for e in &trace.events {
            machine_of.entry(e.proc).or_insert(e.machine);
        }

        // ---- drift: mean alignment offset per machine ----
        let mut drift: HashMap<u16, (f64, usize)> = HashMap::new();
        for (proc, theta) in &a.theta {
            let m = machine_of.get(proc).copied().unwrap_or(0);
            let ent = drift.entry(m).or_insert((0.0, 0));
            ent.0 += *theta;
            ent.1 += 1;
        }
        let mut machine_drift_us: Vec<(u16, f64)> = drift
            .into_iter()
            .map(|(m, (sum, n))| (m, sum / n.max(1) as f64))
            .collect();
        machine_drift_us.sort_by_key(|&(m, _)| m);

        // ---- stretch: mean comp duration per machine / per iteration ----
        let mut by_machine: HashMap<u16, (f64, usize)> = HashMap::new();
        let mut by_iter: HashMap<u32, (f64, usize)> = HashMap::new();
        // comm stretch separately: a degraded NIC inflates SEND/RECV but
        // leaves the kernels alone, so mixing the two would dilute both
        let mut comm_by_machine: HashMap<u16, (f64, usize)> = HashMap::new();
        for e in &trace.events {
            if !e.dur.is_finite() {
                continue;
            }
            if matches!(e.kind, OpKind::Send | OpKind::Recv) {
                let bc = comm_by_machine.entry(e.machine).or_insert((0.0, 0));
                bc.0 += e.dur;
                bc.1 += 1;
                continue;
            }
            if !matches!(e.kind, OpKind::Forward | OpKind::Backward) {
                continue;
            }
            let bm = by_machine.entry(e.machine).or_insert((0.0, 0));
            bm.0 += e.dur;
            bm.1 += 1;
            let bi = by_iter.entry(e.iter).or_insert((0.0, 0));
            bi.0 += e.dur;
            bi.1 += 1;
        }
        let machine_stretch = relative_means(by_machine);
        let iter_stretch = relative_means(by_iter);
        let machine_comm_stretch = relative_means(comm_by_machine);

        // ---- lost workers: who stops emitting before the trace ends ----
        // (the signature worker crashes, machine losses and missing dump
        // files all share; metadata keeps n_workers at the full fleet
        // size, so absent procs stay visible)
        let mut lost_workers = Vec::new();
        if trace.n_workers > 0 {
            let last_iter = trace.events.iter().map(|e| e.iter).max().unwrap_or(0);
            let mut max_iter: Vec<Option<u32>> = vec![None; trace.n_workers];
            for e in &trace.events {
                if (e.proc as usize) < trace.n_workers {
                    let m = &mut max_iter[e.proc as usize];
                    *m = Some(m.map_or(e.iter, |x| x.max(e.iter)));
                }
            }
            for (w, mi) in max_iter.iter().enumerate() {
                match *mi {
                    None => lost_workers.push((w as u16, 0)),
                    Some(mi) if mi < last_iter => lost_workers.push((w as u16, mi + 1)),
                    _ => {}
                }
            }
        }
        TraceFacts {
            machine_drift_us,
            machine_stretch,
            iter_stretch,
            lost_workers,
            machine_comm_stretch,
        }
    }

    /// Machines this evidence marks as deviating from the fleet — the
    /// input the tiered replayer's class splitter consumes
    /// ([`crate::replay::tiered::TieredReplayer::demote_machines`]): a
    /// machine with straggling kernels, a degraded NIC, a flagged clock
    /// offset, or a lost worker must not be derived by symmetry, so any
    /// hit here demotes the job to exact replay. Uses the same
    /// thresholds as the bottleneck ranker; `gpus_per_machine` maps
    /// lost workers onto their machines.
    pub fn broken_machines(&self, gpus_per_machine: usize) -> Vec<u16> {
        let mut out: Vec<u16> = Vec::new();
        for &(m, stretch) in &self.machine_stretch {
            if stretch > STRAGGLER_MACHINE_FACTOR {
                out.push(m);
            }
        }
        for &(m, stretch) in &self.machine_comm_stretch {
            if stretch >= LINK_DEGRADED_FACTOR {
                out.push(m);
            }
        }
        for &(m, theta) in &self.machine_drift_us {
            if theta.abs() > DRIFT_FLAG_US {
                out.push(m);
            }
        }
        for &(w, _) in &self.lost_workers {
            out.push((w as usize / gpus_per_machine.max(1)) as u16);
        }
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// Means per key, normalized by the median mean; sorted by key.
fn relative_means<K: Copy + Ord + std::hash::Hash>(
    sums: HashMap<K, (f64, usize)>,
) -> Vec<(K, f64)> {
    let mut means: Vec<(K, f64)> = sums
        .into_iter()
        .filter(|(_, (_, n))| *n > 0)
        .map(|(k, (s, n))| (k, s / n as f64))
        .collect();
    if means.is_empty() {
        return means;
    }
    let mut vals: Vec<f64> = means.iter().map(|&(_, v)| v).collect();
    vals.sort_by(f64::total_cmp);
    // lower median, so a 2-machine trace normalizes by the healthy
    // machine and the straggler's stretch stays > 1
    let med = vals[(vals.len() - 1) / 2];
    if med > 0.0 {
        for (_, v) in &mut means {
            *v /= med;
        }
    }
    means.sort_by_key(|&(k, _)| k);
    means
}

/// A machine must exceed the fleet median by this factor before it is
/// called a straggler (below it, noise).
const STRAGGLER_MACHINE_FACTOR: f64 = 1.10;
/// An iteration must exceed the median by this factor to be flagged.
const STRAGGLER_ITER_FACTOR: f64 = 1.30;
/// Clock offsets below this are unremarkable NTP jitter (us).
const DRIFT_FLAG_US: f64 = 500.0;
/// A machine's mean SEND/RECV duration must exceed the fleet median by
/// this factor before its NIC is called degraded. Healthy heterogeneous
/// fleets show comm ratios up to ~2.4x (PS servers vs. workers), so the
/// bar sits well above the straggler factors.
pub(crate) const LINK_DEGRADED_FACTOR: f64 = 3.0;
/// How many hot comm/fusion groups to surface.
const TOP_GROUPS: usize = 3;

/// Rank the bottlenecks of one replayed (and optionally traced) job, by
/// estimated headroom, descending. `blame`/`gb` must come from the same
/// replay `r` of `mg` (the [`crate::diagnosis::Diagnoser`] guarantees
/// the pairing).
pub fn rank(
    mg: &MutableGraph,
    r: &ReplayResult,
    blame: &BlameReport,
    gb: &GroupBlame,
    facts: Option<&TraceFacts>,
) -> Vec<Bottleneck> {
    let spec = mg.spec();
    let dfg = mg.dfg();
    let alive = mg.alive();
    let mut out = Vec::new();

    // ---- per-worker GPU busy time → slowest rank + straggler machines ----
    let n_workers = mg.n_workers();
    let mut worker_busy = vec![0.0f64; n_workers];
    for i in dfg.ids() {
        if !alive[i as usize] {
            continue;
        }
        if let DeviceKey::Gpu(w) = dfg.node(i).device {
            if (w as usize) < n_workers {
                worker_busy[w as usize] += r.end[i as usize] - r.start[i as usize];
            }
        }
    }
    if n_workers > 0 {
        let mut sorted = worker_busy.clone();
        sorted.sort_by(f64::total_cmp);
        // lower median: the upper one equals the maximum on 2-element
        // fleets, which would make `busy > median` never fire there
        let median = sorted[(n_workers - 1) / 2];
        let (slowest, &busy) = worker_busy
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .expect("n_workers > 0");
        if busy > median {
            out.push(Bottleneck {
                kind: BottleneckKind::SlowestRank,
                subject: format!("w{slowest}"),
                blame_us: busy,
                headroom_us: busy - median,
                detail: format!(
                    "GPU busy {busy:.0} us vs fleet median {median:.0} us; \
                     what-if equalize={slowest} replays the fix"
                ),
            });
        }

        // replay-side straggler machines (mean GPU busy per machine)
        let gpm = spec.cluster.gpus_per_machine.max(1);
        let n_machines = (n_workers + gpm - 1) / gpm;
        if n_machines > 1 {
            let mut machine_busy = vec![(0.0f64, 0usize); n_machines];
            for (w, &b) in worker_busy.iter().enumerate() {
                let m = w / gpm;
                machine_busy[m].0 += b;
                machine_busy[m].1 += 1;
            }
            let means: Vec<f64> = machine_busy
                .iter()
                .map(|&(s, n)| if n > 0 { s / n as f64 } else { 0.0 })
                .collect();
            let mut ms = means.clone();
            ms.sort_by(f64::total_cmp);
            // lower median (see worker median above): keeps straggler
            // detection alive on two-machine clusters
            let med = ms[(ms.len() - 1) / 2];
            for (m, &mean) in means.iter().enumerate() {
                if med > 0.0 && mean > med * STRAGGLER_MACHINE_FACTOR {
                    out.push(Bottleneck {
                        kind: BottleneckKind::StragglerMachine,
                        subject: format!("machine{m}"),
                        blame_us: mean,
                        headroom_us: mean - med,
                        detail: format!(
                            "mean GPU busy {mean:.0} us vs median machine {med:.0} us \
                             ({:.0}% slower)",
                            (mean / med - 1.0) * 100.0
                        ),
                    });
                }
            }
        }
    }

    // ---- comm stage classes on the critical path ----
    // keyed off the lowered plan's stage metadata: each path op's device
    // class is exactly the Stage::device its planner emitted
    let mut class_time: HashMap<&'static str, f64> = HashMap::new();
    let mut cur = Some(r.last);
    while let Some(n) = cur {
        let node = dfg.node(n);
        if node.kind.is_comm() && node.device != DeviceKey::Null {
            *class_time.entry(device_class(node.device)).or_insert(0.0) +=
                r.end[n as usize] - r.start[n as usize];
        } else if node.kind == OpKind::Negotiate {
            // negotiation runs device-less but is still a comm stage
            *class_time.entry("coordinator").or_insert(0.0) +=
                r.end[n as usize] - r.start[n as usize];
        }
        cur = r.crit_pred[n as usize];
    }
    let props = plan_props(spec);
    let mut classes: Vec<(&'static str, f64)> = class_time.into_iter().collect();
    classes.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(b.0)));
    for (class, t) in classes.into_iter().take(2) {
        if t <= 0.0 {
            continue;
        }
        out.push(Bottleneck {
            kind: BottleneckKind::CommStage,
            subject: class.to_string(),
            blame_us: t,
            headroom_us: t,
            detail: format!(
                "{t:.0} us of the critical path runs {class} stages of the {} plan \
                 (wire factor {:.2}); what-if nic-bw/nvlink-bw replays a faster fabric",
                props.scheme, props.critical_path_wire_factor
            ),
        });
    }

    // ---- hot comm groups ----
    let mut hot_comm: Vec<(usize, f64)> = gb
        .comm_us
        .iter()
        .copied()
        .enumerate()
        .filter(|&(_, t)| t > 0.0)
        .collect();
    hot_comm.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    for (gi, t) in hot_comm.into_iter().take(TOP_GROUPS) {
        let bytes = spec.plan.group_bytes(&spec.model, gi);
        out.push(Bottleneck {
            kind: BottleneckKind::HotCommGroup,
            subject: format!("g{gi}"),
            blame_us: t,
            headroom_us: t,
            detail: format!(
                "synchronization of {bytes:.0} B ({} tensors, {} partitions) holds \
                 {t:.0} us of the path; what-if zero-group={gi} bounds the gain",
                spec.plan.groups[gi].tensors.len(),
                spec.plan.groups[gi].partitions
            ),
        });
    }

    // ---- hot fusion groups (kernels) ----
    let mut hot_comp: Vec<(usize, f64)> = gb
        .comp_us
        .iter()
        .copied()
        .enumerate()
        .filter(|&(_, t)| t > 0.0)
        .collect();
    hot_comp.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    for (fg, t) in hot_comp.into_iter().take(TOP_GROUPS) {
        let first_op = spec.fusion.groups[fg][0] as usize;
        let name = spec.model.ops[first_op].name.clone();
        out.push(Bottleneck {
            kind: BottleneckKind::HotOpGroup,
            subject: name,
            blame_us: t,
            // the matching what-if (shrink-op=fg:0.5) halves the kernel:
            // its gain is bounded by half the kernel's path share
            headroom_us: t * 0.5,
            detail: format!(
                "fusion group {fg} holds {t:.0} us of critical-path compute; \
                 what-if shrink-op={fg}:0.5 replays a 2x-faster kernel"
            ),
        });
    }

    // ---- trace-side evidence: drift + stretch ----
    if let Some(f) = facts {
        for &(m, theta) in &f.machine_drift_us {
            if theta.abs() > DRIFT_FLAG_US {
                out.push(Bottleneck {
                    kind: BottleneckKind::ClockDrift,
                    subject: format!("machine{m}"),
                    blame_us: theta.abs(),
                    headroom_us: 0.0,
                    detail: format!(
                        "solved clock offset θ = {theta:+.0} us — a measurement artifact \
                         the alignment stage corrects, not a job slowdown"
                    ),
                });
            }
        }
        for &(m, stretch) in &f.machine_stretch {
            if stretch > STRAGGLER_MACHINE_FACTOR {
                out.push(Bottleneck {
                    kind: BottleneckKind::StragglerMachine,
                    subject: format!("machine{m}"),
                    blame_us: blame.iteration_us * (1.0 - 1.0 / stretch),
                    headroom_us: blame.iteration_us * (1.0 - 1.0 / stretch),
                    detail: format!(
                        "measured kernel durations {:.0}% above the fleet median \
                         (trace stretch {stretch:.2})",
                        (stretch - 1.0) * 100.0
                    ),
                });
            }
        }
        for &(w, from_iter) in &f.lost_workers {
            let survivors = n_workers.saturating_sub(f.lost_workers.len());
            let remedy = if survivors >= 2 {
                format!("what-if continue-on:{survivors} prices finishing on the survivors")
            } else {
                "too few survivors to continue — restart the job".to_string()
            };
            out.push(Bottleneck {
                kind: BottleneckKind::WorkerLost,
                subject: format!("w{w}"),
                blame_us: blame.iteration_us,
                headroom_us: blame.iteration_us / (n_workers.max(1) as f64),
                detail: format!(
                    "no events from iteration {from_iter} on — crashed worker, lost \
                     machine, or missing dump file; {remedy}"
                ),
            });
        }
        for &(m, stretch) in &f.machine_comm_stretch {
            if stretch >= LINK_DEGRADED_FACTOR {
                out.push(Bottleneck {
                    kind: BottleneckKind::LinkDegraded,
                    subject: format!("machine{m}"),
                    blame_us: blame.iteration_us * (1.0 - 1.0 / stretch),
                    headroom_us: blame.iteration_us * (1.0 - 1.0 / stretch),
                    detail: format!(
                        "measured SEND/RECV durations {stretch:.1}x the fleet median \
                         while kernels stay typical — degraded NIC; what-if nic-bw \
                         prices restoring the link"
                    ),
                });
            }
        }
        for &(it, stretch) in &f.iter_stretch {
            if stretch > STRAGGLER_ITER_FACTOR {
                out.push(Bottleneck {
                    kind: BottleneckKind::StragglerIteration,
                    subject: format!("iter{it}"),
                    blame_us: blame.iteration_us * (stretch - 1.0),
                    headroom_us: 0.0,
                    detail: format!(
                        "iteration ran {stretch:.2}x the median — a profiling-window \
                         artifact inflating the per-op averages; re-profile or drop it"
                    ),
                });
            }
        }
    }

    out.sort_by(|a, b| {
        b.headroom_us
            .total_cmp(&a.headroom_us)
            .then(b.blame_us.total_cmp(&a.blame_us))
            .then(a.subject.cmp(&b.subject))
    });
    // one row per root cause: the replay-side and trace-side detectors
    // can both flag the same (kind, subject) — e.g. a straggler machine
    // seen in replayed busy time *and* in measured duration stretch —
    // and the sorted order keeps the higher-headroom row
    let mut seen: std::collections::HashSet<(&'static str, String)> =
        std::collections::HashSet::new();
    out.retain(|b| seen.insert((b.kind.name(), b.subject.clone())));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobSpec, Transport};
    use crate::replay::incremental::IncrementalReplayer;
    use crate::trace::degrade;

    #[test]
    fn ranking_surfaces_comm_and_comp() {
        let spec = JobSpec::standard("vgg16", "byteps", Transport::Tcp);
        let mut mg = MutableGraph::new(spec);
        let mut eng = IncrementalReplayer::new();
        let log = mg.commit();
        eng.replay_incremental(&mg, &log);
        let b = super::super::critical::blame(&mg, eng.result());
        let gb = super::super::critical::group_blame(&mg, eng.result());
        let ranked = rank(&mg, eng.result(), &b, &gb, None);
        assert!(!ranked.is_empty());
        // comm-bound TCP PS job: a comm finding must rank near the top
        assert!(
            ranked.iter().take(3).any(|x| matches!(
                x.kind,
                BottleneckKind::CommStage | BottleneckKind::HotCommGroup
            )),
            "top-3: {:?}",
            ranked.iter().take(3).map(|x| x.kind).collect::<Vec<_>>()
        );
        // ranked by headroom, descending
        for w in ranked.windows(2) {
            assert!(w[0].headroom_us >= w[1].headroom_us);
        }
    }

    #[test]
    fn trace_facts_detect_injected_drift_and_stretch() {
        let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let tb = crate::testbed::run(
            &spec,
            &crate::testbed::TestbedOpts { iterations: 4, ..Default::default() },
        );
        let mut trace = tb.trace.clone();
        degrade::inject_drift(&mut trace, 1, 50_000.0);
        degrade::straggle_iteration(&mut trace, 2, 2.0);
        let f = TraceFacts::from_trace(&trace);
        // machine 1's solved offset must dwarf machine 0's
        let d0 = f.machine_drift_us.iter().find(|&&(m, _)| m == 0).map(|&(_, d)| d);
        let d1 = f.machine_drift_us.iter().find(|&&(m, _)| m == 1).map(|&(_, d)| d);
        let (d0, d1) = (d0.unwrap_or(0.0), d1.unwrap_or(0.0));
        assert!(
            (d1 - d0).abs() > 10_000.0,
            "drift not recovered: d0={d0} d1={d1}"
        );
        // iteration 2 must stand out
        let s2 = f.iter_stretch.iter().find(|&&(i, _)| i == 2).map(|&(_, s)| s);
        assert!(s2.unwrap_or(1.0) > STRAGGLER_ITER_FACTOR, "s2={s2:?}");
    }

    #[test]
    fn trace_facts_detect_injected_faults() {
        use crate::fault::Fault;
        let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let tb = crate::testbed::run(
            &spec,
            &crate::testbed::TestbedOpts { iterations: 4, ..Default::default() },
        );
        // healthy trace: nobody lost, no link flagged
        let clean = TraceFacts::from_trace(&tb.trace);
        assert!(clean.lost_workers.is_empty(), "{:?}", clean.lost_workers);
        assert!(
            clean.machine_comm_stretch.iter().all(|&(_, s)| s < LINK_DEGRADED_FACTOR),
            "{:?}",
            clean.machine_comm_stretch
        );

        let mut trace = tb.trace.clone();
        Fault::WorkerCrash { worker: 1, at_iter: 2 }.apply(&mut trace);
        Fault::NicDegrade { machine: 1, factor: 8.0, at_iter: 0 }.apply(&mut trace);
        let f = TraceFacts::from_trace(&trace);
        assert!(
            f.lost_workers.contains(&(1, 2)),
            "crash not detected: {:?}",
            f.lost_workers
        );
        let s1 = f
            .machine_comm_stretch
            .iter()
            .find(|&&(m, _)| m == 1)
            .map(|&(_, s)| s)
            .unwrap_or(1.0);
        assert!(s1 >= LINK_DEGRADED_FACTOR, "comm stretch not detected: {s1}");
    }
}
