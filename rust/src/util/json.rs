//! Minimal JSON value, writer and recursive-descent parser.
//!
//! The offline image has no `serde`; traces are interchange files (Chrome
//! trace format) so we implement the subset of JSON we need: objects,
//! arrays, strings, f64 numbers, bools, null. Output is deterministic
//! (object keys keep insertion order).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are f64 (sufficient for microsecond timestamps).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// JSON `null` (also what non-finite numbers serialize to).
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (always f64).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// BTreeMap gives deterministic serialization; trace consumers do not
    /// depend on field order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// A fresh empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert/overwrite a key (panics on non-objects — builder use only).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("set on non-object");
        }
        self
    }

    /// Object field lookup (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The number, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Field access helpers that panic with a useful message — used on
    /// trusted, self-produced trace files.
    pub fn f64(&self, key: &str) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or_else(|| panic!("missing num field {key}"))
    }

    /// Like [`Json::f64`] but for string fields.
    pub fn str(&self, key: &str) -> &str {
        self.get(key).and_then(Json::as_str).unwrap_or_else(|| panic!("missing str field {key}"))
    }

    /// Compact serialization (no whitespace).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Indented serialization with a trailing newline (for files humans
    /// read and hand-edit).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    x.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() && x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else if x.is_finite() {
        let _ = write!(out, "{x}");
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Errors carry the byte offset.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos.saturating_sub(1)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("eof in \\u escape")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(format!("bad escape at byte {}", self.pos)),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the raw bytes through
                    let len = utf8_len(c);
                    let start = self.pos - 1;
                    self.pos += len - 1;
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| "invalid utf8".to_string())?;
                    s.push_str(chunk);
                }
                None => return Err("eof in string".to_string()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {text:?}: {e}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = parse(text).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a":[1,2,{"b":null}],"c":"x\ny","d":true}"#;
        let v = parse(text).unwrap();
        let round = parse(&v.to_string()).unwrap();
        assert_eq!(v, round);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn object_builder() {
        let mut o = Json::obj();
        o.set("name", Json::Str("recv".into()));
        o.set("ts", Json::Num(12.5));
        let s = o.to_string();
        let back = parse(&s).unwrap();
        assert_eq!(back.f64("ts"), 12.5);
        assert_eq!(back.str("name"), "recv");
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""é café ✓""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é café ✓");
        let round = parse(&v.to_string()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let mut o = Json::obj();
        o.set("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]));
        let pretty = o.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), o);
    }

    #[test]
    fn large_int_exact() {
        let v = parse("123456789012").unwrap();
        assert_eq!(v.to_string(), "123456789012");
    }
}
