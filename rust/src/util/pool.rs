//! Minimal scoped data-parallelism: the offline build image has no crate
//! registry (no rayon), so fleet-scale replay parallelizes its
//! embarrassingly-parallel loops with `std::thread::scope` plus an atomic
//! work-stealing counter. Threads live only for the duration of one call —
//! no pool state, no channels, no `'static` bounds on the closure.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Upper bound on worker threads — beyond this the per-task work in the
/// replay/derivation loops stops scaling (memory-bandwidth bound).
const MAX_THREADS: usize = 8;

/// How many worker threads a `parallel_for` over `n_tasks` would use.
pub fn n_threads(n_tasks: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    hw.min(MAX_THREADS).min(n_tasks).max(1)
}

/// Run `f(0) .. f(n_tasks-1)` across a small scoped thread pool. Tasks
/// are claimed from an atomic counter, so uneven task costs balance
/// themselves. Falls back to a plain sequential loop when the machine is
/// single-core or there is at most one task. `f` must be safe to call
/// concurrently for *distinct* indices (the usual disjoint-output
/// contract — see [`DisjointSlice`]).
pub fn parallel_for<F: Fn(usize) + Sync>(n_tasks: usize, f: F) {
    let threads = n_threads(n_tasks);
    if threads <= 1 {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_tasks {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Shared-write view of a mutable slice for disjoint-index parallel
/// fills (each element written by at most one thread). The replay
/// derivation pass fills `start[]`/`end[]` for machine *m*'s nodes from
/// thread *m*; index sets never overlap, so unsynchronized writes are
/// race-free.
pub struct DisjointSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: all access goes through `set`/`get`, whose contract (below)
// requires callers to keep concurrently-touched indices disjoint.
unsafe impl<T: Send> Sync for DisjointSlice<'_, T> {}
unsafe impl<T: Send> Send for DisjointSlice<'_, T> {}

impl<'a, T> DisjointSlice<'a, T> {
    /// Wrap a slice for disjoint parallel writes.
    pub fn new(slice: &'a mut [T]) -> DisjointSlice<'a, T> {
        DisjointSlice {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the underlying slice is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write one element.
    ///
    /// # Safety
    /// No other thread may read or write index `i` concurrently; `i`
    /// must be in bounds (checked in debug builds).
    pub unsafe fn set(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) = v };
    }

    /// Read one element.
    ///
    /// # Safety
    /// No other thread may write index `i` concurrently; `i` must be in
    /// bounds (checked in debug builds).
    pub unsafe fn get(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_every_index_once() {
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn parallel_for_handles_edge_sizes() {
        parallel_for(0, |_| panic!("no tasks"));
        let one = AtomicU64::new(0);
        parallel_for(1, |i| {
            assert_eq!(i, 0);
            one.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(one.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn disjoint_slice_parallel_fill() {
        let mut data = vec![0u64; 4096];
        let view = DisjointSlice::new(&mut data);
        parallel_for(4096, |i| unsafe { view.set(i, i as u64 * 3) });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u64 * 3);
        }
    }

    #[test]
    fn n_threads_is_bounded() {
        assert_eq!(n_threads(0), 1);
        assert_eq!(n_threads(1), 1);
        assert!(n_threads(1_000_000) <= MAX_THREADS);
    }
}
