//! Minimal thread-parallelism without crates (the offline build image has
//! no registry, so no rayon/threadpool). Two shapes:
//!
//! - [`parallel_for`] — scoped data-parallelism for fleet-scale replay's
//!   embarrassingly-parallel loops: `std::thread::scope` plus an atomic
//!   work-stealing counter; threads live only for the duration of one
//!   call, no `'static` bound on the closure.
//! - [`FixedPool`] — a persistent pool of worker threads fed over an mpsc
//!   channel, for the serve daemon's request handling where threads must
//!   outlive any one call and jobs arrive continuously. Jobs are `'static`
//!   boxed closures; a panicking job is caught and does not kill its
//!   worker.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// Upper bound on worker threads — beyond this the per-task work in the
/// replay/derivation loops stops scaling (memory-bandwidth bound).
const MAX_THREADS: usize = 8;

/// How many worker threads a `parallel_for` over `n_tasks` would use.
pub fn n_threads(n_tasks: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    hw.min(MAX_THREADS).min(n_tasks).max(1)
}

/// Run `f(0) .. f(n_tasks-1)` across a small scoped thread pool. Tasks
/// are claimed from an atomic counter, so uneven task costs balance
/// themselves. Falls back to a plain sequential loop when the machine is
/// single-core or there is at most one task. `f` must be safe to call
/// concurrently for *distinct* indices (the usual disjoint-output
/// contract — see [`DisjointSlice`]).
pub fn parallel_for<F: Fn(usize) + Sync>(n_tasks: usize, f: F) {
    let threads = n_threads(n_tasks);
    if threads <= 1 {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    // spans recorded inside workers parent under the caller's open span
    // (no-op when span collection is disabled)
    let ctx = crate::obs::current_ctx();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let _obs = crate::obs::inherit(ctx);
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n_tasks {
                        break;
                    }
                    f(i);
                }
            });
        }
    });
}

/// Shared-write view of a mutable slice for disjoint-index parallel
/// fills (each element written by at most one thread). The replay
/// derivation pass fills `start[]`/`end[]` for machine *m*'s nodes from
/// thread *m*; index sets never overlap, so unsynchronized writes are
/// race-free.
pub struct DisjointSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: all access goes through `set`/`get`, whose contract (below)
// requires callers to keep concurrently-touched indices disjoint.
unsafe impl<T: Send> Sync for DisjointSlice<'_, T> {}
unsafe impl<T: Send> Send for DisjointSlice<'_, T> {}

impl<'a, T> DisjointSlice<'a, T> {
    /// Wrap a slice for disjoint parallel writes.
    pub fn new(slice: &'a mut [T]) -> DisjointSlice<'a, T> {
        DisjointSlice {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the underlying slice is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write one element.
    ///
    /// # Safety
    /// No other thread may read or write index `i` concurrently; `i`
    /// must be in bounds (checked in debug builds).
    pub unsafe fn set(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) = v };
    }

    /// Read one element.
    ///
    /// # Safety
    /// No other thread may write index `i` concurrently; `i` must be in
    /// bounds (checked in debug builds).
    pub unsafe fn get(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) }
    }
}

/// A boxed unit of work for a [`FixedPool`].
type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size pool of persistent worker threads. Submitted jobs queue
/// on an mpsc channel and run on whichever worker frees up first; the
/// queue is unbounded (the serve daemon bounds work upstream by refusing
/// oversized request bodies, not by dropping accepted connections).
///
/// Dropping the pool closes the channel; workers finish the jobs already
/// queued and exit, and `Drop` joins them — so a `FixedPool` going out of
/// scope is a clean barrier, like `std::thread::scope`.
pub struct FixedPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Jobs submitted but not yet finished (queued + running).
    pending: Arc<AtomicUsize>,
}

impl FixedPool {
    /// Spawn `threads` persistent workers (at least one).
    pub fn new(threads: usize) -> FixedPool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::spawn(move || loop {
                    // hold the receiver lock only to pick a job, never
                    // while running it
                    let job = match rx.lock() {
                        Ok(guard) => guard.recv(),
                        Err(_) => break, // a worker panicked mid-recv; shut down
                    };
                    match job {
                        Ok(job) => {
                            // a panicking request handler must not take
                            // the worker (and its siblings' channel) down
                            let _ = catch_unwind(AssertUnwindSafe(job));
                            pending.fetch_sub(1, Ordering::Relaxed);
                        }
                        Err(_) => break, // channel closed: pool dropped
                    }
                })
            })
            .collect();
        FixedPool { tx: Some(tx), workers, pending }
    }

    /// Queue a job. Panics if called after the pool started shutting down
    /// (impossible through the public API — `execute` needs `&self`, and
    /// shutdown happens in `Drop`).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        // capture the submitter's span context so spans recorded inside
        // the job parent under the submitting span; the guard restores
        // the worker's previous context even if the job panics
        let ctx = crate::obs::current_ctx();
        self.pending.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(move || {
                let _obs = crate::obs::inherit(ctx);
                f();
            }))
            .expect("workers alive");
    }

    /// Jobs submitted but not yet finished (queued + running) — the
    /// `queue_depth` statistic of `/statsz`.
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::Relaxed)
    }

    /// Shared handle to the pending-jobs counter, for observers that must
    /// outlive access to the pool itself (the serve daemon keeps the pool
    /// on its accept thread but reports queue depth from `/statsz`).
    pub fn pending_handle(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.pending)
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for FixedPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel: workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_every_index_once() {
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn parallel_for_handles_edge_sizes() {
        parallel_for(0, |_| panic!("no tasks"));
        let one = AtomicU64::new(0);
        parallel_for(1, |i| {
            assert_eq!(i, 0);
            one.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(one.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn disjoint_slice_parallel_fill() {
        let mut data = vec![0u64; 4096];
        let view = DisjointSlice::new(&mut data);
        parallel_for(4096, |i| unsafe { view.set(i, i as u64 * 3) });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u64 * 3);
        }
    }

    #[test]
    fn fixed_pool_runs_all_jobs_and_survives_panics() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = FixedPool::new(3);
            assert_eq!(pool.threads(), 3);
            for i in 0..64 {
                let counter = Arc::clone(&counter);
                pool.execute(move || {
                    if i % 8 == 0 {
                        // poisoned jobs must not kill workers
                        panic!("job {i} panics");
                    }
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            }
            // drop joins: all 64 jobs (including the 8 panickers) finish
        }
        assert_eq!(counter.load(Ordering::Relaxed), 56);
    }

    #[test]
    fn fixed_pool_pending_drains_to_zero() {
        let pool = FixedPool::new(2);
        for _ in 0..16 {
            pool.execute(|| {
                std::thread::sleep(std::time::Duration::from_millis(1));
            });
        }
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while pool.pending() > 0 {
            assert!(std::time::Instant::now() < deadline, "pool stuck");
            std::thread::yield_now();
        }
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn n_threads_is_bounded() {
        assert_eq!(n_threads(0), 1);
        assert_eq!(n_threads(1), 1);
        assert!(n_threads(1_000_000) <= MAX_THREADS);
    }
}
