//! Seeded PCG-64 style pseudo-random number generator.
//!
//! The image has no `rand` crate, and the testbed simulator needs a small,
//! deterministic, splittable RNG for jitter / drift / straggler injection.
//! This is the PCG-XSH-RR 64/32 generator (O'Neill 2014) extended with a
//! convenience layer for the distributions we use.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and stream id. Different stream ids
    /// yield statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator; used to give each simulated
    /// device its own stream so event ordering never perturbs the draws.
    pub fn split(&mut self, salt: u64) -> Pcg {
        let seed = self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15);
        Pcg::new(seed, salt.wrapping_add(0x632BE59BD9B4E019))
    }

    /// Next raw 32-bit output of the generator.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 bits (two 32-bit outputs concatenated).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift; bias is negligible for our n << 2^32.
        ((self.next_u32() as u64 * n as u64) >> 32) as usize
    }

    /// Standard normal via Box–Muller (we do not cache the second value to
    /// keep the generator state a pure function of the draw count).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean / std.
    pub fn gauss(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal multiplicative jitter centered at 1.0 with the given
    /// coefficient of variation; models GPU kernel duration noise.
    pub fn jitter(&mut self, cv: f64) -> f64 {
        if cv <= 0.0 {
            return 1.0;
        }
        let sigma = (1.0 + cv * cv).ln().sqrt();
        let mu = -0.5 * sigma * sigma;
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg::new(42, 1);
        let mut b = Pcg::new(42, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Pcg::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::seeded(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn jitter_centered_at_one() {
        let mut rng = Pcg::seeded(3);
        let n = 50_000;
        let mean = (0..n).map(|_| rng.jitter(0.05)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
        // zero-cv jitter is exactly 1
        assert_eq!(rng.jitter(0.0), 1.0);
    }

    #[test]
    fn below_bounds() {
        let mut rng = Pcg::seeded(5);
        for _ in 0..10_000 {
            assert!(rng.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::seeded(9);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_independent() {
        let mut root = Pcg::seeded(1);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }
}
