//! Global op-name interner: `String ↔ u32` so the replay hot path carries
//! 4-byte [`OpId`]s instead of heap strings. Names are resolved back to
//! `&str` only at report/JSON boundaries (trace emission, CLI output,
//! assert messages).
//!
//! The table is process-global and append-only: interned strings are
//! leaked (`Box::leak`) so `resolve` can hand out `&'static str` without
//! holding the lock across the caller's use. A training job names a few
//! hundred thousand distinct ops at the very most (4096 workers × ~100
//! ops), so the leak is bounded and intentional — it is the same
//! lifetime as the strings previously stored inline in every `Node`.
//!
//! Id 0 is pre-interned as the empty string: graph builders that skip
//! name materialization (`with_names = false`, the optimizer's hot loop)
//! use [`OpId::EMPTY`] without touching the table at all.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// Interned op name. `Ord`/`Hash` are by table index (creation order),
/// not lexicographic — fine for map keys, not for sorted display.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub u32);

impl OpId {
    /// The pre-interned empty name (id 0) — the nameless fast path.
    pub const EMPTY: OpId = OpId(0);

    /// True for the pre-interned empty name.
    pub fn is_empty(self) -> bool {
        self == OpId::EMPTY
    }

    /// The interned string. O(1), lock held only for the index read.
    pub fn resolve(self) -> &'static str {
        resolve(self)
    }
}

impl std::fmt::Display for OpId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(self.resolve())
    }
}

impl std::fmt::Debug for OpId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "OpId({} {:?})", self.0, self.resolve())
    }
}

struct Inner {
    map: HashMap<&'static str, u32>,
    names: Vec<&'static str>,
}

fn table() -> &'static Mutex<Inner> {
    static TABLE: OnceLock<Mutex<Inner>> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut map = HashMap::new();
        map.insert("", 0);
        Mutex::new(Inner { map, names: vec![""] })
    })
}

/// Intern a name, returning its stable id. The empty string never takes
/// the lock ([`OpId::EMPTY`]).
pub fn intern(name: &str) -> OpId {
    if name.is_empty() {
        return OpId::EMPTY;
    }
    let mut t = table().lock().unwrap();
    if let Some(&id) = t.map.get(name) {
        return OpId(id);
    }
    let id = t.names.len() as u32;
    let leaked: &'static str = Box::leak(name.to_string().into_boxed_str());
    t.names.push(leaked);
    t.map.insert(leaked, id);
    OpId(id)
}

/// The string an id was interned from. Panics on an id that never came
/// out of [`intern`] (a forged `OpId`).
pub fn resolve(id: OpId) -> &'static str {
    let t = table().lock().unwrap();
    t.names[id.0 as usize]
}

/// The id of an already-interned name, without interning it. `None`
/// means no node ever carried this name — callers doing read-only joins
/// (trace → graph) use this to avoid growing the table with miss keys.
pub fn lookup(name: &str) -> Option<OpId> {
    if name.is_empty() {
        return Some(OpId::EMPTY);
    }
    let t = table().lock().unwrap();
    t.map.get(name).map(|&id| OpId(id))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_stable_and_resolves() {
        let a = intern("intern.test.alpha");
        let b = intern("intern.test.beta");
        assert_ne!(a, b);
        assert_eq!(intern("intern.test.alpha"), a);
        assert_eq!(a.resolve(), "intern.test.alpha");
        assert_eq!(b.resolve(), "intern.test.beta");
    }

    #[test]
    fn empty_is_id_zero() {
        assert_eq!(intern(""), OpId::EMPTY);
        assert!(intern("").is_empty());
        assert_eq!(OpId::EMPTY.resolve(), "");
        assert_eq!(lookup(""), Some(OpId::EMPTY));
    }

    #[test]
    fn lookup_does_not_intern() {
        assert_eq!(lookup("intern.test.never-interned"), None);
        let c = intern("intern.test.gamma");
        assert_eq!(lookup("intern.test.gamma"), Some(c));
    }

    #[test]
    fn display_and_debug_resolve() {
        let d = intern("intern.test.delta");
        assert_eq!(format!("{d}"), "intern.test.delta");
        assert!(format!("{d:?}").contains("intern.test.delta"));
    }
}
