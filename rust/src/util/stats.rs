//! Small statistics helpers shared by the replayer, alignment and benches.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance; 0.0 for len < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Smallest element (+∞ for empty input).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Largest element (−∞ for empty input).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Relative error |est - truth| / truth, in percent.
pub fn rel_err_pct(est: f64, truth: f64) -> f64 {
    if truth == 0.0 {
        return 0.0;
    }
    100.0 * (est - truth).abs() / truth.abs()
}

/// Simple online timer summary used by the custom bench harness.
#[derive(Default, Clone, Debug)]
pub struct Summary {
    /// The recorded samples, in insertion order.
    pub samples: Vec<f64>,
}

impl Summary {
    /// Record one sample.
    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    /// Mean of the recorded samples.
    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }

    /// Standard deviation of the recorded samples.
    pub fn stddev(&self) -> f64 {
        stddev(&self.samples)
    }

    /// Median.
    pub fn p50(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    /// 99th percentile.
    pub fn p99(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[5.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn rel_err() {
        assert!((rel_err_pct(105.0, 100.0) - 5.0).abs() < 1e-12);
        assert!((rel_err_pct(95.0, 100.0) - 5.0).abs() < 1e-12);
        assert_eq!(rel_err_pct(1.0, 0.0), 0.0);
    }

    #[test]
    fn minmax() {
        let xs = [3.0, -1.0, 2.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 3.0);
    }
}
