//! Substrate utilities: seeded RNG, JSON, statistics, CLI arg parsing.
//!
//! The build image is fully offline with only the `xla` crate's dependency
//! closure available, so `rand`, `serde`, `clap` and `criterion` are
//! re-implemented here at the scale this project needs.

pub mod intern;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;

use std::collections::BTreeMap;

/// Microseconds, the time unit used across the whole crate (profilers emit
/// microsecond timestamps; iteration times are tens-to-hundreds of ms).
pub type Us = f64;

/// Tiny argv parser: positional args plus `--key value` / `--key=value` /
/// `--flag` options and single-letter `-k value` short options (the CLI
/// documents `-o trace.json`). Sufficient for the `dpro` CLI and examples;
/// errors on unknown '--' keys are left to the caller so subcommands can
/// define their own sets.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Non-option arguments, in order (the subcommand is first).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse an argv iterator (without the program name).
    pub fn parse(argv: impl Iterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut argv = argv.peekable();
        while let Some(a) = argv.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value or --key value or --flag
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if argv.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = argv.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else if a.len() == 2
                && a.starts_with('-')
                && a.as_bytes()[1].is_ascii_alphabetic()
            {
                // -k value short option (e.g. `-o trace.json`); a lone
                // short switch becomes a flag. Negative numbers stay
                // positional (second byte is a digit).
                let key = a[1..].to_string();
                if argv.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = argv.next().unwrap();
                    out.options.insert(key, v);
                } else {
                    out.flags.push(key);
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Option value, if given.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option value or a default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Option parsed as `usize`, or the default on absence/parse failure.
    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Option parsed as `f64`, or the default on absence/parse failure.
    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Option parsed as `u64`, or the default on absence/parse failure.
    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Whether a bare `--flag` was given.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// Format a microsecond duration human-readably (for reports).
pub fn fmt_us(us: Us) -> String {
    if us >= 1e6 {
        format!("{:.2} s", us / 1e6)
    } else if us >= 1e3 {
        format!("{:.2} ms", us / 1e3)
    } else {
        format!("{us:.1} us")
    }
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: f64) -> String {
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.2} KB", b / 1e3)
    } else {
        format!("{b:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(items: &[&str]) -> Args {
        Args::parse(items.iter().map(|s| s.to_string()))
    }

    #[test]
    fn args_positional_and_options() {
        let a = parse(&["replay", "--trace", "t.json", "--iters", "10", "fast"]);
        assert_eq!(a.positional, vec!["replay", "fast"]);
        assert_eq!(a.get("trace"), Some("t.json"));
        assert_eq!(a.usize("iters", 1), 10);
    }

    #[test]
    fn args_short_options() {
        let a = parse(&["profile", "-o", "trace.json", "--iters", "5"]);
        assert_eq!(a.get("o"), Some("trace.json"));
        assert_eq!(a.usize("iters", 0), 5);
        assert_eq!(a.positional, vec!["profile"]);
        // negative numbers are positional, not short options
        let b = parse(&["shift", "-5"]);
        assert_eq!(b.positional, vec!["shift", "-5"]);
    }

    #[test]
    fn args_eq_form_and_flags() {
        let a = parse(&["--mode=ps", "--verbose", "--k", "3"]);
        assert_eq!(a.get("mode"), Some("ps"));
        assert!(a.flag("verbose"));
        assert_eq!(a.usize("k", 0), 3);
        assert_eq!(a.usize("missing", 7), 7);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_us(12.3), "12.3 us");
        assert_eq!(fmt_us(12_300.0), "12.30 ms");
        assert_eq!(fmt_us(2_000_000.0), "2.00 s");
        assert_eq!(fmt_bytes(4.0e6), "4.00 MB");
    }
}

/// Print a padded ASCII table (bench harness output).
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(headers.iter().map(|h| h.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}
