//! Fault model: inject worker/machine/link failures into a [`GTrace`].
//!
//! Production fleets lose workers, drop NICs, and ship half-written trace
//! dumps; dPRO's replay-before-implement workflow (and Daydream's
//! estimate-efficacy-first idea) applies to failures just as well as to
//! optimizations. This module is the injection half of that story: a
//! small closed set of faults, each pinned to an iteration boundary,
//! parseable from a CLI spec string (`dpro replay|diagnose --inject …`)
//! and deterministic — the same fault on the same trace always produces
//! the same bytes. The detection half lives in `diagnosis/rank.rs`
//! ([`DiagKind::WorkerLost`] / [`DiagKind::LinkDegraded`] findings and
//! the `continue-on:<k>` what-if); the recovery half is
//! `MutableGraph::rescale_workers`. See `docs/FAULTS.md` for the full
//! grammar and semantics.
//!
//! Faults compose with the continuous degradation knobs in
//! [`crate::trace::degrade`] (clock drift, event drops, straggler
//! iterations): both operate in place on a `GTrace`, so any sequence of
//! the two families is a valid degraded-trace scenario.
//!
//! [`DiagKind::WorkerLost`]: crate::trace::validate::DiagKind::WorkerLost
//! [`DiagKind::LinkDegraded`]: crate::trace::validate::DiagKind::LinkDegraded

use crate::graph::dfg::OpKind;
use crate::trace::validate::{DiagKind, Severity, TraceReport};
use crate::trace::GTrace;

/// The valid `--inject` forms, quoted by every parse error.
pub const FAULT_FORMS: &str = "worker-crash:<w>@<iter>, machine-loss:<m>@<iter>, \
     nic-degrade:<m>:<factor>@<iter>, nic-flap:<m>:<factor>@<from>..<to>, \
     straggler:<w>:<factor>@<iter>";

/// One injectable fault, pinned to an iteration boundary.
///
/// Iteration pinning mirrors how elastic training frameworks observe
/// failures: a worker is lost *between* iterations (its last complete
/// iteration is `at_iter - 1`), a NIC degrades for a window of
/// iterations, a straggler persists from some iteration on.
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    /// Worker `worker` emits no events from iteration `at_iter` on —
    /// the trace signature of a process crash.
    WorkerCrash {
        /// Crashed worker (process id).
        worker: u16,
        /// First iteration the worker misses.
        at_iter: u32,
    },
    /// Every process on `machine` emits no events from `at_iter` on —
    /// a host failure takes all its workers at once.
    MachineLoss {
        /// Lost machine id.
        machine: u16,
        /// First iteration the machine misses.
        at_iter: u32,
    },
    /// `machine`'s NIC permanently degrades: SEND/RECV durations on it
    /// are multiplied by `factor` (> 1 slows) from `at_iter` on.
    NicDegrade {
        /// Machine whose NIC degrades.
        machine: u16,
        /// Duration multiplier for its SEND/RECV events.
        factor: f64,
        /// First affected iteration.
        at_iter: u32,
    },
    /// A transient NIC flap: like [`Fault::NicDegrade`] but only inside
    /// the half-open iteration window `[from_iter, to_iter)`.
    NicFlap {
        /// Machine whose NIC flaps.
        machine: u16,
        /// Duration multiplier while flapping.
        factor: f64,
        /// First affected iteration (inclusive).
        from_iter: u32,
        /// First iteration after recovery (exclusive).
        to_iter: u32,
    },
    /// Worker `worker` becomes a permanent straggler: its FW/BW kernel
    /// durations are multiplied by `factor` from `at_iter` on.
    Straggler {
        /// Straggling worker.
        worker: u16,
        /// Duration multiplier for its compute kernels.
        factor: f64,
        /// First affected iteration.
        at_iter: u32,
    },
}

impl std::fmt::Display for Fault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Fault::WorkerCrash { worker, at_iter } => {
                write!(f, "worker-crash:{worker}@{at_iter}")
            }
            Fault::MachineLoss { machine, at_iter } => {
                write!(f, "machine-loss:{machine}@{at_iter}")
            }
            Fault::NicDegrade { machine, factor, at_iter } => {
                write!(f, "nic-degrade:{machine}:{factor}@{at_iter}")
            }
            Fault::NicFlap { machine, factor, from_iter, to_iter } => {
                write!(f, "nic-flap:{machine}:{factor}@{from_iter}..{to_iter}")
            }
            Fault::Straggler { worker, factor, at_iter } => {
                write!(f, "straggler:{worker}:{factor}@{at_iter}")
            }
        }
    }
}

fn bad(spec: &str, why: &str) -> String {
    format!("invalid fault spec '{spec}': {why}; valid forms: {FAULT_FORMS}")
}

fn parse_u16(spec: &str, s: &str, what: &str) -> Result<u16, String> {
    s.parse::<u16>().map_err(|_| bad(spec, &format!("'{s}' is not a valid {what} id")))
}

fn parse_iter(spec: &str, s: &str) -> Result<u32, String> {
    s.parse::<u32>().map_err(|_| bad(spec, &format!("'{s}' is not a valid iteration")))
}

fn parse_factor(spec: &str, s: &str) -> Result<f64, String> {
    match s.parse::<f64>() {
        Ok(x) if x.is_finite() && x > 0.0 => Ok(x),
        _ => Err(bad(spec, &format!("'{s}' is not a positive finite factor"))),
    }
}

/// Split `body` at the last `@` into (head, iteration part).
fn split_at_iter<'a>(spec: &str, body: &'a str) -> Result<(&'a str, &'a str), String> {
    body.rsplit_once('@').ok_or_else(|| bad(spec, "missing '@<iter>'"))
}

impl Fault {
    /// Parse one fault from its canonical spec form (the inverse of
    /// `Display`): `worker-crash:<w>@<iter>`, `machine-loss:<m>@<iter>`,
    /// `nic-degrade:<m>:<factor>@<iter>`,
    /// `nic-flap:<m>:<factor>@<from>..<to>`,
    /// `straggler:<w>:<factor>@<iter>`.
    pub fn parse(spec: &str) -> Result<Fault, String> {
        let spec = spec.trim();
        if let Some(body) = spec.strip_prefix("worker-crash:") {
            let (w, it) = split_at_iter(spec, body)?;
            return Ok(Fault::WorkerCrash {
                worker: parse_u16(spec, w, "worker")?,
                at_iter: parse_iter(spec, it)?,
            });
        }
        if let Some(body) = spec.strip_prefix("machine-loss:") {
            let (m, it) = split_at_iter(spec, body)?;
            return Ok(Fault::MachineLoss {
                machine: parse_u16(spec, m, "machine")?,
                at_iter: parse_iter(spec, it)?,
            });
        }
        if let Some(body) = spec.strip_prefix("nic-degrade:") {
            let (head, it) = split_at_iter(spec, body)?;
            let (m, fac) = head.split_once(':').ok_or_else(|| bad(spec, "missing ':<factor>'"))?;
            return Ok(Fault::NicDegrade {
                machine: parse_u16(spec, m, "machine")?,
                factor: parse_factor(spec, fac)?,
                at_iter: parse_iter(spec, it)?,
            });
        }
        if let Some(body) = spec.strip_prefix("nic-flap:") {
            let (head, window) = split_at_iter(spec, body)?;
            let (m, fac) = head.split_once(':').ok_or_else(|| bad(spec, "missing ':<factor>'"))?;
            let (from, to) = window
                .split_once("..")
                .ok_or_else(|| bad(spec, "flap window must be '<from>..<to>'"))?;
            let (from_iter, to_iter) = (parse_iter(spec, from)?, parse_iter(spec, to)?);
            if to_iter <= from_iter {
                return Err(bad(spec, "flap window is empty (need from < to)"));
            }
            return Ok(Fault::NicFlap {
                machine: parse_u16(spec, m, "machine")?,
                factor: parse_factor(spec, fac)?,
                from_iter,
                to_iter,
            });
        }
        if let Some(body) = spec.strip_prefix("straggler:") {
            let (head, it) = split_at_iter(spec, body)?;
            let (w, fac) = head.split_once(':').ok_or_else(|| bad(spec, "missing ':<factor>'"))?;
            return Ok(Fault::Straggler {
                worker: parse_u16(spec, w, "worker")?,
                factor: parse_factor(spec, fac)?,
                at_iter: parse_iter(spec, it)?,
            });
        }
        Err(bad(spec, "unknown fault kind"))
    }

    /// Apply the fault to a trace in place; returns the number of events
    /// removed (crash/loss) or edited (NIC/straggler). Deterministic and
    /// idempotent for removals; duration faults compound if re-applied.
    pub fn apply(&self, trace: &mut GTrace) -> usize {
        match *self {
            Fault::WorkerCrash { worker, at_iter } => {
                let before = trace.events.len();
                trace.events.retain(|e| !(e.proc == worker && e.iter >= at_iter));
                before - trace.events.len()
            }
            Fault::MachineLoss { machine, at_iter } => {
                let before = trace.events.len();
                trace.events.retain(|e| !(e.machine == machine && e.iter >= at_iter));
                before - trace.events.len()
            }
            Fault::NicDegrade { machine, factor, at_iter } => {
                stretch_comm(trace, machine, factor, at_iter, u32::MAX)
            }
            Fault::NicFlap { machine, factor, from_iter, to_iter } => {
                stretch_comm(trace, machine, factor, from_iter, to_iter)
            }
            Fault::Straggler { worker, factor, at_iter } => {
                let mut n = 0;
                for e in &mut trace.events {
                    if e.proc == worker
                        && e.iter >= at_iter
                        && matches!(e.kind, OpKind::Forward | OpKind::Backward)
                    {
                        e.dur *= factor;
                        n += 1;
                    }
                }
                n
            }
        }
    }

    /// Like [`Fault::apply`], but also records the injection in the
    /// trace report so downstream consumers (CLI `--json`, diagnosis)
    /// see *why* the trace is degraded. Crash/loss faults record a
    /// [`DiagKind::WorkerLost`] warning, NIC faults a
    /// [`DiagKind::LinkDegraded`] warning; a straggler leaves no marker
    /// (it is detected, not declared — `rank` flags the machine).
    pub fn apply_with_report(&self, trace: &mut GTrace, report: &mut TraceReport) -> usize {
        let n = self.apply(trace);
        match *self {
            Fault::WorkerCrash { worker, at_iter } => report.push(
                Severity::Warning,
                DiagKind::WorkerLost,
                format!("injected {self}: worker {worker} lost at iteration {at_iter} ({n} events removed)"),
            ),
            Fault::MachineLoss { machine, at_iter } => report.push(
                Severity::Warning,
                DiagKind::WorkerLost,
                format!("injected {self}: machine {machine} lost at iteration {at_iter} ({n} events removed)"),
            ),
            Fault::NicDegrade { machine, .. } | Fault::NicFlap { machine, .. } => report.push(
                Severity::Warning,
                DiagKind::LinkDegraded,
                format!("injected {self}: NIC on machine {machine} degraded ({n} comm events stretched)"),
            ),
            Fault::Straggler { .. } => {}
        }
        n
    }
}

/// Multiply SEND/RECV durations on `machine` inside `[from, to)`.
fn stretch_comm(trace: &mut GTrace, machine: u16, factor: f64, from: u32, to: u32) -> usize {
    let mut n = 0;
    for e in &mut trace.events {
        if e.machine == machine
            && e.iter >= from
            && e.iter < to
            && matches!(e.kind, OpKind::Send | OpKind::Recv)
        {
            e.dur *= factor;
            n += 1;
        }
    }
    n
}

/// Parse a comma-separated fault list (the `--inject` argument).
pub fn parse_faults(list: &str) -> Result<Vec<Fault>, String> {
    let mut out = Vec::new();
    for part in list.split(',') {
        if part.trim().is_empty() {
            continue;
        }
        out.push(Fault::parse(part)?);
    }
    if out.is_empty() {
        return Err(format!("empty fault list; valid forms: {FAULT_FORMS}"));
    }
    Ok(out)
}

/// Apply every fault in order, recording each in the report; returns the
/// total event count removed/edited.
pub fn apply_all(faults: &[Fault], trace: &mut GTrace, report: &mut TraceReport) -> usize {
    faults.iter().map(|f| f.apply_with_report(trace, report)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceEvent;

    fn ev(name: &str, kind: OpKind, proc: u16, machine: u16, iter: u32, dur: f64) -> TraceEvent {
        TraceEvent {
            name: name.into(),
            kind,
            ts: 1000.0 * iter as f64,
            dur,
            proc,
            machine,
            iter,
            txid: None,
        }
    }

    fn toy() -> GTrace {
        let mut events = Vec::new();
        for iter in 0..3u32 {
            for w in 0..4u16 {
                let m = w / 2;
                events.push(ev(&format!("w{w}.FW"), OpKind::Forward, w, m, iter, 100.0));
                events.push(ev(&format!("w{w}.SEND"), OpKind::Send, w, m, iter, 40.0));
                events.push(ev(&format!("w{w}.RECV"), OpKind::Recv, w, m, iter, 40.0));
            }
        }
        GTrace { events, n_workers: 4, n_procs: 4, iterations: 3 }
    }

    #[test]
    fn parse_roundtrips_and_rejects() {
        for s in [
            "worker-crash:3@1",
            "machine-loss:1@2",
            "nic-degrade:1:5@1",
            "nic-flap:0:3.5@1..3",
            "straggler:2:4@0",
        ] {
            let f = Fault::parse(s).unwrap();
            assert_eq!(f.to_string(), s, "display must round-trip");
            assert_eq!(Fault::parse(&f.to_string()).unwrap(), f);
        }
        for s in [
            "worker-crash:3",     // missing @iter
            "worker-crash:x@1",   // bad worker
            "nic-degrade:1@1",    // missing factor
            "nic-degrade:1:0@1",  // non-positive factor
            "nic-flap:1:2@3..3",  // empty window
            "nic-flap:1:2@3..1",  // inverted window
            "gpu-melt:1@1",       // unknown kind
            "",
        ] {
            let e = Fault::parse(s).unwrap_err();
            assert!(e.contains("worker-crash"), "error must list valid forms: {e}");
        }
        let fs = parse_faults("worker-crash:1@1, nic-flap:0:2@1..2").unwrap();
        assert_eq!(fs.len(), 2);
        assert!(parse_faults("  ").is_err());
    }

    #[test]
    fn crash_removes_only_the_worker_from_the_boundary() {
        let mut t = toy();
        let n = Fault::parse("worker-crash:1@1").unwrap().apply(&mut t);
        assert_eq!(n, 6, "2 iterations x 3 events");
        assert!(t.events.iter().all(|e| e.proc != 1 || e.iter < 1));
        // other workers and w1's pre-crash iteration are untouched
        assert_eq!(t.events.len(), 36 - 6);
    }

    #[test]
    fn machine_loss_takes_all_colocated_workers() {
        let mut t = toy();
        let n = Fault::parse("machine-loss:1@2").unwrap().apply(&mut t);
        assert_eq!(n, 6, "workers 2,3 x 1 iteration x 3 events");
        assert!(t.events.iter().all(|e| e.machine != 1 || e.iter < 2));
    }

    #[test]
    fn nic_faults_stretch_only_comm_in_window() {
        let mut t = toy();
        let n = Fault::parse("nic-flap:0:5@1..2").unwrap().apply(&mut t);
        assert_eq!(n, 4, "2 workers x 1 iteration x SEND+RECV");
        for e in &t.events {
            let hit = e.machine == 0 && e.iter == 1 && matches!(e.kind, OpKind::Send | OpKind::Recv);
            assert_eq!(e.dur, if hit { 200.0 } else if e.kind == OpKind::Forward { 100.0 } else { 40.0 });
        }
        // permanent degrade covers the open end
        let n = Fault::parse("nic-degrade:1:2@1").unwrap().apply(&mut t);
        assert_eq!(n, 8, "2 workers x 2 iterations x SEND+RECV");
    }

    #[test]
    fn straggler_stretches_compute_only() {
        let mut t = toy();
        let n = Fault::parse("straggler:0:3@0").unwrap().apply(&mut t);
        assert_eq!(n, 3, "FW each iteration");
        assert!(t
            .events
            .iter()
            .filter(|e| e.proc == 0 && e.kind == OpKind::Forward)
            .all(|e| e.dur == 300.0));
    }

    #[test]
    fn apply_with_report_records_the_injection() {
        let mut t = toy();
        let mut rep = TraceReport::default();
        let faults = parse_faults("worker-crash:1@1,nic-degrade:0:4@0").unwrap();
        let n = apply_all(&faults, &mut t, &mut rep);
        assert!(n > 0);
        assert_eq!(rep.count(DiagKind::WorkerLost), 1);
        assert_eq!(rep.count(DiagKind::LinkDegraded), 1);
        assert!(rep.no_errors(), "injections are warnings: {rep}");
    }

    #[test]
    fn faults_compose_with_degrade_knobs() {
        use crate::trace::degrade;
        let mut t = toy();
        Fault::parse("worker-crash:3@1").unwrap().apply(&mut t);
        let shifted = degrade::inject_drift(&mut t, 1, 500.0);
        assert!(shifted > 0);
        let dropped = degrade::drop_events(&mut t, 0.2, 7);
        assert!(dropped > 0);
        // deterministic under a fixed seed: same pipeline, same bytes
        let mut t2 = toy();
        Fault::parse("worker-crash:3@1").unwrap().apply(&mut t2);
        degrade::inject_drift(&mut t2, 1, 500.0);
        degrade::drop_events(&mut t2, 0.2, 7);
        assert_eq!(t.events, t2.events);
    }
}
