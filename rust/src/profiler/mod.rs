//! The dPRO profiler front-end: joins a measured [`GTrace`] with the job's
//! global-DFG skeleton, applies trace time alignment (§4.2), and produces
//! the replayer-ready graph + iteration estimate. This is the `dpro
//! replay` pipeline of the paper's Fig. 3.

use crate::alignment::{align, Alignment};
use crate::config::JobSpec;
use crate::diagnosis::TraceFacts;
use crate::graph::dfg::OpKind;
use crate::graph::{build_global, AnalyticCost, GlobalDfg};
use crate::replay::tiered::{ReplayMode, TierReport, TieredReplayer};
use crate::replay::{replay_once, ReplayResult};
use crate::trace::{GTrace, ProfileDb};
use crate::util::Us;
use std::collections::HashMap;

/// Build the per-op duration table from a measured trace.
///
/// Non-RECV durations are drift-immune (same-clock differences) and are
/// averaged directly. RECV durations are corrected with the paper's
/// clipping formula `ed + θⱼ − max(st + θⱼ, send_st + θᵢ)`; passing
/// [`Alignment::identity`] gives the "w/o alignment" ablation where raw
/// (drifted) timestamps are used for the clip.
pub fn corrected_profile(trace: &GTrace, alignment: &Alignment) -> ProfileDb {
    // index sends by (txid, iter); the clip point is the SEND's completion
    // (unlike the paper's instantaneous send posts, our SEND ops occupy
    // the tx wire, so data cannot arrive before the send finishes)
    let mut sends: HashMap<(u64, u32), (u16, f64)> = HashMap::new();
    for e in &trace.events {
        if e.kind == OpKind::Send {
            if let Some(t) = e.txid {
                sends.insert((t, e.iter), (e.proc, e.ts + e.dur));
            }
        }
    }
    // previous RECV's end on the same process within the same iteration:
    // the rx wire cannot have been serving this transfer before it freed
    // up, so the measured queue wait is excluded from the service time
    // (the replayer re-creates queueing from device serialization).
    let mut order: Vec<usize> = (0..trace.events.len()).collect();
    order.sort_by(|&a, &b| {
        let (ea, eb) = (&trace.events[a], &trace.events[b]);
        // total_cmp: a NaN timestamp in a hand-edited trace must not panic
        // the profiler (NaNs sort last instead)
        (ea.proc, ea.iter)
            .cmp(&(eb.proc, eb.iter))
            .then((ea.ts + ea.dur).total_cmp(&(eb.ts + eb.dur)))
    });
    let mut prev_end: Vec<f64> = vec![f64::NEG_INFINITY; trace.events.len()];
    let mut last: HashMap<(u16, u32), f64> = HashMap::new();
    for &i in &order {
        let e = &trace.events[i];
        if e.kind != OpKind::Recv {
            continue;
        }
        let key = (e.proc, e.iter);
        if let Some(&p) = last.get(&key) {
            prev_end[i] = p;
        }
        last.insert(key, e.ts + e.dur);
    }

    let mut agg: HashMap<&str, (f64, u32)> = HashMap::new();
    for (i, e) in trace.events.iter().enumerate() {
        let dur = if e.kind == OpKind::Recv {
            match e.txid.and_then(|t| sends.get(&(t, e.iter))) {
                Some(&(sp, send_end)) => {
                    // send completion expressed in the receiver's clock
                    let send_adj =
                        send_end + alignment.offset(sp) - alignment.offset(e.proc);
                    let start_est = e.ts.max(send_adj).max(prev_end[i]);
                    ((e.ts + e.dur) - start_est).max(0.0)
                }
                None => e.dur,
            }
        } else {
            e.dur
        };
        let ent = agg.entry(e.name.as_str()).or_insert((0.0, 0));
        ent.0 += dur;
        ent.1 += 1;
    }
    let mut db = ProfileDb::default();
    for (name, (sum, cnt)) in agg {
        db.insert(name.to_string(), sum / cnt as f64);
    }
    db
}

/// A complete dPRO estimate for one job from its measured trace.
pub struct Estimate {
    /// The global DFG with profiled durations applied.
    pub graph: GlobalDfg,
    /// The replayed schedule.
    pub result: ReplayResult,
    /// The solved (or identity) clock alignment used.
    pub alignment: Alignment,
    /// ops whose duration came from the trace (coverage diagnostic)
    pub profiled_ops: usize,
    /// What the tiered engine did, when tiered replay was requested
    /// (`None` under [`ReplayMode::Exact`]).
    pub tier: Option<TierReport>,
}

impl Estimate {
    /// Estimated iteration time (us).
    pub fn iteration_us(&self) -> Us {
        self.result.iteration_time
    }

    /// Worker 0's forward busy time (us).
    pub fn fw_us(&self) -> Us {
        self.result.kind_time(&self.graph, 0, OpKind::Forward)
    }

    /// Worker 0's backward busy time (us).
    pub fn bw_us(&self) -> Us {
        self.result.kind_time(&self.graph, 0, OpKind::Backward)
    }

    /// Estimated peak memory per worker (bytes).
    pub fn peak_memory(&self, spec: &JobSpec) -> f64 {
        crate::replay::estimate_peak_memory(spec, &self.graph, &self.result)
    }
}

/// Replay a job from its measured trace, with or without time alignment.
pub fn estimate(spec: &JobSpec, trace: &GTrace, use_alignment: bool) -> Estimate {
    estimate_with_mode(spec, trace, use_alignment, ReplayMode::Exact)
}

/// Like [`estimate`], but selecting the replay engine. Under
/// [`ReplayMode::Tiered`] the trace's straggler/drift/lost-worker
/// evidence ([`TraceFacts`]) feeds the class splitter: machines the
/// diagnosis thresholds flag are demoted up front, and the tiered
/// engine's own symmetry verification (which sees the profiled,
/// per-worker durations) catches everything subtler — either way the
/// result equals exact replay, and [`Estimate::tier`] reports which
/// engine actually ran.
pub fn estimate_with_mode(
    spec: &JobSpec,
    trace: &GTrace,
    use_alignment: bool,
    mode: ReplayMode,
) -> Estimate {
    let alignment = if use_alignment { align(trace, 1.0, 1.0) } else { Alignment::identity() };
    // without the alignment machinery there is no SEND-clipping either:
    // the profiler can only average the raw (launch-inflated) durations
    let db = if use_alignment {
        corrected_profile(trace, &alignment)
    } else {
        trace.profile_db()
    };
    let mut graph = build_global(spec, &AnalyticCost::new(spec));
    let profiled_ops = db.apply(&mut graph);
    let (result, tier) = match mode {
        ReplayMode::Exact => (replay_once(&graph), None),
        ReplayMode::Tiered => {
            let mut rp = TieredReplayer::new(&graph, spec);
            let facts = TraceFacts::from_trace_aligned(trace, &alignment);
            rp.demote_machines(facts.broken_machines(spec.cluster.gpus_per_machine));
            let result = rp.replay(&graph).clone();
            (result, Some(rp.report().clone()))
        }
    };
    Estimate { graph, result, alignment, profiled_ops, tier }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobSpec, Transport};
    use crate::testbed::{run, TestbedOpts};
    use crate::util::stats::rel_err_pct;

    fn accuracy(model: &str, scheme: &str, transport: Transport, aligned: bool) -> f64 {
        let spec = JobSpec::standard(model, scheme, transport);
        let tb = run(&spec, &TestbedOpts { iterations: 10, ..Default::default() });
        let est = estimate(&spec, &tb.trace, aligned);
        rel_err_pct(est.iteration_us(), tb.avg_iter())
    }

    #[test]
    fn aligned_replay_under_5pct_resnet_horovod_rdma() {
        let err = accuracy("resnet50", "horovod", Transport::Rdma, true);
        assert!(err < 5.0, "err={err:.2}%");
    }

    #[test]
    fn aligned_replay_under_5pct_byteps_tcp() {
        let err = accuracy("resnet50", "byteps", Transport::Tcp, true);
        assert!(err < 6.0, "err={err:.2}%");
    }

    #[test]
    fn alignment_reduces_error() {
        let with = accuracy("resnet50", "horovod", Transport::Rdma, true);
        let without = accuracy("resnet50", "horovod", Transport::Rdma, false);
        assert!(
            with <= without + 0.5,
            "aligned={with:.2}% unaligned={without:.2}%"
        );
    }

    #[test]
    fn profile_coverage_complete() {
        let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
        let tb = run(&spec, &TestbedOpts { iterations: 3, ..Default::default() });
        let est = estimate(&spec, &tb.trace, true);
        // every non-virtual op must have a measured duration
        let non_virtual = est
            .graph
            .dfg
            .nodes
            .iter()
            .filter(|n| !n.kind.is_virtual())
            .count();
        assert_eq!(est.profiled_ops, non_virtual);
    }

    #[test]
    fn fw_bw_breakdown_close_to_truth() {
        let spec = JobSpec::standard("bert_base", "horovod", Transport::Rdma);
        let tb = run(&spec, &TestbedOpts { iterations: 5, ..Default::default() });
        let est = estimate(&spec, &tb.trace, true);
        let fw_err = rel_err_pct(est.fw_us(), tb.fw_time);
        let bw_err = rel_err_pct(est.bw_us(), tb.bw_time);
        assert!(fw_err < 3.0, "fw err={fw_err:.2}%");
        assert!(bw_err < 3.0, "bw err={bw_err:.2}%");
    }
}
