//! Hierarchical RAII spans with per-thread buffers and a global sink.
//!
//! Design constraints, in order:
//!
//! 1. **Disabled is free.** Every `span()` call starts with one relaxed
//!    [`AtomicBool`] load; when collection is off nothing else happens —
//!    no TLS touch, no clock read, no interning. The perf_hotpath bench
//!    pins this cost (`obs_overhead` section, ≤2% of a replay round).
//! 2. **Hot path is thread-local.** An open span pushes onto a
//!    thread-local stack; a closing span pops it and appends one
//!    [`SpanRec`] to a thread-local buffer. The global sink mutex is
//!    taken only when a *root* span closes (or a thread exits), so
//!    nested spans never contend.
//! 3. **Parenting crosses threads explicitly.** `util/pool.rs` captures
//!    the submitting thread's context ([`current_ctx`]) and installs it
//!    in the worker ([`inherit`]), so spans recorded inside
//!    `parallel_for` / `FixedPool` jobs parent under the span that
//!    spawned the work.
//!
//! Spans must close in LIFO order per thread — guaranteed by RAII
//! scoping; the pop loop tolerates (and silently discards) violations
//! rather than corrupting the stack. A `SpanGuard` is `!Send`: dropping
//! it on a different thread than created it would pop the wrong stack.

use crate::util::intern::{intern, OpId};
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU16, AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;

/// What a span's duration represents — mapped by the exporter onto the
/// non-overlap-checked gTrace op kinds so a self-trace dump validates
/// with zero diagnostics (see `docs/OBSERVABILITY.md` for the table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Computation — the thread is doing the named work (→ `AGG`).
    Work,
    /// Blocked — queue wait, lock wait, condvar (→ `NEG`).
    Wait,
    /// Ingress — reading/parsing input (→ `IN`).
    Read,
    /// Egress — serializing/writing output (→ `OUT`).
    Write,
    /// Remote call — HTTP request to another process (→ `SEND`).
    Net,
}

/// One closed span, as drained by [`take_spans`].
#[derive(Clone, Debug)]
pub struct SpanRec {
    /// Interned span name (`replay.exact`, `serve.request`, ...).
    pub name: OpId,
    /// What the duration represents.
    pub kind: SpanKind,
    /// Process-unique span id (never 0).
    pub id: u64,
    /// Id of the enclosing span — same-thread nesting or an inherited
    /// cross-thread parent; 0 for a root span.
    pub parent: u64,
    /// Per-thread lane (the exporter's `proc`): dense small ids reused
    /// as threads exit, so short-lived scoped threads don't inflate the
    /// dump's process count.
    pub lane: u16,
    /// Start, µs since the telemetry epoch ([`super::now_us`]).
    pub start_us: f64,
    /// Duration in µs (clamped non-negative).
    pub dur_us: f64,
}

/// Hard cap on buffered spans; beyond it the newest spans are counted in
/// [`dropped_spans`] instead of growing memory without bound. 2^20 spans
/// ≈ 56 MiB — far above any CLI run that then dumps and drains.
pub const SINK_CAP: usize = 1 << 20;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static SINK: Mutex<Vec<SpanRec>> = Mutex::new(Vec::new());
// Lane allocator: lowest free id first, so lanes stay dense no matter
// how many scoped threads come and go.
static LANE_FREE: Mutex<Vec<u16>> = Mutex::new(Vec::new());
static LANE_HIGH: AtomicU16 = AtomicU16::new(0);

/// Turn span collection on or off process-wide. Metrics are unaffected
/// (always on). Spans opened while enabled still record on drop after a
/// disable — the flag gates span *creation* only.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Relaxed);
}

/// Whether span collection is currently enabled.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Relaxed)
}

/// Spans discarded because the sink was at [`SINK_CAP`].
pub fn dropped_spans() -> u64 {
    DROPPED.load(Relaxed)
}

struct ThreadBuf {
    lane: u16,
    /// Open span ids, innermost last.
    stack: Vec<u64>,
    /// Cross-thread parent installed by [`inherit`]; used when `stack`
    /// is empty. 0 = none.
    inherited: u64,
    buf: Vec<SpanRec>,
}

impl ThreadBuf {
    fn new() -> ThreadBuf {
        let lane = LANE_FREE
            .lock()
            .ok()
            .and_then(|mut free| free.pop())
            // `% u16::MAX` keeps the lane below the trace format's
            // coordinator sentinel (u16::MAX); collisions are only
            // possible past 65535 *concurrent* threads.
            .unwrap_or_else(|| LANE_HIGH.fetch_add(1, Relaxed) % u16::MAX);
        ThreadBuf { lane, stack: Vec::new(), inherited: 0, buf: Vec::new() }
    }
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        flush_into_sink(&mut self.buf);
        if let Ok(mut free) = LANE_FREE.lock() {
            free.push(self.lane);
        }
    }
}

thread_local! {
    static TLS: RefCell<ThreadBuf> = RefCell::new(ThreadBuf::new());
}

fn flush_into_sink(buf: &mut Vec<SpanRec>) {
    if buf.is_empty() {
        return;
    }
    let mut sink = SINK.lock().unwrap_or_else(|p| p.into_inner());
    let room = SINK_CAP.saturating_sub(sink.len());
    if buf.len() > room {
        DROPPED.fetch_add((buf.len() - room) as u64, Relaxed);
        buf.truncate(room);
    }
    sink.append(buf);
}

/// Open a span. Returns a guard that records the span when dropped; bind
/// it (`let _g = ...`) — an unnamed `let _ =` drops immediately and
/// records a zero-length span.
///
/// Interns `name` on every call; call sites inside hot loops should
/// intern once up front and use [`span_interned`].
#[must_use]
pub fn span(name: &str, kind: SpanKind) -> SpanGuard {
    if !enabled() {
        return SpanGuard::inactive(kind);
    }
    span_interned(intern(name), kind)
}

/// [`span`] with a pre-interned name — the hot-loop form.
#[must_use]
pub fn span_interned(name: OpId, kind: SpanKind) -> SpanGuard {
    if !enabled() {
        return SpanGuard::inactive(kind);
    }
    let id = NEXT_ID.fetch_add(1, Relaxed);
    let parent = TLS.with(|t| {
        let mut t = t.borrow_mut();
        let parent = t.stack.last().copied().unwrap_or(t.inherited);
        t.stack.push(id);
        parent
    });
    SpanGuard {
        live: true,
        id,
        parent,
        name,
        kind,
        start_us: super::now_us(),
        _not_send: std::marker::PhantomData,
    }
}

/// RAII guard for an open span; records the [`SpanRec`] on drop.
pub struct SpanGuard {
    live: bool,
    id: u64,
    parent: u64,
    name: OpId,
    kind: SpanKind,
    start_us: f64,
    // a guard must drop on the thread that created it (it pops that
    // thread's span stack), so: !Send
    _not_send: std::marker::PhantomData<*const ()>,
}

impl SpanGuard {
    fn inactive(kind: SpanKind) -> SpanGuard {
        SpanGuard {
            live: false,
            id: 0,
            parent: 0,
            name: OpId::EMPTY,
            kind,
            start_us: 0.0,
            _not_send: std::marker::PhantomData,
        }
    }

    /// This span's id — parent for spans recorded on other threads via
    /// [`current_ctx`]/[`inherit`]; 0 when collection was disabled at
    /// creation.
    pub fn id(&self) -> u64 {
        self.id
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        let end_us = super::now_us();
        // TLS teardown may already have destroyed the buffer (a guard
        // held in another TLS destructor); losing that one span beats
        // aborting the process.
        let _ = TLS.try_with(|t| {
            let mut t = t.borrow_mut();
            // pop through our id — tolerates non-LIFO drops by
            // discarding the ids opened (and leaked) above us
            while let Some(top) = t.stack.pop() {
                if top == self.id {
                    break;
                }
            }
            t.buf.push(SpanRec {
                name: self.name,
                kind: self.kind,
                id: self.id,
                parent: self.parent,
                lane: t.lane,
                start_us: self.start_us,
                dur_us: (end_us - self.start_us).max(0.0),
            });
            if t.stack.is_empty() {
                flush_into_sink(&mut t.buf);
            }
        });
    }
}

/// A capture of the calling thread's innermost open span, for parenting
/// work handed to another thread. Copyable and inert — installing it is
/// [`inherit`]'s job.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpanCtx {
    parent: u64,
}

/// Capture the current span context: the innermost open span on this
/// thread (or its own inherited parent when none is open). Returns an
/// empty context when collection is disabled — making the
/// capture/install pair a no-op end to end.
pub fn current_ctx() -> SpanCtx {
    if !enabled() {
        return SpanCtx { parent: 0 };
    }
    let parent =
        TLS.with(|t| {
            let t = t.borrow();
            t.stack.last().copied().unwrap_or(t.inherited)
        });
    SpanCtx { parent }
}

/// Install a captured context as this thread's parent for root spans,
/// until the returned guard drops (which restores the previous value —
/// panic-safe, so pool workers can wrap jobs in it). No-op for an empty
/// context.
pub fn inherit(ctx: SpanCtx) -> CtxGuard {
    if ctx.parent == 0 {
        return CtxGuard { prev: 0, installed: false };
    }
    let prev = TLS.with(|t| std::mem::replace(&mut t.borrow_mut().inherited, ctx.parent));
    CtxGuard { prev, installed: true }
}

/// Restores the previously inherited span context on drop. See
/// [`inherit`].
pub struct CtxGuard {
    prev: u64,
    installed: bool,
}

impl Drop for CtxGuard {
    fn drop(&mut self) {
        if self.installed {
            let prev = self.prev;
            let _ = TLS.try_with(|t| t.borrow_mut().inherited = prev);
        }
    }
}

/// Flush this thread's span buffer to the global sink. Root-span drops
/// and thread exits flush automatically; callers draining mid-flight
/// (the exporter, tests) use this to pick up spans recorded under a
/// still-open root.
pub fn flush_thread() {
    let _ = TLS.try_with(|t| {
        if let Ok(mut t) = t.try_borrow_mut() {
            flush_into_sink(&mut t.buf);
        }
    });
}

/// Drain every buffered span (flushing the calling thread first). Spans
/// buffered on *other* live threads under still-open roots are not
/// included — they arrive when their root closes.
pub fn take_spans() -> Vec<SpanRec> {
    flush_thread();
    let mut sink = SINK.lock().unwrap_or_else(|p| p.into_inner());
    std::mem::take(&mut *sink)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Unit tests here only cover what cannot race with the integration
    // suite (separate process): the disabled fast path. Enabled-mode
    // behavior lives in rust/tests/obs.rs behind one serializing lock.
    #[test]
    fn disabled_spans_record_nothing() {
        assert!(!enabled(), "spans must be off by default");
        {
            let g = span("span.test.disabled", SpanKind::Work);
            assert_eq!(g.id(), 0);
        }
        flush_thread();
        // cannot assert the sink is empty (other lib tests may enable);
        // but our named span must not be present
        let sink = SINK.lock().unwrap_or_else(|p| p.into_inner());
        assert!(sink.iter().all(|s| s.name.resolve() != "span.test.disabled"));
    }

    #[test]
    fn disabled_ctx_is_inert() {
        let ctx = current_ctx();
        let _g = inherit(ctx);
        assert_eq!(format!("{ctx:?}"), "SpanCtx { parent: 0 }");
    }
}
