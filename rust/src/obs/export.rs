//! Dump collected spans as a gTrace directory — dpro's own execution in
//! dpro's own trace format, loadable by Perfetto and by
//! [`crate::trace::io::load_dir`].
//!
//! Span kinds map onto the gTrace op kinds that the validator never
//! overlap- or pairing-checks (`AGG`/`NEG`/`IN`/`OUT`/`SEND`; see
//! [`SpanKind`]), every event carries `txid: None` and `iter: 0`, and
//! lanes become `proc` ids — so a self-trace dump re-ingests with **zero
//! diagnostics of any severity**, which `rust/tests/obs.rs` pins. Parent
//! links are not representable in the on-disk format; within a lane they
//! are visible as time-nesting (Perfetto renders the containment), and
//! tests read them from [`SpanRec`] directly.

use super::span::{SpanKind, SpanRec};
use super::{global, take_spans};
use crate::graph::OpKind;
use crate::trace::io::{dump_dir, DumpSummary};
use crate::trace::{GTrace, TraceEvent};
use std::path::Path;

/// The gTrace op kind a span kind is exported as.
pub fn op_kind_for(kind: SpanKind) -> OpKind {
    match kind {
        SpanKind::Work => OpKind::Aggregate,
        SpanKind::Wait => OpKind::Negotiate,
        SpanKind::Read => OpKind::In,
        SpanKind::Write => OpKind::Out,
        SpanKind::Net => OpKind::Send,
    }
}

/// Assemble spans into an in-memory [`GTrace`]: events sorted by
/// `(start, id)`, one `proc` per lane, a single declared iteration. An
/// empty span set yields one zero-length `obs.idle` marker so the dump
/// directory is still a loadable trace.
pub fn gtrace_from_spans(spans: &[SpanRec]) -> GTrace {
    let mut ordered: Vec<&SpanRec> = spans.iter().collect();
    ordered.sort_by(|a, b| {
        a.start_us
            .partial_cmp(&b.start_us)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    let mut events: Vec<TraceEvent> = ordered
        .iter()
        .map(|s| TraceEvent {
            name: s.name.resolve().to_string(),
            kind: op_kind_for(s.kind),
            ts: s.start_us,
            dur: s.dur_us,
            proc: s.lane,
            machine: 0,
            iter: 0,
            txid: None,
        })
        .collect();
    if events.is_empty() {
        events.push(TraceEvent {
            name: "obs.idle".to_string(),
            kind: OpKind::Aggregate,
            ts: 0.0,
            dur: 0.0,
            proc: 0,
            machine: 0,
            iter: 0,
            txid: None,
        });
    }
    let n_procs = events.iter().map(|e| e.proc as usize + 1).max().unwrap_or(1);
    GTrace { events, n_workers: 1, n_procs, iterations: 1 }
}

/// Drain the span sink and write it to `dir` as a gTrace dump, plus a
/// `metrics.prom` sidecar with the [`global`] registry's Prometheus text
/// (non-`.json` files are ignored by the trace loader). Returns the dump
/// summary; the sink is left empty either way.
pub fn dump_self_trace(dir: &Path) -> Result<DumpSummary, String> {
    let spans = take_spans();
    let trace = gtrace_from_spans(&spans);
    let summary =
        dump_dir(&trace, dir).map_err(|e| format!("self-trace dump {}: {e}", dir.display()))?;
    let prom = global().render_prometheus();
    std::fs::write(dir.join("metrics.prom"), prom)
        .map_err(|e| format!("self-trace metrics {}: {e}", dir.display()))?;
    Ok(summary)
}
