//! Self-telemetry: dpro profiles dpro.
//!
//! The thesis of the source paper is that you cannot fix a distributed
//! system you cannot observe — this module applies that standard to the
//! tool itself. It is a zero-dependency, std-only telemetry layer with
//! two independent halves:
//!
//! - **Spans** ([`span`], [`SpanGuard`]) — hierarchical RAII timing
//!   regions with interned names ([`crate::util::intern`]), a monotonic
//!   process clock ([`now_us`]), per-thread buffers, and a global sink.
//!   Span collection is **off by default** and costs one relaxed atomic
//!   load per call site when disabled; `--self-trace <dir>` (or
//!   [`set_enabled`]) turns it on. The exporter ([`export`]) writes the
//!   collected span forest in the crate's own gTrace format, so a dpro
//!   run opens in Perfetto and round-trips through
//!   [`crate::trace::io::load_dir`] like any training trace.
//! - **Metrics** ([`metrics::MetricsRegistry`]) — typed counters, gauges
//!   and fixed-bucket latency histograms behind plain atomics. Metrics
//!   are **always on**: they replace the serve daemon's previous ad-hoc
//!   `AtomicU64` fields, so `/statsz` and `/metricsz` are two renderings
//!   of one registry rather than two sets of counters that can drift.
//!
//! Naming conventions (see `docs/OBSERVABILITY.md`): span names are
//! dotted paths rooted at the subsystem (`replay.exact`,
//! `search.candidate`, `serve.request`, `campaign.cell`); metric families
//! are Prometheus-style `dpro_<noun>_<unit-or-total>`
//! (`dpro_replay_heap_pops_total`, `dpro_request_latency_us`).

pub mod export;
pub mod metrics;
pub mod span;

pub use metrics::{Counter, Gauge, Histogram, HistogramSnapshot, MetricsRegistry};
pub use span::{
    current_ctx, dropped_spans, enabled, flush_thread, inherit, set_enabled, span, span_interned,
    take_spans, CtxGuard, SpanCtx, SpanGuard, SpanKind, SpanRec,
};

use std::sync::OnceLock;
use std::time::Instant;

/// Microseconds since the process-wide telemetry epoch (the first call to
/// this function). Monotonic — backed by [`Instant`], never wall-clock —
/// so span timestamps order correctly across threads.
pub fn now_us() -> f64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64() * 1e6
}

/// The process-global metrics registry. Hot-loop call sites should clone
/// a metric handle once (they are `Arc`-backed) instead of re-resolving
/// the name per event; the serve daemon deliberately does **not** use
/// this instance — each [`crate::serve::ServeOpts`] start gets its own
/// registry so concurrent in-process daemons (the test harness) don't
/// share counters.
pub fn global() -> &'static MetricsRegistry {
    static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

/// Process-global counter handles for the replay/search hot loops:
/// resolved once through a `OnceLock`, then one relaxed atomic add per
/// use. Kept here (not at the call sites) so the family names stay in
/// one auditable place.
pub mod hot {
    use super::{global, Counter};
    use std::sync::OnceLock;

    macro_rules! hot_counter {
        ($(#[$doc:meta])* $fn_name:ident, $family:expr) => {
            $(#[$doc])*
            pub fn $fn_name() -> &'static Counter {
                static C: OnceLock<Counter> = OnceLock::new();
                C.get_or_init(|| global().counter($family))
            }
        };
    }

    hot_counter!(
        /// Heap pops across all exact replays (`replay.exact`).
        replay_heap_pops,
        "dpro_replay_heap_pops_total"
    );
    hot_counter!(
        /// Full exact replays executed.
        replay_runs,
        "dpro_replay_runs_total"
    );
    hot_counter!(
        /// Incremental replays executed.
        replay_incremental_runs,
        "dpro_replay_incremental_runs_total"
    );
    hot_counter!(
        /// Nodes recomputed by incremental replays (cone sizes summed).
        replay_cone_nodes,
        "dpro_replay_cone_nodes_total"
    );
    hot_counter!(
        /// Tiered-replay machine demotions to the exact engine.
        tiered_demotions,
        "dpro_tiered_demotions_total"
    );
    hot_counter!(
        /// Optimizer candidates accepted (committed).
        search_accepts,
        "dpro_search_accepts_total"
    );
    hot_counter!(
        /// Optimizer candidates rejected (worse than current).
        search_rejects,
        "dpro_search_rejects_total"
    );
    hot_counter!(
        /// Optimizer candidate transactions rolled back (rejected or
        /// not applicable in the current state).
        search_rollbacks,
        "dpro_search_rollbacks_total"
    );
}
