//! Typed metrics — counters, gauges, fixed-bucket latency histograms —
//! in a registry that renders deterministic Prometheus text exposition.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are `Arc`-backed
//! clones of the registered metric: resolve once, then update with one
//! relaxed atomic op per event, no lock. The registry lock is taken only
//! at registration and render time.
//!
//! Rendering is deterministic for fixed inputs: series are stored in a
//! `BTreeMap` keyed by (family, labels), so `/metricsz` output is
//! byte-stable — pinned by `rust/tests/obs.rs`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

/// Histogram bucket upper bounds in µs: a 1–2.5–5 decade ladder from
/// 1 µs to 10 s, plus the implicit `+Inf` bucket. Chosen so both a
/// sub-ms what-if and a multi-second optimize land mid-ladder.
pub const LATENCY_BOUNDS_US: [f64; 22] = [
    1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1_000.0, 2_500.0, 5_000.0, 10_000.0,
    25_000.0, 50_000.0, 100_000.0, 250_000.0, 500_000.0, 1_000_000.0, 2_500_000.0, 5_000_000.0,
    10_000_000.0,
];

/// Number of histogram buckets including `+Inf`.
pub const N_BUCKETS: usize = LATENCY_BOUNDS_US.len() + 1;

/// Monotonically increasing event count.
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// A detached counter (not in any registry) — the default wired into
    /// components built outside a daemon, e.g. `Session::build` in unit
    /// tests.
    pub fn new() -> Counter {
        Counter::default()
    }

    /// Add 1.
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Relaxed);
    }

    /// Add `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Last-write-wins instantaneous value.
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// A detached gauge (not in any registry).
    pub fn new() -> Gauge {
        Gauge::default()
    }

    /// Set the value.
    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Relaxed);
    }

    /// Track a high-water mark: keep the larger of the current and `v`.
    pub fn set_max(&self, v: u64) {
        self.0.fetch_max(v, Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

struct HistInner {
    /// Per-bucket (non-cumulative) counts; index `LATENCY_BOUNDS_US.len()`
    /// is `+Inf`.
    buckets: [AtomicU64; N_BUCKETS],
    /// Sum of observed values, rounded to whole µs.
    sum_us: AtomicU64,
    count: AtomicU64,
}

/// Fixed-bucket latency histogram over [`LATENCY_BOUNDS_US`].
#[derive(Clone)]
pub struct Histogram(Arc<HistInner>);

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram(Arc::new(HistInner {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }))
    }
}

impl Histogram {
    /// A detached histogram (not in any registry).
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one observation in µs. Non-finite and negative values
    /// count as 0 (first bucket) rather than poisoning the sum.
    pub fn observe_us(&self, us: f64) {
        let v = if us.is_finite() && us > 0.0 { us } else { 0.0 };
        let idx = LATENCY_BOUNDS_US
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(LATENCY_BOUNDS_US.len());
        self.0.buckets[idx].fetch_add(1, Relaxed);
        self.0.sum_us.fetch_add(v.round() as u64, Relaxed);
        self.0.count.fetch_add(1, Relaxed);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.0.count.load(Relaxed)
    }

    /// Sum of observations in whole µs.
    pub fn sum_us(&self) -> u64 {
        self.0.sum_us.load(Relaxed)
    }

    /// Consistent-enough point-in-time copy (buckets are read one by one
    /// without a global lock; concurrent observes may straddle the read,
    /// which percentile math tolerates).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.0.buckets[i].load(Relaxed)),
            sum_us: self.sum_us(),
            count: self.count(),
        }
    }
}

/// Point-in-time histogram state, with percentile estimation.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    /// Per-bucket (non-cumulative) counts, `+Inf` last.
    pub buckets: [u64; N_BUCKETS],
    /// Sum of observations in whole µs.
    pub sum_us: u64,
    /// Total observations.
    pub count: u64,
}

impl HistogramSnapshot {
    /// Estimate the `q`-quantile (`0.0..=1.0`) in µs by linear
    /// interpolation within the bucket containing the target rank. The
    /// `+Inf` bucket extrapolates to 2× the last finite bound. Returns
    /// 0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        let total: u64 = self.buckets.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let before = seen;
            seen += c;
            if seen >= target {
                let lo = if i == 0 { 0.0 } else { LATENCY_BOUNDS_US[i - 1] };
                let hi = LATENCY_BOUNDS_US
                    .get(i)
                    .copied()
                    .unwrap_or(LATENCY_BOUNDS_US[LATENCY_BOUNDS_US.len() - 1] * 2.0);
                let frac = (target - before) as f64 / c as f64;
                return lo + (hi - lo) * frac;
            }
        }
        0.0
    }

    /// p50 in µs.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// p95 in µs.
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    /// p99 in µs.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

#[derive(Clone, PartialEq, Eq, PartialOrd, Ord)]
struct Key {
    family: String,
    labels: Vec<(String, String)>,
}

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

/// A named collection of metrics with get-or-create registration and
/// Prometheus text rendering.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<BTreeMap<Key, Metric>>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Get or create the unlabeled counter `family`.
    pub fn counter(&self, family: &str) -> Counter {
        self.counter_with(family, &[])
    }

    /// Get or create a counter with label pairs (sorted internally, so
    /// label order at the call site doesn't create duplicate series).
    pub fn counter_with(&self, family: &str, labels: &[(&str, &str)]) -> Counter {
        let key = Self::key(family, labels);
        let mut m = self.lock();
        match m.entry(key).or_insert_with(|| Metric::Counter(Counter::new())) {
            Metric::Counter(c) => c.clone(),
            // family already registered as another type: hand back a
            // detached handle instead of panicking mid-request
            _ => Counter::new(),
        }
    }

    /// Get or create the unlabeled gauge `family`.
    pub fn gauge(&self, family: &str) -> Gauge {
        let key = Self::key(family, &[]);
        let mut m = self.lock();
        match m.entry(key).or_insert_with(|| Metric::Gauge(Gauge::new())) {
            Metric::Gauge(g) => g.clone(),
            _ => Gauge::new(),
        }
    }

    /// Get or create the unlabeled histogram `family`.
    pub fn histogram(&self, family: &str) -> Histogram {
        self.histogram_with(family, &[])
    }

    /// Get or create a histogram with label pairs.
    pub fn histogram_with(&self, family: &str, labels: &[(&str, &str)]) -> Histogram {
        let key = Self::key(family, labels);
        let mut m = self.lock();
        match m.entry(key).or_insert_with(|| Metric::Histogram(Histogram::new())) {
            Metric::Histogram(h) => h.clone(),
            _ => Histogram::new(),
        }
    }

    fn key(family: &str, labels: &[(&str, &str)]) -> Key {
        let mut labels: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        labels.sort();
        Key { family: family.to_string(), labels }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BTreeMap<Key, Metric>> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Render the whole registry as Prometheus text exposition
    /// (`text/plain; version=0.0.4`): one `# TYPE` line per family, then
    /// its series in sorted label order; histograms expand to cumulative
    /// `_bucket{le=...}`, `_sum` and `_count` series. Deterministic for
    /// fixed metric values.
    pub fn render_prometheus(&self) -> String {
        let m = self.lock();
        let mut out = String::new();
        let mut last_family = String::new();
        for (key, metric) in m.iter() {
            if key.family != last_family {
                let ty = match metric {
                    Metric::Counter(_) => "counter",
                    Metric::Gauge(_) => "gauge",
                    Metric::Histogram(_) => "histogram",
                };
                out.push_str(&format!("# TYPE {} {ty}\n", key.family));
                last_family = key.family.clone();
            }
            match metric {
                Metric::Counter(c) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        key.family,
                        render_labels(&key.labels, None),
                        c.get()
                    ));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        key.family,
                        render_labels(&key.labels, None),
                        g.get()
                    ));
                }
                Metric::Histogram(h) => {
                    let snap = h.snapshot();
                    let mut cum = 0u64;
                    for (i, &c) in snap.buckets.iter().enumerate() {
                        cum += c;
                        let le = LATENCY_BOUNDS_US
                            .get(i)
                            .map(|b| fmt_bound(*b))
                            .unwrap_or_else(|| "+Inf".to_string());
                        out.push_str(&format!(
                            "{}_bucket{} {cum}\n",
                            key.family,
                            render_labels(&key.labels, Some(&le)),
                        ));
                    }
                    out.push_str(&format!(
                        "{}_sum{} {}\n",
                        key.family,
                        render_labels(&key.labels, None),
                        snap.sum_us
                    ));
                    out.push_str(&format!(
                        "{}_count{} {}\n",
                        key.family,
                        render_labels(&key.labels, None),
                        snap.count
                    ));
                }
            }
        }
        out
    }
}

/// `1`, `2.5`, `10000` — integral bounds without a trailing `.0`.
fn fmt_bound(b: f64) -> String {
    if b.fract() == 0.0 {
        format!("{}", b as u64)
    } else {
        format!("{b}")
    }
}

fn render_labels(labels: &[(String, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", v.replace('\\', "\\\\").replace('"', "\\\"")))
        .collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_register_once() {
        let r = MetricsRegistry::new();
        let a = r.counter("dpro_test_total");
        let b = r.counter("dpro_test_total");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3, "both handles hit one atomic");
        let g = r.gauge("dpro_test_gauge");
        g.set(7);
        g.set_max(3);
        assert_eq!(r.gauge("dpro_test_gauge").get(), 7);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::new();
        h.observe_us(0.5); // le=1
        h.observe_us(1.0); // le=1 (inclusive upper bound)
        h.observe_us(1.1); // le=2.5
        h.observe_us(1e9); // +Inf
        h.observe_us(f64::NAN); // counts as 0 → le=1
        h.observe_us(-3.0); // counts as 0 → le=1
        let s = h.snapshot();
        assert_eq!(s.count, 6);
        assert_eq!(s.buckets[0], 4);
        assert_eq!(s.buckets[1], 1);
        assert_eq!(s.buckets[N_BUCKETS - 1], 1);
        assert!(s.p50() <= 1.0 && s.p50() > 0.0);
        assert!(s.p99() > LATENCY_BOUNDS_US[LATENCY_BOUNDS_US.len() - 1]);
        assert_eq!(HistogramSnapshot { buckets: [0; N_BUCKETS], sum_us: 0, count: 0 }.p95(), 0.0);
    }

    #[test]
    fn prometheus_rendering_is_deterministic_and_typed() {
        let r = MetricsRegistry::new();
        r.counter("dpro_b_total").add(5);
        r.counter_with("dpro_req_total", &[("route", "/statsz")]).inc();
        r.counter_with("dpro_req_total", &[("route", "/healthz")]).add(2);
        r.gauge("dpro_a_gauge").set(9);
        r.histogram("dpro_lat_us").observe_us(3.0);
        let once = r.render_prometheus();
        assert_eq!(once, r.render_prometheus(), "render must be stable");
        assert!(once.contains("# TYPE dpro_a_gauge gauge\ndpro_a_gauge 9\n"));
        assert!(once.contains("# TYPE dpro_b_total counter\ndpro_b_total 5\n"));
        // label-sorted series under one TYPE line
        let req = once.find("# TYPE dpro_req_total counter").expect("family present");
        let healthz = once.find("dpro_req_total{route=\"/healthz\"} 2").expect("healthz series");
        let statsz = once.find("dpro_req_total{route=\"/statsz\"} 1").expect("statsz series");
        assert!(req < healthz && healthz < statsz);
        assert!(once.contains("dpro_lat_us_bucket{le=\"2.5\"} 1"));
        assert!(once.contains("dpro_lat_us_bucket{le=\"+Inf\"} 1"));
        assert!(once.contains("dpro_lat_us_sum 3\n"));
        assert!(once.contains("dpro_lat_us_count 1\n"));
    }
}
