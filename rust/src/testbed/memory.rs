//! GPU memory accounting over an executed (or replayed) schedule.
//!
//! Same walk serves both sides of paper Table 3: the testbed computes the
//! "real" peak (with allocator fragmentation + runtime overheads the
//! replayer cannot see), the replayer computes the estimate from its own
//! simulated schedule via [`peak_from_schedule`].

use crate::config::JobSpec;
use crate::graph::dfg::{Node, NodeId, OpKind};
use crate::graph::{GlobalDfg, MutableGraph};

/// Fixed per-process GPU overhead a profiler-side estimate does not model:
/// CUDA context, cuDNN handles, framework arenas (bytes).
pub const RUNTIME_OVERHEAD: f64 = 0.72e9;

/// Allocator fragmentation + caching-allocator slack on the real device.
pub const FRAGMENTATION: f64 = 1.045;

/// Peak memory of worker 0 given the schedule's end times, in bytes.
///
/// Accounting: persistent weights + optimizer state; activations live from
/// their forward op's completion to their mirrored backward's completion;
/// gradients live from their producing backward to the group's update.
pub fn peak_from_schedule(spec: &JobSpec, g: &GlobalDfg, end: &[f64]) -> f64 {
    peak_core(
        spec,
        end,
        g.dfg.ids().map(|i| (i, g.dfg.node(i))),
        &|fg| g.comp_node.get(&(0u16, fg)).copied(),
        &|gi| g.update_node.get(&(0u16, gi)).copied(),
    )
}

/// Same accounting walk over a live [`MutableGraph`] — the optimizer's
/// accept/reject loop judges memory strategies on the incrementally-edited
/// graph with zero `build_global*` calls.
pub fn peak_from_mutable(mg: &MutableGraph, end: &[f64]) -> f64 {
    let dfg = mg.dfg();
    let alive = mg.alive();
    peak_core(
        mg.spec(),
        end,
        dfg.ids().filter(|&i| alive[i as usize]).map(|i| (i, dfg.node(i))),
        &|fg| mg.comp_node(0, fg),
        &|gi| Some(mg.update_node(0, gi)),
    )
}

fn peak_core<'a>(
    spec: &JobSpec,
    end: &[f64],
    nodes: impl Iterator<Item = (NodeId, &'a Node)>,
    comp_of: &dyn Fn(u32) -> Option<NodeId>,
    update_of: &dyn Fn(usize) -> Option<NodeId>,
) -> f64 {
    let model = &spec.model;
    // (time, delta) events
    let mut deltas: Vec<(f64, f64)> = Vec::new();

    for (i, node) in nodes {
        if node.owner != 0 || node.proc != 0 {
            continue;
        }
        let Some(fg) = node.template_id else { continue };
        // node covers one fusion group; walk its member template ops
        for &m in &spec.fusion.groups[fg as usize] {
            let op = &model.ops[m as usize];
            match node.kind {
                OpKind::Forward if op.activation_bytes > 0.0 => {
                    deltas.push((end[i as usize], op.activation_bytes));
                    if let Some(mi) = op.mirror {
                        let bw_group = spec.fusion.group_of[mi as usize];
                        if let Some(bw) = comp_of(bw_group) {
                            deltas.push((end[bw as usize], -op.activation_bytes));
                        }
                    }
                }
                OpKind::Backward if !op.produces.is_empty() => {
                    let grad_bytes: f64 =
                        op.produces.iter().map(|&t| model.tensors[t as usize].bytes).sum();
                    deltas.push((end[i as usize], grad_bytes));
                    // freed when the owning comm group's update completes
                    for (gi, group) in spec.plan.groups.iter().enumerate() {
                        let b: f64 = group
                            .tensors
                            .iter()
                            .filter(|t| op.produces.contains(t))
                            .map(|&t| model.tensors[t as usize].bytes)
                            .sum();
                        if b > 0.0 {
                            if let Some(upd) = update_of(gi) {
                                deltas.push((end[upd as usize], -b));
                            }
                        }
                    }
                }
                _ => {}
            }
        }
    }

    // weights + momentum persist the whole iteration
    let persistent = 2.0 * model.param_bytes();
    deltas.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut cur = persistent;
    let mut peak = persistent;
    for (_, d) in deltas {
        cur += d;
        peak = peak.max(cur);
    }
    peak
}

/// Ground-truth peak on the real device: schedule walk plus the overheads
/// only the hardware sees.
pub fn ground_truth_peak(spec: &JobSpec, g: &GlobalDfg, _start: &[f64], end: &[f64]) -> f64 {
    peak_from_schedule(spec, g, end) * FRAGMENTATION + RUNTIME_OVERHEAD
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobSpec, Transport};
    use crate::graph::{build_global, AnalyticCost};

    #[test]
    fn peak_exceeds_persistent_state() {
        let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        // trivial schedule: everything ends at its topological index
        let order = g.dfg.topo_order();
        let mut end = vec![0.0; g.dfg.len()];
        for (t, &id) in order.iter().enumerate() {
            end[id as usize] = t as f64;
        }
        let peak = peak_from_schedule(&spec, &g, &end);
        assert!(peak > 2.0 * spec.model.param_bytes());
        // activations dominate for ResNet50 at bs 32 — peak should be GBs
        assert!(peak > 2.0e9, "peak={peak}");
        assert!(peak < 40.0e9, "peak={peak}");
    }

    #[test]
    fn ground_truth_adds_overheads() {
        let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
        let g = build_global(&spec, &AnalyticCost::new(&spec));
        let end = vec![1.0; g.dfg.len()];
        let est = peak_from_schedule(&spec, &g, &end);
        let real = ground_truth_peak(&spec, &g, &end, &end);
        assert!(real > est);
        assert!(real - est < est * 0.10 + RUNTIME_OVERHEAD + 1.0);
    }
}
