//! Ground-truth testbed: a discrete-event simulator standing in for the
//! paper's 16-server V100 cluster (see DESIGN.md §Substitutions).
//!
//! It executes a [`JobSpec`]'s global DFG with *stochastic, protocol-aware*
//! semantics — per-kernel jitter, FIFO engines, NIC serialization, TCP
//! incast spikes, Horovod negotiation cycles, stragglers — and emits the
//! *measured* trace a real profiler would see: timestamps shifted by
//! per-machine clock drift, RECV durations inflated by the launch-time
//! error (§2.2). dPRO's replayer/optimizer only ever see this trace, never
//! the simulator's internals — the same information asymmetry as on real
//! hardware.

pub mod memory;

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::{JobSpec, Transport};
use crate::graph::{build_global, AnalyticCost, GlobalDfg};
use crate::graph::dfg::{DeviceKey, NodeId, OpKind, COORD_PROC};
use crate::trace::{GTrace, TraceEvent};
use crate::util::rng::Pcg;
use crate::util::Us;

/// TCP retransmit/incast stall model: probability of a stall per message.
pub const TCP_SPIKE_P: f64 = 0.015;
/// Lower bound of the additive stall delay (us).
pub const TCP_SPIKE_LO: f64 = 100.0;
/// Upper bound of the additive stall delay (us).
pub const TCP_SPIKE_HI: f64 = 900.0;

/// Injected performance faults (used by the diagnosis example and tests).
#[derive(Clone, Debug)]
pub enum Straggler {
    /// GPU `worker` runs all computation `factor`× slower.
    SlowGpu { worker: usize, factor: f64 },
    /// The NIC of `machine` transfers `factor`× slower.
    SlowLink { machine: usize, factor: f64 },
}

/// Knobs of one testbed run.
#[derive(Clone, Debug)]
pub struct TestbedOpts {
    /// Measured iterations (paper averages over 10 after warm-up).
    pub iterations: usize,
    /// Run seed, XORed with the cluster seed.
    pub seed: u64,
    /// Injected performance faults.
    pub stragglers: Vec<Straggler>,
}

impl Default for TestbedOpts {
    fn default() -> Self {
        TestbedOpts { iterations: 10, seed: 1, stragglers: Vec::new() }
    }
}

/// Ground-truth outcome of running a job on the testbed.
#[derive(Clone, Debug)]
pub struct TestbedResult {
    /// True per-iteration times (us).
    pub iter_times: Vec<Us>,
    /// The measured trace (drifted clocks, RECV launch error).
    pub trace: GTrace,
    /// True FW busy time per iteration on worker 0 (us).
    pub fw_time: Us,
    /// True BW busy time per iteration on worker 0 (us).
    pub bw_time: Us,
    /// Ground-truth peak memory per worker (bytes).
    pub peak_memory: f64,
}

impl TestbedResult {
    /// Mean measured iteration time (us).
    pub fn avg_iter(&self) -> Us {
        crate::util::stats::mean(&self.iter_times)
    }
}

/// Run a job on the testbed. Deterministic for a given (spec, opts) pair.
pub fn run(spec: &JobSpec, opts: &TestbedOpts) -> TestbedResult {
    let g = build_global(spec, &AnalyticCost::new(spec));
    run_on(spec, &g, opts)
}

/// Run on a pre-built global DFG (lets callers reuse the skeleton).
pub fn run_on(spec: &JobSpec, g: &GlobalDfg, opts: &TestbedOpts) -> TestbedResult {
    let mut rng = Pcg::new(spec.cluster.seed ^ opts.seed, 7);
    let n = g.dfg.len();

    // --- intern devices ---
    let mut dev_ids: std::collections::HashMap<DeviceKey, usize> = std::collections::HashMap::new();
    let mut node_dev: Vec<usize> = Vec::with_capacity(n);
    for node in &g.dfg.nodes {
        let next = dev_ids.len();
        let id = *dev_ids.entry(node.device).or_insert(next);
        node_dev.push(id);
    }
    let n_dev = dev_ids.len();

    // --- per-machine clock offsets (same machine ⇒ same clock) ---
    let n_machines = spec.cluster.n_machines();
    let drift_std = spec.cluster.clock.drift_std_us;
    let clock_offset: Vec<Us> = (0..n_machines)
        .map(|m| if m == 0 || n_machines == 1 { 0.0 } else { rng.gauss(0.0, drift_std) })
        .collect();
    let machine_of_proc = |proc: u16| -> u16 {
        if proc == COORD_PROC {
            0
        } else if (proc as usize) < spec.cluster.n_workers {
            spec.cluster.machine_of(proc as usize) as u16
        } else {
            // PS server s is colocated on machine s % n_machines
            ((proc as usize - spec.cluster.n_workers) % n_machines) as u16
        }
    };

    // straggler lookups
    let mut gpu_slow = vec![1.0f64; spec.cluster.n_workers];
    let mut link_slow = vec![1.0f64; n_machines];
    for s in &opts.stragglers {
        match *s {
            Straggler::SlowGpu { worker, factor } => gpu_slow[worker] = factor,
            Straggler::SlowLink { machine, factor } => link_slow[machine] = factor,
        }
    }

    let net_cv = match spec.cluster.network.transport {
        Transport::Tcp => 0.10,
        Transport::Rdma => 0.03,
    };
    let comp_cv = spec.cluster.gpu.duration_cv;
    let cycle = spec.scheme.cycle_time_us();

    // --- event-driven execution, one iteration at a time ---
    let mut events: Vec<TraceEvent> = Vec::with_capacity(n * opts.iterations);
    let mut iter_times: Vec<Us> = Vec::with_capacity(opts.iterations);
    let mut fw_time = 0.0;
    let mut bw_time = 0.0;
    let mut peak_memory: f64 = 0.0;
    let mut clock_base: Us = 0.0;

    // reusable buffers
    let base_indeg: Vec<u32> = g.dfg.ids().map(|i| g.dfg.preds(i).len() as u32).collect();
    let mut start = vec![0.0f64; n];
    let mut prev_dev_end = vec![0.0f64; n];
    let mut end = vec![0.0f64; n];
    let mut launch = vec![0.0f64; n];

    for it in 0..opts.iterations as u32 {
        let mut indeg = base_indeg.clone();
        let mut ready_at = vec![0.0f64; n];
        let mut dev_busy = vec![false; n_dev];
        let mut dev_queue: Vec<std::collections::VecDeque<NodeId>> =
            vec![std::collections::VecDeque::new(); n_dev];
        let mut dev_last_end = vec![0.0f64; n_dev];
        let mut heap: BinaryHeap<Reverse<(u64, NodeId)>> = BinaryHeap::new();
        let key = |t: f64| (t.max(0.0) * 1024.0) as u64; // fixed-point heap key

        let mut iter_end: Us = 0.0;
        let mut finished = 0usize;

        // sample this iteration's durations
        let mut dur = vec![0.0f64; n];
        for (i, node) in g.dfg.nodes.iter().enumerate() {
            let base = node.duration;
            dur[i] = match node.kind {
                OpKind::Forward | OpKind::Backward | OpKind::Update => {
                    base * rng.jitter(comp_cv) * gpu_slow[node.owner as usize]
                }
                OpKind::Negotiate => {
                    // waiting for the next coordinator cycle: uniform in
                    // (0.1, 1.0) of a cycle, mean ≈ the analytic 0.55·cycle
                    if cycle > 0.0 { rng.uniform(0.1, 1.0) * cycle } else { 0.0 }
                }
                OpKind::Send | OpKind::Recv => {
                    let m = machine_of_proc(node.proc) as usize;
                    let mut d = base * rng.jitter(net_cv) * link_slow[m];
                    // TCP: occasional incast/retransmit stall — additive
                    // (a timeout costs fixed time, not a multiple of size)
                    if spec.cluster.network.transport == Transport::Tcp && rng.f64() < TCP_SPIKE_P {
                        d += rng.uniform(TCP_SPIKE_LO, TCP_SPIKE_HI);
                    }
                    d
                }
                OpKind::Aggregate => base * rng.jitter(comp_cv),
                OpKind::In | OpKind::Out => 0.0,
            };
        }

        // seed sources
        let mut stack: Vec<NodeId> = Vec::new();
        for i in g.dfg.ids() {
            if indeg[i as usize] == 0 {
                stack.push(i);
            }
        }
        // helper to finish zero-device (virtual) nodes immediately
        macro_rules! enqueue {
            ($node:expr, $t:expr) => {{
                let node = $node;
                let t: f64 = $t;
                let d = node_dev[node as usize];
                if g.dfg.node(node).device == DeviceKey::Null {
                    if dur[node as usize] > 0.0 {
                        // timed but non-queuing (e.g. negotiation delay)
                        start[node as usize] = t;
                        end[node as usize] = t + dur[node as usize];
                        heap.push(Reverse((key(end[node as usize]), node)));
                    } else {
                        // virtual: completes instantly
                        start[node as usize] = t;
                        end[node as usize] = t;
                        launch[node as usize] = t;
                        finished += 1;
                        iter_end = iter_end.max(t);
                        for &s in g.dfg.succs(node) {
                            indeg[s as usize] -= 1;
                            ready_at[s as usize] = ready_at[s as usize].max(t);
                            if indeg[s as usize] == 0 {
                                stack.push(s);
                            }
                        }
                    }
                } else {
                    dev_queue[d].push_back(node);
                    if !dev_busy[d] {
                        let nd = dev_queue[d].pop_front().unwrap();
                        let st = ready_at[nd as usize].max(t).max(dev_last_end[d]);
                        prev_dev_end[nd as usize] = dev_last_end[d];
                        start[nd as usize] = st;
                        end[nd as usize] = st + dur[nd as usize];
                        dev_busy[d] = true;
                        heap.push(Reverse((key(end[nd as usize]), nd)));
                    }
                }
            }};
        }

        while finished < n {
            // drain ready stack (virtual nodes may cascade)
            while let Some(node) = stack.pop() {
                let t = ready_at[node as usize];
                enqueue!(node, t);
            }
            let Some(Reverse((_, node))) = heap.pop() else {
                break;
            };
            let t = end[node as usize];
            finished += 1;
            iter_end = iter_end.max(t);
            let d = node_dev[node as usize];
            dev_busy[d] = false;
            dev_last_end[d] = t;
            // successors
            for &s in g.dfg.succs(node) {
                indeg[s as usize] -= 1;
                ready_at[s as usize] = ready_at[s as usize].max(t);
                if indeg[s as usize] == 0 {
                    stack.push(s);
                }
            }
            // start next queued op on this device
            if let Some(nd) = dev_queue[d].pop_front() {
                let st = ready_at[nd as usize].max(t);
                prev_dev_end[nd as usize] = dev_last_end[d];
                start[nd as usize] = st;
                end[nd as usize] = st + dur[nd as usize];
                dev_busy[d] = true;
                heap.push(Reverse((key(end[nd as usize]), nd)));
            }
        }
        assert_eq!(finished, n, "testbed deadlock: {} of {} ops ran", finished, n);

        // RECV launch time: when the op was posted — after its *local*
        // (same-proc) predecessors and the previous op on its device, but
        // NOT the remote SEND. The profiler reports this as the start.
        for i in g.dfg.ids() {
            let node = g.dfg.node(i);
            if node.kind != OpKind::Recv {
                launch[i as usize] = start[i as usize];
                continue;
            }
            let mut l: f64 = 0.0;
            for &p in g.dfg.preds(i) {
                if g.dfg.node(p).proc == node.proc {
                    l = l.max(end[p as usize]);
                }
            }
            launch[i as usize] = l.max(prev_dev_end[i as usize]).min(start[i as usize]);
        }

        // emit measured trace
        for i in g.dfg.ids() {
            let node = g.dfg.node(i);
            if node.kind.is_virtual() {
                continue;
            }
            let m = machine_of_proc(node.proc);
            let off = clock_offset[m as usize];
            let (ts, dur_meas) = if node.kind == OpKind::Recv && spec.cluster.clock.recv_launch_error
            {
                (launch[i as usize], end[i as usize] - launch[i as usize])
            } else {
                (start[i as usize], end[i as usize] - start[i as usize])
            };
            events.push(TraceEvent {
                name: node.name.resolve().to_string(),
                kind: node.kind,
                ts: clock_base + ts + off,
                dur: dur_meas,
                proc: node.proc,
                machine: m,
                iter: it,
                txid: node.txid,
            });
        }

        iter_times.push(iter_end);
        clock_base += iter_end + rng.uniform(150.0, 400.0); // inter-iteration gap

        if it == 0 {
            // true FW/BW busy time + ground-truth peak memory (worker 0)
            for i in g.dfg.ids() {
                let node = g.dfg.node(i);
                if node.owner == 0 && node.proc == 0 {
                    match node.kind {
                        OpKind::Forward => fw_time += end[i as usize] - start[i as usize],
                        OpKind::Backward => bw_time += end[i as usize] - start[i as usize],
                        _ => {}
                    }
                }
            }
            peak_memory = memory::ground_truth_peak(spec, g, &start, &end);
        }
    }

    let n_procs = spec.cluster.n_workers + spec.scheme.n_servers();
    TestbedResult {
        iter_times,
        trace: GTrace {
            events,
            n_workers: spec.cluster.n_workers,
            n_procs,
            iterations: opts.iterations,
        },
        fw_time,
        bw_time,
        peak_memory,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobSpec, Transport};

    fn job() -> JobSpec {
        let mut j = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        j.model = crate::models::by_name("resnet50", 32).unwrap();
        j
    }

    #[test]
    fn deterministic_runs() {
        let spec = job();
        let opts = TestbedOpts { iterations: 2, ..Default::default() };
        let a = run(&spec, &opts);
        let b = run(&spec, &opts);
        assert_eq!(a.iter_times, b.iter_times);
        assert_eq!(a.trace.events.len(), b.trace.events.len());
    }

    #[test]
    fn iteration_time_exceeds_compute_time() {
        let spec = job();
        let r = run(&spec, &TestbedOpts { iterations: 3, ..Default::default() });
        let iter = r.avg_iter();
        // iteration > FW+BW (communication adds), but far below serial sum
        assert!(iter > r.fw_time + r.bw_time, "iter={iter} fw+bw={}", r.fw_time + r.bw_time);
        assert!(iter < (r.fw_time + r.bw_time) * 4.0, "iter={iter}");
    }

    #[test]
    fn tcp_slower_than_rdma_when_comm_is_exposed() {
        // With one fully-fused tensor group, synchronization of VGG16's
        // 550 MB of gradients starts only after backprop finishes, so the
        // wire time is exposed and the transport matters. (With per-tensor
        // granularity both transports hide behind compute — correctly.)
        let mut tcp = JobSpec::standard("vgg16", "horovod", Transport::Tcp);
        tcp.plan = crate::config::CommPlan {
            groups: vec![crate::config::TensorGroup {
                tensors: (0..tcp.model.tensors.len() as u32).collect(),
                partitions: 1,
            }],
        };
        let mut rdma = tcp.clone();
        rdma.cluster.network = crate::config::NetworkSpec::rdma_100g();
        let t = run(&tcp, &TestbedOpts { iterations: 3, ..Default::default() }).avg_iter();
        let r = run(&rdma, &TestbedOpts { iterations: 3, ..Default::default() }).avg_iter();
        assert!(t > r * 1.15, "tcp={t} rdma={r}");
    }

    #[test]
    fn straggler_slows_training() {
        let spec = job();
        let base = run(&spec, &TestbedOpts { iterations: 2, ..Default::default() }).avg_iter();
        let slow = run(
            &spec,
            &TestbedOpts {
                iterations: 2,
                stragglers: vec![Straggler::SlowGpu { worker: 3, factor: 1.8 }],
                ..Default::default()
            },
        )
        .avg_iter();
        assert!(slow > base * 1.2, "base={base} slow={slow}");
    }

    #[test]
    fn recv_durations_inflated_by_launch_error() {
        let spec = job();
        let r = run(&spec, &TestbedOpts { iterations: 2, ..Default::default() });
        // measured RECV durations should on average exceed the analytic
        // wire time because they include sender wait
        let recvs: Vec<f64> = r
            .trace
            .events
            .iter()
            .filter(|e| e.kind == crate::graph::OpKind::Recv && e.name.contains("RECV"))
            .map(|e| e.dur)
            .collect();
        assert!(!recvs.is_empty());
    }

    #[test]
    fn clock_drift_disabled_on_single_machine() {
        let mut spec = job();
        spec.cluster.n_workers = 8;
        spec.cluster.gpus_per_machine = 8;
        spec.plan = crate::config::CommPlan::per_tensor(&spec.model);
        let r = run(&spec, &TestbedOpts { iterations: 1, ..Default::default() });
        // all events from machine 0
        assert!(r.trace.events.iter().all(|e| e.machine == 0));
    }
}
