//! Incremental replay engine over a [`MutableGraph`]: after a batch of
//! in-place plan edits, recompute only the downstream cone whose times can
//! actually change, reusing the previous schedule everywhere else
//! (timestamp-dominance pruning — the same idea [`super::partial`] applies
//! to a single tensor's chain, here for the full engine).
//!
//! ## Semantics: execution-graph replay
//!
//! The engine materializes the paper's *execution graph* (§4.3): every
//! device serializes its ops in a **canonical static order** — ascending
//! dependency-only ASAP time, ties broken by the graph's canonical rank
//! ([`MutableGraph::canon_ranks`]) — which adds one implicit order edge
//! between consecutive ops of a device. Start times are then the longest
//! path over dependency + order edges:
//!
//! `start(v) = max( max_{p∈preds(v)} end(p),  end(device_prev(v)) )`
//!
//! Because every quantity is a pure max/plus reduction over its inputs and
//! the tie-break rank is derived from the *plan*, not from node numbering,
//! a replay of an incrementally-edited graph is **bit-identical** to a
//! replay of a freshly built graph of the same spec — the equivalence
//! guarantee the `incremental` test suite sweeps. (The event-driven
//! [`super::Replayer`] keeps its FIFO semantics for the trace-driven
//! profiler path; the search loop uses this engine.)
//!
//! ## Incrementality
//!
//! Per [`ChangeLog`] the engine repairs, in order:
//! 1. device membership (tombstoned nodes leave; spliced nodes and nodes
//!    revived by a transaction rollback enter);
//! 2. dependency-only ASAP times (one pass, with change detection);
//! 3. the static order of only the devices whose member set or member
//!    ASAP changed (re-sort + relink);
//! 4. final times over the affected cone only: a node is recomputed iff
//!    its duration/predecessors changed, its device predecessor changed,
//!    or a recomputed input's `(start, end)` actually moved — unaffected
//!    prefixes keep their previous schedule verbatim.
//!
//! All state (including the [`ReplayResult`]) is engine-owned and reused
//! across replays; a steady-state round allocates nothing.

use std::collections::HashMap;

use crate::graph::dfg::{DeviceKey, NodeId};
use crate::graph::mutable::{ChangeLog, MutableGraph};
use crate::replay::ReplayResult;

const NONE: NodeId = NodeId::MAX;
const NULL_DEV: u32 = 0;

/// Reusable incremental engine. See module docs.
pub struct IncrementalReplayer {
    n: usize,
    // ---- device interning & static order ----
    dev_ids: HashMap<DeviceKey, u32>,
    n_dev: usize,
    node_dev: Vec<u32>,
    dev_list: Vec<Vec<NodeId>>,
    dev_pending: Vec<Vec<NodeId>>,
    dev_dirty: Vec<bool>,
    dev_prev: Vec<NodeId>,
    dev_next: Vec<NodeId>,
    // ---- cached per-node state ----
    asap: Vec<f64>,
    result: ReplayResult,
    // ---- scratch ----
    indeg: Vec<u32>,
    order: Vec<NodeId>,
    stack: Vec<NodeId>,
    /// affected-cone epoch marks (node is in this replay's cone iff
    /// `aff[i] == epoch`)
    aff: Vec<u64>,
    epoch: u64,
    // ---- stats ----
    replays: usize,
    last_recomputed: usize,
    ran_once: bool,
}

impl Default for IncrementalReplayer {
    fn default() -> Self {
        Self::new()
    }
}

impl IncrementalReplayer {
    /// Empty engine; feed it graph state via the first `replay` call's
    /// change log (everything starts dirty).
    pub fn new() -> IncrementalReplayer {
        let mut dev_ids = HashMap::new();
        dev_ids.insert(DeviceKey::Null, NULL_DEV);
        IncrementalReplayer {
            n: 0,
            dev_ids,
            n_dev: 1,
            node_dev: Vec::new(),
            dev_list: vec![Vec::new()],
            dev_pending: vec![Vec::new()],
            dev_dirty: vec![false],
            dev_prev: Vec::new(),
            dev_next: Vec::new(),
            asap: Vec::new(),
            result: ReplayResult {
                iteration_time: 0.0,
                start: Vec::new(),
                end: Vec::new(),
                crit_pred: Vec::new(),
                last: 0,
            },
            indeg: Vec::new(),
            order: Vec::new(),
            stack: Vec::new(),
            aff: Vec::new(),
            epoch: 0,
            replays: 0,
            last_recomputed: 0,
            ran_once: false,
        }
    }

    /// The schedule of the last replay.
    pub fn result(&self) -> &ReplayResult {
        &self.result
    }

    /// Total replays performed (cache-hit fast paths included).
    pub fn replays(&self) -> usize {
        self.replays
    }

    /// Nodes whose times were recomputed in the last replay — the cone
    /// size the dominance pruning achieved.
    pub fn last_recomputed(&self) -> usize {
        self.last_recomputed
    }

    fn intern(&mut self, dev: DeviceKey) -> u32 {
        let next = self.dev_ids.len() as u32;
        let id = *self.dev_ids.entry(dev).or_insert(next);
        while self.n_dev <= id as usize {
            self.n_dev += 1;
            self.dev_list.push(Vec::new());
            self.dev_pending.push(Vec::new());
            self.dev_dirty.push(false);
        }
        id
    }

    /// Replay after the edits described by `changes` (obtained from
    /// [`MutableGraph::commit`]). The first call — or a `ChangeLog` whose
    /// `added_from` is 0 — performs a full replay.
    pub fn replay_incremental(
        &mut self,
        mg: &MutableGraph,
        changes: &ChangeLog,
    ) -> &ReplayResult {
        let _span = crate::obs::span("replay.incremental", crate::obs::SpanKind::Work);
        let dfg = mg.dfg();
        let alive = mg.alive();
        let canon = mg.canon_ranks();
        let n = dfg.len();
        self.replays += 1;
        crate::obs::hot::replay_incremental_runs().inc();

        if self.ran_once && changes.is_empty(n) {
            self.last_recomputed = 0;
            return &self.result;
        }
        // the first replay is always a full one, whatever the changelog
        // says (a caller may have committed more than once before ever
        // replaying)
        let added_from = if self.ran_once { changes.added_from as usize } else { 0 };
        self.ran_once = true;
        self.epoch += 1;

        // ---- 1. sync arrays & device membership ----
        if n > self.n {
            self.node_dev.resize(n, NULL_DEV);
            self.asap.resize(n, 0.0);
            self.result.start.resize(n, 0.0);
            self.result.end.resize(n, 0.0);
            self.result.crit_pred.resize(n, None);
            self.dev_prev.resize(n, NONE);
            self.dev_next.resize(n, NONE);
            self.indeg.resize(n, 0);
            self.aff.resize(n, 0);
        }
        self.n = n;
        for k in 0..changes.removed.len() {
            let r = changes.removed[k] as usize;
            let d = self.node_dev[r];
            if d != NULL_DEV {
                self.dev_dirty[d as usize] = true;
                self.node_dev[r] = NULL_DEV;
            }
            // a tombstone keeps its last schedule entry; it is excluded
            // from every pass below because it is not `alive`
        }
        // nodes revived by a transaction rollback re-enter exactly like
        // fresh additions: re-intern the device, queue for the order repair
        for k in 0..changes.revived.len() {
            let i = changes.revived[k] as usize;
            if i >= n || !alive[i] {
                continue;
            }
            let d = self.intern(dfg.node(i as NodeId).device);
            self.node_dev[i] = d;
            if d != NULL_DEV {
                self.dev_pending[d as usize].push(i as NodeId);
                self.dev_dirty[d as usize] = true;
            }
            self.aff[i] = self.epoch;
        }
        for i in added_from..n {
            if !alive[i] {
                continue;
            }
            let d = self.intern(dfg.node(i as NodeId).device);
            self.node_dev[i] = d;
            if d != NULL_DEV {
                self.dev_pending[d as usize].push(i as NodeId);
                self.dev_dirty[d as usize] = true;
            }
            self.aff[i] = self.epoch;
        }
        for k in 0..changes.touched.len() {
            let t = changes.touched[k] as usize;
            if alive[t] {
                self.aff[t] = self.epoch;
            }
        }

        // ---- 2. dependency-only topological order over live nodes ----
        self.order.clear();
        self.stack.clear();
        let mut alive_count = 0usize;
        for i in 0..n {
            self.indeg[i] = dfg.preds(i as NodeId).len() as u32;
            if alive[i] {
                alive_count += 1;
                if self.indeg[i] == 0 {
                    self.stack.push(i as NodeId);
                }
            }
        }
        while let Some(i) = self.stack.pop() {
            self.order.push(i);
            for &s in dfg.succs(i) {
                self.indeg[s as usize] -= 1;
                if self.indeg[s as usize] == 0 {
                    self.stack.push(s);
                }
            }
        }
        assert_eq!(self.order.len(), alive_count, "cycle in live DFG");

        // ---- 3. ASAP pass (dependency-only longest path) ----
        // Recomputed for every live node (pure float max/plus — cheap);
        // devices with any moved member are marked for re-sorting.
        for k in 0..self.order.len() {
            let i = self.order[k];
            let iu = i as usize;
            let mut t = 0.0f64;
            for &p in dfg.preds(i) {
                let e = self.asap[p as usize] + dfg.node(p).duration;
                if e > t {
                    t = e;
                }
            }
            if t != self.asap[iu] {
                self.asap[iu] = t;
                let d = self.node_dev[iu];
                if d != NULL_DEV {
                    self.dev_dirty[d as usize] = true;
                }
            }
        }

        // ---- 4. repair the static order of dirty devices ----
        for d in 1..self.n_dev {
            if !self.dev_dirty[d] {
                continue;
            }
            self.dev_dirty[d] = false;
            let mut list = std::mem::take(&mut self.dev_list[d]);
            list.retain(|&x| self.node_dev[x as usize] == d as u32);
            let mut pending = std::mem::take(&mut self.dev_pending[d]);
            list.append(&mut pending);
            self.dev_pending[d] = pending;
            {
                let asap = &self.asap;
                list.sort_unstable_by(|&x, &y| {
                    asap[x as usize]
                        .total_cmp(&asap[y as usize])
                        .then(canon[x as usize].cmp(&canon[y as usize]))
                });
            }
            // a revived node may already sit in the retained list *and* in
            // pending (it was never removed from the engine's perspective);
            // identical ids sort adjacent (equal keys), so dedup here
            list.dedup();
            let mut prev = NONE;
            for k in 0..list.len() {
                let x = list[k];
                let xu = x as usize;
                if self.dev_prev[xu] != prev {
                    self.dev_prev[xu] = prev;
                    self.aff[xu] = self.epoch;
                }
                if prev != NONE {
                    self.dev_next[prev as usize] = x;
                }
                prev = x;
            }
            if prev != NONE {
                self.dev_next[prev as usize] = NONE;
            }
            self.dev_list[d] = list;
        }

        // ---- 5. topological order over dependency + device-order edges ----
        self.order.clear();
        self.stack.clear();
        for i in 0..n {
            if !alive[i] {
                self.indeg[i] = 0;
                continue;
            }
            self.indeg[i] =
                dfg.preds(i as NodeId).len() as u32 + (self.dev_prev[i] != NONE) as u32;
            if self.indeg[i] == 0 {
                self.stack.push(i as NodeId);
            }
        }
        while let Some(i) = self.stack.pop() {
            self.order.push(i);
            for &s in dfg.succs(i) {
                self.indeg[s as usize] -= 1;
                if self.indeg[s as usize] == 0 {
                    self.stack.push(s);
                }
            }
            let nx = self.dev_next[i as usize];
            if nx != NONE {
                self.indeg[nx as usize] -= 1;
                if self.indeg[nx as usize] == 0 {
                    self.stack.push(nx);
                }
            }
        }
        assert_eq!(
            self.order.len(),
            alive_count,
            "device order contradicts dependencies (canonical-rank invariant broken)"
        );

        // ---- 6. final times over the affected cone ----
        let mut recomputed = 0usize;
        let mut max_end = f64::NEG_INFINITY;
        let mut last: NodeId = 0;
        let mut last_canon = u64::MAX;
        for k in 0..self.order.len() {
            let i = self.order[k];
            let iu = i as usize;
            if self.aff[iu] == self.epoch {
                recomputed += 1;
                let mut ready = 0.0f64;
                let mut best = NONE;
                let mut best_end = f64::NEG_INFINITY;
                let mut best_canon = u64::MAX;
                for &p in dfg.preds(i) {
                    let e = self.result.end[p as usize];
                    if e > ready {
                        ready = e;
                    }
                    if e > best_end || (e == best_end && canon[p as usize] < best_canon) {
                        best_end = e;
                        best = p;
                        best_canon = canon[p as usize];
                    }
                }
                let dp = self.dev_prev[iu];
                let (st, crit) = if dp != NONE && self.result.end[dp as usize] > ready {
                    (self.result.end[dp as usize], Some(dp))
                } else if best != NONE {
                    (ready, Some(best))
                } else {
                    (ready, None)
                };
                let en = st + dfg.node(i).duration;
                if st != self.result.start[iu] || en != self.result.end[iu] {
                    // the schedule moved: dependents join the cone
                    for &s in dfg.succs(i) {
                        self.aff[s as usize] = self.epoch;
                    }
                    let nx = self.dev_next[iu];
                    if nx != NONE {
                        self.aff[nx as usize] = self.epoch;
                    }
                }
                self.result.start[iu] = st;
                self.result.end[iu] = en;
                self.result.crit_pred[iu] = crit;
            }
            let en = self.result.end[iu];
            if en > max_end || (en == max_end && canon[iu] < last_canon) {
                max_end = en;
                last = i;
                last_canon = canon[iu];
            }
        }
        self.result.iteration_time = max_end.max(0.0);
        self.result.last = last;
        self.last_recomputed = recomputed;
        crate::obs::hot::replay_cone_nodes().add(recomputed as u64);
        &self.result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobSpec, Transport};
    use crate::graph::MutableGraph;

    fn replay_fresh(spec: &JobSpec) -> (MutableGraph, IncrementalReplayer) {
        let mut mg = MutableGraph::new(spec.clone());
        let mut eng = IncrementalReplayer::new();
        let log = mg.commit();
        eng.replay_incremental(&mg, &log);
        (mg, eng)
    }

    #[test]
    fn full_replay_respects_dependencies_and_devices() {
        let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let (mg, eng) = replay_fresh(&spec);
        let r = eng.result();
        assert!(r.iteration_time > 0.0);
        let dfg = mg.dfg();
        for i in dfg.ids() {
            if !mg.alive()[i as usize] {
                continue;
            }
            for &p in dfg.preds(i) {
                assert!(
                    r.end[p as usize] <= r.start[i as usize] + 1e-9,
                    "dependency violated"
                );
            }
        }
        // per-device serialization
        let mut per_dev: std::collections::HashMap<crate::graph::DeviceKey, Vec<(f64, f64)>> =
            Default::default();
        for i in dfg.ids() {
            if mg.alive()[i as usize] && dfg.node(i).device != crate::graph::DeviceKey::Null {
                per_dev
                    .entry(dfg.node(i).device)
                    .or_default()
                    .push((r.start[i as usize], r.end[i as usize]));
            }
        }
        for (_, mut spans) in per_dev {
            spans.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for w in spans.windows(2) {
                assert!(w[0].1 <= w[1].0 + 1e-9, "device overlap {:?} {:?}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn no_change_replay_hits_fast_path() {
        let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
        let (mut mg, mut eng) = replay_fresh(&spec);
        let t0 = eng.result().iteration_time;
        let log = mg.commit(); // nothing happened
        let t1 = eng.replay_incremental(&mg, &log).iteration_time;
        assert_eq!(t0, t1);
        assert_eq!(eng.last_recomputed(), 0);
    }

    #[test]
    fn incremental_matches_from_scratch_after_edits() {
        let spec = JobSpec::standard("resnet50", "byteps", Transport::Rdma);
        let (mut mg, mut eng) = replay_fresh(&spec);
        mg.fuse_tensor_groups(0, 1).unwrap();
        mg.fuse_comp_groups(2, 3).unwrap();
        mg.set_partitions(0, 4).unwrap();
        let log = mg.commit();
        let inc = eng.replay_incremental(&mg, &log).iteration_time;
        assert!(eng.last_recomputed() > 0);
        // from scratch on the mutated spec
        let (_, eng2) = replay_fresh(mg.spec());
        let fresh = eng2.result().iteration_time;
        assert_eq!(inc, fresh, "incremental {inc} != from-scratch {fresh}");
    }

    #[test]
    fn cone_is_smaller_than_graph_for_late_edits() {
        let spec = JobSpec::standard("resnet50", "horovod", Transport::Rdma);
        let (mut mg, mut eng) = replay_fresh(&spec);
        let n_live = mg.n_alive();
        // fuse two late tensor groups (early in backward time, late in id
        // order the cone is still bounded by the affected chains)
        let g = mg.n_groups();
        mg.fuse_tensor_groups(g - 2, g - 1).unwrap();
        let log = mg.commit();
        eng.replay_incremental(&mg, &log);
        assert!(
            eng.last_recomputed() < n_live,
            "cone {} should be below live nodes {}",
            eng.last_recomputed(),
            n_live
        );
    }
}
