//! The dPRO replayer (paper §4.3): simulates one training iteration of the
//! global DFG using a modified Kahn's algorithm — one FIFO queue and one
//! device-time per device (worker GPU, link tx/rx, PS CPU, NVLink) instead
//! of Daydream's single global ready queue.
//!
//! Also derives the execution graph's **critical path** (for the optimizer)
//! and estimates **peak memory** from the replayed schedule.
//!
//! This is the hot path of strategy search (thousands of replays per
//! search), so the engine reuses all scratch buffers across replays —
//! including the result arrays: [`Replayer::replay`] returns a borrow of
//! engine-owned storage and allocates nothing per call. The strategy
//! search itself uses the even cheaper [`incremental`] engine, which also
//! skips recomputation outside the edited cone. Fleet-scale jobs (1k+
//! workers) use the [`tiered`] engine, which simulates one machine per
//! verified symmetry class and derives the rest by timeline translation.

pub mod incremental;
pub mod partial;
pub mod tiered;

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::JobSpec;
use crate::graph::dfg::{DeviceKey, NodeId, OpKind};
use crate::graph::GlobalDfg;
use crate::util::Us;

/// Result of replaying one iteration.
#[derive(Clone, Debug)]
pub struct ReplayResult {
    /// Simulated iteration time: the latest end time (us).
    pub iteration_time: Us,
    /// Per-node simulated start times (us).
    pub start: Vec<Us>,
    /// Per-node simulated end times (us).
    pub end: Vec<Us>,
    /// For each node, the predecessor (dependency or device-order) that
    /// determined its start time; backtracking yields the critical path.
    pub crit_pred: Vec<Option<NodeId>>,
    /// Node with the latest end time.
    pub last: NodeId,
}

impl ReplayResult {
    /// Critical path, source → sink, following `crit_pred` back from the
    /// last-finishing node (the paper's execution-graph critical path).
    pub fn critical_path(&self) -> Vec<NodeId> {
        let mut path = Vec::new();
        let mut cur = Some(self.last);
        while let Some(c) = cur {
            path.push(c);
            cur = self.crit_pred[c as usize];
        }
        path.reverse();
        path
    }

    /// Total busy time of a kind on one worker (FW/BW breakdown, Table 2).
    pub fn kind_time(&self, g: &GlobalDfg, worker: u16, kind: OpKind) -> Us {
        g.dfg
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.owner == worker && n.proc == worker && n.kind == kind)
            .map(|(i, _)| self.end[i] - self.start[i])
            .sum()
    }
}

/// Reusable replay engine over one global DFG topology. Durations can be
/// swapped (profile updates, what-if edits) without rebuilding.
pub struct Replayer {
    n: usize,
    node_dev: Vec<u32>,
    /// Interned id of [`DeviceKey::Null`] (non-queuing ops), if present.
    null_dev: u32,
    n_dev: usize,
    base_indeg: Vec<u32>,
    durations: Vec<Us>,
    // scratch, reused across replays
    indeg: Vec<u32>,
    ready_at: Vec<Us>,
    ready_pred: Vec<Option<NodeId>>,
    dev_tail: Vec<Option<NodeId>>,
    dev_free: Vec<Us>,
    dev_busy: Vec<bool>,
    queues: Vec<std::collections::VecDeque<NodeId>>,
    stack: Vec<NodeId>,
    heap: BinaryHeap<Reverse<(u64, NodeId)>>,
    /// engine-owned result storage, overwritten by every replay
    result: ReplayResult,
}

impl Replayer {
    /// Build an engine for one graph topology (durations refreshable).
    pub fn new(g: &GlobalDfg) -> Replayer {
        let n = g.dfg.len();
        let mut dev_ids: std::collections::HashMap<DeviceKey, u32> =
            std::collections::HashMap::new();
        // reserve id 0 for Null so zero-cost ops never queue
        dev_ids.insert(DeviceKey::Null, 0);
        let mut node_dev = Vec::with_capacity(n);
        for node in &g.dfg.nodes {
            let next = dev_ids.len() as u32;
            let id = *dev_ids.entry(node.device).or_insert(next);
            node_dev.push(id);
        }
        let n_dev = dev_ids.len();
        Replayer {
            n,
            node_dev,
            null_dev: 0,
            n_dev,
            base_indeg: g.dfg.ids().map(|i| g.dfg.preds(i).len() as u32).collect(),
            durations: g.dfg.nodes.iter().map(|nd| nd.duration).collect(),
            indeg: vec![0; n],
            ready_at: vec![0.0; n],
            ready_pred: vec![None; n],
            dev_tail: vec![None; n_dev],
            dev_free: vec![0.0; n_dev],
            dev_busy: vec![false; n_dev],
            queues: vec![std::collections::VecDeque::new(); n_dev],
            stack: Vec::with_capacity(64),
            heap: BinaryHeap::with_capacity(256),
            result: ReplayResult {
                iteration_time: 0.0,
                start: vec![0.0; n],
                end: vec![0.0; n],
                crit_pred: vec![None; n],
                last: 0,
            },
        }
    }

    /// Take ownership of the last replay's result (for one-shot callers).
    pub fn into_result(self) -> ReplayResult {
        self.result
    }

    /// Refresh durations from the (possibly profile-updated) graph.
    pub fn set_durations_from(&mut self, g: &GlobalDfg) {
        for (i, node) in g.dfg.nodes.iter().enumerate() {
            self.durations[i] = node.duration;
        }
    }

    /// Override one node's duration (what-if evaluations).
    pub fn set_duration(&mut self, id: NodeId, d: Us) {
        self.durations[id as usize] = d;
    }

    /// Current duration of one node (including overrides).
    pub fn duration(&self, id: NodeId) -> Us {
        self.durations[id as usize]
    }

    /// Replay one iteration. The returned schedule borrows engine-owned
    /// storage (no per-call allocation); clone it or use
    /// [`Replayer::into_result`] if it must outlive the engine.
    pub fn replay(&mut self, g: &GlobalDfg) -> &ReplayResult {
        let _span = crate::obs::span("replay.exact", crate::obs::SpanKind::Work);
        let mut heap_pops: u64 = 0;
        let n = self.n;
        self.result.start.iter_mut().for_each(|x| *x = 0.0);
        self.result.end.iter_mut().for_each(|x| *x = 0.0);
        self.result.crit_pred.iter_mut().for_each(|x| *x = None);

        self.indeg.copy_from_slice(&self.base_indeg);
        self.ready_at.iter_mut().for_each(|x| *x = 0.0);
        self.ready_pred.iter_mut().for_each(|x| *x = None);
        for d in 0..self.n_dev {
            self.dev_free[d] = 0.0;
            self.dev_busy[d] = false;
            self.dev_tail[d] = None;
            self.queues[d].clear();
        }
        self.heap.clear();
        self.stack.clear();

        #[inline(always)]
        fn key(t: f64) -> u64 {
            // fixed-point (2^-16 us resolution) keeps heap keys orderable
            (t * 65536.0) as u64
        }

        let mut finished = 0usize;
        let mut last: NodeId = 0;
        let mut max_end = -1.0f64;

        for i in 0..n as NodeId {
            if self.indeg[i as usize] == 0 {
                self.stack.push(i);
            }
        }

        macro_rules! propagate {
            ($node:expr, $t:expr) => {{
                let node: NodeId = $node;
                let t: f64 = $t;
                finished += 1;
                if t > max_end {
                    max_end = t;
                    last = node;
                }
                for &s in g.dfg.succs(node) {
                    let si = s as usize;
                    self.indeg[si] -= 1;
                    if t >= self.ready_at[si] {
                        self.ready_at[si] = t;
                        self.ready_pred[si] = Some(node);
                    }
                    if self.indeg[si] == 0 {
                        self.stack.push(s);
                    }
                }
            }};
        }

        macro_rules! start_op {
            ($nd:expr, $dev:expr) => {{
                let nd: NodeId = $nd;
                let d: usize = $dev;
                let i = nd as usize;
                let ready = self.ready_at[i];
                let free = self.dev_free[d];
                let st = if free > ready {
                    self.result.crit_pred[i] = self.dev_tail[d];
                    free
                } else {
                    self.result.crit_pred[i] = self.ready_pred[i];
                    ready
                };
                self.result.start[i] = st;
                let en = st + self.durations[i];
                self.result.end[i] = en;
                self.dev_tail[d] = Some(nd);
                self.dev_free[d] = en;
                self.dev_busy[d] = true;
                self.heap.push(Reverse((key(en), nd)));
            }};
        }

        loop {
            // drain newly-ready nodes
            while let Some(node) = self.stack.pop() {
                let i = node as usize;
                let d = self.node_dev[i] as usize;
                if d as u32 == self.null_dev {
                    // non-queuing op (virtual or negotiation delay)
                    let t = self.ready_at[i];
                    self.result.crit_pred[i] = self.ready_pred[i];
                    self.result.start[i] = t;
                    let dur = self.durations[i];
                    self.result.end[i] = t + dur;
                    if dur == 0.0 {
                        propagate!(node, t);
                    } else {
                        self.heap.push(Reverse((key(t + dur), node)));
                    }
                } else if self.dev_busy[d] {
                    self.queues[d].push_back(node);
                } else {
                    start_op!(node, d);
                }
            }

            let Some(Reverse((_, node))) = self.heap.pop() else { break };
            heap_pops += 1;
            let i = node as usize;
            let t = self.result.end[i];
            let d = self.node_dev[i] as usize;
            if d as u32 != self.null_dev {
                self.dev_busy[d] = false;
            }
            propagate!(node, t);
            if d as u32 != self.null_dev && !self.dev_busy[d] {
                if let Some(nd) = self.queues[d].pop_front() {
                    start_op!(nd, d);
                }
            }
        }
        debug_assert_eq!(finished, n, "replay deadlock: {finished}/{n}");

        // one atomic add per replay, not per pop — the loop above stays
        // a plain register increment
        crate::obs::hot::replay_heap_pops().add(heap_pops);
        crate::obs::hot::replay_runs().inc();
        self.result.iteration_time = max_end.max(0.0);
        self.result.last = last;
        &self.result
    }
}

/// Convenience: build + replay in one call.
pub fn replay_once(g: &GlobalDfg) -> ReplayResult {
    let mut rp = Replayer::new(g);
    rp.replay(g);
    rp.into_result()
}

/// Peak-memory estimate from a replayed schedule (paper Table 3): the same
/// accounting walk as the testbed's ground truth, on the replayer's
/// simulated timeline; the replayer models fragmentation/runtime overheads
/// with slightly different constants than the device actually exhibits —
/// that imperfection is the estimation error the paper reports.
pub fn estimate_peak_memory(spec: &JobSpec, g: &GlobalDfg, result: &ReplayResult) -> f64 {
    crate::testbed::memory::peak_from_schedule(spec, g, &result.end)
        * crate::testbed::memory::FRAGMENTATION
        + crate::testbed::memory::RUNTIME_OVERHEAD * 0.92
}

/// The same estimate over a live [`crate::graph::MutableGraph`] schedule —
/// what the optimizer's round loop uses to judge memory strategies without
/// constructing a [`GlobalDfg`].
pub fn estimate_peak_memory_mut(mg: &crate::graph::MutableGraph, end: &[f64]) -> f64 {
    crate::testbed::memory::peak_from_mutable(mg, end)
        * crate::testbed::memory::FRAGMENTATION
        + crate::testbed::memory::RUNTIME_OVERHEAD * 0.92
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobSpec, Transport};
    use crate::graph::{build_global, AnalyticCost};

    fn spec(model: &str, scheme: &str) -> JobSpec {
        JobSpec::standard(model, scheme, Transport::Rdma)
    }

    #[test]
    fn replay_terminates_and_orders_deps() {
        let s = spec("resnet50", "horovod");
        let g = build_global(&s, &AnalyticCost::new(&s));
        let r = replay_once(&g);
        assert!(r.iteration_time > 0.0);
        for i in g.dfg.ids() {
            for &p in g.dfg.preds(i) {
                assert!(
                    r.end[p as usize] <= r.start[i as usize] + 1e-6,
                    "dep violated: {} -> {}",
                    g.dfg.node(p).name.resolve(),
                    g.dfg.node(i).name.resolve()
                );
            }
        }
    }

    #[test]
    fn device_serialization_holds() {
        let s = spec("vgg16", "byteps");
        let g = build_global(&s, &AnalyticCost::new(&s));
        let r = replay_once(&g);
        let mut per_dev: std::collections::HashMap<crate::graph::DeviceKey, Vec<(f64, f64)>> =
            std::collections::HashMap::new();
        for i in g.dfg.ids() {
            let nd = g.dfg.node(i);
            if nd.device != crate::graph::DeviceKey::Null {
                per_dev
                    .entry(nd.device)
                    .or_default()
                    .push((r.start[i as usize], r.end[i as usize]));
            }
        }
        for (_, mut spans) in per_dev {
            spans.sort_by(|a, b| (a.0, a.1).partial_cmp(&(b.0, b.1)).unwrap());
            for w in spans.windows(2) {
                assert!(w[0].1 <= w[1].0 + 1e-6, "overlap {:?} {:?}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn critical_path_connected_and_monotone() {
        let s = spec("resnet50", "horovod");
        let g = build_global(&s, &AnalyticCost::new(&s));
        let r = replay_once(&g);
        let path = r.critical_path();
        assert!(path.len() > 10);
        for w in path.windows(2) {
            assert!(r.start[w[1] as usize] >= r.end[w[0] as usize] - 1e-6);
        }
        assert_eq!(*path.last().unwrap(), r.last);
        assert!((r.end[r.last as usize] - r.iteration_time).abs() < 1e-9);
    }

    #[test]
    fn replay_close_to_testbed_with_true_durations() {
        // With durations equal to the testbed's *expected* values, replay
        // should land near the testbed's average iteration time.
        let s = spec("resnet50", "horovod");
        let g = build_global(&s, &AnalyticCost::new(&s));
        let r = replay_once(&g);
        let tb = crate::testbed::run(
            &s,
            &crate::testbed::TestbedOpts { iterations: 5, ..Default::default() },
        );
        let err = crate::util::stats::rel_err_pct(r.iteration_time, tb.avg_iter());
        assert!(err < 12.0, "analytic replay err={err:.1}%");
    }

    #[test]
    fn memory_estimate_within_ballpark_of_ground_truth() {
        let s = spec("resnet50", "horovod");
        let g = build_global(&s, &AnalyticCost::new(&s));
        let r = replay_once(&g);
        let est = estimate_peak_memory(&s, &g, &r);
        let tb = crate::testbed::run(
            &s,
            &crate::testbed::TestbedOpts { iterations: 2, ..Default::default() },
        );
        let err = crate::util::stats::rel_err_pct(est, tb.peak_memory);
        assert!(err < 10.0, "mem err={err:.1}%");
    }

    #[test]
    fn durations_can_be_overridden() {
        let s = spec("vgg16", "horovod");
        let g = build_global(&s, &AnalyticCost::new(&s));
        let mut rp = Replayer::new(&g);
        let base = rp.replay(&g).iteration_time;
        // double every computation op
        for i in g.dfg.ids() {
            if g.dfg.node(i).kind.is_comp() {
                let d = rp.duration(i);
                rp.set_duration(i, d * 2.0);
            }
        }
        let slowed = rp.replay(&g).iteration_time;
        assert!(slowed > base * 1.5, "base={base} slowed={slowed}");
        // restore
        rp.set_durations_from(&g);
        let restored = rp.replay(&g).iteration_time;
        assert!((restored - base).abs() < 1e-6);
    }
}
