//! Partial replay (paper §5.3): estimate `t_sync(s, k)` — the time to
//! synchronize a tensor of `s` bytes split into `k` partitions — by
//! replaying only the communication subgraph of a single tensor group,
//! instead of the whole global DFG.
//!
//! The estimator never constructs graphs on the query path: for each
//! partition count `k` it keeps one *probe engine* — the tiny comm
//! subgraph plus per-node affine duration coefficients `(α, β)` extracted
//! from two reference sizes. A query sets `duration_i = α_i + β_i·s` on
//! the long-lived [`Replayer`] and replays in place, so the optimizer's
//! `OptPartNum` grid search costs zero builds and zero allocations after
//! warm-up. Results are additionally memoized on (rounded size, k).
//!
//! The engine is **scheme-blind**: the probe graph is lowered through the
//! comm-plan IR like any other, and the affinity assumption is a planner
//! contract ([`crate::graph::comm_plan`] module docs §4 — every stage
//! duration is affine in the moved bytes, because every cost-model term
//! is: wire time and aggregation linear, per-message overheads and
//! latencies constant). Any scheme whose planner honors that contract gets
//! exact `t_sync` probes for free; `affine_probe_matches_direct_build`
//! pins it across all registered schemes.

use std::collections::HashMap;

use crate::config::{CommPlan, FusionPlan, JobSpec, TensorGroup};
use crate::graph::dfg::NodeId;
use crate::graph::{build_global_nameless, AnalyticCost, GlobalDfg, OpKind};
use crate::models::{ModelBuilder, ModelGraph};
use crate::replay::Replayer;
use crate::util::Us;

/// A minimal model with one backward op producing one tensor of `bytes`.
fn one_tensor_model(bytes: f64) -> ModelGraph {
    let mut b = ModelBuilder::new("probe", 1);
    b.op("probe", &[], 0.0, 8.0, 1.0, 0.0, &[("t", bytes / 4.0)]);
    b.finish()
}

/// The reference sizes the affine coefficients are extracted from. Any two
/// distinct sizes give the exact same coefficients (the model is affine);
/// these are far apart to keep the division well-conditioned.
const PROBE_S0: f64 = 1.0e6;
const PROBE_S1: f64 = 17.0e6;

/// One partition count's reusable probe: graph + engine + coefficients.
struct ProbeEngine {
    g: GlobalDfg,
    rp: Replayer,
    alpha: Vec<f64>,
    beta: Vec<f64>,
    out_nodes: Vec<NodeId>,
}

fn probe_engine(job: &JobSpec, k: usize) -> ProbeEngine {
    let mut s = job.clone();
    s.model = one_tensor_model(PROBE_S0);
    s.fusion = FusionPlan::singletons(&s.model);
    s.plan = CommPlan {
        groups: vec![TensorGroup { tensors: vec![0], partitions: k.max(1) }],
    };
    let g0 = build_global_nameless(&s, &AnalyticCost::new(&s));
    s.model = one_tensor_model(PROBE_S1);
    s.fusion = FusionPlan::singletons(&s.model);
    let g1 = build_global_nameless(&s, &AnalyticCost::new(&s));
    debug_assert_eq!(g0.dfg.len(), g1.dfg.len());
    let n = g0.dfg.len();
    let mut alpha = vec![0.0f64; n];
    let mut beta = vec![0.0f64; n];
    for i in 0..n {
        let d0 = g0.dfg.node(i as NodeId).duration;
        let d1 = g1.dfg.node(i as NodeId).duration;
        let b = (d1 - d0) / (PROBE_S1 - PROBE_S0);
        beta[i] = b;
        alpha[i] = d0 - b * PROBE_S0;
    }
    let out_nodes: Vec<NodeId> =
        g0.dfg.ids().filter(|&i| g0.dfg.node(i).kind == OpKind::Out).collect();
    let rp = Replayer::new(&g0);
    ProbeEngine { g: g0, rp, alpha, beta, out_nodes }
}

/// Memoizing t_sync estimator for one job configuration.
pub struct TsyncEstimator {
    /// Job skeleton (cluster + scheme); the probe model is substituted
    /// when an engine for a new partition count is instantiated.
    spec: JobSpec,
    engines: HashMap<usize, ProbeEngine>,
    cache: HashMap<(u64, usize), Us>,
    /// Probe replays performed (cache misses).
    pub replays: usize,
}

impl TsyncEstimator {
    /// Lazy estimator: probe engines are built on first query per `k`.
    pub fn new(job: &JobSpec) -> TsyncEstimator {
        TsyncEstimator {
            spec: job.clone(),
            engines: HashMap::new(),
            cache: HashMap::new(),
            replays: 0,
        }
    }

    /// Estimator with probe engines for every `k` in `ks` built up front,
    /// so no query inside a search round ever constructs a graph (the
    /// optimizer passes its grid range plus the partition counts already
    /// present in the deployed plan).
    pub fn with_prebuilt(job: &JobSpec, ks: impl IntoIterator<Item = usize>) -> TsyncEstimator {
        let mut est = TsyncEstimator::new(job);
        for k in ks {
            let k = k.max(1);
            est.engines.entry(k).or_insert_with(|| probe_engine(&est.spec, k));
        }
        est
    }

    /// `t_sync(s, k)`: complete synchronization time of an `s`-byte tensor
    /// in `k` partitions on an otherwise idle network.
    pub fn t_sync(&mut self, bytes: f64, k: usize) -> Us {
        // quantize size to 1 KB buckets for memoization
        let key = ((bytes / 1024.0).round() as u64, k.max(1));
        if let Some(&v) = self.cache.get(&key) {
            return v;
        }
        let b = key.0 as f64 * 1024.0;
        let t = {
            let eng = self
                .engines
                .entry(key.1)
                .or_insert_with(|| probe_engine(&self.spec, key.1));
            for i in 0..eng.alpha.len() {
                eng.rp.set_duration(i as NodeId, eng.alpha[i] + eng.beta[i] * b);
            }
            let r = eng.rp.replay(&eng.g);
            let mut t = 0.0f64;
            for &o in &eng.out_nodes {
                t = t.max(r.end[o as usize]);
            }
            t
        };
        self.replays += 1;
        self.cache.insert(key, t);
        t
    }

    /// Optimal partition count via grid search (paper: "obtained through
    /// grid search"), and its t_sync.
    pub fn opt_part_num(&mut self, bytes: f64, max_k: usize) -> (usize, Us) {
        let mut best = (1usize, f64::INFINITY);
        for k in 1..=max_k.max(1) {
            let t = self.t_sync(bytes, k);
            if t < best.1 {
                best = (k, t);
            }
        }
        best
    }

    /// Memoized `(size bucket, k)` entries so far.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Probe engines instantiated so far (one per partition count).
    pub fn engines_built(&self) -> usize {
        self.engines.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobSpec, Transport};

    #[test]
    fn tsync_monotone_in_size() {
        let job = JobSpec::standard("resnet50", "byteps", Transport::Rdma);
        let mut est = TsyncEstimator::new(&job);
        let small = est.t_sync(1.0e6, 1);
        let large = est.t_sync(64.0e6, 1);
        assert!(large > small * 4.0, "small={small} large={large}");
    }

    #[test]
    fn partitioning_helps_large_ps_tensors() {
        // PS push/pull pipeline: partitions overlap push and pull.
        let job = JobSpec::standard("vgg16", "byteps", Transport::Rdma);
        let mut est = TsyncEstimator::new(&job);
        let whole = est.t_sync(400.0e6, 1);
        let parts = est.t_sync(400.0e6, 8);
        assert!(parts < whole, "k=1: {whole}, k=8: {parts}");
    }

    #[test]
    fn too_many_partitions_hurt() {
        // per-message overhead dominates tiny partitions
        let job = JobSpec::standard("resnet50", "byteps", Transport::Tcp);
        let mut est = TsyncEstimator::new(&job);
        let reasonable = est.t_sync(4.0e6, 2);
        let absurd = est.t_sync(4.0e6, 256);
        assert!(absurd > reasonable, "k=2: {reasonable}, k=256: {absurd}");
    }

    #[test]
    fn opt_part_num_beats_endpoints() {
        let job = JobSpec::standard("vgg16", "byteps", Transport::Rdma);
        let mut est = TsyncEstimator::new(&job);
        let (k, t) = est.opt_part_num(100.0e6, 16);
        assert!(k >= 1 && k <= 16);
        assert!(t <= est.t_sync(100.0e6, 1));
        assert!(t <= est.t_sync(100.0e6, 16));
    }

    #[test]
    fn cache_hits_avoid_replays() {
        let job = JobSpec::standard("resnet50", "byteps", Transport::Rdma);
        let mut est = TsyncEstimator::new(&job);
        est.t_sync(8.0e6, 4);
        let replays = est.replays;
        est.t_sync(8.0e6, 4);
        assert_eq!(est.replays, replays);
        assert!(est.cache_len() >= 1);
    }

    #[test]
    fn queries_never_build_beyond_prebuilt_engines() {
        let job = JobSpec::standard("vgg16", "byteps", Transport::Rdma);
        let mut est = TsyncEstimator::with_prebuilt(&job, 1..=8);
        assert_eq!(est.engines_built(), 8);
        let b0 = crate::graph::build_count();
        for k in 1..=8 {
            est.t_sync(32.0e6, k);
            est.t_sync(9.0e6, k);
        }
        assert_eq!(crate::graph::build_count(), b0, "queries must not build graphs");
    }

    #[test]
    fn affine_probe_matches_direct_build() {
        // the affine evaluation must agree with building the probe graph
        // at the queried size directly, for every registered scheme.
        // a 1 KB-bucket-exact size, so memo quantization is a no-op and
        // the two paths evaluate the same operating point
        let bytes = 8192.0 * 1024.0;
        for scheme in crate::config::ALL_SCHEMES {
            let job = JobSpec::standard("resnet50", scheme, Transport::Rdma);
            let mut est = TsyncEstimator::new(&job);
            let via_affine = est.t_sync(bytes, 4);
            let mut s = job.clone();
            s.model = one_tensor_model(bytes);
            s.fusion = FusionPlan::singletons(&s.model);
            s.plan =
                CommPlan { groups: vec![TensorGroup { tensors: vec![0], partitions: 4 }] };
            let g = build_global_nameless(&s, &AnalyticCost::new(&s));
            let r = crate::replay::replay_once(&g);
            let mut direct = 0.0f64;
            for i in g.dfg.ids() {
                if g.dfg.node(i).kind == OpKind::Out {
                    direct = direct.max(r.end[i as usize]);
                }
            }
            let rel = (via_affine - direct).abs() / direct.max(1e-9);
            assert!(rel < 1e-9, "{scheme}: affine {via_affine} vs direct {direct}");
        }
    }

    #[test]
    fn tsync_scheme_blind_queries_never_build() {
        // prebuilt probe engines answer queries with zero graph builds for
        // every scheme, and partitioning helps large tensors under both PS
        // variants (their per-partition chains pipeline push against pull)
        for scheme in crate::config::ALL_SCHEMES {
            let job = JobSpec::standard("vgg16", scheme, Transport::Rdma);
            let mut est = TsyncEstimator::with_prebuilt(&job, 1..=4);
            let b0 = crate::graph::build_count();
            let t1 = est.t_sync(64.0e6, 1);
            let t4 = est.t_sync(64.0e6, 4);
            assert_eq!(crate::graph::build_count(), b0, "{scheme}: query built a graph");
            assert!(t1.is_finite() && t1 > 0.0, "{scheme}: t1={t1}");
            assert!(t4.is_finite() && t4 > 0.0, "{scheme}: t4={t4}");
            if job.scheme.uses_servers() {
                assert!(t4 < t1, "{scheme}: partitions should pipeline ({t4} !< {t1})");
            }
        }
    }
}
