//! Partial replay (paper §5.3): estimate `t_sync(s, k)` — the time to
//! synchronize a tensor of `s` bytes split into `k` partitions — by
//! replaying only the communication subgraph of a single tensor group,
//! instead of the whole global DFG.
//!
//! Results are memoized on (scheme, rounded size, k); the optimizer calls
//! this inside `OptPartNum` grid search thousands of times.

use std::collections::HashMap;

use crate::config::{CommPlan, FusionPlan, JobSpec, TensorGroup};
use crate::graph::{build_global_nameless, AnalyticCost};
use crate::models::{ModelBuilder, ModelGraph};
use crate::util::Us;

/// Memoizing t_sync estimator for one job configuration.
pub struct TsyncEstimator {
    /// Job skeleton with a single-op model; we rewrite the single group's
    /// size/partitions and replay the (tiny) comm subgraph.
    spec: JobSpec,
    cache: HashMap<(u64, usize), Us>,
    pub replays: usize,
}

/// A minimal model with one backward op producing one tensor of `bytes`.
fn one_tensor_model(bytes: f64) -> ModelGraph {
    let mut b = ModelBuilder::new("probe", 1);
    b.op("probe", &[], 0.0, 8.0, 1.0, 0.0, &[("t", bytes / 4.0)]);
    b.finish()
}

impl TsyncEstimator {
    pub fn new(job: &JobSpec) -> TsyncEstimator {
        let mut spec = job.clone();
        spec.model = one_tensor_model(4096.0);
        spec.plan = CommPlan::per_tensor(&spec.model);
        spec.fusion = FusionPlan::singletons(&spec.model);
        TsyncEstimator { spec, cache: HashMap::new(), replays: 0 }
    }

    /// `t_sync(s, k)`: complete synchronization time of an `s`-byte tensor
    /// in `k` partitions on an otherwise idle network.
    pub fn t_sync(&mut self, bytes: f64, k: usize) -> Us {
        // quantize size to 1 KB buckets for memoization
        let key = ((bytes / 1024.0).round() as u64, k.max(1));
        if let Some(&v) = self.cache.get(&key) {
            return v;
        }
        self.spec.model = one_tensor_model((key.0 as f64) * 1024.0);
        self.spec.fusion = FusionPlan::singletons(&self.spec.model);
        self.spec.plan = CommPlan {
            groups: vec![TensorGroup { tensors: vec![0], partitions: k.max(1) }],
        };
        let g = build_global_nameless(&self.spec, &AnalyticCost::new(&self.spec));
        let r = crate::replay::replay_once(&g);
        self.replays += 1;
        // synchronization time = from the In ops (time 0; the probe op is
        // ~free) to the last Out — minus the probe/update tails.
        let mut t = 0.0f64;
        for i in g.dfg.ids() {
            let n = g.dfg.node(i);
            if n.kind == crate::graph::OpKind::Out {
                t = t.max(r.end[i as usize]);
            }
        }
        self.cache.insert(key, t);
        t
    }

    /// Optimal partition count via grid search (paper: "obtained through
    /// grid search"), and its t_sync.
    pub fn opt_part_num(&mut self, bytes: f64, max_k: usize) -> (usize, Us) {
        let mut best = (1usize, f64::INFINITY);
        for k in 1..=max_k.max(1) {
            let t = self.t_sync(bytes, k);
            if t < best.1 {
                best = (k, t);
            }
        }
        best
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobSpec, Transport};

    #[test]
    fn tsync_monotone_in_size() {
        let job = JobSpec::standard("resnet50", "byteps", Transport::Rdma);
        let mut est = TsyncEstimator::new(&job);
        let small = est.t_sync(1.0e6, 1);
        let large = est.t_sync(64.0e6, 1);
        assert!(large > small * 4.0, "small={small} large={large}");
    }

    #[test]
    fn partitioning_helps_large_ps_tensors() {
        // PS push/pull pipeline: partitions overlap push and pull.
        let job = JobSpec::standard("vgg16", "byteps", Transport::Rdma);
        let mut est = TsyncEstimator::new(&job);
        let whole = est.t_sync(400.0e6, 1);
        let parts = est.t_sync(400.0e6, 8);
        assert!(parts < whole, "k=1: {whole}, k=8: {parts}");
    }

    #[test]
    fn too_many_partitions_hurt() {
        // per-message overhead dominates tiny partitions
        let job = JobSpec::standard("resnet50", "byteps", Transport::Tcp);
        let mut est = TsyncEstimator::new(&job);
        let reasonable = est.t_sync(4.0e6, 2);
        let absurd = est.t_sync(4.0e6, 256);
        assert!(absurd > reasonable, "k=2: {reasonable}, k=256: {absurd}");
    }

    #[test]
    fn opt_part_num_beats_endpoints() {
        let job = JobSpec::standard("vgg16", "byteps", Transport::Rdma);
        let mut est = TsyncEstimator::new(&job);
        let (k, t) = est.opt_part_num(100.0e6, 16);
        assert!(k >= 1 && k <= 16);
        assert!(t <= est.t_sync(100.0e6, 1));
        assert!(t <= est.t_sync(100.0e6, 16));
    }

    #[test]
    fn cache_hits_avoid_replays() {
        let job = JobSpec::standard("resnet50", "byteps", Transport::Rdma);
        let mut est = TsyncEstimator::new(&job);
        est.t_sync(8.0e6, 4);
        let replays = est.replays;
        est.t_sync(8.0e6, 4);
        assert_eq!(est.replays, replays);
        assert!(est.cache_len() >= 1);
    }
}
