//! Symmetry-class (tiered) replay: simulate one representative machine
//! exactly, derive the rest by timeline translation.
//!
//! ## Why this is sound
//!
//! The ring-structured collective schemes declare
//! [`PlanSymmetry::MachineRotation`]: rotating the machine index maps the
//! lowered plan onto itself, so under identical durations every machine's
//! timeline is *equal* (rotation composed with the rotation-invariant
//! start-time recurrence is the identity on times). The engine never
//! trusts the declaration alone — before deriving anything it verifies,
//! structurally and against **effective** durations (profile + what-if
//! overrides included), that every machine's node stream is the
//! representative's stream modulo rotation:
//!
//! - same kind/device-class/normalized-index/proc/owner per position,
//! - bit-equal effective duration and tensor bytes per position,
//! - identical normalized predecessor sets (own-machine preds by local
//!   index, shared preds by exact id, foreign preds by rotation distance
//!   + local index),
//! - every *shared* node (negotiate stages, coordinator ops) draws its
//!   machine-side predecessors identically from all machines,
//! - every cross-class edge into the simulated set is either mirrored by
//!   an equivalent representative-local edge (zero-duration markers) or
//!   carried by a phantom event (positive-duration ring hops).
//!
//! Any violation — a straggler multiplier, an injected fault, a what-if
//! edit on one machine, diagnosis evidence naming a deviating machine, a
//! scheme that declares no symmetry — demotes the whole job to the exact
//! engine. Demotion is all-or-nothing by design: the ring topologies
//! that make machine rotation a symmetry also couple every machine to
//! every other within one group, so a single perturbed machine perturbs
//! all timelines and no partial class survives. The demotion reasons are
//! reported, never silent.
//!
//! ## The reduced simulation
//!
//! The simulated set is machine 0's nodes plus all shared nodes. Edges
//! from *derived* (non-simulated) nodes into the simulated set are
//! replayed by **phantom events**: when the representative mirror of a
//! derived boundary op is scheduled, the engine enqueues a heap entry
//! under the *derived node's own id* with the mirror's end time — by
//! symmetry exactly the entry the exact engine would pop, in exactly the
//! same `(time, id)` heap position — whose pop propagates only into the
//! simulated set. Zero-duration cross-class edges (the In markers
//! feeding a shared negotiate stage) need no event at all: the
//! verification above guarantees the representative's own mirror edge
//! delivers the same ready time, so their in-degree contribution is
//! dropped up front. Derived timelines are then filled in parallel
//! ([`crate::util::pool`]) by positional copy from the representative,
//! with critical-path predecessors translated through the rotation.
//!
//! Results are **bit-for-bit identical** to [`super::Replayer`] on every
//! unbroken symmetric plan — the `tiered` test suite sweeps this across
//! all registered schemes (`start`/`end`/`iteration_time`; the
//! `last`/`crit_pred` tie-break metadata may legitimately pick a
//! different node with the same time).

use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap, HashMap, VecDeque};

use crate::config::JobSpec;
use crate::graph::dfg::{DeviceKey, NodeId, COORD_PROC};
use crate::graph::{plan_symmetry, GlobalDfg, PlanSymmetry};
use crate::replay::{ReplayResult, Replayer};
use crate::util::pool::{parallel_for, DisjointSlice};
use crate::util::Us;

/// Replay mode selector (CLI `--replay-mode`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplayMode {
    /// Event-driven simulation of every node ([`super::Replayer`]).
    Exact,
    /// Symmetry-class simulation with verified derivation (this module).
    Tiered,
}

impl ReplayMode {
    /// Parse a CLI value.
    pub fn parse(s: &str) -> Option<ReplayMode> {
        match s {
            "exact" => Some(ReplayMode::Exact),
            "tiered" => Some(ReplayMode::Tiered),
            _ => None,
        }
    }

    /// CLI name.
    pub fn name(self) -> &'static str {
        match self {
            ReplayMode::Exact => "exact",
            ReplayMode::Tiered => "tiered",
        }
    }
}

/// What the tiered engine actually did for the last replay.
#[derive(Clone, Debug, Default)]
pub struct TierReport {
    /// `"tiered"` when derivation applied, `"exact"` after a demotion
    /// (or when tiered was never requested).
    pub mode_used: String,
    /// Machines in the cluster layout.
    pub n_machines: usize,
    /// Machines verified shift-equivalent to the representative
    /// (including the representative; equals `n_machines` when tiered
    /// applied, 0 after a structural demotion).
    pub n_symmetric: usize,
    /// Nodes simulated event-by-event (representative + shared).
    pub simulated_nodes: usize,
    /// Nodes filled by timeline translation.
    pub derived_nodes: usize,
    /// Why the job fell back to exact replay (empty when tiered ran).
    pub demoted: Vec<String>,
}

impl TierReport {
    /// JSON form for the CLI's `--json` output.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = Json::obj();
        o.set("mode_used", Json::Str(self.mode_used.clone()));
        o.set("n_machines", Json::Num(self.n_machines as f64));
        o.set("n_symmetric", Json::Num(self.n_symmetric as f64));
        o.set("simulated_nodes", Json::Num(self.simulated_nodes as f64));
        o.set("derived_nodes", Json::Num(self.derived_nodes as f64));
        o.set(
            "demoted",
            Json::Arr(self.demoted.iter().map(|r| Json::Str(r.clone())).collect()),
        );
        o
    }
}

/// Node scope: which timeline a node belongs to.
const SHARED: i32 = -1;

/// Durations below the heap's fixed-point resolution would make a
/// phantom's push/pop keys collide; such plans demote rather than risk a
/// tie-order divergence (none of the built-in schemes produce them).
const RES_GUARD: Us = 1e-4;

/// Reusable tiered engine over one graph topology. Owns an exact
/// [`Replayer`] for the fallback path; durations set through this type
/// flow into both engines and into the symmetry verification.
pub struct TieredReplayer {
    exact: Replayer,
    n: usize,
    n_machines: usize,
    gpus_per_machine: usize,
    n_workers: usize,
    declared: bool,
    /// [`SHARED`] or the owning machine index.
    scope: Vec<i32>,
    /// Position of each machine-scoped node inside its machine's
    /// id-ordered node list (meaningless for shared nodes).
    local_idx: Vec<u32>,
    /// Per machine: its node ids, ascending.
    machine_nodes: Vec<Vec<NodeId>>,
    /// Effective durations (graph values + overrides); the single source
    /// the verification and the reduced simulation both read.
    durations: Vec<Us>,
    /// Machines demoted by external (diagnosis) evidence.
    broken: BTreeSet<u16>,
    /// Verification is duration-sensitive: any duration change re-runs it.
    dirty: bool,
    plan_ok: bool,
    simulated: Vec<bool>,
    /// In-degree restricted to simulated + phantom-carried edges.
    sim_indeg: Vec<u32>,
    /// Representative mirror id → derived nodes whose cross-class edges
    /// it carries (phantom registration).
    phantoms: HashMap<NodeId, Vec<NodeId>>,
    n_sim: usize,
    report: TierReport,
    // ---- reduced-sim scratch (mirrors the exact engine's layout) ----
    node_dev: Vec<u32>,
    n_dev: usize,
    indeg: Vec<u32>,
    ready_at: Vec<Us>,
    ready_pred: Vec<Option<NodeId>>,
    dev_tail: Vec<Option<NodeId>>,
    dev_free: Vec<Us>,
    dev_busy: Vec<bool>,
    queues: Vec<VecDeque<NodeId>>,
    stack: Vec<NodeId>,
    heap: BinaryHeap<Reverse<(u64, NodeId)>>,
    result: ReplayResult,
}

impl TieredReplayer {
    /// Build an engine for one graph topology under one cluster layout.
    pub fn new(g: &GlobalDfg, spec: &JobSpec) -> TieredReplayer {
        let n = g.dfg.len();
        let cluster = &spec.cluster;
        let n_machines = cluster.n_machines();
        let gpus_per_machine = cluster.gpus_per_machine;
        let n_workers = cluster.n_workers;
        let machine_of = |w: u16| -> i32 { (w as usize / gpus_per_machine.max(1)) as i32 };

        let mut scope = Vec::with_capacity(n);
        for node in &g.dfg.nodes {
            let s = match node.device {
                DeviceKey::Gpu(w) => machine_of(w),
                DeviceKey::LinkTx(m) | DeviceKey::LinkRx(m) | DeviceKey::NvLink(m) => {
                    if (m as usize) < n_machines {
                        m as i32
                    } else {
                        SHARED
                    }
                }
                DeviceKey::PsCpu(_) | DeviceKey::Coordinator => SHARED,
                DeviceKey::Null => {
                    if node.proc == COORD_PROC || node.proc as usize >= n_workers {
                        SHARED
                    } else {
                        machine_of(node.proc)
                    }
                }
            };
            scope.push(s);
        }
        let mut machine_nodes: Vec<Vec<NodeId>> = vec![Vec::new(); n_machines];
        let mut local_idx = vec![0u32; n];
        for i in 0..n {
            let s = scope[i];
            if s >= 0 {
                local_idx[i] = machine_nodes[s as usize].len() as u32;
                machine_nodes[s as usize].push(i as NodeId);
            }
        }

        // device interning, same scheme as the exact engine (id 0 = Null)
        let mut dev_ids: HashMap<DeviceKey, u32> = HashMap::new();
        dev_ids.insert(DeviceKey::Null, 0);
        let mut node_dev = Vec::with_capacity(n);
        for node in &g.dfg.nodes {
            let next = dev_ids.len() as u32;
            node_dev.push(*dev_ids.entry(node.device).or_insert(next));
        }
        let n_dev = dev_ids.len();

        TieredReplayer {
            exact: Replayer::new(g),
            n,
            n_machines,
            gpus_per_machine,
            n_workers,
            declared: plan_symmetry(&spec.scheme) == PlanSymmetry::MachineRotation,
            scope,
            local_idx,
            machine_nodes,
            durations: g.dfg.nodes.iter().map(|nd| nd.duration).collect(),
            broken: BTreeSet::new(),
            dirty: true,
            plan_ok: false,
            simulated: vec![false; n],
            sim_indeg: vec![0; n],
            phantoms: HashMap::new(),
            n_sim: 0,
            report: TierReport::default(),
            node_dev,
            n_dev,
            indeg: vec![0; n],
            ready_at: vec![0.0; n],
            ready_pred: vec![None; n],
            dev_tail: vec![None; n_dev],
            dev_free: vec![0.0; n_dev],
            dev_busy: vec![false; n_dev],
            queues: vec![VecDeque::new(); n_dev],
            stack: Vec::with_capacity(64),
            heap: BinaryHeap::with_capacity(256),
            result: ReplayResult {
                iteration_time: 0.0,
                start: vec![0.0; n],
                end: vec![0.0; n],
                crit_pred: vec![None; n],
                last: 0,
            },
        }
    }

    /// Refresh durations from the (possibly profile-updated) graph.
    pub fn set_durations_from(&mut self, g: &GlobalDfg) {
        for (i, node) in g.dfg.nodes.iter().enumerate() {
            self.durations[i] = node.duration;
        }
        self.exact.set_durations_from(g);
        self.dirty = true;
    }

    /// Override one node's duration (what-if evaluations). Asymmetric
    /// overrides break the verified symmetry and demote to exact replay
    /// automatically — the signature covers effective durations.
    pub fn set_duration(&mut self, id: NodeId, d: Us) {
        self.durations[id as usize] = d;
        self.exact.set_duration(id, d);
        self.dirty = true;
    }

    /// Current effective duration of one node.
    pub fn duration(&self, id: NodeId) -> Us {
        self.durations[id as usize]
    }

    /// Demote machines named by external evidence (diagnosis straggler /
    /// drift findings): any non-empty set forces exact replay with the
    /// machines recorded in the report.
    pub fn demote_machines(&mut self, machines: impl IntoIterator<Item = u16>) {
        for m in machines {
            self.broken.insert(m);
        }
        self.dirty = true;
    }

    /// Forget evidence demotions (symmetry verification still applies).
    pub fn clear_demotions(&mut self) {
        if !self.broken.is_empty() {
            self.broken.clear();
            self.dirty = true;
        }
    }

    /// What the last [`TieredReplayer::replay`] did. Before the first
    /// replay the report is empty.
    pub fn report(&self) -> &TierReport {
        &self.report
    }

    /// Replay one iteration: tiered when the verified symmetry allows,
    /// exact otherwise. The returned schedule covers **all** nodes
    /// either way and borrows engine-owned storage.
    pub fn replay(&mut self, g: &GlobalDfg) -> &ReplayResult {
        let _span = crate::obs::span("replay.tiered", crate::obs::SpanKind::Work);
        if self.dirty {
            let _cls = crate::obs::span("replay.tiered.classify", crate::obs::SpanKind::Work);
            self.classify(g);
            self.dirty = false;
            crate::obs::hot::tiered_demotions().add(self.report.demoted.len() as u64);
        }
        if !self.plan_ok {
            self.report.mode_used = "exact".into();
            self.report.simulated_nodes = self.n;
            self.report.derived_nodes = 0;
            return self.exact.replay(g);
        }
        self.report.mode_used = "tiered".into();
        self.report.simulated_nodes = self.n_sim;
        self.report.derived_nodes = self.n - self.n_sim;
        {
            let _red = crate::obs::span("replay.tiered.reduced", crate::obs::SpanKind::Work);
            self.reduced_replay(g);
        }
        {
            let _der = crate::obs::span("replay.tiered.derive", crate::obs::SpanKind::Work);
            self.derive(g);
        }
        &self.result
    }

    // ---------------------------------------------------------------
    // verification
    // ---------------------------------------------------------------

    /// Normalized device signature of a node on machine `m`.
    fn dev_sig(&self, dev: DeviceKey, m: usize) -> (u8, i64) {
        let base_w = (m * self.gpus_per_machine) as i64;
        match dev {
            DeviceKey::Gpu(w) => (0, w as i64 - base_w),
            DeviceKey::LinkTx(x) => (1, x as i64 - m as i64),
            DeviceKey::LinkRx(x) => (2, x as i64 - m as i64),
            DeviceKey::NvLink(x) => (3, x as i64 - m as i64),
            DeviceKey::PsCpu(s) => (4, s as i64),
            DeviceKey::Coordinator => (5, 0),
            DeviceKey::Null => (6, 0),
        }
    }

    /// Normalized proc/owner signature on machine `m`.
    fn proc_sig(&self, p: u16, m: usize) -> i64 {
        if p == COORD_PROC {
            i64::MAX
        } else if (p as usize) < self.n_workers {
            p as i64 - (m * self.gpus_per_machine) as i64
        } else {
            (1i64 << 32) + p as i64
        }
    }

    /// Normalized predecessor token: own-machine preds by local index,
    /// shared preds by exact id, foreign preds by rotation distance.
    fn pred_sig(&self, p: NodeId, m: usize) -> (u8, i64) {
        let ps = self.scope[p as usize];
        if ps == SHARED {
            (2, p as i64)
        } else if ps as usize == m {
            (0, self.local_idx[p as usize] as i64)
        } else {
            let delta = (ps as usize + self.n_machines - m) % self.n_machines;
            (1, (delta as i64) << 32 | self.local_idx[p as usize] as i64)
        }
    }

    /// Does machine `k`'s node stream equal the representative's modulo
    /// rotation? Compared positionally against machine 0.
    fn machine_matches(&self, g: &GlobalDfg, k: usize) -> bool {
        let rep = &self.machine_nodes[0];
        let mem = &self.machine_nodes[k];
        if rep.len() != mem.len() {
            return false;
        }
        let mut pa: Vec<(u8, i64)> = Vec::with_capacity(8);
        let mut pb: Vec<(u8, i64)> = Vec::with_capacity(8);
        for i in 0..rep.len() {
            let (a, b) = (rep[i], mem[i]);
            let (na, nb) = (g.dfg.node(a), g.dfg.node(b));
            if na.kind != nb.kind
                || self.dev_sig(na.device, 0) != self.dev_sig(nb.device, k)
                || self.proc_sig(na.proc, 0) != self.proc_sig(nb.proc, k)
                || self.proc_sig(na.owner, 0) != self.proc_sig(nb.owner, k)
                || self.durations[a as usize].to_bits() != self.durations[b as usize].to_bits()
                || na.txid.is_some() != nb.txid.is_some()
            {
                return false;
            }
            let (ba, bb) = (
                na.tensor.map(|t| t.bytes.to_bits()),
                nb.tensor.map(|t| t.bytes.to_bits()),
            );
            if ba != bb {
                return false;
            }
            let (preds_a, preds_b) = (g.dfg.preds(a), g.dfg.preds(b));
            if preds_a.len() != preds_b.len() {
                return false;
            }
            pa.clear();
            pb.clear();
            pa.extend(preds_a.iter().map(|&p| self.pred_sig(p, 0)));
            pb.extend(preds_b.iter().map(|&p| self.pred_sig(p, k)));
            pa.sort_unstable();
            pb.sort_unstable();
            if pa != pb {
                return false;
            }
        }
        true
    }

    /// Full symmetry verification + reduced-plan construction. Sets
    /// `plan_ok` and fills the report's structural fields.
    fn classify(&mut self, g: &GlobalDfg) {
        self.report = TierReport {
            n_machines: self.n_machines,
            ..TierReport::default()
        };
        self.plan_ok = false;

        if !self.declared {
            self.report.demoted.push("scheme declares no machine-rotation symmetry".into());
            return;
        }
        if self.n_machines <= 1 {
            self.report.demoted.push("single machine: nothing to derive".into());
            return;
        }
        if !self.broken.is_empty() {
            self.report.demoted.push(format!(
                "diagnosis evidence marks machines {:?} as deviating",
                self.broken.iter().collect::<Vec<_>>()
            ));
            return;
        }

        // ---- per-machine signature streams, verified in parallel ----
        let m = self.n_machines;
        let ok_flags: Vec<std::sync::atomic::AtomicBool> =
            (0..m).map(|_| std::sync::atomic::AtomicBool::new(true)).collect();
        {
            let me = &*self;
            parallel_for(m - 1, |j| {
                let k = j + 1;
                if !me.machine_matches(g, k) {
                    ok_flags[k].store(false, std::sync::atomic::Ordering::Relaxed);
                }
            });
        }
        let mismatched: Vec<usize> = (1..m)
            .filter(|&k| !ok_flags[k].load(std::sync::atomic::Ordering::Relaxed))
            .collect();
        self.report.n_symmetric = m - mismatched.len();
        if !mismatched.is_empty() {
            self.report.demoted.push(format!(
                "machines {mismatched:?} are not shift-equivalent to machine 0 \
                 (structure or effective durations differ)"
            ));
            return;
        }

        // ---- shared nodes must couple to every machine identically ----
        for i in 0..self.n {
            if self.scope[i] != SHARED {
                continue;
            }
            let mut per_machine: Vec<Vec<u32>> = vec![Vec::new(); m];
            for &p in g.dfg.preds(i as NodeId) {
                let ps = self.scope[p as usize];
                if ps >= 0 {
                    per_machine[ps as usize].push(self.local_idx[p as usize]);
                }
            }
            for pm in &mut per_machine {
                pm.sort_unstable();
            }
            if per_machine.iter().skip(1).any(|pm| *pm != per_machine[0]) {
                self.report.demoted.push(format!(
                    "shared node {i} draws predecessors asymmetrically across machines"
                ));
                return;
            }
        }

        // ---- reduced plan: simulated mask, adjusted in-degrees,
        //      phantom registration, cross-class edge audit ----
        for i in 0..self.n {
            self.simulated[i] = self.scope[i] == SHARED || self.scope[i] == 0;
        }
        self.n_sim = self.simulated.iter().filter(|&&s| s).count();
        self.phantoms.clear();
        let mut phantom_seen: std::collections::HashSet<NodeId> =
            std::collections::HashSet::new();
        for s in 0..self.n {
            if !self.simulated[s] {
                self.sim_indeg[s] = 0;
                continue;
            }
            let mut deg = 0u32;
            // lazily materialized: only nodes with zero-duration
            // cross-class preds (negotiate stages) pay for the set
            let mut pred_set: Option<std::collections::HashSet<NodeId>> = None;
            for &p in g.dfg.preds(s as NodeId) {
                let pu = p as usize;
                if self.simulated[pu] {
                    deg += 1;
                    continue;
                }
                // cross-class edge: derived predecessor of a simulated node
                let dur = self.durations[pu];
                if dur == 0.0 {
                    // must be mirrored by the representative's own edge,
                    // which then delivers the identical ready time
                    let mirror =
                        self.machine_nodes[0][self.local_idx[pu] as usize];
                    let set = pred_set.get_or_insert_with(|| {
                        g.dfg.preds(s as NodeId).iter().copied().collect()
                    });
                    if !set.contains(&mirror) {
                        self.report.demoted.push(format!(
                            "zero-duration cross-class edge {p} -> {s} has no \
                             mirrored representative edge"
                        ));
                        return;
                    }
                    // in-degree contribution dropped: the mirror's edge
                    // already gates `s` at the same time
                } else if dur < RES_GUARD {
                    self.report.demoted.push(format!(
                        "cross-class edge {p} -> {s} below heap resolution \
                         ({dur} us)"
                    ));
                    return;
                } else {
                    deg += 1;
                    if phantom_seen.insert(p) {
                        let mirror =
                            self.machine_nodes[0][self.local_idx[pu] as usize];
                        self.phantoms.entry(mirror).or_default().push(p);
                    }
                }
            }
            self.sim_indeg[s] = deg;
        }
        self.plan_ok = true;
    }

    // ---------------------------------------------------------------
    // reduced simulation
    // ---------------------------------------------------------------

    /// The exact engine's event loop restricted to the simulated set,
    /// with phantom events carrying the cross-class edges. Any heap
    /// entry whose id is a *derived* node is a phantom: its `(key, id)`
    /// pair equals, by verified symmetry, the entry the exact engine
    /// would pop for that node, so pop order — and therefore every FIFO
    /// and device-tail decision — is preserved bit-for-bit.
    fn reduced_replay(&mut self, g: &GlobalDfg) {
        let n = self.n;
        self.result.start.iter_mut().for_each(|x| *x = 0.0);
        self.result.end.iter_mut().for_each(|x| *x = 0.0);
        self.result.crit_pred.iter_mut().for_each(|x| *x = None);

        self.indeg.copy_from_slice(&self.sim_indeg);
        self.ready_at.iter_mut().for_each(|x| *x = 0.0);
        self.ready_pred.iter_mut().for_each(|x| *x = None);
        for d in 0..self.n_dev {
            self.dev_free[d] = 0.0;
            self.dev_busy[d] = false;
            self.dev_tail[d] = None;
            self.queues[d].clear();
        }
        self.heap.clear();
        self.stack.clear();

        #[inline(always)]
        fn key(t: f64) -> u64 {
            // identical fixed-point key to the exact engine
            (t * 65536.0) as u64
        }

        let mut finished = 0usize;
        let mut last: NodeId = 0;
        let mut max_end = -1.0f64;

        for i in 0..n as NodeId {
            if self.simulated[i as usize] && self.indeg[i as usize] == 0 {
                self.stack.push(i);
            }
        }

        macro_rules! propagate {
            ($node:expr, $t:expr) => {{
                let node: NodeId = $node;
                let t: f64 = $t;
                finished += 1;
                if t > max_end {
                    max_end = t;
                    last = node;
                }
                for &s in g.dfg.succs(node) {
                    let si = s as usize;
                    if !self.simulated[si] {
                        continue;
                    }
                    self.indeg[si] -= 1;
                    if t >= self.ready_at[si] {
                        self.ready_at[si] = t;
                        self.ready_pred[si] = Some(node);
                    }
                    if self.indeg[si] == 0 {
                        self.stack.push(s);
                    }
                }
            }};
        }

        // a phantom pop: the derived node's cross-class effects only
        macro_rules! propagate_phantom {
            ($node:expr, $t:expr) => {{
                let node: NodeId = $node;
                let t: f64 = $t;
                for &s in g.dfg.succs(node) {
                    let si = s as usize;
                    if !self.simulated[si] {
                        continue;
                    }
                    self.indeg[si] -= 1;
                    if t >= self.ready_at[si] {
                        self.ready_at[si] = t;
                        self.ready_pred[si] = Some(node);
                    }
                    if self.indeg[si] == 0 {
                        self.stack.push(s);
                    }
                }
            }};
        }

        macro_rules! emit_phantoms {
            ($mirror:expr, $st:expr, $en:expr) => {{
                if let Some(ds) = self.phantoms.get(&$mirror) {
                    for &d in ds {
                        let du = d as usize;
                        // by symmetry the derived node runs at the same
                        // times as its mirror; record them now so the
                        // pop (and the derivation fill) read them back
                        self.result.start[du] = $st;
                        self.result.end[du] = $en;
                        self.heap.push(Reverse((key($en), d)));
                    }
                }
            }};
        }

        macro_rules! start_op {
            ($nd:expr, $dev:expr) => {{
                let nd: NodeId = $nd;
                let d: usize = $dev;
                let i = nd as usize;
                let ready = self.ready_at[i];
                let free = self.dev_free[d];
                let st = if free > ready {
                    self.result.crit_pred[i] = self.dev_tail[d];
                    free
                } else {
                    self.result.crit_pred[i] = self.ready_pred[i];
                    ready
                };
                self.result.start[i] = st;
                let en = st + self.durations[i];
                self.result.end[i] = en;
                self.dev_tail[d] = Some(nd);
                self.dev_free[d] = en;
                self.dev_busy[d] = true;
                self.heap.push(Reverse((key(en), nd)));
                emit_phantoms!(nd, st, en);
            }};
        }

        loop {
            while let Some(node) = self.stack.pop() {
                let i = node as usize;
                let d = self.node_dev[i] as usize;
                if d == 0 {
                    // non-queuing op (virtual or negotiation delay)
                    let t = self.ready_at[i];
                    self.result.crit_pred[i] = self.ready_pred[i];
                    self.result.start[i] = t;
                    let dur = self.durations[i];
                    self.result.end[i] = t + dur;
                    if dur == 0.0 {
                        propagate!(node, t);
                    } else {
                        self.heap.push(Reverse((key(t + dur), node)));
                    }
                    emit_phantoms!(node, t, t + dur);
                } else if self.dev_busy[d] {
                    self.queues[d].push_back(node);
                } else {
                    start_op!(node, d);
                }
            }

            let Some(Reverse((_, node))) = self.heap.pop() else { break };
            let i = node as usize;
            let t = self.result.end[i];
            if !self.simulated[i] {
                propagate_phantom!(node, t);
                continue;
            }
            let d = self.node_dev[i] as usize;
            if d != 0 {
                self.dev_busy[d] = false;
            }
            propagate!(node, t);
            if d != 0 && !self.dev_busy[d] {
                if let Some(nd) = self.queues[d].pop_front() {
                    start_op!(nd, d);
                }
            }
        }
        debug_assert_eq!(
            finished, self.n_sim,
            "tiered replay deadlock: {finished}/{} simulated", self.n_sim
        );

        self.result.iteration_time = max_end.max(0.0);
        self.result.last = last;
    }

    /// Fill derived timelines by positional copy from the representative
    /// — one parallel task per derived machine, disjoint index sets.
    fn derive(&mut self, _g: &GlobalDfg) {
        let m = self.n_machines;
        let rep: &[NodeId] = &self.machine_nodes[0];
        let machine_nodes = &self.machine_nodes;
        let scope = &self.scope;
        let local_idx = &self.local_idx;
        // split borrows: the result arrays become shared-write views
        let start = DisjointSlice::new(&mut self.result.start);
        let end = DisjointSlice::new(&mut self.result.end);
        let crit = DisjointSlice::new(&mut self.result.crit_pred);
        parallel_for(m - 1, |j| {
            let k = j + 1;
            let mem = &machine_nodes[k];
            for (pos, &d) in mem.iter().enumerate() {
                let r = rep[pos] as usize;
                let du = d as usize;
                // SAFETY: machine k's node ids are touched by task k only
                // (machines partition the derived ids; the simulated set
                // is untouched here)
                unsafe {
                    start.set(du, start.get(r));
                    end.set(du, end.get(r));
                    // translate the critical predecessor through the
                    // rotation: representative-local preds map to the
                    // member's positional twin, shared preds stay
                    let c = crit.get(r);
                    let mapped = c.map(|p| {
                        let pu = p as usize;
                        if scope[pu] == 0 {
                            machine_nodes[k][local_idx[pu] as usize]
                        } else {
                            p
                        }
                    });
                    crit.set(du, mapped);
                }
            }
        });
    }
}

/// Convenience: build + replay in one call, returning the schedule and
/// what the engine did.
pub fn replay_tiered(g: &GlobalDfg, spec: &JobSpec) -> (ReplayResult, TierReport) {
    let mut rp = TieredReplayer::new(g, spec);
    let result = rp.replay(g).clone();
    let report = rp.report().clone();
    (result, report)
}
