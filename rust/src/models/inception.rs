//! InceptionV3-style template (Szegedy et al. 2016): a branchy DAG of ~94
//! conv+bn+relu triples totalling ≈ 24 M parameters. Branch widths follow
//! the published architecture closely enough to reproduce its op-size
//! *distribution* (many small convolutions, heavy graph parallelism) —
//! the property that stresses the replayer's device-queue model.

use super::{conv2d, elementwise_bytes, ModelBuilder, ModelGraph};

const CONV_EFF: f64 = 0.95;

struct Ctx {
    b: ModelBuilder,
    h: usize,
    w: usize,
}

impl Ctx {
    /// conv+bn+relu triple from `cin` channels; returns (relu id, cout).
    fn cbr(&mut self, name: &str, dep: Option<u32>, cin: usize, cout: usize, k: usize, stride: usize) -> u32 {
        let batch = self.b.batch();
        let s = conv2d(batch, self.h, self.w, cin, cout, k, stride);
        let deps: Vec<u32> = dep.into_iter().collect();
        let conv = self.b.op(name, &deps, s.flops, s.bytes, CONV_EFF, s.act_bytes,
                             &[("weight", s.weight_elems)]);
        self.h = s.out_h;
        self.w = s.out_w;
        let elems = (self.h * self.w * cout) as f64;
        let bn = self.b.op(&format!("{name}_bn"), &[conv], 0.0,
                           2.0 * elementwise_bytes(batch, elems), 1.0, 4.0 * batch * elems,
                           &[("gamma", cout as f64), ("beta", cout as f64)]);
        self.b.op(&format!("{name}_relu"), &[bn], 0.0, elementwise_bytes(batch, elems), 1.0,
                  4.0 * batch * elems, &[])
    }

    /// A chain of convs inside one branch; all at current spatial dims,
    /// except the last which may stride.
    fn branch(&mut self, name: &str, input: u32, cin: usize, chain: &[(usize, usize)], stride_last: usize) -> (u32, usize) {
        let (h0, w0) = (self.h, self.w);
        let mut c = cin;
        let mut last = input;
        for (i, &(cout, k)) in chain.iter().enumerate() {
            let s = if i + 1 == chain.len() { stride_last } else { 1 };
            self.h = if i == 0 { h0 } else { self.h };
            self.w = if i == 0 { w0 } else { self.w };
            last = self.cbr(&format!("{name}_c{}", i + 1), Some(last), c, cout, k, s);
            c = cout;
        }
        (last, c)
    }

    /// Inception module: parallel branches concatenated along channels.
    /// `branches`: per-branch conv chains [(cout, k), ...].
    fn module(&mut self, name: &str, input: u32, cin: usize, branches: &[&[(usize, usize)]], stride: usize) -> (u32, usize) {
        let (h0, w0) = (self.h, self.w);
        let mut outs = Vec::new();
        let mut total_c = 0usize;
        let (mut oh, mut ow) = (h0, w0);
        for (bi, chain) in branches.iter().enumerate() {
            self.h = h0;
            self.w = w0;
            let (out, c) = self.branch(&format!("{name}_b{}", bi + 1), input, cin, chain, stride);
            outs.push(out);
            total_c += c;
            oh = self.h;
            ow = self.w;
        }
        self.h = oh;
        self.w = ow;
        // concat: memory-bound shuffle of the concatenated activation
        let elems = (self.h * self.w * total_c) as f64;
        let concat = self.b.op(&format!("{name}_concat"), &outs, 0.0,
                               elementwise_bytes(self.b.batch(), elems), 1.0,
                               4.0 * self.b.batch() * elems, &[]);
        (concat, total_c)
    }
}

/// Build the InceptionV3 template (input 299×299×3, 1000 classes).
pub fn inception_v3(batch_size: usize) -> ModelGraph {
    let mut ctx = Ctx { b: ModelBuilder::new("inception_v3", batch_size), h: 299, w: 299 };
    // Stem: 3 convs + pool + 2 convs + pool
    let c1 = ctx.cbr("stem1", None, 3, 32, 3, 2);
    let c2 = ctx.cbr("stem2", Some(c1), 32, 32, 3, 1);
    let c3 = ctx.cbr("stem3", Some(c2), 32, 64, 3, 1);
    ctx.h /= 2;
    ctx.w /= 2; // pool
    let c4 = ctx.cbr("stem4", Some(c3), 64, 80, 1, 1);
    let c5 = ctx.cbr("stem5", Some(c4), 80, 192, 3, 1);
    ctx.h /= 2;
    ctx.w /= 2; // pool
    let mut x = c5;
    let mut c = 192usize;

    // 3× module A (35×35): branches 1x1/64, 1x1-5x5/48-64, 1x1-3x3-3x3/64-96-96, pool-1x1/32..64
    for i in 0..3 {
        let pool_c = if i == 0 { 32 } else { 64 };
        let branches: Vec<Vec<(usize, usize)>> = vec![
            vec![(64, 1)],
            vec![(48, 1), (64, 5)],
            vec![(64, 1), (96, 3), (96, 3)],
            vec![(pool_c, 1)],
        ];
        let bref: Vec<&[(usize, usize)]> = branches.iter().map(|v| v.as_slice()).collect();
        let (out, cc) = ctx.module(&format!("mixA{}", i + 1), x, c, &bref, 1);
        x = out;
        c = cc;
    }
    // reduction A (35→17)
    {
        let branches: Vec<Vec<(usize, usize)>> =
            vec![vec![(384, 3)], vec![(64, 1), (96, 3), (96, 3)]];
        let bref: Vec<&[(usize, usize)]> = branches.iter().map(|v| v.as_slice()).collect();
        let (out, cc) = ctx.module("redA", x, c, &bref, 2);
        x = out;
        c = cc + c / 2; // pooled passthrough approximated in channel count
    }
    // 4× module B (17×17) with factorized 7x1/1x7 (approximated as k=7 cost split)
    for (i, ch7) in [128usize, 160, 160, 192].iter().enumerate() {
        let branches: Vec<Vec<(usize, usize)>> = vec![
            vec![(192, 1)],
            vec![(*ch7, 1), (*ch7, 3), (192, 3)],
            vec![(*ch7, 1), (*ch7, 3), (*ch7, 3), (*ch7, 3), (192, 3)],
            vec![(192, 1)],
        ];
        let bref: Vec<&[(usize, usize)]> = branches.iter().map(|v| v.as_slice()).collect();
        let (out, cc) = ctx.module(&format!("mixB{}", i + 1), x, c, &bref, 1);
        x = out;
        c = cc;
    }
    // reduction B (17→8)
    {
        let branches: Vec<Vec<(usize, usize)>> =
            vec![vec![(192, 1), (320, 3)], vec![(192, 1), (192, 3), (192, 3)]];
        let bref: Vec<&[(usize, usize)]> = branches.iter().map(|v| v.as_slice()).collect();
        let (out, cc) = ctx.module("redB", x, c, &bref, 2);
        x = out;
        c = cc + c / 2;
    }
    // 2× module C (8×8)
    for i in 0..2 {
        let branches: Vec<Vec<(usize, usize)>> = vec![
            vec![(320, 1)],
            vec![(384, 1), (384, 3)],
            vec![(448, 1), (384, 3), (384, 3)],
            vec![(192, 1)],
        ];
        let bref: Vec<&[(usize, usize)]> = branches.iter().map(|v| v.as_slice()).collect();
        let (out, cc) = ctx.module(&format!("mixC{}", i + 1), x, c, &bref, 1);
        x = out;
        c = cc;
    }
    // global pool + fc
    let batch = ctx.b.batch();
    let gap = ctx.b.op("gap", &[x], 0.0, 4.0 * batch * (ctx.h * ctx.w * c) as f64, 1.0,
                       4.0 * batch * c as f64, &[]);
    ctx.b.op("fc", &[gap], 2.0 * batch * c as f64 * 1000.0,
             4.0 * (c as f64 * 1000.0 + batch * (c as f64 + 1000.0)), 1.4,
             4.0 * batch * 1000.0, &[("weight", c as f64 * 1000.0), ("bias", 1000.0)]);
    ctx.b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_near_24m() {
        let g = inception_v3(32);
        let params = g.num_params();
        assert!((18.0e6..30.0e6).contains(&params), "params={params}");
    }

    #[test]
    fn branchy_and_valid() {
        let g = inception_v3(8);
        assert_eq!(g.validate(), Ok(()));
        // concat ops have >= 2 deps
        assert!(g.ops.iter().any(|o| o.name.contains("concat") && o.deps.len() >= 2));
        // ~90+ convs
        let convs = g.ops.iter().filter(|o| o.name.starts_with("FW.") && o.produces.is_empty() && o.flops > 0.0).count();
        assert!(convs > 60, "convs={convs}");
    }
}
