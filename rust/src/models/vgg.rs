//! VGG16 template (Simonyan & Zisserman 2015): 13 convs + 3 FC layers,
//! ~138 M parameters dominated by the 102 M-element fc1 weight — the
//! pathological huge-tensor case that makes tensor *partitioning* matter
//! (BytePS's default 4 MB slices vs dPRO's searched size).

use super::{conv2d, elementwise_bytes, ModelBuilder, ModelGraph};

const CONV_EFF: f64 = 1.0;
const FC_EFF: f64 = 1.1;

/// Build the VGG16 template (input 224×224×3, 1000 classes, no BN).
pub fn vgg16(batch_size: usize) -> ModelGraph {
    let mut b = ModelBuilder::new("vgg16", batch_size);
    let batch = b.batch();
    let cfg: [&[usize]; 5] = [&[64, 64], &[128, 128], &[256, 256, 256], &[512, 512, 512], &[512, 512, 512]];
    let (mut h, mut w, mut c) = (224usize, 224usize, 3usize);
    let mut last: Option<u32> = None;
    for (si, stage) in cfg.iter().enumerate() {
        for (ci, &cout) in stage.iter().enumerate() {
            let s = conv2d(batch, h, w, c, cout, 3, 1);
            let name = format!("conv{}_{}", si + 1, ci + 1);
            let deps: Vec<u32> = last.into_iter().collect();
            let conv = b.op(&name, &deps, s.flops, s.bytes, CONV_EFF, s.act_bytes,
                            &[("weight", s.weight_elems), ("bias", cout as f64)]);
            h = s.out_h;
            w = s.out_w;
            c = cout;
            let relu_elems = (h * w * c) as f64;
            last = Some(b.op(&format!("{name}_relu"), &[conv], 0.0,
                             elementwise_bytes(batch, relu_elems), 1.0,
                             4.0 * batch * relu_elems, &[]));
        }
        // max pool /2
        h /= 2;
        w /= 2;
        let pool_elems = (h * w * c) as f64;
        last = Some(b.op(&format!("pool{}", si + 1), &[last.unwrap()], 0.0,
                         elementwise_bytes(batch, pool_elems), 1.0,
                         4.0 * batch * pool_elems, &[]));
    }
    // flatten 7*7*512 = 25088 → fc 4096 → 4096 → 1000
    let mut in_dim = (h * w * c) as f64;
    debug_assert_eq!(in_dim, 25088.0);
    for (i, out_dim) in [4096.0, 4096.0, 1000.0].iter().enumerate() {
        let name = format!("fc{}", i + 1);
        let flops = 2.0 * batch * in_dim * out_dim;
        let bytes = 4.0 * (in_dim * out_dim + batch * (in_dim + out_dim));
        let fc = b.op(&name, &[last.unwrap()], flops, bytes, FC_EFF, 4.0 * batch * out_dim,
                      &[("weight", in_dim * out_dim), ("bias", *out_dim)]);
        last = if i < 2 {
            Some(b.op(&format!("{name}_relu"), &[fc], 0.0, elementwise_bytes(batch, *out_dim),
                      1.0, 4.0 * batch * out_dim, &[]))
        } else {
            Some(fc)
        };
        in_dim = *out_dim;
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_138m() {
        let g = vgg16(32);
        let params = g.num_params();
        assert!((135.0e6..140.0e6).contains(&params), "params={params}");
        assert_eq!(g.tensors.len(), 32); // 16 weight + 16 bias
    }

    #[test]
    fn fc1_is_the_huge_tensor() {
        let g = vgg16(32);
        let max = g.tensors.iter().max_by(|a, b2| a.bytes.partial_cmp(&b2.bytes).unwrap()).unwrap();
        assert!(max.name.contains("fc1"));
        assert!((max.bytes - 25088.0 * 4096.0 * 4.0).abs() < 1.0);
    }

    #[test]
    fn structure_valid() {
        let g = vgg16(16);
        assert_eq!(g.validate(), Ok(()));
        assert_eq!(g.fw_ids().len(), g.bw_ids().len());
    }
}
