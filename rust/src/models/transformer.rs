//! Configurable GPT-style decoder template. Mirrors the JAX/Pallas model in
//! `python/compile/model.py`, so the live end-to-end example can profile
//! the same architecture it actually executes through PJRT, and dPRO can
//! replay/optimize that live job.

use super::{elementwise_bytes, ModelBuilder, ModelGraph};

const GEMM_EFF: f64 = 0.95;

/// Shape of the GPT-style decoder (mirrors `python/compile/model.py`).
#[derive(Clone, Copy, Debug)]
pub struct GptConfig {
    /// Per-worker batch size.
    pub batch_size: usize,
    /// Sequence length.
    pub seq_len: usize,
    /// Model (embedding) dimension.
    pub hidden: usize,
    /// Decoder layer count.
    pub layers: usize,
    /// Attention head count.
    pub heads: usize,
    /// Vocabulary size.
    pub vocab: usize,
}

impl GptConfig {
    /// ~25 M params — the config the e2e example trains for hundreds of
    /// steps through PJRT on this CPU box.
    pub fn mini(batch_size: usize) -> GptConfig {
        GptConfig { batch_size, seq_len: 128, hidden: 384, layers: 6, heads: 6, vocab: 8192 }
    }

    /// ~117 M params — the "100M-class" configuration used for profiling /
    /// replay experiments (GPT-2-small shaped).
    pub fn m100(batch_size: usize) -> GptConfig {
        GptConfig { batch_size, seq_len: 256, hidden: 768, layers: 12, heads: 12, vocab: 32768 }
    }

    /// Analytic parameter count of the configuration.
    pub fn num_params(&self) -> f64 {
        let h = self.hidden as f64;
        let v = self.vocab as f64;
        let per_layer = 4.0 * h * h + 2.0 * 4.0 * h * h + 4.0 * h + 9.0 * h; // attn + mlp + biases/ln
        v * h + self.seq_len as f64 * h + self.layers as f64 * per_layer + 2.0 * h
    }
}

/// Build the GPT template from a config.
pub fn gpt(cfg: GptConfig) -> ModelGraph {
    let mut b = ModelBuilder::new("gpt", cfg.batch_size);
    let bs = b.batch();
    let s = cfg.seq_len as f64;
    let h = cfg.hidden as f64;
    let ff = 4.0 * h;
    let tok = bs * s;

    let emb = b.op("embed", &[], 0.0, 3.0 * 4.0 * tok * h, 1.0, 4.0 * tok * h,
                   &[("wte", cfg.vocab as f64 * h), ("wpe", s * h)]);
    let mut x = emb;
    for l in 0..cfg.layers {
        let name = format!("layer{l:02}");
        let dense = |b: &mut ModelBuilder, nm: &str, dep: u32, din: f64, dout: f64| -> u32 {
            b.op(nm, &[dep], 2.0 * tok * din * dout, 4.0 * (din * dout + tok * (din + dout)),
                 GEMM_EFF, 4.0 * tok * dout, &[("kernel", din * dout), ("bias", dout)])
        };
        let ln1 = b.op(&format!("{name}_ln1"), &[x], 0.0, 2.0 * elementwise_bytes(1.0, tok * h),
                       1.0, 4.0 * tok * h, &[("gamma", h), ("beta", h)]);
        // fused qkv projection (as the Pallas/JAX model emits it)
        let qkv = dense(&mut b, &format!("{name}_qkv"), ln1, h, 3.0 * h);
        // fused attention kernel (the L1 Pallas hot-spot): scores+softmax+context
        let heads = cfg.heads as f64;
        let attn_flops = 2.0 * 2.0 * bs * heads * s * s * (h / heads);
        let attn = b.op(&format!("{name}_attn"), &[qkv], attn_flops,
                        4.0 * (3.0 * tok * h + bs * heads * s * s), GEMM_EFF, 4.0 * tok * h, &[]);
        let proj = dense(&mut b, &format!("{name}_proj"), attn, h, h);
        let add1 = b.op(&format!("{name}_add1"), &[proj, x], 0.0,
                        1.5 * elementwise_bytes(1.0, tok * h), 1.0, 4.0 * tok * h, &[]);
        let ln2 = b.op(&format!("{name}_ln2"), &[add1], 0.0, 2.0 * elementwise_bytes(1.0, tok * h),
                       1.0, 4.0 * tok * h, &[("gamma", h), ("beta", h)]);
        let fc1 = dense(&mut b, &format!("{name}_fc1"), ln2, h, ff);
        let gelu = b.op(&format!("{name}_gelu"), &[fc1], 0.0, elementwise_bytes(1.0, tok * ff),
                        1.0, 4.0 * tok * ff, &[]);
        let fc2 = dense(&mut b, &format!("{name}_fc2"), gelu, ff, h);
        x = b.op(&format!("{name}_add2"), &[fc2, add1], 0.0,
                 1.5 * elementwise_bytes(1.0, tok * h), 1.0, 4.0 * tok * h, &[]);
    }
    let lnf = b.op("ln_f", &[x], 0.0, 2.0 * elementwise_bytes(1.0, tok * h), 1.0, 4.0 * tok * h,
                   &[("gamma", h), ("beta", h)]);
    // logits head (ties to wte in the JAX model; treated as flops-only here)
    b.op("logits", &[lnf], 2.0 * tok * h * cfg.vocab as f64,
         4.0 * (h * cfg.vocab as f64 + tok * h), GEMM_EFF, 4.0 * tok * cfg.vocab as f64, &[]);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mini_config_size() {
        let cfg = GptConfig::mini(8);
        let g = gpt(cfg);
        assert_eq!(g.validate(), Ok(()));
        let params = g.num_params();
        assert!((8.0e6..30.0e6).contains(&params), "params={params}");
    }

    #[test]
    fn m100_is_100m_class() {
        let cfg = GptConfig::m100(8);
        assert!((80.0e6..150.0e6).contains(&cfg.num_params()), "estimate={}", cfg.num_params());
        let g = gpt(cfg);
        let params = g.num_params();
        assert!((80.0e6..150.0e6).contains(&params), "params={params}");
    }

    #[test]
    fn layers_scale_ops() {
        let a = gpt(GptConfig { layers: 2, ..GptConfig::mini(8) });
        let b = gpt(GptConfig { layers: 4, ..GptConfig::mini(8) });
        assert!(b.ops.len() > a.ops.len());
    }
}
