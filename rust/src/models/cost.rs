//! Analytic GPU cost model used to synthesize op-level profiles.
//!
//! The paper profiles real V100s; we have none, so op durations come from a
//! roofline-style model: `launch_overhead + max(flops / eff_flops, bytes /
//! eff_bw)`. Default constants are calibrated so that ResNet50 / BERT-Base
//! forward+backward times land near the paper's Table 2 measurements
//! (ResNet50 FW ≈ 35 ms, BW ≈ 70 ms at batch 32; BERT FW ≈ 107 ms,
//! BW ≈ 186 ms), which keeps compute/communication ratios — the quantity
//! every dPRO claim depends on — realistic.

use crate::util::Us;

/// Numeric precision of an op's math; mixed-precision pass flips eligible
/// ops to Fp16.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Single precision (the default).
    Fp32,
    /// Half precision on tensor cores (mixed-precision training).
    Fp16,
}

/// Device model (defaults approximate one V100-32GB running TF graphs
/// without XLA, i.e. *achieved* rather than peak rates).
#[derive(Clone, Debug)]
pub struct GpuModel {
    /// Achieved FLOP/s for compute-bound fp32 kernels.
    pub flops: f64,
    /// fp16 (tensor core) multiplier over fp32 throughput.
    pub fp16_speedup: f64,
    /// Achieved HBM bytes/s for memory-bound kernels.
    pub mem_bw: f64,
    /// Fixed per-kernel launch + framework scheduling overhead (us). This
    /// is the term op fusion removes, so it is first-class here.
    pub launch_overhead_us: Us,
    /// Coefficient of variation of kernel durations (testbed jitter).
    pub duration_cv: f64,
    /// Device memory capacity in bytes (V100-32GB default; Table 4 uses
    /// the 16 GB variant).
    pub mem_capacity: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        GpuModel {
            flops: 7.0e12,
            fp16_speedup: 2.6,
            mem_bw: 800.0e9,
            launch_overhead_us: 8.0,
            duration_cv: 0.04,
            mem_capacity: 32.0e9,
        }
    }
}

impl GpuModel {
    /// The 16 GB V100 variant (Table 4's memory experiments).
    pub fn v100_16gb() -> GpuModel {
        GpuModel { mem_capacity: 16.0e9, ..GpuModel::default() }
    }

    /// Duration of a kernel with the given work, in microseconds.
    pub fn kernel_time(&self, flops: f64, bytes: f64, prec: Precision) -> Us {
        let eff_flops = match prec {
            Precision::Fp32 => self.flops,
            Precision::Fp16 => self.flops * self.fp16_speedup,
        };
        let eff_bytes = match prec {
            // fp16 halves the traffic of the same logical op.
            Precision::Fp32 => bytes,
            Precision::Fp16 => bytes * 0.5,
        };
        let compute_us = flops / eff_flops * 1e6;
        let mem_us = eff_bytes / self.mem_bw * 1e6;
        self.launch_overhead_us + compute_us.max(mem_us)
    }

    /// Duration of a *fused* kernel: one launch overhead, slight locality
    /// gain on the body (fused intermediates stay in registers/L2).
    pub fn fused_time(&self, body_times: &[Us]) -> Us {
        const LOCALITY_GAIN: f64 = 0.06;
        let body: Us = body_times
            .iter()
            .map(|t| (t - self.launch_overhead_us).max(0.0))
            .sum();
        self.launch_overhead_us + body * (1.0 - LOCALITY_GAIN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_bound_vs_memory_bound() {
        let g = GpuModel::default();
        // 7 GFLOP compute-bound kernel: 1 ms + launch
        let t = g.kernel_time(7.0e9, 1.0e6, Precision::Fp32);
        assert!((t - (1000.0 + g.launch_overhead_us)).abs() < 1e-6, "t={t}");
        // 800 MB memory-bound kernel: 1 ms + launch
        let t = g.kernel_time(1.0e6, 800.0e6, Precision::Fp32);
        assert!((t - (1000.0 + g.launch_overhead_us)).abs() < 1e-6, "t={t}");
    }

    #[test]
    fn fp16_faster() {
        let g = GpuModel::default();
        let a = g.kernel_time(4.0e9, 1.0e6, Precision::Fp32);
        let b = g.kernel_time(4.0e9, 1.0e6, Precision::Fp16);
        assert!(b < a);
    }

    #[test]
    fn fusion_saves_launch_overhead() {
        let g = GpuModel::default();
        let a = g.kernel_time(1.0e8, 1.0e6, Precision::Fp32);
        let b = g.kernel_time(1.0e8, 1.0e6, Precision::Fp32);
        let fused = g.fused_time(&[a, b]);
        assert!(fused < a + b);
        // Saves at least one launch overhead.
        assert!(a + b - fused >= g.launch_overhead_us * 0.9);
    }

    #[test]
    fn fused_never_negative() {
        let g = GpuModel::default();
        let fused = g.fused_time(&[1.0, 2.0]);
        assert!(fused >= g.launch_overhead_us);
    }
}
