//! BERT-Base template (Devlin et al. 2019): embeddings + 12 identical
//! transformer encoder blocks + pooler, ≈ 110 M parameters. The 12-block
//! repetition is the symmetry the optimizer's search acceleration exploits
//! (paper §5.3), and the per-block GEMM sizes reproduce BERT's
//! communication profile (a few large tensors per block).

use super::{elementwise_bytes, ModelBuilder, ModelGraph};

const GEMM_EFF: f64 = 0.95;
/// TF keeps attention probs, dropout masks and fp32 softmax buffers alive
/// for the backward pass — about 2.2x the raw layer outputs.
const ACT_FACTOR: f64 = 2.2;
const HIDDEN: f64 = 768.0;
const FF: f64 = 3072.0;
const HEADS: f64 = 12.0;
const VOCAB: f64 = 30522.0;

/// Build BERT-Base at the given per-GPU batch size and sequence length.
pub fn bert_base(batch_size: usize, seq_len: usize) -> ModelGraph {
    let mut b = ModelBuilder::new("bert_base", batch_size);
    let bs = b.batch();
    let s = seq_len as f64;
    let tok = bs * s; // total tokens
    let h = HIDDEN;

    // Embedding lookup + additions: memory-bound; params: word/pos/type
    // embeddings + LN(γ,β).
    let emb = b.op(
        "embed",
        &[],
        0.0,
        3.0 * 4.0 * tok * h,
        1.0,
        4.0 * tok * h,
        &[
            ("word", VOCAB * h),
            ("pos", 512.0 * h),
            ("type", 2.0 * h),
        ],
    );
    let emb_ln = b.op("embed_ln", &[emb], 0.0, 2.0 * elementwise_bytes(1.0, tok * h), 1.0,
                      4.0 * tok * h, &[("gamma", h), ("beta", h)]);

    let mut x = emb_ln;
    for l in 0..12 {
        x = encoder_block(&mut b, &format!("blk{l:02}"), x, bs, s);
    }

    // pooler: dense over [CLS]
    b.op("pooler", &[x], 2.0 * bs * h * h, 4.0 * (h * h + bs * 2.0 * h), GEMM_EFF,
         4.0 * bs * h, &[("weight", h * h), ("bias", h)]);
    let mut g = b.finish();
    for op in &mut g.ops {
        op.activation_bytes *= ACT_FACTOR;
    }
    g
}

/// One encoder block; returns the id of its final op.
fn encoder_block(b: &mut ModelBuilder, name: &str, input: u32, bs: f64, s: f64) -> u32 {
    let h = HIDDEN;
    let tok = bs * s;
    let dense = |b: &mut ModelBuilder, nm: &str, dep: u32, din: f64, dout: f64| -> u32 {
        b.op(nm, &[dep], 2.0 * tok * din * dout, 4.0 * (din * dout + tok * (din + dout)),
             GEMM_EFF, 4.0 * tok * dout,
             &[("kernel", din * dout), ("bias", dout)])
    };
    // Q, K, V projections (three separate matmuls, as TF graphs emit them)
    let q = dense(b, &format!("{name}_q"), input, h, h);
    let k = dense(b, &format!("{name}_k"), input, h, h);
    let v = dense(b, &format!("{name}_v"), input, h, h);
    // attention scores: B*heads * (s×d)·(d×s)
    let score_flops = 2.0 * bs * HEADS * s * s * (h / HEADS);
    let scores = b.op(&format!("{name}_scores"), &[q, k], score_flops,
                      4.0 * (2.0 * tok * h + bs * HEADS * s * s), GEMM_EFF,
                      4.0 * bs * HEADS * s * s, &[]);
    let softmax = b.op(&format!("{name}_softmax"), &[scores], 0.0,
                       2.0 * 4.0 * bs * HEADS * s * s, 1.0, 4.0 * bs * HEADS * s * s, &[]);
    let ctx = b.op(&format!("{name}_context"), &[softmax, v], score_flops,
                   4.0 * (bs * HEADS * s * s + 2.0 * tok * h), GEMM_EFF, 4.0 * tok * h, &[]);
    let attn_out = dense(b, &format!("{name}_attnout"), ctx, h, h);
    let add1 = b.op(&format!("{name}_add1"), &[attn_out, input], 0.0,
                    1.5 * elementwise_bytes(1.0, tok * h), 1.0, 4.0 * tok * h, &[]);
    let ln1 = b.op(&format!("{name}_ln1"), &[add1], 0.0, 2.0 * elementwise_bytes(1.0, tok * h),
                   1.0, 4.0 * tok * h, &[("gamma", h), ("beta", h)]);
    let ff1 = dense(b, &format!("{name}_ff1"), ln1, h, FF);
    let gelu = b.op(&format!("{name}_gelu"), &[ff1], 0.0, elementwise_bytes(1.0, tok * FF), 1.0,
                    4.0 * tok * FF, &[]);
    let ff2 = dense(b, &format!("{name}_ff2"), gelu, FF, h);
    let add2 = b.op(&format!("{name}_add2"), &[ff2, ln1], 0.0,
                    1.5 * elementwise_bytes(1.0, tok * h), 1.0, 4.0 * tok * h, &[]);
    b.op(&format!("{name}_ln2"), &[add2], 0.0, 2.0 * elementwise_bytes(1.0, tok * h), 1.0,
         4.0 * tok * h, &[("gamma", h), ("beta", h)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dfg::OpKind;
    use crate::models::cost::GpuModel;

    #[test]
    fn params_near_110m() {
        let g = bert_base(32, 128);
        let params = g.num_params();
        assert!((105.0e6..115.0e6).contains(&params), "params={params}");
    }

    #[test]
    fn fw_bw_near_paper_table2() {
        // Paper Table 2: FW 107.49 ms, BW 185.66 ms (bs 32, V100, TF).
        let g = bert_base(32, 128);
        let gpu = GpuModel::default();
        let fw_ms = g.comp_time(&gpu, OpKind::Forward) / 1e3;
        let bw_ms = g.comp_time(&gpu, OpKind::Backward) / 1e3;
        assert!((80.0..140.0).contains(&fw_ms), "fw={fw_ms}ms");
        assert!((160.0..280.0).contains(&bw_ms), "bw={bw_ms}ms");
    }

    #[test]
    fn twelve_symmetric_blocks() {
        let g = bert_base(8, 128);
        assert_eq!(g.validate(), Ok(()));
        let blk0: Vec<&str> = g.ops.iter().filter(|o| o.name.contains("blk00")).map(|o| o.name.as_str()).collect();
        let blk7: Vec<&str> = g.ops.iter().filter(|o| o.name.contains("blk07")).map(|o| o.name.as_str()).collect();
        assert_eq!(blk0.len(), blk7.len());
        assert!(blk0.len() >= 28); // 14 fw + 14 bw
    }
}
