//! Model zoo: op-level local-DFG templates for the four paper benchmarks
//! (ResNet50, VGG16, InceptionV3, BERT-Base) plus a configurable GPT-style
//! decoder used by the live end-to-end example.
//!
//! A template describes *one worker's* computation graph: forward ops,
//! mirrored backward ops, the gradient tensors each backward op produces,
//! and per-op FLOPs / memory traffic from which the [`cost::GpuModel`]
//! synthesizes durations. Data-parallel training replicates the template on
//! every worker (the paper's symmetry assumption).

pub mod bert;
pub mod cost;
pub mod inception;
pub mod resnet;
pub mod transformer;
pub mod vgg;

use crate::graph::dfg::{OpKind, TensorId};
use crate::util::Us;
use cost::{GpuModel, Precision};

/// A gradient tensor synchronized across workers.
#[derive(Clone, Debug)]
pub struct TensorTpl {
    /// Tensor name (`<op>.<suffix>`, e.g. `conv1.w`).
    pub name: String,
    /// Size in bytes at fp32.
    pub bytes: f64,
}

/// One computation op of the per-worker template.
#[derive(Clone, Debug)]
pub struct CompOpTpl {
    /// Op name (`FW.<layer>` / `BW.<layer>`).
    pub name: String,
    /// `Forward` or `Backward`.
    pub kind: OpKind,
    /// Floating-point operations the op performs.
    pub flops: f64,
    /// HBM traffic in bytes (memory-bound ops).
    pub bytes: f64,
    /// Achieved-FLOPs multiplier relative to the device baseline (GEMMs
    /// run closer to peak than convolutions on V100/TF).
    pub eff: f64,
    /// Template ids of predecessor ops.
    pub deps: Vec<u32>,
    /// Gradient tensors this (backward) op produces, in production order.
    pub produces: Vec<TensorId>,
    /// Bytes of output activations a forward op keeps alive until its
    /// mirrored backward op consumes them (memory estimation, §7.4).
    pub activation_bytes: f64,
    /// Numeric precision the op computes in (mixed precision flips this).
    pub precision: Precision,
    /// Original template ids merged into this op by op fusion (empty for
    /// unfused ops). Used for reporting and for `opfs_time` refinement.
    pub fused_from: Vec<u32>,
    /// For a forward op: template id of its mirrored backward op (and vice
    /// versa). Drives activation lifetime in memory estimation.
    pub mirror: Option<u32>,
}

impl CompOpTpl {
    /// Expected kernel duration on `gpu` (roofline + launch overhead).
    pub fn duration(&self, gpu: &GpuModel) -> Us {
        if !self.fused_from.is_empty() {
            // Fused op: body times of constituents are folded by the cost
            // model's fusion rule at construction time and cached in
            // `flops/bytes`; duration recomputed the same way.
        }
        let mut g = gpu.clone();
        g.flops *= self.eff;
        g.kernel_time(self.flops, self.bytes, self.precision)
    }
}

/// Per-worker model template.
#[derive(Clone, Debug)]
pub struct ModelGraph {
    /// Registry name (`resnet50`, `bert_base`, ...).
    pub name: String,
    /// Per-worker batch size the costs were synthesized for.
    pub batch_size: usize,
    /// Computation ops, forward ops first, then mirrored backward ops.
    pub ops: Vec<CompOpTpl>,
    /// Gradient tensors synchronized across workers.
    pub tensors: Vec<TensorTpl>,
}

impl ModelGraph {
    /// Total parameter/gradient bytes (fp32).
    pub fn param_bytes(&self) -> f64 {
        self.tensors.iter().map(|t| t.bytes).sum()
    }

    /// Parameter count (fp32 elements).
    pub fn num_params(&self) -> f64 {
        self.param_bytes() / 4.0
    }

    /// Template ids of all forward ops, ascending.
    pub fn fw_ids(&self) -> Vec<u32> {
        self.ids_of(OpKind::Forward)
    }

    /// Template ids of all backward ops, ascending.
    pub fn bw_ids(&self) -> Vec<u32> {
        self.ids_of(OpKind::Backward)
    }

    fn ids_of(&self, kind: OpKind) -> Vec<u32> {
        (0..self.ops.len() as u32).filter(|&i| self.ops[i as usize].kind == kind).collect()
    }

    /// Total forward/backward time on one device with no jitter (the
    /// "profiled" single-GPU breakdown).
    pub fn comp_time(&self, gpu: &GpuModel, kind: OpKind) -> Us {
        self.ops.iter().filter(|o| o.kind == kind).map(|o| o.duration(gpu)).sum()
    }

    /// Backward op that produces tensor `t`, if any.
    pub fn producer_of(&self, t: TensorId) -> Option<u32> {
        (0..self.ops.len() as u32).find(|&i| self.ops[i as usize].produces.contains(&t))
    }

    /// Validate invariant structure (DAG over template ids; every tensor
    /// produced exactly once; deps within range).
    pub fn validate(&self) -> Result<(), String> {
        let n = self.ops.len() as u32;
        let mut produced = vec![0u32; self.tensors.len()];
        for (i, op) in self.ops.iter().enumerate() {
            for &d in &op.deps {
                if d >= n {
                    return Err(format!("op {i} dep {d} out of range"));
                }
                if d as usize >= i {
                    return Err(format!("op {i} ({}) dep {d} not earlier", op.name));
                }
            }
            for &t in &op.produces {
                if t as usize >= produced.len() {
                    return Err(format!("op {i} produces unknown tensor {t}"));
                }
                produced[t as usize] += 1;
            }
        }
        if let Some(t) = produced.iter().position(|&c| c != 1) {
            return Err(format!("tensor {t} ({}) produced {} times", self.tensors[t].name, produced[t]));
        }
        Ok(())
    }
}

/// Incremental builder used by the per-model generators. Ops are appended
/// in forward order, then `finish_backward` mirrors them.
pub struct ModelBuilder {
    name: String,
    batch_size: usize,
    ops: Vec<CompOpTpl>,
    tensors: Vec<TensorTpl>,
    /// For each forward op: parameter tensors its backward op will produce.
    fw_params: Vec<Vec<TensorId>>,
}

impl ModelBuilder {
    /// Start a template with no ops.
    pub fn new(name: &str, batch_size: usize) -> ModelBuilder {
        ModelBuilder {
            name: name.to_string(),
            batch_size,
            ops: Vec::new(),
            tensors: Vec::new(),
            fw_params: Vec::new(),
        }
    }

    /// Batch size as f64 (cost formulas).
    pub fn batch(&self) -> f64 {
        self.batch_size as f64
    }

    fn add_tensor(&mut self, name: String, elems: f64) -> TensorId {
        let id = self.tensors.len() as TensorId;
        self.tensors.push(TensorTpl { name, bytes: elems * 4.0 });
        id
    }

    /// Append a forward op. `params` lists (suffix, element-count) pairs of
    /// learnable tensors whose gradients the mirrored backward op emits.
    /// Returns the forward op id (use as dep for later ops).
    pub fn op(
        &mut self,
        name: &str,
        deps: &[u32],
        flops: f64,
        bytes: f64,
        eff: f64,
        activation_bytes: f64,
        params: &[(&str, f64)],
    ) -> u32 {
        let id = self.ops.len() as u32;
        let tensor_ids: Vec<TensorId> =
            params.iter().map(|(suffix, elems)| self.add_tensor(format!("{name}.{suffix}"), *elems)).collect();
        self.ops.push(CompOpTpl {
            name: format!("FW.{name}"),
            kind: OpKind::Forward,
            flops,
            bytes,
            eff,
            deps: deps.to_vec(),
            produces: Vec::new(),
            activation_bytes,
            precision: Precision::Fp32,
            fused_from: Vec::new(),
            mirror: None,
        });
        self.fw_params.push(tensor_ids);
        id
    }

    /// Mirror every forward op into a backward op (reverse order, ~1.8×
    /// FLOPs, ~1.9× memory traffic — calibrated to Table 2 BW/FW ratios) and return
    /// the finished template. Backward of op i depends on backward of each
    /// successor of i (chain rule) and on forward op i (activations).
    pub fn finish(self) -> ModelGraph {
        let ModelBuilder { name, batch_size, mut ops, tensors, fw_params } = self;
        let n_fw = ops.len() as u32;
        // successor lists over forward template
        let mut fw_succs: Vec<Vec<u32>> = vec![Vec::new(); n_fw as usize];
        for i in 0..n_fw {
            for &d in &ops[i as usize].deps {
                fw_succs[d as usize].push(i);
            }
        }
        // Backward op for forward op i gets id n_fw + (n_fw - 1 - i):
        // reverse program order so deps point backwards.
        let bw_id = |i: u32| n_fw + (n_fw - 1 - i);
        for i in (0..n_fw).rev() {
            let fw = ops[i as usize].clone();
            let mut deps: Vec<u32> = fw_succs[i as usize].iter().map(|&s| bw_id(s)).collect();
            deps.push(i); // activations from the forward op
            deps.sort();
            deps.dedup();
            ops.push(CompOpTpl {
                name: format!("BW.{}", fw.name.trim_start_matches("FW.")),
                kind: OpKind::Backward,
                flops: fw.flops * 1.8,
                bytes: fw.bytes * 1.9,
                eff: fw.eff,
                deps,
                produces: fw_params[i as usize].clone(),
                activation_bytes: 0.0,
                precision: fw.precision,
                fused_from: Vec::new(),
                mirror: Some(i),
            });
            ops[i as usize].mirror = Some(bw_id(i));
        }
        let g = ModelGraph { name, batch_size, ops, tensors };
        debug_assert_eq!(g.validate(), Ok(()));
        g
    }
}

/// Convolution FLOPs/traffic helper shared by the CNN generators.
pub(crate) struct ConvShape {
    pub flops: f64,
    pub bytes: f64,
    pub act_bytes: f64,
    pub weight_elems: f64,
    pub out_h: usize,
    pub out_w: usize,
}

pub(crate) fn conv2d(
    batch: f64,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
) -> ConvShape {
    let out_h = (h + stride - 1) / stride;
    let out_w = (w + stride - 1) / stride;
    let out_elems = batch * (out_h * out_w * cout) as f64;
    let in_elems = batch * (h * w * cin) as f64;
    let weight_elems = (k * k * cin * cout) as f64;
    ConvShape {
        flops: 2.0 * out_elems * (k * k * cin) as f64,
        bytes: 4.0 * (in_elems + out_elems + weight_elems),
        act_bytes: 4.0 * out_elems,
        weight_elems,
        out_h,
        out_w,
    }
}

/// Elementwise-op traffic (ReLU/add/BN): read+write of the activation.
pub(crate) fn elementwise_bytes(batch: f64, elems_per_sample: f64) -> f64 {
    2.0 * 4.0 * batch * elems_per_sample
}

/// Construct a model by name — the registry used by the CLI and benches.
pub fn by_name(name: &str, batch_size: usize) -> Option<ModelGraph> {
    match name {
        "resnet50" => Some(resnet::resnet50(batch_size)),
        "vgg16" => Some(vgg::vgg16(batch_size)),
        "inception_v3" => Some(inception::inception_v3(batch_size)),
        "bert_base" => Some(bert::bert_base(batch_size, 128)),
        "gpt_mini" => Some(transformer::gpt(transformer::GptConfig::mini(batch_size))),
        _ => None,
    }
}

/// The four paper benchmark models (excludes the live-path `gpt_mini`).
pub const ALL_MODELS: [&str; 4] = ["resnet50", "vgg16", "inception_v3", "bert_base"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_mirrors_backward() {
        let mut b = ModelBuilder::new("toy", 8);
        let c1 = b.op("conv1", &[], 1e9, 1e6, 1.0, 1e6, &[("w", 100.0)]);
        let r1 = b.op("relu1", &[c1], 0.0, 2e6, 1.0, 1e6, &[]);
        let _c2 = b.op("conv2", &[r1], 1e9, 1e6, 1.0, 1e6, &[("w", 200.0), ("b", 10.0)]);
        let g = b.finish();
        assert_eq!(g.ops.len(), 6);
        assert_eq!(g.tensors.len(), 3);
        assert_eq!(g.validate(), Ok(()));
        // BW.conv2 is first backward op and produces its two tensors.
        let bw2 = &g.ops[3];
        assert_eq!(bw2.name, "BW.conv2");
        assert_eq!(bw2.produces, vec![1, 2]);
        // BW.conv1 is the last op, depends on BW.relu1 (id 4) and FW.conv1.
        let bw1 = &g.ops[5];
        assert_eq!(bw1.name, "BW.conv1");
        assert!(bw1.deps.contains(&4));
        assert!(bw1.deps.contains(&0));
    }

    #[test]
    fn conv_shape_math() {
        let c = conv2d(1.0, 224, 224, 3, 64, 7, 2);
        assert_eq!((c.out_h, c.out_w), (112, 112));
        assert_eq!(c.weight_elems, (7 * 7 * 3 * 64) as f64);
        let expected_flops = 2.0 * (112.0 * 112.0 * 64.0) * (7.0 * 7.0 * 3.0);
        assert!((c.flops - expected_flops).abs() < 1.0);
    }
}
