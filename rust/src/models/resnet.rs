//! ResNet50 template (He et al. 2016): stem + 4 stages of bottleneck
//! blocks [3,4,6,3] + fc. Each conv is followed by separate BatchNorm and
//! ReLU ops (TF graph mode keeps them distinct — this is exactly the op
//! population the paper's op-fusion pass collapses). BatchNorm produces
//! *two* learnable tensors (γ, β) — the Coarsened-View example of Fig. 6.

use super::{conv2d, elementwise_bytes, ModelBuilder, ModelGraph};

/// GEMM/conv achieved-efficiency multipliers (V100, TF, fp32).
const CONV_EFF: f64 = 1.05;
const FC_EFF: f64 = 1.1;

struct Ctx {
    b: ModelBuilder,
    h: usize,
    w: usize,
    c: usize,
}

impl Ctx {
    /// conv + bn + relu, returns id of the relu op.
    fn cbr(&mut self, name: &str, deps: &[u32], cout: usize, k: usize, stride: usize) -> u32 {
        let conv = self.conv(name, deps, cout, k, stride);
        let bn = self.bn(&format!("{name}_bn"), conv, cout);
        self.relu(&format!("{name}_relu"), bn)
    }

    fn conv(&mut self, name: &str, deps: &[u32], cout: usize, k: usize, stride: usize) -> u32 {
        let batch = self.b.batch();
        let s = conv2d(batch, self.h, self.w, self.c, cout, k, stride);
        let id = self.b.op(
            name,
            deps,
            s.flops,
            s.bytes,
            CONV_EFF,
            s.act_bytes,
            &[("weight", s.weight_elems)],
        );
        self.h = s.out_h;
        self.w = s.out_w;
        self.c = cout;
        id
    }

    fn bn(&mut self, name: &str, dep: u32, ch: usize) -> u32 {
        let elems = (self.h * self.w * ch) as f64;
        let bytes = elementwise_bytes(self.b.batch(), elems) * 2.0; // stats + normalize
        let act = 4.0 * self.b.batch() * elems;
        self.b.op(name, &[dep], 0.0, bytes, 1.0, act, &[("gamma", ch as f64), ("beta", ch as f64)])
    }

    fn relu(&mut self, name: &str, dep: u32) -> u32 {
        let elems = (self.h * self.w * self.c) as f64;
        // ReLU output can be recomputed from BN cheaply; frameworks still
        // keep it — count a single activation copy.
        self.b.op(name, &[dep], 0.0, elementwise_bytes(self.b.batch(), elems), 1.0,
                  4.0 * self.b.batch() * elems, &[])
    }

    fn add(&mut self, name: &str, a: u32, b2: u32) -> u32 {
        let elems = (self.h * self.w * self.c) as f64;
        self.b.op(name, &[a, b2], 0.0, 1.5 * elementwise_bytes(self.b.batch(), elems), 1.0,
                  4.0 * self.b.batch() * elems, &[])
    }

    /// Bottleneck block: 1x1 reduce, 3x3, 1x1 expand (+ projection
    /// shortcut when shape changes), residual add, relu.
    fn bottleneck(&mut self, name: &str, input: u32, width: usize, stride: usize, project: bool) -> u32 {
        let (in_c, in_h, in_w) = (self.c, self.h, self.w);
        let a = self.cbr(&format!("{name}_conv1"), &[input], width, 1, 1);
        let b2 = self.cbr(&format!("{name}_conv2"), &[a], width, 3, stride);
        let c = self.conv(&format!("{name}_conv3"), &[b2], width * 4, 1, 1);
        let c_bn = self.bn(&format!("{name}_conv3_bn"), c, width * 4);
        let shortcut = if project {
            // projection path starts from the block input shape
            let (oh, ow, oc) = (self.h, self.w, self.c);
            self.h = in_h;
            self.w = in_w;
            self.c = in_c;
            let p = self.conv(&format!("{name}_proj"), &[input], width * 4, 1, stride);
            let p_bn = self.bn(&format!("{name}_proj_bn"), p, width * 4);
            debug_assert_eq!((self.h, self.w, self.c), (oh, ow, oc));
            p_bn
        } else {
            input
        };
        let add = self.add(&format!("{name}_add"), c_bn, shortcut);
        self.relu(&format!("{name}_relu"), add)
    }
}

/// Build the ResNet50 template at the given per-GPU batch size (input
/// 224×224×3, 1000 classes). ~25.5 M parameters in 161 tensors.
pub fn resnet50(batch_size: usize) -> ModelGraph {
    let mut ctx = Ctx { b: ModelBuilder::new("resnet50", batch_size), h: 224, w: 224, c: 3 };
    let stem = ctx.cbr("stem", &[], 64, 7, 2);
    // max pool /2: memory-bound, no params
    let pool_elems = (ctx.h / 2 * (ctx.w / 2) * ctx.c) as f64;
    let pool = ctx.b.op("stem_pool", &[stem], 0.0, elementwise_bytes(ctx.b.batch(), pool_elems), 1.0,
                        4.0 * ctx.b.batch() * pool_elems, &[]);
    ctx.h /= 2;
    ctx.w /= 2;

    let mut x = pool;
    let stages: [(usize, usize, usize); 4] =
        [(3, 64, 1), (4, 128, 2), (6, 256, 2), (3, 512, 2)];
    for (si, (blocks, width, stride)) in stages.iter().enumerate() {
        for bi in 0..*blocks {
            let s = if bi == 0 { *stride } else { 1 };
            let project = bi == 0;
            x = ctx.bottleneck(&format!("s{}b{}", si + 1, bi + 1), x, *width, s, project);
        }
    }

    // global average pool + fc
    let gap_elems = (ctx.h * ctx.w * ctx.c) as f64;
    let gap = ctx.b.op("gap", &[x], 0.0, 4.0 * ctx.b.batch() * gap_elems, 1.0,
                       4.0 * ctx.b.batch() * 2048.0, &[]);
    let fc_flops = 2.0 * ctx.b.batch() * 2048.0 * 1000.0;
    ctx.b.op("fc", &[gap], fc_flops, 4.0 * (2048.0 * 1000.0 + ctx.b.batch() * 3048.0), FC_EFF,
             4.0 * ctx.b.batch() * 1000.0, &[("weight", 2048.0 * 1000.0), ("bias", 1000.0)]);
    ctx.b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dfg::OpKind;
    use crate::models::cost::GpuModel;

    #[test]
    fn parameter_count_close_to_25m() {
        let g = resnet50(32);
        let params = g.num_params();
        assert!((24.0e6..27.5e6).contains(&params), "params={params}");
        // 53 convs * 1 + 53 bns * 2 + fc * 2 = 161 tensors
        assert_eq!(g.tensors.len(), 161, "tensors={}", g.tensors.len());
    }

    #[test]
    fn fw_time_near_paper_table2() {
        let g = resnet50(32);
        let gpu = GpuModel::default();
        let fw_ms = g.comp_time(&gpu, OpKind::Forward) / 1e3;
        let bw_ms = g.comp_time(&gpu, OpKind::Backward) / 1e3;
        // Paper Table 2: FW 34.78 ms, BW 71.34 ms (V100, TF, bs 32).
        assert!((25.0..50.0).contains(&fw_ms), "fw={fw_ms}ms");
        assert!((50.0..100.0).contains(&bw_ms), "bw={bw_ms}ms");
    }

    #[test]
    fn valid_dag_with_branches() {
        let g = resnet50(8);
        assert_eq!(g.validate(), Ok(()));
        // residual adds give some op two successors
        let mut succ_count = vec![0; g.ops.len()];
        for op in &g.ops {
            for &d in &op.deps {
                succ_count[d as usize] += 1;
            }
        }
        assert!(succ_count.iter().any(|&c| c >= 2));
    }

    #[test]
    fn batch_scales_flops_not_params() {
        let a = resnet50(16);
        let b = resnet50(32);
        assert_eq!(a.param_bytes(), b.param_bytes());
        let fa: f64 = a.ops.iter().map(|o| o.flops).sum();
        let fb: f64 = b.ops.iter().map(|o| o.flops).sum();
        assert!((fb / fa - 2.0).abs() < 0.01);
    }
}
