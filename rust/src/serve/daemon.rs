//! The serve daemon: TCP accept loop over a
//! [`crate::util::pool::FixedPool`], request routing, and the operational
//! endpoints. See `docs/SERVE.md` for the full endpoint + schema
//! reference; the short form:
//!
//! ```text
//! POST /jobs                  register a job: {"trace_dir": DIR} |
//!                             {"files": {name: contents}} | {"job": {...}}
//! GET  /jobs/:id/replay       snapshot replay payload
//! GET  /jobs/:id/diagnose     snapshot diagnosis payload
//! POST /jobs/:id/whatif       {"query": "nic-bw=2,..."} | {"queries": [...]}
//! POST /jobs/:id/optimize     {"budget_s": .., "strategies": "..", ...}
//! GET  /healthz               liveness
//! GET  /statsz                cache hit rate, sessions, queue depth, ...
//! GET  /metricsz              the same registry as Prometheus text
//! ```
//!
//! Status mapping (the CLI exit-code contract, lifted to HTTP): 200 ok —
//! including degraded-but-usable traces, whose warnings ride in the
//! `report` payload; 400 argument/body errors (exit-2 class); 422
//! unusable trace (exit-3 class); 404 unknown job/route; 405 wrong
//! method; 413 oversized body; 500 handler bug.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::diagnosis::parse_whatif;
use crate::obs::{Counter, Histogram, MetricsRegistry, SpanKind};
use crate::optimizer::{strategy, SearchOpts};
use crate::serve::http::{read_request, write_response, Request};
use crate::serve::session::Session;
use crate::serve::{fnv1a, ServeError, ServeOpts, SessionCache};
use crate::trace::io::{load_dir, load_mem, JobMeta};
use crate::util::json::{parse, Json};
use crate::util::pool::FixedPool;
use crate::util::Args;

/// Shared server state: the session cache plus one per-daemon
/// [`MetricsRegistry`] that every operational counter lives in —
/// `/statsz` (legacy JSON) and `/metricsz` (Prometheus text) are two
/// renderings of it. Per-daemon rather than process-global so the test
/// harness can run several in-process daemons without shared counters.
struct State {
    opts: ServeOpts,
    cache: SessionCache,
    /// Mirror of the pool's pending-jobs counter (the pool itself lives
    /// on the accept thread).
    queue_depth: Arc<AtomicUsize>,
    threads: usize,
    started: Instant,
    registry: MetricsRegistry,
    /// `dpro_requests_total` — resolved once, bumped per request.
    requests: Counter,
    /// `dpro_slow_queries_total` — requests over `--slow-query-us`.
    slow_queries: Counter,
    /// `dpro_conn_queue_wait_us` — accept → worker-pickup latency.
    conn_wait: Histogram,
}

/// A running daemon. Dropping the handle stops it; [`ServerHandle::wait`]
/// blocks until it stops on its own (the CLI foreground mode).
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (the actual port when `--addr` asked for 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain in-flight requests, and join.
    pub fn stop(mut self) {
        self.shutdown();
    }

    /// Block until the daemon exits (it doesn't on its own — this is the
    /// CLI's foreground serve loop; ^C ends the process).
    pub fn wait(mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }

    fn shutdown(&mut self) {
        if let Some(j) = self.join.take() {
            self.stop.store(true, Ordering::SeqCst);
            // the accept loop is blocked in accept(); a throwaway
            // connection wakes it to observe the stop flag
            let _ = TcpStream::connect(self.addr);
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Start the daemon: build preloaded sessions, bind, spawn the accept
/// loop. Preloading runs *before* bind so an unusable `--trace-dir`
/// fails startup (exit-3 class) instead of serving 422s forever.
pub fn start(opts: &ServeOpts) -> Result<ServerHandle, ServeError> {
    let pool = FixedPool::new(opts.threads);
    let registry = MetricsRegistry::new();
    let cache = SessionCache::with_metrics(
        opts.cache_bytes,
        registry.counter("dpro_cache_hits_total"),
        registry.counter("dpro_cache_misses_total"),
        registry.counter("dpro_cache_evictions_total"),
    );
    let requests = registry.counter("dpro_requests_total");
    let slow_queries = registry.counter("dpro_slow_queries_total");
    let conn_wait = registry.histogram("dpro_conn_queue_wait_us");
    let state = Arc::new(State {
        opts: opts.clone(),
        cache,
        queue_depth: pool.pending_handle(),
        threads: pool.threads(),
        started: Instant::now(),
        registry,
        requests,
        slow_queries,
        conn_wait,
    });
    for dir in &opts.preload {
        register_trace_dir(&state, dir)?;
    }
    let listener = TcpListener::bind(&opts.addr)
        .map_err(|e| ServeError::BadRequest(format!("cannot bind {}: {e}", opts.addr)))?;
    let addr = listener
        .local_addr()
        .map_err(|e| ServeError::Internal(format!("local_addr: {e}")))?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let state2 = Arc::clone(&state);
    let join = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = conn else { continue };
            // idle keep-alive connections release their worker after this
            let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
            let st = Arc::clone(&state2);
            let accepted = Instant::now();
            pool.execute(move || {
                // accept → pickup: how long the connection sat in the
                // pool queue behind other work
                st.conn_wait.observe_us(accepted.elapsed().as_secs_f64() * 1e6);
                serve_conn(stream, st)
            });
        }
        // `pool` drops here: queued + in-flight requests drain, then the
        // accept thread (and with it ServerHandle::wait/stop) returns
    });
    Ok(ServerHandle { addr, stop, join: Some(join) })
}

/// One connection: serve keep-alive requests until the peer closes, goes
/// idle past the read timeout, or a protocol error ends the conversation.
fn serve_conn(stream: TcpStream, state: Arc<State>) {
    let mut reader = BufReader::new(stream);
    loop {
        match read_request(&mut reader) {
            Ok(None) => break,
            Err((status, msg)) => {
                let _ = write_response(reader.get_mut(), status, &err_body(&msg), false);
                break;
            }
            Ok(Some(req)) => {
                state.requests.inc();
                let pattern = route_pattern(&req.path);
                let span_guard = crate::obs::span("serve.request", SpanKind::Work);
                let t0 = Instant::now();
                // a handler bug answers 500 and keeps the worker alive
                let (status, body) =
                    match catch_unwind(AssertUnwindSafe(|| route(&state, &req))) {
                        Ok(r) => r,
                        Err(_) => (500, err_body("handler panicked")),
                    };
                let lat_us = t0.elapsed().as_secs_f64() * 1e6;
                drop(span_guard);
                state
                    .registry
                    .histogram_with("dpro_request_latency_us", &[("route", pattern)])
                    .observe_us(lat_us);
                state
                    .registry
                    .counter_with(
                        "dpro_responses_total",
                        &[("route", pattern), ("status", status_label(status))],
                    )
                    .inc();
                state.registry.counter("dpro_response_bytes_total").add(body.len() as u64);
                let slow = state.opts.slow_query_us;
                if slow > 0 && lat_us > slow as f64 {
                    state.slow_queries.inc();
                    eprintln!(
                        "slow-query: {} {} -> {status} took {:.0}us (threshold {slow}us, {}B)",
                        req.method,
                        req.path,
                        lat_us,
                        body.len(),
                    );
                }
                let ok = write_response(reader.get_mut(), status, &body, req.keep_alive);
                if ok.is_err() || !req.keep_alive {
                    break;
                }
            }
        }
    }
}

/// `{"error": msg}`.
fn err_body(msg: &str) -> String {
    let mut j = Json::obj();
    j.set("error", Json::Str(msg.to_string()));
    j.to_string()
}

/// Normalized route label for metrics — path parameters collapsed to
/// `:id` so label cardinality stays bounded no matter how many jobs the
/// daemon has seen.
fn route_pattern(path: &str) -> &'static str {
    let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
    match segs.as_slice() {
        ["healthz"] => "/healthz",
        ["statsz"] => "/statsz",
        ["metricsz"] => "/metricsz",
        ["jobs"] => "/jobs",
        ["jobs", _, "replay"] => "/jobs/:id/replay",
        ["jobs", _, "diagnose"] => "/jobs/:id/diagnose",
        ["jobs", _, "whatif"] => "/jobs/:id/whatif",
        ["jobs", _, "optimize"] => "/jobs/:id/optimize",
        _ => "other",
    }
}

/// Static status label (the daemon emits a closed set of statuses).
fn status_label(status: u16) -> &'static str {
    match status {
        200 => "200",
        400 => "400",
        404 => "404",
        405 => "405",
        413 => "413",
        422 => "422",
        500 => "500",
        _ => "other",
    }
}

fn route(state: &Arc<State>, req: &Request) -> (u16, String) {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    let result = match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => Ok((200, healthz())),
        ("GET", ["statsz"]) => Ok((200, statsz(state))),
        ("GET", ["metricsz"]) => Ok((200, metricsz(state))),
        ("POST", ["jobs"]) => post_jobs(state, &req.body),
        ("GET", ["jobs", id, "replay"]) => read_snapshot(state, id, true),
        ("GET", ["jobs", id, "diagnose"]) => read_snapshot(state, id, false),
        ("POST", ["jobs", id, "whatif"]) => post_whatif(state, id, &req.body),
        ("POST", ["jobs", id, "optimize"]) => post_optimize(state, id, &req.body),
        (_, ["healthz" | "statsz" | "metricsz"])
        | (_, ["jobs"])
        | (_, ["jobs", _, "replay" | "diagnose" | "whatif" | "optimize"]) => {
            Ok((405, err_body(&format!("{} not allowed on {}", req.method, req.path))))
        }
        _ => Ok((404, err_body(&format!("no route for {} {}", req.method, req.path)))),
    };
    result.unwrap_or_else(|e| (e.http_status(), err_body(e.message())))
}

fn healthz() -> String {
    let mut j = Json::obj();
    j.set("status", Json::Str("ok".into()));
    j.set("version", Json::Str(crate::version().to_string()));
    j.to_string()
}

fn statsz(state: &Arc<State>) -> String {
    let cs = state.cache.stats();
    let mut cache = Json::obj();
    cache.set("hits", Json::Num(cs.hits as f64));
    cache.set("misses", Json::Num(cs.misses as f64));
    cache.set("hit_rate", Json::Num(cs.hit_rate()));
    cache.set("evictions", Json::Num(cs.evictions as f64));
    cache.set("bytes", Json::Num(cs.bytes as f64));
    cache.set("cap_bytes", Json::Num(cs.cap_bytes as f64));
    cache.set("sessions", Json::Num(cs.sessions as f64));

    let (mut batches, mut coalesced) = (0u64, 0u64);
    let mut sessions = Vec::new();
    for (id, bytes, served) in state.cache.sessions() {
        // peek, not lookup: assembling the report must not inflate the
        // hit counters it is reporting
        if let Some(sess) = state.cache.peek(&id) {
            let (b, c) = sess.batch_stats();
            batches += b;
            coalesced += c;
        }
        let mut row = Json::obj();
        row.set("job", Json::Str(id));
        row.set("bytes", Json::Num(bytes as f64));
        row.set("whatif_served", Json::Num(served as f64));
        sessions.push(row);
    }
    let mut batch = Json::obj();
    batch.set("batches", Json::Num(batches as f64));
    batch.set("coalesced", Json::Num(coalesced as f64));

    let mut j = Json::obj();
    j.set("version", Json::Str(crate::version().to_string()));
    j.set("uptime_s", Json::Num(state.started.elapsed().as_secs_f64()));
    j.set("cache", cache);
    j.set("batch", batch);
    j.set("sessions", Json::Arr(sessions));
    j.set("queue_depth", Json::Num(state.queue_depth.load(Ordering::Relaxed) as f64));
    j.set("threads", Json::Num(state.threads as f64));
    j.set("requests", Json::Num(state.requests.get() as f64));
    j.to_string()
}

/// `GET /metricsz`: the registry as Prometheus text exposition. Gauges
/// that mirror live structures (cache occupancy, queue depth, uptime)
/// are refreshed at scrape time; counters and histograms are the same
/// atomics `/statsz` reads, so the two views cannot drift.
fn metricsz(state: &Arc<State>) -> String {
    let cs = state.cache.stats();
    state.registry.gauge("dpro_cache_bytes").set(cs.bytes as u64);
    state.registry.gauge("dpro_cache_cap_bytes").set(cs.cap_bytes as u64);
    state.registry.gauge("dpro_sessions").set(cs.sessions as u64);
    state
        .registry
        .gauge("dpro_queue_depth")
        .set(state.queue_depth.load(Ordering::Relaxed) as u64);
    state.registry.gauge("dpro_threads").set(state.threads as u64);
    state
        .registry
        .gauge("dpro_uptime_seconds")
        .set(state.started.elapsed().as_secs());
    state.registry.render_prometheus()
}

/// The `POST /jobs` response.
fn registered(sess: &Session, cached: bool) -> (u16, String) {
    let snap = sess.snapshot();
    let mut j = Json::obj();
    j.set("job", Json::Str(sess.id().to_string()));
    j.set("cached", Json::Bool(cached));
    j.set("snapshot", Json::Num(snap.version as f64));
    j.set("iteration_us", Json::Num(snap.iteration_us));
    (200, j.to_string())
}

fn post_jobs(state: &Arc<State>, body: &str) -> Result<(u16, String), ServeError> {
    let j = parse(body)
        .map_err(|e| ServeError::BadRequest(format!("invalid JSON body: {e}")))?;
    if let Some(dir) = j.get("trace_dir") {
        let dir = dir
            .as_str()
            .ok_or_else(|| ServeError::BadRequest("trace_dir must be a string".into()))?;
        let (sess, cached) = register_trace_dir(state, dir)?;
        return Ok(registered(&sess, cached));
    }
    if let Some(files) = j.get("files") {
        let Json::Obj(map) = files else {
            return Err(ServeError::BadRequest(
                "files must be an object of {name: contents}".into(),
            ));
        };
        // contents may be the file text or the JSON value itself (both
        // end up as the bytes load_mem ingests)
        let files: Vec<(String, String)> = map
            .iter()
            .map(|(name, v)| {
                let text = match v {
                    Json::Str(s) => s.clone(),
                    other => other.to_string(),
                };
                (name.clone(), text)
            })
            .collect();
        let loaded = load_mem(&files).map_err(ServeError::UnusableTrace)?;
        if loaded.trace.events.is_empty() {
            return Err(ServeError::UnusableTrace(format!(
                "no usable events in upload: {}",
                loaded.report
            )));
        }
        let spec = resolve_spec(j.get("job"), loaded.job.as_ref())?;
        // trace identity = content hash, so the same dump uploaded twice
        // is one session (the smoke test's cache hit)
        let mut acc: u64 = 0xcbf2_9ce4_8422_2325;
        for (name, text) in &files {
            acc ^= fnv1a(name.bytes().chain([0u8]).chain(text.bytes()));
        }
        let tag = format!("u{acc:016x}");
        let (sess, cached) =
            insert_session(state, spec, Some((loaded.trace, loaded.report)), &tag)?;
        return Ok(registered(&sess, cached));
    }
    if j.get("job").is_some() {
        // analytic session: the cost model supplies durations (the
        // pre-deployment workflow — same as `dpro diagnose` with no trace)
        let spec = resolve_spec(j.get("job"), None)?;
        let (sess, cached) = insert_session(state, spec, None, "analytic")?;
        return Ok(registered(&sess, cached));
    }
    Err(ServeError::BadRequest(
        "body must contain one of: trace_dir, files, job".into(),
    ))
}

fn read_snapshot(
    state: &Arc<State>,
    id: &str,
    replay: bool,
) -> Result<(u16, String), ServeError> {
    match state.cache.lookup(id) {
        None => Ok((404, err_body(&format!("unknown job {id:?}; POST /jobs first")))),
        Some(sess) => {
            let snap = sess.snapshot();
            Ok((200, if replay { snap.replay.clone() } else { snap.diagnose.clone() }))
        }
    }
}

fn post_whatif(state: &Arc<State>, id: &str, body: &str) -> Result<(u16, String), ServeError> {
    let Some(sess) = state.cache.lookup(id) else {
        return Ok((404, err_body(&format!("unknown job {id:?}; POST /jobs first"))));
    };
    let j = parse(body)
        .map_err(|e| ServeError::BadRequest(format!("invalid JSON body: {e}")))?;
    let text = if let Some(q) = j.get("query") {
        q.as_str()
            .ok_or_else(|| ServeError::BadRequest("query must be a string".into()))?
            .to_string()
    } else if let Some(arr) = j.get("queries").and_then(Json::as_arr) {
        let parts: Result<Vec<&str>, ServeError> = arr
            .iter()
            .map(|q| {
                q.as_str()
                    .ok_or_else(|| ServeError::BadRequest("queries must be strings".into()))
            })
            .collect();
        parts?.join(",")
    } else {
        return Err(ServeError::BadRequest(
            "body must contain query or queries".into(),
        ));
    };
    let queries = parse_whatif(&text).map_err(ServeError::BadRequest)?;
    let (payload, _coalesced) = sess.whatif(&queries);
    payload.map(|p| (200, p)).map_err(ServeError::Internal)
}

fn post_optimize(state: &Arc<State>, id: &str, body: &str) -> Result<(u16, String), ServeError> {
    let Some(sess) = state.cache.lookup(id) else {
        return Ok((404, err_body(&format!("unknown job {id:?}; POST /jobs first"))));
    };
    let j = if body.trim().is_empty() {
        Json::obj()
    } else {
        parse(body).map_err(|e| ServeError::BadRequest(format!("invalid JSON body: {e}")))?
    };
    let Json::Obj(map) = &j else {
        return Err(ServeError::BadRequest("body must be an object".into()));
    };
    // resident graphs skip coarsened-view setup (it would force a
    // rebuild); everything else mirrors `dpro optimize` flag validation
    let mut opts = SearchOpts { use_coarsened_view: false, ..SearchOpts::default() };
    for (k, v) in map {
        match k.as_str() {
            "budget_s" => match v.as_f64() {
                Some(x) if x > 0.0 => opts.budget_wall_s = x,
                _ => {
                    return Err(ServeError::BadRequest(
                        "budget_s must be a positive number".into(),
                    ))
                }
            },
            "max_rounds" => match v.as_f64() {
                Some(x) if x >= 1.0 && x.fract() == 0.0 => opts.max_rounds = x as usize,
                _ => {
                    return Err(ServeError::BadRequest(
                        "max_rounds must be a positive integer".into(),
                    ))
                }
            },
            "memory_budget_gb" => match v.as_f64() {
                Some(g) if g > 0.0 => opts.memory_budget_bytes = Some(g * 1e9),
                _ => {
                    return Err(ServeError::BadRequest(
                        "memory_budget_gb must be a positive number".into(),
                    ))
                }
            },
            "strategies" => {
                let list = v.as_str().ok_or_else(|| {
                    ServeError::BadRequest("strategies must be a string".into())
                })?;
                strategy::parse_strategies(list).map_err(ServeError::BadRequest)?;
                opts.strategies = Some(list.to_string());
            }
            other => {
                return Err(ServeError::BadRequest(format!(
                    "unknown optimize field {other:?}; valid: budget_s, max_rounds, \
                     memory_budget_gb, strategies"
                )))
            }
        }
    }
    Ok((200, sess.optimize(&opts)))
}

/// Resolve the job spec from the request's optional `job` object layered
/// over the dump's metadata — through the *same* code path as the CLI
/// ([`crate::cli::job_from_args_with`]), so a bad value gets the
/// identical message over HTTP (400) and on the command line (exit 2).
fn resolve_spec(job: Option<&Json>, meta: Option<&JobMeta>) -> Result<crate::config::JobSpec, ServeError> {
    let args = match job {
        Some(j) => args_from_job_json(j)?,
        None => Args::default(),
    };
    crate::cli::job_from_args_with(&args, meta).map_err(ServeError::BadRequest)
}

/// Map a `job` JSON object onto the CLI's argument surface.
fn args_from_job_json(j: &Json) -> Result<Args, ServeError> {
    let Json::Obj(map) = j else {
        return Err(ServeError::BadRequest("job must be an object".into()));
    };
    let mut a = Args::default();
    for (k, v) in map {
        match k.as_str() {
            "model" | "scheme" | "transport" => {
                let s = v.as_str().ok_or_else(|| {
                    ServeError::BadRequest(format!("job.{k} must be a string"))
                })?;
                a.options.insert(k.clone(), s.to_string());
            }
            "workers" => {
                // integral numbers pass through; anything else reaches the
                // CLI validator verbatim and gets its exit-2-class message
                let s = match v {
                    Json::Num(x) if x.fract() == 0.0 && *x >= 0.0 => format!("{}", *x as u64),
                    Json::Num(x) => format!("{x}"),
                    Json::Str(s) => s.clone(),
                    _ => {
                        return Err(ServeError::BadRequest(
                            "job.workers must be a positive integer".into(),
                        ))
                    }
                };
                a.options.insert("workers".into(), s);
            }
            "plan" => match v.as_str() {
                Some("per-tensor") => a.flags.push("per-tensor".into()),
                Some("deployed") => a.flags.push("deployed".into()),
                _ => {
                    return Err(ServeError::BadRequest(
                        "job.plan must be \"per-tensor\" or \"deployed\"".into(),
                    ))
                }
            },
            other => {
                return Err(ServeError::BadRequest(format!(
                    "unknown job field {other:?}; valid: model, scheme, transport, \
                     workers, plan"
                )))
            }
        }
    }
    Ok(a)
}

/// Session key: job descriptor + plan family + trace identity, hashed
/// into a URL-safe id. Same descriptor + same trace ⇒ same session.
fn session_id(spec: &crate::config::JobSpec, trace_tag: &str) -> String {
    let m = JobMeta::of(spec);
    let desc = format!(
        "{}|{}|{}|{}|{}|{}|{trace_tag}",
        m.model, m.scheme, m.transport, m.n_workers, m.gpus_per_machine, m.plan
    );
    format!("j{:016x}", fnv1a(desc.bytes()))
}

fn insert_session(
    state: &Arc<State>,
    spec: crate::config::JobSpec,
    trace: Option<(crate::trace::GTrace, crate::trace::validate::TraceReport)>,
    trace_tag: &str,
) -> Result<(Arc<Session>, bool), ServeError> {
    let id = session_id(&spec, trace_tag);
    state.cache.get_or_build(&id, || {
        Ok(Session::build(&id, spec, trace, state.opts.top, state.opts.batch_window_ms)
            .with_metrics(
                state.registry.histogram("dpro_engine_lock_wait_us"),
                state.registry.histogram("dpro_serialize_us"),
            ))
    })
}

/// Register a trace directory (`--trace-dir` preload and the
/// `{"trace_dir": ...}` upload form). The cache key fingerprints the
/// canonical path plus every trace file's (name, size, mtime), so
/// re-registering an edited dump builds a fresh session while an
/// untouched one hits.
fn register_trace_dir(
    state: &Arc<State>,
    dir: &str,
) -> Result<(Arc<Session>, bool), ServeError> {
    let canon = std::fs::canonicalize(dir)
        .map_err(|e| ServeError::UnusableTrace(format!("cannot read trace dir {dir}: {e}")))?;
    let mut fingerprint = canon.to_string_lossy().into_owned().into_bytes();
    if let Ok(rd) = std::fs::read_dir(&canon) {
        let mut entries: Vec<String> = rd
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                if !name.ends_with(".json") {
                    return None;
                }
                let md = e.metadata().ok()?;
                let mtime = md
                    .modified()
                    .ok()
                    .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
                    .map(|d| d.as_nanos())
                    .unwrap_or(0);
                Some(format!("{name}:{}:{mtime}", md.len()))
            })
            .collect();
        entries.sort();
        for e in entries {
            fingerprint.extend(e.into_bytes());
        }
    }
    let id = format!("d{:016x}", fnv1a(fingerprint));
    state.cache.get_or_build(&id, || {
        let loaded = load_dir(&canon).map_err(ServeError::UnusableTrace)?;
        if loaded.trace.events.is_empty() {
            return Err(ServeError::UnusableTrace(format!(
                "no usable events in {}: {}",
                canon.display(),
                loaded.report
            )));
        }
        let spec = crate::cli::job_from_args_with(&Args::default(), loaded.job.as_ref())
            .map_err(ServeError::BadRequest)?;
        Ok(Session::build(
            &id,
            spec,
            Some((loaded.trace, loaded.report)),
            state.opts.top,
            state.opts.batch_window_ms,
        )
        .with_metrics(
            state.registry.histogram("dpro_engine_lock_wait_us"),
            state.registry.histogram("dpro_serialize_us"),
        ))
    })
}
