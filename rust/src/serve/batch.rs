//! What-if request batching: identical queries against the same snapshot
//! that arrive within a window run **once** — the first arrival becomes
//! the leader, waits out the window so stragglers can join, evaluates
//! under the engine lock, and fans the payload out to every waiter.
//!
//! The batch key includes the snapshot version (see
//! [`crate::serve::session::Session::whatif`]), so a query batched before
//! an optimizer commit never serves a waiter who arrived after it: the
//! post-commit arrival keys to the new version and starts a fresh batch.
//! Payloads are shared verbatim — every waiter gets the byte-identical
//! answer, which is what makes coalescing invisible to the reader
//! bit-for-bit property (`rust/tests/serve.rs`).

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// One in-flight batch: the leader publishes into `done` and broadcasts.
struct Slot {
    done: Mutex<Option<Result<String, String>>>,
    cv: Condvar,
}

/// Coalesces identical evaluations by key. One `Batcher` per session.
pub struct Batcher {
    window: Duration,
    slots: Mutex<HashMap<String, Arc<Slot>>>,
    /// Leader evaluations performed.
    batches: AtomicU64,
    /// Waiters served from another request's evaluation.
    coalesced: AtomicU64,
}

impl Batcher {
    /// Batcher with the given coalescing window; 0 still coalesces
    /// queries that overlap in flight, it just never waits for them.
    pub fn new(window_ms: u64) -> Batcher {
        Batcher {
            window: Duration::from_millis(window_ms),
            slots: Mutex::new(HashMap::new()),
            batches: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
        }
    }

    /// Run `eval` for `key`, or wait for the identical in-flight run.
    /// Returns the (shared) payload and whether this call coalesced onto
    /// another's evaluation.
    pub fn run(
        &self,
        key: &str,
        eval: impl FnOnce() -> Result<String, String>,
    ) -> (Result<String, String>, bool) {
        let (slot, leader) = {
            let mut slots = lock(&self.slots);
            match slots.get(key) {
                Some(s) => (Arc::clone(s), false),
                None => {
                    let s = Arc::new(Slot { done: Mutex::new(None), cv: Condvar::new() });
                    slots.insert(key.to_string(), Arc::clone(&s));
                    (s, true)
                }
            }
        };
        if leader {
            if !self.window.is_zero() {
                std::thread::sleep(self.window);
            }
            // a panicking evaluation must not strand the waiters
            let result = match catch_unwind(AssertUnwindSafe(eval)) {
                Ok(r) => r,
                Err(_) => Err("internal error: evaluation panicked".to_string()),
            };
            // unregister BEFORE publishing: requests arriving from here on
            // start a fresh batch instead of receiving a stale payload
            lock(&self.slots).remove(key);
            *lock(&slot.done) = Some(result.clone());
            slot.cv.notify_all();
            self.batches.fetch_add(1, Ordering::Relaxed);
            (result, false)
        } else {
            let mut done = lock(&slot.done);
            while done.is_none() {
                done = slot.cv.wait(done).unwrap_or_else(|p| p.into_inner());
            }
            self.coalesced.fetch_add(1, Ordering::Relaxed);
            (done.clone().expect("published above"), true)
        }
    }

    /// `(leader evaluations, coalesced waiters)` so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.batches.load(Ordering::Relaxed), self.coalesced.load(Ordering::Relaxed))
    }
}

/// Lock that tolerates poisoning: the protected state is only ever
/// written in a published-complete form, so a panicked peer cannot leave
/// it half-updated.
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn concurrent_identical_queries_coalesce_to_one_eval() {
        let b = Arc::new(Batcher::new(30));
        let evals = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let b = Arc::clone(&b);
            let evals = Arc::clone(&evals);
            handles.push(std::thread::spawn(move || {
                b.run("k", || {
                    evals.fetch_add(1, Ordering::Relaxed);
                    Ok("payload".to_string())
                })
            }));
        }
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // all 8 got the identical payload from however many leaders the
        // 30 ms window produced (typically exactly one)
        for (r, _) in &results {
            assert_eq!(r.as_deref(), Ok("payload"));
        }
        let leaders = evals.load(Ordering::Relaxed);
        assert!(leaders >= 1);
        let coalesced = results.iter().filter(|(_, c)| *c).count();
        assert_eq!(coalesced, 8 - leaders, "every non-leader coalesced");
        assert!(coalesced >= 1, "30ms window should have coalesced something");
        assert_eq!(b.stats().1, coalesced as u64);
    }

    #[test]
    fn different_keys_do_not_coalesce() {
        let b = Batcher::new(0);
        let (r1, c1) = b.run("a", || Ok("1".into()));
        let (r2, c2) = b.run("b", || Ok("2".into()));
        assert_eq!((r1.unwrap().as_str(), c1), ("1", false));
        assert_eq!((r2.unwrap().as_str(), c2), ("2", false));
    }

    #[test]
    fn panicking_leader_releases_waiters_with_an_error() {
        let b = Arc::new(Batcher::new(20));
        let b2 = Arc::clone(&b);
        let waiter = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                // give the leader time to register its slot
                std::thread::sleep(Duration::from_millis(5));
                b.run("k", || Ok("never the leader's payload".into()))
            })
        };
        let (lead, _) = b2.run("k", || panic!("evaluation bug"));
        assert!(lead.is_err());
        let (got, _) = waiter.join().unwrap();
        // the waiter either coalesced onto the panicked leader (error) or
        // raced past the removal and evaluated fresh (ok) — never hangs
        match got {
            Ok(s) => assert_eq!(s, "never the leader's payload"),
            Err(e) => assert!(e.contains("panicked")),
        }
    }
}
