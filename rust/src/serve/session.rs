//! One resident diagnosis session: a built graph + incremental engine
//! (inside a [`Diagnoser`]) shared by many readers through published
//! immutable [`Snapshot`]s, with `optimize` as the single-writer path.
//!
//! # Isolation model
//!
//! Reads (`replay`, `diagnose`) never touch the engine: they clone an
//! `Arc<Snapshot>` whose payloads were serialized at publish time, so a
//! reader's answer is decided entirely by *which* snapshot it picked up —
//! there is no window where a half-applied strategy is visible. What-if
//! queries do borrow the engine (they replay), but each query is a
//! begin → edit → replay → rollback transaction that restores the graph
//! bit-exactly, and the engine mutex serializes them against the writer.
//! The writer (`optimize`) commits accepted strategies through the
//! transaction journal and publishes a new snapshot **while still holding
//! the engine lock**; rejected candidates roll back and no snapshot is
//! published, so a search that accepts nothing is invisible to every
//! reader — the property `rust/tests/serve.rs` pins bit-for-bit.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock};
use std::time::Instant;

use crate::config::JobSpec;
use crate::diagnosis::{Diagnoser, WhatIfQuery};
use crate::graph::dfg::OpKind;
use crate::obs::Histogram;
use crate::optimizer::strategy::Strategy;
use crate::optimizer::SearchOpts;
use crate::serve::batch::Batcher;
use crate::trace::validate::TraceReport;
use crate::trace::GTrace;
use crate::util::json::Json;

/// An immutable published view of one session: the replay and diagnose
/// payloads, serialized once so concurrent readers share bytes instead of
/// re-running analytics. Readers compare equal iff they read the same
/// snapshot — the unit of isolation.
pub struct Snapshot {
    /// Monotonic per-session version; bumped only by optimizer commits.
    pub version: u64,
    /// Baseline replayed iteration time (us) of this snapshot.
    pub iteration_us: f64,
    /// The `GET /jobs/:id/replay` body (docs/SERVE.md schema).
    pub replay: String,
    /// The `GET /jobs/:id/diagnose` body (`docs/DIAGNOSIS.md` schema plus
    /// `job` and `snapshot` keys).
    pub diagnose: String,
}

/// A cached, resident diagnosis session (see module docs).
pub struct Session {
    id: String,
    engine: Mutex<Diagnoser>,
    snap: RwLock<Arc<Snapshot>>,
    batcher: Batcher,
    /// Approximate resident size, fixed at build time (cache accounting).
    bytes: usize,
    top: usize,
    whatif_served: AtomicU64,
    /// Time spent waiting for the engine mutex (what-if + optimize) —
    /// detached unless the daemon attached registry handles
    /// ([`Session::with_metrics`]).
    lock_wait: Histogram,
    /// Time spent serializing published snapshots.
    serialize: Histogram,
}

impl Session {
    /// Build a session: construct the graph (from the trace when given,
    /// analytic otherwise), replay the baseline, and publish snapshot 0.
    /// This is the expensive step the cache amortizes.
    pub fn build(
        id: &str,
        spec: JobSpec,
        trace: Option<(GTrace, TraceReport)>,
        top: usize,
        batch_window_ms: u64,
    ) -> Session {
        let mut d = match trace {
            Some((t, r)) => Diagnoser::from_trace(spec, &t, r),
            None => Diagnoser::new(spec),
        };
        let snap = publish(&mut d, id, 0, top);
        let bytes = approx_bytes(&d, &snap);
        Session {
            id: id.to_string(),
            engine: Mutex::new(d),
            snap: RwLock::new(Arc::new(snap)),
            batcher: Batcher::new(batch_window_ms),
            bytes,
            top,
            whatif_served: AtomicU64::new(0),
            lock_wait: Histogram::new(),
            serialize: Histogram::new(),
        }
    }

    /// Attach registry-backed phase histograms (engine-lock wait,
    /// snapshot serialization) in place of the detached defaults —
    /// builder-style so [`Session::build`]'s signature (used directly by
    /// tests and benches) stays put.
    pub fn with_metrics(mut self, lock_wait: Histogram, serialize: Histogram) -> Session {
        self.lock_wait = lock_wait;
        self.serialize = serialize;
        self
    }

    /// The session id (also its cache key and URL segment).
    pub fn id(&self) -> &str {
        &self.id
    }

    /// Approximate resident bytes (graph arena + published payloads).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// What-if queries served (coalesced waiters included).
    pub fn whatif_served(&self) -> u64 {
        self.whatif_served.load(Ordering::Relaxed)
    }

    /// The current published snapshot. Cheap: one `RwLock` read + `Arc`
    /// clone, never blocked by in-flight what-ifs or rejected searches.
    pub fn snapshot(&self) -> Arc<Snapshot> {
        Arc::clone(&self.snap.read().unwrap_or_else(|p| p.into_inner()))
    }

    /// Answer a what-if battery. Identical batteries against the same
    /// snapshot version coalesce into one transactional evaluation (see
    /// [`crate::serve::batch`]); the canonical key is the `Display` form
    /// of the parsed queries, so textual variants of the same query list
    /// batch together. Returns the payload and whether this call
    /// coalesced onto another request's evaluation.
    pub fn whatif(&self, queries: &[WhatIfQuery]) -> (Result<String, String>, bool) {
        let canonical: Vec<String> = queries.iter().map(|q| q.to_string()).collect();
        let key = format!("{}:{}", self.snapshot().version, canonical.join(","));
        let out = self.batcher.run(&key, || {
            let _span = crate::obs::span("serve.whatif", crate::obs::SpanKind::Work);
            let mut eng = self.lock_engine();
            // re-read under the engine lock: commits republish while
            // holding it, so the version cannot move during evaluation
            // and the payload's snapshot tag matches the baseline the
            // answers were replayed against
            let version = self.snapshot().version;
            let answers: Vec<Json> = queries.iter().map(|q| eng.what_if(q).to_json()).collect();
            let mut j = Json::obj();
            j.set("job", Json::Str(self.id.clone()));
            j.set("snapshot", Json::Num(version as f64));
            j.set("baseline_us", Json::Num(eng.baseline_us()));
            j.set("answers", Json::Arr(answers));
            Ok(j.to_string())
        });
        self.whatif_served.fetch_add(1, Ordering::Relaxed);
        out
    }

    /// Leader-evaluation / coalesced-waiter counts of this session's
    /// batcher.
    pub fn batch_stats(&self) -> (u64, u64) {
        self.batcher.stats()
    }

    /// Run the transactional optimizer on the resident graph — the
    /// single-writer path. See [`Session::optimize_with`].
    pub fn optimize(&self, opts: &SearchOpts) -> String {
        self.optimize_with(opts, crate::optimizer::strategy::strategies_from_opts(opts))
    }

    /// [`Session::optimize`] with an explicit strategy set. Accepted
    /// decisions commit and publish a new snapshot (version + 1) before
    /// the engine lock drops; a search that accepts nothing publishes
    /// nothing — concurrent readers cannot observe it.
    pub fn optimize_with(
        &self,
        opts: &SearchOpts,
        strategies: Vec<Box<dyn Strategy>>,
    ) -> String {
        let _span = crate::obs::span("serve.optimize", crate::obs::SpanKind::Work);
        let mut eng = self.lock_engine();
        let out = eng.optimize_with(opts, strategies);
        let committed = !out.accepted.is_empty();
        let mut j = out.to_json();
        if committed {
            let version = self.snapshot().version + 1;
            let snap = {
                let _ser = crate::obs::span("serve.serialize", crate::obs::SpanKind::Write);
                let t0 = Instant::now();
                let snap = publish(&mut eng, &self.id, version, self.top);
                self.serialize.observe_us(t0.elapsed().as_secs_f64() * 1e6);
                snap
            };
            *self.snap.write().unwrap_or_else(|p| p.into_inner()) = Arc::new(snap);
        }
        j.set("job", Json::Str(self.id.clone()));
        j.set("committed", Json::Bool(committed));
        j.set("snapshot", Json::Num(self.snapshot().version as f64));
        j.to_string()
    }

    /// Acquire the engine mutex, measuring the wait into the session's
    /// `lock_wait` histogram (and a `serve.lock_wait` span when tracing).
    fn lock_engine(&self) -> MutexGuard<'_, Diagnoser> {
        let _wait = crate::obs::span("serve.lock_wait", crate::obs::SpanKind::Wait);
        let t0 = Instant::now();
        let guard = lock(&self.engine);
        self.lock_wait.observe_us(t0.elapsed().as_secs_f64() * 1e6);
        guard
    }
}

/// Serialize both read payloads from the diagnoser's current baseline.
/// Runs the auto what-if battery (transactional — the graph is restored),
/// so publishing is the slow path; readers only ever clone the result.
fn publish(d: &mut Diagnoser, id: &str, version: u64, top: usize) -> Snapshot {
    let qs = d.auto_queries();
    let mut dj = d.report(&qs, top).to_json();
    dj.set("job", Json::Str(id.to_string()));
    dj.set("snapshot", Json::Num(version as f64));
    let diagnose = dj.to_string();

    // replay payload: the `dpro replay --json` schema keys that exist for
    // a resident graph, plus session identity (docs/SERVE.md)
    let dfg = d.mg().dfg();
    let alive = d.mg().alive();
    let base = d.baseline();
    let (mut fw, mut bw) = (0.0f64, 0.0f64);
    for i in dfg.ids() {
        let n = dfg.node(i);
        if !alive[i as usize] || n.owner != 0 || n.proc != 0 {
            continue;
        }
        let busy = base.end[i as usize] - base.start[i as usize];
        match n.kind {
            OpKind::Forward => fw += busy,
            OpKind::Backward => bw += busy,
            _ => {}
        }
    }
    let spec = d.spec();
    let mut rj = Json::obj();
    rj.set("job", Json::Str(id.to_string()));
    rj.set("snapshot", Json::Num(version as f64));
    rj.set("model", Json::Str(spec.model.name.clone()));
    rj.set("scheme", Json::Str(spec.scheme.cli_name().to_string()));
    rj.set("transport", Json::Str(spec.cluster.network.transport.name().to_lowercase()));
    rj.set("workers", Json::Num(spec.cluster.n_workers as f64));
    rj.set("ops", Json::Num(dfg.len() as f64));
    rj.set("alive_ops", Json::Num(alive.iter().filter(|a| **a).count() as f64));
    rj.set("iteration_us", Json::Num(base.iteration_time));
    rj.set("fw_us", Json::Num(fw));
    rj.set("bw_us", Json::Num(bw));
    rj.set(
        "est_peak_mem_bytes",
        Json::Num(crate::replay::estimate_peak_memory_mut(d.mg(), &base.end)),
    );
    rj.set("report", d.trace_report().to_json());
    let replay = rj.to_string();

    Snapshot { version, iteration_us: d.baseline_us(), replay, diagnose }
}

/// Resident-size estimate for cache accounting: graph arena (nodes plus
/// edges/timing vectors, ~256 B per node across the engine's arrays) +
/// the published payloads + a fixed overhead. An estimate is enough —
/// eviction needs relative weight, not an allocator audit.
fn approx_bytes(d: &Diagnoser, snap: &Snapshot) -> usize {
    d.mg().dfg().len() * 256 + snap.replay.len() + snap.diagnose.len() + (1 << 20)
}

/// Poison-tolerant lock: a handler that panicked mid-query can only have
/// left transaction state behind, which the next transaction's `begin`
/// resets; the daemon already answered that request with a 500.
fn lock(m: &Mutex<Diagnoser>) -> MutexGuard<'_, Diagnoser> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Transport;
    use crate::util::json::parse;

    #[test]
    fn snapshot_payloads_carry_identity_and_schema_keys() {
        let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
        let s = Session::build("j1", spec, None, 5, 0);
        let snap = s.snapshot();
        assert_eq!(snap.version, 0);
        let r = parse(&snap.replay).unwrap();
        for key in [
            "job", "snapshot", "model", "scheme", "transport", "workers", "ops",
            "alive_ops", "iteration_us", "fw_us", "bw_us", "est_peak_mem_bytes", "report",
        ] {
            assert!(r.get(key).is_some(), "replay payload missing {key}");
        }
        assert_eq!(r.str("job"), "j1");
        assert!(r.f64("iteration_us") > 0.0);
        let d = parse(&snap.diagnose).unwrap();
        for key in ["job", "snapshot", "blame", "bottlenecks", "whatif", "builds_during_queries"] {
            assert!(d.get(key).is_some(), "diagnose payload missing {key}");
        }
        assert_eq!(d.f64("builds_during_queries"), 0.0);
    }

    #[test]
    fn whatif_answers_are_stable_across_repeats() {
        let spec = JobSpec::standard("vgg16", "horovod", Transport::Rdma);
        let s = Session::build("j1", spec, None, 5, 0);
        let qs = crate::diagnosis::parse_whatif("nic-bw=2,perfect-overlap").unwrap();
        let (first, _) = s.whatif(&qs);
        let first = first.unwrap();
        for _ in 0..3 {
            let (again, _) = s.whatif(&qs);
            // transactional rollback: repeated queries see an identical
            // graph, so the payload is bit-for-bit stable
            assert_eq!(again.unwrap(), first);
        }
        assert_eq!(s.whatif_served(), 4);
        let parsed = parse(&first).unwrap();
        assert_eq!(parsed.get("answers").and_then(Json::as_arr).unwrap().len(), 2);
    }
}
