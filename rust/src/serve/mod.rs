//! Diagnosis-as-a-service (`dpro serve`): a long-running daemon that keeps
//! built graphs resident so replay / diagnose / what-if queries cost an
//! HTTP round-trip instead of a full trace ingestion + graph build.
//!
//! ROADMAP item 2 names the production shape this module implements; the
//! interactive "estimate efficacy before implementing" loop is Daydream's
//! framing of the same workflow. Layers, bottom up:
//!
//! - [`http`] — a std-only HTTP/1.1 server core and client over
//!   `std::net::TcpListener`/`TcpStream` (the crate has no external
//!   dependencies; this is the subset of HTTP the service needs:
//!   `Content-Length`-framed requests and responses with keep-alive).
//! - [`session`] — a [`session::Session`] owns one built
//!   [`crate::graph::MutableGraph`] + [`crate::replay::incremental::IncrementalReplayer`]
//!   (wrapped in a [`crate::diagnosis::Diagnoser`]) and publishes immutable
//!   [`session::Snapshot`]s: pre-serialized replay/diagnose payloads that
//!   any number of reader threads share without locking the engine.
//!   `optimize` is the single-writer path — accepted strategies commit
//!   through the PR-3 transaction journal and publish a new snapshot;
//!   rejected ones roll back and readers never notice.
//! - [`batch`] — identical what-if queries arriving within a window
//!   coalesce into one transactional evaluation fanned out to all waiters.
//! - [`cache`] — sessions live in a byte-accounted LRU keyed by job
//!   descriptor + plan family + trace identity; an over-budget insert
//!   evicts the least-recently-used session.
//! - [`daemon`] — the accept loop over a [`crate::util::pool::FixedPool`],
//!   request routing, and the `/healthz` + `/statsz` + `/metricsz`
//!   surfaces. All operational counters live in one per-daemon
//!   [`crate::obs::MetricsRegistry`]; `/statsz` (legacy JSON schema) and
//!   `/metricsz` (Prometheus text) are two renderings of it.
//!
//! The HTTP status contract extends the CLI's exit-code contract
//! (docs/SERVE.md): **400** is the exit-2 class (argument/body errors),
//! **422** the exit-3 class (unusable trace), 200 a clean or
//! degraded-but-usable run (warnings ride in the `report` payload).

pub mod batch;
pub mod cache;
pub mod daemon;
pub mod http;
pub mod session;

pub use cache::SessionCache;
pub use daemon::{start, ServerHandle};
pub use session::{Session, Snapshot};

/// Daemon configuration — the `dpro serve` flags, pre-validated by the
/// CLI (invalid values exit 2 before a socket is opened).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Bind address (`--addr`); port 0 picks a free port (tests/benches).
    pub addr: String,
    /// Session-cache capacity in bytes (`--cache-bytes`).
    pub cache_bytes: usize,
    /// Worker threads handling requests (`--threads`).
    pub threads: usize,
    /// What-if coalescing window in milliseconds (`--batch-window-ms`);
    /// 0 disables the wait (identical in-flight queries still coalesce).
    pub batch_window_ms: u64,
    /// Trace directories to register as sessions before the socket opens
    /// (`--trace-dir`); an unusable one aborts startup with the exit-3
    /// class, same as `dpro replay --trace-dir`.
    pub preload: Vec<String>,
    /// Bottleneck top-N in published diagnose snapshots (`--top`).
    pub top: usize,
    /// Log (and count) requests slower than this many µs
    /// (`--slow-query-us`); 0 disables the threshold.
    pub slow_query_us: u64,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            addr: "127.0.0.1:7077".into(),
            cache_bytes: 1 << 30,
            threads: 8,
            batch_window_ms: 2,
            preload: Vec::new(),
            top: 5,
            slow_query_us: 0,
        }
    }
}

/// Service-layer error, classified so the daemon and the CLI agree on
/// severity: `BadRequest` ↔ HTTP 400 ↔ exit 2, `UnusableTrace` ↔ HTTP 422
/// ↔ exit 3, `Internal` ↔ HTTP 500.
#[derive(Clone, Debug)]
pub enum ServeError {
    /// Malformed request body / invalid argument values (exit-2 class).
    BadRequest(String),
    /// The trace exists but yields nothing usable (exit-3 class).
    UnusableTrace(String),
    /// A bug: a handler panicked or an invariant broke.
    Internal(String),
}

impl ServeError {
    /// The HTTP status this error maps to (docs/SERVE.md).
    pub fn http_status(&self) -> u16 {
        match self {
            ServeError::BadRequest(_) => 400,
            ServeError::UnusableTrace(_) => 422,
            ServeError::Internal(_) => 500,
        }
    }

    /// The error message.
    pub fn message(&self) -> &str {
        match self {
            ServeError::BadRequest(m)
            | ServeError::UnusableTrace(m)
            | ServeError::Internal(m) => m,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message())
    }
}

/// Parse a byte-size flag value: a plain integer or one with a `K`/`M`/`G`
/// suffix (powers of 1024, case-insensitive). Rejects zero — a zero-byte
/// cache could never hold a session, so every request would rebuild.
pub fn parse_bytes(s: &str) -> Result<usize, String> {
    let bad = || format!("invalid byte size {s:?}; expected e.g. 536870912, 512M, 2G");
    let t = s.trim();
    let (digits, mult) = match t.chars().last().map(|c| c.to_ascii_uppercase()) {
        Some('K') => (&t[..t.len() - 1], 1usize << 10),
        Some('M') => (&t[..t.len() - 1], 1 << 20),
        Some('G') => (&t[..t.len() - 1], 1 << 30),
        _ => (t, 1),
    };
    let n: usize = digits.trim().parse().map_err(|_| bad())?;
    let bytes = n.checked_mul(mult).ok_or_else(bad)?;
    if bytes == 0 {
        return Err(bad());
    }
    Ok(bytes)
}

/// FNV-1a over a byte stream — the session-key hash (trace identity,
/// job descriptors). Not cryptographic; collisions only cost a spurious
/// cache hit between adversarially crafted dumps, which a local analysis
/// daemon does not defend against.
pub fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_bytes_accepts_suffixes_and_rejects_junk() {
        assert_eq!(parse_bytes("1024").unwrap(), 1024);
        assert_eq!(parse_bytes("4K").unwrap(), 4096);
        assert_eq!(parse_bytes("2m").unwrap(), 2 << 20);
        assert_eq!(parse_bytes(" 1G ").unwrap(), 1 << 30);
        for bad in ["", "0", "-1", "1.5G", "12Q", "G", "9999999999999999999G"] {
            assert!(parse_bytes(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn fnv1a_is_stable_and_input_sensitive() {
        let h = |s: &str| fnv1a(s.bytes());
        assert_eq!(h("dpro"), h("dpro"));
        assert_ne!(h("dpro"), h("dprp"));
        assert_ne!(h(""), h("\0"));
    }
}
