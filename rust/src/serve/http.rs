//! Std-only HTTP/1.1 plumbing for the serve daemon: request reading,
//! response writing, and a small keep-alive client (used by the load
//! generator and the integration tests — and usable from `curl`, since
//! the wire format is ordinary HTTP).
//!
//! Scope is deliberately the subset the service needs: `Content-Length`
//! framing only (no chunked transfer), no TLS, header names matched
//! case-insensitively, bodies are UTF-8 JSON. Requests over [`MAX_BODY`]
//! are refused with 413 before the body is read.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Largest accepted request body (64 MiB) — bounds memory per connection;
/// trace uploads beyond this should use `--trace-dir` registration.
pub const MAX_BODY: usize = 64 << 20;

/// One parsed request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Upper-case method (`GET`, `POST`, ...).
    pub method: String,
    /// Decoded path without the query string (`/jobs/j1a2b/replay`).
    pub path: String,
    /// Body bytes as UTF-8 (empty when no `Content-Length`).
    pub body: String,
    /// Whether the client asked to keep the connection open (HTTP/1.1
    /// default; `Connection: close` overrides).
    pub keep_alive: bool,
}

/// Read one request off a connection. `Ok(None)` means the peer closed
/// (or went idle past the read timeout) between requests — not an error,
/// just the end of a keep-alive conversation. `Err` carries the status +
/// message to answer with before closing.
pub fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Option<Request>, (u16, String)> {
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(_) => return Ok(None), // timeout / reset between requests
    }
    let mut parts = line.split_whitespace();
    let (method, target) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v)) if v.starts_with("HTTP/") => (m.to_uppercase(), t.to_string()),
        _ => return Err((400, format!("malformed request line {:?}", line.trim_end()))),
    };

    let mut content_length = 0usize;
    let mut keep_alive = true; // HTTP/1.1 default
    loop {
        let mut h = String::new();
        match reader.read_line(&mut h) {
            Ok(0) => return Ok(None),
            Ok(_) => {}
            Err(e) => return Err((400, format!("error reading headers: {e}"))),
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            let value = value.trim();
            match name.trim().to_ascii_lowercase().as_str() {
                "content-length" => {
                    content_length = value
                        .parse()
                        .map_err(|_| (400, format!("bad Content-Length {value:?}")))?;
                }
                "connection" => keep_alive = !value.eq_ignore_ascii_case("close"),
                _ => {}
            }
        }
    }
    if content_length > MAX_BODY {
        return Err((413, format!("body of {content_length} bytes exceeds the {MAX_BODY}-byte limit")));
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader
            .read_exact(&mut body)
            .map_err(|e| (400, format!("error reading body: {e}")))?;
    }
    let body =
        String::from_utf8(body).map_err(|_| (400, "body is not valid UTF-8".to_string()))?;
    // the service's paths carry no query strings; strip one if present so
    // routing sees a clean path
    let path = target.split('?').next().unwrap_or("").to_string();
    Ok(Some(Request { method, path, body, keep_alive }))
}

/// Standard reason phrase for the statuses the service emits.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Write one `Content-Length`-framed JSON response.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    body: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        status_text(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// A minimal keep-alive HTTP client for the load generator, the CI smoke
/// step and the tests. Reconnects once per call when the server closed
/// the pooled connection.
pub struct Client {
    addr: String,
    conn: Option<BufReader<TcpStream>>,
}

impl Client {
    /// Client for `addr` (`host:port`); connects lazily.
    pub fn new(addr: &str) -> Client {
        Client { addr: addr.to_string(), conn: None }
    }

    /// Issue one request; returns `(status, body)`.
    pub fn call(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String), String> {
        match self.call_once(method, path, body) {
            Ok(r) => Ok(r),
            Err(_) => {
                // the server may have closed a kept-alive connection;
                // retry exactly once on a fresh one
                self.conn = None;
                self.call_once(method, path, body)
            }
        }
    }

    fn call_once(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String), String> {
        if self.conn.is_none() {
            let s = TcpStream::connect(&self.addr)
                .map_err(|e| format!("connect {}: {e}", self.addr))?;
            s.set_read_timeout(Some(std::time::Duration::from_secs(60)))
                .map_err(|e| format!("set timeout: {e}"))?;
            self.conn = Some(BufReader::new(s));
        }
        let reader = self.conn.as_mut().expect("just connected");
        let payload = body.unwrap_or("");
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{payload}",
            self.addr,
            payload.len(),
        );
        let w = reader.get_mut();
        w.write_all(req.as_bytes()).map_err(|e| format!("write: {e}"))?;
        w.flush().map_err(|e| format!("flush: {e}"))?;

        let mut line = String::new();
        reader.read_line(&mut line).map_err(|e| format!("read status: {e}"))?;
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| format!("malformed status line {:?}", line.trim_end()))?;
        let mut content_length = 0usize;
        let mut close = false;
        loop {
            let mut h = String::new();
            let n = reader.read_line(&mut h).map_err(|e| format!("read header: {e}"))?;
            if n == 0 {
                return Err("connection closed mid-headers".into());
            }
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((name, value)) = h.split_once(':') {
                match name.trim().to_ascii_lowercase().as_str() {
                    "content-length" => {
                        content_length =
                            value.trim().parse().map_err(|_| "bad Content-Length".to_string())?;
                    }
                    "connection" => close = value.trim().eq_ignore_ascii_case("close"),
                    _ => {}
                }
            }
        }
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body).map_err(|e| format!("read body: {e}"))?;
        if close {
            self.conn = None;
        }
        let body = String::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
        Ok((status, body))
    }

    /// GET `path`, expect 200, parse the JSON body. Non-200 statuses and
    /// unparsable bodies are errors carrying the status + payload — the
    /// one helper the load generator, the campaign executor and the
    /// tests all share instead of each re-wrapping [`Client::call`].
    pub fn get_json(&mut self, path: &str) -> Result<crate::util::json::Json, String> {
        self.expect_json("GET", path, None)
    }

    /// POST `body` to `path`, expect 200, parse the JSON response.
    pub fn post_json(&mut self, path: &str, body: &str) -> Result<crate::util::json::Json, String> {
        self.expect_json("POST", path, Some(body))
    }

    fn expect_json(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<crate::util::json::Json, String> {
        match self.call(method, path, body) {
            Ok((200, resp)) => crate::util::json::parse(&resp)
                .map_err(|e| format!("{method} {path}: bad JSON response: {e}")),
            Ok((status, resp)) => Err(format!("{method} {path}: status {status}: {resp}")),
            Err(e) => Err(format!("{method} {path}: {e}")),
        }
    }
}
