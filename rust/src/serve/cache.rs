//! Byte-accounted LRU session cache. Sessions are keyed by job descriptor
//! + plan family + trace identity (see [`crate::serve::daemon`]); a
//! `POST /jobs` for a key already resident is a cache hit — the expensive
//! ingest + build is skipped and the existing session answers.
//!
//! Concurrent requests for the same missing key coalesce: the first
//! inserts a `Building` placeholder and builds **outside** the cache
//! lock; the rest wait on a condvar, so a slow build never blocks hits on
//! other sessions. When the accounted bytes exceed the capacity, ready
//! sessions are evicted least-recently-used first — except the entry just
//! inserted, so one oversized session still serves (and is evicted by the
//! next insert).

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::obs::Counter;
use crate::serve::session::Session;
use crate::serve::ServeError;

enum Slot {
    /// A builder is constructing this session outside the lock.
    Building,
    /// Resident; `last_used` is the LRU tick.
    Ready { sess: Arc<Session>, last_used: u64 },
}

struct Inner {
    map: HashMap<String, Slot>,
    /// Monotonic use counter (LRU clock).
    tick: u64,
    /// Accounted bytes of all `Ready` sessions.
    bytes: usize,
}

/// Cumulative cache statistics (`/statsz`).
#[derive(Clone, Copy, Debug)]
pub struct CacheStats {
    /// Lookups that found a ready session.
    pub hits: u64,
    /// Lookups that found nothing (and, for `get_or_build`, built).
    pub misses: u64,
    /// Sessions evicted to fit the byte budget.
    pub evictions: u64,
    /// Ready sessions resident now.
    pub sessions: usize,
    /// Accounted bytes resident now.
    pub bytes: usize,
    /// Capacity in bytes.
    pub cap_bytes: usize,
}

impl CacheStats {
    /// hits / (hits + misses), 0 when idle.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The session cache (see module docs). Hit/miss/eviction counters are
/// [`Counter`] handles so a daemon can register them in its
/// [`crate::obs::MetricsRegistry`] ([`SessionCache::with_metrics`]) —
/// `/statsz` and `/metricsz` then read the *same* atomics instead of two
/// drift-prone sets.
pub struct SessionCache {
    cap_bytes: usize,
    inner: Mutex<Inner>,
    cv: Condvar,
    hits: Counter,
    misses: Counter,
    evictions: Counter,
}

impl SessionCache {
    /// Cache holding at most ~`cap_bytes` of accounted session bytes,
    /// with detached (unregistered) counters.
    pub fn new(cap_bytes: usize) -> SessionCache {
        SessionCache::with_metrics(cap_bytes, Counter::new(), Counter::new(), Counter::new())
    }

    /// [`SessionCache::new`] with externally owned counters — the serve
    /// daemon passes registry-backed handles.
    pub fn with_metrics(
        cap_bytes: usize,
        hits: Counter,
        misses: Counter,
        evictions: Counter,
    ) -> SessionCache {
        SessionCache {
            cap_bytes,
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0, bytes: 0 }),
            cv: Condvar::new(),
            hits,
            misses,
            evictions,
        }
    }

    /// Fetch `key`, building it with `build` on a miss. Returns the
    /// session and whether it was a hit. Concurrent callers for the same
    /// key share one build; a failed or panicked build clears the
    /// placeholder so the key can be retried.
    pub fn get_or_build(
        &self,
        key: &str,
        build: impl FnOnce() -> Result<Session, ServeError>,
    ) -> Result<(Arc<Session>, bool), ServeError> {
        {
            let mut guard = lock(&self.inner);
            loop {
                match probe(&mut guard, key) {
                    Probe::Ready(sess) => {
                        self.hits.inc();
                        return Ok((sess, true));
                    }
                    Probe::Building => {
                        guard = self.cv.wait(guard).unwrap_or_else(|p| p.into_inner());
                    }
                    Probe::Absent => {
                        self.misses.inc();
                        guard.map.insert(key.to_string(), Slot::Building);
                        break;
                    }
                }
            }
        }
        // build with no lock held — hits on other keys proceed
        let built = match catch_unwind(AssertUnwindSafe(build)) {
            Ok(r) => r,
            Err(_) => Err(ServeError::Internal("session build panicked".into())),
        };
        let mut inner = lock(&self.inner);
        match built {
            Ok(sess) => {
                let sess = Arc::new(sess);
                inner.tick += 1;
                let t = inner.tick;
                inner.bytes += sess.bytes();
                inner
                    .map
                    .insert(key.to_string(), Slot::Ready { sess: Arc::clone(&sess), last_used: t });
                self.evict_over_budget(&mut inner, key);
                self.cv.notify_all();
                Ok((sess, false))
            }
            Err(e) => {
                inner.map.remove(key);
                self.cv.notify_all();
                Err(e)
            }
        }
    }

    /// Look up a session by key without building — the `GET` path.
    /// Counts toward the hit rate; waits out an in-flight build of the
    /// same key rather than reporting a spurious miss.
    pub fn lookup(&self, key: &str) -> Option<Arc<Session>> {
        let mut guard = lock(&self.inner);
        loop {
            match probe(&mut guard, key) {
                Probe::Ready(sess) => {
                    self.hits.inc();
                    return Some(sess);
                }
                Probe::Building => {
                    guard = self.cv.wait(guard).unwrap_or_else(|p| p.into_inner());
                }
                Probe::Absent => {
                    self.misses.inc();
                    return None;
                }
            }
        }
    }

    /// Look at `key` without counting a hit/miss or touching the LRU
    /// clock — for observers assembling reports over sessions already
    /// enumerated ([`SessionCache::sessions`]). Before this existed,
    /// `/statsz` used [`SessionCache::lookup`] per session row and
    /// inflated the hit counters it was reporting.
    pub fn peek(&self, key: &str) -> Option<Arc<Session>> {
        let guard = lock(&self.inner);
        match guard.map.get(key) {
            Some(Slot::Ready { sess, .. }) => Some(Arc::clone(sess)),
            _ => None,
        }
    }

    /// Ready sessions, `(id, bytes, whatif_served)` per session — the
    /// `/statsz` session table.
    pub fn sessions(&self) -> Vec<(String, usize, u64)> {
        let inner = lock(&self.inner);
        let mut out: Vec<(String, usize, u64)> = inner
            .map
            .iter()
            .filter_map(|(k, s)| match s {
                Slot::Ready { sess, .. } => Some((k.clone(), sess.bytes(), sess.whatif_served())),
                Slot::Building => None,
            })
            .collect();
        out.sort();
        out
    }

    /// Current statistics snapshot.
    pub fn stats(&self) -> CacheStats {
        let inner = lock(&self.inner);
        CacheStats {
            hits: self.hits.get(),
            misses: self.misses.get(),
            evictions: self.evictions.get(),
            sessions: inner
                .map
                .values()
                .filter(|s| matches!(s, Slot::Ready { .. }))
                .count(),
            bytes: inner.bytes,
            cap_bytes: self.cap_bytes,
        }
    }

    /// Evict LRU `Ready` entries (never `keep`, never `Building`) until
    /// the accounted bytes fit the capacity.
    fn evict_over_budget(&self, inner: &mut Inner, keep: &str) {
        while inner.bytes > self.cap_bytes {
            let victim = inner
                .map
                .iter()
                .filter_map(|(k, s)| match s {
                    Slot::Ready { last_used, .. } if k != keep => Some((*last_used, k.clone())),
                    _ => None,
                })
                .min();
            let Some((_, key)) = victim else { break };
            if let Some(Slot::Ready { sess, .. }) = inner.map.remove(&key) {
                inner.bytes = inner.bytes.saturating_sub(sess.bytes());
                self.evictions.inc();
            }
        }
    }
}

fn lock(m: &Mutex<Inner>) -> MutexGuard<'_, Inner> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// One lock-held look at `key`. Touching the LRU tick happens here so
/// the callers' condvar loops never hold a borrow across a `wait`.
enum Probe {
    Ready(Arc<Session>),
    Building,
    Absent,
}

fn probe(guard: &mut MutexGuard<'_, Inner>, key: &str) -> Probe {
    let inner = &mut **guard; // split-borrow `map` and `tick`
    match inner.map.get_mut(key) {
        Some(Slot::Ready { sess, last_used }) => {
            inner.tick += 1;
            *last_used = inner.tick;
            Probe::Ready(Arc::clone(sess))
        }
        Some(Slot::Building) => Probe::Building,
        None => Probe::Absent,
    }
}
