//! Job/cluster specifications: everything needed to run a training job on
//! the testbed, to build its global DFG, and for the optimizer to rewrite.

use crate::graph::dfg::TensorId;
use crate::models::cost::GpuModel;
use crate::models::ModelGraph;
use crate::util::Us;

/// Inter-server transport. The two cases differ in achievable efficiency,
/// per-message overhead and latency — exactly the effects Daydream's
/// `size/bandwidth` estimate ignores (paper Fig. 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    /// Kernel TCP/IP over Ethernet (CPU-bound, incast-prone).
    Tcp,
    /// RDMA (RoCE/IB): kernel-bypass, near-line-rate.
    Rdma,
}

impl Transport {
    /// Display name (`TCP` / `RDMA`).
    pub fn name(self) -> &'static str {
        match self {
            Transport::Tcp => "TCP",
            Transport::Rdma => "RDMA",
        }
    }
}

/// Network model of the cluster fabric.
#[derive(Clone, Debug)]
pub struct NetworkSpec {
    /// Inter-server transport protocol.
    pub transport: Transport,
    /// Nominal NIC bandwidth in Gbit/s (100 in the paper's testbed).
    pub nic_gbps: f64,
    /// Intra-machine GPU interconnect bandwidth in Gbit/s (NVLink).
    pub nvlink_gbps: f64,
}

impl NetworkSpec {
    /// The paper testbed's fabric over kernel TCP (100 GbE NICs).
    pub fn tcp_100g() -> NetworkSpec {
        NetworkSpec { transport: Transport::Tcp, nic_gbps: 100.0, nvlink_gbps: 1200.0 }
    }

    /// The paper testbed's fabric over RDMA (100 GbE NICs).
    pub fn rdma_100g() -> NetworkSpec {
        NetworkSpec { transport: Transport::Rdma, nic_gbps: 100.0, nvlink_gbps: 1200.0 }
    }

    /// Fraction of nominal bandwidth large transfers achieve. TCP on
    /// 100 GbE is CPU-bound in practice (kernel stack, copies, congestion
    /// control): a single stream lands near 30–40 Gbps.
    pub fn efficiency(&self) -> f64 {
        match self.transport {
            Transport::Tcp => 0.34,
            Transport::Rdma => 0.94,
        }
    }

    /// Fixed per-message cost on the sending side (syscall / doorbell,
    /// protocol headers), microseconds.
    pub fn per_msg_overhead_us(&self) -> Us {
        match self.transport {
            Transport::Tcp => 25.0,
            Transport::Rdma => 4.0,
        }
    }

    /// One-way propagation + switching latency, microseconds.
    pub fn base_latency_us(&self) -> Us {
        match self.transport {
            Transport::Tcp => 18.0,
            Transport::Rdma => 2.5,
        }
    }

    /// Wire time of `bytes` on the NIC at achieved bandwidth (us), without
    /// per-message overhead.
    pub fn wire_time_us(&self, bytes: f64) -> Us {
        bytes * 8.0 / (self.nic_gbps * 1e9 * self.efficiency()) * 1e6
    }

    /// Intra-machine transfer time over NVLink (us).
    pub fn nvlink_time_us(&self, bytes: f64) -> Us {
        bytes * 8.0 / (self.nvlink_gbps * 1e9 * 0.85) * 1e6 + 3.0
    }
}

/// Per-machine clock behaviour injected by the testbed (paper §2.2: NTP
/// leaves ms-level drift; RECV launch timestamps mismeasure transfers).
#[derive(Clone, Debug)]
pub struct ClockSpec {
    /// Std-dev of the per-machine clock offset (us). NTP-grade ≈ 1–3 ms.
    pub drift_std_us: f64,
    /// If true, RECV trace events report the op *launch* time rather than
    /// when data actually started arriving (paper §2.2 factor 2).
    pub recv_launch_error: bool,
}

impl Default for ClockSpec {
    fn default() -> Self {
        ClockSpec { drift_std_us: 1500.0, recv_launch_error: true }
    }
}

/// The machines + devices the job runs on.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// Total worker (GPU) count.
    pub n_workers: usize,
    /// GPUs per physical machine (defines the machine layout).
    pub gpus_per_machine: usize,
    /// GPU cost model shared by all workers.
    pub gpu: GpuModel,
    /// Fabric connecting the machines.
    pub network: NetworkSpec,
    /// Per-machine clock behaviour the testbed injects.
    pub clock: ClockSpec,
    /// Seed for all stochastic testbed behaviour on this cluster.
    pub seed: u64,
}

impl ClusterSpec {
    /// Cluster with default GPU model, clock spec and seed.
    pub fn new(n_workers: usize, gpus_per_machine: usize, network: NetworkSpec) -> ClusterSpec {
        ClusterSpec {
            n_workers,
            gpus_per_machine,
            gpu: GpuModel::default(),
            network,
            clock: ClockSpec::default(),
            seed: 42,
        }
    }

    /// Paper default testbed: 16 GPUs on 2 servers (8 per machine).
    pub fn default_16(transport: Transport) -> ClusterSpec {
        let net = match transport {
            Transport::Tcp => NetworkSpec::tcp_100g(),
            Transport::Rdma => NetworkSpec::rdma_100g(),
        };
        ClusterSpec::new(16, 8, net)
    }

    /// Number of physical machines (workers packed densely).
    pub fn n_machines(&self) -> usize {
        (self.n_workers + self.gpus_per_machine - 1) / self.gpus_per_machine
    }

    /// Machine hosting a worker.
    pub fn machine_of(&self, worker: usize) -> usize {
        worker / self.gpus_per_machine
    }

    /// Workers located on machine `m`.
    pub fn workers_on(&self, m: usize) -> Vec<usize> {
        (0..self.n_workers).filter(|&w| self.machine_of(w) == m).collect()
    }
}

/// Gradient-synchronization architecture.
///
/// The enum is deliberately *opaque* outside this module and the planner
/// module ([`crate::graph::comm_plan`]): every other layer keys off the
/// property accessors below (or off [`crate::graph::comm_plan::PlanProps`]
/// derived from the lowered plan), never off the variants, so adding a
/// scheme touches only the two scheme-owning modules.
#[derive(Clone, Debug)]
pub enum CommScheme {
    /// Horovod-style collective AllReduce (hierarchical: NVLink
    /// reduce/broadcast within a machine, flat ring across machine NICs).
    AllReduce(ArSpec),
    /// Flat ring AllReduce over *workers* — no NVLink hierarchy; `2(n−1)`
    /// chunk steps around the full worker ring, intra-machine hops on
    /// NVLink, machine-boundary hops on the NIC.
    Ring(ArSpec),
    /// BytePS-style parameter servers (per-worker PUSH/PULL with tensor
    /// partitions).
    Ps(PsSpec),
    /// Tree/hierarchical PS: machine-local NVLink aggregation first, then
    /// one PUSH/PULL per *machine* to the server.
    PsTree(PsSpec),
}

/// Scheme names accepted by [`CommScheme::parse`] / the CLI `--scheme`
/// flag, one canonical spelling per scheme.
pub const ALL_SCHEMES: [&str; 4] = ["horovod", "ring", "byteps", "ps-tree"];

impl CommScheme {
    /// Human-readable scheme name (report labels, matches the paper).
    pub fn name(&self) -> &'static str {
        match self {
            CommScheme::AllReduce(_) => "Horovod",
            CommScheme::Ring(_) => "Ring",
            CommScheme::Ps(_) => "BytePS",
            CommScheme::PsTree(_) => "PS-Tree",
        }
    }

    /// Canonical machine-readable name — the [`ALL_SCHEMES`] spelling that
    /// [`CommScheme::parse`] accepts back. Used by trace dumps
    /// ([`crate::trace::io::JobMeta`]) so a replay from disk reconstructs
    /// the same scheme.
    pub fn cli_name(&self) -> &'static str {
        match self {
            CommScheme::AllReduce(_) => "horovod",
            CommScheme::Ring(_) => "ring",
            CommScheme::Ps(_) => "byteps",
            CommScheme::PsTree(_) => "ps-tree",
        }
    }

    /// Parse a CLI/config scheme name. Server-based schemes size their
    /// server fleet from the cluster (colocated mode).
    pub fn parse(name: &str, cluster: &ClusterSpec) -> Option<CommScheme> {
        Some(match name {
            "horovod" | "allreduce" | "hier" => CommScheme::AllReduce(ArSpec::default()),
            "ring" | "flat-ring" => CommScheme::Ring(ArSpec::default()),
            "byteps" | "ps" => CommScheme::Ps(PsSpec::for_cluster(cluster)),
            "ps-tree" | "pstree" | "byteps-tree" => {
                CommScheme::PsTree(PsSpec::for_cluster(cluster))
            }
            _ => return None,
        })
    }

    /// Collective-family parameters, if this scheme negotiates collectives.
    pub fn ar_spec(&self) -> Option<&ArSpec> {
        match self {
            CommScheme::AllReduce(ar) | CommScheme::Ring(ar) => Some(ar),
            _ => None,
        }
    }

    /// Server-family parameters, if this scheme uses parameter servers.
    pub fn ps_spec(&self) -> Option<&PsSpec> {
        match self {
            CommScheme::Ps(ps) | CommScheme::PsTree(ps) => Some(ps),
            _ => None,
        }
    }

    /// Coordinator negotiation cycle (0 for schemes without a coordinator).
    pub fn cycle_time_us(&self) -> Us {
        self.ar_spec().map_or(0.0, |ar| ar.cycle_time_us)
    }

    /// Server-side aggregation throughput, if servers exist.
    pub fn agg_bytes_per_s(&self) -> Option<f64> {
        self.ps_spec().map(|ps| ps.agg_bytes_per_s)
    }

    /// Number of extra (non-worker) processes the scheme runs — PS server
    /// processes; 0 for collective schemes.
    pub fn n_servers(&self) -> usize {
        self.ps_spec().map_or(0, |ps| ps.n_servers)
    }

    /// Whether synchronization routes through parameter-server processes.
    /// (Also derivable from the lowered plan — see
    /// [`crate::graph::comm_plan::PlanProps`]; a test pins the agreement.)
    pub fn uses_servers(&self) -> bool {
        self.ps_spec().is_some()
    }

    /// Re-derive the cluster-dependent parameters after the cluster shape
    /// changed — the elastic-rescale hook
    /// (`MutableGraph::rescale_workers`). Collective schemes keep their
    /// tuning untouched; server schemes keep their tuning but re-size the
    /// server fleet from the new machine count (colocated mode), exactly
    /// as [`CommScheme::parse`] would size a fresh job on that cluster.
    pub fn resized_for(&self, cluster: &ClusterSpec) -> CommScheme {
        match self {
            CommScheme::AllReduce(ar) => CommScheme::AllReduce(ar.clone()),
            CommScheme::Ring(ar) => CommScheme::Ring(ar.clone()),
            CommScheme::Ps(ps) => CommScheme::Ps(PsSpec {
                n_servers: cluster.n_machines().max(1),
                ..ps.clone()
            }),
            CommScheme::PsTree(ps) => CommScheme::PsTree(PsSpec {
                n_servers: cluster.n_machines().max(1),
                ..ps.clone()
            }),
        }
    }
}

/// Parameters of the collective (AllReduce) scheme family.
#[derive(Clone, Debug)]
pub struct ArSpec {
    /// Coordinator negotiation cycle time (us): a ready tensor waits on
    /// average half a cycle before its collective is scheduled.
    pub cycle_time_us: Us,
}

impl Default for ArSpec {
    fn default() -> Self {
        ArSpec { cycle_time_us: 2000.0 }
    }
}

/// Parameters of the parameter-server scheme family.
#[derive(Clone, Debug)]
pub struct PsSpec {
    /// Number of parameter-server processes (one per machine by default —
    /// BytePS colocated mode).
    pub n_servers: usize,
    /// Server-side aggregation throughput, bytes/s (summation on CPU).
    pub agg_bytes_per_s: f64,
}

impl PsSpec {
    /// Colocated-mode sizing: one server per machine.
    pub fn for_cluster(c: &ClusterSpec) -> PsSpec {
        PsSpec { n_servers: c.n_machines().max(1), agg_bytes_per_s: 24.0e9 }
    }
}

/// How tensors are grouped (fusion) and sliced (partition) for
/// synchronization — the structure the optimizer's tensor-fusion and
/// tensor-partition passes rewrite.
#[derive(Clone, Debug)]
pub struct TensorGroup {
    /// Template tensor ids fused into one synchronization unit.
    pub tensors: Vec<TensorId>,
    /// Number of equal slices the fused tensor is split into.
    pub partitions: usize,
}

/// The job's tensor-synchronization plan: a partition of all template
/// tensors into fused groups.
#[derive(Clone, Debug)]
pub struct CommPlan {
    /// Disjoint tensor groups covering every template tensor.
    pub groups: Vec<TensorGroup>,
}

impl CommPlan {
    /// One group per tensor, no partitioning — the unoptimized plan.
    pub fn per_tensor(model: &ModelGraph) -> CommPlan {
        CommPlan {
            groups: (0..model.tensors.len() as TensorId)
                .map(|t| TensorGroup { tensors: vec![t], partitions: 1 })
                .collect(),
        }
    }

    /// Fused-tensor bytes of a group.
    pub fn group_bytes(&self, model: &ModelGraph, gi: usize) -> f64 {
        self.groups[gi].tensors.iter().map(|&t| model.tensors[t as usize].bytes).sum()
    }

    /// Validate: every tensor appears in exactly one group; partitions >= 1.
    pub fn validate(&self, model: &ModelGraph) -> Result<(), String> {
        let mut seen = vec![false; model.tensors.len()];
        for (gi, g) in self.groups.iter().enumerate() {
            if g.partitions == 0 {
                return Err(format!("group {gi} has 0 partitions"));
            }
            if g.tensors.is_empty() {
                return Err(format!("group {gi} empty"));
            }
            for &t in &g.tensors {
                let i = t as usize;
                if i >= seen.len() {
                    return Err(format!("group {gi} references unknown tensor {t}"));
                }
                if seen[i] {
                    return Err(format!("tensor {t} in multiple groups"));
                }
                seen[i] = true;
            }
        }
        if let Some(t) = seen.iter().position(|&s| !s) {
            return Err(format!("tensor {t} not in any group"));
        }
        Ok(())
    }
}

/// How computation ops are clustered into fused kernels — the structure
/// the op-fusion pass (and the XLA auto-clustering baseline) rewrites.
/// Mirrors [`CommPlan`]: the template itself is never mutated.
#[derive(Clone, Debug)]
pub struct FusionPlan {
    /// Disjoint groups of template op ids; each group executes as one
    /// fused kernel. Singleton groups = unfused ops.
    pub groups: Vec<Vec<u32>>,
    /// group index of each template op (derived; kept in sync)
    pub group_of: Vec<u32>,
}

impl FusionPlan {
    /// One group per op — the unfused plan.
    pub fn singletons(model: &ModelGraph) -> FusionPlan {
        FusionPlan {
            groups: (0..model.ops.len() as u32).map(|i| vec![i]).collect(),
            group_of: (0..model.ops.len() as u32).collect(),
        }
    }

    /// Recompute the derived `group_of` index after editing `groups`.
    pub fn rebuild_index(&mut self, n_ops: usize) {
        self.group_of = vec![0; n_ops];
        for (gi, g) in self.groups.iter().enumerate() {
            for &op in g {
                self.group_of[op as usize] = gi as u32;
            }
        }
    }

    /// Fused kernel duration of group `gi` (one launch overhead, slight
    /// locality gain — see [`crate::models::cost::GpuModel::fused_time`]).
    pub fn duration(&self, model: &ModelGraph, gpu: &crate::models::cost::GpuModel, gi: usize) -> Us {
        let g = &self.groups[gi];
        if g.len() == 1 {
            return model.ops[g[0] as usize].duration(gpu);
        }
        let times: Vec<Us> = g.iter().map(|&i| model.ops[i as usize].duration(gpu)).collect();
        gpu.fused_time(&times)
    }

    /// Validate: every op in exactly one group, no kind mixing.
    pub fn validate(&self, model: &ModelGraph) -> Result<(), String> {
        let mut seen = vec![false; model.ops.len()];
        for (gi, g) in self.groups.iter().enumerate() {
            if g.is_empty() {
                return Err(format!("fusion group {gi} empty"));
            }
            let kind = model.ops[g[0] as usize].kind;
            for &op in g {
                let i = op as usize;
                if i >= seen.len() {
                    return Err(format!("fusion group {gi} references op {op}"));
                }
                if seen[i] {
                    return Err(format!("op {op} in multiple fusion groups"));
                }
                if model.ops[i].kind != kind {
                    return Err(format!("fusion group {gi} mixes op kinds"));
                }
                seen[i] = true;
            }
        }
        if let Some(i) = seen.iter().position(|&s| !s) {
            return Err(format!("op {i} not in any fusion group"));
        }
        Ok(())
    }
}

/// A complete training-job specification: what the testbed executes and
/// what the global DFG is built from.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// The model template being trained.
    pub model: ModelGraph,
    /// The machines + devices the job runs on.
    pub cluster: ClusterSpec,
    /// Gradient-synchronization architecture.
    pub scheme: CommScheme,
    /// Tensor fusion/partition plan.
    pub plan: CommPlan,
    /// Kernel (op) fusion plan.
    pub fusion: FusionPlan,
}

impl JobSpec {
    /// Job with the unoptimized plans (per-tensor, unfused kernels).
    pub fn new(model: ModelGraph, cluster: ClusterSpec, scheme: CommScheme) -> JobSpec {
        let plan = CommPlan::per_tensor(&model);
        let fusion = FusionPlan::singletons(&model);
        JobSpec { model, cluster, scheme, plan, fusion }
    }

    /// Paper-default job: model × 16 GPUs/2 machines × scheme × transport.
    pub fn standard(model_name: &str, scheme_name: &str, transport: Transport) -> JobSpec {
        let model = crate::models::by_name(model_name, 32)
            .unwrap_or_else(|| panic!("unknown model {model_name}"));
        let cluster = ClusterSpec::default_16(transport);
        JobSpec::with_scheme_name(model, cluster, scheme_name)
    }

    /// Job from an explicit model + cluster and a scheme *name* — the
    /// constructor non-scheme-owning code uses so the `CommScheme` variants
    /// stay private to `config`/`comm_plan`.
    pub fn with_scheme_name(
        model: ModelGraph,
        cluster: ClusterSpec,
        scheme_name: &str,
    ) -> JobSpec {
        let scheme = CommScheme::parse(scheme_name, &cluster)
            .unwrap_or_else(|| panic!("unknown scheme {scheme_name}"));
        JobSpec::new(model, cluster, scheme)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn cluster_layout() {
        let c = ClusterSpec::default_16(Transport::Rdma);
        assert_eq!(c.n_machines(), 2);
        assert_eq!(c.machine_of(7), 0);
        assert_eq!(c.machine_of(8), 1);
        assert_eq!(c.workers_on(1), (8..16).collect::<Vec<_>>());
    }

    #[test]
    fn network_models_differ() {
        let tcp = NetworkSpec::tcp_100g();
        let rdma = NetworkSpec::rdma_100g();
        assert!(tcp.wire_time_us(4.0e6) > rdma.wire_time_us(4.0e6));
        assert!(tcp.per_msg_overhead_us() > rdma.per_msg_overhead_us());
        // 4 MB at ~94 Gbps ≈ 340 us
        let t = rdma.wire_time_us(4.0e6);
        assert!((300.0..400.0).contains(&t), "t={t}");
    }

    #[test]
    fn per_tensor_plan_valid() {
        let m = models::by_name("resnet50", 8).unwrap();
        let plan = CommPlan::per_tensor(&m);
        assert_eq!(plan.validate(&m), Ok(()));
        assert_eq!(plan.groups.len(), m.tensors.len());
    }

    #[test]
    fn plan_validation_catches_errors() {
        let m = models::by_name("vgg16", 8).unwrap();
        let mut plan = CommPlan::per_tensor(&m);
        plan.groups[0].tensors.push(1); // duplicate of group 1's tensor
        assert!(plan.validate(&m).is_err());
        let mut plan2 = CommPlan::per_tensor(&m);
        plan2.groups.pop();
        assert!(plan2.validate(&m).is_err());
    }

    #[test]
    fn standard_jobs_construct() {
        for scheme in ALL_SCHEMES {
            for transport in [Transport::Tcp, Transport::Rdma] {
                let j = JobSpec::standard("resnet50", scheme, transport);
                assert_eq!(j.cluster.n_workers, 16);
                assert_eq!(j.plan.validate(&j.model), Ok(()));
            }
        }
    }

    #[test]
    fn scheme_properties_consistent() {
        let c = ClusterSpec::default_16(Transport::Rdma);
        for name in ALL_SCHEMES {
            let s = CommScheme::parse(name, &c).unwrap();
            // the canonical name parses back to the same scheme
            assert_eq!(s.cli_name(), name);
            assert_eq!(CommScheme::parse(s.cli_name(), &c).unwrap().name(), s.name());
            // servers and coordinators are mutually exclusive families
            assert_eq!(s.uses_servers(), s.ps_spec().is_some(), "{name}");
            assert_eq!(s.uses_servers(), s.n_servers() > 0, "{name}");
            assert_eq!(!s.uses_servers(), s.ar_spec().is_some(), "{name}");
            assert_eq!(s.uses_servers(), s.agg_bytes_per_s().is_some(), "{name}");
            // server-family schemes have no coordinator cycle (a collective
            // scheme with cycle 0 is valid — don't assert the converse)
            if s.uses_servers() {
                assert_eq!(s.cycle_time_us(), 0.0, "{name}");
            }
        }
        assert!(CommScheme::parse("carrier-pigeon", &c).is_none());
        // aliases resolve to the same scheme
        assert_eq!(
            CommScheme::parse("allreduce", &c).unwrap().name(),
            CommScheme::parse("horovod", &c).unwrap().name()
        );
        assert_eq!(
            CommScheme::parse("pstree", &c).unwrap().name(),
            CommScheme::parse("ps-tree", &c).unwrap().name()
        );
    }
}
