//! Trace time alignment (paper §4.2).
//!
//! Traces from different machines carry clock drift, and RECV events report
//! launch time rather than data-arrival time. We solve for one clock offset
//! θ per process, minimizing
//!
//! `a₁·O₁ + a₂·O₂`  subject to SEND→RECV dependency constraints,
//!
//! where `O₁` is the variance of *clipped* RECV durations within each RECV
//! op family (same op name across iterations — same receiver, same sender,
//! same tensor size) and `O₂` ties offsets of processes on the same
//! physical machine together. The paper uses CVXPY; this image has no
//! convex-optimization library, so [`qp`] implements a projected-gradient /
//! penalty solver specialized to this problem shape.

pub mod qp;

use std::collections::HashMap;

use crate::graph::dfg::OpKind;
use crate::trace::GTrace;
use crate::util::Us;

/// Solved clock offsets per process, plus alignment diagnostics.
#[derive(Clone, Debug)]
pub struct Alignment {
    /// θ per process index (dense over procs seen in the trace; the
    /// reference process 0 has θ = 0).
    pub theta: HashMap<u16, f64>,
    /// Final objective value (for convergence reporting).
    pub objective: f64,
    /// Solver iterations performed.
    pub iterations: usize,
}

impl Alignment {
    /// Identity alignment (θ = 0 everywhere): the "w/o alignment" ablation.
    pub fn identity() -> Alignment {
        Alignment { theta: HashMap::new(), objective: 0.0, iterations: 0 }
    }

    /// Solved clock offset θ of a process (0.0 for unseen processes).
    pub fn offset(&self, proc: u16) -> f64 {
        self.theta.get(&proc).copied().unwrap_or(0.0)
    }

    /// Corrected duration of a RECV event given its matched SEND's
    /// (process, start): `ed + θ_j − max(st + θ_j, send_st + θ_i)`.
    pub fn recv_duration(&self, recv_proc: u16, recv_st: Us, recv_ed: Us, send_proc: u16, send_st: Us) -> Us {
        let tj = self.offset(recv_proc);
        let ti = self.offset(send_proc);
        let start = (recv_st + tj).max(send_st + ti);
        ((recv_ed + tj) - start).max(0.0)
    }
}

/// One RECV observation joined with its SEND (by transaction id + iter).
#[derive(Clone, Debug)]
pub struct RecvObs {
    /// RECV-op family id (same op name across iterations).
    pub family: u32,
    /// Receiving process.
    pub recv_proc: u16,
    /// Sending process.
    pub send_proc: u16,
    /// Measured RECV start (receiver clock).
    pub recv_st: f64,
    /// Measured RECV end (receiver clock).
    pub recv_ed: f64,
    /// The SEND's completion time (sender clock) — the clip point.
    pub send_st: f64,
}

/// The assembled alignment problem.
pub struct Problem {
    /// Number of processes (θ dimension). Process ids are remapped densely.
    pub procs: Vec<u16>,
    /// Machine hosting each dense process index (O₂ ties same machines).
    pub machine_of: Vec<u16>,
    /// All joined SEND↔RECV observations.
    pub obs: Vec<RecvObs>,
    /// Cross-process dependency constraints (i, t_i, j, t_j): require
    /// `t_i + θ_i ≤ t_j + θ_j` (op on i happens-before op on j).
    pub deps: Vec<(usize, f64, usize, f64)>,
    /// Dense index per proc id.
    pub index: HashMap<u16, usize>,
}

/// Build the alignment problem from a measured trace.
pub fn build_problem(trace: &GTrace) -> Problem {
    // dense proc index
    let mut index: HashMap<u16, usize> = HashMap::new();
    let mut procs: Vec<u16> = Vec::new();
    let mut machine_of: Vec<u16> = Vec::new();
    for e in &trace.events {
        index.entry(e.proc).or_insert_with(|| {
            procs.push(e.proc);
            machine_of.push(e.machine);
            procs.len() - 1
        });
    }

    // join SEND↔RECV on (txid, iter); `send_st` carries the send's
    // *completion* time — our SEND ops occupy the tx wire (see profiler)
    let mut sends: HashMap<(u64, u32), (u16, f64)> = HashMap::new();
    for e in &trace.events {
        if e.kind == OpKind::Send {
            if let Some(t) = e.txid {
                sends.insert((t, e.iter), (e.proc, e.ts + e.dur));
            }
        }
    }
    // family = recv op name (same name across iterations)
    let mut fam_ids: HashMap<&str, u32> = HashMap::new();
    let mut obs = Vec::new();
    let mut deps = Vec::new();
    for e in &trace.events {
        if e.kind != OpKind::Recv {
            continue;
        }
        let Some(t) = e.txid else { continue };
        let Some(&(send_proc, send_st)) = sends.get(&(t, e.iter)) else { continue };
        if send_proc == e.proc {
            continue; // same clock: no information
        }
        let next = fam_ids.len() as u32;
        let fam = *fam_ids.entry(e.name.as_str()).or_insert(next);
        obs.push(RecvObs {
            family: fam,
            recv_proc: e.proc,
            send_proc,
            recv_st: e.ts,
            recv_ed: e.ts + e.dur,
            send_st,
        });
        // dependency: SEND starts before RECV *ends*
        deps.push((index[&send_proc], send_st, index[&e.proc], e.ts + e.dur));
    }
    Problem { procs, machine_of, obs, deps, index }
}

/// Solve the alignment QP for a trace. `a1`, `a2` follow the paper's
/// objective weights.
pub fn align(trace: &GTrace, a1: f64, a2: f64) -> Alignment {
    let p = build_problem(trace);
    if p.procs.len() <= 1 || p.obs.is_empty() {
        return Alignment::identity();
    }
    let sol = qp::solve(&p, a1, a2);
    let theta = p
        .procs
        .iter()
        .enumerate()
        .map(|(i, &proc)| (proc, sol.theta[i]))
        .collect();
    Alignment { theta, objective: sol.objective, iterations: sol.iterations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceEvent;

    /// Synthesize a trace with known drift: proc 0 on machine 0 (truth),
    /// proc 1 on machine 1 shifted by +5000 us. Sends from 0 at t, recv on
    /// 1 truly [t+10, t+60], recorded in drifted clock.
    fn synthetic_trace(drift: f64, iters: u32) -> GTrace {
        let mut events = Vec::new();
        for it in 0..iters {
            let base = it as f64 * 1000.0;
            for k in 0..4u64 {
                let t = base + 100.0 * k as f64;
                events.push(TraceEvent {
                    name: format!("send.{k}"),
                    kind: OpKind::Send,
                    ts: t,
                    dur: 8.0,
                    proc: 0,
                    machine: 0,
                    iter: it,
                    txid: Some(k + 1),
                });
                // true arrival [t+10, t+60]; the launch error varies per
                // iteration (queueing noise) — the variability O₁ exploits
                let launch_err = 3.0 + 9.0 * ((it as f64 * 1.7 + k as f64) % 5.0);
                events.push(TraceEvent {
                    name: format!("recv.{k}"),
                    kind: OpKind::Recv,
                    ts: t - launch_err + drift,
                    dur: 60.0 + launch_err,
                    proc: 1,
                    machine: 1,
                    iter: it,
                    txid: Some(k + 1),
                });
            }
        }
        GTrace { events, n_workers: 2, n_procs: 2, iterations: iters as usize }
    }

    #[test]
    fn problem_assembly() {
        let trace = synthetic_trace(5000.0, 3);
        let p = build_problem(&trace);
        assert_eq!(p.procs.len(), 2);
        assert_eq!(p.obs.len(), 12);
        assert_eq!(p.deps.len(), 12);
        // 4 families, 3 iterations each
        let fam_max = p.obs.iter().map(|o| o.family).max().unwrap();
        assert_eq!(fam_max, 3);
    }

    #[test]
    fn recovers_injected_drift() {
        let drift = 5000.0;
        let trace = synthetic_trace(drift, 5);
        let a = align(&trace, 1.0, 1.0);
        let theta1 = a.offset(1);
        // θ₁ should approximately cancel the drift: recorded+θ ≈ true.
        assert!(
            (theta1 + drift).abs() < 60.0,
            "theta1={theta1}, expected ≈ {}",
            -drift
        );
    }

    #[test]
    fn corrected_recv_duration_close_to_true_transfer() {
        let drift = 5000.0;
        let trace = synthetic_trace(drift, 5);
        let a = align(&trace, 1.0, 1.0);
        // true transfer is 50 us (arrival t+10 .. t+60); clipped estimate
        // uses send start t ⇒ 60 us upper bound.
        let o = &build_problem(&trace).obs[0];
        let d = a.recv_duration(o.recv_proc, o.recv_st, o.recv_ed, o.send_proc, o.send_st);
        assert!(
            (40.0..80.0).contains(&d),
            "corrected={d}, raw={}",
            o.recv_ed - o.recv_st
        );
    }

    #[test]
    fn identity_for_single_proc() {
        let mut trace = synthetic_trace(0.0, 1);
        trace.events.retain(|e| e.proc == 0);
        let a = align(&trace, 1.0, 1.0);
        assert_eq!(a.offset(0), 0.0);
        assert_eq!(a.offset(9), 0.0);
    }
}
