//! Solver for the alignment objective (paper §4.2 uses CVXPY; we implement
//! a projected-gradient / quadratic-penalty method specialized to the
//! problem: tens of variables, thousands of residual terms).
//!
//! Objective (minimize over θ, with θ₀ = 0):
//!   a₁ · Σ_families Var(clipped recv durations)            (O₁)
//! + a₂ · Σ_machines Var(θ of procs on the machine)          (O₂)
//! + ρ  · Σ_deps  max(0, (tᵢ+θᵢ) − (tⱼ+θⱼ))²                (constraints)
//!
//! O₁'s `max` makes the objective piecewise-quadratic; we use the
//! subgradient of the active branch, which is exact almost everywhere, with
//! Adam-style steps and a growing penalty weight. Converges in a few
//! hundred iterations for the traces we produce (≤ ~150 processes).

use super::Problem;

/// Solver output: θ per dense process index, plus convergence info.
pub struct Solution {
    /// Solved clock offsets, indexed like [`Problem::procs`].
    pub theta: Vec<f64>,
    /// Final objective value.
    pub objective: f64,
    /// Iterations performed before convergence or the cap.
    pub iterations: usize,
}

/// Evaluate objective and gradient at θ.
fn eval(p: &Problem, a1: f64, a2: f64, rho: f64, theta: &[f64], grad: &mut [f64]) -> f64 {
    for g in grad.iter_mut() {
        *g = 0.0;
    }
    let n_fam = p.obs.iter().map(|o| o.family).max().map(|m| m as usize + 1).unwrap_or(0);

    // O1: per-family variance of clipped durations.
    // duration d_k = ed_j + θ_j − max(st_j + θ_j, st_i + θ_i)
    // d(d_k)/dθ_j = 1 − [recv branch active]; d(d_k)/dθ_i = −[send branch active]
    let mut sums = vec![0.0f64; n_fam];
    let mut counts = vec![0u32; n_fam];
    let mut durs = vec![0.0f64; p.obs.len()];
    let mut branch_send = vec![false; p.obs.len()];
    for (k, o) in p.obs.iter().enumerate() {
        let j = p.index[&o.recv_proc];
        let i = p.index[&o.send_proc];
        let recv_start = o.recv_st + theta[j];
        let send_start = o.send_st + theta[i];
        let send_active = send_start > recv_start;
        let d = (o.recv_ed + theta[j]) - recv_start.max(send_start);
        durs[k] = d;
        branch_send[k] = send_active;
        sums[o.family as usize] += d;
        counts[o.family as usize] += 1;
    }
    let mut obj = 0.0;
    // variance gradient: d/dd_k Var = 2 (d_k − mean) / n
    for (k, o) in p.obs.iter().enumerate() {
        let f = o.family as usize;
        let n = counts[f] as f64;
        if n < 2.0 {
            continue;
        }
        let mean = sums[f] / n;
        let dev = durs[k] - mean;
        obj += a1 * dev * dev / n;
        let g = a1 * 2.0 * dev / n;
        let j = p.index[&o.recv_proc];
        let i = p.index[&o.send_proc];
        if branch_send[k] {
            // d = ed_j + θ_j − st_i − θ_i
            grad[j] += g;
            grad[i] -= g;
        }
        // else d = ed_j − st_j: no θ dependence
    }

    // O2: variance of θ per machine
    let n_machines = p.machine_of.iter().map(|&m| m as usize + 1).max().unwrap_or(0);
    let mut msum = vec![0.0f64; n_machines];
    let mut mcnt = vec![0u32; n_machines];
    for (i, &m) in p.machine_of.iter().enumerate() {
        msum[m as usize] += theta[i];
        mcnt[m as usize] += 1;
    }
    for (i, &m) in p.machine_of.iter().enumerate() {
        let n = mcnt[m as usize] as f64;
        if n < 2.0 {
            continue;
        }
        let dev = theta[i] - msum[m as usize] / n;
        obj += a2 * dev * dev / n;
        grad[i] += a2 * 2.0 * dev / n;
    }

    // Tie-breaker: the variance is flat wherever *every* family member is
    // clipped by its SEND, so among variance-minimal θ we prefer the least
    // clipping (trust measured RECV starts unless O₁ disagrees). Small
    // quadratic penalty on the clip amount.
    let eps = 0.02 * a1;
    for o in p.obs.iter() {
        let j = p.index[&o.recv_proc];
        let i = p.index[&o.send_proc];
        let clip = (o.send_st + theta[i]) - (o.recv_st + theta[j]);
        if clip > 0.0 {
            obj += eps * clip * clip / p.obs.len() as f64;
            let g = eps * 2.0 * clip / p.obs.len() as f64;
            grad[i] += g;
            grad[j] -= g;
        }
    }

    // dependency penalty: (t_i + θ_i) ≤ (t_j + θ_j)
    for &(i, ti, j, tj) in &p.deps {
        let v = (ti + theta[i]) - (tj + theta[j]);
        if v > 0.0 {
            obj += rho * v * v;
            grad[i] += rho * 2.0 * v;
            grad[j] -= rho * 2.0 * v;
        }
    }

    // θ₀ pinned to 0
    grad[0] = 0.0;
    obj
}

/// Solve with Adam + growing penalty. Deterministic.
pub fn solve(p: &Problem, a1: f64, a2: f64) -> Solution {
    let n = p.procs.len();
    let mut theta = vec![0.0f64; n];

    // Warm start: per-proc median of (recv_ed − send_st) offsets would need
    // true durations; instead initialize θ_j so the *minimum* observed
    // (send_st + θ_i) − recv_st gap is zero-ish: use mean of
    // send_st − recv_st per receiving proc (sender assumed aligned).
    let mut acc = vec![(0.0f64, 0u32); n];
    for o in &p.obs {
        let j = p.index[&o.recv_proc];
        acc[j].0 += o.send_st - o.recv_st;
        acc[j].1 += 1;
    }
    for jdx in 1..n {
        if acc[jdx].1 > 0 {
            theta[jdx] = acc[jdx].0 / acc[jdx].1 as f64;
        }
    }
    theta[0] = 0.0;

    let mut grad = vec![0.0f64; n];
    let mut m = vec![0.0f64; n];
    let mut v = vec![0.0f64; n];
    let (b1, b2, eps) = (0.9, 0.999, 1e-8);
    let mut rho = 1e-4;
    let mut obj = f64::INFINITY;
    let mut iters = 0;
    let max_iters = 4000;
    let mut last_improve = 0;
    let mut best = f64::INFINITY;

    for t in 1..=max_iters {
        iters = t;
        obj = eval(p, a1, a2, rho, &theta, &mut grad);
        if obj < best - 1e-9 * (1.0 + best.abs()) {
            best = obj;
            last_improve = t;
        } else if t - last_improve > 200 {
            break; // converged at this penalty level
        }
        let lr = 50.0 / (1.0 + t as f64 / 500.0);
        for i in 1..n {
            m[i] = b1 * m[i] + (1.0 - b1) * grad[i];
            v[i] = b2 * v[i] + (1.0 - b2) * grad[i] * grad[i];
            let mh = m[i] / (1.0 - b1.powi(t as i32));
            let vh = v[i] / (1.0 - b2.powi(t as i32));
            theta[i] -= lr * mh / (vh.sqrt() + eps);
        }
        if t % 500 == 0 {
            rho *= 4.0; // tighten constraints over time
            best = f64::INFINITY;
        }
    }
    Solution { theta, objective: obj, iterations: iters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alignment::RecvObs;
    use std::collections::HashMap;

    /// Two procs; recv durations within a family should be equalizable by
    /// shifting θ₁.
    fn toy_problem() -> Problem {
        let mut index = HashMap::new();
        index.insert(0u16, 0usize);
        index.insert(1u16, 1usize);
        let mut obs = Vec::new();
        // family 0: true transfer 50, recorded with recv clock +1000 and
        // launch 20 early
        for it in 0..6 {
            let t = 500.0 * it as f64;
            obs.push(RecvObs {
                family: 0,
                recv_proc: 1,
                send_proc: 0,
                recv_st: t - 20.0 + 1000.0,
                recv_ed: t + 50.0 + 1000.0,
                send_st: t,
            });
        }
        let deps = obs
            .iter()
            .map(|o| (0usize, o.send_st, 1usize, o.recv_ed))
            .collect();
        Problem {
            procs: vec![0, 1],
            machine_of: vec![0, 1],
            obs,
            deps,
            index,
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let p = toy_problem();
        let theta = vec![0.0, -900.0];
        let mut grad = vec![0.0; 2];
        let obj = eval(&p, 1.0, 1.0, 1.0, &theta, &mut grad);
        let h = 1e-4;
        let mut tp = theta.clone();
        tp[1] += h;
        let mut tmp = vec![0.0; 2];
        let obj2 = eval(&p, 1.0, 1.0, 1.0, &tp, &mut tmp);
        let fd = (obj2 - obj) / h;
        assert!(
            (fd - grad[1]).abs() < 1e-2 * (1.0 + fd.abs()),
            "fd={fd} grad={}",
            grad[1]
        );
    }

    #[test]
    fn solves_toy_to_low_objective() {
        let p = toy_problem();
        let sol = solve(&p, 1.0, 1.0);
        // the drift is -1000; anything within ±80 us collapses variance
        assert!(
            (sol.theta[1] + 1000.0).abs() < 80.0,
            "theta1={}",
            sol.theta[1]
        );
    }
}
