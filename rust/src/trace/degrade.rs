//! Degraded-trace scenario knobs: take a clean measured [`GTrace`] and
//! make it look like one collected on a sick cluster — extra per-machine
//! clock drift, dropped events (a profiler buffer overflowed, a worker
//! died mid-dump), straggler iterations (preemption / GC pause artifacts).
//!
//! These are *test and bench instruments*: `rust/tests/trace_io.rs` uses
//! them to pin that the ingestion pipeline diagnoses rather than panics
//! and that §4.2 alignment recovers injected drift; the
//! `fig8_time_alignment` bench tabulates replay error under each scenario.
//! All knobs are deterministic (seeded [`Pcg`]) and compose: apply several
//! in sequence to model a compounding failure.

use crate::trace::GTrace;
use crate::util::rng::Pcg;
use crate::util::Us;

/// Shift the clock of every event recorded on `machine` by `offset_us` —
/// the same per-machine drift the testbed injects, but chosen by the
/// caller so tests know the ground truth. Alignment (§4.2) should recover
/// `-offset_us` (relative to machine 0) from the degraded trace.
///
/// Returns the number of events shifted.
pub fn inject_drift(trace: &mut GTrace, machine: u16, offset_us: Us) -> usize {
    let mut n = 0;
    for e in &mut trace.events {
        if e.machine == machine {
            e.ts += offset_us;
            n += 1;
        }
    }
    n
}

/// Drop each event independently with probability `rate` (deterministic
/// for a given `seed`). Models lossy collection; dropping a SEND or RECV
/// breaks its transaction, which ingestion then flags as
/// [`UnmatchedTxid`](crate::trace::validate::DiagKind::UnmatchedTxid).
///
/// Returns the number of events removed.
pub fn drop_events(trace: &mut GTrace, rate: f64, seed: u64) -> usize {
    if rate <= 0.0 {
        return 0;
    }
    let mut rng = Pcg::new(seed, 0x9e37);
    let before = trace.events.len();
    trace.events.retain(|_| rng.f64() >= rate);
    before - trace.events.len()
}

/// Stretch every event duration of one iteration by `factor` — the trace
/// a whole-cluster straggler iteration (checkpoint stall, preemption,
/// page-cache storm) leaves behind. Timestamps are left as recorded, so
/// the stretched events overlap their successors exactly the way a
/// profiler that reports stale launch timestamps would show it; the
/// validator flags these as
/// [`OverlapOnProc`](crate::trace::validate::DiagKind::OverlapOnProc)
/// warnings and the profiler's averages absorb the inflated durations.
///
/// Returns the number of events stretched.
pub fn straggle_iteration(trace: &mut GTrace, iter: u32, factor: f64) -> usize {
    let mut n = 0;
    for e in &mut trace.events {
        if e.iter == iter {
            e.dur *= factor;
            n += 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dfg::OpKind;
    use crate::trace::validate::{validate, DiagKind, TraceReport};
    use crate::trace::TraceEvent;

    fn trace() -> GTrace {
        let mut events = Vec::new();
        for it in 0..3u32 {
            for p in 0..2u16 {
                events.push(TraceEvent {
                    name: format!("w{p}.FW.a"),
                    kind: OpKind::Forward,
                    ts: it as f64 * 1000.0,
                    dur: 100.0,
                    proc: p,
                    machine: p,
                    iter: it,
                    txid: None,
                });
                events.push(TraceEvent {
                    name: format!("w{p}.FW.b"),
                    kind: OpKind::Forward,
                    ts: it as f64 * 1000.0 + 110.0,
                    dur: 100.0,
                    proc: p,
                    machine: p,
                    iter: it,
                    txid: None,
                });
            }
        }
        GTrace { events, n_workers: 2, n_procs: 2, iterations: 3 }
    }

    #[test]
    fn drift_shifts_only_target_machine() {
        let mut t = trace();
        let orig = t.clone();
        let n = inject_drift(&mut t, 1, 5000.0);
        assert_eq!(n, 6);
        for (a, b) in t.events.iter().zip(&orig.events) {
            if a.machine == 1 {
                assert_eq!(a.ts, b.ts + 5000.0);
            } else {
                assert_eq!(a.ts, b.ts);
            }
            assert_eq!(a.dur, b.dur); // drift never changes durations
        }
    }

    #[test]
    fn drop_is_deterministic_and_rate_shaped() {
        let mut a = trace();
        let mut b = trace();
        let na = drop_events(&mut a, 0.5, 7);
        let nb = drop_events(&mut b, 0.5, 7);
        assert_eq!(na, nb);
        assert_eq!(a.events, b.events);
        assert!(na > 0 && na < 12, "na={na}");
        let mut c = trace();
        assert_eq!(drop_events(&mut c, 0.0, 7), 0);
        assert_eq!(c.events.len(), 12);
    }

    #[test]
    fn straggler_creates_detectable_overlap() {
        let mut t = trace();
        // events are 100 us long with a 10 us gap; 2x duration overlaps
        let n = straggle_iteration(&mut t, 1, 2.0);
        assert_eq!(n, 4);
        let mut r = TraceReport::default();
        validate(&t, &mut r);
        assert!(r.count(DiagKind::OverlapOnProc) >= 2, "{r}");
    }
}
