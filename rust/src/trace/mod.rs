//! gTrace: the global trace format the profiler emits and the replayer
//! consumes (paper §3). Events carry *measured* timestamps in the clock of
//! the process that recorded them — i.e. including per-machine clock drift
//! and the RECV launch-time error the alignment stage (§4.2) corrects.
//!
//! Serialization is Chrome-trace-format JSON (`ph:"X"` complete events), so
//! dumps load directly into `chrome://tracing` / Perfetto. [`io`] is the
//! on-disk pipeline (per-process dump directories + tolerant ingestion),
//! [`validate`] the diagnostic layer over untrusted traces, [`degrade`]
//! the scenario knobs that make a clean trace look like a sick cluster's.
//! `docs/TRACE_FORMAT.md` documents the serialized schema.

pub mod degrade;
pub mod io;
pub mod validate;

use std::collections::HashMap;

use crate::graph::dfg::OpKind;
use crate::util::intern::{self, OpId};
use crate::util::json::{parse, Json};
use crate::util::Us;

/// One measured op execution.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Op name — identical to the global-DFG node name, so traces join
    /// back onto the graph skeleton.
    pub name: String,
    /// Op kind (serialized via [`kind_str`] / [`kind_from_str`]).
    pub kind: OpKind,
    /// Measured start in the recording process's clock (us).
    pub ts: Us,
    /// Measured duration (us). For RECV ops this includes sender wait when
    /// the profiler can only observe the launch time (§2.2).
    pub dur: Us,
    /// Recording process (worker id, `n_workers + s` for server s,
    /// `u16::MAX` for the coordinator).
    pub proc: u16,
    /// Physical machine hosting `proc` (same machine ⇒ same clock).
    pub machine: u16,
    /// Training iteration the event belongs to.
    pub iter: u32,
    /// SEND↔RECV matching id (paper §4.1's transaction id).
    pub txid: Option<u64>,
}

/// A full multi-iteration global trace.
#[derive(Clone, Debug, Default)]
pub struct GTrace {
    /// All measured events, in recording order.
    pub events: Vec<TraceEvent>,
    /// Worker count of the traced job.
    pub n_workers: usize,
    /// Workers + PS servers (excludes the coordinator process).
    pub n_procs: usize,
    /// Training iterations the trace covers.
    pub iterations: usize,
}

impl GTrace {
    /// Average measured duration per op name — the per-op estimate the
    /// replayer uses ("averaging op execution time over 10 training
    /// iterations", §4.3). Aggregates by `&str` so each distinct op name
    /// is materialized once, not cloned per event (a 10-iteration trace
    /// repeats every name 10×).
    pub fn profile_db(&self) -> ProfileDb {
        let mut agg: HashMap<&str, (f64, u32)> = HashMap::new();
        for e in &self.events {
            let ent = agg.entry(e.name.as_str()).or_insert((0.0, 0));
            ent.0 += e.dur;
            ent.1 += 1;
        }
        ProfileDb {
            avg: agg
                .into_iter()
                .map(|(k, (s, c))| (intern::intern(k), s / c as f64))
                .collect(),
        }
    }

    /// Events of one iteration.
    pub fn iter_events(&self, iter: u32) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter().filter(move |e| e.iter == iter)
    }

    /// Serialize to Chrome trace format.
    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let mut o = Json::obj();
                o.set("name", Json::Str(e.name.clone()));
                o.set("ph", Json::Str("X".into()));
                o.set("ts", Json::Num(e.ts));
                o.set("dur", Json::Num(e.dur));
                o.set("pid", Json::Num(e.machine as f64));
                o.set("tid", Json::Num(e.proc as f64));
                let mut args = Json::obj();
                args.set("kind", Json::Str(kind_str(e.kind).into()));
                args.set("iter", Json::Num(e.iter as f64));
                if let Some(t) = e.txid {
                    args.set("txid", Json::Num(t as f64));
                }
                o.set("args", args);
                o
            })
            .collect();
        let mut root = Json::obj();
        root.set("traceEvents", Json::Arr(events));
        let mut meta = Json::obj();
        meta.set("n_workers", Json::Num(self.n_workers as f64));
        meta.set("n_procs", Json::Num(self.n_procs as f64));
        meta.set("iterations", Json::Num(self.iterations as f64));
        root.set("dpro", meta);
        root
    }

    /// Write the single-file Chrome-trace form (see [`io`] for the
    /// per-process directory form).
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Parse the single-file form produced by [`GTrace::to_json`]. Strict
    /// (errors on missing fields) — the tolerant path for external traces
    /// is [`io::load_dir`].
    pub fn from_json(j: &Json) -> Result<GTrace, String> {
        let meta = j.get("dpro").ok_or("missing dpro metadata")?;
        let events = j
            .get("traceEvents")
            .and_then(Json::as_arr)
            .ok_or("missing traceEvents")?;
        let mut out = GTrace {
            events: Vec::with_capacity(events.len()),
            n_workers: meta.f64("n_workers") as usize,
            n_procs: meta.f64("n_procs") as usize,
            iterations: meta.f64("iterations") as usize,
        };
        for e in events {
            let args = e.get("args").ok_or("event missing args")?;
            out.events.push(TraceEvent {
                name: e.str("name").to_string(),
                kind: kind_from_str(args.str("kind"))?,
                ts: e.f64("ts"),
                dur: e.f64("dur"),
                proc: e.f64("tid") as u16,
                machine: e.f64("pid") as u16,
                iter: args.f64("iter") as u32,
                txid: args.get("txid").and_then(Json::as_f64).map(|x| x as u64),
            });
        }
        Ok(out)
    }

    /// Load the single-file form written by [`GTrace::save`].
    pub fn load(path: &str) -> Result<GTrace, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        GTrace::from_json(&parse(&text)?)
    }
}

/// Per-op average durations from a trace, keyed by interned [`OpId`] —
/// the graph join in [`ProfileDb::apply`] is an integer map hit per
/// node, no string hashing on the hot path.
#[derive(Clone, Debug, Default)]
pub struct ProfileDb {
    avg: HashMap<OpId, f64>,
}

impl ProfileDb {
    /// Average measured duration of an op, if the trace covered it. A
    /// name no node ever carried can't have been inserted either, so
    /// the interner miss short-circuits to `None` without interning.
    pub fn get(&self, name: &str) -> Option<Us> {
        self.get_id(intern::lookup(name)?)
    }

    /// Average measured duration by interned id.
    pub fn get_id(&self, id: OpId) -> Option<Us> {
        self.avg.get(&id).copied()
    }

    /// Number of distinct ops with a measurement.
    pub fn len(&self) -> usize {
        self.avg.len()
    }

    /// True when no op has a measurement.
    pub fn is_empty(&self) -> bool {
        self.avg.is_empty()
    }

    /// Insert/overwrite one op's average duration.
    pub fn insert(&mut self, name: String, dur: Us) {
        self.avg.insert(intern::intern(&name), dur);
    }

    /// Overwrite the durations of a global DFG's nodes with profiled
    /// averages (nodes without a measurement keep their analytic value).
    pub fn apply(&self, g: &mut crate::graph::GlobalDfg) -> usize {
        let mut applied = 0;
        for n in &mut g.dfg.nodes {
            if let Some(d) = self.get_id(n.name) {
                n.duration = d;
                applied += 1;
            }
        }
        applied
    }
}

/// Serialized form of an op kind (`args.kind` in trace files).
pub fn kind_str(k: OpKind) -> &'static str {
    match k {
        OpKind::Forward => "FW",
        OpKind::Backward => "BW",
        OpKind::Update => "UPD",
        OpKind::Negotiate => "NEG",
        OpKind::Send => "SEND",
        OpKind::Recv => "RECV",
        OpKind::Aggregate => "AGG",
        OpKind::In => "IN",
        OpKind::Out => "OUT",
    }
}

/// Inverse of [`kind_str`]; errors on unknown labels.
pub fn kind_from_str(s: &str) -> Result<OpKind, String> {
    Ok(match s {
        "FW" => OpKind::Forward,
        "BW" => OpKind::Backward,
        "UPD" => OpKind::Update,
        "NEG" => OpKind::Negotiate,
        "SEND" => OpKind::Send,
        "RECV" => OpKind::Recv,
        "AGG" => OpKind::Aggregate,
        "IN" => OpKind::In,
        "OUT" => OpKind::Out,
        other => return Err(format!("unknown op kind {other:?}")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &str, iter: u32, dur: f64) -> TraceEvent {
        TraceEvent {
            name: name.into(),
            kind: OpKind::Forward,
            ts: 0.0,
            dur,
            proc: 0,
            machine: 0,
            iter,
            txid: None,
        }
    }

    #[test]
    fn profile_db_averages_over_iterations() {
        let trace = GTrace {
            events: vec![ev("a", 0, 10.0), ev("a", 1, 14.0), ev("b", 0, 5.0)],
            n_workers: 1,
            n_procs: 1,
            iterations: 2,
        };
        let db = trace.profile_db();
        assert_eq!(db.get("a"), Some(12.0));
        assert_eq!(db.get("b"), Some(5.0));
        assert_eq!(db.get("c"), None);
    }

    #[test]
    fn json_roundtrip() {
        let mut e = ev("w0.FW.conv1", 3, 42.5);
        e.txid = Some(77);
        e.kind = OpKind::Recv;
        let trace = GTrace { events: vec![e], n_workers: 2, n_procs: 3, iterations: 4 };
        let j = trace.to_json();
        let back = GTrace::from_json(&j).unwrap();
        assert_eq!(back.events.len(), 1);
        let b = &back.events[0];
        assert_eq!(b.name, "w0.FW.conv1");
        assert_eq!(b.kind, OpKind::Recv);
        assert_eq!(b.dur, 42.5);
        assert_eq!(b.iter, 3);
        assert_eq!(b.txid, Some(77));
        assert_eq!(back.n_procs, 3);
    }

    #[test]
    fn kind_str_roundtrip() {
        for k in [
            OpKind::Forward,
            OpKind::Backward,
            OpKind::Update,
            OpKind::Negotiate,
            OpKind::Send,
            OpKind::Recv,
            OpKind::Aggregate,
            OpKind::In,
            OpKind::Out,
        ] {
            assert_eq!(kind_from_str(kind_str(k)).unwrap(), k);
        }
        assert!(kind_from_str("nope").is_err());
    }
}
