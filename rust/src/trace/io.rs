//! On-disk trace pipeline (paper Fig. 3): dump a [`GTrace`] as per-process
//! Chrome-trace JSON files, and ingest a trace directory back into a
//! replayer-ready [`GTrace`] — tolerantly, with every anomaly collected
//! into a [`TraceReport`](crate::trace::validate::TraceReport) instead of
//! panicking.
//!
//! A dump directory contains `metadata.json` (trace shape + optional job
//! descriptor) and one `proc_<id>.json` per recording process. Each
//! process file is standard Chrome trace format (`ph:"X"` complete
//! events, `pid` = machine, `tid` = process), so it loads directly in
//! Perfetto / `chrome://tracing`; dPRO-specific context (`kind`, `proc`,
//! `machine`, `iter`, `txid`, `seq`) rides in `args`, which those viewers
//! display and other tools ignore. See `docs/TRACE_FORMAT.md` for the
//! field-by-field schema.
//!
//! # Worked example (two workers, one SEND↔RECV transaction)
//!
//! The receiver's file of the two-worker trace in `docs/TRACE_FORMAT.md`
//! (worker 1 lives on machine 1, whose clock runs 2 ms ahead; the RECV's
//! `ts` is the *launch* time, so its duration includes sender wait):
//!
//! ```
//! use dpro::trace::io::parse_trace_file;
//! use dpro::trace::validate::TraceReport;
//!
//! let file = r#"{
//!   "traceEvents": [
//!     {"name": "w1.FW.toy_stem", "ph": "X", "ts": 2000, "dur": 95,
//!      "pid": 1, "tid": 1,
//!      "args": {"kind": "FW", "proc": 1, "machine": 1, "iter": 0, "seq": 2}},
//!     {"name": "w1.RECV.g0", "ph": "X", "ts": 2095, "dur": 95,
//!      "pid": 1, "tid": 1,
//!      "args": {"kind": "RECV", "proc": 1, "machine": 1, "iter": 0,
//!               "txid": 1, "seq": 3}}
//!   ],
//!   "dpro": {"proc": 1}
//! }"#;
//!
//! let mut report = TraceReport::default();
//! let events = parse_trace_file(file, "proc_00001.json", &mut report)
//!     .expect("well-formed file");
//! assert!(report.is_clean());
//! assert_eq!(events.len(), 2);
//! let (seq, recv) = &events[1];
//! assert_eq!(*seq, Some(3));
//! assert_eq!(recv.name, "w1.RECV.g0");
//! assert_eq!(recv.txid, Some(1));
//! assert_eq!(recv.machine, 1);
//! // measured duration includes the launch error the §4.2 alignment
//! // stage later clips against the matching SEND (txid 1)
//! assert_eq!(recv.dur, 95.0);
//! ```

use std::collections::BTreeMap;
use std::io;
use std::path::{Path, PathBuf};

use crate::config::JobSpec;
use crate::graph::dfg::{OpKind, COORD_PROC};
use crate::trace::validate::{validate, DiagKind, Severity, TraceReport};
use crate::trace::{kind_from_str, kind_str, GTrace, TraceEvent};
use crate::util::json::{parse, Json};

/// Version tag written into `metadata.json` (`dpro.format`). Readers
/// accept any value — unknown fields and future versions degrade to
/// diagnostics, not failures.
pub const TRACE_FORMAT_VERSION: f64 = 1.0;

/// Name of the per-directory metadata file.
pub const METADATA_FILE: &str = "metadata.json";

/// The job context a dump optionally carries so `dpro replay --trace-dir`
/// can rebuild the DFG skeleton without the user re-specifying the job on
/// the command line.
#[derive(Clone, Debug, PartialEq)]
pub struct JobMeta {
    /// Model template name (`resnet50`, `bert_base`, ...).
    pub model: String,
    /// Canonical communication-scheme name (an [`crate::config::ALL_SCHEMES`] entry).
    pub scheme: String,
    /// Transport name (`rdma` / `tcp`), lower-case.
    pub transport: String,
    /// Worker count of the job.
    pub n_workers: usize,
    /// GPUs per physical machine (machine layout of the cluster).
    pub gpus_per_machine: usize,
    /// Comm/fusion plan family: `"per-tensor"` (unoptimized singleton
    /// plans) or `"deployed"` (framework-default fusion buckets). The
    /// replay skeleton's op names depend on it, so a dump must record it
    /// or the profiled durations would silently fail to join.
    pub plan: String,
}

/// The `plan` label of an unoptimized per-tensor/singleton spec.
pub const PLAN_PER_TENSOR: &str = "per-tensor";
/// The `plan` label of a deployed-defaults spec (the CLI default).
pub const PLAN_DEPLOYED: &str = "deployed";

impl JobMeta {
    /// Capture the replay-relevant shape of a [`JobSpec`]. The plan
    /// family is derived structurally: singleton one-partition groups and
    /// singleton fusion ⇒ per-tensor, anything else ⇒ deployed.
    pub fn of(spec: &JobSpec) -> JobMeta {
        let per_tensor = spec.plan.groups.len() == spec.model.tensors.len()
            && spec.plan.groups.iter().all(|g| g.tensors.len() == 1 && g.partitions == 1)
            && spec.fusion.groups.iter().all(|g| g.len() == 1);
        JobMeta {
            model: spec.model.name.clone(),
            scheme: spec.scheme.cli_name().to_string(),
            transport: spec.cluster.network.transport.name().to_lowercase(),
            n_workers: spec.cluster.n_workers,
            gpus_per_machine: spec.cluster.gpus_per_machine,
            plan: if per_tensor { PLAN_PER_TENSOR } else { PLAN_DEPLOYED }.to_string(),
        }
    }

    /// Serialize for the `job` section of `metadata.json`.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("model", Json::Str(self.model.clone()));
        j.set("scheme", Json::Str(self.scheme.clone()));
        j.set("transport", Json::Str(self.transport.clone()));
        j.set("n_workers", Json::Num(self.n_workers as f64));
        j.set("gpus_per_machine", Json::Num(self.gpus_per_machine as f64));
        j.set("plan", Json::Str(self.plan.clone()));
        j
    }

    /// Parse the `job` section. Returns `None` (not an error) when any of
    /// the required fields is missing or mistyped.
    pub fn from_json(j: &Json) -> Option<JobMeta> {
        Some(JobMeta {
            model: j.get("model")?.as_str()?.to_string(),
            scheme: j.get("scheme")?.as_str()?.to_string(),
            transport: j.get("transport")?.as_str()?.to_string(),
            n_workers: j.get("n_workers")?.as_f64()? as usize,
            gpus_per_machine: j.get("gpus_per_machine")?.as_f64()?.max(1.0) as usize,
            // older dumps lack the field; the CLI default is deployed
            plan: j
                .get("plan")
                .and_then(Json::as_str)
                .unwrap_or(PLAN_DEPLOYED)
                .to_string(),
        })
    }
}

/// What [`dump_dir`] wrote.
#[derive(Clone, Debug)]
pub struct DumpSummary {
    /// The dump directory.
    pub dir: PathBuf,
    /// Number of per-process trace files written (excludes metadata).
    pub files: usize,
    /// Total events written across all files.
    pub events: usize,
}

/// File name of the per-process trace of `proc` (zero-padded so
/// lexicographic directory order equals process order).
pub fn proc_file_name(proc: u16) -> String {
    format!("proc_{proc:05}.json")
}

/// Dump a trace as a directory of per-process Chrome-trace files (no job
/// descriptor). See [`dump_dir_with_job`].
pub fn dump_dir(trace: &GTrace, dir: &Path) -> io::Result<DumpSummary> {
    dump_dir_with_job(trace, dir, None)
}

/// Dump a trace as a directory of per-process Chrome-trace files plus
/// `metadata.json`, creating `dir` if needed. Stale `proc_*.json` files
/// from a previous dump are removed first (the reader ingests every
/// trace file in the directory, so leftovers from a larger job would
/// silently merge into the new trace).
///
/// Events keep their in-memory order: each event's position in
/// [`GTrace::events`] is written as `args.seq`, and the reader re-sorts by
/// it, so `dump → load` reproduces the source trace — and therefore the
/// source replay — bit-for-bit (pinned by `rust/tests/trace_io.rs`).
pub fn dump_dir_with_job(
    trace: &GTrace,
    dir: &Path,
    job: Option<&JobMeta>,
) -> io::Result<DumpSummary> {
    std::fs::create_dir_all(dir)?;
    // clear previous per-process files so the dump is the directory's
    // whole truth
    for entry in std::fs::read_dir(dir)?.filter_map(|e| e.ok()) {
        if let Ok(name) = entry.file_name().into_string() {
            if name.starts_with("proc_") && name.ends_with(".json") {
                std::fs::remove_file(entry.path())?;
            }
        }
    }

    // group per process, preserving global emission order
    let mut per_proc: BTreeMap<u16, Vec<Json>> = BTreeMap::new();
    for (seq, e) in trace.events.iter().enumerate() {
        per_proc.entry(e.proc).or_default().push(event_to_json(e, seq as u64));
    }

    let mut meta = Json::obj();
    let mut dpro = Json::obj();
    dpro.set("format", Json::Num(TRACE_FORMAT_VERSION));
    dpro.set("n_workers", Json::Num(trace.n_workers as f64));
    dpro.set("n_procs", Json::Num(trace.n_procs as f64));
    dpro.set("iterations", Json::Num(trace.iterations as f64));
    dpro.set(
        "files",
        Json::Arr(per_proc.keys().map(|&p| Json::Str(proc_file_name(p))).collect()),
    );
    meta.set("dpro", dpro);
    if let Some(job) = job {
        meta.set("job", job.to_json());
    }
    std::fs::write(dir.join(METADATA_FILE), meta.to_string_pretty())?;

    let mut files = 0;
    for (proc, events) in per_proc {
        let mut root = Json::obj();
        root.set("traceEvents", Json::Arr(events));
        let mut d = Json::obj();
        d.set("proc", Json::Num(proc as f64));
        root.set("dpro", d);
        std::fs::write(dir.join(proc_file_name(proc)), root.to_string_pretty())?;
        files += 1;
    }
    Ok(DumpSummary { dir: dir.to_path_buf(), files, events: trace.events.len() })
}

/// One trace event as a Chrome-trace `ph:"X"` complete event.
fn event_to_json(e: &TraceEvent, seq: u64) -> Json {
    let mut o = Json::obj();
    o.set("name", Json::Str(e.name.clone()));
    o.set("ph", Json::Str("X".into()));
    o.set("ts", Json::Num(e.ts));
    o.set("dur", Json::Num(e.dur));
    o.set("pid", Json::Num(e.machine as f64));
    o.set("tid", Json::Num(e.proc as f64));
    let mut args = Json::obj();
    args.set("kind", Json::Str(kind_str(e.kind).into()));
    args.set("proc", Json::Num(e.proc as f64));
    args.set("machine", Json::Num(e.machine as f64));
    args.set("iter", Json::Num(e.iter as f64));
    if let Some(t) = e.txid {
        args.set("txid", Json::Num(t as f64));
    }
    args.set("seq", Json::Num(seq as f64));
    o.set("args", args);
    o
}

/// A trace directory, ingested.
#[derive(Clone, Debug)]
pub struct LoadedTrace {
    /// The assembled trace (usable events only).
    pub trace: GTrace,
    /// Everything the reader and validator flagged along the way.
    pub report: TraceReport,
    /// The job descriptor from `metadata.json`, if one was present.
    pub job: Option<JobMeta>,
}

/// Ingest a trace directory written by [`dump_dir_with_job`] — or by hand.
///
/// Tolerant by design: unknown fields are ignored, individual broken
/// events (missing fields, NaN times, unknown kinds) are skipped with a
/// diagnostic, unparsable files are skipped with a diagnostic, and
/// structural anomalies (unmatched SEND↔RECV txids, overlapping compute,
/// iteration gaps) are collected by
/// [`validate`](crate::trace::validate::validate). The only hard errors
/// are an unreadable directory and a directory with no trace files.
pub fn load_dir(dir: &Path) -> Result<LoadedTrace, String> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("cannot read trace dir {}: {e}", dir.display()))?;
    let files: Vec<NamedFile> = entries
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().into_string().ok().map(|n| (n, e.path())))
        .map(|(name, path)| {
            let content = std::fs::read_to_string(&path).map_err(|e| e.to_string());
            (name, content)
        })
        .collect();
    assemble(files, &dir.display().to_string())
}

/// Ingest a trace delivered as in-memory `(file name, contents)` pairs —
/// the upload path of `dpro serve`, where a client POSTs the same files a
/// dump directory would hold (`metadata.json` + `proc_*.json`, or any
/// Chrome-trace files) without them ever touching disk. Same tolerance
/// rules, diagnostics, and assembled result as [`load_dir`]: the two
/// fronts share one assembly core, so a dump ingested from disk and the
/// identical dump ingested from memory produce bit-for-bit equal traces.
pub fn load_mem(files: &[(String, String)]) -> Result<LoadedTrace, String> {
    assemble(
        files.iter().map(|(n, t)| (n.clone(), Ok(t.clone()))).collect(),
        "upload",
    )
}

/// A named trace file and its contents; `Err` carries a read error for
/// sources (directory listings) where the name is known but the bytes
/// could not be fetched — reported as an `Io` diagnostic, not a failure.
type NamedFile = (String, Result<String, String>);

/// Shared assembly core of [`load_dir`] / [`load_mem`]: metadata lookup,
/// file-list scoping, per-file parsing, deterministic event ordering, and
/// shape inference. `origin` labels error messages ("upload", a dir path).
fn assemble(mut files: Vec<NamedFile>, origin: &str) -> Result<LoadedTrace, String> {
    files.sort_by(|a, b| a.0.cmp(&b.0));
    files.retain(|(n, _)| n.ends_with(".json"));
    if files.is_empty() {
        return Err(format!("no .json trace files in {origin}"));
    }
    let names: Vec<String> = files.iter().map(|(n, _)| n.clone()).collect();

    let mut report = TraceReport::default();

    // --- metadata ---
    let mut meta_workers: Option<usize> = None;
    let mut meta_procs: Option<usize> = None;
    let mut meta_iters: Option<usize> = None;
    let mut meta_files: Option<Vec<String>> = None;
    let mut job: Option<JobMeta> = None;
    if let Some((_, content)) = files.iter().find(|(n, _)| n == METADATA_FILE) {
        match content {
            Err(e) => report.push(Severity::Error, DiagKind::Io, format!("{METADATA_FILE}: {e}")),
            Ok(text) => match parse(text) {
                Err(e) => {
                    report.push(Severity::Error, DiagKind::Parse, format!("{METADATA_FILE}: {e}"))
                }
                Ok(j) => {
                    if let Some(d) = j.get("dpro") {
                        meta_workers = d.get("n_workers").and_then(Json::as_f64).map(|x| x as usize);
                        meta_procs = d.get("n_procs").and_then(Json::as_f64).map(|x| x as usize);
                        meta_iters = d.get("iterations").and_then(Json::as_f64).map(|x| x as usize);
                        meta_files = d.get("files").and_then(Json::as_arr).map(|a| {
                            a.iter().filter_map(Json::as_str).map(str::to_string).collect()
                        });
                    }
                    if let Some(jj) = j.get("job") {
                        job = JobMeta::from_json(jj);
                        if job.is_none() {
                            report.push(
                                Severity::Warning,
                                DiagKind::MetadataMismatch,
                                "metadata job section present but incomplete; ignoring it",
                            );
                        }
                    }
                }
            },
        }
    } else {
        report.push(
            Severity::Info,
            DiagKind::MetadataMismatch,
            format!("no {METADATA_FILE}; trace shape inferred from events"),
        );
    }

    // --- per-process files ---
    // when metadata lists its files, it scopes the ingestion: a stale
    // legacy trace.json (or any unrelated .json) in the same directory
    // must not silently merge into the dump
    let trace_files: Vec<&String> = match &meta_files {
        Some(listed) => {
            for extra in names
                .iter()
                .filter(|n| n.as_str() != METADATA_FILE && !listed.contains(*n))
            {
                report.push(
                    Severity::Warning,
                    DiagKind::MetadataMismatch,
                    format!("{extra}: not in metadata file list; ignored"),
                );
            }
            // the converse means a whole process's events are missing
            // (partial copy, dead worker) — the on-disk signature of a
            // lost worker. Ingest what survives and flag the loss so the
            // diagnosis engine can attribute it and offer the
            // `continue-on:<k>` counterfactual; a hard error here would
            // make a crashed worker unanalyzable exactly when analysis
            // matters most (see docs/FAULTS.md).
            for gone in listed.iter().filter(|f| !names.contains(*f)) {
                report.push(
                    Severity::Warning,
                    DiagKind::WorkerLost,
                    format!(
                        "{gone}: listed in metadata but missing from the directory \
                         — its process contributes no events (dead worker?)"
                    ),
                );
            }
            names
                .iter()
                .filter(|n| n.as_str() != METADATA_FILE && listed.contains(*n))
                .collect()
        }
        None => names.iter().filter(|n| n.as_str() != METADATA_FILE).collect(),
    };
    if trace_files.is_empty() {
        return Err(format!("no trace files in {origin}"));
    }
    let mut tagged: Vec<(Option<u64>, TraceEvent)> = Vec::new();
    for name in trace_files {
        // membership in `files` is how `name` got selected, so the lookup
        // cannot miss
        let content = &files.iter().find(|(n, _)| n == name).expect("selected file").1;
        match content {
            Err(e) => report.push(Severity::Error, DiagKind::Io, format!("{name}: {e}")),
            Ok(text) => {
                if let Some(events) = parse_trace_file(text, name, &mut report) {
                    report.files += 1;
                    tagged.extend(events);
                }
            }
        }
    }

    // --- deterministic event order ---
    // `seq` restores the recorder's exact emission order (required for
    // bit-for-bit replay equality: f64 sums depend on order). Without a
    // complete set of seqs, fall back to a deterministic (iter, ts, proc)
    // sort and say so.
    if tagged.iter().all(|(s, _)| s.is_some()) {
        tagged.sort_by_key(|(s, _)| s.unwrap());
    } else {
        let missing = tagged.iter().filter(|(s, _)| s.is_none()).count();
        report.push(
            Severity::Info,
            DiagKind::MissingSeq,
            format!("{missing} events lack args.seq; using (iter, ts, proc) order"),
        );
        tagged.sort_by(|(_, a), (_, b)| {
            a.iter.cmp(&b.iter).then(a.ts.total_cmp(&b.ts)).then(a.proc.cmp(&b.proc))
        });
    }
    let events: Vec<TraceEvent> = tagged.into_iter().map(|(_, e)| e).collect();

    // --- trace shape: metadata wins, events fill the gaps ---
    let seen_procs: std::collections::BTreeSet<u16> =
        events.iter().map(|e| e.proc).filter(|&p| p != COORD_PROC).collect();
    // inferred proc count is max+1 (a missing worker's file must not
    // shrink the arena below the ids actually present)
    let inferred_procs = seen_procs.iter().max().map(|&p| p as usize + 1).unwrap_or(0);
    let n_procs = meta_procs.unwrap_or(inferred_procs);
    let n_workers = meta_workers.unwrap_or(n_procs);
    let iterations =
        meta_iters.unwrap_or_else(|| events.iter().map(|e| e.iter as usize + 1).max().unwrap_or(0));
    if meta_procs.is_some_and(|declared| inferred_procs > declared) {
        report.push(
            Severity::Warning,
            DiagKind::MetadataMismatch,
            format!(
                "events from proc {} but metadata declares {n_procs} procs",
                inferred_procs - 1
            ),
        );
    }

    report.events_loaded = events.len();
    let trace = GTrace { events, n_workers, n_procs, iterations };
    validate(&trace, &mut report);
    Ok(LoadedTrace { trace, report, job })
}

/// Parse one Chrome-trace file (either `{"traceEvents": [...]}` or a bare
/// top-level event array) into `(seq, event)` pairs, appending per-event
/// findings to `report`. Returns `None` (with a `parse` diagnostic) when
/// the file is not usable at all; `Some` means the file parsed, even if
/// every individual event was skipped. Public so tests and the format
/// documentation's worked example can exercise the exact ingestion rules.
pub fn parse_trace_file(
    text: &str,
    label: &str,
    report: &mut TraceReport,
) -> Option<Vec<(Option<u64>, TraceEvent)>> {
    let root = match parse(text) {
        Ok(j) => j,
        Err(e) => {
            report.push(Severity::Error, DiagKind::Parse, format!("{label}: {e}"));
            return None;
        }
    };
    let events = match root.get("traceEvents").and_then(Json::as_arr) {
        Some(a) => a,
        None => match root.as_arr() {
            Some(a) => a,
            None => {
                report.push(
                    Severity::Error,
                    DiagKind::Parse,
                    format!("{label}: no traceEvents array"),
                );
                return None;
            }
        },
    };
    let mut out = Vec::with_capacity(events.len());
    for (idx, e) in events.iter().enumerate() {
        match parse_event(e, label, idx, report) {
            Some(pair) => out.push(pair),
            None => report.events_skipped += 1,
        }
    }
    Some(out)
}

/// Field of an event that must be a finite number. Distinguishes "absent"
/// from "present but null/NaN" (our writer serializes NaN as `null`).
fn finite_num(
    e: &Json,
    key: &str,
    label: &str,
    idx: usize,
    report: &mut TraceReport,
) -> Option<f64> {
    match e.get(key) {
        None => {
            report.push(
                Severity::Error,
                DiagKind::MissingField,
                format!("{label}[{idx}]: missing {key}"),
            );
            None
        }
        Some(v) => match v.as_f64() {
            Some(x) if x.is_finite() => Some(x),
            _ => {
                report.push(
                    Severity::Error,
                    DiagKind::NonFiniteTime,
                    format!("{label}[{idx}]: {key} is not a finite number"),
                );
                None
            }
        },
    }
}

/// Parse one event object; `None` means it was skipped (with a diagnostic
/// already recorded).
fn parse_event(
    e: &Json,
    label: &str,
    idx: usize,
    report: &mut TraceReport,
) -> Option<(Option<u64>, TraceEvent)> {
    // tolerate non-complete phases (metadata, counters, ...) from other
    // producers: note and skip
    if let Some(ph) = e.get("ph").and_then(Json::as_str) {
        if ph != "X" {
            report.push(
                Severity::Info,
                DiagKind::NonCompleteEvent,
                format!("{label}[{idx}]: ph {ph:?} ignored"),
            );
            return None;
        }
    }
    let name = match e.get("name").and_then(Json::as_str) {
        Some(n) => n.to_string(),
        None => {
            report.push(
                Severity::Error,
                DiagKind::MissingField,
                format!("{label}[{idx}]: missing name"),
            );
            return None;
        }
    };
    let ts = finite_num(e, "ts", label, idx, report)?;
    let mut dur = finite_num(e, "dur", label, idx, report)?;
    if dur < 0.0 {
        report.push(
            Severity::Warning,
            DiagKind::NegativeDuration,
            format!("{label}[{idx}]: {name}: dur {dur} clamped to 0"),
        );
        dur = 0.0;
    }
    let args = e.get("args");
    let arg = |key: &str| args.and_then(|a| a.get(key));

    let kind = match arg("kind").and_then(Json::as_str) {
        Some(s) => match kind_from_str(s) {
            Ok(k) => k,
            Err(_) => {
                report.push(
                    Severity::Error,
                    DiagKind::UnknownKind,
                    format!("{label}[{idx}]: {name}: unknown kind {s:?}"),
                );
                return None;
            }
        },
        None => match infer_kind(&name) {
            Some(k) => k,
            None => {
                report.push(
                    Severity::Error,
                    DiagKind::UnknownKind,
                    format!("{label}[{idx}]: {name}: no args.kind and name gives no hint"),
                );
                return None;
            }
        },
    };

    // proc: args.proc, falling back to Chrome's tid
    let proc_raw = arg("proc").and_then(Json::as_f64).or_else(|| e.get("tid").and_then(Json::as_f64));
    let proc = match proc_raw {
        Some(p) if (0.0..=u16::MAX as f64).contains(&p) => p as u16,
        Some(p) => {
            report.push(
                Severity::Error,
                DiagKind::MetadataMismatch,
                format!("{label}[{idx}]: {name}: proc {p} out of range"),
            );
            return None;
        }
        None => {
            report.push(
                Severity::Error,
                DiagKind::MissingField,
                format!("{label}[{idx}]: {name}: no args.proc or tid"),
            );
            return None;
        }
    };
    // machine: args.machine, falling back to Chrome's pid, then 0
    let machine = match arg("machine")
        .and_then(Json::as_f64)
        .or_else(|| e.get("pid").and_then(Json::as_f64))
    {
        Some(m) if (0.0..=u16::MAX as f64).contains(&m) => m as u16,
        _ => {
            report.push(
                Severity::Warning,
                DiagKind::MissingField,
                format!("{label}[{idx}]: {name}: no machine/pid; assuming machine 0"),
            );
            0
        }
    };
    let iter = match arg("iter").and_then(Json::as_f64) {
        Some(i) if i >= 0.0 => i as u32,
        _ => {
            report.push(
                Severity::Info,
                DiagKind::MissingField,
                format!("{label}[{idx}]: {name}: no args.iter; assuming iteration 0"),
            );
            0
        }
    };
    // negative ids would saturate to 0 via `as u64` and silently collide
    // with genuine txid/seq 0 — diagnose and treat as absent instead
    let txid = match arg("txid").and_then(Json::as_f64) {
        Some(t) if t >= 0.0 => Some(t as u64),
        Some(t) => {
            report.push(
                Severity::Warning,
                DiagKind::InvalidValue,
                format!("{label}[{idx}]: {name}: negative txid {t} ignored"),
            );
            None
        }
        None => None,
    };
    let seq = match arg("seq").and_then(Json::as_f64) {
        Some(s) if s >= 0.0 => Some(s as u64),
        Some(s) => {
            report.push(
                Severity::Warning,
                DiagKind::InvalidValue,
                format!("{label}[{idx}]: {name}: negative seq {s} ignored"),
            );
            None
        }
        None => None,
    };

    Some((seq, TraceEvent { name, kind, ts, dur, proc, machine, iter, txid }))
}

/// Guess an op kind from a dPRO-style op name (`w3.BW.conv1`,
/// `w0.SEND.g4.m1>m0`...). Used only when `args.kind` is absent.
fn infer_kind(name: &str) -> Option<OpKind> {
    for part in name.split('.') {
        if let Ok(k) = kind_from_str(part) {
            return Some(k);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::validate::DiagKind;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "dpro_io_test_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn toy_trace() -> GTrace {
        let ev = |name: &str, kind: OpKind, ts: f64, dur: f64, proc: u16, txid: Option<u64>| {
            TraceEvent { name: name.into(), kind, ts, dur, proc, machine: proc, iter: 0, txid }
        };
        GTrace {
            events: vec![
                ev("w0.FW.a", OpKind::Forward, 0.0, 100.0, 0, None),
                ev("w0.SEND.t", OpKind::Send, 100.0, 40.0, 0, Some(1)),
                ev("w1.FW.a", OpKind::Forward, 2000.0, 95.0, 1, None),
                ev("w1.RECV.t", OpKind::Recv, 2095.0, 95.0, 1, Some(1)),
            ],
            n_workers: 2,
            n_procs: 2,
            iterations: 1,
        }
    }

    #[test]
    fn dump_then_load_roundtrips_exactly() {
        let dir = tmp_dir("roundtrip");
        let trace = toy_trace();
        let s = dump_dir(&trace, &dir).unwrap();
        assert_eq!(s.files, 2);
        assert_eq!(s.events, 4);
        let loaded = load_dir(&dir).unwrap();
        assert!(loaded.report.is_clean(), "{}", loaded.report);
        assert_eq!(loaded.trace.events, trace.events);
        assert_eq!(loaded.trace.n_workers, 2);
        assert_eq!(loaded.trace.n_procs, 2);
        assert_eq!(loaded.trace.iterations, 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn job_meta_roundtrips() {
        let dir = tmp_dir("jobmeta");
        let spec = JobSpec::standard("vgg16", "ps-tree", crate::config::Transport::Tcp);
        let meta = JobMeta::of(&spec);
        assert_eq!(meta.scheme, "ps-tree");
        assert_eq!(meta.transport, "tcp");
        // standard specs carry the unoptimized singleton plans
        assert_eq!(meta.plan, PLAN_PER_TENSOR);
        dump_dir_with_job(&toy_trace(), &dir, Some(&meta)).unwrap();
        let loaded = load_dir(&dir).unwrap();
        assert_eq!(loaded.job.as_ref(), Some(&meta));
        // a deployed-default spec is recognized as such
        let deployed = crate::baselines::deployed_default(&spec);
        assert_eq!(JobMeta::of(&deployed).plan, PLAN_DEPLOYED);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn redump_removes_stale_proc_files() {
        let dir = tmp_dir("redump");
        dump_dir(&toy_trace(), &dir).unwrap();
        // shrink the job to one process and dump into the same directory
        let mut small = toy_trace();
        small.events.retain(|e| e.proc == 0);
        small.n_procs = 1;
        small.n_workers = 1;
        let s = dump_dir(&small, &dir).unwrap();
        assert_eq!(s.files, 1);
        let loaded = load_dir(&dir).unwrap();
        // proc 1's old file must not leak into the new trace
        assert_eq!(loaded.trace.events.len(), 2);
        assert!(loaded.trace.events.iter().all(|e| e.proc == 0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_listed_file_is_a_worker_lost_warning() {
        let dir = tmp_dir("gone");
        dump_dir(&toy_trace(), &dir).unwrap();
        std::fs::remove_file(dir.join(proc_file_name(1))).unwrap();
        let loaded = load_dir(&dir).unwrap();
        // proc 1's events are gone; that is the minimal dead-worker dump,
        // and it must ingest as a diagnosed degradation, not an error
        assert_eq!(loaded.trace.events.len(), 2);
        assert_eq!(loaded.report.count(DiagKind::WorkerLost), 1);
        assert!(loaded.report.no_errors(), "{}", loaded.report);
        // the declared shape survives, so the lost proc stays visible
        assert_eq!(loaded.trace.n_workers, 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unlisted_json_files_are_scoped_out() {
        let dir = tmp_dir("scoped");
        dump_dir(&toy_trace(), &dir).unwrap();
        // a stale legacy single-file trace in the same directory must not
        // merge into the dump (metadata's file list scopes ingestion)
        toy_trace().save(dir.join("trace.json").to_str().unwrap()).unwrap();
        let loaded = load_dir(&dir).unwrap();
        assert_eq!(loaded.trace.events.len(), 4);
        assert!(loaded.report.count(DiagKind::MetadataMismatch) >= 1, "{}", loaded.report);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn negative_txid_and_seq_diagnosed_not_coerced() {
        let mut report = TraceReport::default();
        let text = r#"{ "traceEvents": [
            {"name": "w0.SEND.a", "ph": "X", "ts": 0, "dur": 5, "tid": 0, "pid": 0,
             "args": {"kind": "SEND", "iter": 0, "txid": -1, "seq": -3}}
        ]}"#;
        let events = parse_trace_file(text, "neg.json", &mut report).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].0, None, "negative seq must not become 0");
        assert_eq!(events[0].1.txid, None, "negative txid must not become 0");
        assert_eq!(report.count(DiagKind::InvalidValue), 2);
    }

    #[test]
    fn missing_metadata_is_inferred_with_note() {
        let dir = tmp_dir("nometa");
        dump_dir(&toy_trace(), &dir).unwrap();
        std::fs::remove_file(dir.join(METADATA_FILE)).unwrap();
        let loaded = load_dir(&dir).unwrap();
        assert_eq!(loaded.trace.events.len(), 4);
        assert_eq!(loaded.trace.n_procs, 2);
        assert_eq!(loaded.trace.iterations, 1);
        assert_eq!(loaded.report.count(DiagKind::MetadataMismatch), 1);
        assert!(loaded.report.no_errors());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn broken_events_are_skipped_not_fatal() {
        let mut report = TraceReport::default();
        let text = r#"{ "traceEvents": [
            {"name": "w0.FW.a", "ph": "X", "ts": 0, "dur": 10,
             "tid": 0, "args": {"kind": "FW", "iter": 0}},
            {"ph": "X", "ts": 5, "dur": 1, "tid": 0},
            {"name": "nan_ts", "ph": "X", "ts": null, "dur": 1, "tid": 0,
             "args": {"kind": "FW"}},
            {"name": "neg_dur", "ph": "X", "ts": 7, "dur": -3, "tid": 0,
             "args": {"kind": "FW"}},
            {"name": "meta", "ph": "M", "args": {"labels": "ignored"}},
            {"name": "mystery", "ph": "X", "ts": 9, "dur": 1, "tid": 0}
        ]}"#;
        let events = parse_trace_file(text, "f.json", &mut report).unwrap();
        // kept: w0.FW.a (machine inferred), neg_dur (clamped)
        assert_eq!(events.len(), 2);
        assert_eq!(report.events_skipped, 4);
        assert!(report.count(DiagKind::MissingField) >= 2);
        assert_eq!(report.count(DiagKind::NonFiniteTime), 1);
        assert_eq!(report.count(DiagKind::NegativeDuration), 1);
        assert_eq!(report.count(DiagKind::NonCompleteEvent), 1);
        assert_eq!(report.count(DiagKind::UnknownKind), 1);
        assert_eq!(events[1].1.dur, 0.0);
    }

    #[test]
    fn bare_array_and_kind_inference_accepted() {
        let mut report = TraceReport::default();
        let text = r#"[
            {"name": "w0.BW.conv", "ph": "X", "ts": 0, "dur": 10, "tid": 0, "pid": 0}
        ]"#;
        let events = parse_trace_file(text, "bare.json", &mut report).unwrap();
        assert_eq!(events.len(), 1);
        assert!(parse_trace_file("not json", "bad.json", &mut report).is_none());
        assert_eq!(events[0].1.kind, OpKind::Backward);
        assert_eq!(events[0].0, None); // no seq
    }

    #[test]
    fn load_mem_matches_load_dir_bit_for_bit() {
        let dir = tmp_dir("mem");
        let spec = JobSpec::standard("vgg16", "ps-tree", crate::config::Transport::Tcp);
        dump_dir_with_job(&toy_trace(), &dir, Some(&JobMeta::of(&spec))).unwrap();
        let from_disk = load_dir(&dir).unwrap();
        let files: Vec<(String, String)> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| {
                let name = e.file_name().into_string().unwrap();
                let text = std::fs::read_to_string(e.path()).unwrap();
                (name, text)
            })
            .collect();
        let from_mem = load_mem(&files).unwrap();
        assert!(from_mem.report.is_clean(), "{}", from_mem.report);
        assert_eq!(from_mem.trace.events, from_disk.trace.events);
        assert_eq!(from_mem.trace.n_workers, from_disk.trace.n_workers);
        assert_eq!(from_mem.job, from_disk.job);
        // an upload with no usable files is the hard error, same as a dir
        assert!(load_mem(&[]).is_err());
        assert!(load_mem(&[("notes.txt".into(), "hi".into())]).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_dir_errors_only_on_unusable_directories() {
        let dir = tmp_dir("empty");
        assert!(load_dir(&dir).is_err()); // does not exist
        std::fs::create_dir_all(&dir).unwrap();
        assert!(load_dir(&dir).is_err()); // no json files
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
