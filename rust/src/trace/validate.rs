//! Trace validation: structural checks over an ingested [`GTrace`] whose
//! findings are *collected*, never panicked — external and hand-edited
//! traces are untrusted input (the Daydream-style what-if workflow edits
//! dumps by hand), so every anomaly becomes a typed [`Diagnostic`] in a
//! [`TraceReport`] and the pipeline keeps going with whatever is usable.
//!
//! The reader ([`crate::trace::io`]) feeds per-event parse findings into
//! the same report; [`validate`] adds the cross-event checks that only
//! make sense once the whole directory is assembled (SEND↔RECV txid
//! pairing, same-GPU overlap, iteration gaps).

use std::collections::{BTreeMap, HashMap, HashSet};

use crate::graph::dfg::OpKind;
use crate::trace::GTrace;
use crate::util::json::Json;

/// How bad a [`Diagnostic`] is.
///
/// `Error` means data was dropped or unusable; `Warning` means the trace
/// is suspicious but every event was kept; `Info` is a note (e.g. a
/// tolerated legacy file without sequence numbers).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Note only; the trace is fully usable.
    Info,
    /// Suspicious data kept as-is (e.g. overlapping compute events).
    Warning,
    /// Data was skipped or cannot be interpreted.
    Error,
}

impl Severity {
    /// Lower-case label used in reports and JSON output.
    pub fn name(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// The closed set of anomaly classes the pipeline detects.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum DiagKind {
    /// A file could not be read from disk.
    Io,
    /// A file was not valid JSON (the whole file is skipped).
    Parse,
    /// An event lacked a required field (`name`, `ts`, `dur`) and was
    /// skipped.
    MissingField,
    /// An event's `args.kind` was absent or unknown and could not be
    /// inferred from the op name; the event was skipped.
    UnknownKind,
    /// A timestamp or duration was NaN/±Inf; the event was skipped.
    NonFiniteTime,
    /// A negative duration was clamped to zero.
    NegativeDuration,
    /// A field held an out-of-domain value (e.g. a negative txid/seq,
    /// which was ignored rather than saturated to 0).
    InvalidValue,
    /// An event with `ph != "X"` was ignored (counter/metadata events from
    /// other tools are tolerated, not interpreted).
    NonCompleteEvent,
    /// A SEND without a matching RECV on the same `(txid, iter)`, or the
    /// converse — dropped events or a hand-edit broke the pairing.
    UnmatchedTxid,
    /// Two SENDs (or two RECVs) share one `(txid, iter)` key.
    DuplicateTxid,
    /// Two computation events on one process overlap in time — a single
    /// GPU cannot run two kernels at once, so either the trace is degraded
    /// (straggler/preemption artifact) or clocks are inconsistent.
    OverlapOnProc,
    /// Events carried no `args.seq`; the reader fell back to a
    /// deterministic `(iter, ts, proc)` sort, which may not reproduce the
    /// recorder's exact event order (bit-for-bit replay is not guaranteed).
    MissingSeq,
    /// Per-file or per-event data disagreed with `metadata.json`
    /// (unknown proc id, iteration beyond the declared count, ...).
    MetadataMismatch,
    /// Observed iteration numbers are not contiguous from 0.
    IterationGap,
    /// Graph ops without a measured duration in the trace (dropped events,
    /// partial dumps): the diagnosis/replay pipeline fell back to analytic
    /// estimates for them, so blame attributed to those ops is
    /// model-derived, not measured.
    MissingProfile,
    /// A worker stopped emitting events before the trace ended (or its
    /// per-process dump file is missing/empty) — the on-disk signature of
    /// a crashed worker or lost machine. The trace still ingests; the
    /// diagnosis engine attributes the fault and offers the
    /// `continue-on:<k>` what-if (see `docs/FAULTS.md`).
    WorkerLost,
    /// One machine's SEND/RECV durations are several times the fleet
    /// median — a degraded or flapping NIC rather than a slow kernel.
    LinkDegraded,
}

impl DiagKind {
    /// Stable snake_case key used in JSON reports (schema-stable: tests
    /// and CI key off these names).
    pub fn name(self) -> &'static str {
        match self {
            DiagKind::Io => "io",
            DiagKind::Parse => "parse",
            DiagKind::MissingField => "missing_field",
            DiagKind::UnknownKind => "unknown_kind",
            DiagKind::NonFiniteTime => "non_finite_time",
            DiagKind::NegativeDuration => "negative_duration",
            DiagKind::InvalidValue => "invalid_value",
            DiagKind::NonCompleteEvent => "non_complete_event",
            DiagKind::UnmatchedTxid => "unmatched_txid",
            DiagKind::DuplicateTxid => "duplicate_txid",
            DiagKind::OverlapOnProc => "overlap_on_proc",
            DiagKind::MissingSeq => "missing_seq",
            DiagKind::MetadataMismatch => "metadata_mismatch",
            DiagKind::IterationGap => "iteration_gap",
            DiagKind::MissingProfile => "missing_profile",
            DiagKind::WorkerLost => "worker_lost",
            DiagKind::LinkDegraded => "link_degraded",
        }
    }
}

/// One finding: what happened, how bad it is, and where.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Severity class (drives exit codes and report summaries).
    pub severity: Severity,
    /// Anomaly class.
    pub kind: DiagKind,
    /// Human-readable context (file, event name, values involved).
    pub detail: String,
}

/// Cap on stored `detail` strings *per kind*: a 100k-event trace with a
/// systematic defect should report one class with a count, not 100k
/// strings. Counts in [`TraceReport::counts`] are always exact.
pub const MAX_DETAILS_PER_KIND: usize = 16;

/// Everything the reader and validator found while ingesting a trace.
#[derive(Clone, Debug, Default)]
pub struct TraceReport {
    /// Trace files successfully parsed.
    pub files: usize,
    /// Events kept in the assembled [`GTrace`].
    pub events_loaded: usize,
    /// Events present in the input but skipped as unusable.
    pub events_skipped: usize,
    /// Stored findings (detail strings capped per kind, counts exact).
    pub diagnostics: Vec<Diagnostic>,
    counts: BTreeMap<DiagKind, usize>,
    /// Tracked across *all* pushes — detail capping must not hide an
    /// Error that arrived after a kind's cap was reached.
    worst: Option<Severity>,
}

impl TraceReport {
    /// Record a finding. The exact per-kind count is always kept; the
    /// detail string is stored only for the first
    /// [`MAX_DETAILS_PER_KIND`] findings of that kind.
    pub fn push(&mut self, severity: Severity, kind: DiagKind, detail: impl Into<String>) {
        self.worst = Some(self.worst.map_or(severity, |w| w.max(severity)));
        let n = self.counts.entry(kind).or_insert(0);
        *n += 1;
        if *n <= MAX_DETAILS_PER_KIND {
            self.diagnostics.push(Diagnostic { severity, kind, detail: detail.into() });
        }
    }

    /// Exact number of findings of `kind` (independent of the detail cap).
    pub fn count(&self, kind: DiagKind) -> usize {
        self.counts.get(&kind).copied().unwrap_or(0)
    }

    /// Highest severity among all findings, if any — exact even past the
    /// per-kind detail cap.
    pub fn max_severity(&self) -> Option<Severity> {
        self.worst
    }

    /// True when nothing at all was flagged.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// True when no data was lost (warnings and notes allowed).
    pub fn no_errors(&self) -> bool {
        self.max_severity().map_or(true, |s| s < Severity::Error)
    }

    /// JSON form with a stable schema: `files`, `events_loaded`,
    /// `events_skipped`, `max_severity`, `counts` (kind → exact count) and
    /// `details` (capped list of `{severity, kind, detail}`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("files", Json::Num(self.files as f64));
        j.set("events_loaded", Json::Num(self.events_loaded as f64));
        j.set("events_skipped", Json::Num(self.events_skipped as f64));
        j.set(
            "max_severity",
            match self.max_severity() {
                Some(s) => Json::Str(s.name().to_string()),
                None => Json::Null,
            },
        );
        let mut counts = Json::obj();
        for (&k, &n) in &self.counts {
            counts.set(k.name(), Json::Num(n as f64));
        }
        j.set("counts", counts);
        let details: Vec<Json> = self
            .diagnostics
            .iter()
            .map(|d| {
                let mut o = Json::obj();
                o.set("severity", Json::Str(d.severity.name().to_string()));
                o.set("kind", Json::Str(d.kind.name().to_string()));
                o.set("detail", Json::Str(d.detail.clone()));
                o
            })
            .collect();
        j.set("details", Json::Arr(details));
        j
    }

    /// One-line human summary for CLI output.
    pub fn summary(&self) -> String {
        if self.is_clean() {
            format!("{} events from {} files, no diagnostics", self.events_loaded, self.files)
        } else {
            let by_kind: Vec<String> =
                self.counts.iter().map(|(k, n)| format!("{}×{}", n, k.name())).collect();
            format!(
                "{} events from {} files ({} skipped); diagnostics: {}",
                self.events_loaded,
                self.files,
                self.events_skipped,
                by_kind.join(", ")
            )
        }
    }
}

impl std::fmt::Display for TraceReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.summary())
    }
}

/// Tolerance for the same-GPU overlap check (us): sub-microsecond overlap
/// is serialization noise, not an anomaly.
const OVERLAP_EPS_US: f64 = 1.0;

/// Cross-event structural checks on an assembled trace. Appends findings
/// to `report`; never panics, never mutates the trace.
///
/// Checks: SEND↔RECV `(txid, iter)` pairing (unmatched and duplicate
/// transactions), overlap between computation events on one process, and
/// iteration contiguity.
pub fn validate(trace: &GTrace, report: &mut TraceReport) {
    // --- SEND↔RECV pairing on (txid, iter) ---
    let mut sends: HashMap<(u64, u32), u32> = HashMap::new();
    let mut recvs: HashMap<(u64, u32), u32> = HashMap::new();
    for e in &trace.events {
        let Some(t) = e.txid else { continue };
        match e.kind {
            OpKind::Send => *sends.entry((t, e.iter)).or_insert(0) += 1,
            OpKind::Recv => *recvs.entry((t, e.iter)).or_insert(0) += 1,
            _ => {}
        }
    }
    for (&(t, it), &n) in &sends {
        if n > 1 {
            report.push(
                Severity::Warning,
                DiagKind::DuplicateTxid,
                format!("{n} SENDs share txid {t} in iter {it}"),
            );
        }
        if !recvs.contains_key(&(t, it)) {
            report.push(
                Severity::Warning,
                DiagKind::UnmatchedTxid,
                format!("SEND txid {t} iter {it} has no RECV"),
            );
        }
    }
    for (&(t, it), &n) in &recvs {
        if n > 1 {
            report.push(
                Severity::Warning,
                DiagKind::DuplicateTxid,
                format!("{n} RECVs share txid {t} in iter {it}"),
            );
        }
        if !sends.contains_key(&(t, it)) {
            report.push(
                Severity::Warning,
                DiagKind::UnmatchedTxid,
                format!("RECV txid {t} iter {it} has no SEND"),
            );
        }
    }

    // --- computation overlap per process ---
    // Communication events legitimately overlap compute (separate NIC /
    // NVLink engines share the proc id) and RECVs carry launch-time
    // inflation by design, so only FW/BW/UPD — which serialize on the one
    // GPU — are checked.
    let mut per_proc: HashMap<u16, Vec<(f64, f64, &str)>> = HashMap::new();
    for e in &trace.events {
        if matches!(e.kind, OpKind::Forward | OpKind::Backward | OpKind::Update)
            && e.ts.is_finite()
            && e.dur.is_finite()
        {
            per_proc.entry(e.proc).or_default().push((e.ts, e.ts + e.dur, e.name.as_str()));
        }
    }
    for (proc, mut spans) in per_proc {
        spans.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        // running max end catches overlaps with *any* earlier event, not
        // just the immediate predecessor (one long straggler kernel can
        // cover many successors)
        let mut max_end = f64::NEG_INFINITY;
        let mut max_name = "";
        for &(st, en, name) in &spans {
            if max_end > st + OVERLAP_EPS_US {
                report.push(
                    Severity::Warning,
                    DiagKind::OverlapOnProc,
                    format!(
                        "proc {proc}: {max_name} [..{max_end:.1}] overlaps {name} [{st:.1}..]"
                    ),
                );
            }
            if en > max_end {
                max_end = en;
                max_name = name;
            }
        }
    }

    // --- iteration contiguity ---
    let iters: HashSet<u32> = trace.events.iter().map(|e| e.iter).collect();
    if let Some(&max) = iters.iter().max() {
        let missing: Vec<u32> = (0..=max).filter(|i| !iters.contains(i)).collect();
        if !missing.is_empty() {
            report.push(
                Severity::Info,
                DiagKind::IterationGap,
                format!("iterations missing below {max}: {missing:?}"),
            );
        }
        if trace.iterations > 0 && (max as usize) >= trace.iterations {
            report.push(
                Severity::Warning,
                DiagKind::MetadataMismatch,
                format!(
                    "event iteration {max} outside declared iteration count {}",
                    trace.iterations
                ),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceEvent;

    fn ev(name: &str, kind: OpKind, proc: u16, ts: f64, dur: f64, txid: Option<u64>) -> TraceEvent {
        TraceEvent { name: name.into(), kind, ts, dur, proc, machine: 0, iter: 0, txid }
    }

    #[test]
    fn clean_trace_reports_nothing() {
        let trace = GTrace {
            events: vec![
                ev("w0.FW.a", OpKind::Forward, 0, 0.0, 10.0, None),
                ev("w0.SEND.t", OpKind::Send, 0, 10.0, 5.0, Some(1)),
                ev("w1.RECV.t", OpKind::Recv, 1, 11.0, 6.0, Some(1)),
            ],
            n_workers: 2,
            n_procs: 2,
            iterations: 1,
        };
        let mut r = TraceReport::default();
        validate(&trace, &mut r);
        assert!(r.is_clean(), "{r}");
        assert!(r.no_errors());
        assert_eq!(r.max_severity(), None);
    }

    #[test]
    fn unmatched_and_duplicate_txids_flagged() {
        let trace = GTrace {
            events: vec![
                ev("w0.SEND.a", OpKind::Send, 0, 0.0, 5.0, Some(1)),
                ev("w0.SEND.b", OpKind::Send, 0, 6.0, 5.0, Some(2)),
                ev("w1.RECV.b", OpKind::Recv, 1, 6.0, 9.0, Some(2)),
                ev("w1.RECV.b2", OpKind::Recv, 1, 16.0, 9.0, Some(2)),
            ],
            n_workers: 2,
            n_procs: 2,
            iterations: 1,
        };
        let mut r = TraceReport::default();
        validate(&trace, &mut r);
        assert_eq!(r.count(DiagKind::UnmatchedTxid), 1); // SEND 1 unanswered
        assert_eq!(r.count(DiagKind::DuplicateTxid), 1); // two RECVs on 2
        assert!(r.no_errors()); // warnings, not errors
    }

    #[test]
    fn comp_overlap_flagged_but_comm_overlap_ignored() {
        let trace = GTrace {
            events: vec![
                ev("w0.FW.a", OpKind::Forward, 0, 0.0, 10.0, None),
                ev("w0.FW.b", OpKind::Forward, 0, 5.0, 10.0, None),
                // comm overlapping compute is fine (different engine)
                ev("w0.SEND.t", OpKind::Send, 0, 2.0, 30.0, Some(1)),
                ev("w1.RECV.t", OpKind::Recv, 1, 2.0, 30.0, Some(1)),
            ],
            n_workers: 2,
            n_procs: 2,
            iterations: 1,
        };
        let mut r = TraceReport::default();
        validate(&trace, &mut r);
        assert_eq!(r.count(DiagKind::OverlapOnProc), 1);
        assert_eq!(r.count(DiagKind::UnmatchedTxid), 0);
    }

    #[test]
    fn iteration_gap_noted() {
        let mut e0 = ev("w0.FW.a", OpKind::Forward, 0, 0.0, 1.0, None);
        let mut e2 = ev("w0.FW.a", OpKind::Forward, 0, 100.0, 1.0, None);
        e0.iter = 0;
        e2.iter = 2;
        let trace = GTrace { events: vec![e0, e2], n_workers: 1, n_procs: 1, iterations: 3 };
        let mut r = TraceReport::default();
        validate(&trace, &mut r);
        assert_eq!(r.count(DiagKind::IterationGap), 1);
        assert_eq!(r.max_severity(), Some(Severity::Info));
    }

    #[test]
    fn detail_cap_keeps_exact_counts() {
        let mut r = TraceReport::default();
        for i in 0..100 {
            r.push(Severity::Warning, DiagKind::UnmatchedTxid, format!("d{i}"));
        }
        assert_eq!(r.count(DiagKind::UnmatchedTxid), 100);
        assert_eq!(r.diagnostics.len(), MAX_DETAILS_PER_KIND);
        let j = r.to_json();
        assert_eq!(j.get("counts").unwrap().f64("unmatched_txid"), 100.0);
    }

    #[test]
    fn severity_tracked_past_detail_cap() {
        let mut r = TraceReport::default();
        // fill the MissingField cap with warnings, then push an Error of
        // the same kind: it must still dominate max_severity
        for i in 0..MAX_DETAILS_PER_KIND {
            r.push(Severity::Warning, DiagKind::MissingField, format!("w{i}"));
        }
        assert!(r.no_errors());
        r.push(Severity::Error, DiagKind::MissingField, "dropped event");
        assert_eq!(r.max_severity(), Some(Severity::Error));
        assert!(!r.no_errors());
        assert_eq!(r.diagnostics.len(), MAX_DETAILS_PER_KIND);
    }
}
