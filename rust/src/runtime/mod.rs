//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from Rust. Python is never on
//! this path — the HLO text is parsed, compiled and run by the `xla`
//! crate's PJRT CPU client (see /opt/xla-example/load_hlo).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{parse, Json};

/// A compiled executable plus its name (for reporting).
pub struct Artifact {
    /// Artifact file name (reporting/diagnostics).
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with literal inputs; the artifact returns one tuple (aot.py
    /// lowers with `return_tuple=True`) which is decomposed into leaves.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<L>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// PJRT client wrapper; one per process.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// The host-CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Artifact> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {path:?}"))?;
        Ok(Artifact {
            name: path.file_name().unwrap().to_string_lossy().to_string(),
            exe,
        })
    }
}

/// Parameter metadata from `gpt_<cfg>.meta.json`.
#[derive(Clone, Debug)]
pub struct ParamMeta {
    /// Parameter (pytree leaf) name.
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Element count.
    pub size: usize,
}

/// Model/config metadata exported next to the HLO artifacts.
#[derive(Clone, Debug)]
pub struct GptMeta {
    /// Config name (`mini`, `m100`, ...).
    pub config: String,
    /// Per-worker batch size the artifacts were lowered for.
    pub batch_size: usize,
    /// Sequence length.
    pub seq_len: usize,
    /// Model dimension.
    pub hidden: usize,
    /// Decoder layer count.
    pub layers: usize,
    /// Attention head count.
    pub heads: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Optimizer-state leaves appended after the params in `init` output.
    pub n_state_leaves: usize,
    /// Per-parameter metadata, in pytree order.
    pub params: Vec<ParamMeta>,
}

impl GptMeta {
    /// Number of parameter leaves.
    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Total parameter element count.
    pub fn total_elems(&self) -> usize {
        self.params.iter().map(|p| p.size).sum()
    }

    /// Load `gpt_<config>.meta.json` from the artifacts directory.
    pub fn load(dir: &Path, config: &str) -> Result<GptMeta> {
        let path = dir.join(format!("gpt_{config}.meta.json"));
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
        let j = parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing params"))?
            .iter()
            .map(|p| ParamMeta {
                name: p.str("name").to_string(),
                shape: p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .unwrap()
                    .iter()
                    .map(|d| d.as_f64().unwrap() as usize)
                    .collect(),
                size: p.f64("size") as usize,
            })
            .collect();
        Ok(GptMeta {
            config: j.str("config").to_string(),
            batch_size: j.f64("batch_size") as usize,
            seq_len: j.f64("seq_len") as usize,
            hidden: j.f64("hidden") as usize,
            layers: j.f64("layers") as usize,
            heads: j.f64("heads") as usize,
            vocab: j.f64("vocab") as usize,
            n_state_leaves: j.f64("n_state_leaves") as usize,
            params,
        })
    }
}

/// The full artifact bundle for one model config.
pub struct GptArtifacts {
    /// Config + parameter metadata.
    pub meta: GptMeta,
    /// Parameter/optimizer-state initializer.
    pub init: Artifact,
    /// Loss + gradients of one micro-batch.
    pub grad: Artifact,
    /// Optimizer update from averaged gradients.
    pub apply: Artifact,
    /// Fused single-worker train step (init→grad→apply in one program).
    pub train: Artifact,
}

impl GptArtifacts {
    /// Compile all four artifacts of a config.
    pub fn load(rt: &Runtime, dir: impl Into<PathBuf>, config: &str) -> Result<GptArtifacts> {
        let dir: PathBuf = dir.into();
        let meta = GptMeta::load(&dir, config)?;
        let load = |kind: &str| rt.load(&dir.join(format!("gpt_{config}.{kind}.hlo.txt")));
        Ok(GptArtifacts {
            meta,
            init: load("init")?,
            grad: load("grad")?,
            apply: load("apply")?,
            train: load("train")?,
        })
    }
}

/// Build an `[batch, seq]` i32 literal from row-major token ids.
pub fn tokens_literal(tokens: &[i32], batch: usize, seq: usize) -> Result<xla::Literal> {
    assert_eq!(tokens.len(), batch * seq);
    Ok(xla::Literal::vec1(tokens).reshape(&[batch as i64, seq as i64])?)
}

/// Extract a scalar f32 (e.g. the loss) from a literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    v.first().copied().ok_or_else(|| anyhow!("empty literal"))
}
